"""Batched serving of a butterfly-sparse model: prefill + decode with KV
caches through the ServeLoop driver.

    PYTHONPATH=src python examples/serve_butterfly.py
"""

import dataclasses

import jax
import numpy as np

from repro.configs import registry
from repro.launch.mesh import make_local_mesh
from repro.launch.serve import Request, ServeLoop
from repro.models import model as M

cfg = registry.get("qwen3-0.6b+bpmm", reduced=True)
cfg = dataclasses.replace(cfg, dtype="float32")
mesh = make_local_mesh()
params = M.init_params(cfg, jax.random.PRNGKey(0))

loop = ServeLoop(cfg, mesh, params, batch=4, cache_len=64)
requests = [
    Request(uid=i, prompt=np.arange(3 + i, dtype=np.int32) % cfg.vocab, max_new=8)
    for i in range(4)
]
done = loop.run(requests)
for r in done:
    print(f"request {r.uid}: prompt={list(r.prompt)} -> generated={r.generated}")
