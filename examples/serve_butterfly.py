"""Streaming serving of a butterfly-sparse model: more requests than slots
flow through ALL THREE engine modes — the admission-prefill engine (slots
admit, evict, re-admit mid-stream), the chunked mixed-step engine (prompts
stream in chunks while decode rows keep sampling; zero decode stalls), and
the paged engine (one global page pool, per-request tile-granular page
tables; capacity priced at live pages instead of batch x cache_len) — and
must generate identical tokens.  A fourth run serves a SLIDING-WINDOW
config through the paged engine's mod-window ring tables and must match
the contiguous ring engine token for token.  A fifth run overloads a tiny
page pool with mixed priorities: the scheduler preempts the youngest batch
request for an interactive arrival and resumes it, still token-complete.

    PYTHONPATH=src python examples/serve_butterfly.py
"""

import dataclasses

import jax
import numpy as np

from repro.configs import registry
from repro.launch.mesh import make_local_mesh
from repro.launch.serve import Request, ServeLoop
from repro.models import model as M

cfg = registry.get("qwen3-0.6b+bpmm", reduced=True)
cfg = dataclasses.replace(cfg, dtype="float32")
mesh = make_local_mesh()
params = M.init_params(cfg, jax.random.PRNGKey(0))


def requests():
    # 6 mixed-length requests through 2 slots
    return [
        Request(
            uid=i,
            prompt=np.arange(3 + 2 * i, dtype=np.int32) % cfg.vocab,
            max_new=2 + i % 4,
        )
        for i in range(6)
    ]


# ``with`` closes each engine on exit — even when an assertion below fires —
# releasing prefix-cache references and verifying the page pools drain
with ServeLoop(cfg, mesh, params, batch=2, cache_len=32) as loop:
    done = loop.run(requests())
    for r in done:
        print(f"request {r.uid}: prompt_len={len(r.prompt)} -> generated={r.generated}")
    print(f"admission engine: {loop.stats['prefill_calls']} prefills, "
          f"{loop.stats['decode_steps']} ragged decode steps, "
          f"{loop.stats['admission_stall_steps']} admission stalls")

with ServeLoop(
    cfg, mesh, params, batch=2, cache_len=32, chunked=True, chunk_size=8
) as chunked:
    done_ch = chunked.run(requests())
    assert [r.generated for r in done_ch] == [r.generated for r in done], \
        "chunked scheduling changed the tokens"
    print(f"chunked engine:   {chunked.stats['mixed_steps']} mixed steps "
          f"({chunked.stats['prefill_tokens']} prompt tokens streamed, "
          f"{chunked.stats['decode_tokens']} decoded), "
          f"{chunked.stats['decode_stall_steps']} decode stalls — token-identical")

with ServeLoop(
    cfg, mesh, params, batch=2, cache_len=32, chunked=True, chunk_size=8,
    paged=True,
) as paged:
    done_pg = paged.run(requests())
    assert [r.generated for r in done_pg] == [r.generated for r in done], \
        "page-table indirection changed the tokens"
    print(f"paged engine:     {paged.stats['mixed_steps']} mixed steps, "
          f"{paged.stats['pool_peak_pages']}/{paged.stats['pool_pages']} peak "
          f"pages resident ({paged.stats['page_allocs']} allocs) — "
          f"token-identical across all three engines")

# sliding window: the XLA reference (contiguous per-slot ring rows) vs the
# paged engine's mod-window ring page table — absolute tile j lives in page-
# table slot j % ring_tiles, decode laps the ring, tokens must not move
wcfg = dataclasses.replace(cfg, sliding_window=10)
wparams = M.init_params(wcfg, jax.random.PRNGKey(0))
with ServeLoop(wcfg, mesh, wparams, batch=2, cache_len=32) as wref:
    done_wr = wref.run(requests())
with ServeLoop(wcfg, mesh, wparams, batch=2, cache_len=32, paged=True) as wring:
    done_wp = wring.run(requests())
    assert [r.generated for r in done_wp] == [r.generated for r in done_wr], \
        "mod-window ring paging changed the tokens"
    print(f"windowed paged:   window={wcfg.sliding_window}, "
          f"ring_tiles={wring.ring_tiles}, "
          f"{wring.stats['pool_peak_pages']}/{wring.stats['pool_pages']} peak "
          f"pages resident — token-identical to the contiguous ring reference")

# priority scheduling under pool pressure: two long batch prompts fill a
# 4-page pool; a late interactive request preempts the youngest (its pages
# are donated to the radix tree and it resumes, token-identically)
rng = np.random.default_rng(0)
pressure = [
    Request(uid=0, priority="batch", max_new=8, arrival=0,
            prompt=rng.integers(0, cfg.vocab, size=200).astype(np.int32)),
    Request(uid=1, priority="batch", max_new=8, arrival=0,
            prompt=rng.integers(0, cfg.vocab, size=200).astype(np.int32)),
    Request(uid=2, priority="interactive", max_new=4, arrival=4,
            prompt=rng.integers(0, cfg.vocab, size=100).astype(np.int32)),
]
with ServeLoop(cfg, mesh, params, batch=3, cache_len=512, chunked=True,
               chunk_size=32, paged=True, pool_pages=4) as prio:
    done_pr = prio.run(pressure)
    slo = prio.stats["slo"]
    print(f"priority engine:  {prio.stats['preemptions']} preemptions / "
          f"{prio.stats['resumes']} resumes at a 4-page pool; interactive "
          f"p99 TTFT {slo['interactive']['ttft_p99']:.0f} clocks vs batch "
          f"{slo['batch']['ttft_p99']:.0f} — every request completed "
          f"({sum(len(r.generated) for r in done_pr)} tokens)")
