"""Continuous-batching serving of a butterfly-sparse model: more requests
than slots stream through the ragged engine — short requests retire and hand
their slot to the queue mid-stream.

    PYTHONPATH=src python examples/serve_butterfly.py
"""

import dataclasses

import jax
import numpy as np

from repro.configs import registry
from repro.launch.mesh import make_local_mesh
from repro.launch.serve import Request, ServeLoop
from repro.models import model as M

cfg = registry.get("qwen3-0.6b+bpmm", reduced=True)
cfg = dataclasses.replace(cfg, dtype="float32")
mesh = make_local_mesh()
params = M.init_params(cfg, jax.random.PRNGKey(0))

# 6 mixed-length requests through 2 slots: the engine admits, evicts, and
# re-admits without ever stalling a live slot on the longest request
loop = ServeLoop(cfg, mesh, params, batch=2, cache_len=32)
requests = [
    Request(
        uid=i,
        prompt=np.arange(3 + 2 * i, dtype=np.int32) % cfg.vocab,
        max_new=2 + i % 4,
    )
    for i in range(6)
]
done = loop.run(requests)
for r in done:
    print(f"request {r.uid}: prompt_len={len(r.prompt)} -> generated={r.generated}")
print(f"engine: {loop.stats['prefill_calls']} prefills, "
      f"{loop.stats['decode_steps']} ragged decode steps")
