"""Streaming serving of a butterfly-sparse model: more requests than slots
flow through ALL THREE engine modes — the admission-prefill engine (slots
admit, evict, re-admit mid-stream), the chunked mixed-step engine (prompts
stream in chunks while decode rows keep sampling; zero decode stalls), and
the paged engine (one global page pool, per-request tile-granular page
tables; capacity priced at live pages instead of batch x cache_len) — and
must generate identical tokens.  A fourth run serves a SLIDING-WINDOW
config through the paged engine's mod-window ring tables and must match
the contiguous ring engine token for token.

    PYTHONPATH=src python examples/serve_butterfly.py
"""

import dataclasses

import jax
import numpy as np

from repro.configs import registry
from repro.launch.mesh import make_local_mesh
from repro.launch.serve import Request, ServeLoop
from repro.models import model as M

cfg = registry.get("qwen3-0.6b+bpmm", reduced=True)
cfg = dataclasses.replace(cfg, dtype="float32")
mesh = make_local_mesh()
params = M.init_params(cfg, jax.random.PRNGKey(0))


def requests():
    # 6 mixed-length requests through 2 slots
    return [
        Request(
            uid=i,
            prompt=np.arange(3 + 2 * i, dtype=np.int32) % cfg.vocab,
            max_new=2 + i % 4,
        )
        for i in range(6)
    ]


loop = ServeLoop(cfg, mesh, params, batch=2, cache_len=32)
done = loop.run(requests())
for r in done:
    print(f"request {r.uid}: prompt_len={len(r.prompt)} -> generated={r.generated}")
print(f"admission engine: {loop.stats['prefill_calls']} prefills, "
      f"{loop.stats['decode_steps']} ragged decode steps, "
      f"{loop.stats['admission_stall_steps']} admission stalls")

chunked = ServeLoop(
    cfg, mesh, params, batch=2, cache_len=32, chunked=True, chunk_size=8
)
done_ch = chunked.run(requests())
assert [r.generated for r in done_ch] == [r.generated for r in done], \
    "chunked scheduling changed the tokens"
print(f"chunked engine:   {chunked.stats['mixed_steps']} mixed steps "
      f"({chunked.stats['prefill_tokens']} prompt tokens streamed, "
      f"{chunked.stats['decode_tokens']} decoded), "
      f"{chunked.stats['decode_stall_steps']} decode stalls — token-identical")

paged = ServeLoop(
    cfg, mesh, params, batch=2, cache_len=32, chunked=True, chunk_size=8,
    paged=True,
)
done_pg = paged.run(requests())
assert [r.generated for r in done_pg] == [r.generated for r in done], \
    "page-table indirection changed the tokens"
print(f"paged engine:     {paged.stats['mixed_steps']} mixed steps, "
      f"{paged.stats['pool_peak_pages']}/{paged.stats['pool_pages']} peak "
      f"pages resident ({paged.stats['page_allocs']} allocs) — "
      f"token-identical across all three engines")
paged.close()

# sliding window: the XLA reference (contiguous per-slot ring rows) vs the
# paged engine's mod-window ring page table — absolute tile j lives in page-
# table slot j % ring_tiles, decode laps the ring, tokens must not move
wcfg = dataclasses.replace(cfg, sliding_window=10)
wparams = M.init_params(wcfg, jax.random.PRNGKey(0))
wref = ServeLoop(wcfg, mesh, wparams, batch=2, cache_len=32)
done_wr = wref.run(requests())
wring = ServeLoop(wcfg, mesh, wparams, batch=2, cache_len=32, paged=True)
done_wp = wring.run(requests())
assert [r.generated for r in done_wp] == [r.generated for r in done_wr], \
    "mod-window ring paging changed the tokens"
print(f"windowed paged:   window={wcfg.sliding_window}, "
      f"ring_tiles={wring.ring_tiles}, "
      f"{wring.stats['pool_peak_pages']}/{wring.stats['pool_pages']} peak "
      f"pages resident — token-identical to the contiguous ring reference")
wring.close()
