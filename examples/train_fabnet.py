"""End-to-end driver: train FABNet (the paper's benchmark model — 2D-FFT
attention + BPMM FFN) on the synthetic pipeline, with checkpoints and
auto-resume.

Full run (~110M-param dense-equivalent model, a few hundred steps):

    PYTHONPATH=src python examples/train_fabnet.py --steps 300 --batch 16 --seq 256

Smoke run (reduced config, finishes on a laptop CPU in ~a minute):

    PYTHONPATH=src python examples/train_fabnet.py --reduced --steps 40
"""

import argparse
import dataclasses
import logging

from repro.configs import registry
from repro.data.pipeline import DataConfig
from repro.launch.mesh import make_local_mesh
from repro.launch.train import TrainHParams, train_loop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_fabnet_ckpt")
    args = ap.parse_args()

    logging.basicConfig(level=logging.INFO, format="%(asctime)s %(message)s")
    cfg = registry.get("fabnet-base", reduced=args.reduced)
    cfg = dataclasses.replace(cfg, remat=False)
    mesh = make_local_mesh()
    hp = TrainHParams(peak_lr=args.lr, warmup=20, total_steps=args.steps)
    dc = DataConfig(vocab=cfg.vocab, seq_len=args.seq, global_batch=args.batch)

    state, hist = train_loop(
        cfg, mesh, hp, dc, steps=args.steps, ckpt_dir=args.ckpt_dir, ckpt_every=50
    )
    print(f"\nFABNet trained {args.steps} steps: loss {hist[0]:.3f} -> {hist[-1]:.3f}")
    print(f"checkpoints in {args.ckpt_dir} (rerun the same command to resume)")


if __name__ == "__main__":
    main()
