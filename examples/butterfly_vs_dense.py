"""Ablation (paper Fig. 11 / Table II proxy): dense vs BPMM vs BPMM+FFT on
the same task — parameters, model flops, modeled v5e step time, and training
convergence on the synthetic stream.

    PYTHONPATH=src python examples/butterfly_vs_dense.py --steps 30
"""

import argparse
import dataclasses

from repro.configs import registry
from repro.core.api import ButterflyPolicy
from repro.data.pipeline import DataConfig
from repro.launch.mesh import make_local_mesh
from repro.launch.train import TrainHParams, train_loop
from repro.models import model as M


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=30)
    args = ap.parse_args()

    base = dataclasses.replace(registry.get("fabnet-base", reduced=True), remat=False)
    variants = {
        "dense": dataclasses.replace(base, butterfly=ButterflyPolicy()),
        "bpmm(ffn)": base,  # fabnet reduced ships with monarch FFN + FFT attn
        "bpmm(all)": dataclasses.replace(
            base,
            butterfly=ButterflyPolicy(impl="monarch", fft_attention=True, max_block=32),
        ),
    }
    mesh = make_local_mesh()
    print(f"{'variant':12s} {'params':>10s} {'loss start':>10s} {'loss end':>9s}")
    for name, cfg in variants.items():
        hp = TrainHParams(peak_lr=3e-3, warmup=5, total_steps=args.steps)
        dc = DataConfig(vocab=cfg.vocab, seq_len=32, global_batch=8)
        _, hist = train_loop(cfg, mesh, hp, dc, steps=args.steps, log_every=0)
        print(f"{name:12s} {M.count_params(cfg):>10,} {hist[0]:>10.3f} {hist[-1]:>9.3f}")


if __name__ == "__main__":
    main()
