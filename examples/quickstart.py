"""Quickstart: the paper's technique as a three-line config change.

    PYTHONPATH=src python examples/quickstart.py
"""

import dataclasses

import jax
import jax.numpy as jnp

from repro.configs import registry
from repro.core.api import ButterflyPolicy
from repro.models import model as M
from repro.models import transformer as tf
from repro.models.layers import Runtime

rt = Runtime(mesh=None)

# 1. any registered architecture...
dense_cfg = registry.get("qwen3-0.6b", reduced=True)

# 2. ...becomes butterfly-sparse by swapping the policy (BPMM on qkv/out/ffn,
#    executed in the grouped multilayer-dataflow form)
bfly_cfg = dataclasses.replace(
    dense_cfg,
    name="qwen3-0.6b+bpmm",
    butterfly=ButterflyPolicy(impl="monarch", max_block=32),
)

for cfg in (dense_cfg, bfly_cfg):
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0, cfg.vocab)
    loss, metrics = tf.loss_fn(params, cfg, {"tokens": tokens, "labels": tokens}, rt)
    n = M.count_params(cfg)
    print(f"{cfg.name:24s} params={n:>12,}  loss={float(loss):.3f}")

print("\nbutterfly compression:",
      f"{M.count_params(bfly_cfg) / M.count_params(dense_cfg):.1%} of dense parameters")
