"""Deterministic, resumable, shardable synthetic token pipeline.

Batches are a pure function of (seed, step), so restart-at-step-k reproduces
the exact stream with no iterator state to checkpoint — the data-side half of
fault tolerance.  Tokens follow a Zipf-ish marginal with local n-gram
structure so losses are non-degenerate (a pure-uniform stream gives the model
nothing to learn and masks wiring bugs).

For multi-host deployment, :func:`global_batch` builds the globally-sharded
array from per-host slices via `jax.make_array_from_callback`, so each host
only materialises its own shard.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = ["DataConfig", "host_batch", "global_batch"]


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0


def _tokens_for(cfg: DataConfig, step: int, rows: np.ndarray) -> np.ndarray:
    """Rows of the global batch (deterministic per (seed, step, row))."""
    rng = np.random.Generator(np.random.Philox(key=cfg.seed, counter=[0, 0, 0, step]))
    # Zipf marginal over vocab, then repeat-previous with prob .3 (local structure)
    v = cfg.vocab
    ranks = np.arange(1, v + 1)
    probs = 1.0 / ranks
    probs /= probs.sum()
    n = len(rows)
    draws = rng.choice(v, size=(n, cfg.seq_len + 1), p=probs)
    rep = rng.random((n, cfg.seq_len + 1)) < 0.3
    for t in range(1, cfg.seq_len + 1):
        draws[:, t] = np.where(rep[:, t], draws[:, t - 1], draws[:, t])
    return draws.astype(np.int32)


def host_batch(cfg: DataConfig, step: int) -> dict[str, np.ndarray]:
    """Full global batch on one host (single-process runs)."""
    draws = _tokens_for(cfg, step, np.arange(cfg.global_batch))
    return {"tokens": draws[:, :-1], "labels": draws[:, 1:]}


def global_batch(cfg: DataConfig, step: int, mesh: Mesh) -> dict[str, jax.Array]:
    """Globally-sharded batch; each process materialises only its slice."""
    spec = P(tuple(a for a in ("pod", "data") if a in mesh.axis_names))
    sharding = NamedSharding(mesh, spec)
    shape = (cfg.global_batch, cfg.seq_len)
    full = host_batch(cfg, step)

    out = {}
    for name in ("tokens", "labels"):
        arr = full[name]
        out[name] = jax.make_array_from_callback(
            shape, sharding, lambda idx, a=arr: a[idx]
        )
    return out
