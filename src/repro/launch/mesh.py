"""Production mesh builders (as functions — importing never touches jax
device state)."""

from __future__ import annotations

import jax

__all__ = [
    "make_mesh", "make_production_mesh", "make_local_mesh", "make_pages_mesh",
]


def make_mesh(shape, names):
    """jax.make_mesh across jax versions: `axis_types=Auto` where the kwarg
    exists (>= 0.5), plain call where it doesn't (0.4.x defaults to auto)."""
    at = getattr(jax.sharding, "AxisType", None)
    if at is not None:
        return jax.make_mesh(shape, names, axis_types=(at.Auto,) * len(names))
    return jax.make_mesh(shape, names)


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_local_mesh():
    """Whatever this host has (CPU smoke runs: 1 device)."""
    n = len(jax.devices())
    return make_mesh((n, 1), ("data", "model"))


def make_pages_mesh(n_shards: int):
    """Serve mesh with a ``pages`` axis: the paged KV pool's page rows shard
    ``n_shards``-way (see :func:`repro.models.transformer.paged_pool_specs`),
    remaining devices data-parallel.  CPU CI reaches 4 devices via
    ``XLA_FLAGS=--xla_force_host_platform_device_count=4``."""
    n = len(jax.devices())
    if n % n_shards:
        raise ValueError(
            f"{n} devices do not split into {n_shards} page shards"
        )
    return make_mesh((n // n_shards, 1, n_shards), ("data", "model", "pages"))
