"""Compiled-artifact analysis: cost, memory, collective bytes, roofline terms.

The roofline model (TPU v5e):
    compute    = HLO_FLOPs / (chips * 197e12 FLOP/s bf16)
    memory     = HLO_bytes / (chips * 819e9 B/s HBM)
    collective = collective_bytes / (chips * 50e9 B/s per ICI link)

collective_bytes is not in cost_analysis(): we parse the post-SPMD optimized
HLO and sum operand sizes of all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute ops (per-device view).
"""

from __future__ import annotations

import dataclasses
import re

__all__ = ["HW", "collective_bytes", "roofline", "Roofline"]

# TPU v5e per chip
PEAK_FLOPS = 197e12  # bf16
HBM_BW = 819e9  # B/s
ICI_BW = 50e9  # B/s per link

HW = {"peak_flops": PEAK_FLOPS, "hbm_bw": HBM_BW, "ici_bw": ICI_BW}

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_COLLECTIVES = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    if dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def collective_bytes(hlo_text: str) -> dict[str, int]:
    """Sum operand bytes per collective kind from optimized HLO text."""
    out = {k: 0 for k in _COLLECTIVES}
    out["count"] = 0
    for line in hlo_text.splitlines():
        stripped = line.strip()
        # result = TYPE opcode(operands...); TYPE may be a tuple "(f32[..], ..)"
        m = re.search(
            r"=\s+(\([^)]*\)|[a-z0-9]+\[[0-9,]*\]\S*)\s+"
            r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
            r"(-start|-done)?\(",
            stripped,
        )
        if not m:
            continue
        op = m.group(2)
        if m.group(3) == "-done":
            continue  # avoid double counting start/done pairs
        # bytes moved ~ result shape(s); for all-gather this is the gathered
        # size, for all-reduce/permute the payload, for reduce-scatter the
        # pre-reduce operand is larger but the result is the steady-state wire
        # payload per device under a ring schedule.
        shapes = _SHAPE_RE.findall(m.group(1))
        nbytes = sum(_shape_bytes(d, s) for d, s in shapes)
        if nbytes == 0:  # fall back to operand shapes if inline
            shapes = _SHAPE_RE.findall(stripped[m.end() - 1 :])
            nbytes = sum(_shape_bytes(d, s) for d, s in shapes)
        if op == "reduce-scatter":
            # the *operand* (pre-reduce) is the wire payload: result x group
            g = re.search(r"replica_groups=\[(\d+),(\d+)\]", stripped)
            if g:
                nbytes *= int(g.group(2))
        out[op] += nbytes
        out["count"] += 1
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    return out


@dataclasses.dataclass
class Roofline:
    """All byte/flop quantities are PER-DEVICE: `cost_analysis()` and
    `as_text()` describe the post-SPMD per-device program (verified against a
    hand-checked sharded matmul).  This matches the spec formula
    `HLO_FLOPs_global / (chips * peak)` exactly since
    flops_per_device = flops_global / chips."""

    flops: float  # per-device HLO flops for one step
    hbm_bytes: float  # per-device bytes accessed
    coll_bytes: float  # per-device collective operand bytes (HLO parse)
    chips: int
    model_flops: float = 0.0  # per-device analytic 6ND-style useful flops

    @property
    def t_compute(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.coll_bytes / ICI_BW

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.t_compute,
            "memory": self.t_memory,
            "collective": self.t_collective,
        }
        return max(terms, key=terms.get)

    @property
    def t_step(self) -> float:
        """Modeled step time: overlapped execution = max of the three terms."""
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_ratio(self) -> float:
        return self.model_flops / self.flops if self.flops else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the compute roofline the modeled step achieves on
        useful (MODEL) flops — per device, so chips cancel."""
        if self.t_step == 0:
            return 0.0
        return (self.model_flops / self.t_step) / PEAK_FLOPS

    def row(self) -> dict:
        return {
            "flops": self.flops,
            "hbm_bytes": self.hbm_bytes,
            "coll_bytes": self.coll_bytes,
            "t_compute": self.t_compute,
            "t_memory": self.t_memory,
            "t_collective": self.t_collective,
            "dominant": self.dominant,
            "model_flops": self.model_flops,
            "useful_ratio": self.useful_ratio,
            "roofline_fraction": self.roofline_fraction,
        }


def roofline(
    compiled, chips: int, model_flops_global: float = 0.0, hlo_text: str | None = None
) -> Roofline:
    """model_flops_global is the whole-step analytic useful-flop count; it is
    divided by `chips` to match the per-device HLO numbers."""
    cost = compiled.cost_analysis()
    if isinstance(cost, list):  # some backends return [dict]
        cost = cost[0]
    flops = float(cost.get("flops", 0.0))
    nbytes = float(cost.get("bytes accessed", 0.0))
    text = hlo_text if hlo_text is not None else compiled.as_text()
    coll = collective_bytes(text)
    return Roofline(
        flops=flops,
        hbm_bytes=nbytes,
        coll_bytes=float(coll["total"]),
        chips=chips,
        model_flops=model_flops_global / max(chips, 1),
    )
