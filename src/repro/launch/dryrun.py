import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

Proves the distribution config is coherent without hardware: the SPMD
partitioner must accept every sharding, the compiled module must fit, and the
cost/memory/collective numbers feed EXPERIMENTS.md §Dry-run and §Roofline.

Usage::

    PYTHONPATH=src python -m repro.launch.dryrun --arch yi-6b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all --out results/dryrun.jsonl
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-72b --multi-pod
"""

import argparse
import dataclasses
import json
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import registry
from repro.core import attention as attn
from repro.configs.shapes import SHAPES, Shape, applicable, batch_specs
from repro.launch import analysis
from repro.launch.mesh import make_production_mesh
from repro.launch.serve import abstract_cache, cache_shardings
from repro.launch.train import (
    TrainHParams,
    abstract_train_state,
    make_train_step,
    train_state_shardings,
)
from repro.distributed import sharding as shd
from repro.models import model as M
from repro.models import transformer as tf
from repro.models.config import ModelConfig
from jax.sharding import NamedSharding, PartitionSpec as P


def lower_cell(cfg: ModelConfig, shape: Shape, mesh, hp: TrainHParams | None = None):
    """Lower one (arch x shape) on `mesh`; returns the jax Lowered object and
    the analytic model-flops for the step."""
    rt = M.resolve_runtime(cfg, mesh)
    hp = hp or TrainHParams()
    bspecs = batch_specs(cfg, shape)
    b_shard = shd.data_shardings(bspecs, mesh)

    if shape.kind == "train":
        step, st_sh, b_sh = make_train_step(cfg, mesh, hp, batch_example=bspecs)
        ab_state = abstract_train_state(cfg, hp)
        lowered = step.lower(ab_state, bspecs)
        tokens = shape.batch * shape.seq
        mf = M.model_flops_per_token(cfg, shape.seq, mode="train") * tokens
        return lowered, mf

    pspecs = M.build_specs(cfg)
    p_shard = shd.sharding_tree(pspecs, mesh, M.rules_for(cfg))
    ab_params = M.abstract_params(cfg)

    if shape.kind == "prefill":
        logit_shard = shd.sharding_for((shape.batch, cfg.vocab), ("batch", None), mesh)
        fn = jax.jit(
            lambda params, b: tf.prefill(params, cfg, b, rt, cache_len=shape.seq),
            in_shardings=(p_shard, b_shard),
            out_shardings=(logit_shard, cache_shardings(cfg, mesh, shape.batch, shape.seq)),
        )
        lowered = fn.lower(ab_params, bspecs)
        tokens = shape.batch * shape.seq
        mf = M.model_flops_per_token(cfg, shape.seq, mode="fwd") * tokens
        return lowered, mf

    # decode: one token against a seq_len-deep cache
    c_shard = cache_shardings(cfg, mesh, shape.batch, shape.seq)
    ab_caches = abstract_cache(cfg, shape.batch, shape.seq)
    rep = NamedSharding(mesh, P())
    logit_shard = shd.sharding_for((shape.batch, cfg.vocab), ("batch", None), mesh)
    fn = jax.jit(
        lambda params, caches, toks, pos: tf.decode_step(params, cfg, caches, toks, pos, rt),
        in_shardings=(p_shard, c_shard, b_shard["tokens"], rep),
        out_shardings=(logit_shard, c_shard),
        donate_argnums=(1,),
    )
    toks = bspecs["tokens"]
    pos = jax.ShapeDtypeStruct((), jnp.int32)
    lowered = fn.lower(ab_params, ab_caches, toks, pos)
    mf = M.decode_flops_per_token(cfg, shape.seq) * shape.batch
    return lowered, mf


def _probe_cfg(cfg: ModelConfig, k: int) -> ModelConfig:
    """k-period unrolled cost-probe variant of cfg."""
    import dataclasses

    period = len(cfg.period_slots)
    kw = dict(
        n_layers=k * period,
        unroll_layers=True,
        grad_accum=1,
    )
    if cfg.family == "encdec" and cfg.n_enc_layers:
        kw["n_enc_layers"] = max(1, cfg.n_enc_layers * k * period // cfg.n_layers)
    return dataclasses.replace(cfg, **kw)


def _probe_cost(cfg: ModelConfig, shape: Shape, mesh, k: int) -> dict:
    lowered, _ = lower_cell(_probe_cfg(cfg, k), shape, mesh)
    compiled = lowered.compile()
    text = compiled.as_text()
    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    coll = analysis.collective_bytes(text)
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "coll": float(coll["total"]),
        "coll_by_kind": {kk: coll[kk] for kk in
                         ("all-gather", "all-reduce", "reduce-scatter",
                          "all-to-all", "collective-permute")},
    }


def _attention_stage(cfg: ModelConfig, shape: Shape) -> dict | None:
    """Analytic fwd FLOP/HBM-byte accounting for the attention softmax stage
    under both execution forms.  The fused Pallas kernel is invisible to XLA's
    ``cost_analysis`` (a near-zero-cost custom call), so the dry-run roofline
    models it from :mod:`repro.core.attention` instead."""
    n_attn = sum(1 for s in cfg.period_slots if s.mixer == "attn") * cfg.n_periods
    if not n_attn or not cfg.n_heads:
        return None
    if shape.kind == "decode":
        s_q, s_kv, causal = 1, shape.seq, False
        if cfg.sliding_window:
            s_kv = min(s_kv, cfg.sliding_window)
    else:
        s_q = s_kv = shape.seq
        causal = cfg.causal
    win = cfg.sliding_window if causal else None
    args = (shape.batch, s_q, s_kv, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim)
    spec = cfg.attention_spec
    flops = n_attn * attn.attention_flops(
        shape.batch, s_q, s_kv, cfg.n_heads, cfg.head_dim, causal=causal,
        window=win, pattern=spec.pattern, pattern_arg=spec.pattern_arg,
        q_tile=spec.q_tile, kv_tile=spec.kv_tile,
    )
    out = {"flops": flops, "n_attn_layers": n_attn, "pattern": spec.pattern}
    if spec.sparse:
        from repro.core import sparsity

        out["kv_density"] = sparsity.pattern_kv_density(
            spec.pattern, s_q if s_q > 1 else s_kv, s_kv, spec.q_tile,
            spec.kv_tile, causal=causal, window=win,
            pattern_arg=spec.pattern_arg,
        )
    for impl in attn.IMPLS:
        out[impl] = {
            "hbm_bytes": n_attn * attn.attention_hbm_bytes(
                dataclasses.replace(spec, impl=impl), *args, causal=causal, window=win
            )
        }
    return out


def _kv_cache_stage(cfg: ModelConfig, shape: Shape) -> dict | None:
    """KV-cache HBM accounting for serving shapes, priced both ways.

    ``dense_reserved_bytes`` is the contiguous engine's cost: every slot
    reserves ``cache_len`` rows regardless of pattern — capacity is priced at
    worst-case dense length.  ``paged_resident_bytes`` prices the paged
    engine: per request, the PEAK simultaneously-live page count under the
    pattern's retention schedule (:func:`repro.core.sparsity.
    page_peak_resident` — the admission reservation), times the page size.
    ``paged_live_read_bytes`` is the steady-state *read* set (block-map
    density x pages — what one decode step actually streams).  The ratio of
    the first two is the concurrent-request capacity win at a fixed HBM
    budget (the serve_throughput ``paged_capacity`` gate measures it live).

    One page table serves every layer, so retention is the UNION of the
    per-slot patterns' last-reader schedules (``Slot.attn_pattern``
    overrides included) — exactly what ``ServeLoop._paged_schedule``
    reserves: a hybrid stack with one dense-causal slot prices at dense
    retention, not at the sparse slots' optimism.

    The ``prefix_*`` fields price the radix prefix cache under an assumed
    share ratio (half the prompt shared batch-wide): shared tiles resident
    once + per-request unique-suffix peaks, and the fraction of admission
    prefill FLOPs the cache absorbs — the analytic counterpart of the
    ``--check-prefix`` gate in ``benchmarks.serve_throughput``.

    ``shard_split`` prices the mesh-sharded pool at 2- and 4-way page
    sharding: per-shard peak resident pages (the balanced allocator's
    ``ceil(global / k)`` bound), per-shard resident bytes, and the
    per-shard capacity ratio — the analytic counterpart of the
    ``--check-shard`` gate.

    ``kv_dtype`` prices the SAME paged residency at each pool storage
    width (bf16 | int8 | fp8_e4m3, :func:`repro.core.attention.
    kv_dtype_bytes` — quantized widths include the amortized per-row f32
    scale): resident bytes, capacity ratio against the bf16 dense
    reservation, and the decode-step live read set — the analytic
    counterpart of the ``--check-quant`` gate."""
    import math

    from repro.core import sparsity

    n_attn = sum(1 for s in cfg.period_slots if s.mixer == "attn") * cfg.n_periods
    if not n_attn or not cfg.n_kv_heads or shape.kind not in ("decode", "prefill"):
        return None
    if cfg.sliding_window or cfg.family == "encdec":
        return None  # ring / cross caches keep the contiguous layout
    spec = cfg.attention_spec
    pattern, arg, _, win = sparsity.canonical_pattern(
        spec.pattern, spec.pattern_arg, True, None
    )
    s = shape.seq
    page = sparsity.pick_pattern_tiles(1, s, spec.q_tile, spec.kv_tile)[1]
    n_tiles = -(-s // page)
    pats = {
        sl.attn_pattern or spec.pattern
        for sl in cfg.period_slots
        if sl.mixer == "attn"
    }
    last = sparsity.page_last_reader_union(
        pats, s, spec.q_tile, page, pattern_arg=spec.pattern_arg
    )
    peak_pages = int(sparsity.page_residency(last, s, page).max())
    density = sparsity.pattern_kv_density(
        pattern, s, s, spec.q_tile, page, causal=True, window=win,
        pattern_arg=arg,
    ) if pattern != "dense" or win is not None else 1.0
    row_bytes = 2 * cfg.n_kv_heads * cfg.head_dim * jnp.dtype(cfg.dtype).itemsize
    per_layer_dense = shape.batch * s * row_bytes
    per_layer_paged = shape.batch * peak_pages * page * row_bytes
    live_read = shape.batch * max(math.ceil(density * n_tiles), 1) * page * row_bytes

    # --- prefix sharing (radix cache) under an assumed share ratio -------
    # Model the ROADMAP's system-prompt traffic shape: every request in the
    # batch shares the first ``share`` of its prompt.  Shared prefix tiles
    # are resident ONCE (the tree + every sharer alias one physical copy);
    # each request adds only its unique-suffix peak
    # (page_residency(start_tile) — the same quantity warm admission
    # reserves).  Prefill FLOPs saved uses the engine's analytic pricing:
    # after the first request, each sharer prefills only its suffix, whose
    # attention term starts at the divergence position.
    share = 0.5
    shared_tiles = int(share * s) // page
    shared_tokens = shared_tiles * page
    uniq_peak = (
        int(sparsity.page_residency(last, s, page, start_tile=shared_tiles).max())
        if shared_tiles < len(last) else 0
    )
    per_layer_shared = (
        shared_tiles * page + shape.batch * uniq_peak * page
    ) * row_bytes
    b = shape.batch
    per_tok = M.model_flops_per_token(cfg, 1, "fwd")
    attn_c = 4 * cfg.n_heads * cfg.head_dim * n_attn

    def _pf(t, pos0):  # analytic prefill FLOPs for t tokens at offset pos0
        return t * per_tok + attn_c * (t * pos0 + t * (t + 1) / 2)

    cold = b * _pf(s, 0)
    warm = _pf(s, 0) + (b - 1) * _pf(s - shared_tokens, shared_tokens)

    # --- mesh-sharded pool: per-shard pricing at 2- and 4-way ------------
    # A "pages" mesh axis splits the pool's page rows into k contiguous
    # ranges; the balanced host allocator keeps each shard's residency at
    # ceil(global / k) (page_residency's n_shards is that per-request
    # analytic bound), so each DEVICE holds a 1/k slice of the paged
    # resident set while dense reservations on the same mesh would shard
    # their full batch x cache_len rows the same way — the capacity ratio
    # is preserved per shard, and the absolute per-device bytes shrink.
    shard_split = {}
    for k in (2, 4):
        shard_peak = int(
            sparsity.page_residency(last, s, page, n_shards=k).max()
        )
        per_layer_shard = shape.batch * shard_peak * page * row_bytes
        shard_split[str(k)] = {
            "shard_peak_resident_pages": shard_peak,
            "shard_paged_resident_bytes": float(n_attn * per_layer_shard),
            "shard_dense_reserved_bytes": float(
                n_attn * per_layer_dense / k
            ),
            "shard_capacity_ratio": float(
                (per_layer_dense / k) / max(per_layer_shard, 1)
            ),
        }
    # --- pool storage width: the same residency at bf16 / int8 / fp8 ------
    kv_dtype_split = {}
    base_bytes = jnp.dtype(cfg.dtype).itemsize
    for kd in ("bf16", "int8", "fp8_e4m3"):
        eff = attn.kv_dtype_bytes(kd, cfg.head_dim, base_bytes=base_bytes)
        rb = 2 * cfg.n_kv_heads * cfg.head_dim * eff
        plp = shape.batch * peak_pages * page * rb
        lr = shape.batch * max(math.ceil(density * n_tiles), 1) * page * rb
        kv_dtype_split[kd] = {
            "effective_bytes_per_value": float(eff),
            "paged_resident_bytes": float(n_attn * plp),
            "decode_live_read_bytes": float(n_attn * lr),
            "capacity_ratio": float(per_layer_dense / max(plp, 1)),
        }

    return {
        "pattern": pattern,
        "retention_patterns": sorted(pats),
        "page_tokens": page,
        "n_tiles": n_tiles,
        "peak_resident_pages": peak_pages,
        "dense_reserved_bytes": float(n_attn * per_layer_dense),
        "paged_resident_bytes": float(n_attn * per_layer_paged),
        "paged_live_read_bytes": float(n_attn * live_read),
        "capacity_ratio": float(per_layer_dense / max(per_layer_paged, 1)),
        "prefix_share_ratio": share,
        "shared_prefix_tokens": shared_tokens,
        "shared_resident_pages": shared_tiles,
        "unique_peak_pages_per_request": uniq_peak,
        "prefix_resident_bytes": float(n_attn * per_layer_shared),
        "prefix_capacity_ratio": float(
            per_layer_paged / max(per_layer_shared, 1)
        ),
        "prefill_flops_saved_frac": float(1.0 - warm / max(cold, 1.0)),
        "shard_split": shard_split,
        "kv_dtype": kv_dtype_split,
    }


def run_cell(
    arch: str,
    shape_name: str,
    multi_pod: bool,
    reduced: bool = False,
    cfg_override: ModelConfig | None = None,
    lower_only: bool = False,
    probes: bool = True,
    attn_impl: str | None = None,
    attn_pattern: str | None = None,
) -> dict:
    cfg = cfg_override or registry.get(arch, reduced=reduced)
    cfg = attn.override_attention(cfg, impl=attn_impl, pattern=attn_pattern)
    shape = SHAPES[shape_name]
    rec: dict = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "params": M.count_params(cfg),
    }
    ok, reason = applicable(cfg, shape)
    if not ok:
        rec.update(status="skipped", reason=reason)
        return rec
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    t0 = time.monotonic()
    try:
        # --- 1. the real (scanned) module: compile-proof + memory analysis
        lowered, model_flops = lower_cell(cfg, shape, mesh)
        t_lower = time.monotonic() - t0
        if lower_only:
            rec.update(status="lowered", t_lower_s=round(t_lower, 1), chips=chips)
            return rec
        compiled = lowered.compile()
        t_compile = time.monotonic() - t0 - t_lower
        mem = compiled.memory_analysis()
        full_coll = analysis.collective_bytes(compiled.as_text())

        rl = None
        p1 = p2 = None
        if probes:
            # --- 2. unrolled probes: per-period cost slope (XLA counts while
            # bodies once — ModelConfig.unroll_layers doc)
            p1 = _probe_cost(cfg, shape, mesh, 1)
            p2 = _probe_cost(cfg, shape, mesh, 2)
            n = cfg.n_periods
            extrap = {
                key: p1[key] + (n - 1) * (p2[key] - p1[key])
                for key in ("flops", "bytes", "coll")
            }
            rl = analysis.Roofline(
                flops=extrap["flops"],
                hbm_bytes=extrap["bytes"],
                coll_bytes=extrap["coll"],
                chips=chips,
                model_flops=model_flops / chips,
            )
        # attention-stage accounting: the probes lower the XLA chunked form
        # (the kernel is single-device); when flash_kernel is configured the
        # roofline swaps the chunked stage's score traffic for the fused
        # kernel's streaming traffic (per-device share)
        stage = _attention_stage(cfg, shape)
        if stage and rl and cfg.attention.fused:
            delta = (
                stage["flash_kernel"]["hbm_bytes"]
                - stage["xla_chunked"]["hbm_bytes"]
            ) / chips
            rl = dataclasses.replace(rl, hbm_bytes=max(rl.hbm_bytes + delta, 0.0))
        rec["attention_stage_fwd"] = stage
        rec["kv_cache"] = _kv_cache_stage(cfg, shape)
        rec.update(
            status="ok",
            t_lower_s=round(t_lower, 1),
            t_compile_s=round(t_compile, 1),
            chips=chips,
            memory={
                "argument_bytes": mem.argument_size_in_bytes,
                "output_bytes": mem.output_size_in_bytes,
                "temp_bytes": mem.temp_size_in_bytes,
                "alias_bytes": mem.alias_size_in_bytes,
                "peak_est_bytes": mem.argument_size_in_bytes
                + mem.output_size_in_bytes
                + mem.temp_size_in_bytes
                - mem.alias_size_in_bytes,
            },
            collectives_full_module=dict(full_coll),
            probe_1p=p1,
            probe_2p=p2,
            roofline=rl.row() if rl else None,
        )
    except Exception as e:  # noqa: BLE001 — a failing cell is a bug to report
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   trace=traceback.format_exc()[-2000:])
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--lower-only", action="store_true")
    ap.add_argument("--no-probes", action="store_true")
    ap.add_argument("--attn", default=None, choices=["xla_chunked", "flash_kernel"],
                    help="override the attention execution form for every cell")
    ap.add_argument("--pattern", default=None,
                    choices=["dense", "causal", "window", "butterfly", "strided",
                             "global_window"],
                    help="override the attention block-sparsity pattern")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    archs = registry.ASSIGNED if args.all or not args.arch else [args.arch]
    shapes = list(SHAPES) if args.all or not args.shape else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    out_f = open(args.out, "a") if args.out else None
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                rec = run_cell(
                    arch, shape, mp, reduced=args.reduced,
                    lower_only=args.lower_only, probes=not args.no_probes,
                    attn_impl=args.attn, attn_pattern=args.pattern,
                )
                line = json.dumps(rec)
                print(_summ0(rec), flush=True)
                if out_f:
                    out_f.write(line + "\n")
                    out_f.flush()
    if out_f:
        out_f.close()


def _summ0(rec: dict) -> str:
    if rec["status"] == "ok" and rec.get("roofline"):
        return _summ(rec)
    if rec["status"] == "ok":
        return (f"[ok] {rec['arch']:18s} {rec['shape']:12s} {rec['mesh']:8s} "
                f"compile={rec['t_compile_s']:.0f}s "
                f"mem/dev={rec['memory']['peak_est_bytes']/2**30:.2f}GiB (no probes)")
    if rec["status"] == "lowered":
        return f"[lowered] {rec['arch']:18s} {rec['shape']:12s} {rec['mesh']:8s} t={rec['t_lower_s']}s"
    if rec["status"] == "skipped":
        return f"[skip] {rec['arch']:18s} {rec['shape']:12s} {rec['mesh']:8s} {rec['reason']}"
    return json.dumps(rec)[:800]


def _summ(rec: dict) -> str:
    r = rec["roofline"]
    m = rec["memory"]
    kv = rec.get("kv_cache")
    kv_s = (
        f" kv_cap={kv['capacity_ratio']:.1f}x"
        f"({kv['peak_resident_pages']}/{kv['n_tiles']}pg)"
        f" px@{kv['prefix_share_ratio']:.0%}="
        f"{kv['prefix_capacity_ratio']:.1f}x"
        f"(-{kv['prefill_flops_saved_frac']:.0%}flops)"
        if kv else ""
    )
    if kv and kv.get("kv_dtype"):
        kd = kv["kv_dtype"]
        kv_s += " qcap=" + "/".join(
            f"{name.split('_')[0]}:{kd[name]['capacity_ratio']:.1f}x"
            for name in ("bf16", "int8", "fp8_e4m3")
            if name in kd
        )
    return (
        f"[ok] {rec['arch']:18s} {rec['shape']:12s} {rec['mesh']:8s} "
        f"compile={rec['t_compile_s']:.0f}s mem/dev={m['peak_est_bytes']/2**30:.2f}GiB "
        f"t_comp={r['t_compute']*1e3:.2f}ms t_mem={r['t_memory']*1e3:.2f}ms "
        f"t_coll={r['t_collective']*1e3:.2f}ms dom={r['dominant']} "
        f"useful={r['useful_ratio']:.2f} roofline={r['roofline_fraction']:.2%}"
        f"{kv_s}"
    )


if __name__ == "__main__":
    main()
