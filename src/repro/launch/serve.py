"""Compatibility facade over :mod:`repro.launch.serving`.

The serve runtime grew from one module into a package — this name is kept
because it IS the public surface (tests, benchmarks, examples, and the
dryrun all import from here):

* :mod:`repro.launch.serving.entries` — jitted prefill/decode/mixed/chunk/
  paged entry-point factories with sharded KV caches and page pools.
* :mod:`repro.launch.serving.pool` — the host-side refcounted (and
  mesh-sharded) :class:`PagePool` plus the :class:`RadixCache` prefix tree.
* :mod:`repro.launch.serving.queueing` — :class:`Request`, the priority
  admission queue, kv-live bucketing, the async token fetch.
* :mod:`repro.launch.serving.loop` — :class:`ServeLoop`, the single-loop
  engine: admission-prefill, chunked mixed-step, and the paged engines
  (prefix cache, mod-window rings, encdec cross ranges, SLO-aware
  preemption).
* :mod:`repro.launch.serving.disagg` — :class:`DisaggRouter` with its
  :class:`PrefillWorker` / :class:`DecodeWorker`: phase-disaggregated
  serving over one mesh-sharded page pool, page-table handoff between
  phases.
"""

from __future__ import annotations

from repro.launch.serving.disagg import (  # noqa: F401
    DecodeWorker,
    DisaggRouter,
    PrefillWorker,
)
from repro.launch.serving.entries import (  # noqa: F401
    abstract_cache,
    cache_shardings,
    make_mixed_fn,
    make_paged_fns,
    make_serve_fns,
    make_slot_chunk_fn,
    zero_pools,
)
from repro.launch.serving.loop import ServeLoop  # noqa: F401
from repro.launch.serving.pool import (  # noqa: F401
    PagePool,
    RadixCache,
    _RadixNode,
)
from repro.launch.serving.queueing import (  # noqa: F401
    Request,
    _AdmitQueue,
    _AsyncTokens,
    _PagedSlot,
    _PRIORITY_RANK,
    _next_bucket,
)

__all__ = [
    "make_serve_fns",
    "make_mixed_fn",
    "make_slot_chunk_fn",
    "make_paged_fns",
    "cache_shardings",
    "abstract_cache",
    "zero_pools",
    "PagePool",
    "RadixCache",
    "Request",
    "ServeLoop",
    "PrefillWorker",
    "DecodeWorker",
    "DisaggRouter",
]
