"""Streaming serve runtime: chunked-prefill mixed-step engine + jit'd
prefill/decode entry points with sharded KV caches.

`make_serve_fns` builds the two classic compiled entry points the dry-run
exercises (`prefill_32k` lowers prefill; `decode_32k` / `long_500k` lower
decode_step); with ``ragged=True`` the prefill takes per-request prompt
lengths and the decode takes a (B,) position vector instead of a batch-wide
scalar.  `make_mixed_fn` builds the third, unified entry point: one jitted
``mixed_step`` where every batch row consumes a per-row token count — a
prompt chunk, one decode token, or nothing.

`ServeLoop` is the engine.  In its **chunked** mode (the paper's §V-A
{Load | Cal | Store} streaming applied at the request level) prompts are
split into fixed-size chunks and every iteration advances the WHOLE batch
through ``mixed_step`` issued at two ragged shapes: a (B, 1) *decode wave*
(every decoding row takes one token, bucketed at the decode rows' own
live-cache depth) and a (1, C) *slot chunk* per mid-prompt row
(prefill-into-slot, writing straight into the shared KV cache at positions
``pos..pos+C-1`` at the prompt's own frontier bucket) — admission is free
(no blocking batch-1 prefill) and decode never stalls while a long prompt
streams in.  A per-step chunk *budget* bounds prefill work per iteration
(Sarathi-style), and sampled tokens are fetched with a one-step lag so host
dispatch overlaps device compute.

``chunked=False`` keeps the admission-prefill engine (bucketed batch-1
prefill inserted into the shared cache) — the seed contiguous path, kept as
the parity baseline for every other mode.

The page table is the ONLY serve-time cache abstraction beyond that
baseline: sliding-window ring caches become **mod-window page tables** (a
``ring_tiles``-slot table reused in phase — absolute tile ``j`` lives in
slot ``j % ring_tiles``, positions stay absolute, decode is unbounded) and
encoder-decoder cross KV becomes **read-only shared page ranges** (the
encoder output is prefilled ONCE into refcounted pages via
:func:`repro.models.transformer.paged_encode` and aliased into every
decoder request's table — decode never writes a cross page, so CoW never
triggers and cross-attention prefix sharing falls out of the refcounts).
A chunked request for either family upgrades to the paged engine
automatically — there is no contiguous chunked ring/encdec path to fall
back to, by design.

Under pool pressure the engine degrades gracefully instead of serializing:
the admission queue is **priority-ordered** (``Request.priority`` —
``interactive`` ahead of ``batch``, FIFO within a class, with an aging
guard that promotes a batch request after ``aging_steps`` engine clocks so
it is delayed, never starved), and a higher-priority request whose
page-residency peak cannot be reserved **preempts** the youngest
lowest-priority active request: the victim's completed full pages are
inserted into the radix tree (so resume is a warm prefix hit), its pool
references released, and the request requeued — resume re-enters through
the restartable chunked-prefill path at the divergence frontier.  A
per-request preemption cap plus a minimum-progress guard make
preempt/resume livelock impossible.  Sliding-window rings and
encoder-decoder cross ranges are **non-preemptible** (fixed page sets,
radix disabled — there is nothing warm to resume from).  Every engine mode
stamps per-request time-to-first-token and inter-token latency in
engine-step clock units and aggregates p50/p99 and an SLO-attainment
fraction into ``stats``.
"""

from __future__ import annotations

import collections
import dataclasses
import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import sparsity
from repro.core.attention import override_attention
from repro.distributed import sharding as shd
from repro.models import model as M
from repro.models import transformer as tf
from repro.models.config import ModelConfig

__all__ = [
    "make_serve_fns",
    "make_mixed_fn",
    "make_slot_chunk_fn",
    "make_paged_fns",
    "cache_shardings",
    "abstract_cache",
    "PagePool",
    "RadixCache",
    "Request",
    "ServeLoop",
]


def cache_shardings(cfg: ModelConfig, mesh: Mesh, batch: int, cache_len: int):
    return shd.sharding_tree(tf.cache_specs(cfg, batch, cache_len), mesh, M.rules_for(cfg))


def abstract_cache(cfg: ModelConfig, batch: int, cache_len: int):
    specs = tf.cache_specs(cfg, batch, cache_len)
    dt = jnp.dtype(cfg.dtype)
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, dt),
        specs,
        is_leaf=lambda x: isinstance(x, shd.ParamSpec),
    )


def _entry_shardings(cfg: ModelConfig, mesh: Mesh, batch: int, cache_len: int):
    """Shared setup of every serve entry-point factory: resolved runtime +
    the param / cache / token / replicated shardings.  One definition so the
    prefill, decode, mixed-wave and slot-chunk compiles can never diverge."""
    rt = M.resolve_runtime(cfg, mesh)
    p_shard = shd.sharding_tree(M.build_specs(cfg), mesh, M.rules_for(cfg))
    c_shard = cache_shardings(cfg, mesh, batch, cache_len)
    tok_shard = NamedSharding(
        mesh, P(tuple(a for a in ("pod", "data") if a in mesh.axis_names))
    )
    rep = NamedSharding(mesh, P())
    return rt, p_shard, c_shard, tok_shard, rep


def make_serve_fns(
    cfg: ModelConfig,
    mesh: Mesh,
    *,
    batch: int,
    cache_len: int,
    attn_impl: str | None = None,
    attn_pattern: str | None = None,
    ragged: bool = False,
):
    """Returns (prefill_fn, decode_fn).

    ``ragged=False`` (static batch): prefill_fn(params, batch_dict) and
    decode_fn(params, caches, tokens, pos-scalar).  ``ragged=True``:
    prefill_fn(params, batch_dict, lengths (B,)) gathers each row's last real
    token and decode_fn takes pos as a (B,) per-request position vector.

    ``attn_impl`` / ``attn_pattern`` override the config's attention
    execution form / block-sparsity pattern for this serving instance (e.g.
    "flash_kernel" + "butterfly" on a single-chip deployment).

    ``decode_fn`` takes an optional trailing ``kv_live`` (static int): a
    host-known bound on every row's live cache length.  Attention then
    streams only the first ``kv_live`` cache rows — each distinct value
    compiles once, so callers should bucket it (the engine uses powers of
    two)."""
    cfg = override_attention(cfg, impl=attn_impl, pattern=attn_pattern)
    rt, p_shard, c_shard, tok_shard, rep = _entry_shardings(
        cfg, mesh, batch, cache_len
    )

    if ragged:
        prefill = jax.jit(
            lambda params, b, lengths: tf.prefill(
                params, cfg, b, rt, cache_len=cache_len, lengths=lengths
            ),
            in_shardings=(p_shard, None, rep),
            out_shardings=(tok_shard, c_shard),
        )
        pos_shard = rep  # (B,) per-request positions, replicated
    else:
        prefill = jax.jit(
            lambda params, b: tf.prefill(params, cfg, b, rt, cache_len=cache_len),
            in_shardings=(p_shard, None),
            out_shardings=(tok_shard, c_shard),
        )
        pos_shard = rep
    jitted: dict[int | None, object] = {}

    def decode(params, caches, tokens, pos, kv_live: int | None = None):
        fn = jitted.get(kv_live)
        if fn is None:
            fn = jax.jit(
                lambda params, caches, tokens, pos: tf.decode_step(
                    params, cfg, caches, tokens, pos, rt, kv_live=kv_live
                ),
                in_shardings=(p_shard, c_shard, tok_shard, pos_shard),
                out_shardings=(tok_shard, c_shard),
                donate_argnums=(1,),
            )
            jitted[kv_live] = fn
        return fn(params, caches, tokens, pos)

    return prefill, decode


def make_mixed_fn(
    cfg: ModelConfig,
    mesh: Mesh,
    *,
    batch: int,
    cache_len: int,
    chunk: int,
    attn_impl: str | None = None,
    attn_pattern: str | None = None,
):
    """The unified mixed-step entry point: one compiled function advances the
    whole batch, each row consuming ``ntok[b]`` tokens (0 idle / 1 decode /
    2..chunk prompt chunk) at positions ``pos[b]..``.

    Returned callable: ``mixed(params, caches, tokens (B,C) host prompt
    chunks, nxt (B,) device feedback tokens, use_nxt (B,) bool, pos (B,),
    ntok (B,), kv_live)``.  Decode rows take their input token from ``nxt``
    (the previous step's on-device argmax — the host never syncs on token
    values), prefill rows from ``tokens``.  ``kv_live`` buckets compile
    per value, like the decode entry point."""
    cfg = override_attention(cfg, impl=attn_impl, pattern=attn_pattern)
    rt, p_shard, c_shard, tok_shard, rep = _entry_shardings(
        cfg, mesh, batch, cache_len
    )
    jitted: dict[int | None, object] = {}

    def mixed(params, caches, tokens, nxt, use_nxt, pos, ntok,
              kv_live: int | None = None):
        if tokens.shape != (batch, chunk):
            raise ValueError(
                f"tokens {tokens.shape} vs compiled chunk shape {(batch, chunk)}"
            )
        fn = jitted.get(kv_live)
        if fn is None:
            def _step(params, caches, tokens, nxt, use_nxt, pos, ntok):
                col0 = jnp.arange(tokens.shape[1], dtype=jnp.int32)[None, :] == 0
                toks = jnp.where(use_nxt[:, None] & col0, nxt[:, None], tokens)
                return tf.mixed_step(
                    params, cfg, caches, toks, pos, ntok, rt, kv_live=kv_live
                )

            fn = jax.jit(
                _step,
                in_shardings=(p_shard, c_shard, tok_shard, tok_shard, rep, rep, rep),
                out_shardings=(tok_shard, c_shard),
                donate_argnums=(1,),
            )
            jitted[kv_live] = fn
        return fn(params, caches, tokens, nxt, use_nxt, pos, ntok)

    return mixed


def make_slot_chunk_fn(
    cfg: ModelConfig,
    mesh: Mesh,
    *,
    batch: int,
    cache_len: int,
    chunk: int,
    attn_impl: str | None = None,
    attn_pattern: str | None = None,
):
    """``mixed_step`` at its other ragged shape, (1, chunk): stream one
    prompt chunk into ONE slot of the shared cache at a traced slot index.

    Returned callable: ``chunk_fn(params, caches, tokens (1, C), slot, pos,
    ntok, kv_live)`` -> (logits (vocab,) at the chunk's last valid token,
    full updated caches).  The slot's cache rows are sliced to a batch-1
    view, the chunk runs through the exact same mixed_step / chunk-kernel
    path, and the updated rows are written back in place (donated) — so a
    chunk call costs ``C x kv_live`` attention for one row, not
    ``B x C x kv_live`` for the whole batch.  Compiles once per ``kv_live``
    bucket, like the decode entry point."""
    cfg = override_attention(cfg, impl=attn_impl, pattern=attn_pattern)
    rt, p_shard, c_shard, _, rep = _entry_shardings(cfg, mesh, batch, cache_len)
    jitted: dict[int | None, object] = {}

    def chunk_fn(params, caches, tokens, slot, pos, ntok,
                 kv_live: int | None = None):
        if tokens.shape != (1, chunk):
            raise ValueError(
                f"tokens {tokens.shape} vs compiled chunk shape {(1, chunk)}"
            )
        fn = jitted.get(kv_live)
        if fn is None:
            def _step(params, caches, tokens, slot, pos, ntok):
                sub = jax.tree.map(
                    lambda c: jax.lax.dynamic_slice_in_dim(c, slot, 1, axis=1),
                    caches,
                )
                logits, new_sub = tf.mixed_step(
                    params, cfg, sub, tokens, jnp.reshape(pos, (1,)),
                    jnp.reshape(ntok, (1,)), rt, kv_live=kv_live,
                )
                caches = jax.tree.map(
                    lambda c, w: jax.lax.dynamic_update_slice_in_dim(
                        c, w.astype(c.dtype), slot, axis=1
                    ),
                    caches,
                    new_sub,
                )
                return logits[0], caches

            fn = jax.jit(
                _step,
                in_shardings=(p_shard, c_shard, rep, rep, rep, rep),
                out_shardings=(rep, c_shard),
                donate_argnums=(1,),
            )
            jitted[kv_live] = fn
        return fn(params, caches, tokens, slot, pos, ntok)

    return chunk_fn


def make_paged_fns(
    cfg: ModelConfig,
    mesh: Mesh,
    *,
    n_pages: int,
    page: int,
    chunk: int,
    attn_impl: str | None = None,
    attn_pattern: str | None = None,
    cross_pages: int | None = None,
):
    """Compiled entry points of the PAGED serve engine: ``(prefill, decode,
    chunk_fn, copy_fn, encode_fn)`` over one global page pool instead of
    per-slot ``cache_len`` reservations.

    * ``prefill(params, caches, b, lengths, pt_row)`` — batch-1 admission
      prefill scattered through the request's page-table row (retraces per
      prompt bucket, like the ragged contiguous prefill).
    * ``decode(params, caches, tokens (B,1), pos (B,), pt (B,nv), kv_live)``
      — the ragged decode wave; every row reads the pool through its own
      page-table row, bucketed per ``kv_live``.
    * ``chunk_fn(params, caches, tokens (1,C), pt_row (1,nv), pos, ntok,
      kv_live)`` — one prompt chunk streamed straight into the pool.  No
      slot slice/insert dance: the pool is already shared, the page table IS
      the slot.
    * ``copy_fn(caches, src, dst)`` — copy-on-write page duplication
      (:func:`repro.models.transformer.paged_copy_page`); src/dst are traced
      page ids, so the whole prefix-sharing machinery compiles exactly one
      extra program.

    With ``cross_pages`` (encoder-decoder stacks) the pools grow per-slot
    read-only cross pools; ``decode`` / ``chunk_fn`` then take a trailing
    cross-table argument and a fifth entry point appears:

    * ``encode_fn(params, caches, frames (1, S, D), ct_row (1, n_ct))`` —
      run the encoder ONCE and scatter every decoder slot's cross KV into
      the cross pool through ``ct_row``
      (:func:`repro.models.transformer.paged_encode`); the written pages
      are read-only for the rest of their life and alias freely.

    All entry points donate the pools; the page tables are tiny replicated
    int32 arrays refreshed from host state every call."""
    cfg = override_attention(cfg, impl=attn_impl, pattern=attn_pattern)
    rt = M.resolve_runtime(cfg, mesh)
    p_shard = shd.sharding_tree(M.build_specs(cfg), mesh, M.rules_for(cfg))
    pool_shard = shd.sharding_tree(
        tf.paged_pool_specs(cfg, n_pages, page, cross_pages=cross_pages),
        mesh, M.rules_for(cfg),
    )
    tok_shard = NamedSharding(
        mesh, P(tuple(a for a in ("pod", "data") if a in mesh.axis_names))
    )
    rep = NamedSharding(mesh, P())

    prefill = jax.jit(
        lambda params, caches, b, lengths, pt: tf.paged_prefill(
            params, cfg, b, rt, caches=caches, page_table=pt, page=page,
            lengths=lengths,
        ),
        in_shardings=(p_shard, pool_shard, None, rep, rep),
        out_shardings=(tok_shard, pool_shard),
        donate_argnums=(1,),
    )

    dec_jit: dict[int | None, object] = {}

    def decode(params, caches, tokens, pos, pt, kv_live: int | None = None,
               ct=None):
        fn = dec_jit.get(kv_live)
        if fn is None:
            if cross_pages is not None:
                fn = jax.jit(
                    lambda params, caches, tokens, pos, pt, ct: tf.decode_step(
                        params, cfg, caches, tokens, pos, rt, kv_live=kv_live,
                        page_table=pt, page=page, cross_table=ct,
                    ),
                    in_shardings=(p_shard, pool_shard, tok_shard, rep, rep,
                                  rep),
                    out_shardings=(tok_shard, pool_shard),
                    donate_argnums=(1,),
                )
            else:
                fn = jax.jit(
                    lambda params, caches, tokens, pos, pt: tf.decode_step(
                        params, cfg, caches, tokens, pos, rt, kv_live=kv_live,
                        page_table=pt, page=page,
                    ),
                    in_shardings=(p_shard, pool_shard, tok_shard, rep, rep),
                    out_shardings=(tok_shard, pool_shard),
                    donate_argnums=(1,),
                )
            dec_jit[kv_live] = fn
        if cross_pages is not None:
            return fn(params, caches, tokens, pos, pt, ct)
        return fn(params, caches, tokens, pos, pt)

    chk_jit: dict[int | None, object] = {}

    def chunk_fn(params, caches, tokens, pt, pos, ntok,
                 kv_live: int | None = None, ct=None):
        if tokens.shape != (1, chunk):
            raise ValueError(
                f"tokens {tokens.shape} vs compiled chunk shape {(1, chunk)}"
            )
        fn = chk_jit.get(kv_live)
        if fn is None:
            def _step(params, caches, tokens, pt, pos, ntok, ct=None):
                logits, caches = tf.mixed_step(
                    params, cfg, caches, tokens, jnp.reshape(pos, (1,)),
                    jnp.reshape(ntok, (1,)), rt, kv_live=kv_live,
                    page_table=pt, page=page, cross_table=ct,
                )
                return logits[0], caches

            if cross_pages is not None:
                fn = jax.jit(
                    _step,
                    in_shardings=(p_shard, pool_shard, rep, rep, rep, rep,
                                  rep),
                    out_shardings=(rep, pool_shard),
                    donate_argnums=(1,),
                )
            else:
                fn = jax.jit(
                    _step,
                    in_shardings=(p_shard, pool_shard, rep, rep, rep, rep),
                    out_shardings=(rep, pool_shard),
                    donate_argnums=(1,),
                )
            chk_jit[kv_live] = fn
        if cross_pages is not None:
            return fn(params, caches, tokens, pt, pos, ntok, ct)
        return fn(params, caches, tokens, pt, pos, ntok)

    copy_fn = jax.jit(
        lambda caches, src, dst: tf.paged_copy_page(caches, src, dst, page),
        in_shardings=(pool_shard, rep, rep),
        out_shardings=pool_shard,
        donate_argnums=(0,),
    )

    encode_fn = None
    if cross_pages is not None:
        encode_fn = jax.jit(
            lambda params, caches, frames, ct: tf.paged_encode(
                params, cfg, frames, rt, caches=caches, cross_table=ct,
                page=page,
            ),
            in_shardings=(p_shard, pool_shard, None, rep),
            out_shardings=pool_shard,
            donate_argnums=(1,),
        )

    return prefill, decode, chunk_fn, copy_fn, encode_fn


class PagePool:
    """Host-side refcounted free-list allocator over the global KV page pool.

    Pages are unit-granular (one kv tile each), so there is no external
    fragmentation by construction: ``alloc`` succeeds whenever ``in_use <
    n_pages`` — the fragmentation bound the tests pin down.  The engine
    layers a *reservation* discipline on top (each active request commits its
    worst-case future residency, :func:`repro.core.sparsity.
    page_peak_resident`), which makes ``alloc`` infallible at every reachable
    state and turns pool exhaustion into admission backpressure instead of a
    mid-stream deadlock.

    Prefix sharing adds reference counting: a physical page can back the
    same virtual tile of many requests plus the radix cache.  Every sharer
    holds one reference (``retain``); ``release`` drops one, and the page
    returns to the free list only when the LAST reference across all sharers
    is gone — dead-tile freeing from the retention schedules composes with
    sharing for free.  ``fork`` is the allocator half of copy-on-write: a
    writer that holds a page jointly trades its reference for a fresh
    private page (the engine copies the device rows).

    Every reference carries an advisory ``owner`` label (request id, the
    radix tree, the encoder cache) so a leak at :meth:`ServeLoop.close`
    names WHO still holds the pages instead of just counting them —
    :meth:`holders` aggregates the labels of every in-use page.  Labels
    never influence refcount semantics; a mismatched release just drops the
    most recent label."""

    def __init__(self, n_pages: int):
        if n_pages < 1:
            raise ValueError(f"pool needs >= 1 page, got {n_pages}")
        self.n_pages = n_pages
        self._free = list(range(n_pages - 1, -1, -1))
        self._refs = [0] * n_pages
        self._owners: list[list[str]] = [[] for _ in range(n_pages)]
        self.in_use = 0
        self.peak_in_use = 0
        self.alloc_count = 0
        self.fork_count = 0

    @property
    def free_pages(self) -> int:
        return len(self._free)

    def page_refs(self, pid: int) -> int:
        if not 0 <= pid < self.n_pages:
            raise ValueError(f"page id {pid} outside pool of {self.n_pages}")
        return self._refs[pid]

    def _drop_owner(self, pid: int, owner: str | None) -> None:
        ow = self._owners[pid]
        if owner is not None and owner in ow:
            ow.remove(owner)
        elif ow:
            ow.pop()

    def alloc(self, owner: str = "?") -> int:
        if not self._free:
            raise RuntimeError(
                "page pool exhausted — the reservation invariant was broken "
                "(engine bug), admission should have backpressured"
            )
        pid = self._free.pop()
        if self._refs[pid]:
            # the free list must never hand out a page somebody still reads
            # — this is the invariant the churn property test hammers
            raise AssertionError(
                f"free list handed out page {pid} with {self._refs[pid]} "
                "live refs — refcount bookkeeping is corrupt"
            )
        self._refs[pid] = 1
        self._owners[pid] = [owner]
        self.in_use += 1
        self.alloc_count += 1
        self.peak_in_use = max(self.peak_in_use, self.in_use)
        return pid

    def retain(self, pid: int, owner: str = "?") -> None:
        """Add a sharer's reference to an allocated page (prefix aliasing)."""
        if not 0 <= pid < self.n_pages:
            raise ValueError(f"page id {pid} outside pool of {self.n_pages}")
        if self._refs[pid] == 0:
            raise ValueError(f"retain of free page {pid} — it could be "
                             "reallocated under the new reader")
        self._refs[pid] += 1
        self._owners[pid].append(owner)

    def fork(self, pid: int, owner: str = "?") -> int:
        """Copy-on-write: move the caller's reference off shared page ``pid``
        onto a freshly allocated private page (returned).  The caller owns
        the device copy of the rows.  Forking an exclusively-held page is an
        engine bug — the write could have gone in place."""
        if not 0 <= pid < self.n_pages:
            raise ValueError(f"page id {pid} outside pool of {self.n_pages}")
        if self._refs[pid] == 0:
            raise ValueError(f"fork of free page {pid}")
        if self._refs[pid] == 1:
            raise ValueError(
                f"fork of exclusively-held page {pid} — write in place"
            )
        new = self.alloc(owner)
        self._refs[pid] -= 1  # never reaches zero here: refs were >= 2
        self._drop_owner(pid, owner)
        self.fork_count += 1
        return new

    def release(self, pid: int, owner: str | None = None) -> None:
        if not 0 <= pid < self.n_pages:
            raise ValueError(f"page id {pid} outside pool of {self.n_pages}")
        if self._refs[pid] == 0:
            # a double free would put the page on the free list twice and
            # later hand it to two requests — silent cross-request KV
            # corruption; fail loudly at the bug site instead
            raise ValueError(f"page id {pid} is not allocated (double free?)")
        self._refs[pid] -= 1
        self._drop_owner(pid, owner)
        if self._refs[pid] == 0:
            self._free.append(pid)
            self.in_use -= 1

    def holders(self) -> dict[str, int]:
        """Reference counts per owner label over all in-use pages — the
        attribution a leak error reports."""
        c: collections.Counter[str] = collections.Counter()
        for pid in range(self.n_pages):
            if self._refs[pid]:
                c.update(self._owners[pid] or ["?"])
        return dict(c)


class _RadixNode:
    """One edge of the prefix tree: a token run (length a multiple of the
    page size, so ownership never tears a page) plus the physical pages
    backing it.  ``children`` maps first-token -> LIST of nodes: when two
    cached sequences diverge inside a page we cannot split at the true
    divergence point, so sub-page-divergent siblings share a bucket instead
    (bounded duplication, exact matching)."""

    __slots__ = ("tokens", "pages", "children", "parent", "last_use")

    def __init__(self, tokens: np.ndarray, pages: list[int], parent):
        self.tokens = tokens
        self.pages = pages
        self.children: dict[int, list[_RadixNode]] = {}
        self.parent = parent
        self.last_use = 0


class RadixCache:
    """SGLang-style radix tree over prompt token ids, owning KV pages of the
    paged pool at tile granularity.

    Every page a node owns carries ONE tree reference in the
    :class:`PagePool`; requests that alias a cached prefix retain their own
    references, so a page outlives the tree node (eviction) and the
    requests (retirement) independently — it frees exactly when the last
    reader across all sharers lets go.  ``match`` may extend partway into a
    node's last page (the divergence frontier can sit mid-tile); the aliased
    boundary page is then shared, and the engine CoW-forks it on the first
    divergent write.  Eviction is LRU over leaves whose pages hold no
    reference but the tree's — evicting a still-read node would free
    nothing and orphan the sharers' accounting."""

    def __init__(self, pool: PagePool, page: int):
        self.pool = pool
        self.page = page
        self.root = _RadixNode(np.empty(0, np.int32), [], None)
        self.clock = 0
        self.held_pages = 0  # pages currently carrying a tree reference
        self.inserted_pages = 0
        self.evicted_pages = 0

    @staticmethod
    def _common(a: np.ndarray, b: np.ndarray) -> int:
        n = min(len(a), len(b))
        if n == 0:
            return 0
        eq = a[:n] == b[:n]
        return int(eq.argmin()) if not eq.all() else n

    def _best_child(self, node: _RadixNode, tokens: np.ndarray):
        best, bk = None, 0
        if len(tokens):
            for child in node.children.get(int(tokens[0]), []):
                k = self._common(tokens, child.tokens)
                if k > bk:
                    best, bk = child, k
        return best, bk

    def match(self, prompt: np.ndarray, cap: int) -> tuple[int, list[int]]:
        """Longest cached prefix of ``prompt[:cap]``: returns (matched token
        count m, physical pages covering positions 0..m-1).  The last page is
        only partially matched when m lands mid-tile — aliasing it anyway is
        what lets chunked prefill start exactly at the divergence frontier;
        the engine must treat it as shared (fork before writing).  Touches
        the walked path's LRU clocks."""
        prompt = np.asarray(prompt, np.int32)
        self.clock += 1
        node, m, pages = self.root, 0, []
        node.last_use = self.clock
        while m < cap:
            best, bk = self._best_child(node, prompt[m:cap])
            if best is None or bk == 0:
                break
            best.last_use = self.clock
            pages += best.pages[: -(-bk // self.page)]
            m += bk
            if bk < len(best.tokens):
                break  # diverged (or cap) inside this edge
            node = best
        return m, pages

    def insert(self, tokens: np.ndarray, pages: list[int]) -> None:
        """Cache ``pages`` (full pages backing ``tokens``; len(tokens) ==
        len(pages) * page) — the tree retains the pages not already covered
        by an existing cached prefix."""
        tokens = np.asarray(tokens, np.int32)
        if len(tokens) != len(pages) * self.page:
            raise ValueError(
                f"insert of {len(tokens)} tokens over {len(pages)} pages of "
                f"{self.page} — only whole pages are cacheable"
            )
        self.clock += 1
        node = self.root
        node.last_use = self.clock
        i = 0
        while i < len(tokens):
            best, bk = self._best_child(node, tokens[i:])
            kp = (bk // self.page) * self.page  # page-aligned match depth
            if best is not None and kp == len(best.tokens):
                best.last_use = self.clock
                node = best
                i += kp
                continue
            if best is not None and kp > 0:
                # diverges past a page boundary inside the edge: split there
                best = self._split(best, kp)
                best.last_use = self.clock
                node = best
                i += kp
                continue
            # no child, or divergence inside the first page: new sibling
            new = _RadixNode(tokens[i:].copy(), list(pages[i // self.page:]), node)
            new.last_use = self.clock
            for p in new.pages:
                self.pool.retain(p, owner="radix")
            self.held_pages += len(new.pages)
            self.inserted_pages += len(new.pages)
            node.children.setdefault(int(tokens[i]), []).append(new)
            return
        # the whole run is already cached — nothing new to own

    def _split(self, node: _RadixNode, kp: int) -> _RadixNode:
        head = _RadixNode(node.tokens[:kp], node.pages[: kp // self.page],
                          node.parent)
        head.last_use = node.last_use
        bucket = node.parent.children[int(node.tokens[0])]
        bucket[bucket.index(node)] = head
        node.tokens = node.tokens[kp:]
        node.pages = node.pages[kp // self.page:]
        node.parent = head
        head.children = {int(node.tokens[0]): [node]}
        return head

    def _walk(self):
        stack = [self.root]
        while stack:
            n = stack.pop()
            for kids in n.children.values():
                stack.extend(kids)
            yield n

    def evict(self, need: int) -> int:
        """Free >= ``need`` pool pages by dropping least-recently-used cached
        prefixes whose pages nobody else references; returns pages freed
        (possibly fewer — everything left is either shared or interior)."""
        freed = 0
        while freed < need:
            victim = None
            for n in self._walk():
                if n is self.root or n.children:
                    continue  # interior nodes keep their prefix chain intact
                if any(self.pool.page_refs(p) > 1 for p in n.pages):
                    continue  # shared with an active request: frees nothing
                if victim is None or n.last_use < victim.last_use:
                    victim = n
            if victim is None:
                break
            for p in victim.pages:
                self.pool.release(p, owner="radix")
            freed += len(victim.pages)
            self.held_pages -= len(victim.pages)
            self.evicted_pages += len(victim.pages)
            bucket = victim.parent.children[int(victim.tokens[0])]
            bucket.remove(victim)
            if not bucket:
                del victim.parent.children[int(victim.tokens[0])]
        return freed

    def clear(self) -> None:
        """Drop every tree reference (end of run): pages shared with live
        readers survive until those readers release."""
        for n in self._walk():
            for p in n.pages:
                self.pool.release(p, owner="radix")
        self.root = _RadixNode(np.empty(0, np.int32), [], None)
        self.held_pages = 0


@dataclasses.dataclass
class _PagedSlot:
    """Host bookkeeping for one active request's pages: the retention
    schedule (from the block maps) plus its allocated tiles."""

    last_reader: np.ndarray  # (n_tiles,) last query position reading tile j
    peak_from: np.ndarray  # (L,) max future residency from frontier p
    length: int  # written-position horizon: plen + max_new - 1

    def remaining_peak(self, pos: int) -> int:
        return int(self.peak_from[min(pos, self.length - 1)])


# priority classes, best first.  Rank 0 is served ahead of rank 1 at every
# admission decision; the aging guard promotes a waiting batch request to
# rank 0 after ``aging_steps`` engine clocks so batch work is delayed under
# load, never starved.
_PRIORITY_RANK = {"interactive": 0, "batch": 1}


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray  # (S,) int32
    max_new: int
    arrival: int = 0  # earliest engine step at which the request exists
    priority: str = "interactive"  # scheduling class, see _PRIORITY_RANK
    generated: list[int] = dataclasses.field(default_factory=list)
    extras: dict = dataclasses.field(default_factory=dict)  # e.g. encdec frames
    # SLO accounting, in engine-step clock units (reset by each run()):
    emit_clocks: list[int] = dataclasses.field(default_factory=list)
    ttft: int | None = None  # first-token clock minus arrival
    preemptions: int = 0  # times this request was evicted and requeued


class _AdmitQueue:
    """Priority-ordered admission queue with an aging/starvation guard.

    ``peek(clock)`` returns the best ARRIVED request under the order
    (rank, arrival, insertion seq) — interactive ahead of batch, FIFO
    within a class — without removing it; the engine pops it only once its
    page reservation succeeds, so backpressure keeps the request queued.
    A batch request that has waited ``aging_steps`` clocks is promoted to
    the interactive rank (counted in ``promotions``): batch work is
    delayed under load, never starved.  ``fifo=True`` disables both the
    priority order and aging — the strict arrival-order baseline the
    --check-preempt gate compares against.  Preempted requests re-enter
    through ``push`` keeping their original ``arrival``, so their age (and
    any promotion) keeps accruing across evictions."""

    def __init__(self, requests: list[Request], aging_steps: int,
                 fifo: bool = False):
        self.aging_steps = aging_steps
        self.fifo = fifo
        self.promotions = 0
        self._seq = 0
        self._q: list[tuple[int, Request]] = []
        for r in requests:
            self.push(r)

    def __len__(self) -> int:
        return len(self._q)

    def push(self, r: Request) -> None:
        self._q.append((self._seq, r))
        self._seq += 1

    def rank(self, r: Request, clock: int) -> int:
        if self.fifo:
            return 0
        base = _PRIORITY_RANK[r.priority]
        if base and clock - r.arrival >= self.aging_steps:
            return 0  # aged: promoted to the interactive rank
        return base

    def peek(self, clock: int) -> Request | None:
        best_key, best = None, None
        for seq, r in self._q:
            if r.arrival > clock:
                continue
            key = (self.rank(r, clock), r.arrival, seq)
            if best_key is None or key < best_key:
                best_key, best = key, r
        return best

    def pop(self, r: Request, clock: int) -> None:
        for i, (_, q) in enumerate(self._q):
            if q is r:
                if (not self.fifo and _PRIORITY_RANK[r.priority]
                        and self.rank(r, clock) == 0):
                    self.promotions += 1
                del self._q[i]
                return
        raise ValueError(f"pop of request {r.uid} not in queue")


def _next_bucket(n: int, cap: int, floor: int = 8) -> int:
    """Smallest power-of-two >= n (>= floor), clamped at ``cap`` — the result
    is always a power of two or exactly ``cap``, so the jit shape cache stays
    bounded (at most log2(cap) values).  ``n`` must already be validated
    against ``cap`` (the engine checks prompts/positions against cache_len);
    a larger ``n`` is a caller bug, not a bucket to allocate."""
    if n > cap:
        raise ValueError(f"bucket request {n} exceeds cap {cap}")
    b = floor
    while b < n:
        b *= 2
    return min(b, cap)


class _AsyncTokens:
    """One-step-lag device-to-host token fetch.

    ``push(dev, sinks)`` registers a device array of sampled token ids and
    the (request, row) pairs that consumed them, starts an async copy, and
    resolves any record older than ``lag`` steps — so the host appends step
    t-1's values while step t's compute is already dispatched, and the
    per-token blocking ``np.asarray(argmax(...))`` sync disappears from the
    steady-state loop.  ``flush()`` resolves everything (end of run)."""

    def __init__(self, lag: int = 1):
        self.lag = lag
        self._q: collections.deque = collections.deque()

    def push(self, dev, sinks: list[tuple[Request, int]]) -> None:
        try:
            dev.copy_to_host_async()
        except AttributeError:  # non-array backends / older jax
            pass
        self._q.append((dev, sinks))
        while len(self._q) > self.lag:
            self._resolve()

    def _resolve(self) -> None:
        dev, sinks = self._q.popleft()
        vals = np.asarray(dev).reshape(-1)
        for r, i in sinks:
            r.generated.append(int(vals[i]))

    def flush(self) -> None:
        while self._q:
            self._resolve()


class ServeLoop:
    """Streaming serve engine (greedy sampling), two scheduling modes.

    **Chunked** — mixed-step scheduling: every iteration advances all slots
    through the ONE unified entry point (``tf.mixed_step``) at two ragged
    shapes — a (B, 1) decode wave (all decoding rows sample one token,
    kv_live bucketed at *their* live depth) plus a (1, C) slot-chunk call
    per mid-prompt row (up to ``chunk_size`` prompt tokens written straight
    into the slot's rows of the shared cache, bucketed at the prompt's own
    frontier).  Admission costs nothing (a freed slot just starts consuming
    the next request's chunks), a per-step ``chunk_budget`` caps total
    prefill tokens per iteration so decode latency stays bounded, and
    ``kv_live`` buckets (powers of two) bound the compiled shape count.
    Decode rows advance on EVERY step by construction —
    ``stats["decode_stall_steps"]`` stays 0.

    **Admission-prefill** (``chunked=False``) — the slot admit/evict engine:
    each admission runs a bucketed batch-1 prefill and inserts the caches at
    the slot index; all live decode slots idle for that prefill
    (``stats["admission_stall_steps"]`` counts them).  This is the seed
    contiguous engine, kept as the parity baseline; with
    ``static_batching=True`` it degrades admission to wave scheduling (the
    serve_throughput baseline).

    Both modes fetch sampled tokens with a one-step lag (`_AsyncTokens`):
    the decode feedback token stays on device, the host only tracks counts
    (stopping is length-based), so the loop never blocks on the current
    step's values.

    Per-slot host state mirrors the device-side (B,)-vector threading:
    ``pos[b]`` is request b's next write position (== tokens seen so far),
    so RoPE angles, cache writes and live-KV masks are all per-request.
    Prompts are *right*-padded / chunk-aligned — real tokens at positions
    0..L-1, positions and causal masks exact, pad keys never attended.

    ``paged=True`` additionally runs a radix-tree **prefix cache**
    (``prefix_cache=False`` disables it): completed prompts donate their
    full KV pages to a :class:`RadixCache`, admission longest-prefix
    matches new prompts against it, and a hit aliases the matched physical
    pages into the request's page table — prefill then starts at the
    divergence frontier and the admission reservation covers only the
    unique suffix.  Shared pages are refcounted in the :class:`PagePool`
    and copy-on-write forked before any divergent write.

    The page table is the ONLY cache substrate beyond the contiguous
    baseline: a **sliding-window** config serves through a mod-window ring
    table (``ring_tiles`` slots reused in phase, unbounded decode length,
    a fixed page set held per request) and an **encoder-decoder** config
    serves through read-only shared cross page ranges (the encoder output
    prefills once per distinct ``frames`` input; repeat inputs alias the
    cached range, counted as ``prefix_hits``; decode never writes cross
    pages so copy-on-write never triggers).  ``chunked=True`` requests for
    either family upgrade to ``paged=True`` automatically.  The token
    radix tree is disabled for those two families (ring slots are reused
    in phase; encdec decoder KV depends on the frames through
    cross-attention) — the encoder cache is their sharing layer.

    The :class:`PagePool`, the radix tree, and the encoder cache PERSIST
    across ``run()`` calls — a warm second run hits the first run's
    prefixes.  Call :meth:`close` to release the engine-held references;
    it raises if the pools do not drain to zero.
    """

    def __init__(
        self, cfg: ModelConfig, mesh: Mesh, params, *,
        batch: int, cache_len: int, attn_impl: str | None = None,
        attn_pattern: str | None = None, static_batching: bool = False,
        chunked: bool = False, chunk_size: int = 32,
        chunk_budget: int | None = None, paged: bool = False,
        page: int | None = None, pool_pages: int | None = None,
        prefix_cache: bool = True, scheduler: str = "priority",
        aging_steps: int = 64, max_preemptions: int = 2,
        preempt_min_progress: int = 1, slo_ttft: int | None = None,
        slo_itl: float | None = None,
    ):
        cfg = override_attention(cfg, impl=attn_impl, pattern=attn_pattern)
        if cfg.sliding_window and cache_len < cfg.sliding_window:
            raise ValueError(
                f"cache_len {cache_len} < sliding_window {cfg.sliding_window}: "
                "the ring modulus must equal the window for prefill/decode "
                "phase alignment"
            )
        stateful = [s.mixer for s in cfg.period_slots if s.mixer != "attn"]
        if stateful:
            raise ValueError(
                f"{cfg.name}: ragged serving requires attention-only stacks — "
                f"{stateful} mixers integrate right-pad tokens into their "
                "state during bucketed prefill (no per-row mask can undo it)"
            )
        if chunked:
            if static_batching:
                raise ValueError("chunked and static_batching are exclusive: "
                                 "chunked scheduling IS continuous")
            if chunk_size < 1:
                raise ValueError(f"chunk_size must be >= 1, got {chunk_size}")
            if chunk_budget is not None and chunk_budget < 1:
                raise ValueError(
                    f"chunk_budget must be >= 1, got {chunk_budget} — a "
                    "zero budget would starve prefill rows forever"
                )
        if paged and static_batching:
            raise ValueError("paged and static_batching are exclusive")
        if (chunked or paged) and cfg.n_img_tokens:
            # the ONE remaining extras rejection: stub image-patch tokens are
            # prepended inside prefill and have no chunk/page write path yet
            raise ValueError(
                "image-token extras have no chunked/paged path; use the "
                "admission-prefill engine (chunked=False, paged=False)"
            )
        if chunked and not paged and (
            cfg.sliding_window or cfg.family == "encdec"
        ):
            # one cache substrate: a chunked request for a ring or encoder-
            # decoder cache upgrades to the paged engine — the mod-window /
            # read-only page tables ARE the streaming layout for these
            # families (there is no contiguous chunked ring/encdec path)
            paged = True
        if scheduler not in ("priority", "fifo"):
            raise ValueError(
                f"scheduler must be 'priority' or 'fifo', got {scheduler!r}"
            )
        if aging_steps < 1:
            raise ValueError(f"aging_steps must be >= 1, got {aging_steps}")
        if max_preemptions < 0:
            raise ValueError(
                f"max_preemptions must be >= 0, got {max_preemptions}"
            )
        if preempt_min_progress < 1:
            raise ValueError(
                "preempt_min_progress must be >= 1, got "
                f"{preempt_min_progress} — zero progress between evictions "
                "is a livelock"
            )
        self.cfg, self.mesh, self.params = cfg, mesh, params
        self.batch, self.cache_len = batch, cache_len
        self.static_batching = static_batching
        self.chunked = chunked
        self.chunk_size = chunk_size
        self.chunk_budget = chunk_budget if chunk_budget is not None else chunk_size
        self.fifo = scheduler == "fifo"
        self.aging_steps = aging_steps
        self.max_preemptions = max_preemptions
        self.preempt_min_progress = preempt_min_progress
        self.slo_ttft = slo_ttft
        self.slo_itl = slo_itl
        self._closed = False
        # preemption needs a page substrate to evict from and a restartable
        # resume path; rings hold fixed in-phase page sets and encdec KV
        # depends on the frames through cross-attention — both families are
        # NON-preemptible (nothing warm to resume from, by declaration)
        self.preemptible = (
            paged and not self.fifo and max_preemptions > 0
            and not cfg.sliding_window and cfg.family != "encdec"
        )
        self.paged = paged
        if paged:
            spec = cfg.attention_spec
            # one page == one kv tile of the effective grid, so the packed
            # live tables ARE the page-table domain (tile-granular paging)
            self.page = page if page is not None else sparsity.pick_pattern_tiles(
                1, cache_len, spec.q_tile, spec.kv_tile
            )[1]
            if self.page < 1:
                raise ValueError(f"page must be >= 1 token, got {self.page}")
            self.ring_tiles: int | None = None
            if cfg.sliding_window:
                # mod-window ring: the table has exactly ring_tiles slots and
                # absolute tile j lives in slot j % ring_tiles — a window-
                # sized page set reused in phase, positions unbounded
                self.ring_tiles = sparsity.ring_tiles_for(
                    cfg.sliding_window, chunk_size, self.page
                )
                self.n_vtiles = self.ring_tiles
            else:
                self.n_vtiles = -(-cache_len // self.page)
            # default pool budget == the dense reservation the contiguous
            # engine would make (batch x cache_len rows; batch rings for a
            # window config) — benchmarks shrink it to show the capacity win
            self.pool_pages = (
                pool_pages if pool_pages is not None else batch * self.n_vtiles
            )
            if self.pool_pages < 1:
                raise ValueError(
                    f"pool_pages must be >= 1, got {self.pool_pages}"
                )
            # encoder-decoder: a SEPARATE read-only cross pool — encoder
            # outputs prefill once, decoders alias; sized for one distinct
            # encoder input per slot (the frames cache shares below that)
            self.cross_pages: int | None = None
            if cfg.family == "encdec":
                self.cross_tiles = -(-cfg.enc_seq // self.page)
                self.cross_pages = batch * self.cross_tiles
                self.cross_pool = PagePool(self.cross_pages)
                self._cross_cache: collections.OrderedDict[
                    str, list[int]
                ] = collections.OrderedDict()
            # prefix sharing: the radix tree is token-keyed, so it is OFF for
            # rings (slots are reused in phase — nothing stable to alias) and
            # for encdec decoders (self-KV depends on the encoder output
            # through cross-attention, not on tokens alone); encdec gets the
            # frames-keyed encoder cache instead.  Both the tree and the page
            # pool PERSIST across run() calls — drain checks live in close().
            self.prefix_cache = (
                prefix_cache and not cfg.sliding_window
                and cfg.family != "encdec"
            )
            self.pool = PagePool(self.pool_pages)
            self.radix: RadixCache | None = (
                RadixCache(self.pool, self.page) if self.prefix_cache else None
            )
            self._pools = None  # device pools, lazily built, persist too
            self._sched_cache: dict[tuple, _PagedSlot] = {}
            (self.p_prefill_fn, self.p_decode_fn, self.p_chunk_fn,
             self.p_copy_fn, self.p_encode_fn) = make_paged_fns(
                cfg, mesh, n_pages=self.pool_pages, page=self.page,
                chunk=chunk_size, cross_pages=self.cross_pages,
            )
            self.stats = {}
            return
        if chunked:
            # ONE entry point (tf.mixed_step), two ragged shapes: the (B, 1)
            # decode wave advances every decoding row each iteration at the
            # decode rows' OWN kv_live bucket, and each (1, C) slot-chunk
            # call streams a prompt chunk into the shared cache at its own
            # frontier bucket — decode work and prefill work never inflate
            # each other's compiled shapes or compute
            self.mixed1_fn = make_mixed_fn(
                cfg, mesh, batch=batch, cache_len=cache_len, chunk=1
            )
            self.chunk_fn = make_slot_chunk_fn(
                cfg, mesh, batch=batch, cache_len=cache_len, chunk=chunk_size
            )
        else:
            # batch-1 ragged prefill (jit retraces per bucket shape; caches
            # insert at a traced slot index so one compile covers every slot)
            # + batch-wide ragged decode, through the sharded entry points
            self.prefill_fn, _ = make_serve_fns(
                cfg, mesh, batch=1, cache_len=cache_len, ragged=True
            )
            _, self.decode_fn = make_serve_fns(
                cfg, mesh, batch=batch, cache_len=cache_len, ragged=True
            )
            self._insert = jax.jit(
                lambda caches, wave, slot: jax.tree.map(
                    lambda c, w: jax.lax.dynamic_update_slice_in_dim(
                        c, w.astype(c.dtype), slot, axis=1
                    ),
                    caches,
                    wave,
                ),
                donate_argnums=(0,),
            )
        self.stats: dict[str, int] = {}

    # -- per-slot prefill (admission-prefill mode) ------------------------

    def _prefill_one(self, r: Request):
        """Prefill one request (batch=1, right-padded to a bucket); returns
        (first sampled token — a DEVICE scalar, batch-1 cache tree)."""
        ln = len(r.prompt)
        bucket = _next_bucket(ln, self.cache_len)
        toks = np.zeros((1, bucket), np.int32)
        toks[0, :ln] = r.prompt
        b = {"tokens": jnp.asarray(toks)}
        for key, val in r.extras.items():
            b[key] = jnp.asarray(val)[None]
        logits, wave = self.prefill_fn(self.params, b, jnp.asarray([ln], jnp.int32))
        self.stats["prefill_calls"] = self.stats.get("prefill_calls", 0) + 1
        return jnp.argmax(logits[0]).astype(jnp.int32), wave

    def _zero_caches(self):
        specs = tf.cache_specs(self.cfg, self.batch, self.cache_len)
        dt = jnp.dtype(self.cfg.dtype)
        return jax.tree.map(
            lambda s: jnp.zeros(s.shape, dt),
            specs,
            is_leaf=lambda x: isinstance(x, shd.ParamSpec),
        )

    def _validate(self, requests: list[Request]) -> None:
        for r in requests:
            if r.arrival < 0:
                raise ValueError(
                    f"request {r.uid}: negative arrival {r.arrival} — the "
                    "engine clock starts at 0"
                )
            if r.priority not in _PRIORITY_RANK:
                raise ValueError(
                    f"request {r.uid}: unknown priority {r.priority!r} "
                    f"(expected one of {sorted(_PRIORITY_RANK)})"
                )
            if len(r.prompt) < 1:
                raise ValueError(f"request {r.uid}: prompt must be non-empty")
            if len(r.prompt) > self.cache_len:
                raise ValueError(
                    f"request {r.uid}: prompt {len(r.prompt)} > cache_len {self.cache_len}"
                )
            if r.max_new < 1:
                raise ValueError(f"request {r.uid}: max_new must be >= 1")
            # without a ring, decode writes positions L .. L+max_new-2 straight
            # into the cache — past cache_len they would silently clamp
            need = len(r.prompt) + r.max_new - 1
            if not self.cfg.sliding_window and need > self.cache_len:
                raise ValueError(
                    f"request {r.uid}: prompt+max_new needs {need} cache rows "
                    f"> cache_len {self.cache_len}"
                )
            if self.paged:
                if self.ring_tiles is not None:
                    # a ring request holds a FIXED page set to retirement
                    peak = min(self.ring_tiles, -(-need // self.page))
                elif self.chunked or self.cfg.family == "encdec":
                    # encdec admission streams the decoder prompt through
                    # the chunk entry point, so its spans are chunk-sized
                    peak = self._paged_schedule(
                        need, self.chunk_size
                    ).remaining_peak(0)
                else:
                    peak = self._paged_schedule(
                        need, len(r.prompt)
                    ).remaining_peak(0)
                if peak > self.pool_pages:
                    raise ValueError(
                        f"request {r.uid}: needs {peak} resident pages at its "
                        f"peak > pool of {self.pool_pages} — unservable at "
                        "this page budget"
                    )
                if self.cross_pages is not None and "frames" not in r.extras:
                    raise ValueError(
                        f"request {r.uid}: encoder-decoder serving needs "
                        "'frames' extras (the encoder input)"
                    )
            r.generated.clear()
            r.emit_clocks.clear()
            r.ttft = None
            r.preemptions = 0

    # -- engine loops -----------------------------------------------------

    def run(self, requests: list[Request]) -> list[Request]:
        """Serve every request to completion; returns them in input order."""
        self._validate(requests)
        if self.paged:
            if self.chunked:
                return self._run_paged_chunked(requests)
            return self._run_paged_admission(requests)
        if self.chunked:
            return self._run_chunked(requests)
        return self._run_admission(requests)

    # -- paged engine: page pool + per-request tile-granular page tables ----

    def _zero_pools(self):
        specs = tf.paged_pool_specs(self.cfg, self.pool_pages, self.page)
        dt = jnp.dtype(self.cfg.dtype)
        return jax.tree.map(
            lambda s: jnp.zeros(s.shape, dt),
            specs,
            is_leaf=lambda x: isinstance(x, shd.ParamSpec),
        )

    def _paged_schedule(
        self, length: int, step_span: int, start_tile: int = 0
    ) -> _PagedSlot:
        """Retention schedule for one request whose written positions span
        ``0..length-1``: per-tile last-reader positions (the union over every
        attention slot's pattern — one page table serves all layers) and the
        max-future-residency curve that backs the reservation discipline.
        ``step_span`` is the engine's largest single advance (chunk size, or
        the whole prompt for a monolithic admission prefill) — tiles
        allocated mid-step widen residency by that much.  ``start_tile > 0``
        prices only the unique suffix of a prefix-cache hit: aliased tiles
        are carried by the radix cache's references, the request allocates
        nothing below its divergence tile."""
        key = (length, step_span, start_tile)
        sc = self._sched_cache.get(key)
        if sc is not None:
            return sc
        spec = self.cfg.attention_spec
        pats = {
            s.attn_pattern or spec.pattern
            for s in self.cfg.period_slots
            if s.mixer == "attn"
        }
        last = sparsity.page_last_reader_union(
            pats, length, spec.q_tile, self.page, pattern_arg=spec.pattern_arg
        )
        res = sparsity.page_residency(
            last, length, self.page, step_span, start_tile
        )
        peak_from = np.maximum.accumulate(res[::-1])[::-1]
        sc = _PagedSlot(last_reader=last, peak_from=peak_from, length=length)
        self._sched_cache[key] = sc
        return sc

    def _ring_schedule(self, length: int) -> _PagedSlot:
        """Retention schedule of a mod-window ring request: a FIXED set of
        ``min(ring_tiles, ceil(length / page))`` pages allocated at admission
        and held to retirement — slots are reused in phase, so no tile ever
        frees early and the reservation is exact by construction."""
        key = ("ring", length)
        sc = self._sched_cache.get(key)
        if sc is None:
            n = min(self.ring_tiles, -(-length // self.page))
            sc = _PagedSlot(
                last_reader=np.full(self.n_vtiles, length - 1, np.int64),
                peak_from=np.full(max(length, 1), n, np.int64),
                length=max(length, 1),
            )
            self._sched_cache[key] = sc
        return sc

    def _committed(self, active, sched, pos) -> int:
        """Sum of active requests' worst-case future residency — admission
        reserves against this so `PagePool.alloc` can never fail mid-stream
        (out-of-pages becomes FIFO backpressure at admission instead)."""
        return sum(
            sched[s].remaining_peak(int(pos[s]))
            for s in range(self.batch)
            if active[s] is not None
        )

    def _ensure_writable(self, pool, pt, slot: int, lo_pos: int, hi_pos: int,
                         caches, owner: str = "?"):
        """Back every virtual tile overlapping positions [lo_pos, hi_pos)
        with a page this request may WRITE before the step that writes it:
        unbacked tiles allocate; tiles whose physical page is shared (an
        aliased prefix boundary, or a page the radix cache still owns)
        copy-on-write fork — pool fork + device row copy + table repoint —
        so the divergent write lands in a private copy instead of corrupting
        siblings.  Returns the (possibly copied-into) pools.

        Mod-window rings are a no-op here: the fixed ring pages were all
        allocated at admission, slots are reused in phase, and ring pages are
        never shared — there is nothing to back and nothing to fork."""
        if self.ring_tiles is not None:
            return caches
        for t in range(lo_pos // self.page, (hi_pos - 1) // self.page + 1):
            pid = int(pt[slot, t])
            if pid == self.pool_pages:
                pt[slot, t] = pool.alloc(owner)
            elif pool.page_refs(pid) > 1:
                new = pool.fork(pid, owner)
                caches = self.p_copy_fn(caches, jnp.int32(pid), jnp.int32(new))
                pt[slot, t] = new
        return caches

    def _free_dead(self, pool, pt, slot: int, sc: _PagedSlot, frontier: int,
                   owner: str | None = None):
        """Release pages whose last possible reader is behind the request's
        next query position — dense-causal never frees until retirement,
        window frees the out-of-window tail, butterfly frees every tile its
        remaining O(log n) stride pairs can no longer touch."""
        nt = len(sc.last_reader)
        for t in range(nt):
            if pt[slot, t] != self.pool_pages and sc.last_reader[t] < frontier:
                pool.release(int(pt[slot, t]), owner)
                pt[slot, t] = self.pool_pages

    def _free_all(self, pool, pt, slot: int, owner: str | None = None):
        for t in range(pt.shape[1]):
            if pt[slot, t] != self.pool_pages:
                pool.release(int(pt[slot, t]), owner)
                pt[slot, t] = self.pool_pages

    # -- prefix cache (radix tree over the page pool) ---------------------

    def _prefill_flop_count(self, pos0: int, t: int) -> float:
        """Analytic admission-side prefill work for ``t`` prompt tokens
        entering at absolute position ``pos0``: linear stack FLOPs plus the
        exact causal attention term.  This is what the --check-prefix gate
        compares — prefix hits skip the matched positions entirely, so the
        number scales with unique suffixes, not requests."""
        cfg = self.cfg
        n_attn = sum(
            1 for s in cfg.period_slots if s.mixer == "attn"
        ) * cfg.n_periods
        attn = 4.0 * cfg.n_heads * cfg.head_dim * n_attn * (
            t * pos0 + t * (t + 1) / 2.0
        )
        return t * M.model_flops_per_token(cfg, 1, mode="fwd") + attn

    def _match_prefix(self, prompt: np.ndarray) -> tuple[int, list[int]]:
        """Longest-prefix match at admission.  Caps the match at plen-1 (the
        last prompt token must run to produce first-token logits) and skips
        sub-page matches (no page to alias).  The caller must retain the
        returned pages before anything else can evict them.  ``prompt`` is
        the EFFECTIVE prompt: for a preempted request being resumed it is
        the original prompt plus every token already emitted, so the warm
        resume frontier is wherever the radix tree still covers it."""
        if self.radix is None:
            return 0, []
        plen = len(prompt)
        m, pages = self.radix.match(np.asarray(prompt, np.int32), plen - 1)
        if m < self.page:
            return 0, []
        return m, pages

    def _fits(self, need: int) -> int:
        """Reservation check against the pool, counting the radix cache's
        held pages; under pressure, LRU-evicts unreferenced cached prefixes.
        Returns the residual gap (<= 0 means the reservation fits)."""
        held = self.radix.held_pages if self.radix is not None else 0
        gap = need + held - self.pool_pages
        if gap > 0 and self.radix is not None:
            self.radix.evict(gap)
            gap = need + self.radix.held_pages - self.pool_pages
        return gap

    def _cache_pages(self, tokens: np.ndarray, pt, slot: int) -> None:
        """Hand ``tokens``' full, still-resident pages to the radix cache
        (shared ownership) — called on prompt completion AND on preemption,
        where ``tokens`` is the victim's written prefix so resume becomes a
        warm hit.  Retention may already have freed mid-prompt tiles
        (butterfly streams past them) — only the contiguous resident run
        from tile 0 is cacheable."""
        if self.radix is None:
            return
        k = len(tokens) // self.page
        run = 0
        while run < k and pt[slot, run] != self.pool_pages:
            run += 1
        if run:
            self.radix.insert(
                np.asarray(tokens[: run * self.page], np.int32),
                [int(pt[slot, t]) for t in range(run)],
            )

    def _suffix_prefill(self, prompt: np.ndarray, m: int, sc: _PagedSlot,
                        pool, pt, slot: int, caches, ct=None,
                        owner: str = "?"):
        """Admission-mode prefill of a prefix-cache hit: stream ONLY the
        unique suffix (positions m..plen-1) through the paged chunk entry
        point — prefill starts at the divergence frontier, attending the
        aliased prefix pages through the page table.  The first chunk
        CoW-forks the partially-shared boundary tile.  Dead tiles free
        between chunks (the unique-suffix reservation is priced at
        chunk-size spans, so the stream must keep that schedule).  Returns
        (first sampled token — device scalar, pools)."""
        C = self.chunk_size
        plen = len(prompt)
        p = m
        logits1 = None
        while p < plen:
            t = min(C, plen - p)
            caches = self._ensure_writable(pool, pt, slot, p, p + t, caches,
                                           owner)
            ctoks = np.zeros((1, C), np.int32)
            ctoks[0, :t] = prompt[p : p + t]
            kv_live = _next_bucket(p + t, self.cache_len)
            logits1, caches = self.p_chunk_fn(
                self.params, caches, jnp.asarray(ctoks),
                jnp.asarray(pt[slot : slot + 1]), jnp.int32(p), jnp.int32(t),
                kv_live, ct=ct,
            )
            self.stats["chunk_calls"] = self.stats.get("chunk_calls", 0) + 1
            self.stats["prefill_tokens"] += t
            self.stats["prefill_flops"] += self._prefill_flop_count(p, t)
            p += t
            self._free_dead(pool, pt, slot, sc, p, owner)
        return jnp.argmax(logits1).astype(jnp.int32), caches

    def _cross_admit(self, r: Request, slot: int, ct, caches):
        """Admit the request's ENCODER side: key the frames, alias the cached
        read-only page range on a hit (a ``retain`` per page — CoW can never
        trigger because decode never writes a cross page), or allocate a
        fresh range and run the encoder once on a miss.  Returns the updated
        pools, or ``None`` when the cross pool cannot fit a new range even
        after evicting every unreferenced cached encoder (backpressure)."""
        frames = np.asarray(r.extras["frames"], np.float32)
        key = frames.tobytes()
        pages = self._cross_cache.get(key)
        if pages is not None:
            self._cross_cache.move_to_end(key)  # LRU touch
            for p in pages:
                self.cross_pool.retain(p, owner=f"req{r.uid}")
            ct[slot, : len(pages)] = pages
            self.stats["prefix_hits"] += 1
            self.stats["prefix_hit_tokens"] += self.cfg.enc_seq
            self.stats["encoder_hits"] = self.stats.get("encoder_hits", 0) + 1
            return caches
        n = self.cross_tiles
        if self.cross_pool.free_pages < n:
            # evict LRU cached encoders nobody references but the cache
            for k in [
                k for k in self._cross_cache
                if all(
                    self.cross_pool.page_refs(p) == 1
                    for p in self._cross_cache[k]
                )
            ]:
                for p in self._cross_cache.pop(k):
                    self.cross_pool.release(p, owner="encoder-cache")
                if self.cross_pool.free_pages >= n:
                    break
        if self.cross_pool.free_pages < n:
            return None
        pages = [self.cross_pool.alloc("encoder-cache") for _ in range(n)]
        ct[slot, :n] = pages
        caches = self.p_encode_fn(
            self.params, caches, jnp.asarray(frames)[None],
            jnp.asarray(ct[slot : slot + 1]),
        )
        for p in pages:  # the request's own reference; alloc's is the cache's
            self.cross_pool.retain(p, owner=f"req{r.uid}")
        self._cross_cache[key] = pages
        self.stats["encode_calls"] = self.stats.get("encode_calls", 0) + 1
        return caches

    def _release_cross(self, ct, slot: int, owner: str | None = None) -> None:
        """Drop the request's references on its aliased cross page range."""
        for t in range(ct.shape[1]):
            if ct[slot, t] != self.cross_pages:
                self.cross_pool.release(int(ct[slot, t]), owner)
                ct[slot, t] = self.cross_pages

    # -- priority scheduling, preemption, SLO accounting ------------------

    @staticmethod
    def _eff_prompt(r: Request) -> np.ndarray:
        """The EFFECTIVE prompt of an admission: the original prompt plus
        every already-emitted token — non-empty ``generated`` only for a
        preempted request being resumed.  Greedy sampling makes the resume
        token-identical: re-prefilling the written prefix reconstructs the
        exact cache the victim lost (warm via the radix tree where its
        pages survived, cold recompute otherwise), and the next sampled
        token follows deterministically."""
        if not r.generated:
            return np.asarray(r.prompt, np.int32)
        return np.concatenate(
            [np.asarray(r.prompt, np.int32),
             np.asarray(r.generated, np.int32)]
        )

    def _stamp_emits(self, sinks: list[tuple[Request, int]],
                     clock: int) -> None:
        """Record the emission clock of every token pushed this step — the
        raw series per-request TTFT / inter-token latency aggregate from."""
        for r, _ in sinks:
            if r.ttft is None:
                r.ttft = clock - r.arrival
            r.emit_clocks.append(clock)

    def _finalize_slo(self, requests: list[Request],
                      q: _AdmitQueue) -> None:
        """End-of-run latency aggregation: p50/p99 TTFT and mean inter-token
        latency per priority class (engine-step clock units), the
        SLO-attainment fraction (1.0 when no SLO is configured), and the
        scheduler counters every loop shares."""
        per: dict[str, dict[str, list[float]]] = {}
        attained: list[bool] = []
        for r in requests:
            if not r.emit_clocks:
                continue
            t = float(r.ttft)
            gaps = np.diff(np.asarray(r.emit_clocks))
            itl = float(gaps.mean()) if len(gaps) else 0.0
            d = per.setdefault(r.priority, {"ttft": [], "itl": []})
            d["ttft"].append(t)
            d["itl"].append(itl)
            ok = True
            if self.slo_ttft is not None and t > self.slo_ttft:
                ok = False
            if self.slo_itl is not None and itl > self.slo_itl:
                ok = False
            attained.append(ok)
        slo = {}
        for prio in sorted(per):
            ts = np.asarray(per[prio]["ttft"])
            its = np.asarray(per[prio]["itl"])
            slo[prio] = {
                "n": int(len(ts)),
                "ttft_p50": float(np.percentile(ts, 50)),
                "ttft_p99": float(np.percentile(ts, 99)),
                "itl_p50": float(np.percentile(its, 50)),
                "itl_p99": float(np.percentile(its, 99)),
            }
        self.stats["slo"] = slo
        self.stats["slo_attainment"] = (
            float(np.mean(attained)) if attained else 1.0
        )
        self.stats["aging_promotions"] = q.promotions
        self.stats["starved_requests"] = sum(
            1 for r in requests if not r.emit_clocks
        )
        self.stats.setdefault("preemptions", 0)

    def _preempt_slot(self, s: int, q: _AdmitQueue, fetch, pool, pt,
                      active, sched, parr, pos) -> None:
        """Evict the request in slot ``s``: flush the async token fetch (the
        snapshot must hold every emitted token), donate its written prefix's
        full resident pages to the radix tree (so resume is a warm hit),
        release its pool pages, and requeue it at its ORIGINAL arrival so
        its age — and any aging promotion — keeps accruing."""
        fetch.flush()
        r = active[s]
        written = self._eff_prompt(r)[: int(pos[s])]
        self._cache_pages(written, pt, s)
        self._free_all(pool, pt, s, owner=f"req{r.uid}")
        r.preemptions += 1
        self.stats["preemptions"] = self.stats.get("preemptions", 0) + 1
        active[s] = None
        sched[s] = None
        if parr is not None:
            parr[s] = None
        q.push(r)

    def _preempt_until(self, need, rank: int, q: _AdmitQueue, fetch, pool,
                       pt, active, sched, parr, pos, admit_pos,
                       admit_seq) -> int:
        """Preempt youngest lowest-priority victims until the reservation
        gap ``self._fits(need())`` closes or no eligible victim remains;
        returns the final gap (<= 0 means the admission fits).  A victim
        must hold a strictly worse RAW priority rank than the admitting
        request (aging changes admission order, never preemption power), be
        under the per-request preemption cap, and have advanced at least
        ``preempt_min_progress`` positions since its own admission — the
        cap bounds total evictions and the progress floor bounds wasted
        work, so preempt/resume cannot livelock."""
        gap = self._fits(need())
        while gap > 0:
            victim, vkey = None, None
            for s in range(self.batch):
                a = active[s]
                if a is None:
                    continue
                if _PRIORITY_RANK[a.priority] <= rank:
                    continue
                if a.preemptions >= self.max_preemptions:
                    continue
                if int(pos[s]) - int(admit_pos[s]) < self.preempt_min_progress:
                    continue
                key = (_PRIORITY_RANK[a.priority], int(a.arrival),
                       int(admit_seq[s]))
                if victim is None or key > vkey:
                    victim, vkey = s, key
            if victim is None:
                break
            self._preempt_slot(victim, q, fetch, pool, pt, active, sched,
                               parr, pos)
            gap = self._fits(need())
        return gap

    def close(self) -> None:
        """Release the engine-held cache state (radix tree references, cached
        encoder cross ranges) and check the pools drain to zero.  The pools
        and the prefix caches PERSIST across ``run()`` calls — a warm second
        run alias-hits the first run's prompts — so the end-of-run drain
        assertion of the per-run engines lives here instead.

        Idempotent: a second ``close()`` after a CLEAN first one is a no-op.
        A close that raised (leak detected) stays re-runnable so a caller
        can release the stragglers and verify the drain; the leak error
        names the holders (:meth:`PagePool.holders` labels) so the bug site
        is attributable without a refcount bisect."""
        if self._closed or not self.paged:
            self._closed = True
            return
        if self.radix is not None:
            self.radix.clear()
        if self.cross_pages is not None:
            for pages in self._cross_cache.values():
                for p in pages:
                    self.cross_pool.release(p, owner="encoder-cache")
            self._cross_cache.clear()
            if self.cross_pool.in_use:
                raise RuntimeError(
                    f"cross pool leak: {self.cross_pool.in_use} pages still "
                    "referenced after close() released the encoder cache — "
                    f"held by {self.cross_pool.holders()}"
                )
        if self.pool.in_use:
            raise RuntimeError(
                f"page pool leak: {self.pool.in_use} pages still referenced "
                "after close() released the radix tree — held by "
                f"{self.pool.holders()}"
            )
        self._closed = True

    def __enter__(self) -> "ServeLoop":
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        if exc_type is not None:
            # an exception is already propagating: close best-effort, but a
            # leak (requests mid-flight when the body raised) must not mask
            # the original error
            try:
                self.close()
            except RuntimeError:
                pass
            return False
        self.close()
        return False

    def _finish_paged_run(self, pool) -> None:
        """End-of-run bookkeeping shared by both paged loops: surface the
        pool and prefix-cache counters.  Requests have released all their
        references by now; what remains in ``in_use`` is exactly the engine-
        held cache state (radix tree + encoder cross ranges), which persists
        for the next run and drains in :meth:`close`."""
        self.stats["pool_pages"] = self.pool_pages
        self.stats["pool_peak_pages"] = pool.peak_in_use
        self.stats["page_allocs"] = pool.alloc_count
        self.stats["cow_forks"] = pool.fork_count
        if self.radix is not None:
            self.stats["prefix_cached_pages_end"] = self.radix.held_pages
            self.stats["prefix_inserted_pages"] = self.radix.inserted_pages
            self.stats["prefix_evicted_pages"] = self.radix.evicted_pages
        if self.cross_pages is not None:
            self.stats.setdefault("encode_calls", 0)
            self.stats["cross_pool_pages"] = self.cross_pages
            self.stats["cross_pool_peak_pages"] = self.cross_pool.peak_in_use
            self.stats["cross_cached_ranges_end"] = len(self._cross_cache)

    def _run_admission(self, requests: list[Request]) -> list[Request]:
        """Admission-prefill engine: per-slot prefill + cache insert, then
        ragged decode steps; finished requests retire immediately and free
        their slot — but every admission stalls all live decode slots for
        one blocking batch-1 prefill (counted in ``admission_stall_steps``).
        """
        q = _AdmitQueue(requests, self.aging_steps, self.fifo)
        active: list[Request | None] = [None] * self.batch
        pos = np.zeros(self.batch, np.int32)  # next write position per slot
        remaining = np.zeros(self.batch, np.int32)  # decode tokens still owed
        nxt = jnp.zeros((self.batch,), jnp.int32)  # device feedback tokens
        fetch = _AsyncTokens(lag=1)
        self.stats = {
            "prefill_calls": 0, "decode_steps": 0, "admission_stall_steps": 0,
        }
        clock = 0  # admission clock: decode steps + idle ticks (arrivals)
        with self.mesh:
            caches = self._zero_caches()
            while len(q) or any(r is not None for r in active):
                # admit: fill free slots (waves only, under static batching)
                may_admit = not self.static_batching or all(
                    r is None for r in active
                )
                if may_admit:
                    for slot in range(self.batch):
                        if active[slot] is not None:
                            continue
                        r = q.peek(clock)
                        if r is None:
                            break  # nothing in the queue has arrived yet
                        q.pop(r, clock)
                        if any(a is not None for a in active):
                            # live decode slots idle for this whole prefill —
                            # the stall the chunked engine exists to remove
                            self.stats["admission_stall_steps"] += 1
                        tok, wave = self._prefill_one(r)
                        self._stamp_emits([(r, 0)], clock)
                        fetch.push(tok, [(r, 0)])
                        if r.max_new <= 1:
                            continue  # done at prefill; slot stays free
                        caches = self._insert(caches, wave, jnp.int32(slot))
                        active[slot] = r
                        pos[slot] = len(r.prompt)
                        remaining[slot] = r.max_new - 1
                        nxt = nxt.at[slot].set(tok)
                if not any(r is not None for r in active):
                    clock += 1  # idle tick: waiting on arrivals
                    continue
                # one ragged decode step for the whole batch; attention
                # streams only the live cache prefix (bucketed so each bucket
                # compiles once) — a short wave on a deep cache reads its own
                # tiles, not the padded cache.  Ring caches keep their own
                # mod-window layout and stream the whole (window-sized) ring.
                kv_live = None
                if not self.cfg.sliding_window:
                    hot = max(int(pos[s]) for s in range(self.batch)
                              if active[s] is not None) + 1
                    kv_live = _next_bucket(hot, self.cache_len)
                    self.stats["decode_kv_live_max"] = max(
                        self.stats.get("decode_kv_live_max", 0), kv_live
                    )
                logits, caches = self.decode_fn(
                    self.params, caches, nxt[:, None], jnp.asarray(pos), kv_live,
                )
                self.stats["decode_steps"] += 1
                clock += 1
                toks = jnp.argmax(logits, -1).astype(jnp.int32)
                sinks = []
                for slot in range(self.batch):
                    r = active[slot]
                    if r is None:
                        continue
                    sinks.append((r, slot))
                    pos[slot] += 1
                    remaining[slot] -= 1
                    if remaining[slot] <= 0:
                        active[slot] = None  # evict: slot frees for the queue
                self._stamp_emits(sinks, clock)
                fetch.push(toks, sinks)
                nxt = toks
        fetch.flush()
        self._finalize_slo(requests, q)
        return requests

    def _run_chunked(self, requests: list[Request]) -> list[Request]:
        """Mixed-step engine: every iteration advances ALL slots — one (B, 1)
        decode wave samples every decoding row, then each mid-prompt row
        streams one chunk into the shared cache through a (1, C) slot-chunk
        call — so a long admission never stalls the batch, and decode steps
        stay bucketed at the decode rows' own live-cache depth while the
        prompt streams at its own."""
        B, C = self.batch, self.chunk_size
        q = _AdmitQueue(requests, self.aging_steps, self.fifo)
        active: list[Request | None] = [None] * B
        pos = np.zeros(B, np.int32)  # next cache write position per slot
        consumed = np.zeros(B, np.int32)  # prompt tokens consumed per slot
        remaining = np.zeros(B, np.int32)  # decode tokens still owed
        nxt = jnp.zeros((B,), jnp.int32)  # device feedback tokens
        zeros_b1 = jnp.zeros((B, 1), jnp.int32)
        fetch = _AsyncTokens(lag=1)
        self.stats = {
            "prefill_calls": 0, "mixed_steps": 0, "chunk_calls": 0,
            "decode_steps": 0, "prefill_tokens": 0, "decode_tokens": 0,
            "decode_stall_steps": 0, "overlap_steps": 0,
        }
        clock = 0
        rr = 0  # round-robin offset: fair prefill budget across slots
        with self.mesh:
            caches = self._zero_caches()
            while len(q) or any(r is not None for r in active):
                # admission is free: a freed slot starts consuming the next
                # arrived request's chunks on the very next mixed step
                for slot in range(B):
                    if active[slot] is not None:
                        continue
                    r = q.peek(clock)
                    if r is None:
                        break  # nothing in the queue has arrived yet
                    q.pop(r, clock)
                    active[slot] = r
                    pos[slot] = 0
                    consumed[slot] = 0
                    remaining[slot] = r.max_new
                if not any(r is not None for r in active):
                    clock += 1  # idle tick: waiting on arrivals
                    continue
                # schedule: decode rows always advance; prompt rows split the
                # per-step chunk budget under a round-robin rotation
                eligible = [
                    s for s in range(B)
                    if active[s] is not None
                    and len(active[s].prompt) - consumed[s] <= 0
                ]
                use_nxt = np.zeros(B, bool)
                chunk_t = np.zeros(B, np.int32)
                budget = self.chunk_budget
                # interactive rows split the chunk budget ahead of batch
                # rows; the rotation keeps it fair within a class (and IS
                # the whole order under uniform priority / fifo scheduling)
                order = sorted(
                    range(B),
                    key=lambda s: (
                        0 if self.fifo or active[s] is None
                        else _PRIORITY_RANK[active[s].priority],
                        (s - rr) % B,
                    ),
                )
                for slot in order:
                    r = active[slot]
                    if r is None:
                        continue
                    rem_prompt = len(r.prompt) - consumed[slot]
                    if rem_prompt > 0:
                        t = min(C, rem_prompt, budget)
                        if t <= 0:
                            continue  # budget-starved this step; retries next
                        chunk_t[slot] = t
                        budget -= t
                    else:
                        use_nxt[slot] = True  # decode rows: never budget-gated
                rr = (rr + 1) % B
                clock += 1
                self.stats["mixed_steps"] += 1
                dec_rows = [s for s in range(B) if use_nxt[s]]
                chunk_rows = [s for s in range(B) if chunk_t[s] > 0]
                if any(s not in dec_rows for s in eligible):
                    # observational, not definitional: trips if a scheduler
                    # change ever gates a decode-eligible row (e.g. on the
                    # chunk budget) — the CI gate asserts this stays 0
                    self.stats["decode_stall_steps"] += 1
                if dec_rows and chunk_rows:
                    self.stats["overlap_steps"] += 1  # the §V-A overlap
                # (a) decode wave — mixed_step at (B, 1), bucketed by the
                # decode rows' own frontier (a short request decoding next to
                # a 4k prompt mid-prefill still reads a shallow cache)
                if dec_rows:
                    ntok_a = np.where(use_nxt, 1, 0).astype(np.int32)
                    hot = max(int(pos[s]) + 1 for s in dec_rows)
                    kv_live = _next_bucket(hot, self.cache_len)
                    self.stats["decode_kv_live_max"] = max(
                        self.stats.get("decode_kv_live_max", 0), kv_live
                    )
                    logits, caches = self.mixed1_fn(
                        self.params, caches, zeros_b1, nxt,
                        jnp.asarray(use_nxt), jnp.asarray(pos),
                        jnp.asarray(ntok_a), kv_live,
                    )
                    toks = jnp.argmax(logits, -1).astype(jnp.int32)
                    self.stats["decode_steps"] += 1
                    self.stats["decode_tokens"] += len(dec_rows)
                    sinks = []
                    for slot in dec_rows:
                        r = active[slot]
                        sinks.append((r, slot))
                        pos[slot] += 1
                        remaining[slot] -= 1
                        if remaining[slot] <= 0:
                            active[slot] = None
                    self._stamp_emits(sinks, clock)
                    fetch.push(toks, sinks)
                    nxt = jnp.where(jnp.asarray(use_nxt), toks, nxt)
                # (b) prompt chunks — mixed_step at (1, C) per mid-prompt
                # row, streaming into the slot's rows of the shared cache at
                # the prompt's own frontier bucket
                for slot in chunk_rows:
                    r = active[slot]
                    t = int(chunk_t[slot])
                    ctoks = np.zeros((1, C), np.int32)
                    ctoks[0, :t] = r.prompt[consumed[slot] : consumed[slot] + t]
                    kv_live = _next_bucket(int(pos[slot]) + t, self.cache_len)
                    logits1, caches = self.chunk_fn(
                        self.params, caches, jnp.asarray(ctoks),
                        jnp.int32(slot), jnp.int32(pos[slot]), jnp.int32(t),
                        kv_live,
                    )
                    self.stats["chunk_calls"] += 1
                    self.stats["prefill_tokens"] += t
                    pos[slot] += t
                    consumed[slot] += t
                    if consumed[slot] == len(r.prompt):
                        # the chunk that finishes the prompt samples the
                        # first generated token (logits at ntok-1)
                        tok1 = jnp.argmax(logits1).astype(jnp.int32)
                        self._stamp_emits([(r, 0)], clock)
                        fetch.push(tok1, [(r, 0)])
                        nxt = nxt.at[slot].set(tok1)
                        remaining[slot] -= 1
                        if remaining[slot] <= 0:
                            active[slot] = None
        fetch.flush()
        self._finalize_slo(requests, q)
        return requests

    def _run_paged_admission(self, requests: list[Request]) -> list[Request]:
        """Admission-by-pages engine: per-request batch-1 prefill scattered
        straight into the page pool through the request's page-table row,
        then ragged paged decode waves.  A free SLOT no longer suffices for
        admission — the request must also reserve its worst-case resident
        page count; otherwise it backpressures in FIFO order until decode
        frees pages.  Resident HBM is the pool, not batch x cache_len.

        With the radix prefix cache on, admission first longest-prefix
        matches the prompt: a hit aliases the cached pages into the page
        table, reserves only the unique-suffix peak, and prefills JUST the
        suffix from the divergence frontier (via the chunk entry point)."""
        B = self.batch
        q = _AdmitQueue(requests, self.aging_steps, self.fifo)
        active: list[Request | None] = [None] * B
        sched: list[_PagedSlot | None] = [None] * B
        pos = np.zeros(B, np.int32)
        remaining = np.zeros(B, np.int32)
        admit_pos = np.zeros(B, np.int32)  # pos at admission: progress floor
        admit_seq = np.zeros(B, np.int64)  # admission order: victim tiebreak
        aseq = 0
        nxt = jnp.zeros((B,), jnp.int32)
        pt = np.full((B, self.n_vtiles), self.pool_pages, np.int32)
        pool = self.pool
        ct = None
        if self.cross_pages is not None:
            ct = np.full((B, self.cross_tiles), self.cross_pages, np.int32)
        fetch = _AsyncTokens(lag=1)
        self.stats = {
            "prefill_calls": 0, "decode_steps": 0, "admission_stall_steps": 0,
            "admission_backpressure": 0, "max_concurrent": 0,
            "prefill_tokens": 0, "prefill_flops": 0.0,
            "prefix_hits": 0, "prefix_hit_tokens": 0,
            "preemptions": 0, "resumes": 0, "resume_warm_hits": 0,
        }
        clock = 0
        with self.mesh:
            caches = (
                self._pools if self._pools is not None else self._zero_pools()
            )
            while len(q) or any(r is not None for r in active):
                for slot in range(B):
                    if active[slot] is not None:
                        continue
                    r = q.peek(clock)
                    if r is None:
                        break  # nothing in the queue has arrived yet
                    pr = self._eff_prompt(r)  # prompt + resumed tokens
                    plen = len(pr)
                    mn = r.max_new - len(r.generated)
                    L = plen + mn - 1  # == original prompt + max_new - 1
                    own = f"req{r.uid}"
                    rank = _PRIORITY_RANK[r.priority]
                    # prefix hit: alias cached pages, reserve the unique
                    # suffix only; fall back to a cold admission if even
                    # that reservation cannot fit (after preempting any
                    # eligible lower-priority victims)
                    m, spages = self._match_prefix(pr)
                    if m:
                        for p in spages:
                            pool.retain(p, owner=own)
                        sc = self._paged_schedule(
                            L, step_span=self.chunk_size,
                            start_tile=m // self.page,
                        )
                        need = lambda: (
                            self._committed(active, sched, pos)
                            + sc.remaining_peak(m)
                        )
                        gap = self._fits(need())
                        if gap > 0 and self.preemptible:
                            gap = self._preempt_until(
                                need, rank, q, fetch, pool, pt, active,
                                sched, None, pos, admit_pos, admit_seq,
                            )
                        if gap > 0:
                            for p in spages:
                                pool.release(p, owner=own)
                            cold_peak = self._paged_schedule(
                                L, step_span=(
                                    self.chunk_size
                                    if self.cross_pages is not None else plen
                                ),
                            ).remaining_peak(0)
                            if cold_peak < sc.remaining_peak(m):
                                # cold genuinely cheaper (retention frees
                                # tiles the alias would pin): retry cold
                                m, spages = 0, []
                            else:
                                # cold could not fit either — and its _fits
                                # would evict the very prefix (a preemption
                                # victim's donated pages) that makes the
                                # eventual resume warm
                                self.stats["admission_backpressure"] += 1
                                break
                    if not m:
                        if self.ring_tiles is not None:
                            sc = self._ring_schedule(L)
                        elif self.cross_pages is not None:
                            # encdec streams the decoder prompt through the
                            # chunk entry point — spans are chunk-sized
                            sc = self._paged_schedule(
                                L, step_span=self.chunk_size
                            )
                        else:
                            sc = self._paged_schedule(L, step_span=plen)
                        need = lambda: (
                            self._committed(active, sched, pos)
                            + sc.remaining_peak(0)
                        )
                        gap = self._fits(need())
                        if gap > 0 and self.preemptible:
                            gap = self._preempt_until(
                                need, rank, q, fetch, pool, pt, active,
                                sched, None, pos, admit_pos, admit_seq,
                            )
                        if gap > 0:
                            # out of pages: the head waits for decode to free
                            # some — backpressure, not an error
                            self.stats["admission_backpressure"] += 1
                            break
                    if self.cross_pages is not None:
                        nc = self._cross_admit(r, slot, ct, caches)
                        if nc is None:
                            # no cross range free for a new encoder input
                            self.stats["admission_backpressure"] += 1
                            break
                        caches = nc
                    q.pop(r, clock)
                    if r.preemptions:  # a victim re-admitting (possibly
                        self.stats["resumes"] += 1  # mid-prefill, no tokens)
                        if m:
                            self.stats["resume_warm_hits"] += 1
                    if any(a is not None for a in active):
                        self.stats["admission_stall_steps"] += 1
                    ct_row = (
                        None if ct is None else jnp.asarray(ct[slot:slot + 1])
                    )
                    if m:
                        for i, p in enumerate(spages):
                            pt[slot, i] = p
                        self.stats["prefix_hits"] += 1
                        self.stats["prefix_hit_tokens"] += m
                        tok, caches = self._suffix_prefill(
                            pr, m, sc, pool, pt, slot, caches, owner=own
                        )
                    elif self.ring_tiles is not None or ct is not None:
                        # mod-window rings allocate their fixed page set up
                        # front; both rings and encoder-decoder admissions
                        # then STREAM the prompt through the chunk entry
                        # point (a monolithic paged prefill would wrap the
                        # ring / has no cross-table path)
                        if self.ring_tiles is not None:
                            for t in range(
                                min(self.ring_tiles, -(-L // self.page))
                            ):
                                pt[slot, t] = pool.alloc(own)
                        tok, caches = self._suffix_prefill(
                            pr, 0, sc, pool, pt, slot, caches, ct=ct_row,
                            owner=own,
                        )
                    else:
                        caches = self._ensure_writable(
                            pool, pt, slot, 0, plen, caches, own
                        )
                        bucket = _next_bucket(plen, self.cache_len)
                        toks = np.zeros((1, bucket), np.int32)
                        toks[0, :plen] = pr
                        logits, caches = self.p_prefill_fn(
                            self.params, caches, {"tokens": jnp.asarray(toks)},
                            jnp.asarray([plen], jnp.int32),
                            jnp.asarray(pt[slot : slot + 1]),
                        )
                        self.stats["prefill_calls"] += 1
                        self.stats["prefill_tokens"] += plen
                        self.stats["prefill_flops"] += (
                            self._prefill_flop_count(0, plen)
                        )
                        tok = jnp.argmax(logits[0]).astype(jnp.int32)
                    self._stamp_emits([(r, 0)], clock)
                    fetch.push(tok, [(r, 0)])
                    self._cache_pages(pr, pt, slot)
                    if mn <= 1:
                        self._free_all(pool, pt, slot, own)
                        if ct is not None:
                            self._release_cross(ct, slot, own)
                        continue  # done at prefill; slot and pages free
                    self._free_dead(pool, pt, slot, sc, plen, own)
                    active[slot] = r
                    sched[slot] = sc
                    pos[slot] = plen
                    admit_pos[slot] = plen
                    admit_seq[slot] = aseq
                    aseq += 1
                    remaining[slot] = mn - 1
                    nxt = nxt.at[slot].set(tok)
                self.stats["max_concurrent"] = max(
                    self.stats["max_concurrent"],
                    sum(a is not None for a in active),
                )
                if not any(r is not None for r in active):
                    clock += 1
                    continue
                # ragged paged decode wave: back each row's write tile (CoW-
                # forking a still-shared boundary tile), then every row
                # streams its own live pages through its page-table row at
                # the bucketed virtual depth
                for slot in range(B):
                    if active[slot] is not None:
                        caches = self._ensure_writable(
                            pool, pt, slot, int(pos[slot]),
                            int(pos[slot]) + 1, caches,
                            f"req{active[slot].uid}",
                        )
                if self.ring_tiles is not None:
                    # the ring streams its fixed window-sized page set and
                    # positions are unbounded — no live-depth bucketing
                    kv_live = None
                else:
                    hot = max(int(pos[s]) for s in range(B)
                              if active[s] is not None) + 1
                    kv_live = _next_bucket(hot, self.cache_len)
                    self.stats["decode_kv_live_max"] = max(
                        self.stats.get("decode_kv_live_max", 0), kv_live
                    )
                logits, caches = self.p_decode_fn(
                    self.params, caches, nxt[:, None], jnp.asarray(pos),
                    jnp.asarray(pt), kv_live,
                    **({} if ct is None else {"ct": jnp.asarray(ct)}),
                )
                self.stats["decode_steps"] += 1
                clock += 1
                toks = jnp.argmax(logits, -1).astype(jnp.int32)
                sinks = []
                for slot in range(B):
                    r = active[slot]
                    if r is None:
                        continue
                    sinks.append((r, slot))
                    pos[slot] += 1
                    remaining[slot] -= 1
                    if remaining[slot] <= 0:
                        self._free_all(pool, pt, slot, f"req{r.uid}")
                        if ct is not None:
                            self._release_cross(ct, slot, f"req{r.uid}")
                        active[slot] = None
                        sched[slot] = None
                    else:
                        self._free_dead(
                            pool, pt, slot, sched[slot], int(pos[slot]),
                            f"req{r.uid}",
                        )
                self._stamp_emits(sinks, clock)
                fetch.push(toks, sinks)
                nxt = toks
        fetch.flush()
        self._pools = caches
        self._finish_paged_run(pool)
        self._finalize_slo(requests, q)
        return requests

    def _run_paged_chunked(self, requests: list[Request]) -> list[Request]:
        """Mixed-step engine over the page pool: the decode wave and the
        per-row prompt chunks of the chunked scheduler, with cache writes and
        reads indirected through per-request page tables.  Pages allocate
        lazily at each row's write frontier and free as soon as the
        retention schedule says no future query can read them — a butterfly
        prompt releases most of its tiles WHILE it streams in, which is the
        capacity win the paged_capacity benchmark measures.

        A radix prefix-cache hit admits at the divergence frontier: the
        matched pages alias into the slot's page table, ``pos``/``consumed``
        start at the matched length, and the reservation covers only the
        unique suffix — chunk streaming then picks up mid-prompt exactly as
        if the prefix had already streamed."""
        B, C = self.batch, self.chunk_size
        q = _AdmitQueue(requests, self.aging_steps, self.fifo)
        active: list[Request | None] = [None] * B
        sched: list[_PagedSlot | None] = [None] * B
        parr: list[np.ndarray | None] = [None] * B  # effective prompt per slot
        pos = np.zeros(B, np.int32)
        consumed = np.zeros(B, np.int32)
        remaining = np.zeros(B, np.int32)
        admit_pos = np.zeros(B, np.int32)  # pos at admission: progress floor
        admit_seq = np.zeros(B, np.int64)  # admission order: victim tiebreak
        aseq = 0
        nxt = jnp.zeros((B,), jnp.int32)
        pt = np.full((B, self.n_vtiles), self.pool_pages, np.int32)
        pool = self.pool
        ct = None
        if self.cross_pages is not None:
            ct = np.full((B, self.cross_tiles), self.cross_pages, np.int32)
        fetch = _AsyncTokens(lag=1)
        self.stats = {
            "prefill_calls": 0, "mixed_steps": 0, "chunk_calls": 0,
            "decode_steps": 0, "prefill_tokens": 0, "decode_tokens": 0,
            "decode_stall_steps": 0, "overlap_steps": 0,
            "admission_backpressure": 0, "max_concurrent": 0,
            "prefill_flops": 0.0, "prefix_hits": 0, "prefix_hit_tokens": 0,
            "preemptions": 0, "resumes": 0, "resume_warm_hits": 0,
        }
        clock = 0
        rr = 0
        with self.mesh:
            caches = (
                self._pools if self._pools is not None else self._zero_pools()
            )
            while len(q) or any(r is not None for r in active):
                # admission: a free slot AND a page reservation — the page
                # budget, not the slot count, is the capacity limit; a
                # higher-priority request that cannot reserve may evict the
                # youngest lowest-priority active request instead of waiting
                for slot in range(B):
                    if active[slot] is not None:
                        continue
                    r = q.peek(clock)
                    if r is None:
                        break  # nothing in the queue has arrived yet
                    pr = self._eff_prompt(r)  # prompt + resumed tokens
                    L = len(pr) + (r.max_new - len(r.generated)) - 1
                    own = f"req{r.uid}"
                    rank = _PRIORITY_RANK[r.priority]
                    m, spages = self._match_prefix(pr)
                    if m:
                        for p in spages:
                            pool.retain(p, owner=own)
                        sc = self._paged_schedule(
                            L, step_span=C, start_tile=m // self.page
                        )
                        need = lambda: (
                            self._committed(active, sched, pos)
                            + sc.remaining_peak(m)
                        )
                        gap = self._fits(need())
                        if gap > 0 and self.preemptible:
                            gap = self._preempt_until(
                                need, rank, q, fetch, pool, pt, active,
                                sched, parr, pos, admit_pos, admit_seq,
                            )
                        if gap > 0:
                            for p in spages:
                                pool.release(p, owner=own)
                            cold_peak = self._paged_schedule(
                                L, step_span=C
                            ).remaining_peak(0)
                            if cold_peak < sc.remaining_peak(m):
                                # cold genuinely cheaper (retention frees
                                # tiles the alias would pin): retry cold
                                m, spages = 0, []
                            else:
                                # cold could not fit either — and its _fits
                                # would evict the very prefix (a preemption
                                # victim's donated pages) that makes the
                                # eventual resume warm
                                self.stats["admission_backpressure"] += 1
                                break
                    if not m:
                        sc = (
                            self._ring_schedule(L)
                            if self.ring_tiles is not None
                            else self._paged_schedule(L, step_span=C)
                        )
                        need = lambda: (
                            self._committed(active, sched, pos)
                            + sc.remaining_peak(0)
                        )
                        gap = self._fits(need())
                        if gap > 0 and self.preemptible:
                            gap = self._preempt_until(
                                need, rank, q, fetch, pool, pt, active,
                                sched, parr, pos, admit_pos, admit_seq,
                            )
                        if gap > 0:
                            self.stats["admission_backpressure"] += 1
                            break
                    if self.cross_pages is not None:
                        nc = self._cross_admit(r, slot, ct, caches)
                        if nc is None:
                            self.stats["admission_backpressure"] += 1
                            break
                        caches = nc
                    q.pop(r, clock)
                    if r.preemptions:  # a victim re-admitting (possibly
                        self.stats["resumes"] += 1  # mid-prefill, no tokens)
                        if m:
                            self.stats["resume_warm_hits"] += 1
                    if m:
                        for i, p in enumerate(spages):
                            pt[slot, i] = p
                        self.stats["prefix_hits"] += 1
                        self.stats["prefix_hit_tokens"] += m
                    elif self.ring_tiles is not None:
                        # the fixed mod-window page set, allocated up front —
                        # chunk streaming reuses the slots in phase
                        for t in range(min(self.ring_tiles, -(-L // self.page))):
                            pt[slot, t] = pool.alloc(own)
                    active[slot] = r
                    sched[slot] = sc
                    parr[slot] = pr
                    pos[slot] = m
                    consumed[slot] = m
                    admit_pos[slot] = m
                    admit_seq[slot] = aseq
                    aseq += 1
                    remaining[slot] = r.max_new - len(r.generated)
                self.stats["max_concurrent"] = max(
                    self.stats["max_concurrent"],
                    sum(a is not None for a in active),
                )
                if not any(r is not None for r in active):
                    clock += 1
                    continue
                eligible = [
                    s for s in range(B)
                    if active[s] is not None
                    and len(parr[s]) - consumed[s] <= 0
                ]
                use_nxt = np.zeros(B, bool)
                chunk_t = np.zeros(B, np.int32)
                budget = self.chunk_budget
                # interactive rows split the chunk budget ahead of batch
                # rows; the rotation keeps it fair within a class (and IS
                # the whole order under uniform priority / fifo scheduling)
                order = sorted(
                    range(B),
                    key=lambda s: (
                        0 if self.fifo or active[s] is None
                        else _PRIORITY_RANK[active[s].priority],
                        (s - rr) % B,
                    ),
                )
                for slot in order:
                    r = active[slot]
                    if r is None:
                        continue
                    rem_prompt = len(parr[slot]) - consumed[slot]
                    if rem_prompt > 0:
                        t = min(C, rem_prompt, budget)
                        if t <= 0:
                            continue
                        chunk_t[slot] = t
                        budget -= t
                    else:
                        use_nxt[slot] = True
                rr = (rr + 1) % B
                clock += 1
                self.stats["mixed_steps"] += 1
                dec_rows = [s for s in range(B) if use_nxt[s]]
                chunk_rows = [s for s in range(B) if chunk_t[s] > 0]
                if any(s not in dec_rows for s in eligible):
                    self.stats["decode_stall_steps"] += 1
                if dec_rows and chunk_rows:
                    self.stats["overlap_steps"] += 1
                # (a) paged decode wave: every decoding row advances through
                # the decode grid; non-decoding rows run with a sentinel
                # page-table row so their garbage write DROPS — a mid-prompt
                # row's frontier tile may alias a shared prefix page, which
                # an unmasked write would corrupt for every sibling
                if dec_rows:
                    for slot in dec_rows:
                        caches = self._ensure_writable(
                            pool, pt, slot, int(pos[slot]),
                            int(pos[slot]) + 1, caches,
                            f"req{active[slot].uid}",
                        )
                    if self.ring_tiles is not None:
                        kv_live = None  # ring positions are unbounded
                    else:
                        hot = max(int(pos[s]) + 1 for s in dec_rows)
                        kv_live = _next_bucket(hot, self.cache_len)
                        self.stats["decode_kv_live_max"] = max(
                            self.stats.get("decode_kv_live_max", 0), kv_live
                        )
                    use = np.asarray(use_nxt)
                    pt_wave = np.where(
                        use[:, None], pt, np.int32(self.pool_pages)
                    ).astype(np.int32)
                    logits, caches = self.p_decode_fn(
                        self.params, caches, nxt[:, None], jnp.asarray(pos),
                        jnp.asarray(pt_wave), kv_live,
                        **({} if ct is None else {"ct": jnp.asarray(ct)}),
                    )
                    toks = jnp.argmax(logits, -1).astype(jnp.int32)
                    self.stats["decode_steps"] += 1
                    self.stats["decode_tokens"] += len(dec_rows)
                    sinks = []
                    for slot in dec_rows:
                        r = active[slot]
                        sinks.append((r, slot))
                        pos[slot] += 1
                        remaining[slot] -= 1
                        if remaining[slot] <= 0:
                            self._free_all(pool, pt, slot, f"req{r.uid}")
                            if ct is not None:
                                self._release_cross(ct, slot, f"req{r.uid}")
                            active[slot] = None
                            sched[slot] = None
                            parr[slot] = None
                        else:
                            self._free_dead(
                                pool, pt, slot, sched[slot], int(pos[slot]),
                                f"req{r.uid}",
                            )
                    self._stamp_emits(sinks, clock)
                    fetch.push(toks, sinks)
                    nxt = jnp.where(jnp.asarray(use_nxt), toks, nxt)
                # (b) prompt chunks through the paged chunk grid: allocate
                # the chunk's tiles, stream it into the pool, then free
                # whatever the pattern says is already dead
                for slot in chunk_rows:
                    r = active[slot]
                    t = int(chunk_t[slot])
                    caches = self._ensure_writable(
                        pool, pt, slot, int(pos[slot]), int(pos[slot]) + t,
                        caches, f"req{r.uid}",
                    )
                    ctoks = np.zeros((1, C), np.int32)
                    ctoks[0, :t] = parr[slot][
                        consumed[slot] : consumed[slot] + t
                    ]
                    kv_live = _next_bucket(int(pos[slot]) + t, self.cache_len)
                    logits1, caches = self.p_chunk_fn(
                        self.params, caches, jnp.asarray(ctoks),
                        jnp.asarray(pt[slot : slot + 1]),
                        jnp.int32(pos[slot]), jnp.int32(t), kv_live,
                        ct=None if ct is None else jnp.asarray(
                            ct[slot : slot + 1]
                        ),
                    )
                    self.stats["chunk_calls"] += 1
                    self.stats["prefill_tokens"] += t
                    self.stats["prefill_flops"] += self._prefill_flop_count(
                        int(pos[slot]), t
                    )
                    pos[slot] += t
                    consumed[slot] += t
                    if consumed[slot] == len(parr[slot]):
                        self._cache_pages(parr[slot], pt, slot)
                        tok1 = jnp.argmax(logits1).astype(jnp.int32)
                        self._stamp_emits([(r, 0)], clock)
                        fetch.push(tok1, [(r, 0)])
                        nxt = nxt.at[slot].set(tok1)
                        remaining[slot] -= 1
                        if remaining[slot] <= 0:
                            self._free_all(pool, pt, slot, f"req{r.uid}")
                            if ct is not None:
                                self._release_cross(ct, slot, f"req{r.uid}")
                            active[slot] = None
                            sched[slot] = None
                            parr[slot] = None
                            continue
                    self._free_dead(pool, pt, slot, sched[slot],
                                    int(pos[slot]), f"req{r.uid}")
        fetch.flush()
        self._pools = caches
        self._finish_paged_run(pool)
        self._finalize_slo(requests, q)
        return requests
