"""Batched serving runtime: jit'd prefill + decode with sharded KV caches.

`make_serve_fns` builds the two compiled entry points the dry-run exercises
(`prefill_32k` lowers prefill; `decode_32k` / `long_500k` lower decode_step);
`ServeLoop` is a minimal continuous-batching driver used by the example.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.distributed import sharding as shd
from repro.models import model as M
from repro.models import transformer as tf
from repro.models.config import ModelConfig

__all__ = ["make_serve_fns", "cache_shardings", "abstract_cache", "ServeLoop"]


def cache_shardings(cfg: ModelConfig, mesh: Mesh, batch: int, cache_len: int):
    return shd.sharding_tree(tf.cache_specs(cfg, batch, cache_len), mesh, M.rules_for(cfg))


def abstract_cache(cfg: ModelConfig, batch: int, cache_len: int):
    specs = tf.cache_specs(cfg, batch, cache_len)
    dt = jnp.dtype(cfg.dtype)
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, dt),
        specs,
        is_leaf=lambda x: isinstance(x, shd.ParamSpec),
    )


def make_serve_fns(
    cfg: ModelConfig,
    mesh: Mesh,
    *,
    batch: int,
    cache_len: int,
    attn_impl: str | None = None,
):
    """Returns (prefill_fn(params, batch_dict) -> (logits, caches),
    decode_fn(params, caches, tokens, pos) -> (logits, caches)).

    ``attn_impl`` overrides the config's attention execution form for this
    serving instance (e.g. "flash_kernel" on a single-chip deployment)."""
    if attn_impl is not None:
        spec = dataclasses.replace(cfg.attention, impl=attn_impl)
        cfg = dataclasses.replace(cfg, attention=spec)
    rt = M.resolve_runtime(cfg, mesh)
    pspecs = M.build_specs(cfg)
    p_shard = shd.sharding_tree(pspecs, mesh, M.rules_for(cfg))
    c_shard = cache_shardings(cfg, mesh, batch, cache_len)
    tok_shard = NamedSharding(mesh, P(tuple(a for a in ("pod", "data") if a in mesh.axis_names)))
    rep = NamedSharding(mesh, P())

    prefill = jax.jit(
        lambda params, b: tf.prefill(params, cfg, b, rt, cache_len=cache_len),
        in_shardings=(p_shard, None),
        out_shardings=(tok_shard, c_shard),
        static_argnums=(),
    )
    decode = jax.jit(
        lambda params, caches, tokens, pos: tf.decode_step(
            params, cfg, caches, tokens, pos, rt
        ),
        in_shardings=(p_shard, c_shard, tok_shard, rep),
        out_shardings=(tok_shard, c_shard),
        donate_argnums=(1,),
    )
    return prefill, decode


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray  # (S,) int32
    max_new: int
    generated: list[int] = dataclasses.field(default_factory=list)


class ServeLoop:
    """Minimal batched decode loop (static batch, greedy sampling).

    Requests are padded into one batch, prefilled once, then decoded
    step-by-step; finished requests exit with their generations.
    """

    def __init__(
        self, cfg: ModelConfig, mesh: Mesh, params, *,
        batch: int, cache_len: int, attn_impl: str | None = None,
    ):
        if attn_impl is not None:
            cfg = dataclasses.replace(
                cfg, attention=dataclasses.replace(cfg.attention, impl=attn_impl)
            )
        self.cfg, self.mesh, self.params = cfg, mesh, params
        self.batch, self.cache_len = batch, cache_len
        self.prefill_fn, self.decode_fn = make_serve_fns(
            cfg, mesh, batch=batch, cache_len=cache_len
        )

    def run(self, requests: list[Request]) -> list[Request]:
        assert len(requests) <= self.batch
        plen = max(len(r.prompt) for r in requests)
        toks = np.zeros((self.batch, plen), np.int32)
        for i, r in enumerate(requests):
            toks[i, plen - len(r.prompt) :] = r.prompt  # left-pad
        with self.mesh:
            logits, caches = self.prefill_fn(self.params, {"tokens": jnp.asarray(toks)})
            nxt = jnp.argmax(logits, -1).astype(jnp.int32)
            max_new = max(r.max_new for r in requests)
            for j in range(max_new):
                for i, r in enumerate(requests):
                    if j < r.max_new:
                        r.generated.append(int(nxt[i]))
                if j == max_new - 1:
                    break
                logits, caches = self.decode_fn(
                    self.params, caches, nxt[:, None], jnp.int32(plen + j)
                )
                nxt = jnp.argmax(logits, -1).astype(jnp.int32)
        return requests
