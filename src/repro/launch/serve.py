"""Ragged continuous-batching serve runtime: jit'd prefill + decode with
sharded KV caches.

`make_serve_fns` builds the two compiled entry points the dry-run exercises
(`prefill_32k` lowers prefill; `decode_32k` / `long_500k` lower decode_step);
with ``ragged=True`` the prefill takes per-request prompt lengths and the
decode takes a (B,) position vector instead of a batch-wide scalar.

`ServeLoop` is the continuous-batching engine: requests stream through a
fixed set of batch *slots* — each admission runs a bucketed batch-1 prefill
(right-padded, masked by true length) and inserts the resulting caches into
the shared KV cache at the slot index; every decode step advances all live
slots with per-request positions and live-KV masks, so short requests retire
and hand their slot to the queue without stalling on the longest request
(the request-level analogue of the paper's §V-A {Load | Cal | Store}
streaming: admission/eviction keeps the decode array saturated).
"""

from __future__ import annotations

import dataclasses
import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.attention import override_attention
from repro.distributed import sharding as shd
from repro.models import model as M
from repro.models import transformer as tf
from repro.models.config import ModelConfig

__all__ = ["make_serve_fns", "cache_shardings", "abstract_cache", "Request", "ServeLoop"]


def cache_shardings(cfg: ModelConfig, mesh: Mesh, batch: int, cache_len: int):
    return shd.sharding_tree(tf.cache_specs(cfg, batch, cache_len), mesh, M.rules_for(cfg))


def abstract_cache(cfg: ModelConfig, batch: int, cache_len: int):
    specs = tf.cache_specs(cfg, batch, cache_len)
    dt = jnp.dtype(cfg.dtype)
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, dt),
        specs,
        is_leaf=lambda x: isinstance(x, shd.ParamSpec),
    )


def make_serve_fns(
    cfg: ModelConfig,
    mesh: Mesh,
    *,
    batch: int,
    cache_len: int,
    attn_impl: str | None = None,
    attn_pattern: str | None = None,
    ragged: bool = False,
):
    """Returns (prefill_fn, decode_fn).

    ``ragged=False`` (static batch): prefill_fn(params, batch_dict) and
    decode_fn(params, caches, tokens, pos-scalar).  ``ragged=True``:
    prefill_fn(params, batch_dict, lengths (B,)) gathers each row's last real
    token and decode_fn takes pos as a (B,) per-request position vector.

    ``attn_impl`` / ``attn_pattern`` override the config's attention
    execution form / block-sparsity pattern for this serving instance (e.g.
    "flash_kernel" + "butterfly" on a single-chip deployment).

    ``decode_fn`` takes an optional trailing ``kv_live`` (static int): a
    host-known bound on every row's live cache length.  Attention then
    streams only the first ``kv_live`` cache rows — each distinct value
    compiles once, so callers should bucket it (the engine uses powers of
    two)."""
    cfg = override_attention(cfg, impl=attn_impl, pattern=attn_pattern)
    rt = M.resolve_runtime(cfg, mesh)
    pspecs = M.build_specs(cfg)
    p_shard = shd.sharding_tree(pspecs, mesh, M.rules_for(cfg))
    c_shard = cache_shardings(cfg, mesh, batch, cache_len)
    tok_shard = NamedSharding(mesh, P(tuple(a for a in ("pod", "data") if a in mesh.axis_names)))
    rep = NamedSharding(mesh, P())

    if ragged:
        prefill = jax.jit(
            lambda params, b, lengths: tf.prefill(
                params, cfg, b, rt, cache_len=cache_len, lengths=lengths
            ),
            in_shardings=(p_shard, None, rep),
            out_shardings=(tok_shard, c_shard),
        )
        pos_shard = rep  # (B,) per-request positions, replicated
    else:
        prefill = jax.jit(
            lambda params, b: tf.prefill(params, cfg, b, rt, cache_len=cache_len),
            in_shardings=(p_shard, None),
            out_shardings=(tok_shard, c_shard),
        )
        pos_shard = rep
    jitted: dict[int | None, object] = {}

    def decode(params, caches, tokens, pos, kv_live: int | None = None):
        fn = jitted.get(kv_live)
        if fn is None:
            fn = jax.jit(
                lambda params, caches, tokens, pos: tf.decode_step(
                    params, cfg, caches, tokens, pos, rt, kv_live=kv_live
                ),
                in_shardings=(p_shard, c_shard, tok_shard, pos_shard),
                out_shardings=(tok_shard, c_shard),
                donate_argnums=(1,),
            )
            jitted[kv_live] = fn
        return fn(params, caches, tokens, pos)

    return prefill, decode


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray  # (S,) int32
    max_new: int
    generated: list[int] = dataclasses.field(default_factory=list)
    extras: dict = dataclasses.field(default_factory=dict)  # e.g. encdec frames


def _next_bucket(n: int, cap: int, floor: int = 8) -> int:
    """Smallest power-of-two >= n (>= floor), capped at ``cap`` but never
    below n — bounds the number of compiled prefill shapes."""
    b = floor
    while b < n:
        b *= 2
    return max(n, min(b, cap))


class ServeLoop:
    """Continuous-batching decode loop (slot admit/evict, greedy sampling).

    Per-slot host state mirrors the device-side (B,)-vector threading:
    ``pos[b]`` is request b's next write position (== tokens seen so far),
    fed to ``decode_step`` so RoPE angles, cache writes and live-KV masks are
    all per-request.  Prompts are *right*-padded into prefill buckets — real
    tokens at positions 0..L-1, so positions and causal masks are exact and
    pad keys are never attended (masked by the decode ``cur_len`` and
    overwritten in place by the first decode steps).

    ``static_batching=True`` degrades admission to wave scheduling (admit
    only when every slot is free) — the old-ServeLoop baseline the
    serve_throughput benchmark compares against; the decode path itself stays
    ragged-correct.
    """

    def __init__(
        self, cfg: ModelConfig, mesh: Mesh, params, *,
        batch: int, cache_len: int, attn_impl: str | None = None,
        attn_pattern: str | None = None, static_batching: bool = False,
    ):
        cfg = override_attention(cfg, impl=attn_impl, pattern=attn_pattern)
        if cfg.sliding_window and cache_len < cfg.sliding_window:
            raise ValueError(
                f"cache_len {cache_len} < sliding_window {cfg.sliding_window}: "
                "the ring modulus must equal the window for prefill/decode "
                "phase alignment"
            )
        stateful = [s.mixer for s in cfg.period_slots if s.mixer != "attn"]
        if stateful:
            raise ValueError(
                f"{cfg.name}: ragged serving requires attention-only stacks — "
                f"{stateful} mixers integrate right-pad tokens into their "
                "state during bucketed prefill (no per-row mask can undo it)"
            )
        self.cfg, self.mesh, self.params = cfg, mesh, params
        self.batch, self.cache_len = batch, cache_len
        self.static_batching = static_batching
        # batch-1 ragged prefill (jit retraces per bucket shape; caches insert
        # at a traced slot index so one compile covers every slot) + batch-wide
        # ragged decode, both through the sharded serve entry points
        self.prefill_fn, _ = make_serve_fns(
            cfg, mesh, batch=1, cache_len=cache_len, ragged=True
        )
        _, self.decode_fn = make_serve_fns(
            cfg, mesh, batch=batch, cache_len=cache_len, ragged=True
        )
        self._insert = jax.jit(
            lambda caches, wave, slot: jax.tree.map(
                lambda c, w: jax.lax.dynamic_update_slice_in_dim(
                    c, w.astype(c.dtype), slot, axis=1
                ),
                caches,
                wave,
            ),
            donate_argnums=(0,),
        )
        self.stats: dict[str, int] = {}

    # -- per-slot prefill -------------------------------------------------

    def _prefill_one(self, r: Request):
        """Prefill one request (batch=1, right-padded to a bucket); returns
        (first generated token, batch-1 cache tree)."""
        ln = len(r.prompt)
        bucket = _next_bucket(ln, self.cache_len)
        toks = np.zeros((1, bucket), np.int32)
        toks[0, :ln] = r.prompt
        b = {"tokens": jnp.asarray(toks)}
        for key, val in r.extras.items():
            b[key] = jnp.asarray(val)[None]
        logits, wave = self.prefill_fn(self.params, b, jnp.asarray([ln], jnp.int32))
        self.stats["prefill_calls"] = self.stats.get("prefill_calls", 0) + 1
        return int(jnp.argmax(logits[0])), wave

    def _zero_caches(self):
        specs = tf.cache_specs(self.cfg, self.batch, self.cache_len)
        dt = jnp.dtype(self.cfg.dtype)
        return jax.tree.map(
            lambda s: jnp.zeros(s.shape, dt),
            specs,
            is_leaf=lambda x: isinstance(x, shd.ParamSpec),
        )

    # -- engine loop ------------------------------------------------------

    def run(self, requests: list[Request]) -> list[Request]:
        """Serve every request to completion; returns them in input order.

        Admission fills free slots from the queue (per-slot prefill + cache
        insert), then one ragged decode step advances all live slots;
        finished requests retire immediately and free their slot for the
        next admission — decode never stalls on the longest request.
        """
        for r in requests:
            if len(r.prompt) < 1:
                raise ValueError(f"request {r.uid}: prompt must be non-empty")
            if len(r.prompt) > self.cache_len:
                raise ValueError(
                    f"request {r.uid}: prompt {len(r.prompt)} > cache_len {self.cache_len}"
                )
            if r.max_new < 1:
                raise ValueError(f"request {r.uid}: max_new must be >= 1")
            # without a ring, decode writes positions L .. L+max_new-2 straight
            # into the cache — past cache_len they would silently clamp
            need = len(r.prompt) + r.max_new - 1
            if not self.cfg.sliding_window and need > self.cache_len:
                raise ValueError(
                    f"request {r.uid}: prompt+max_new needs {need} cache rows "
                    f"> cache_len {self.cache_len}"
                )
            r.generated.clear()
        queue = list(requests)
        qi = 0
        active: list[Request | None] = [None] * self.batch
        pos = np.zeros(self.batch, np.int32)  # next write position per slot
        nxt = np.zeros(self.batch, np.int32)  # last sampled token per slot
        self.stats = {"prefill_calls": 0, "decode_steps": 0}
        with self.mesh:
            caches = self._zero_caches()
            while qi < len(queue) or any(r is not None for r in active):
                # admit: fill free slots (waves only, under static batching)
                may_admit = not self.static_batching or all(
                    r is None for r in active
                )
                if may_admit:
                    for slot in range(self.batch):
                        if qi >= len(queue):
                            break
                        if active[slot] is not None:
                            continue
                        r = queue[qi]
                        qi += 1
                        tok, wave = self._prefill_one(r)
                        r.generated.append(tok)
                        if r.max_new <= 1:
                            continue  # done at prefill; slot stays free
                        caches = self._insert(caches, wave, jnp.int32(slot))
                        active[slot] = r
                        pos[slot] = len(r.prompt)
                        nxt[slot] = tok
                if not any(r is not None for r in active):
                    continue
                # one ragged decode step for the whole batch; attention
                # streams only the live cache prefix (bucketed so each bucket
                # compiles once) — a short wave on a deep cache reads its own
                # tiles, not the padded cache.  Ring caches keep their own
                # mod-window layout and stream the whole (window-sized) ring.
                kv_live = None
                if not self.cfg.sliding_window:
                    hot = max(int(pos[s]) for s in range(self.batch)
                              if active[s] is not None) + 1
                    kv_live = min(_next_bucket(hot, self.cache_len), self.cache_len)
                    self.stats["decode_kv_live_max"] = max(
                        self.stats.get("decode_kv_live_max", 0), kv_live
                    )
                logits, caches = self.decode_fn(
                    self.params, caches, jnp.asarray(nxt[:, None]),
                    jnp.asarray(pos), kv_live,
                )
                self.stats["decode_steps"] += 1
                toks = np.asarray(jnp.argmax(logits, -1).astype(jnp.int32))
                for slot in range(self.batch):
                    r = active[slot]
                    if r is None:
                        continue
                    r.generated.append(int(toks[slot]))
                    pos[slot] += 1
                    nxt[slot] = toks[slot]
                    if len(r.generated) >= r.max_new:
                        active[slot] = None  # evict: slot frees for the queue
        return requests
