"""End-to-end trainer: jit'd train step (FSDP/TP/CP/EP sharded, donated,
remat'd, microbatched, optionally wire-compressed across pods) + a
fault-tolerant driver loop (auto-resume, async checkpoints, straggler
detection, restart supervision).

CLI::

    PYTHONPATH=src python -m repro.launch.train --arch fabnet-base \
        --steps 200 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt
"""

from __future__ import annotations

import argparse
import dataclasses
import logging
import time
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.checkpoint.manager import CheckpointManager
from repro.data.pipeline import DataConfig, global_batch
from repro.distributed.fault_tolerance import RestartPolicy, StragglerDetector, run_with_restarts
from repro.distributed import sharding as shd
from repro.models import model as M
from repro.models import transformer as tf
from repro.models.config import ModelConfig
from repro.optim import AdamWConfig, adamw_init, adamw_update, cosine_schedule
from repro.optim.compression import ef_compress_tree, dequantize_int8, psum_compressed

log = logging.getLogger("repro.train")

__all__ = ["TrainHParams", "make_train_state_specs", "make_train_step", "train_loop"]


@dataclasses.dataclass(frozen=True)
class TrainHParams:
    peak_lr: float = 3e-4
    warmup: int = 100
    total_steps: int = 10_000
    adamw: AdamWConfig = AdamWConfig()
    # gradient compression across the pod axis: off | simulate | wire
    compression: str = "off"


def _batch_sharding(mesh: Mesh):
    axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    return NamedSharding(mesh, P(axes))


def make_train_state_specs(cfg: ModelConfig, hp: TrainHParams):
    """ParamSpec tree for the full train state (params + moments + step)."""
    pspecs = M.build_specs(cfg)
    state = {
        "params": pspecs,
        "opt": {"mu": pspecs, "nu": pspecs, "count": shd.ParamSpec((), (), init="zeros")},
        "step": shd.ParamSpec((), (), init="zeros"),
    }
    if hp.compression != "off":
        state["err"] = pspecs
    return state


def init_train_state(cfg: ModelConfig, hp: TrainHParams, key: jax.Array):
    params = M.init_params(cfg, key)
    state: dict[str, Any] = {
        "params": params,
        "opt": adamw_init(params, hp.adamw),
        "step": jnp.zeros((), jnp.int32),
    }
    if hp.compression != "off":
        state["err"] = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return state


def abstract_train_state(cfg: ModelConfig, hp: TrainHParams):
    pdt = jnp.dtype(cfg.param_dtype)
    mdt = jnp.dtype(hp.adamw.moment_dtype)
    pspecs = M.build_specs(cfg)
    ab = lambda dt: shd.abstract_tree(pspecs, dt)
    state = {
        "params": ab(pdt),
        "opt": {
            "mu": ab(mdt),
            "nu": ab(mdt),
            "count": jax.ShapeDtypeStruct((), jnp.int32),
        },
        "step": jax.ShapeDtypeStruct((), jnp.int32),
    }
    if hp.compression != "off":
        state["err"] = ab(jnp.float32)
    return state


def train_state_shardings(cfg: ModelConfig, hp: TrainHParams, mesh: Mesh):
    pspecs = M.build_specs(cfg)
    ps = shd.sharding_tree(pspecs, mesh, M.rules_for(cfg))
    scalar = NamedSharding(mesh, P())
    state = {
        "params": ps,
        "opt": {"mu": ps, "nu": ps, "count": scalar},
        "step": scalar,
    }
    if hp.compression != "off":
        state["err"] = ps
    return state


def _grads_fn(cfg: ModelConfig, rt, params, batch, accum: int, pshard=None):
    """Mean loss gradient, microbatched when accum > 1 (scan keeps HLO small
    and caps activation memory at one microbatch)."""

    def loss(p, mb):
        if cfg.cast_params_once and pshard is not None:
            # sharded-local downcast pinned by a sharding constraint, so the
            # FSDP all-gathers downstream move bf16 instead of f32 masters
            cdt = jnp.dtype(cfg.dtype)
            p = jax.tree.map(
                lambda x, s: (
                    jax.lax.with_sharding_constraint(x.astype(cdt), s)
                    if x.dtype == jnp.float32 and x.ndim >= 2
                    else x
                ),
                p,
                pshard,
            )
        l, metrics = tf.loss_fn(p, cfg, mb, rt)
        return l, metrics

    def _pin(grads):
        # pin gradient shardings to the (FSDP-sharded) param shardings so the
        # partitioner can reduce-scatter dW instead of all-reducing it at
        # full size (ZeRO-2 semantics)
        if cfg.cast_params_once and pshard is not None:
            return jax.tree.map(jax.lax.with_sharding_constraint, grads, pshard)
        return grads

    if accum == 1:
        (l, metrics), grads = jax.value_and_grad(loss, has_aux=True)(params, batch)
        return _pin(grads), l, metrics

    def micro(carry, mb):
        g_acc, l_acc = carry
        (l, metrics), g = jax.value_and_grad(loss, has_aux=True)(params, mb)
        g_acc = jax.tree.map(lambda a, b: a + b, g_acc, _pin(g))
        return (g_acc, l_acc + l), metrics

    mbs = jax.tree.map(
        lambda x: x.reshape(accum, x.shape[0] // accum, *x.shape[1:]), batch
    )
    zero_g = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    (g_sum, l_sum), metrics = jax.lax.scan(micro, (zero_g, 0.0), mbs)
    grads = jax.tree.map(lambda g: g / accum, g_sum)
    metrics = jax.tree.map(lambda m: m[-1], metrics)
    return grads, l_sum / accum, metrics


def make_train_step(
    cfg: ModelConfig, mesh: Mesh, hp: TrainHParams, batch_example=None
):
    """Returns (jitted step_fn(state, batch) -> (state, metrics),
    state_shardings, batch_shardings).  `batch_example` (a tree of arrays or
    ShapeDtypeStructs) fixes the batch structure for archs with modality
    inputs (frames / img_embeds); defaults to {tokens, labels}."""
    rt = M.resolve_runtime(cfg, mesh)
    accum = max(cfg.grad_accum, 1)
    st_shardings = train_state_shardings(cfg, hp, mesh)

    def step_fn(state, batch):
        lr = cosine_schedule(
            state["step"], peak_lr=hp.peak_lr, warmup=hp.warmup, total=hp.total_steps
        )

        pshard = st_shardings["params"]
        if hp.compression == "wire" and "pod" in mesh.axis_names:
            # per-pod grads + int8 error-feedback all-reduce across pods
            def pod_grads(params, err, batch):
                g, l, metrics = _grads_fn(cfg, rt, params, batch, accum)
                g_sync, new_err = psum_compressed(g, err, "pod")
                return g_sync, new_err, l, metrics

            grads, new_err, l, metrics = shd.shard_map(
                pod_grads,
                mesh=mesh,
                in_specs=(P(), P(), P("pod")),
                out_specs=(P(), P(), P(), P()),
                axis_names={"pod"},
            )(state["params"], state["err"], batch)
            l = jnp.mean(l)
            metrics = jax.tree.map(jnp.mean, metrics)
        else:
            grads, l, metrics = _grads_fn(
                cfg, rt, state["params"], batch, accum, pshard=pshard
            )
            new_err = None
            if hp.compression == "simulate":
                # numerically-faithful EF int8 (wire bytes unchanged in HLO)
                q, s, new_err = ef_compress_tree(grads, state["err"])
                grads = jax.tree.map(dequantize_int8, q, s)

        new_params, new_opt, stats = adamw_update(
            grads, state["opt"], state["params"], lr, hp.adamw
        )
        new_state = {
            "params": new_params,
            "opt": new_opt,
            "step": state["step"] + 1,
        }
        if new_err is not None:
            new_state["err"] = new_err
        metrics = dict(metrics)
        metrics.update(stats)
        metrics["lr"] = lr
        metrics["loss_total"] = l
        return new_state, metrics

    if batch_example is None:
        b_shard = _batch_sharding(mesh)
        batch_shardings = {"tokens": b_shard, "labels": b_shard}
    else:
        batch_shardings = shd.data_shardings(batch_example, mesh)
    step = jax.jit(
        step_fn,
        in_shardings=(st_shardings, batch_shardings),
        out_shardings=(st_shardings, None),
        donate_argnums=(0,),
    )
    return step, st_shardings, batch_shardings


# --------------------------------------------------------------------------
# Fault-tolerant driver
# --------------------------------------------------------------------------


def train_loop(
    cfg: ModelConfig,
    mesh: Mesh,
    hp: TrainHParams,
    data_cfg: DataConfig,
    *,
    steps: int,
    ckpt_dir: str | None = None,
    ckpt_every: int = 50,
    seed: int = 0,
    log_every: int = 10,
):
    """Resumable training: restores the latest committed checkpoint if one
    exists, otherwise initialises; saves asynchronously; flags stragglers."""
    step_fn, st_shardings, _ = make_train_step(cfg, mesh, hp)
    mgr = CheckpointManager(ckpt_dir) if ckpt_dir else None
    detector = StragglerDetector()

    start = 0
    state = None
    if mgr is not None:
        abstract = abstract_train_state(cfg, hp)
        got_step, got = mgr.restore_latest(abstract, st_shardings)
        if got is not None:
            start, state = got_step, got
            log.info("resumed from step %d", start)
    if state is None:
        with mesh:
            state = init_train_state(cfg, hp, jax.random.PRNGKey(seed))
            state = jax.tree.map(jax.device_put, state, st_shardings)

    history = []
    for step in range(start, steps):
        batch = global_batch(data_cfg, step, mesh)
        t0 = time.monotonic()
        state, metrics = step_fn(state, batch)
        metrics = jax.device_get(metrics)
        dt = time.monotonic() - t0
        if detector.record(dt):
            log.warning("straggler pattern at step %d (%.2fs vs median %.2fs)",
                        step, dt, detector.median())
        history.append(float(metrics["loss"]))
        if log_every and step % log_every == 0:
            log.info("step %d loss %.4f gnorm %.3f lr %.2e (%.2fs)",
                     step, metrics["loss"], metrics["grad_norm"], metrics["lr"], dt)
        if mgr is not None and (step + 1) % ckpt_every == 0:
            mgr.save(step + 1, state)
    if mgr is not None:
        mgr.save(steps, state, blocking=True)
    return state, history


def main():
    from repro.configs import registry

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--compression", default="off")
    ap.add_argument("--reduced", action="store_true", help="smoke-size config")
    args = ap.parse_args()

    logging.basicConfig(level=logging.INFO, format="%(asctime)s %(name)s %(message)s")
    cfg = registry.get(args.arch, reduced=args.reduced)
    from repro.launch.mesh import make_local_mesh

    mesh = make_local_mesh()
    hp = TrainHParams(peak_lr=args.lr, total_steps=args.steps, compression=args.compression)
    data_cfg = DataConfig(vocab=cfg.vocab, seq_len=args.seq, global_batch=args.batch)
    _, hist = train_loop(cfg, mesh, hp, data_cfg, steps=args.steps, ckpt_dir=args.ckpt_dir)
    print(f"final loss: {hist[-1]:.4f} (from {hist[0]:.4f})")


if __name__ == "__main__":
    main()
