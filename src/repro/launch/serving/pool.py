"""Host-side page accounting: the sharded refcounted page-pool allocator and
the radix-tree prefix cache that shares its pages.

Device state lives elsewhere (the pools are plain jax arrays, sharded over
the ``pages`` mesh axis by :func:`repro.models.transformer.paged_pool_specs`);
this module is the single source of truth for WHO holds WHICH physical page
and on WHICH shard.
"""

from __future__ import annotations

import collections

import numpy as np

__all__ = ["PagePool", "RadixCache"]


class PagePool:
    """Host-side refcounted free-list allocator over the global KV page pool.

    Pages are unit-granular (one kv tile each), so there is no external
    fragmentation by construction: ``alloc`` succeeds whenever ``in_use <
    n_pages`` — the fragmentation bound the tests pin down.  The engine
    layers a *reservation* discipline on top (each active request commits its
    worst-case future residency, :func:`repro.core.sparsity.
    page_peak_resident`), which makes ``alloc`` infallible at every reachable
    state and turns pool exhaustion into admission backpressure instead of a
    mid-stream deadlock.

    Prefix sharing adds reference counting: a physical page can back the
    same virtual tile of many requests plus the radix cache.  Every sharer
    holds one reference (``retain``); ``release`` drops one, and the page
    returns to the free list only when the LAST reference across all sharers
    is gone — dead-tile freeing from the retention schedules composes with
    sharing for free.  ``fork`` is the allocator half of copy-on-write: a
    writer that holds a page jointly trades its reference for a fresh
    private page (the engine copies the device rows).

    Every reference carries an advisory ``owner`` label (request id, the
    radix tree, the encoder cache) so a leak at :meth:`close` names WHO
    still holds the pages instead of just counting them — :meth:`holders`
    aggregates the labels of every in-use page.  Labels never influence
    refcount semantics; a mismatched release just drops the most recent
    label.  ``transfer`` relabels a reference without touching the count —
    the disaggregated engine's page-ownership handoff (prefill worker ->
    decode worker) is a page-table row move plus this refcount move.

    ``n_shards > 1`` makes the allocator MESH-SHARDED: the page id space
    splits into ``n_shards`` contiguous ranges (shard ``s`` owns
    ``[s * n_pages/n_shards, (s+1) * n_pages/n_shards)`` — exactly the
    ranges GSPMD's contiguous partition of the device pool's page axis
    assigns to each mesh shard), each range keeps its own free list, and
    ``alloc`` places every page on the fullest-free shard so no shard's
    residency exceeds ``ceil(global / n_shards)``.  ``in_use`` /
    ``peak_in_use`` and the reservation discipline stay GLOBAL — admission
    backpressure and the preemption ladder are unchanged by sharding."""

    def __init__(self, n_pages: int, n_shards: int = 1):
        if n_pages < 1:
            raise ValueError(f"pool needs >= 1 page, got {n_pages}")
        if n_shards < 1:
            raise ValueError(f"pool needs >= 1 shard, got {n_shards}")
        if n_pages % n_shards:
            raise ValueError(
                f"{n_pages} pages do not split into {n_shards} equal shards "
                "— round the pool budget up to a shard multiple"
            )
        self.n_pages = n_pages
        self.n_shards = n_shards
        self.pages_per_shard = n_pages // n_shards
        # one LIFO free list per contiguous shard range; a 1-shard pool is
        # bit-identical to the historical flat free list (pops page 0 first)
        self._free: list[list[int]] = [
            list(range((s + 1) * self.pages_per_shard - 1,
                       s * self.pages_per_shard - 1, -1))
            for s in range(n_shards)
        ]
        self._refs = [0] * n_pages
        self._owners: list[list[str]] = [[] for _ in range(n_pages)]
        self.in_use = 0
        self.peak_in_use = 0
        self.shard_in_use = [0] * n_shards
        self.shard_peak_in_use = [0] * n_shards
        self.alloc_count = 0
        self.fork_count = 0

    @property
    def free_pages(self) -> int:
        return sum(len(f) for f in self._free)

    def shard_of(self, pid: int) -> int:
        """Which shard's range (and device shard) holds physical page pid."""
        if not 0 <= pid < self.n_pages:
            raise ValueError(f"page id {pid} outside pool of {self.n_pages}")
        return pid // self.pages_per_shard

    def page_refs(self, pid: int) -> int:
        if not 0 <= pid < self.n_pages:
            raise ValueError(f"page id {pid} outside pool of {self.n_pages}")
        return self._refs[pid]

    def _drop_owner(self, pid: int, owner: str | None) -> None:
        ow = self._owners[pid]
        if owner is not None and owner in ow:
            ow.remove(owner)
        elif ow:
            ow.pop()

    def alloc(self, owner: str = "?") -> int:
        if self.in_use >= self.n_pages:
            raise RuntimeError(
                "page pool exhausted — the reservation invariant was broken "
                "(engine bug), admission should have backpressured"
            )
        # balanced placement: the fullest-free shard takes the page (ties to
        # the lowest shard id, deterministic) — this is what keeps per-shard
        # peaks within ceil(global peak / n_shards) of each other, the bound
        # the --check-shard gate asserts
        s = max(range(self.n_shards), key=lambda i: (len(self._free[i]), -i))
        pid = self._free[s].pop()
        if self._refs[pid]:
            # the free list must never hand out a page somebody still reads
            # — this is the invariant the churn property test hammers
            raise AssertionError(
                f"free list handed out page {pid} with {self._refs[pid]} "
                "live refs — refcount bookkeeping is corrupt"
            )
        self._refs[pid] = 1
        self._owners[pid] = [owner]
        self.in_use += 1
        self.alloc_count += 1
        self.peak_in_use = max(self.peak_in_use, self.in_use)
        self.shard_in_use[s] += 1
        self.shard_peak_in_use[s] = max(
            self.shard_peak_in_use[s], self.shard_in_use[s]
        )
        return pid

    def retain(self, pid: int, owner: str = "?") -> None:
        """Add a sharer's reference to an allocated page (prefix aliasing)."""
        if not 0 <= pid < self.n_pages:
            raise ValueError(f"page id {pid} outside pool of {self.n_pages}")
        if self._refs[pid] == 0:
            raise ValueError(f"retain of free page {pid} — it could be "
                             "reallocated under the new reader")
        self._refs[pid] += 1
        self._owners[pid].append(owner)

    def fork(self, pid: int, owner: str = "?") -> int:
        """Copy-on-write: move the caller's reference off shared page ``pid``
        onto a freshly allocated private page (returned).  The caller owns
        the device copy of the rows.  Forking an exclusively-held page is an
        engine bug — the write could have gone in place.

        The host pool never sees device payloads: the device-side page copy
        (:func:`repro.models.transformer.paged_copy_page`) tree-maps over
        EVERY pool leaf, so a quantized pool's K/V rows and their per-row
        scale leaves copy together — a page and its scales cannot diverge
        through a fork."""
        if not 0 <= pid < self.n_pages:
            raise ValueError(f"page id {pid} outside pool of {self.n_pages}")
        if self._refs[pid] == 0:
            raise ValueError(f"fork of free page {pid}")
        if self._refs[pid] == 1:
            raise ValueError(
                f"fork of exclusively-held page {pid} — write in place"
            )
        new = self.alloc(owner)
        self._refs[pid] -= 1  # never reaches zero here: refs were >= 2
        self._drop_owner(pid, owner)
        self.fork_count += 1
        return new

    def release(self, pid: int, owner: str | None = None) -> None:
        if not 0 <= pid < self.n_pages:
            raise ValueError(f"page id {pid} outside pool of {self.n_pages}")
        if self._refs[pid] == 0:
            # a double free would put the page on the free list twice and
            # later hand it to two requests — silent cross-request KV
            # corruption; fail loudly at the bug site instead
            raise ValueError(f"page id {pid} is not allocated (double free?)")
        self._refs[pid] -= 1
        self._drop_owner(pid, owner)
        if self._refs[pid] == 0:
            s = self.shard_of(pid)
            self._free[s].append(pid)
            self.in_use -= 1
            self.shard_in_use[s] -= 1

    def transfer(self, pid: int, old: str, new: str) -> None:
        """Relabel one reference on page ``pid`` from owner ``old`` to
        ``new`` — the refcount-move half of a page-ownership handoff (the
        other half is the page-table row move).  The count is untouched: the
        reference changes hands, it does not duplicate or drop.  Device
        payloads are keyed by the PHYSICAL page id, which a transfer never
        changes — quantized K/V rows and their scale leaves ride along
        without the pool knowing the storage dtype."""
        if not 0 <= pid < self.n_pages:
            raise ValueError(f"page id {pid} outside pool of {self.n_pages}")
        ow = self._owners[pid]
        if old not in ow:
            raise ValueError(
                f"transfer of page {pid}: {old!r} holds no reference "
                f"(holders: {ow})"
            )
        ow[ow.index(old)] = new

    def holders(self) -> dict[str, int]:
        """Reference counts per owner label over all in-use pages — the
        attribution a leak error reports."""
        c: collections.Counter[str] = collections.Counter()
        for pid in range(self.n_pages):
            if self._refs[pid]:
                c.update(self._owners[pid] or ["?"])
        return dict(c)

    def close(self, context: str = "") -> None:
        """Assert the pool drained to zero; a leak raises with the per-owner
        holder counts so the bug site is attributable without a refcount
        bisect (owner labels exist exactly for this report)."""
        if self.in_use:
            where = f" ({context})" if context else ""
            raise RuntimeError(
                f"page pool leak{where}: {self.in_use} pages still "
                f"referenced — held by {self.holders()}"
            )


class _RadixNode:
    """One edge of the prefix tree: a token run (length a multiple of the
    page size, so ownership never tears a page) plus the physical pages
    backing it.  ``children`` maps first-token -> LIST of nodes: when two
    cached sequences diverge inside a page we cannot split at the true
    divergence point, so sub-page-divergent siblings share a bucket instead
    (bounded duplication, exact matching)."""

    __slots__ = ("tokens", "pages", "children", "parent", "last_use")

    def __init__(self, tokens: np.ndarray, pages: list[int], parent):
        self.tokens = tokens
        self.pages = pages
        self.children: dict[int, list[_RadixNode]] = {}
        self.parent = parent
        self.last_use = 0


class RadixCache:
    """SGLang-style radix tree over prompt token ids, owning KV pages of the
    paged pool at tile granularity.

    Every page a node owns carries ONE tree reference in the
    :class:`PagePool`; requests that alias a cached prefix retain their own
    references, so a page outlives the tree node (eviction) and the
    requests (retirement) independently — it frees exactly when the last
    reader across all sharers lets go.  ``match`` may extend partway into a
    node's last page (the divergence frontier can sit mid-tile); the aliased
    boundary page is then shared, and the engine CoW-forks it on the first
    divergent write.  Eviction is LRU over leaves whose pages hold no
    reference but the tree's — evicting a still-read node would free
    nothing and orphan the sharers' accounting."""

    def __init__(self, pool: PagePool, page: int):
        self.pool = pool
        self.page = page
        self.root = _RadixNode(np.empty(0, np.int32), [], None)
        self.clock = 0
        self.held_pages = 0  # pages currently carrying a tree reference
        self.inserted_pages = 0
        self.evicted_pages = 0

    @staticmethod
    def _common(a: np.ndarray, b: np.ndarray) -> int:
        n = min(len(a), len(b))
        if n == 0:
            return 0
        eq = a[:n] == b[:n]
        return int(eq.argmin()) if not eq.all() else n

    def _best_child(self, node: _RadixNode, tokens: np.ndarray):
        best, bk = None, 0
        if len(tokens):
            for child in node.children.get(int(tokens[0]), []):
                k = self._common(tokens, child.tokens)
                if k > bk:
                    best, bk = child, k
        return best, bk

    def match(self, prompt: np.ndarray, cap: int) -> tuple[int, list[int]]:
        """Longest cached prefix of ``prompt[:cap]``: returns (matched token
        count m, physical pages covering positions 0..m-1).  The last page is
        only partially matched when m lands mid-tile — aliasing it anyway is
        what lets chunked prefill start exactly at the divergence frontier;
        the engine must treat it as shared (fork before writing).  Touches
        the walked path's LRU clocks."""
        prompt = np.asarray(prompt, np.int32)
        self.clock += 1
        node, m, pages = self.root, 0, []
        node.last_use = self.clock
        while m < cap:
            best, bk = self._best_child(node, prompt[m:cap])
            if best is None or bk == 0:
                break
            best.last_use = self.clock
            pages += best.pages[: -(-bk // self.page)]
            m += bk
            if bk < len(best.tokens):
                break  # diverged (or cap) inside this edge
            node = best
        return m, pages

    def insert(self, tokens: np.ndarray, pages: list[int]) -> None:
        """Cache ``pages`` (full pages backing ``tokens``; len(tokens) ==
        len(pages) * page) — the tree retains the pages not already covered
        by an existing cached prefix."""
        tokens = np.asarray(tokens, np.int32)
        if len(tokens) != len(pages) * self.page:
            raise ValueError(
                f"insert of {len(tokens)} tokens over {len(pages)} pages of "
                f"{self.page} — only whole pages are cacheable"
            )
        self.clock += 1
        node = self.root
        node.last_use = self.clock
        i = 0
        while i < len(tokens):
            best, bk = self._best_child(node, tokens[i:])
            kp = (bk // self.page) * self.page  # page-aligned match depth
            if best is not None and kp == len(best.tokens):
                best.last_use = self.clock
                node = best
                i += kp
                continue
            if best is not None and kp > 0:
                # diverges past a page boundary inside the edge: split there
                best = self._split(best, kp)
                best.last_use = self.clock
                node = best
                i += kp
                continue
            # no child, or divergence inside the first page: new sibling
            new = _RadixNode(tokens[i:].copy(), list(pages[i // self.page:]), node)
            new.last_use = self.clock
            for p in new.pages:
                self.pool.retain(p, owner="radix")
            self.held_pages += len(new.pages)
            self.inserted_pages += len(new.pages)
            node.children.setdefault(int(tokens[i]), []).append(new)
            return
        # the whole run is already cached — nothing new to own

    def _split(self, node: _RadixNode, kp: int) -> _RadixNode:
        head = _RadixNode(node.tokens[:kp], node.pages[: kp // self.page],
                          node.parent)
        head.last_use = node.last_use
        bucket = node.parent.children[int(node.tokens[0])]
        bucket[bucket.index(node)] = head
        node.tokens = node.tokens[kp:]
        node.pages = node.pages[kp // self.page:]
        node.parent = head
        head.children = {int(node.tokens[0]): [node]}
        return head

    def _walk(self):
        stack = [self.root]
        while stack:
            n = stack.pop()
            for kids in n.children.values():
                stack.extend(kids)
            yield n

    def evict(self, need: int) -> int:
        """Free >= ``need`` pool pages by dropping least-recently-used cached
        prefixes whose pages nobody else references; returns pages freed
        (possibly fewer — everything left is either shared or interior)."""
        freed = 0
        while freed < need:
            victim = None
            for n in self._walk():
                if n is self.root or n.children:
                    continue  # interior nodes keep their prefix chain intact
                if any(self.pool.page_refs(p) > 1 for p in n.pages):
                    continue  # shared with an active request: frees nothing
                if victim is None or n.last_use < victim.last_use:
                    victim = n
            if victim is None:
                break
            for p in victim.pages:
                self.pool.release(p, owner="radix")
            freed += len(victim.pages)
            self.held_pages -= len(victim.pages)
            self.evicted_pages += len(victim.pages)
            bucket = victim.parent.children[int(victim.tokens[0])]
            bucket.remove(victim)
            if not bucket:
                del victim.parent.children[int(victim.tokens[0])]
        return freed

    def clear(self) -> None:
        """Drop every tree reference (end of run): pages shared with live
        readers survive until those readers release."""
        for n in self._walk():
            for p in n.pages:
                self.pool.release(p, owner="radix")
        self.root = _RadixNode(np.empty(0, np.int32), [], None)
        self.held_pages = 0
