"""The serving package: host-side page accounting (:mod:`.pool`), request
queueing (:mod:`.queueing`), compiled entry points (:mod:`.entries`), the
single-loop engine (:mod:`.loop`), and the disaggregated prefill/decode
engine (:mod:`.disagg`).  :mod:`repro.launch.serve` re-exports the public
surface for compatibility."""

from repro.launch.serving.disagg import DecodeWorker, DisaggRouter, PrefillWorker
from repro.launch.serving.entries import (
    abstract_cache,
    cache_shardings,
    make_mixed_fn,
    make_paged_fns,
    make_serve_fns,
    make_slot_chunk_fn,
    zero_pools,
)
from repro.launch.serving.loop import ServeLoop
from repro.launch.serving.pool import PagePool, RadixCache
from repro.launch.serving.queueing import Request

__all__ = [
    "abstract_cache",
    "cache_shardings",
    "make_mixed_fn",
    "make_paged_fns",
    "make_serve_fns",
    "make_slot_chunk_fn",
    "zero_pools",
    "PagePool",
    "RadixCache",
    "Request",
    "ServeLoop",
    "PrefillWorker",
    "DecodeWorker",
    "DisaggRouter",
]
