"""Request objects, the priority admission queue, and small host-side
scheduling utilities shared by every serve engine (single-loop and
disaggregated)."""

from __future__ import annotations

import collections
import dataclasses

import numpy as np

__all__ = [
    "Request",
    "_PRIORITY_RANK",
    "_PagedSlot",
    "_AdmitQueue",
    "_AsyncTokens",
    "_next_bucket",
]


@dataclasses.dataclass
class _PagedSlot:
    """Host bookkeeping for one active request's pages: the retention
    schedule (from the block maps) plus its allocated tiles."""

    last_reader: np.ndarray  # (n_tiles,) last query position reading tile j
    peak_from: np.ndarray  # (L,) max future residency from frontier p
    length: int  # written-position horizon: plen + max_new - 1

    def remaining_peak(self, pos: int) -> int:
        return int(self.peak_from[min(pos, self.length - 1)])


# priority classes, best first.  Rank 0 is served ahead of rank 1 at every
# admission decision; the aging guard promotes a waiting batch request to
# rank 0 after ``aging_steps`` engine clocks so batch work is delayed under
# load, never starved.
_PRIORITY_RANK = {"interactive": 0, "batch": 1}


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray  # (S,) int32
    max_new: int
    arrival: int = 0  # earliest engine step at which the request exists
    priority: str = "interactive"  # scheduling class, see _PRIORITY_RANK
    generated: list[int] = dataclasses.field(default_factory=list)
    extras: dict = dataclasses.field(default_factory=dict)  # e.g. encdec frames
    # SLO accounting, in engine-step clock units (reset by each run()):
    emit_clocks: list[int] = dataclasses.field(default_factory=list)
    ttft: int | None = None  # first-token clock minus arrival
    preemptions: int = 0  # times this request was evicted and requeued


class _AdmitQueue:
    """Priority-ordered admission queue with an aging/starvation guard.

    ``peek(clock)`` returns the best ARRIVED request under the order
    (rank, arrival, insertion seq) — interactive ahead of batch, FIFO
    within a class — without removing it; the engine pops it only once its
    page reservation succeeds, so backpressure keeps the request queued.
    A batch request that has waited ``aging_steps`` clocks is promoted to
    the interactive rank (counted in ``promotions``): batch work is
    delayed under load, never starved.  ``fifo=True`` disables both the
    priority order and aging — the strict arrival-order baseline the
    --check-preempt gate compares against.  Preempted requests re-enter
    through ``push`` keeping their original ``arrival``, so their age (and
    any promotion) keeps accruing across evictions."""

    def __init__(self, requests: list[Request], aging_steps: int,
                 fifo: bool = False):
        self.aging_steps = aging_steps
        self.fifo = fifo
        self.promotions = 0
        self._seq = 0
        self._q: list[tuple[int, Request]] = []
        for r in requests:
            self.push(r)

    def __len__(self) -> int:
        return len(self._q)

    def push(self, r: Request) -> None:
        self._q.append((self._seq, r))
        self._seq += 1

    def rank(self, r: Request, clock: int) -> int:
        if self.fifo:
            return 0
        base = _PRIORITY_RANK[r.priority]
        if base and clock - r.arrival >= self.aging_steps:
            return 0  # aged: promoted to the interactive rank
        return base

    def peek(self, clock: int) -> Request | None:
        best_key, best = None, None
        for seq, r in self._q:
            if r.arrival > clock:
                continue
            key = (self.rank(r, clock), r.arrival, seq)
            if best_key is None or key < best_key:
                best_key, best = key, r
        return best

    def pop(self, r: Request, clock: int) -> None:
        for i, (_, q) in enumerate(self._q):
            if q is r:
                if (not self.fifo and _PRIORITY_RANK[r.priority]
                        and self.rank(r, clock) == 0):
                    self.promotions += 1
                del self._q[i]
                return
        raise ValueError(f"pop of request {r.uid} not in queue")


def _next_bucket(n: int, cap: int, floor: int = 8) -> int:
    """Smallest power-of-two >= n (>= floor), clamped at ``cap`` — the result
    is always a power of two or exactly ``cap``, so the jit shape cache stays
    bounded (at most log2(cap) values).  ``n`` must already be validated
    against ``cap`` (the engine checks prompts/positions against cache_len);
    a larger ``n`` is a caller bug, not a bucket to allocate."""
    if n > cap:
        raise ValueError(f"bucket request {n} exceeds cap {cap}")
    b = floor
    while b < n:
        b *= 2
    return min(b, cap)


class _AsyncTokens:
    """One-step-lag device-to-host token fetch.

    ``push(dev, sinks)`` registers a device array of sampled token ids and
    the (request, row) pairs that consumed them, starts an async copy, and
    resolves any record older than ``lag`` steps — so the host appends step
    t-1's values while step t's compute is already dispatched, and the
    per-token blocking ``np.asarray(argmax(...))`` sync disappears from the
    steady-state loop.  ``flush()`` resolves everything (end of run)."""

    def __init__(self, lag: int = 1):
        self.lag = lag
        self._q: collections.deque = collections.deque()

    def push(self, dev, sinks: list[tuple[Request, int]]) -> None:
        try:
            dev.copy_to_host_async()
        except AttributeError:  # non-array backends / older jax
            pass
        self._q.append((dev, sinks))
        while len(self._q) > self.lag:
            self._resolve()

    def _resolve(self) -> None:
        dev, sinks = self._q.popleft()
        vals = np.asarray(dev).reshape(-1)
        for r, i in sinks:
            r.generated.append(int(vals[i]))

    def flush(self) -> None:
        while self._q:
            self._resolve()
