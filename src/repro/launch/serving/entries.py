"""Compiled serve entry points: jitted prefill/decode/mixed/chunk/paged
factories with sharded KV caches and page pools.

`make_serve_fns` builds the two classic compiled entry points the dry-run
exercises (`prefill_32k` lowers prefill; `decode_32k` / `long_500k` lower
decode_step); with ``ragged=True`` the prefill takes per-request prompt
lengths and the decode takes a (B,) position vector instead of a batch-wide
scalar.  `make_mixed_fn` builds the third, unified entry point: one jitted
``mixed_step`` where every batch row consumes a per-row token count — a
prompt chunk, one decode token, or nothing.  `make_paged_fns` builds the
page-pool family; its pools shard over the mesh's ``pages`` axis when one
exists (see :func:`repro.models.transformer.paged_pool_specs`).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import quant
from repro.core.attention import override_attention
from repro.distributed import sharding as shd
from repro.models import model as M
from repro.models import transformer as tf
from repro.models.config import ModelConfig

__all__ = [
    "cache_shardings",
    "abstract_cache",
    "make_serve_fns",
    "make_mixed_fn",
    "make_slot_chunk_fn",
    "make_paged_fns",
    "zero_pools",
]


def cache_shardings(cfg: ModelConfig, mesh: Mesh, batch: int, cache_len: int):
    return shd.sharding_tree(tf.cache_specs(cfg, batch, cache_len), mesh, M.rules_for(cfg))


def abstract_cache(cfg: ModelConfig, batch: int, cache_len: int):
    specs = tf.cache_specs(cfg, batch, cache_len)
    dt = jnp.dtype(cfg.dtype)
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, dt),
        specs,
        is_leaf=lambda x: isinstance(x, shd.ParamSpec),
    )


def zero_pools(cfg: ModelConfig, mesh: Mesh, n_pages: int, page: int,
               cross_pages: int | None = None, kv_dtype: str = "bf16"):
    """Zero-initialised paged KV pools placed at their MESH shardings — on a
    mesh with a ``pages`` axis the page rows land sharded from the start, so
    the donated entry-point calls never reshard a committed replicated
    array.

    ``kv_dtype`` != 'bf16' stores the self-attention K/V leaves at the
    quantized width and adds their float32 ``*_scale`` leaves
    (:func:`repro.models.transformer.paged_pool_specs`); cross pools and
    everything else stay at the config's cache dtype."""
    specs = tf.paged_pool_specs(
        cfg, n_pages, page, cross_pages=cross_pages, kv_dtype=kv_dtype
    )
    shards = shd.sharding_tree(specs, mesh, M.rules_for(cfg))
    base = jnp.dtype(cfg.dtype)
    store = quant.kv_store_dtype(kv_dtype, base)

    def leaf_dtype(path):
        names = [p.key for p in path if hasattr(p, "key")]
        if names and names[-1].endswith("_scale"):
            return jnp.dtype(jnp.float32)
        if "attn" in names and names[-1] in ("k", "v"):
            return store
        return base

    return jax.tree_util.tree_map_with_path(
        lambda path, s, sh: jax.device_put(
            jnp.zeros(s.shape, leaf_dtype(path)), sh
        ),
        specs, shards,
        is_leaf=lambda x: isinstance(x, shd.ParamSpec),
    )


def _entry_shardings(cfg: ModelConfig, mesh: Mesh, batch: int, cache_len: int):
    """Shared setup of every serve entry-point factory: resolved runtime +
    the param / cache / token / replicated shardings.  One definition so the
    prefill, decode, mixed-wave and slot-chunk compiles can never diverge."""
    rt = M.resolve_runtime(cfg, mesh)
    p_shard = shd.sharding_tree(M.build_specs(cfg), mesh, M.rules_for(cfg))
    c_shard = cache_shardings(cfg, mesh, batch, cache_len)
    tok_shard = NamedSharding(
        mesh, P(tuple(a for a in ("pod", "data") if a in mesh.axis_names))
    )
    rep = NamedSharding(mesh, P())
    return rt, p_shard, c_shard, tok_shard, rep


def make_serve_fns(
    cfg: ModelConfig,
    mesh: Mesh,
    *,
    batch: int,
    cache_len: int,
    attn_impl: str | None = None,
    attn_pattern: str | None = None,
    ragged: bool = False,
):
    """Returns (prefill_fn, decode_fn).

    ``ragged=False`` (static batch): prefill_fn(params, batch_dict) and
    decode_fn(params, caches, tokens, pos-scalar).  ``ragged=True``:
    prefill_fn(params, batch_dict, lengths (B,)) gathers each row's last real
    token and decode_fn takes pos as a (B,) per-request position vector.

    ``attn_impl`` / ``attn_pattern`` override the config's attention
    execution form / block-sparsity pattern for this serving instance (e.g.
    "flash_kernel" + "butterfly" on a single-chip deployment).

    ``decode_fn`` takes an optional trailing ``kv_live`` (static int): a
    host-known bound on every row's live cache length.  Attention then
    streams only the first ``kv_live`` cache rows — each distinct value
    compiles once, so callers should bucket it (the engine uses powers of
    two)."""
    cfg = override_attention(cfg, impl=attn_impl, pattern=attn_pattern)
    rt, p_shard, c_shard, tok_shard, rep = _entry_shardings(
        cfg, mesh, batch, cache_len
    )

    if ragged:
        prefill = jax.jit(
            lambda params, b, lengths: tf.prefill(
                params, cfg, b, rt, cache_len=cache_len, lengths=lengths
            ),
            in_shardings=(p_shard, None, rep),
            out_shardings=(tok_shard, c_shard),
        )
        pos_shard = rep  # (B,) per-request positions, replicated
    else:
        prefill = jax.jit(
            lambda params, b: tf.prefill(params, cfg, b, rt, cache_len=cache_len),
            in_shardings=(p_shard, None),
            out_shardings=(tok_shard, c_shard),
        )
        pos_shard = rep
    jitted: dict[int | None, object] = {}

    def decode(params, caches, tokens, pos, kv_live: int | None = None):
        fn = jitted.get(kv_live)
        if fn is None:
            fn = jax.jit(
                lambda params, caches, tokens, pos: tf.decode_step(
                    params, cfg, caches, tokens, pos, rt, kv_live=kv_live
                ),
                in_shardings=(p_shard, c_shard, tok_shard, pos_shard),
                out_shardings=(tok_shard, c_shard),
                donate_argnums=(1,),
            )
            jitted[kv_live] = fn
        return fn(params, caches, tokens, pos)

    return prefill, decode


def make_mixed_fn(
    cfg: ModelConfig,
    mesh: Mesh,
    *,
    batch: int,
    cache_len: int,
    chunk: int,
    attn_impl: str | None = None,
    attn_pattern: str | None = None,
):
    """The unified mixed-step entry point: one compiled function advances the
    whole batch, each row consuming ``ntok[b]`` tokens (0 idle / 1 decode /
    2..chunk prompt chunk) at positions ``pos[b]..``.

    Returned callable: ``mixed(params, caches, tokens (B,C) host prompt
    chunks, nxt (B,) device feedback tokens, use_nxt (B,) bool, pos (B,),
    ntok (B,), kv_live)``.  Decode rows take their input token from ``nxt``
    (the previous step's on-device argmax — the host never syncs on token
    values), prefill rows from ``tokens``.  ``kv_live`` buckets compile
    per value, like the decode entry point."""
    cfg = override_attention(cfg, impl=attn_impl, pattern=attn_pattern)
    rt, p_shard, c_shard, tok_shard, rep = _entry_shardings(
        cfg, mesh, batch, cache_len
    )
    jitted: dict[int | None, object] = {}

    def mixed(params, caches, tokens, nxt, use_nxt, pos, ntok,
              kv_live: int | None = None):
        if tokens.shape != (batch, chunk):
            raise ValueError(
                f"tokens {tokens.shape} vs compiled chunk shape {(batch, chunk)}"
            )
        fn = jitted.get(kv_live)
        if fn is None:
            def _step(params, caches, tokens, nxt, use_nxt, pos, ntok):
                col0 = jnp.arange(tokens.shape[1], dtype=jnp.int32)[None, :] == 0
                toks = jnp.where(use_nxt[:, None] & col0, nxt[:, None], tokens)
                return tf.mixed_step(
                    params, cfg, caches, toks, pos, ntok, rt, kv_live=kv_live
                )

            fn = jax.jit(
                _step,
                in_shardings=(p_shard, c_shard, tok_shard, tok_shard, rep, rep, rep),
                out_shardings=(tok_shard, c_shard),
                donate_argnums=(1,),
            )
            jitted[kv_live] = fn
        return fn(params, caches, tokens, nxt, use_nxt, pos, ntok)

    return mixed


def make_slot_chunk_fn(
    cfg: ModelConfig,
    mesh: Mesh,
    *,
    batch: int,
    cache_len: int,
    chunk: int,
    attn_impl: str | None = None,
    attn_pattern: str | None = None,
):
    """``mixed_step`` at its other ragged shape, (1, chunk): stream one
    prompt chunk into ONE slot of the shared cache at a traced slot index.

    Returned callable: ``chunk_fn(params, caches, tokens (1, C), slot, pos,
    ntok, kv_live)`` -> (logits (vocab,) at the chunk's last valid token,
    full updated caches).  The slot's cache rows are sliced to a batch-1
    view, the chunk runs through the exact same mixed_step / chunk-kernel
    path, and the updated rows are written back in place (donated) — so a
    chunk call costs ``C x kv_live`` attention for one row, not
    ``B x C x kv_live`` for the whole batch.  Compiles once per ``kv_live``
    bucket, like the decode entry point."""
    cfg = override_attention(cfg, impl=attn_impl, pattern=attn_pattern)
    rt, p_shard, c_shard, _, rep = _entry_shardings(cfg, mesh, batch, cache_len)
    jitted: dict[int | None, object] = {}

    def chunk_fn(params, caches, tokens, slot, pos, ntok,
                 kv_live: int | None = None):
        if tokens.shape != (1, chunk):
            raise ValueError(
                f"tokens {tokens.shape} vs compiled chunk shape {(1, chunk)}"
            )
        fn = jitted.get(kv_live)
        if fn is None:
            def _step(params, caches, tokens, slot, pos, ntok):
                sub = jax.tree.map(
                    lambda c: jax.lax.dynamic_slice_in_dim(c, slot, 1, axis=1),
                    caches,
                )
                logits, new_sub = tf.mixed_step(
                    params, cfg, sub, tokens, jnp.reshape(pos, (1,)),
                    jnp.reshape(ntok, (1,)), rt, kv_live=kv_live,
                )
                caches = jax.tree.map(
                    lambda c, w: jax.lax.dynamic_update_slice_in_dim(
                        c, w.astype(c.dtype), slot, axis=1
                    ),
                    caches,
                    new_sub,
                )
                return logits[0], caches

            fn = jax.jit(
                _step,
                in_shardings=(p_shard, c_shard, rep, rep, rep, rep),
                out_shardings=(rep, c_shard),
                donate_argnums=(1,),
            )
            jitted[kv_live] = fn
        return fn(params, caches, tokens, slot, pos, ntok)

    return chunk_fn


def make_paged_fns(
    cfg: ModelConfig,
    mesh: Mesh,
    *,
    n_pages: int,
    page: int,
    chunk: int,
    attn_impl: str | None = None,
    attn_pattern: str | None = None,
    cross_pages: int | None = None,
    kv_dtype: str = "bf16",
):
    """Compiled entry points of the PAGED serve engine: ``(prefill, decode,
    chunk_fn, copy_fn, encode_fn)`` over one global page pool instead of
    per-slot ``cache_len`` reservations.

    * ``prefill(params, caches, b, lengths, pt_row)`` — batch-1 admission
      prefill scattered through the request's page-table row (retraces per
      prompt bucket, like the ragged contiguous prefill).
    * ``decode(params, caches, tokens (B,1), pos (B,), pt (B,nv), kv_live)``
      — the ragged decode wave; every row reads the pool through its own
      page-table row, bucketed per ``kv_live``.
    * ``chunk_fn(params, caches, tokens (1,C), pt_row (1,nv), pos, ntok,
      kv_live)`` — one prompt chunk streamed straight into the pool.  No
      slot slice/insert dance: the pool is already shared, the page table IS
      the slot.
    * ``copy_fn(caches, src, dst)`` — copy-on-write page duplication
      (:func:`repro.models.transformer.paged_copy_page`); src/dst are traced
      page ids, so the whole prefix-sharing machinery compiles exactly one
      extra program.

    With ``cross_pages`` (encoder-decoder stacks) the pools grow per-slot
    read-only cross pools; ``decode`` / ``chunk_fn`` then take a trailing
    cross-table argument and a fifth entry point appears:

    * ``encode_fn(params, caches, frames (1, S, D), ct_row (1, n_ct))`` —
      run the encoder ONCE and scatter every decoder slot's cross KV into
      the cross pool through ``ct_row``
      (:func:`repro.models.transformer.paged_encode`); the written pages
      are read-only for the rest of their life and alias freely.

    All entry points donate the pools; the page tables are tiny replicated
    int32 arrays refreshed from host state every call.  On a mesh with a
    ``pages`` axis the pool's page rows are SHARDED over it — each device
    holds the contiguous physical range the host allocator's matching shard
    places into — while the page tables stay replicated (they are the
    ownership record both sides read).

    ``kv_dtype`` selects the pool storage width (bf16 | int8 | fp8_e4m3) —
    the entry points themselves are layout-agnostic (the caches tree flows
    through opaquely), only the pool SHARDING tree must know about the
    quantized pools' extra ``*_scale`` leaves."""
    cfg = override_attention(cfg, impl=attn_impl, pattern=attn_pattern)
    rt = M.resolve_runtime(cfg, mesh)
    p_shard = shd.sharding_tree(M.build_specs(cfg), mesh, M.rules_for(cfg))
    pool_shard = shd.sharding_tree(
        tf.paged_pool_specs(
            cfg, n_pages, page, cross_pages=cross_pages, kv_dtype=kv_dtype
        ),
        mesh, M.rules_for(cfg),
    )
    tok_shard = NamedSharding(
        mesh, P(tuple(a for a in ("pod", "data") if a in mesh.axis_names))
    )
    rep = NamedSharding(mesh, P())

    prefill = jax.jit(
        lambda params, caches, b, lengths, pt: tf.paged_prefill(
            params, cfg, b, rt, caches=caches, page_table=pt, page=page,
            lengths=lengths,
        ),
        in_shardings=(p_shard, pool_shard, None, rep, rep),
        out_shardings=(tok_shard, pool_shard),
        donate_argnums=(1,),
    )

    dec_jit: dict[int | None, object] = {}

    def decode(params, caches, tokens, pos, pt, kv_live: int | None = None,
               ct=None):
        fn = dec_jit.get(kv_live)
        if fn is None:
            if cross_pages is not None:
                fn = jax.jit(
                    lambda params, caches, tokens, pos, pt, ct: tf.decode_step(
                        params, cfg, caches, tokens, pos, rt, kv_live=kv_live,
                        page_table=pt, page=page, cross_table=ct,
                    ),
                    in_shardings=(p_shard, pool_shard, tok_shard, rep, rep,
                                  rep),
                    out_shardings=(tok_shard, pool_shard),
                    donate_argnums=(1,),
                )
            else:
                fn = jax.jit(
                    lambda params, caches, tokens, pos, pt: tf.decode_step(
                        params, cfg, caches, tokens, pos, rt, kv_live=kv_live,
                        page_table=pt, page=page,
                    ),
                    in_shardings=(p_shard, pool_shard, tok_shard, rep, rep),
                    out_shardings=(tok_shard, pool_shard),
                    donate_argnums=(1,),
                )
            dec_jit[kv_live] = fn
        if cross_pages is not None:
            return fn(params, caches, tokens, pos, pt, ct)
        return fn(params, caches, tokens, pos, pt)

    chk_jit: dict[int | None, object] = {}

    def chunk_fn(params, caches, tokens, pt, pos, ntok,
                 kv_live: int | None = None, ct=None):
        if tokens.shape != (1, chunk):
            raise ValueError(
                f"tokens {tokens.shape} vs compiled chunk shape {(1, chunk)}"
            )
        fn = chk_jit.get(kv_live)
        if fn is None:
            def _step(params, caches, tokens, pt, pos, ntok, ct=None):
                logits, caches = tf.mixed_step(
                    params, cfg, caches, tokens, jnp.reshape(pos, (1,)),
                    jnp.reshape(ntok, (1,)), rt, kv_live=kv_live,
                    page_table=pt, page=page, cross_table=ct,
                )
                return logits[0], caches

            if cross_pages is not None:
                fn = jax.jit(
                    _step,
                    in_shardings=(p_shard, pool_shard, rep, rep, rep, rep,
                                  rep),
                    out_shardings=(rep, pool_shard),
                    donate_argnums=(1,),
                )
            else:
                fn = jax.jit(
                    _step,
                    in_shardings=(p_shard, pool_shard, rep, rep, rep, rep),
                    out_shardings=(rep, pool_shard),
                    donate_argnums=(1,),
                )
            chk_jit[kv_live] = fn
        if cross_pages is not None:
            return fn(params, caches, tokens, pt, pos, ntok, ct)
        return fn(params, caches, tokens, pt, pos, ntok)

    copy_fn = jax.jit(
        lambda caches, src, dst: tf.paged_copy_page(caches, src, dst, page),
        in_shardings=(pool_shard, rep, rep),
        out_shardings=pool_shard,
        donate_argnums=(0,),
    )

    encode_fn = None
    if cross_pages is not None:
        encode_fn = jax.jit(
            lambda params, caches, frames, ct: tf.paged_encode(
                params, cfg, frames, rt, caches=caches, cross_table=ct,
                page=page,
            ),
            in_shardings=(p_shard, pool_shard, None, rep),
            out_shardings=pool_shard,
            donate_argnums=(1,),
        )

    return prefill, decode, chunk_fn, copy_fn, encode_fn
