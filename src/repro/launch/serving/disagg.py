"""Disaggregated prefill/decode serving: two phase-specialised workers over
ONE mesh-sharded page pool, coordinated by a host-side router.

The paper's multilayer-dataflow argument — pick the dataflow per phase
instead of forcing one loop shape onto both — applied at the serving layer:
prefill is a throughput phase (long chunked writes, wide attention reads),
decode is a latency phase (one token per request per step, shallow reads).
The single :class:`~repro.launch.serving.loop.ServeLoop` interleaves them in
one batch; here each phase gets its OWN slot bank:

* :class:`PrefillWorker` — ``prefill_batch`` slots that only stream prompt
  chunks (the ``(1, C)`` paged chunk entry point).  A slot that finishes its
  prompt samples the request's FIRST token and parks, waiting for handoff.
* :class:`DecodeWorker` — ``batch`` slots that only decode (the ``(B, 1)``
  paged decode wave).  Every active row advances every step by
  construction; prefill work can never stall it.
* :class:`DisaggRouter` — owns everything global: the admission queue, the
  :class:`~repro.launch.serving.pool.PagePool`, the radix prefix cache, the
  SLO clocks, and the preemption ladder.  It admits into the prefill
  worker, hands finished prefills to the decode worker, and preempts decode
  victims when a higher-priority admission cannot reserve.

**Handoff is ownership transfer, not data movement.**  Both workers read
the same device pools through per-slot page-table rows; the page table is
the transferable ownership record.  Moving a request from prefill slot
``s`` to decode slot ``d`` copies the table row (host ints), relabels each
page's pool reference from ``prefill:reqN`` to ``decode:reqN``
(:meth:`PagePool.transfer` — the refcount moves, it never duplicates or
drops), and seeds the decode feedback token with the first sampled token.
The KV rows themselves never move: on a ``pages``-sharded mesh they stay on
whichever shard allocated them, and both phases' kernels read them through
the (replicated) tables.

Rings (sliding-window) and encoder-decoder stacks are rejected: their page
sets are reused in phase / shared read-only, which makes them
non-preemptible in the single loop and non-transferable here — the single
loop remains the right engine for those families, and for any deployment
where one batch is enough to keep both phases busy."""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.launch.serving.loop import ServeLoop
from repro.launch.serving.queueing import (
    Request,
    _AdmitQueue,
    _AsyncTokens,
    _PagedSlot,
    _PRIORITY_RANK,
    _next_bucket,
)

__all__ = ["PrefillWorker", "DecodeWorker", "DisaggRouter"]


@dataclasses.dataclass
class _Handoff:
    """A finished prefill waiting for a decode slot: the request, its
    retention schedule, its next write position, and the first sampled
    token (a device scalar — the host never syncs on it)."""

    r: Request
    sched: _PagedSlot
    pos: int
    tok1: object  # device scalar int32


class PrefillWorker:
    """Slot bank of the prefill phase: per-slot host state for requests
    mid-prompt.  The router mutates it; the worker only owns the layout."""

    def __init__(self, n_slots: int, n_vtiles: int, sentinel: int):
        self.n_slots = n_slots
        self.active: list[Request | None] = [None] * n_slots
        self.sched: list[_PagedSlot | None] = [None] * n_slots
        self.parr: list[np.ndarray | None] = [None] * n_slots
        self.pos = np.zeros(n_slots, np.int32)
        self.consumed = np.zeros(n_slots, np.int32)
        self.owed = np.zeros(n_slots, np.int32)  # decode tokens at admission
        self.pt = np.full((n_slots, n_vtiles), sentinel, np.int32)
        self.done: list[_Handoff | None] = [None] * n_slots
        self.rr = 0  # round-robin offset of the chunk budget

    def busy(self) -> bool:
        return any(r is not None for r in self.active)

    def free_slots(self) -> list[int]:
        return [s for s in range(self.n_slots) if self.active[s] is None]


class DecodeWorker:
    """Slot bank of the decode phase: every active row decodes one token per
    step.  Rows arrive only through handoff (the router fills them)."""

    def __init__(self, n_slots: int, n_vtiles: int, sentinel: int):
        self.n_slots = n_slots
        self.active: list[Request | None] = [None] * n_slots
        self.sched: list[_PagedSlot | None] = [None] * n_slots
        self.parr: list[np.ndarray | None] = [None] * n_slots
        self.pos = np.zeros(n_slots, np.int32)
        self.remaining = np.zeros(n_slots, np.int32)
        self.admit_pos = np.zeros(n_slots, np.int32)  # preemption floor
        self.admit_seq = np.zeros(n_slots, np.int64)  # victim tiebreak
        self.pt = np.full((n_slots, n_vtiles), sentinel, np.int32)
        self.nxt = jnp.zeros((n_slots,), jnp.int32)

    def busy(self) -> bool:
        return any(r is not None for r in self.active)

    def free_slots(self) -> list[int]:
        return [s for s in range(self.n_slots) if self.active[s] is None]


class DisaggRouter(ServeLoop):
    """Phase-disaggregated paged serve engine.

    Subclasses :class:`ServeLoop` for everything global — pool, radix tree,
    schedules, reservation discipline, preemption ladder, SLO accounting —
    and replaces the single interleaved loop with a prefill worker, a decode
    worker, and a handoff step between them.  ``batch`` sizes the DECODE
    worker (it is the concurrency limit that matters for ITL);
    ``prefill_batch`` sizes the prefill worker.  Greedy decoding through
    the same compiled entry points keeps the emitted tokens identical to
    the single loop's — the --check-shard gate pins that parity.

    Preemption only ever evicts DECODE rows: a prefill row's pages are
    donated back to the radix tree at eviction anyway, so evicting
    mid-prefill work saves nothing over letting it finish, while evicting a
    decode row frees its whole resident set.  Victims requeue through the
    router's admission path and re-prefill (warm via the radix tree) in the
    prefill worker at the satellite reduced budget share."""

    def __init__(self, cfg, mesh, params, *, batch: int,
                 prefill_batch: int = 1, **kw):
        if prefill_batch < 1:
            raise ValueError(
                f"prefill_batch must be >= 1, got {prefill_batch}"
            )
        if cfg.sliding_window:
            raise ValueError(
                "disaggregated serving does not support sliding-window "
                "rings: a ring's fixed in-phase page set spans prefill and "
                "decode, so there is no ownership to hand off — use the "
                "single-loop engine"
            )
        if cfg.family == "encdec":
            raise ValueError(
                "disaggregated serving does not support encoder-decoder "
                "stacks: the shared read-only cross ranges make requests "
                "non-preemptible and tie admission to the encoder cache — "
                "use the single-loop engine"
            )
        kw.setdefault("paged", True)
        kw.setdefault("chunked", True)
        if not (kw["paged"] and kw["chunked"]):
            raise ValueError(
                "disaggregated serving is paged+chunked by construction"
            )
        super().__init__(cfg, mesh, params, batch=batch, **kw)
        self.prefill_batch = prefill_batch

    def _slot_owner(self, r: Request) -> str:
        # preemption only ever evicts decode-phase rows
        return f"decode:req{r.uid}"

    def run(self, requests: list[Request]) -> list[Request]:
        self._validate(requests)
        return self._run_disagg(requests)

    # -- the router loop --------------------------------------------------

    def _commit_all(self, pw: PrefillWorker, dw: DecodeWorker) -> int:
        """Both workers' committed worst-case future residency — admission
        reserves against the union, so handoff never needs pages."""
        return (self._committed(pw.active, pw.sched, pw.pos)
                + self._committed(dw.active, dw.sched, dw.pos))

    def _run_disagg(self, requests: list[Request]) -> list[Request]:
        C = self.chunk_size
        q = _AdmitQueue(requests, self.aging_steps, self.fifo)
        pw = PrefillWorker(self.prefill_batch, self.n_vtiles, self.pool_pages)
        dw = DecodeWorker(self.batch, self.n_vtiles, self.pool_pages)
        pool = self.pool
        fetch = _AsyncTokens(lag=1)
        aseq = 0
        self.stats = {
            "prefill_calls": 0, "mixed_steps": 0, "chunk_calls": 0,
            "decode_steps": 0, "prefill_tokens": 0, "decode_tokens": 0,
            "decode_stall_steps": 0, "overlap_steps": 0,
            "admission_backpressure": 0, "max_concurrent": 0,
            "prefill_flops": 0.0, "prefix_hits": 0, "prefix_hit_tokens": 0,
            "preemptions": 0, "resumes": 0, "resume_warm_hits": 0,
            "handoffs": 0, "handoff_wait_steps": 0,
            "prefill_batch": self.prefill_batch, "decode_batch": self.batch,
        }
        clock = 0
        with self.mesh:
            caches = (
                self._pools if self._pools is not None else self._zero_pools()
            )
            while (len(q) or pw.busy() or dw.busy()):
                # -- admission into the PREFILL worker --------------------
                for slot in pw.free_slots():
                    r = q.peek(clock)
                    if r is None:
                        break
                    pr = self._eff_prompt(r)
                    owed = r.max_new - len(r.generated)
                    L = len(pr) + owed - 1
                    own = f"prefill:req{r.uid}"
                    rank = _PRIORITY_RANK[r.priority]
                    m, spages = self._match_prefix(pr)
                    if m:
                        for p in spages:
                            pool.retain(p, owner=own)
                        sc = self._paged_schedule(
                            L, step_span=C, start_tile=m // self.page
                        )
                        need = lambda: (
                            self._commit_all(pw, dw) + sc.remaining_peak(m)
                        )
                        gap = self._fits(need())
                        if gap > 0 and self.preemptible:
                            gap = self._preempt_until(
                                need, rank, q, fetch, pool, dw.pt,
                                dw.active, dw.sched, dw.parr, dw.pos,
                                dw.admit_pos, dw.admit_seq,
                            )
                        if gap > 0:
                            for p in spages:
                                pool.release(p, owner=own)
                            cold_peak = self._paged_schedule(
                                L, step_span=C
                            ).remaining_peak(0)
                            if cold_peak < sc.remaining_peak(m):
                                m, spages = 0, []
                            else:
                                self.stats["admission_backpressure"] += 1
                                break
                    if not m:
                        sc = self._paged_schedule(L, step_span=C)
                        need = lambda: (
                            self._commit_all(pw, dw) + sc.remaining_peak(0)
                        )
                        gap = self._fits(need())
                        if gap > 0 and self.preemptible:
                            gap = self._preempt_until(
                                need, rank, q, fetch, pool, dw.pt,
                                dw.active, dw.sched, dw.parr, dw.pos,
                                dw.admit_pos, dw.admit_seq,
                            )
                        if gap > 0:
                            self.stats["admission_backpressure"] += 1
                            break
                    q.pop(r, clock)
                    if r.preemptions:
                        self.stats["resumes"] += 1
                        if m:
                            self.stats["resume_warm_hits"] += 1
                    if m:
                        for i, p in enumerate(spages):
                            pw.pt[slot, i] = p
                        self.stats["prefix_hits"] += 1
                        self.stats["prefix_hit_tokens"] += m
                    pw.active[slot] = r
                    pw.sched[slot] = sc
                    pw.parr[slot] = pr
                    pw.pos[slot] = m
                    pw.consumed[slot] = m
                    pw.owed[slot] = owed
                self.stats["max_concurrent"] = max(
                    self.stats["max_concurrent"],
                    sum(a is not None for a in pw.active)
                    + sum(a is not None for a in dw.active),
                )
                # -- handoff: finished prefills -> free decode slots ------
                waiting = [s for s in range(pw.n_slots) if pw.done[s]]
                if waiting:
                    frees = dw.free_slots()
                    for s, d in zip(waiting, frees):
                        h = pw.done[s]
                        r = h.r
                        dw.pt[d, :] = pw.pt[s, :]
                        pw.pt[s, :] = self.pool_pages
                        for t in range(dw.pt.shape[1]):
                            pid = int(dw.pt[d, t])
                            if pid != self.pool_pages:
                                pool.transfer(
                                    pid, f"prefill:req{r.uid}",
                                    f"decode:req{r.uid}",
                                )
                        dw.active[d] = r
                        dw.sched[d] = h.sched
                        dw.parr[d] = pw.parr[s]
                        dw.pos[d] = h.pos
                        dw.remaining[d] = pw.owed[s] - 1  # tok1 already out
                        dw.admit_pos[d] = h.pos
                        dw.admit_seq[d] = aseq
                        aseq += 1
                        dw.nxt = dw.nxt.at[d].set(h.tok1)
                        pw.done[s] = None
                        pw.active[s] = None
                        pw.sched[s] = None
                        pw.parr[s] = None
                        self.stats["handoffs"] += 1
                    if len(waiting) > len(frees):
                        # decode full: the parked prefill slots backpressure
                        # the prefill worker until a decode row retires
                        self.stats["handoff_wait_steps"] += 1
                if not (pw.busy() or dw.busy()):
                    clock += 1  # idle tick: waiting on arrivals
                    continue
                clock += 1
                self.stats["mixed_steps"] += 1
                # -- decode wave (every active decode row, every step) ----
                dec_rows = [
                    d for d in range(dw.n_slots) if dw.active[d] is not None
                ]
                if dec_rows:
                    for d in dec_rows:
                        caches = self._ensure_writable(
                            pool, dw.pt, d, int(dw.pos[d]),
                            int(dw.pos[d]) + 1, caches,
                            f"decode:req{dw.active[d].uid}",
                        )
                    hot = max(int(dw.pos[d]) + 1 for d in dec_rows)
                    kv_live = _next_bucket(hot, self.cache_len)
                    self.stats["decode_kv_live_max"] = max(
                        self.stats.get("decode_kv_live_max", 0), kv_live
                    )
                    use = np.asarray(
                        [a is not None for a in dw.active], bool
                    )
                    pt_wave = np.where(
                        use[:, None], dw.pt, np.int32(self.pool_pages)
                    ).astype(np.int32)
                    logits, caches = self.p_decode_fn(
                        self.params, caches, dw.nxt[:, None],
                        jnp.asarray(dw.pos), jnp.asarray(pt_wave), kv_live,
                    )
                    toks = jnp.argmax(logits, -1).astype(jnp.int32)
                    self.stats["decode_steps"] += 1
                    self.stats["decode_tokens"] += len(dec_rows)
                    sinks = []
                    for d in dec_rows:
                        r = dw.active[d]
                        sinks.append((r, d))
                        dw.pos[d] += 1
                        dw.remaining[d] -= 1
                        if dw.remaining[d] <= 0:
                            self._free_all(
                                pool, dw.pt, d, f"decode:req{r.uid}"
                            )
                            dw.active[d] = None
                            dw.sched[d] = None
                            dw.parr[d] = None
                        else:
                            self._free_dead(
                                pool, dw.pt, d, dw.sched[d],
                                int(dw.pos[d]), f"decode:req{r.uid}",
                            )
                    self._stamp_emits(sinks, clock)
                    fetch.push(toks, sinks)
                    dw.nxt = jnp.where(jnp.asarray(use), toks, dw.nxt)
                # -- prefill chunks under the step budget -----------------
                budget = self.chunk_budget
                order = sorted(
                    range(pw.n_slots),
                    key=lambda s: (
                        0 if self.fifo or pw.active[s] is None
                        else _PRIORITY_RANK[pw.active[s].priority],
                        (s - pw.rr) % pw.n_slots,
                    ),
                )
                pw.rr = (pw.rr + 1) % pw.n_slots
                did_chunk = False
                for slot in order:
                    r = pw.active[slot]
                    if r is None or pw.done[slot] is not None:
                        continue  # empty, or parked awaiting handoff
                    rem_prompt = len(pw.parr[slot]) - pw.consumed[slot]
                    t = self._budget_draw(r, rem_prompt, budget)
                    if t <= 0:
                        continue
                    budget -= t
                    own = f"prefill:req{r.uid}"
                    caches = self._ensure_writable(
                        pool, pw.pt, slot, int(pw.pos[slot]),
                        int(pw.pos[slot]) + t, caches, own,
                    )
                    ctoks = np.zeros((1, C), np.int32)
                    ctoks[0, :t] = pw.parr[slot][
                        pw.consumed[slot] : pw.consumed[slot] + t
                    ]
                    kv_live = _next_bucket(
                        int(pw.pos[slot]) + t, self.cache_len
                    )
                    logits1, caches = self.p_chunk_fn(
                        self.params, caches, jnp.asarray(ctoks),
                        jnp.asarray(pw.pt[slot : slot + 1]),
                        jnp.int32(pw.pos[slot]), jnp.int32(t), kv_live,
                    )
                    did_chunk = True
                    self.stats["chunk_calls"] += 1
                    self.stats["prefill_tokens"] += t
                    self.stats["prefill_flops"] += self._prefill_flop_count(
                        int(pw.pos[slot]), t
                    )
                    pw.pos[slot] += t
                    pw.consumed[slot] += t
                    if pw.consumed[slot] == len(pw.parr[slot]):
                        self._cache_pages(pw.parr[slot], pw.pt, slot)
                        tok1 = jnp.argmax(logits1).astype(jnp.int32)
                        self._stamp_emits([(r, 0)], clock)
                        fetch.push(tok1, [(r, 0)])
                        if pw.owed[slot] <= 1:
                            # max_new == 1: the prefill token was the whole
                            # response — retire without a handoff
                            self._free_all(pool, pw.pt, slot, own)
                            pw.active[slot] = None
                            pw.sched[slot] = None
                            pw.parr[slot] = None
                            continue
                        pw.done[slot] = _Handoff(
                            r=r, sched=pw.sched[slot],
                            pos=int(pw.pos[slot]), tok1=tok1,
                        )
                    self._free_dead(pool, pw.pt, slot, pw.sched[slot],
                                    int(pw.pos[slot]), own)
                if dec_rows and did_chunk:
                    self.stats["overlap_steps"] += 1
        fetch.flush()
        self._pools = caches
        self._finish_paged_run(pool)
        self._finalize_slo(requests, q)
        return requests
