"""ServeLoop: the single-process serving engine — static, admission,
chunked, and paged continuous batching over one page pool.

The pool may be host- AND mesh-sharded: ``page_shards > 1`` splits the
physical page range into contiguous per-shard sub-pools (balanced
allocation in :class:`repro.launch.serving.pool.PagePool`), and on a mesh
with a ``pages`` axis the device-side pools shard over the same ranges
(see :func:`repro.models.transformer.paged_pool_specs`).  The
disaggregated prefill/decode engine
(:class:`repro.launch.serving.disagg.DisaggRouter`) subclasses this loop
and reuses its schedule/reservation/preemption machinery."""

from __future__ import annotations

import collections

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.core import quant, sparsity
from repro.core.attention import override_attention
from repro.distributed import sharding as shd
from repro.models import model as M
from repro.models import transformer as tf
from repro.models.config import ModelConfig

from repro.launch.serving.entries import (
    abstract_cache,
    cache_shardings,
    make_mixed_fn,
    make_paged_fns,
    make_serve_fns,
    make_slot_chunk_fn,
    zero_pools,
)
from repro.launch.serving.pool import PagePool, RadixCache
from repro.launch.serving.queueing import (
    Request,
    _AdmitQueue,
    _AsyncTokens,
    _PagedSlot,
    _PRIORITY_RANK,
    _next_bucket,
)

__all__ = ["ServeLoop"]


class ServeLoop:
    """Streaming serve engine (greedy sampling), two scheduling modes.

    **Chunked** — mixed-step scheduling: every iteration advances all slots
    through the ONE unified entry point (``tf.mixed_step``) at two ragged
    shapes — a (B, 1) decode wave (all decoding rows sample one token,
    kv_live bucketed at *their* live depth) plus a (1, C) slot-chunk call
    per mid-prompt row (up to ``chunk_size`` prompt tokens written straight
    into the slot's rows of the shared cache, bucketed at the prompt's own
    frontier).  Admission costs nothing (a freed slot just starts consuming
    the next request's chunks), a per-step ``chunk_budget`` caps total
    prefill tokens per iteration so decode latency stays bounded, and
    ``kv_live`` buckets (powers of two) bound the compiled shape count.
    Decode rows advance on EVERY step by construction —
    ``stats["decode_stall_steps"]`` stays 0.

    **Admission-prefill** (``chunked=False``) — the slot admit/evict engine:
    each admission runs a bucketed batch-1 prefill and inserts the caches at
    the slot index; all live decode slots idle for that prefill
    (``stats["admission_stall_steps"]`` counts them).  This is the seed
    contiguous engine, kept as the parity baseline; with
    ``static_batching=True`` it degrades admission to wave scheduling (the
    serve_throughput baseline).

    Both modes fetch sampled tokens with a one-step lag (`_AsyncTokens`):
    the decode feedback token stays on device, the host only tracks counts
    (stopping is length-based), so the loop never blocks on the current
    step's values.

    Per-slot host state mirrors the device-side (B,)-vector threading:
    ``pos[b]`` is request b's next write position (== tokens seen so far),
    so RoPE angles, cache writes and live-KV masks are all per-request.
    Prompts are *right*-padded / chunk-aligned — real tokens at positions
    0..L-1, positions and causal masks exact, pad keys never attended.

    ``paged=True`` additionally runs a radix-tree **prefix cache**
    (``prefix_cache=False`` disables it): completed prompts donate their
    full KV pages to a :class:`RadixCache`, admission longest-prefix
    matches new prompts against it, and a hit aliases the matched physical
    pages into the request's page table — prefill then starts at the
    divergence frontier and the admission reservation covers only the
    unique suffix.  Shared pages are refcounted in the :class:`PagePool`
    and copy-on-write forked before any divergent write.

    The page table is the ONLY cache substrate beyond the contiguous
    baseline: a **sliding-window** config serves through a mod-window ring
    table (``ring_tiles`` slots reused in phase, unbounded decode length,
    a fixed page set held per request) and an **encoder-decoder** config
    serves through read-only shared cross page ranges (the encoder output
    prefills once per distinct ``frames`` input; repeat inputs alias the
    cached range, counted as ``prefix_hits``; decode never writes cross
    pages so copy-on-write never triggers).  ``chunked=True`` requests for
    either family upgrade to ``paged=True`` automatically.  The token
    radix tree is disabled for those two families (ring slots are reused
    in phase; encdec decoder KV depends on the frames through
    cross-attention) — the encoder cache is their sharing layer.

    The :class:`PagePool`, the radix tree, and the encoder cache PERSIST
    across ``run()`` calls — a warm second run hits the first run's
    prefixes.  Call :meth:`close` to release the engine-held references;
    it raises if the pools do not drain to zero.
    """

    def __init__(
        self, cfg: ModelConfig, mesh: Mesh, params, *,
        batch: int, cache_len: int, attn_impl: str | None = None,
        attn_pattern: str | None = None, static_batching: bool = False,
        chunked: bool = False, chunk_size: int = 32,
        chunk_budget: int | None = None, paged: bool = False,
        page: int | None = None, pool_pages: int | None = None,
        page_shards: int | None = None, prefix_cache: bool = True,
        scheduler: str = "priority", aging_steps: int = 64,
        max_preemptions: int = 2, preempt_min_progress: int = 1,
        resume_chunk_frac: float = 0.5, slo_ttft: int | None = None,
        slo_itl: float | None = None, kv_dtype: str = "bf16",
    ):
        cfg = override_attention(cfg, impl=attn_impl, pattern=attn_pattern)
        quant.validate_kv_dtype(kv_dtype)
        if kv_dtype != "bf16" and not paged:
            raise ValueError(
                "kv_dtype quantization is a paged-pool feature (scales ride "
                "the page tables) — pass paged=True or kv_dtype='bf16'"
            )
        if cfg.sliding_window and cache_len < cfg.sliding_window:
            raise ValueError(
                f"cache_len {cache_len} < sliding_window {cfg.sliding_window}: "
                "the ring modulus must equal the window for prefill/decode "
                "phase alignment"
            )
        stateful = [s.mixer for s in cfg.period_slots if s.mixer != "attn"]
        if stateful:
            raise ValueError(
                f"{cfg.name}: ragged serving requires attention-only stacks — "
                f"{stateful} mixers integrate right-pad tokens into their "
                "state during bucketed prefill (no per-row mask can undo it)"
            )
        if chunked:
            if static_batching:
                raise ValueError("chunked and static_batching are exclusive: "
                                 "chunked scheduling IS continuous")
            if chunk_size < 1:
                raise ValueError(f"chunk_size must be >= 1, got {chunk_size}")
            if chunk_budget is not None and chunk_budget < 1:
                raise ValueError(
                    f"chunk_budget must be >= 1, got {chunk_budget} — a "
                    "zero budget would starve prefill rows forever"
                )
        if paged and static_batching:
            raise ValueError("paged and static_batching are exclusive")
        if (chunked or paged) and cfg.n_img_tokens:
            # the ONE remaining extras rejection: stub image-patch tokens are
            # prepended inside prefill and have no chunk/page write path yet
            raise ValueError(
                "image-token extras have no chunked/paged path; use the "
                "admission-prefill engine (chunked=False, paged=False)"
            )
        if chunked and not paged and (
            cfg.sliding_window or cfg.family == "encdec"
        ):
            # one cache substrate: a chunked request for a ring or encoder-
            # decoder cache upgrades to the paged engine — the mod-window /
            # read-only page tables ARE the streaming layout for these
            # families (there is no contiguous chunked ring/encdec path)
            paged = True
        if scheduler not in ("priority", "fifo"):
            raise ValueError(
                f"scheduler must be 'priority' or 'fifo', got {scheduler!r}"
            )
        if aging_steps < 1:
            raise ValueError(f"aging_steps must be >= 1, got {aging_steps}")
        if max_preemptions < 0:
            raise ValueError(
                f"max_preemptions must be >= 0, got {max_preemptions}"
            )
        if preempt_min_progress < 1:
            raise ValueError(
                "preempt_min_progress must be >= 1, got "
                f"{preempt_min_progress} — zero progress between evictions "
                "is a livelock"
            )
        if not 0.0 < resume_chunk_frac <= 1.0:
            raise ValueError(
                f"resume_chunk_frac must be in (0, 1], got {resume_chunk_frac}"
            )
        self.cfg, self.mesh, self.params = cfg, mesh, params
        self.batch, self.cache_len = batch, cache_len
        self.static_batching = static_batching
        self.chunked = chunked
        self.chunk_size = chunk_size
        self.chunk_budget = chunk_budget if chunk_budget is not None else chunk_size
        self.fifo = scheduler == "fifo"
        self.aging_steps = aging_steps
        self.max_preemptions = max_preemptions
        self.preempt_min_progress = preempt_min_progress
        self.resume_chunk_frac = resume_chunk_frac
        self.slo_ttft = slo_ttft
        self.slo_itl = slo_itl
        self._closed = False
        # preemption needs a page substrate to evict from and a restartable
        # resume path; rings hold fixed in-phase page sets and encdec KV
        # depends on the frames through cross-attention — both families are
        # NON-preemptible (nothing warm to resume from, by declaration)
        self.preemptible = (
            paged and not self.fifo and max_preemptions > 0
            and not cfg.sliding_window and cfg.family != "encdec"
        )
        self.paged = paged
        self.kv_dtype = kv_dtype
        if paged:
            spec = cfg.attention_spec
            # one page == one kv tile of the effective grid, so the packed
            # live tables ARE the page-table domain (tile-granular paging)
            self.page = page if page is not None else sparsity.pick_pattern_tiles(
                1, cache_len, spec.q_tile, spec.kv_tile
            )[1]
            if self.page < 1:
                raise ValueError(f"page must be >= 1 token, got {self.page}")
            self.ring_tiles: int | None = None
            if cfg.sliding_window:
                # mod-window ring: the table has exactly ring_tiles slots and
                # absolute tile j lives in slot j % ring_tiles — a window-
                # sized page set reused in phase, positions unbounded
                self.ring_tiles = sparsity.ring_tiles_for(
                    cfg.sliding_window, chunk_size, self.page
                )
                self.n_vtiles = self.ring_tiles
            else:
                self.n_vtiles = -(-cache_len // self.page)
            # default pool budget == the dense reservation the contiguous
            # engine would make (batch x cache_len rows; batch rings for a
            # window config) — benchmarks shrink it to show the capacity win
            self.pool_pages = (
                pool_pages if pool_pages is not None else batch * self.n_vtiles
            )
            if self.pool_pages < 1:
                raise ValueError(
                    f"pool_pages must be >= 1, got {self.pool_pages}"
                )
            # host-side page sharding mirrors the mesh: a "pages" axis splits
            # the pool's physical range into contiguous per-device sub-pools
            # (GSPMD partitions the page rows the same way), so the host
            # allocator's shard ranges ARE the device placement.  Explicit
            # page_shards overrides (host-only sharding on a 1-device mesh is
            # how the capacity accounting is tested without real devices).
            if page_shards is None:
                axes = dict(zip(mesh.axis_names, mesh.devices.shape))
                page_shards = axes.get("pages", 1)
            if page_shards < 1:
                raise ValueError(
                    f"page_shards must be >= 1, got {page_shards}"
                )
            self.page_shards = page_shards
            if self.pool_pages % page_shards:
                # round UP to a shard multiple — never shrink a user budget
                self.pool_pages += page_shards - self.pool_pages % page_shards
            # encoder-decoder: a SEPARATE read-only cross pool — encoder
            # outputs prefill once, decoders alias; sized for one distinct
            # encoder input per slot (the frames cache shares below that)
            self.cross_pages: int | None = None
            if cfg.family == "encdec":
                self.cross_tiles = -(-cfg.enc_seq // self.page)
                self.cross_pages = batch * self.cross_tiles
                self.cross_pool = PagePool(self.cross_pages)
                self._cross_cache: collections.OrderedDict[
                    str, list[int]
                ] = collections.OrderedDict()
            # prefix sharing: the radix tree is token-keyed, so it is OFF for
            # rings (slots are reused in phase — nothing stable to alias) and
            # for encdec decoders (self-KV depends on the encoder output
            # through cross-attention, not on tokens alone); encdec gets the
            # frames-keyed encoder cache instead.  Both the tree and the page
            # pool PERSIST across run() calls — drain checks live in close().
            self.prefix_cache = (
                prefix_cache and not cfg.sliding_window
                and cfg.family != "encdec"
            )
            self.pool = PagePool(self.pool_pages, n_shards=self.page_shards)
            self.radix: RadixCache | None = (
                RadixCache(self.pool, self.page) if self.prefix_cache else None
            )
            self._pools = None  # device pools, lazily built, persist too
            self._sched_cache: dict[tuple, _PagedSlot] = {}
            (self.p_prefill_fn, self.p_decode_fn, self.p_chunk_fn,
             self.p_copy_fn, self.p_encode_fn) = make_paged_fns(
                cfg, mesh, n_pages=self.pool_pages, page=self.page,
                chunk=chunk_size, cross_pages=self.cross_pages,
                kv_dtype=kv_dtype,
            )
            self.stats = {}
            return
        if chunked:
            # ONE entry point (tf.mixed_step), two ragged shapes: the (B, 1)
            # decode wave advances every decoding row each iteration at the
            # decode rows' OWN kv_live bucket, and each (1, C) slot-chunk
            # call streams a prompt chunk into the shared cache at its own
            # frontier bucket — decode work and prefill work never inflate
            # each other's compiled shapes or compute
            self.mixed1_fn = make_mixed_fn(
                cfg, mesh, batch=batch, cache_len=cache_len, chunk=1
            )
            self.chunk_fn = make_slot_chunk_fn(
                cfg, mesh, batch=batch, cache_len=cache_len, chunk=chunk_size
            )
        else:
            # batch-1 ragged prefill (jit retraces per bucket shape; caches
            # insert at a traced slot index so one compile covers every slot)
            # + batch-wide ragged decode, through the sharded entry points
            self.prefill_fn, _ = make_serve_fns(
                cfg, mesh, batch=1, cache_len=cache_len, ragged=True
            )
            _, self.decode_fn = make_serve_fns(
                cfg, mesh, batch=batch, cache_len=cache_len, ragged=True
            )
            self._insert = jax.jit(
                lambda caches, wave, slot: jax.tree.map(
                    lambda c, w: jax.lax.dynamic_update_slice_in_dim(
                        c, w.astype(c.dtype), slot, axis=1
                    ),
                    caches,
                    wave,
                ),
                donate_argnums=(0,),
            )
        self.stats: dict[str, int] = {}

    # -- per-slot prefill (admission-prefill mode) ------------------------

    def _prefill_one(self, r: Request):
        """Prefill one request (batch=1, right-padded to a bucket); returns
        (first sampled token — a DEVICE scalar, batch-1 cache tree)."""
        ln = len(r.prompt)
        bucket = _next_bucket(ln, self.cache_len)
        toks = np.zeros((1, bucket), np.int32)
        toks[0, :ln] = r.prompt
        b = {"tokens": jnp.asarray(toks)}
        for key, val in r.extras.items():
            b[key] = jnp.asarray(val)[None]
        logits, wave = self.prefill_fn(self.params, b, jnp.asarray([ln], jnp.int32))
        self.stats["prefill_calls"] = self.stats.get("prefill_calls", 0) + 1
        return jnp.argmax(logits[0]).astype(jnp.int32), wave

    def _zero_caches(self):
        specs = tf.cache_specs(self.cfg, self.batch, self.cache_len)
        dt = jnp.dtype(self.cfg.dtype)
        return jax.tree.map(
            lambda s: jnp.zeros(s.shape, dt),
            specs,
            is_leaf=lambda x: isinstance(x, shd.ParamSpec),
        )

    def _validate(self, requests: list[Request]) -> None:
        for r in requests:
            if r.arrival < 0:
                raise ValueError(
                    f"request {r.uid}: negative arrival {r.arrival} — the "
                    "engine clock starts at 0"
                )
            if r.priority not in _PRIORITY_RANK:
                raise ValueError(
                    f"request {r.uid}: unknown priority {r.priority!r} "
                    f"(expected one of {sorted(_PRIORITY_RANK)})"
                )
            if len(r.prompt) < 1:
                raise ValueError(f"request {r.uid}: prompt must be non-empty")
            if len(r.prompt) > self.cache_len:
                raise ValueError(
                    f"request {r.uid}: prompt {len(r.prompt)} > cache_len {self.cache_len}"
                )
            if r.max_new < 1:
                raise ValueError(f"request {r.uid}: max_new must be >= 1")
            # without a ring, decode writes positions L .. L+max_new-2 straight
            # into the cache — past cache_len they would silently clamp
            need = len(r.prompt) + r.max_new - 1
            if not self.cfg.sliding_window and need > self.cache_len:
                raise ValueError(
                    f"request {r.uid}: prompt+max_new needs {need} cache rows "
                    f"> cache_len {self.cache_len}"
                )
            if self.paged:
                if self.ring_tiles is not None:
                    # a ring request holds a FIXED page set to retirement
                    peak = min(self.ring_tiles, -(-need // self.page))
                elif self.chunked or self.cfg.family == "encdec":
                    # encdec admission streams the decoder prompt through
                    # the chunk entry point, so its spans are chunk-sized
                    peak = self._paged_schedule(
                        need, self.chunk_size
                    ).remaining_peak(0)
                else:
                    peak = self._paged_schedule(
                        need, len(r.prompt)
                    ).remaining_peak(0)
                if peak > self.pool_pages:
                    raise ValueError(
                        f"request {r.uid}: needs {peak} resident pages at its "
                        f"peak > pool of {self.pool_pages} — unservable at "
                        "this page budget"
                    )
                if self.cross_pages is not None and "frames" not in r.extras:
                    raise ValueError(
                        f"request {r.uid}: encoder-decoder serving needs "
                        "'frames' extras (the encoder input)"
                    )
            r.generated.clear()
            r.emit_clocks.clear()
            r.ttft = None
            r.preemptions = 0

    # -- engine loops -----------------------------------------------------

    def run(self, requests: list[Request]) -> list[Request]:
        """Serve every request to completion; returns them in input order."""
        self._validate(requests)
        if self.paged:
            if self.chunked:
                return self._run_paged_chunked(requests)
            return self._run_paged_admission(requests)
        if self.chunked:
            return self._run_chunked(requests)
        return self._run_admission(requests)

    # -- paged engine: page pool + per-request tile-granular page tables ----

    def _zero_pools(self):
        # device_put at the MESH shardings (entries.zero_pools): on a mesh
        # with a "pages" axis the page rows land sharded before the first
        # donated entry-point call, instead of committing replicated and
        # resharding on entry
        return zero_pools(
            self.cfg, self.mesh, self.pool_pages, self.page,
            cross_pages=self.cross_pages, kv_dtype=self.kv_dtype,
        )

    def _paged_schedule(
        self, length: int, step_span: int, start_tile: int = 0
    ) -> _PagedSlot:
        """Retention schedule for one request whose written positions span
        ``0..length-1``: per-tile last-reader positions (the union over every
        attention slot's pattern — one page table serves all layers) and the
        max-future-residency curve that backs the reservation discipline.
        ``step_span`` is the engine's largest single advance (chunk size, or
        the whole prompt for a monolithic admission prefill) — tiles
        allocated mid-step widen residency by that much.  ``start_tile > 0``
        prices only the unique suffix of a prefix-cache hit: aliased tiles
        are carried by the radix cache's references, the request allocates
        nothing below its divergence tile."""
        key = (length, step_span, start_tile)
        sc = self._sched_cache.get(key)
        if sc is not None:
            return sc
        spec = self.cfg.attention_spec
        pats = {
            s.attn_pattern or spec.pattern
            for s in self.cfg.period_slots
            if s.mixer == "attn"
        }
        last = sparsity.page_last_reader_union(
            pats, length, spec.q_tile, self.page, pattern_arg=spec.pattern_arg
        )
        res = sparsity.page_residency(
            last, length, self.page, step_span, start_tile
        )
        peak_from = np.maximum.accumulate(res[::-1])[::-1]
        sc = _PagedSlot(last_reader=last, peak_from=peak_from, length=length)
        self._sched_cache[key] = sc
        return sc

    def _ring_schedule(self, length: int) -> _PagedSlot:
        """Retention schedule of a mod-window ring request: a FIXED set of
        ``min(ring_tiles, ceil(length / page))`` pages allocated at admission
        and held to retirement — slots are reused in phase, so no tile ever
        frees early and the reservation is exact by construction."""
        key = ("ring", length)
        sc = self._sched_cache.get(key)
        if sc is None:
            n = min(self.ring_tiles, -(-length // self.page))
            sc = _PagedSlot(
                last_reader=np.full(self.n_vtiles, length - 1, np.int64),
                peak_from=np.full(max(length, 1), n, np.int64),
                length=max(length, 1),
            )
            self._sched_cache[key] = sc
        return sc

    def _committed(self, active, sched, pos) -> int:
        """Sum of active requests' worst-case future residency — admission
        reserves against this so `PagePool.alloc` can never fail mid-stream
        (out-of-pages becomes FIFO backpressure at admission instead)."""
        return sum(
            sched[s].remaining_peak(int(pos[s]))
            for s in range(len(active))
            if active[s] is not None
        )

    def _ensure_writable(self, pool, pt, slot: int, lo_pos: int, hi_pos: int,
                         caches, owner: str = "?"):
        """Back every virtual tile overlapping positions [lo_pos, hi_pos)
        with a page this request may WRITE before the step that writes it:
        unbacked tiles allocate; tiles whose physical page is shared (an
        aliased prefix boundary, or a page the radix cache still owns)
        copy-on-write fork — pool fork + device row copy + table repoint —
        so the divergent write lands in a private copy instead of corrupting
        siblings.  Returns the (possibly copied-into) pools.

        Mod-window rings are a no-op here: the fixed ring pages were all
        allocated at admission, slots are reused in phase, and ring pages are
        never shared — there is nothing to back and nothing to fork."""
        if self.ring_tiles is not None:
            return caches
        for t in range(lo_pos // self.page, (hi_pos - 1) // self.page + 1):
            pid = int(pt[slot, t])
            if pid == self.pool_pages:
                pt[slot, t] = pool.alloc(owner)
            elif pool.page_refs(pid) > 1:
                new = pool.fork(pid, owner)
                caches = self.p_copy_fn(caches, jnp.int32(pid), jnp.int32(new))
                pt[slot, t] = new
        return caches

    def _free_dead(self, pool, pt, slot: int, sc: _PagedSlot, frontier: int,
                   owner: str | None = None):
        """Release pages whose last possible reader is behind the request's
        next query position — dense-causal never frees until retirement,
        window frees the out-of-window tail, butterfly frees every tile its
        remaining O(log n) stride pairs can no longer touch."""
        nt = len(sc.last_reader)
        for t in range(nt):
            if pt[slot, t] != self.pool_pages and sc.last_reader[t] < frontier:
                pool.release(int(pt[slot, t]), owner)
                pt[slot, t] = self.pool_pages

    def _free_all(self, pool, pt, slot: int, owner: str | None = None):
        for t in range(pt.shape[1]):
            if pt[slot, t] != self.pool_pages:
                pool.release(int(pt[slot, t]), owner)
                pt[slot, t] = self.pool_pages

    # -- prefix cache (radix tree over the page pool) ---------------------

    def _prefill_flop_count(self, pos0: int, t: int) -> float:
        """Analytic admission-side prefill work for ``t`` prompt tokens
        entering at absolute position ``pos0``: linear stack FLOPs plus the
        exact causal attention term.  This is what the --check-prefix gate
        compares — prefix hits skip the matched positions entirely, so the
        number scales with unique suffixes, not requests."""
        cfg = self.cfg
        n_attn = sum(
            1 for s in cfg.period_slots if s.mixer == "attn"
        ) * cfg.n_periods
        attn = 4.0 * cfg.n_heads * cfg.head_dim * n_attn * (
            t * pos0 + t * (t + 1) / 2.0
        )
        return t * M.model_flops_per_token(cfg, 1, mode="fwd") + attn

    def _match_prefix(self, prompt: np.ndarray) -> tuple[int, list[int]]:
        """Longest-prefix match at admission.  Caps the match at plen-1 (the
        last prompt token must run to produce first-token logits) and skips
        sub-page matches (no page to alias).  The caller must retain the
        returned pages before anything else can evict them.  ``prompt`` is
        the EFFECTIVE prompt: for a preempted request being resumed it is
        the original prompt plus every token already emitted, so the warm
        resume frontier is wherever the radix tree still covers it."""
        if self.radix is None:
            return 0, []
        plen = len(prompt)
        m, pages = self.radix.match(np.asarray(prompt, np.int32), plen - 1)
        if m < self.page:
            return 0, []
        return m, pages

    def _fits(self, need: int) -> int:
        """Reservation check against the pool, counting the radix cache's
        held pages; under pressure, LRU-evicts unreferenced cached prefixes.
        Returns the residual gap (<= 0 means the reservation fits)."""
        held = self.radix.held_pages if self.radix is not None else 0
        gap = need + held - self.pool_pages
        if gap > 0 and self.radix is not None:
            self.radix.evict(gap)
            gap = need + self.radix.held_pages - self.pool_pages
        return gap

    def _cache_pages(self, tokens: np.ndarray, pt, slot: int) -> None:
        """Hand ``tokens``' full, still-resident pages to the radix cache
        (shared ownership) — called on prompt completion AND on preemption,
        where ``tokens`` is the victim's written prefix so resume becomes a
        warm hit.  Retention may already have freed mid-prompt tiles
        (butterfly streams past them) — only the contiguous resident run
        from tile 0 is cacheable."""
        if self.radix is None:
            return
        k = len(tokens) // self.page
        run = 0
        while run < k and pt[slot, run] != self.pool_pages:
            run += 1
        if run:
            self.radix.insert(
                np.asarray(tokens[: run * self.page], np.int32),
                [int(pt[slot, t]) for t in range(run)],
            )

    def _suffix_prefill(self, prompt: np.ndarray, m: int, sc: _PagedSlot,
                        pool, pt, slot: int, caches, ct=None,
                        owner: str = "?"):
        """Admission-mode prefill of a prefix-cache hit: stream ONLY the
        unique suffix (positions m..plen-1) through the paged chunk entry
        point — prefill starts at the divergence frontier, attending the
        aliased prefix pages through the page table.  The first chunk
        CoW-forks the partially-shared boundary tile.  Dead tiles free
        between chunks (the unique-suffix reservation is priced at
        chunk-size spans, so the stream must keep that schedule).  Returns
        (first sampled token — device scalar, pools)."""
        C = self.chunk_size
        plen = len(prompt)
        p = m
        logits1 = None
        while p < plen:
            t = min(C, plen - p)
            caches = self._ensure_writable(pool, pt, slot, p, p + t, caches,
                                           owner)
            ctoks = np.zeros((1, C), np.int32)
            ctoks[0, :t] = prompt[p : p + t]
            kv_live = _next_bucket(p + t, self.cache_len)
            logits1, caches = self.p_chunk_fn(
                self.params, caches, jnp.asarray(ctoks),
                jnp.asarray(pt[slot : slot + 1]), jnp.int32(p), jnp.int32(t),
                kv_live, ct=ct,
            )
            self.stats["chunk_calls"] = self.stats.get("chunk_calls", 0) + 1
            self.stats["prefill_tokens"] += t
            self.stats["prefill_flops"] += self._prefill_flop_count(p, t)
            p += t
            self._free_dead(pool, pt, slot, sc, p, owner)
        return jnp.argmax(logits1).astype(jnp.int32), caches

    def _cross_admit(self, r: Request, slot: int, ct, caches):
        """Admit the request's ENCODER side: key the frames, alias the cached
        read-only page range on a hit (a ``retain`` per page — CoW can never
        trigger because decode never writes a cross page), or allocate a
        fresh range and run the encoder once on a miss.  Returns the updated
        pools, or ``None`` when the cross pool cannot fit a new range even
        after evicting every unreferenced cached encoder (backpressure)."""
        frames = np.asarray(r.extras["frames"], np.float32)
        key = frames.tobytes()
        pages = self._cross_cache.get(key)
        if pages is not None:
            self._cross_cache.move_to_end(key)  # LRU touch
            for p in pages:
                self.cross_pool.retain(p, owner=f"req{r.uid}")
            ct[slot, : len(pages)] = pages
            self.stats["prefix_hits"] += 1
            self.stats["prefix_hit_tokens"] += self.cfg.enc_seq
            self.stats["encoder_hits"] = self.stats.get("encoder_hits", 0) + 1
            return caches
        n = self.cross_tiles
        if self.cross_pool.free_pages < n:
            # evict LRU cached encoders nobody references but the cache
            for k in [
                k for k in self._cross_cache
                if all(
                    self.cross_pool.page_refs(p) == 1
                    for p in self._cross_cache[k]
                )
            ]:
                for p in self._cross_cache.pop(k):
                    self.cross_pool.release(p, owner="encoder-cache")
                if self.cross_pool.free_pages >= n:
                    break
        if self.cross_pool.free_pages < n:
            return None
        pages = [self.cross_pool.alloc("encoder-cache") for _ in range(n)]
        ct[slot, :n] = pages
        caches = self.p_encode_fn(
            self.params, caches, jnp.asarray(frames)[None],
            jnp.asarray(ct[slot : slot + 1]),
        )
        for p in pages:  # the request's own reference; alloc's is the cache's
            self.cross_pool.retain(p, owner=f"req{r.uid}")
        self._cross_cache[key] = pages
        self.stats["encode_calls"] = self.stats.get("encode_calls", 0) + 1
        return caches

    def _release_cross(self, ct, slot: int, owner: str | None = None) -> None:
        """Drop the request's references on its aliased cross page range."""
        for t in range(ct.shape[1]):
            if ct[slot, t] != self.cross_pages:
                self.cross_pool.release(int(ct[slot, t]), owner)
                ct[slot, t] = self.cross_pages

    # -- priority scheduling, preemption, SLO accounting ------------------

    @staticmethod
    def _eff_prompt(r: Request) -> np.ndarray:
        """The EFFECTIVE prompt of an admission: the original prompt plus
        every already-emitted token — non-empty ``generated`` only for a
        preempted request being resumed.  Greedy sampling makes the resume
        token-identical: re-prefilling the written prefix reconstructs the
        exact cache the victim lost (warm via the radix tree where its
        pages survived, cold recompute otherwise), and the next sampled
        token follows deterministically."""
        if not r.generated:
            return np.asarray(r.prompt, np.int32)
        return np.concatenate(
            [np.asarray(r.prompt, np.int32),
             np.asarray(r.generated, np.int32)]
        )

    def _stamp_emits(self, sinks: list[tuple[Request, int]],
                     clock: int) -> None:
        """Record the emission clock of every token pushed this step — the
        raw series per-request TTFT / inter-token latency aggregate from."""
        for r, _ in sinks:
            if r.ttft is None:
                r.ttft = clock - r.arrival
            r.emit_clocks.append(clock)

    def _finalize_slo(self, requests: list[Request],
                      q: _AdmitQueue) -> None:
        """End-of-run latency aggregation: p50/p99 TTFT and mean inter-token
        latency per priority class (engine-step clock units), the
        SLO-attainment fraction (1.0 when no SLO is configured), and the
        scheduler counters every loop shares."""
        per: dict[str, dict[str, list[float]]] = {}
        attained: list[bool] = []
        for r in requests:
            if not r.emit_clocks:
                continue
            t = float(r.ttft)
            gaps = np.diff(np.asarray(r.emit_clocks))
            itl = float(gaps.mean()) if len(gaps) else 0.0
            d = per.setdefault(r.priority, {"ttft": [], "itl": []})
            d["ttft"].append(t)
            d["itl"].append(itl)
            ok = True
            if self.slo_ttft is not None and t > self.slo_ttft:
                ok = False
            if self.slo_itl is not None and itl > self.slo_itl:
                ok = False
            attained.append(ok)
        slo = {}
        for prio in sorted(per):
            ts = np.asarray(per[prio]["ttft"])
            its = np.asarray(per[prio]["itl"])
            slo[prio] = {
                "n": int(len(ts)),
                "ttft_p50": float(np.percentile(ts, 50)),
                "ttft_p99": float(np.percentile(ts, 99)),
                "itl_p50": float(np.percentile(its, 50)),
                "itl_p99": float(np.percentile(its, 99)),
            }
        self.stats["slo"] = slo
        self.stats["slo_attainment"] = (
            float(np.mean(attained)) if attained else 1.0
        )
        self.stats["aging_promotions"] = q.promotions
        self.stats["starved_requests"] = sum(
            1 for r in requests if not r.emit_clocks
        )
        self.stats.setdefault("preemptions", 0)

    def _budget_draw(self, r: Request, rem_prompt: int, budget: int) -> int:
        """How many prompt tokens slot ``r`` may stream this step.
        Preemption-aware: a resumed victim is re-running prefill work the
        engine already paid for once, so its draw is capped at a
        ``resume_chunk_frac`` share of the step budget — fresh interactive
        admissions keep their first-token latency while the victim catches
        up (``resume_budget_capped`` counts the chunks the cap shrank)."""
        t = min(self.chunk_size, rem_prompt, budget)
        if r.preemptions > 0:
            cap = max(1, int(self.chunk_budget * self.resume_chunk_frac))
            if t > cap:
                t = cap
                self.stats["resume_budget_capped"] = (
                    self.stats.get("resume_budget_capped", 0) + 1
                )
        return t

    def _slot_owner(self, r: Request) -> str:
        """Owner label of a victim's pool references — the disaggregated
        router phase-qualifies it ("decode:reqN"), the single loop does not."""
        return f"req{r.uid}"

    def _preempt_slot(self, s: int, q: _AdmitQueue, fetch, pool, pt,
                      active, sched, parr, pos) -> None:
        """Evict the request in slot ``s``: flush the async token fetch (the
        snapshot must hold every emitted token), donate its written prefix's
        full resident pages to the radix tree (so resume is a warm hit),
        release its pool pages, and requeue it at its ORIGINAL arrival so
        its age — and any aging promotion — keeps accruing."""
        fetch.flush()
        r = active[s]
        written = self._eff_prompt(r)[: int(pos[s])]
        self._cache_pages(written, pt, s)
        self._free_all(pool, pt, s, owner=self._slot_owner(r))
        r.preemptions += 1
        self.stats["preemptions"] = self.stats.get("preemptions", 0) + 1
        active[s] = None
        sched[s] = None
        if parr is not None:
            parr[s] = None
        q.push(r)

    def _preempt_until(self, need, rank: int, q: _AdmitQueue, fetch, pool,
                       pt, active, sched, parr, pos, admit_pos,
                       admit_seq) -> int:
        """Preempt youngest lowest-priority victims until the reservation
        gap ``self._fits(need())`` closes or no eligible victim remains;
        returns the final gap (<= 0 means the admission fits).  A victim
        must hold a strictly worse RAW priority rank than the admitting
        request (aging changes admission order, never preemption power), be
        under the per-request preemption cap, and have advanced at least
        ``preempt_min_progress`` positions since its own admission — the
        cap bounds total evictions and the progress floor bounds wasted
        work, so preempt/resume cannot livelock."""
        gap = self._fits(need())
        while gap > 0:
            victim, vkey = None, None
            for s in range(len(active)):
                a = active[s]
                if a is None:
                    continue
                if _PRIORITY_RANK[a.priority] <= rank:
                    continue
                if a.preemptions >= self.max_preemptions:
                    continue
                if int(pos[s]) - int(admit_pos[s]) < self.preempt_min_progress:
                    continue
                key = (_PRIORITY_RANK[a.priority], int(a.arrival),
                       int(admit_seq[s]))
                if victim is None or key > vkey:
                    victim, vkey = s, key
            if victim is None:
                break
            self._preempt_slot(victim, q, fetch, pool, pt, active, sched,
                               parr, pos)
            gap = self._fits(need())
        return gap

    def close(self) -> None:
        """Release the engine-held cache state (radix tree references, cached
        encoder cross ranges) and check the pools drain to zero.  The pools
        and the prefix caches PERSIST across ``run()`` calls — a warm second
        run alias-hits the first run's prompts — so the end-of-run drain
        assertion of the per-run engines lives here instead.

        Idempotent: a second ``close()`` after a CLEAN first one is a no-op.
        A close that raised (leak detected) stays re-runnable so a caller
        can release the stragglers and verify the drain; the leak error
        names the holders (:meth:`PagePool.holders` labels) so the bug site
        is attributable without a refcount bisect."""
        if self._closed or not self.paged:
            self._closed = True
            return
        if self.radix is not None:
            self.radix.clear()
        if self.cross_pages is not None:
            for pages in self._cross_cache.values():
                for p in pages:
                    self.cross_pool.release(p, owner="encoder-cache")
            self._cross_cache.clear()
            self.cross_pool.close(
                context="after close() released the encoder cache"
            )
        self.pool.close(context="after close() released the radix tree")
        self._closed = True

    def __enter__(self) -> "ServeLoop":
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        if exc_type is not None:
            # an exception is already propagating: close best-effort, but a
            # leak (requests mid-flight when the body raised) must not mask
            # the original error
            try:
                self.close()
            except RuntimeError:
                pass
            return False
        self.close()
        return False

    def _finish_paged_run(self, pool) -> None:
        """End-of-run bookkeeping shared by both paged loops: surface the
        pool and prefix-cache counters.  Requests have released all their
        references by now; what remains in ``in_use`` is exactly the engine-
        held cache state (radix tree + encoder cross ranges), which persists
        for the next run and drains in :meth:`close`."""
        self.stats["pool_pages"] = self.pool_pages
        self.stats["pool_peak_pages"] = pool.peak_in_use
        self.stats["page_allocs"] = pool.alloc_count
        self.stats["cow_forks"] = pool.fork_count
        if pool.n_shards > 1:
            self.stats["pool_shards"] = pool.n_shards
            self.stats["shard_peak_pages"] = list(pool.shard_peak_in_use)
        if self.radix is not None:
            self.stats["prefix_cached_pages_end"] = self.radix.held_pages
            self.stats["prefix_inserted_pages"] = self.radix.inserted_pages
            self.stats["prefix_evicted_pages"] = self.radix.evicted_pages
        if self.cross_pages is not None:
            self.stats.setdefault("encode_calls", 0)
            self.stats["cross_pool_pages"] = self.cross_pages
            self.stats["cross_pool_peak_pages"] = self.cross_pool.peak_in_use
            self.stats["cross_cached_ranges_end"] = len(self._cross_cache)

    def _run_admission(self, requests: list[Request]) -> list[Request]:
        """Admission-prefill engine: per-slot prefill + cache insert, then
        ragged decode steps; finished requests retire immediately and free
        their slot — but every admission stalls all live decode slots for
        one blocking batch-1 prefill (counted in ``admission_stall_steps``).
        """
        q = _AdmitQueue(requests, self.aging_steps, self.fifo)
        active: list[Request | None] = [None] * self.batch
        pos = np.zeros(self.batch, np.int32)  # next write position per slot
        remaining = np.zeros(self.batch, np.int32)  # decode tokens still owed
        nxt = jnp.zeros((self.batch,), jnp.int32)  # device feedback tokens
        fetch = _AsyncTokens(lag=1)
        self.stats = {
            "prefill_calls": 0, "decode_steps": 0, "admission_stall_steps": 0,
        }
        clock = 0  # admission clock: decode steps + idle ticks (arrivals)
        with self.mesh:
            caches = self._zero_caches()
            while len(q) or any(r is not None for r in active):
                # admit: fill free slots (waves only, under static batching)
                may_admit = not self.static_batching or all(
                    r is None for r in active
                )
                if may_admit:
                    for slot in range(self.batch):
                        if active[slot] is not None:
                            continue
                        r = q.peek(clock)
                        if r is None:
                            break  # nothing in the queue has arrived yet
                        q.pop(r, clock)
                        if any(a is not None for a in active):
                            # live decode slots idle for this whole prefill —
                            # the stall the chunked engine exists to remove
                            self.stats["admission_stall_steps"] += 1
                        tok, wave = self._prefill_one(r)
                        self._stamp_emits([(r, 0)], clock)
                        fetch.push(tok, [(r, 0)])
                        if r.max_new <= 1:
                            continue  # done at prefill; slot stays free
                        caches = self._insert(caches, wave, jnp.int32(slot))
                        active[slot] = r
                        pos[slot] = len(r.prompt)
                        remaining[slot] = r.max_new - 1
                        nxt = nxt.at[slot].set(tok)
                if not any(r is not None for r in active):
                    clock += 1  # idle tick: waiting on arrivals
                    continue
                # one ragged decode step for the whole batch; attention
                # streams only the live cache prefix (bucketed so each bucket
                # compiles once) — a short wave on a deep cache reads its own
                # tiles, not the padded cache.  Ring caches keep their own
                # mod-window layout and stream the whole (window-sized) ring.
                kv_live = None
                if not self.cfg.sliding_window:
                    hot = max(int(pos[s]) for s in range(self.batch)
                              if active[s] is not None) + 1
                    kv_live = _next_bucket(hot, self.cache_len)
                    self.stats["decode_kv_live_max"] = max(
                        self.stats.get("decode_kv_live_max", 0), kv_live
                    )
                logits, caches = self.decode_fn(
                    self.params, caches, nxt[:, None], jnp.asarray(pos), kv_live,
                )
                self.stats["decode_steps"] += 1
                clock += 1
                toks = jnp.argmax(logits, -1).astype(jnp.int32)
                sinks = []
                for slot in range(self.batch):
                    r = active[slot]
                    if r is None:
                        continue
                    sinks.append((r, slot))
                    pos[slot] += 1
                    remaining[slot] -= 1
                    if remaining[slot] <= 0:
                        active[slot] = None  # evict: slot frees for the queue
                self._stamp_emits(sinks, clock)
                fetch.push(toks, sinks)
                nxt = toks
        fetch.flush()
        self._finalize_slo(requests, q)
        return requests

    def _run_chunked(self, requests: list[Request]) -> list[Request]:
        """Mixed-step engine: every iteration advances ALL slots — one (B, 1)
        decode wave samples every decoding row, then each mid-prompt row
        streams one chunk into the shared cache through a (1, C) slot-chunk
        call — so a long admission never stalls the batch, and decode steps
        stay bucketed at the decode rows' own live-cache depth while the
        prompt streams at its own."""
        B, C = self.batch, self.chunk_size
        q = _AdmitQueue(requests, self.aging_steps, self.fifo)
        active: list[Request | None] = [None] * B
        pos = np.zeros(B, np.int32)  # next cache write position per slot
        consumed = np.zeros(B, np.int32)  # prompt tokens consumed per slot
        remaining = np.zeros(B, np.int32)  # decode tokens still owed
        nxt = jnp.zeros((B,), jnp.int32)  # device feedback tokens
        zeros_b1 = jnp.zeros((B, 1), jnp.int32)
        fetch = _AsyncTokens(lag=1)
        self.stats = {
            "prefill_calls": 0, "mixed_steps": 0, "chunk_calls": 0,
            "decode_steps": 0, "prefill_tokens": 0, "decode_tokens": 0,
            "decode_stall_steps": 0, "overlap_steps": 0,
        }
        clock = 0
        rr = 0  # round-robin offset: fair prefill budget across slots
        with self.mesh:
            caches = self._zero_caches()
            while len(q) or any(r is not None for r in active):
                # admission is free: a freed slot starts consuming the next
                # arrived request's chunks on the very next mixed step
                for slot in range(B):
                    if active[slot] is not None:
                        continue
                    r = q.peek(clock)
                    if r is None:
                        break  # nothing in the queue has arrived yet
                    q.pop(r, clock)
                    active[slot] = r
                    pos[slot] = 0
                    consumed[slot] = 0
                    remaining[slot] = r.max_new
                if not any(r is not None for r in active):
                    clock += 1  # idle tick: waiting on arrivals
                    continue
                # schedule: decode rows always advance; prompt rows split the
                # per-step chunk budget under a round-robin rotation
                eligible = [
                    s for s in range(B)
                    if active[s] is not None
                    and len(active[s].prompt) - consumed[s] <= 0
                ]
                use_nxt = np.zeros(B, bool)
                chunk_t = np.zeros(B, np.int32)
                budget = self.chunk_budget
                # interactive rows split the chunk budget ahead of batch
                # rows; the rotation keeps it fair within a class (and IS
                # the whole order under uniform priority / fifo scheduling)
                order = sorted(
                    range(B),
                    key=lambda s: (
                        0 if self.fifo or active[s] is None
                        else _PRIORITY_RANK[active[s].priority],
                        (s - rr) % B,
                    ),
                )
                for slot in order:
                    r = active[slot]
                    if r is None:
                        continue
                    rem_prompt = len(r.prompt) - consumed[slot]
                    if rem_prompt > 0:
                        t = min(C, rem_prompt, budget)
                        if t <= 0:
                            continue  # budget-starved this step; retries next
                        chunk_t[slot] = t
                        budget -= t
                    else:
                        use_nxt[slot] = True  # decode rows: never budget-gated
                rr = (rr + 1) % B
                clock += 1
                self.stats["mixed_steps"] += 1
                dec_rows = [s for s in range(B) if use_nxt[s]]
                chunk_rows = [s for s in range(B) if chunk_t[s] > 0]
                if any(s not in dec_rows for s in eligible):
                    # observational, not definitional: trips if a scheduler
                    # change ever gates a decode-eligible row (e.g. on the
                    # chunk budget) — the CI gate asserts this stays 0
                    self.stats["decode_stall_steps"] += 1
                if dec_rows and chunk_rows:
                    self.stats["overlap_steps"] += 1  # the §V-A overlap
                # (a) decode wave — mixed_step at (B, 1), bucketed by the
                # decode rows' own frontier (a short request decoding next to
                # a 4k prompt mid-prefill still reads a shallow cache)
                if dec_rows:
                    ntok_a = np.where(use_nxt, 1, 0).astype(np.int32)
                    hot = max(int(pos[s]) + 1 for s in dec_rows)
                    kv_live = _next_bucket(hot, self.cache_len)
                    self.stats["decode_kv_live_max"] = max(
                        self.stats.get("decode_kv_live_max", 0), kv_live
                    )
                    logits, caches = self.mixed1_fn(
                        self.params, caches, zeros_b1, nxt,
                        jnp.asarray(use_nxt), jnp.asarray(pos),
                        jnp.asarray(ntok_a), kv_live,
                    )
                    toks = jnp.argmax(logits, -1).astype(jnp.int32)
                    self.stats["decode_steps"] += 1
                    self.stats["decode_tokens"] += len(dec_rows)
                    sinks = []
                    for slot in dec_rows:
                        r = active[slot]
                        sinks.append((r, slot))
                        pos[slot] += 1
                        remaining[slot] -= 1
                        if remaining[slot] <= 0:
                            active[slot] = None
                    self._stamp_emits(sinks, clock)
                    fetch.push(toks, sinks)
                    nxt = jnp.where(jnp.asarray(use_nxt), toks, nxt)
                # (b) prompt chunks — mixed_step at (1, C) per mid-prompt
                # row, streaming into the slot's rows of the shared cache at
                # the prompt's own frontier bucket
                for slot in chunk_rows:
                    r = active[slot]
                    t = int(chunk_t[slot])
                    ctoks = np.zeros((1, C), np.int32)
                    ctoks[0, :t] = r.prompt[consumed[slot] : consumed[slot] + t]
                    kv_live = _next_bucket(int(pos[slot]) + t, self.cache_len)
                    logits1, caches = self.chunk_fn(
                        self.params, caches, jnp.asarray(ctoks),
                        jnp.int32(slot), jnp.int32(pos[slot]), jnp.int32(t),
                        kv_live,
                    )
                    self.stats["chunk_calls"] += 1
                    self.stats["prefill_tokens"] += t
                    pos[slot] += t
                    consumed[slot] += t
                    if consumed[slot] == len(r.prompt):
                        # the chunk that finishes the prompt samples the
                        # first generated token (logits at ntok-1)
                        tok1 = jnp.argmax(logits1).astype(jnp.int32)
                        self._stamp_emits([(r, 0)], clock)
                        fetch.push(tok1, [(r, 0)])
                        nxt = nxt.at[slot].set(tok1)
                        remaining[slot] -= 1
                        if remaining[slot] <= 0:
                            active[slot] = None
        fetch.flush()
        self._finalize_slo(requests, q)
        return requests

    def _run_paged_admission(self, requests: list[Request]) -> list[Request]:
        """Admission-by-pages engine: per-request batch-1 prefill scattered
        straight into the page pool through the request's page-table row,
        then ragged paged decode waves.  A free SLOT no longer suffices for
        admission — the request must also reserve its worst-case resident
        page count; otherwise it backpressures in FIFO order until decode
        frees pages.  Resident HBM is the pool, not batch x cache_len.

        With the radix prefix cache on, admission first longest-prefix
        matches the prompt: a hit aliases the cached pages into the page
        table, reserves only the unique-suffix peak, and prefills JUST the
        suffix from the divergence frontier (via the chunk entry point)."""
        B = self.batch
        q = _AdmitQueue(requests, self.aging_steps, self.fifo)
        active: list[Request | None] = [None] * B
        sched: list[_PagedSlot | None] = [None] * B
        pos = np.zeros(B, np.int32)
        remaining = np.zeros(B, np.int32)
        admit_pos = np.zeros(B, np.int32)  # pos at admission: progress floor
        admit_seq = np.zeros(B, np.int64)  # admission order: victim tiebreak
        aseq = 0
        nxt = jnp.zeros((B,), jnp.int32)
        pt = np.full((B, self.n_vtiles), self.pool_pages, np.int32)
        pool = self.pool
        ct = None
        if self.cross_pages is not None:
            ct = np.full((B, self.cross_tiles), self.cross_pages, np.int32)
        fetch = _AsyncTokens(lag=1)
        self.stats = {
            "prefill_calls": 0, "decode_steps": 0, "admission_stall_steps": 0,
            "admission_backpressure": 0, "max_concurrent": 0,
            "prefill_tokens": 0, "prefill_flops": 0.0,
            "prefix_hits": 0, "prefix_hit_tokens": 0,
            "preemptions": 0, "resumes": 0, "resume_warm_hits": 0,
        }
        clock = 0
        with self.mesh:
            caches = (
                self._pools if self._pools is not None else self._zero_pools()
            )
            while len(q) or any(r is not None for r in active):
                for slot in range(B):
                    if active[slot] is not None:
                        continue
                    r = q.peek(clock)
                    if r is None:
                        break  # nothing in the queue has arrived yet
                    pr = self._eff_prompt(r)  # prompt + resumed tokens
                    plen = len(pr)
                    mn = r.max_new - len(r.generated)
                    L = plen + mn - 1  # == original prompt + max_new - 1
                    own = f"req{r.uid}"
                    rank = _PRIORITY_RANK[r.priority]
                    # prefix hit: alias cached pages, reserve the unique
                    # suffix only; fall back to a cold admission if even
                    # that reservation cannot fit (after preempting any
                    # eligible lower-priority victims)
                    m, spages = self._match_prefix(pr)
                    if m:
                        for p in spages:
                            pool.retain(p, owner=own)
                        sc = self._paged_schedule(
                            L, step_span=self.chunk_size,
                            start_tile=m // self.page,
                        )
                        need = lambda: (
                            self._committed(active, sched, pos)
                            + sc.remaining_peak(m)
                        )
                        gap = self._fits(need())
                        if gap > 0 and self.preemptible:
                            gap = self._preempt_until(
                                need, rank, q, fetch, pool, pt, active,
                                sched, None, pos, admit_pos, admit_seq,
                            )
                        if gap > 0:
                            for p in spages:
                                pool.release(p, owner=own)
                            cold_peak = self._paged_schedule(
                                L, step_span=(
                                    self.chunk_size
                                    if self.cross_pages is not None else plen
                                ),
                            ).remaining_peak(0)
                            if cold_peak < sc.remaining_peak(m):
                                # cold genuinely cheaper (retention frees
                                # tiles the alias would pin): retry cold
                                m, spages = 0, []
                            else:
                                # cold could not fit either — and its _fits
                                # would evict the very prefix (a preemption
                                # victim's donated pages) that makes the
                                # eventual resume warm
                                self.stats["admission_backpressure"] += 1
                                break
                    if not m:
                        if self.ring_tiles is not None:
                            sc = self._ring_schedule(L)
                        elif self.cross_pages is not None:
                            # encdec streams the decoder prompt through the
                            # chunk entry point — spans are chunk-sized
                            sc = self._paged_schedule(
                                L, step_span=self.chunk_size
                            )
                        else:
                            sc = self._paged_schedule(L, step_span=plen)
                        need = lambda: (
                            self._committed(active, sched, pos)
                            + sc.remaining_peak(0)
                        )
                        gap = self._fits(need())
                        if gap > 0 and self.preemptible:
                            gap = self._preempt_until(
                                need, rank, q, fetch, pool, pt, active,
                                sched, None, pos, admit_pos, admit_seq,
                            )
                        if gap > 0:
                            # out of pages: the head waits for decode to free
                            # some — backpressure, not an error
                            self.stats["admission_backpressure"] += 1
                            break
                    if self.cross_pages is not None:
                        nc = self._cross_admit(r, slot, ct, caches)
                        if nc is None:
                            # no cross range free for a new encoder input
                            self.stats["admission_backpressure"] += 1
                            break
                        caches = nc
                    q.pop(r, clock)
                    if r.preemptions:  # a victim re-admitting (possibly
                        self.stats["resumes"] += 1  # mid-prefill, no tokens)
                        if m:
                            self.stats["resume_warm_hits"] += 1
                    if any(a is not None for a in active):
                        self.stats["admission_stall_steps"] += 1
                    ct_row = (
                        None if ct is None else jnp.asarray(ct[slot:slot + 1])
                    )
                    if m:
                        for i, p in enumerate(spages):
                            pt[slot, i] = p
                        self.stats["prefix_hits"] += 1
                        self.stats["prefix_hit_tokens"] += m
                        tok, caches = self._suffix_prefill(
                            pr, m, sc, pool, pt, slot, caches, owner=own
                        )
                    elif self.ring_tiles is not None or ct is not None:
                        # mod-window rings allocate their fixed page set up
                        # front; both rings and encoder-decoder admissions
                        # then STREAM the prompt through the chunk entry
                        # point (a monolithic paged prefill would wrap the
                        # ring / has no cross-table path)
                        if self.ring_tiles is not None:
                            for t in range(
                                min(self.ring_tiles, -(-L // self.page))
                            ):
                                pt[slot, t] = pool.alloc(own)
                        tok, caches = self._suffix_prefill(
                            pr, 0, sc, pool, pt, slot, caches, ct=ct_row,
                            owner=own,
                        )
                    else:
                        caches = self._ensure_writable(
                            pool, pt, slot, 0, plen, caches, own
                        )
                        bucket = _next_bucket(plen, self.cache_len)
                        toks = np.zeros((1, bucket), np.int32)
                        toks[0, :plen] = pr
                        logits, caches = self.p_prefill_fn(
                            self.params, caches, {"tokens": jnp.asarray(toks)},
                            jnp.asarray([plen], jnp.int32),
                            jnp.asarray(pt[slot : slot + 1]),
                        )
                        self.stats["prefill_calls"] += 1
                        self.stats["prefill_tokens"] += plen
                        self.stats["prefill_flops"] += (
                            self._prefill_flop_count(0, plen)
                        )
                        tok = jnp.argmax(logits[0]).astype(jnp.int32)
                    self._stamp_emits([(r, 0)], clock)
                    fetch.push(tok, [(r, 0)])
                    self._cache_pages(pr, pt, slot)
                    if mn <= 1:
                        self._free_all(pool, pt, slot, own)
                        if ct is not None:
                            self._release_cross(ct, slot, own)
                        continue  # done at prefill; slot and pages free
                    self._free_dead(pool, pt, slot, sc, plen, own)
                    active[slot] = r
                    sched[slot] = sc
                    pos[slot] = plen
                    admit_pos[slot] = plen
                    admit_seq[slot] = aseq
                    aseq += 1
                    remaining[slot] = mn - 1
                    nxt = nxt.at[slot].set(tok)
                self.stats["max_concurrent"] = max(
                    self.stats["max_concurrent"],
                    sum(a is not None for a in active),
                )
                if not any(r is not None for r in active):
                    clock += 1
                    continue
                # ragged paged decode wave: back each row's write tile (CoW-
                # forking a still-shared boundary tile), then every row
                # streams its own live pages through its page-table row at
                # the bucketed virtual depth
                for slot in range(B):
                    if active[slot] is not None:
                        caches = self._ensure_writable(
                            pool, pt, slot, int(pos[slot]),
                            int(pos[slot]) + 1, caches,
                            f"req{active[slot].uid}",
                        )
                if self.ring_tiles is not None:
                    # the ring streams its fixed window-sized page set and
                    # positions are unbounded — no live-depth bucketing
                    kv_live = None
                else:
                    hot = max(int(pos[s]) for s in range(B)
                              if active[s] is not None) + 1
                    kv_live = _next_bucket(hot, self.cache_len)
                    self.stats["decode_kv_live_max"] = max(
                        self.stats.get("decode_kv_live_max", 0), kv_live
                    )
                logits, caches = self.p_decode_fn(
                    self.params, caches, nxt[:, None], jnp.asarray(pos),
                    jnp.asarray(pt), kv_live,
                    **({} if ct is None else {"ct": jnp.asarray(ct)}),
                )
                self.stats["decode_steps"] += 1
                clock += 1
                toks = jnp.argmax(logits, -1).astype(jnp.int32)
                sinks = []
                for slot in range(B):
                    r = active[slot]
                    if r is None:
                        continue
                    sinks.append((r, slot))
                    pos[slot] += 1
                    remaining[slot] -= 1
                    if remaining[slot] <= 0:
                        self._free_all(pool, pt, slot, f"req{r.uid}")
                        if ct is not None:
                            self._release_cross(ct, slot, f"req{r.uid}")
                        active[slot] = None
                        sched[slot] = None
                    else:
                        self._free_dead(
                            pool, pt, slot, sched[slot], int(pos[slot]),
                            f"req{r.uid}",
                        )
                self._stamp_emits(sinks, clock)
                fetch.push(toks, sinks)
                nxt = toks
        fetch.flush()
        self._pools = caches
        self._finish_paged_run(pool)
        self._finalize_slo(requests, q)
        return requests

    def _run_paged_chunked(self, requests: list[Request]) -> list[Request]:
        """Mixed-step engine over the page pool: the decode wave and the
        per-row prompt chunks of the chunked scheduler, with cache writes and
        reads indirected through per-request page tables.  Pages allocate
        lazily at each row's write frontier and free as soon as the
        retention schedule says no future query can read them — a butterfly
        prompt releases most of its tiles WHILE it streams in, which is the
        capacity win the paged_capacity benchmark measures.

        A radix prefix-cache hit admits at the divergence frontier: the
        matched pages alias into the slot's page table, ``pos``/``consumed``
        start at the matched length, and the reservation covers only the
        unique suffix — chunk streaming then picks up mid-prompt exactly as
        if the prefix had already streamed."""
        B, C = self.batch, self.chunk_size
        q = _AdmitQueue(requests, self.aging_steps, self.fifo)
        active: list[Request | None] = [None] * B
        sched: list[_PagedSlot | None] = [None] * B
        parr: list[np.ndarray | None] = [None] * B  # effective prompt per slot
        pos = np.zeros(B, np.int32)
        consumed = np.zeros(B, np.int32)
        remaining = np.zeros(B, np.int32)
        admit_pos = np.zeros(B, np.int32)  # pos at admission: progress floor
        admit_seq = np.zeros(B, np.int64)  # admission order: victim tiebreak
        aseq = 0
        nxt = jnp.zeros((B,), jnp.int32)
        pt = np.full((B, self.n_vtiles), self.pool_pages, np.int32)
        pool = self.pool
        ct = None
        if self.cross_pages is not None:
            ct = np.full((B, self.cross_tiles), self.cross_pages, np.int32)
        fetch = _AsyncTokens(lag=1)
        self.stats = {
            "prefill_calls": 0, "mixed_steps": 0, "chunk_calls": 0,
            "decode_steps": 0, "prefill_tokens": 0, "decode_tokens": 0,
            "decode_stall_steps": 0, "overlap_steps": 0,
            "admission_backpressure": 0, "max_concurrent": 0,
            "prefill_flops": 0.0, "prefix_hits": 0, "prefix_hit_tokens": 0,
            "preemptions": 0, "resumes": 0, "resume_warm_hits": 0,
        }
        clock = 0
        rr = 0
        with self.mesh:
            caches = (
                self._pools if self._pools is not None else self._zero_pools()
            )
            while len(q) or any(r is not None for r in active):
                # admission: a free slot AND a page reservation — the page
                # budget, not the slot count, is the capacity limit; a
                # higher-priority request that cannot reserve may evict the
                # youngest lowest-priority active request instead of waiting
                for slot in range(B):
                    if active[slot] is not None:
                        continue
                    r = q.peek(clock)
                    if r is None:
                        break  # nothing in the queue has arrived yet
                    pr = self._eff_prompt(r)  # prompt + resumed tokens
                    L = len(pr) + (r.max_new - len(r.generated)) - 1
                    own = f"req{r.uid}"
                    rank = _PRIORITY_RANK[r.priority]
                    m, spages = self._match_prefix(pr)
                    if m:
                        for p in spages:
                            pool.retain(p, owner=own)
                        sc = self._paged_schedule(
                            L, step_span=C, start_tile=m // self.page
                        )
                        need = lambda: (
                            self._committed(active, sched, pos)
                            + sc.remaining_peak(m)
                        )
                        gap = self._fits(need())
                        if gap > 0 and self.preemptible:
                            gap = self._preempt_until(
                                need, rank, q, fetch, pool, pt, active,
                                sched, parr, pos, admit_pos, admit_seq,
                            )
                        if gap > 0:
                            for p in spages:
                                pool.release(p, owner=own)
                            cold_peak = self._paged_schedule(
                                L, step_span=C
                            ).remaining_peak(0)
                            if cold_peak < sc.remaining_peak(m):
                                # cold genuinely cheaper (retention frees
                                # tiles the alias would pin): retry cold
                                m, spages = 0, []
                            else:
                                # cold could not fit either — and its _fits
                                # would evict the very prefix (a preemption
                                # victim's donated pages) that makes the
                                # eventual resume warm
                                self.stats["admission_backpressure"] += 1
                                break
                    if not m:
                        sc = (
                            self._ring_schedule(L)
                            if self.ring_tiles is not None
                            else self._paged_schedule(L, step_span=C)
                        )
                        need = lambda: (
                            self._committed(active, sched, pos)
                            + sc.remaining_peak(0)
                        )
                        gap = self._fits(need())
                        if gap > 0 and self.preemptible:
                            gap = self._preempt_until(
                                need, rank, q, fetch, pool, pt, active,
                                sched, parr, pos, admit_pos, admit_seq,
                            )
                        if gap > 0:
                            self.stats["admission_backpressure"] += 1
                            break
                    if self.cross_pages is not None:
                        nc = self._cross_admit(r, slot, ct, caches)
                        if nc is None:
                            self.stats["admission_backpressure"] += 1
                            break
                        caches = nc
                    q.pop(r, clock)
                    if r.preemptions:  # a victim re-admitting (possibly
                        self.stats["resumes"] += 1  # mid-prefill, no tokens)
                        if m:
                            self.stats["resume_warm_hits"] += 1
                    if m:
                        for i, p in enumerate(spages):
                            pt[slot, i] = p
                        self.stats["prefix_hits"] += 1
                        self.stats["prefix_hit_tokens"] += m
                    elif self.ring_tiles is not None:
                        # the fixed mod-window page set, allocated up front —
                        # chunk streaming reuses the slots in phase
                        for t in range(min(self.ring_tiles, -(-L // self.page))):
                            pt[slot, t] = pool.alloc(own)
                    active[slot] = r
                    sched[slot] = sc
                    parr[slot] = pr
                    pos[slot] = m
                    consumed[slot] = m
                    admit_pos[slot] = m
                    admit_seq[slot] = aseq
                    aseq += 1
                    remaining[slot] = r.max_new - len(r.generated)
                self.stats["max_concurrent"] = max(
                    self.stats["max_concurrent"],
                    sum(a is not None for a in active),
                )
                if not any(r is not None for r in active):
                    clock += 1
                    continue
                eligible = [
                    s for s in range(B)
                    if active[s] is not None
                    and len(parr[s]) - consumed[s] <= 0
                ]
                use_nxt = np.zeros(B, bool)
                chunk_t = np.zeros(B, np.int32)
                budget = self.chunk_budget
                # interactive rows split the chunk budget ahead of batch
                # rows; the rotation keeps it fair within a class (and IS
                # the whole order under uniform priority / fifo scheduling)
                order = sorted(
                    range(B),
                    key=lambda s: (
                        0 if self.fifo or active[s] is None
                        else _PRIORITY_RANK[active[s].priority],
                        (s - rr) % B,
                    ),
                )
                for slot in order:
                    r = active[slot]
                    if r is None:
                        continue
                    rem_prompt = len(parr[slot]) - consumed[slot]
                    if rem_prompt > 0:
                        t = self._budget_draw(r, rem_prompt, budget)
                        if t <= 0:
                            continue
                        chunk_t[slot] = t
                        budget -= t
                    else:
                        use_nxt[slot] = True
                rr = (rr + 1) % B
                clock += 1
                self.stats["mixed_steps"] += 1
                dec_rows = [s for s in range(B) if use_nxt[s]]
                chunk_rows = [s for s in range(B) if chunk_t[s] > 0]
                if any(s not in dec_rows for s in eligible):
                    self.stats["decode_stall_steps"] += 1
                if dec_rows and chunk_rows:
                    self.stats["overlap_steps"] += 1
                # (a) paged decode wave: every decoding row advances through
                # the decode grid; non-decoding rows run with a sentinel
                # page-table row so their garbage write DROPS — a mid-prompt
                # row's frontier tile may alias a shared prefix page, which
                # an unmasked write would corrupt for every sibling
                if dec_rows:
                    for slot in dec_rows:
                        caches = self._ensure_writable(
                            pool, pt, slot, int(pos[slot]),
                            int(pos[slot]) + 1, caches,
                            f"req{active[slot].uid}",
                        )
                    if self.ring_tiles is not None:
                        kv_live = None  # ring positions are unbounded
                    else:
                        hot = max(int(pos[s]) + 1 for s in dec_rows)
                        kv_live = _next_bucket(hot, self.cache_len)
                        self.stats["decode_kv_live_max"] = max(
                            self.stats.get("decode_kv_live_max", 0), kv_live
                        )
                    use = np.asarray(use_nxt)
                    pt_wave = np.where(
                        use[:, None], pt, np.int32(self.pool_pages)
                    ).astype(np.int32)
                    logits, caches = self.p_decode_fn(
                        self.params, caches, nxt[:, None], jnp.asarray(pos),
                        jnp.asarray(pt_wave), kv_live,
                        **({} if ct is None else {"ct": jnp.asarray(ct)}),
                    )
                    toks = jnp.argmax(logits, -1).astype(jnp.int32)
                    self.stats["decode_steps"] += 1
                    self.stats["decode_tokens"] += len(dec_rows)
                    sinks = []
                    for slot in dec_rows:
                        r = active[slot]
                        sinks.append((r, slot))
                        pos[slot] += 1
                        remaining[slot] -= 1
                        if remaining[slot] <= 0:
                            self._free_all(pool, pt, slot, f"req{r.uid}")
                            if ct is not None:
                                self._release_cross(ct, slot, f"req{r.uid}")
                            active[slot] = None
                            sched[slot] = None
                            parr[slot] = None
                        else:
                            self._free_dead(
                                pool, pt, slot, sched[slot], int(pos[slot]),
                                f"req{r.uid}",
                            )
                    self._stamp_emits(sinks, clock)
                    fetch.push(toks, sinks)
                    nxt = jnp.where(jnp.asarray(use_nxt), toks, nxt)
                # (b) prompt chunks through the paged chunk grid: allocate
                # the chunk's tiles, stream it into the pool, then free
                # whatever the pattern says is already dead
                for slot in chunk_rows:
                    r = active[slot]
                    t = int(chunk_t[slot])
                    caches = self._ensure_writable(
                        pool, pt, slot, int(pos[slot]), int(pos[slot]) + t,
                        caches, f"req{r.uid}",
                    )
                    ctoks = np.zeros((1, C), np.int32)
                    ctoks[0, :t] = parr[slot][
                        consumed[slot] : consumed[slot] + t
                    ]
                    kv_live = _next_bucket(int(pos[slot]) + t, self.cache_len)
                    logits1, caches = self.p_chunk_fn(
                        self.params, caches, jnp.asarray(ctoks),
                        jnp.asarray(pt[slot : slot + 1]),
                        jnp.int32(pos[slot]), jnp.int32(t), kv_live,
                        ct=None if ct is None else jnp.asarray(
                            ct[slot : slot + 1]
                        ),
                    )
                    self.stats["chunk_calls"] += 1
                    self.stats["prefill_tokens"] += t
                    self.stats["prefill_flops"] += self._prefill_flop_count(
                        int(pos[slot]), t
                    )
                    pos[slot] += t
                    consumed[slot] += t
                    if consumed[slot] == len(parr[slot]):
                        self._cache_pages(parr[slot], pt, slot)
                        tok1 = jnp.argmax(logits1).astype(jnp.int32)
                        self._stamp_emits([(r, 0)], clock)
                        fetch.push(tok1, [(r, 0)])
                        nxt = nxt.at[slot].set(tok1)
                        remaining[slot] -= 1
                        if remaining[slot] <= 0:
                            self._free_all(pool, pt, slot, f"req{r.uid}")
                            if ct is not None:
                                self._release_cross(ct, slot, f"req{r.uid}")
                            active[slot] = None
                            sched[slot] = None
                            parr[slot] = None
                            continue
                    self._free_dead(pool, pt, slot, sched[slot],
                                    int(pos[slot]), f"req{r.uid}")
        fetch.flush()
        self._pools = caches
        self._finish_paged_run(pool)
        self._finalize_slo(requests, q)
        return requests
