"""Fused flash-attention kernels (Pallas, TPU target) — online softmax on
VMEM-resident score tiles.

This is the paper's §IV orchestration applied to the attention AT-all itself:
the (q_tile x kv_tile) score block is computed, masked, softmax-normalised and
contracted against V entirely in VMEM — the score matrix never touches HBM,
vs one full round trip (write + softmax read + probs write + einsum read) for
the block-oriented XLA form (Fig. 2's memory-bound pathology).  Token tiles
stream through the grid exactly like :mod:`repro.kernels.monarch_bpmm`: one
HBM read of Q/K/V and one HBM write of O per tile, with the TPU DMA engine
double-buffering the next tile against MXU compute ({Load | Cal | Store}).

Prefill kernel
    grid = (batch x kv_heads, gqa_group, q_tiles, kv_tiles).  The kv axis is
    the innermost (sequential on TPU) dimension; running max / sum-exp / out
    accumulators live in VMEM scratch and carry across kv steps (the online
    softmax).  Causal and sliding-window blocks that are statically dead for
    a (q_tile, kv_tile) pair are skipped via ``pl.when``.

Decode kernel
    flash-decode: grid = (batch x kv_heads, kv_tiles) over the cache, same
    VMEM partial-max/sum combine across kv tiles; the query block is the GQA
    group of head vectors for one token.  Cache-length masking arrives as a
    *per-row* additive bias (keeps scalars out of the kernel; works
    identically under interpret mode) — ragged batches hand every request its
    own live-KV validity row.

Layouts (pre-padded by :mod:`repro.kernels.ops`):
    prefill  q: (BK, G, Sq, D)   k, v: (BK, Skv, D)   y: (BK, G, Sq, D)
    decode   q: (BK, Gp, D)      k, v: (BK, Skv, D)   bias: (BK, Skv)
    with BK = batch * kv_heads, G the GQA group, D the padded head dim.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["mha_prefill", "mha_decode", "pick_tiles", "NEG_INF"]

NEG_INF = -1e30  # finite stand-in: exp(NEG_INF - m) underflows but never NaNs
_LANES = 128  # running-stat scratch is lane-replicated for TPU tiling


def pick_tiles(s_q: int, s_kv: int, q_tile: int, kv_tile: int) -> tuple[int, int]:
    """Clamp the spec's tile sizes to the (hardware-aligned) problem size."""
    tq = min(q_tile, -(-s_q // 8) * 8)
    tk = min(kv_tile, -(-s_kv // _LANES) * _LANES)
    return max(tq, 8), max(tk, _LANES)


def _prefill_kernel(
    q_ref, k_ref, v_ref, y_ref, m_ref, l_ref, acc_ref,
    *, scale: float, causal: bool, window: int | None, s_q: int, s_kv: int,
    q_tile: int, kv_tile: int,
):
    i = pl.program_id(2)
    j = pl.program_id(3)
    nj = pl.num_programs(3)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # static-per-block liveness: skip kv blocks entirely above the causal
    # diagonal or entirely left of the sliding window
    live = j * kv_tile < s_kv
    if causal:
        live &= j * kv_tile <= i * q_tile + q_tile - 1
    if window is not None:
        live &= j * kv_tile + kv_tile - 1 > i * q_tile - window

    @pl.when(live)
    def _step():
        q = q_ref[0, 0].astype(jnp.float32) * scale  # (tq, d)
        k = k_ref[0].astype(jnp.float32)  # (tk, d)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )  # (tq, tk)

        qpos = i * q_tile + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        kpos = j * kv_tile + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = kpos < s_kv  # padded keys
        if causal:
            mask &= qpos >= kpos
        if window is not None:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]  # (tq, LANES), lane-replicated
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)  # broadcasts back to (tq, LANES)
        alpha = jnp.exp(m_prev[:, :1] - m_new[:, :1])
        # explicit re-mask: when a row is still fully masked m_new == NEG_INF
        # and exp(s - m_new) would be 1, not 0
        p = jnp.where(mask, jnp.exp(s - m_new[:, :1]), 0.0)
        l_new = alpha * l_ref[:, :1] + jnp.sum(p, axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
            p, v, preferred_element_type=jnp.float32
        )
        m_ref[...] = m_new
        l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(j == nj - 1)
    def _flush():
        l = l_ref[:, :1]
        y_ref[0, 0] = (acc_ref[...] / jnp.maximum(l, 1e-30)).astype(y_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=(
        "scale", "causal", "window", "s_q", "s_kv", "q_tile", "kv_tile", "interpret",
    ),
)
def mha_prefill(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    scale: float,
    causal: bool,
    window: int | None,
    s_q: int,
    s_kv: int,
    q_tile: int,
    kv_tile: int,
    interpret: bool = False,
) -> jax.Array:
    """q: (BK, G, Sq_pad, D) -> y same shape; k, v: (BK, Skv_pad, D).

    ``s_q`` / ``s_kv`` are the true (pre-padding) lengths; padded key columns
    are masked inside the kernel, padded query rows are sliced off by the ops
    wrapper."""
    from jax.experimental.pallas import tpu as pltpu

    bk, g, sq_pad, d = q.shape
    skv_pad = k.shape[1]
    if sq_pad % q_tile or skv_pad % kv_tile:
        raise ValueError(f"padded seqs {(sq_pad, skv_pad)} vs tiles {(q_tile, kv_tile)}")

    grid = (bk, g, sq_pad // q_tile, skv_pad // kv_tile)
    return pl.pallas_call(
        functools.partial(
            _prefill_kernel, scale=scale, causal=causal, window=window,
            s_q=s_q, s_kv=s_kv, q_tile=q_tile, kv_tile=kv_tile,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, q_tile, d), lambda b, g, i, j: (b, g, i, 0)),
            pl.BlockSpec((1, kv_tile, d), lambda b, g, i, j: (b, j, 0)),
            pl.BlockSpec((1, kv_tile, d), lambda b, g, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, q_tile, d), lambda b, g, i, j: (b, g, i, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((q_tile, _LANES), jnp.float32),
            pltpu.VMEM((q_tile, _LANES), jnp.float32),
            pltpu.VMEM((q_tile, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)


def _decode_kernel(
    q_ref, k_ref, v_ref, bias_ref, y_ref, m_ref, l_ref, acc_ref,
    *, scale: float,
):
    j = pl.program_id(1)
    nj = pl.num_programs(1)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32) * scale  # (gp, d)
    k = k_ref[0].astype(jnp.float32)  # (tk, d)
    v = v_ref[0].astype(jnp.float32)
    bias = bias_ref[0].astype(jnp.float32)  # (tk,): 0 | NEG_INF
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) + bias[None, :]  # (gp, tk)
    valid = bias[None, :] > 0.5 * NEG_INF

    m_prev = m_ref[...]
    m_cur = jnp.max(s, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    alpha = jnp.exp(m_prev[:, :1] - m_new[:, :1])
    p = jnp.where(valid, jnp.exp(s - m_new[:, :1]), 0.0)
    l_new = alpha * l_ref[:, :1] + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
        p, v, preferred_element_type=jnp.float32
    )
    m_ref[...] = m_new
    l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(j == nj - 1)
    def _flush():
        l = l_ref[:, :1]
        y_ref[0] = (acc_ref[...] / jnp.maximum(l, 1e-30)).astype(y_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("scale", "kv_tile", "interpret")
)
def mha_decode(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    bias: jax.Array,
    *,
    scale: float,
    kv_tile: int,
    interpret: bool = False,
) -> jax.Array:
    """Flash-decode: q (BK, Gp, D); k, v (BK, Skv_pad, D); bias (BK, Skv_pad)
    per-row additive mask (0 for live keys, NEG_INF for padded / beyond the
    row's cur_len — ragged batches mask each request independently).
    Returns (BK, Gp, D)."""
    from jax.experimental.pallas import tpu as pltpu

    bk, gp, d = q.shape
    skv_pad = k.shape[1]
    if skv_pad % kv_tile:
        raise ValueError(f"padded cache {skv_pad} vs kv tile {kv_tile}")
    if bias.shape != (bk, skv_pad):
        raise ValueError(f"bias {bias.shape} vs expected {(bk, skv_pad)}")

    grid = (bk, skv_pad // kv_tile)
    return pl.pallas_call(
        functools.partial(_decode_kernel, scale=scale),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, gp, d), lambda b, j: (b, 0, 0)),
            pl.BlockSpec((1, kv_tile, d), lambda b, j: (b, j, 0)),
            pl.BlockSpec((1, kv_tile, d), lambda b, j: (b, j, 0)),
            pl.BlockSpec((1, kv_tile), lambda b, j: (b, j)),
        ],
        out_specs=pl.BlockSpec((1, gp, d), lambda b, j: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((gp, _LANES), jnp.float32),
            pltpu.VMEM((gp, _LANES), jnp.float32),
            pltpu.VMEM((gp, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v, bias)
