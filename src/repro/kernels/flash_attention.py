"""Fused flash-attention kernels (Pallas, TPU target) — online softmax on
VMEM-resident score tiles, iterating only *live* kv tiles.

This is the paper's §IV orchestration applied to the attention AT-all itself:
the (q_tile x kv_tile) score block is computed, masked, softmax-normalised and
contracted against V entirely in VMEM — the score matrix never touches HBM,
vs one full round trip (write + softmax read + probs write + einsum read) for
the block-oriented XLA form (Fig. 2's memory-bound pathology).  Token tiles
stream through the grid exactly like :mod:`repro.kernels.monarch_bpmm`: one
HBM read of Q/K/V and one HBM write of O per tile, with the TPU DMA engine
double-buffering the next tile against MXU compute ({Load | Cal | Store}).

Block sparsity (§III butterfly-sparsity): both kernels take a packed
per-q-row *live kv-tile index map* (:mod:`repro.core.sparsity`) as
scalar-prefetch arguments.  The kv grid axis has extent ``max_live`` (the
widest row's live count), and the BlockSpec index maps dereference the table —
so statically-dead kv tiles are never part of the grid: no DMA is issued for
them and no MXU step runs.  Rows narrower than ``max_live`` pad with repeats
of tile 0 flagged dead; padded steps skip compute under ``pl.when`` and
revisit an already-streamed block.  A fine in-tile mask (causal diagonal,
window edge, padded keys) keeps partially-live boundary tiles exact.

Prefill kernel
    grid = (batch x kv_heads, gqa_group, q_tiles, max_live_kv_tiles); the
    table is static per (pattern, shape).  Running max / sum-exp / out
    accumulators live in VMEM scratch and carry across kv steps (the online
    softmax).

Decode kernel
    flash-decode: grid = (batch x kv_heads, max_live); the table is *traced*
    per-row data (each request's live tile set over the cache at its own
    position — ragged batches truncate independently).  Cache-length masking
    arrives as a per-row additive bias row.

Layouts (pre-padded by :mod:`repro.kernels.ops`):
    prefill  q: (BK, G, Sq, D)   k, v: (BK, Skv, D)   y: (BK, G, Sq, D)
             kv_index, step_live: (q_tiles, max_live) int32
    decode   q: (BK, Gp, D)      k, v: (BK, Skv, D)   bias: (BK, Skv)
             kv_index, step_live: (BK, max_live) int32
    with BK = batch * kv_heads, G the GQA group, D the padded head dim.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.sparsity import _decode_live_jnp, pick_pattern_tiles

__all__ = [
    "mha_prefill",
    "mha_chunk",
    "mha_decode",
    "mha_chunk_paged",
    "mha_decode_paged",
    "pick_tiles",
    "NEG_INF",
]

NEG_INF = -1e30  # finite stand-in: exp(NEG_INF - m) underflows but never NaNs
_LANES = 128  # running-stat scratch is lane-replicated for TPU tiling


def pick_tiles(s_q: int, s_kv: int, q_tile: int, kv_tile: int) -> tuple[int, int]:
    """Clamp the spec's tile sizes to the (hardware-aligned) problem size.

    Delegates to :func:`repro.core.sparsity.pick_pattern_tiles` — block maps
    and kernels must agree on the effective tile grid."""
    return pick_pattern_tiles(s_q, s_kv, q_tile, kv_tile)


def _prefill_kernel(
    kvi_ref, lv_ref, vt_ref, q_ref, k_ref, v_ref, *refs,
    scale: float, causal: bool, window: int | None, s_q: int, s_kv: int,
    q_tile: int, kv_tile: int, quantized: bool = False,
):
    # quantized pools append per-row scale tiles after v: dequant happens here,
    # right after the tile DMA, so the MXU math below is identical either way
    if quantized:
        ksc_ref, vsc_ref, y_ref, m_ref, l_ref, acc_ref = refs
    else:
        ksc_ref = vsc_ref = None
        y_ref, m_ref, l_ref, acc_ref = refs
    i = pl.program_id(2)
    jj = pl.program_id(3)
    nj = pl.num_programs(3)
    # vt is the VIRTUAL kv-tile (token positions); kvi drives the DMA and is
    # either the same tile (contiguous cache) or its physical page (paged)
    j = vt_ref[i, jj]

    @pl.when(jj == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # table-padding steps (rows narrower than max_live) carry no live block
    @pl.when(lv_ref[i, jj] > 0)
    def _step():
        q = q_ref[0, 0].astype(jnp.float32) * scale  # (tq, d)
        k = k_ref[0].astype(jnp.float32)  # (tk, d)
        v = v_ref[0].astype(jnp.float32)
        if ksc_ref is not None:
            k = k * ksc_ref[0][:, None]
            v = v * vsc_ref[0][:, None]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )  # (tq, tk)

        # fine mask: padded keys + causal diagonal + window edge inside the
        # (pattern-live) tile — block-level pruning already happened in the map
        qpos = i * q_tile + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        kpos = j * kv_tile + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = kpos < s_kv
        if causal:
            mask &= qpos >= kpos
        if window is not None:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]  # (tq, LANES), lane-replicated
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)  # broadcasts back to (tq, LANES)
        alpha = jnp.exp(m_prev[:, :1] - m_new[:, :1])
        # explicit re-mask: when a row is still fully masked m_new == NEG_INF
        # and exp(s - m_new) would be 1, not 0
        p = jnp.where(mask, jnp.exp(s - m_new[:, :1]), 0.0)
        l_new = alpha * l_ref[:, :1] + jnp.sum(p, axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
            p, v, preferred_element_type=jnp.float32
        )
        m_ref[...] = m_new
        l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(jj == nj - 1)
    def _flush():
        l = l_ref[:, :1]
        y_ref[0, 0] = (acc_ref[...] / jnp.maximum(l, 1e-30)).astype(y_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=(
        "scale", "causal", "window", "s_q", "s_kv", "q_tile", "kv_tile", "interpret",
    ),
)
def mha_prefill(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    kv_index: jax.Array,
    step_live: jax.Array,
    *,
    scale: float,
    causal: bool,
    window: int | None,
    s_q: int,
    s_kv: int,
    q_tile: int,
    kv_tile: int,
    interpret: bool = False,
    kv_virt: jax.Array | None = None,
    k_scale: jax.Array | None = None,
    v_scale: jax.Array | None = None,
) -> jax.Array:
    """q: (BK, G, Sq_pad, D) -> y same shape; k, v: (BK, Skv_pad, D).

    ``kv_index`` / ``step_live``: (Sq_pad/q_tile, max_live) packed live
    kv-tile map (:class:`repro.core.sparsity.BlockMap`) — the kv grid axis
    iterates the table, not the full tile range.  ``s_q`` / ``s_kv`` are the
    true (pre-padding) lengths; padded key columns are masked inside the
    kernel, padded query rows are sliced off by the ops wrapper.

    ``kv_virt`` (same shape as ``kv_index``) splits the table in two for a
    *paged* cache: ``kv_index`` then holds PHYSICAL page ids into a shared
    pool (``k``/``v`` are the pool, one page per kv tile) while ``kv_virt``
    holds the virtual tile the fine position mask is computed from
    (:func:`repro.core.sparsity.translate_tables`).  Defaults to
    ``kv_index`` — the contiguous identity mapping.

    ``k_scale`` / ``v_scale`` ((BK, Skv_pad) float32, or None): per-row
    dequant scales of a QUANTIZED pool — the kernel reconstructs each K/V
    tile right after its DMA (:mod:`repro.core.quant`); when None the call
    compiles the exact unquantized graph."""
    from jax.experimental.pallas import tpu as pltpu

    bk, g, sq_pad, d = q.shape
    skv_pad = k.shape[1]
    if sq_pad % q_tile or skv_pad % kv_tile:
        raise ValueError(f"padded seqs {(sq_pad, skv_pad)} vs tiles {(q_tile, kv_tile)}")
    nq, max_live = kv_index.shape
    if nq != sq_pad // q_tile:
        raise ValueError(f"kv_index rows {nq} vs q tiles {sq_pad // q_tile}")
    if kv_virt is None:
        kv_virt = kv_index
    quantized = k_scale is not None

    grid = (bk, g, nq, max_live)
    in_specs = [
        pl.BlockSpec((1, 1, q_tile, d), lambda b, g, i, jj, kvi, lv, vt: (b, g, i, 0)),
        pl.BlockSpec((1, kv_tile, d), lambda b, g, i, jj, kvi, lv, vt: (b, kvi[i, jj], 0)),
        pl.BlockSpec((1, kv_tile, d), lambda b, g, i, jj, kvi, lv, vt: (b, kvi[i, jj], 0)),
    ]
    args = [
        kv_index.astype(jnp.int32), step_live.astype(jnp.int32),
        kv_virt.astype(jnp.int32), q, k, v,
    ]
    if quantized:
        sspec = pl.BlockSpec(
            (1, kv_tile), lambda b, g, i, jj, kvi, lv, vt: (b, kvi[i, jj])
        )
        in_specs += [sspec, sspec]
        args += [k_scale.astype(jnp.float32), v_scale.astype(jnp.float32)]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,  # kv_index, step_live, kv_virt drive the DMA
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec(
            (1, 1, q_tile, d), lambda b, g, i, jj, kvi, lv, vt: (b, g, i, 0)
        ),
        scratch_shapes=[
            pltpu.VMEM((q_tile, _LANES), jnp.float32),
            pltpu.VMEM((q_tile, _LANES), jnp.float32),
            pltpu.VMEM((q_tile, d), jnp.float32),
        ],
    )
    return pl.pallas_call(
        functools.partial(
            _prefill_kernel, scale=scale, causal=causal, window=window,
            s_q=s_q, s_kv=s_kv, q_tile=q_tile, kv_tile=kv_tile,
            quantized=quantized,
        ),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        interpret=interpret,
    )(*args)


def _chunk_kernel(
    start_ref, kvi_ref, lv_ref, q_ref, k_ref, v_ref, y_ref, m_ref, l_ref, acc_ref,
    *, scale: float, window: int | None, s_kv: int, q_tile: int, kv_tile: int,
    n_kv_tiles: int, pattern: str, pattern_arg: int | None,
):
    b = pl.program_id(0)
    jj = pl.program_id(2)
    nj = pl.num_programs(2)
    j = kvi_ref[b, jj]  # the streamed kv-tile index (per-row traced table)
    start = start_ref[b]  # absolute position of this row's first chunk query

    @pl.when(jj == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(lv_ref[b, jj] > 0)
    def _step():
        q = q_ref[0, 0].astype(jnp.float32) * scale  # (cp, d)
        k = k_ref[0].astype(jnp.float32)  # (tk, d)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )  # (cp, tk)

        # per-row causal frontier: query at absolute position start+i attends
        # keys <= its own position — the newest readable cache row is the
        # query itself, so the frontier is also the written-cache mask
        qpos = start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        kpos = j * kv_tile + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = (kpos < s_kv) & (qpos >= kpos)
        if window is not None:
            mask &= kpos > qpos - window
        if pattern != "dense":
            # per-QUERY pattern gate: the chunk table is the union over the
            # q-tile rows the chunk spans; each query keeps only its own
            # q-tile's row (the same liveness the decode tables trace)
            mask &= _decode_live_jnp(
                pattern, qpos // q_tile, j, n_kv_tiles, q_tile, kv_tile,
                window, pattern_arg,
            )
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev[:, :1] - m_new[:, :1])
        p = jnp.where(mask, jnp.exp(s - m_new[:, :1]), 0.0)
        l_new = alpha * l_ref[:, :1] + jnp.sum(p, axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
            p, v, preferred_element_type=jnp.float32
        )
        m_ref[...] = m_new
        l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(jj == nj - 1)
    def _flush():
        l = l_ref[:, :1]
        y_ref[0, 0] = (acc_ref[...] / jnp.maximum(l, 1e-30)).astype(y_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=(
        "scale", "window", "s_kv", "q_tile", "kv_tile", "pattern",
        "pattern_arg", "interpret",
    ),
)
def mha_chunk(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    start: jax.Array,
    kv_index: jax.Array,
    step_live: jax.Array,
    *,
    scale: float,
    window: int | None,
    s_kv: int,
    q_tile: int,
    kv_tile: int,
    pattern: str = "dense",
    pattern_arg: int | None = None,
    interpret: bool = False,
) -> jax.Array:
    """Mixed chunked-prefill attention over a shared KV cache.

    q: (BK, Gp, C_pad, D) — each row's chunk of queries at absolute positions
    ``start[b] .. start[b]+C-1``; k, v: (BK, Skv_pad, D) the (truncated)
    cache; ``kv_index`` / ``step_live``: (BK, max_live) per-row packed live
    kv-tile tables (:func:`repro.core.sparsity.chunk_live_tables`) — traced
    data, so rows mid-prompt, rows decoding one token, and idle rows all run
    the same grid while streaming only their own live tiles.  ``q_tile`` is
    the *pattern* q-tile granularity (absolute position space), not the chunk
    length.  Returns (BK, Gp, C_pad, D)."""
    from jax.experimental.pallas import tpu as pltpu

    bk, g, cp, d = q.shape
    skv_pad = k.shape[1]
    if skv_pad % kv_tile:
        raise ValueError(f"padded cache {skv_pad} vs kv tile {kv_tile}")
    if kv_index.shape[0] != bk or start.shape[0] != bk:
        raise ValueError(
            f"table rows {kv_index.shape[0]} / start rows {start.shape[0]} vs BK {bk}"
        )
    max_live = kv_index.shape[1]

    grid = (bk, g, max_live)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,  # start, kv_index, step_live
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, cp, d), lambda b, gg, jj, st, kvi, lv: (b, gg, 0, 0)),
            pl.BlockSpec((1, kv_tile, d), lambda b, gg, jj, st, kvi, lv: (b, kvi[b, jj], 0)),
            pl.BlockSpec((1, kv_tile, d), lambda b, gg, jj, st, kvi, lv: (b, kvi[b, jj], 0)),
        ],
        out_specs=pl.BlockSpec(
            (1, 1, cp, d), lambda b, gg, jj, st, kvi, lv: (b, gg, 0, 0)
        ),
        scratch_shapes=[
            pltpu.VMEM((cp, _LANES), jnp.float32),
            pltpu.VMEM((cp, _LANES), jnp.float32),
            pltpu.VMEM((cp, d), jnp.float32),
        ],
    )
    return pl.pallas_call(
        functools.partial(
            _chunk_kernel, scale=scale, window=window, s_kv=s_kv,
            q_tile=q_tile, kv_tile=kv_tile, n_kv_tiles=skv_pad // kv_tile,
            pattern=pattern, pattern_arg=pattern_arg,
        ),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        interpret=interpret,
    )(
        start.astype(jnp.int32), kv_index.astype(jnp.int32),
        step_live.astype(jnp.int32), q, k, v,
    )


def _decode_kernel(
    kvi_ref, lv_ref, q_ref, k_ref, v_ref, bias_ref, y_ref, m_ref, l_ref, acc_ref,
    *, scale: float,
):
    b = pl.program_id(0)
    jj = pl.program_id(1)
    nj = pl.num_programs(1)

    @pl.when(jj == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(lv_ref[b, jj] > 0)
    def _step():
        q = q_ref[0].astype(jnp.float32) * scale  # (gp, d)
        k = k_ref[0].astype(jnp.float32)  # (tk, d)
        v = v_ref[0].astype(jnp.float32)
        bias = bias_ref[0].astype(jnp.float32)  # (tk,): 0 | NEG_INF
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) + bias[None, :]  # (gp, tk)
        valid = bias[None, :] > 0.5 * NEG_INF

        m_prev = m_ref[...]
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev[:, :1] - m_new[:, :1])
        p = jnp.where(valid, jnp.exp(s - m_new[:, :1]), 0.0)
        l_new = alpha * l_ref[:, :1] + jnp.sum(p, axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
            p, v, preferred_element_type=jnp.float32
        )
        m_ref[...] = m_new
        l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(jj == nj - 1)
    def _flush():
        l = l_ref[:, :1]
        y_ref[0] = (acc_ref[...] / jnp.maximum(l, 1e-30)).astype(y_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("scale", "kv_tile", "interpret")
)
def mha_decode(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    bias: jax.Array,
    kv_index: jax.Array,
    step_live: jax.Array,
    *,
    scale: float,
    kv_tile: int,
    interpret: bool = False,
) -> jax.Array:
    """Flash-decode: q (BK, Gp, D); k, v (BK, Skv_pad, D); bias (BK, Skv_pad)
    per-row additive mask (0 for live keys, NEG_INF for padded / beyond the
    row's cur_len — ragged batches mask each request independently).

    ``kv_index`` / ``step_live``: (BK, max_live) per-row live kv-tile tables
    (:func:`repro.core.sparsity.decode_live_tables`) — the grid's kv extent is
    ``max_live``, not the cache tile count, so a short request against a deep
    cache streams only its own written (and pattern-live) tiles.
    Returns (BK, Gp, D)."""
    from jax.experimental.pallas import tpu as pltpu

    bk, gp, d = q.shape
    skv_pad = k.shape[1]
    if skv_pad % kv_tile:
        raise ValueError(f"padded cache {skv_pad} vs kv tile {kv_tile}")
    if bias.shape != (bk, skv_pad):
        raise ValueError(f"bias {bias.shape} vs expected {(bk, skv_pad)}")
    if kv_index.shape[0] != bk:
        raise ValueError(f"kv_index rows {kv_index.shape[0]} vs BK {bk}")
    max_live = kv_index.shape[1]

    grid = (bk, max_live)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, gp, d), lambda b, jj, kvi, lv: (b, 0, 0)),
            pl.BlockSpec((1, kv_tile, d), lambda b, jj, kvi, lv: (b, kvi[b, jj], 0)),
            pl.BlockSpec((1, kv_tile, d), lambda b, jj, kvi, lv: (b, kvi[b, jj], 0)),
            pl.BlockSpec((1, kv_tile), lambda b, jj, kvi, lv: (b, kvi[b, jj])),
        ],
        out_specs=pl.BlockSpec((1, gp, d), lambda b, jj, kvi, lv: (b, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((gp, _LANES), jnp.float32),
            pltpu.VMEM((gp, _LANES), jnp.float32),
            pltpu.VMEM((gp, d), jnp.float32),
        ],
    )
    return pl.pallas_call(
        functools.partial(_decode_kernel, scale=scale),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        interpret=interpret,
    )(kv_index.astype(jnp.int32), step_live.astype(jnp.int32), q, k, v, bias)


# --------------------------------------------------------------------------
# Paged grids: the kv tables hold PHYSICAL page ids into a batch-shared pool
# --------------------------------------------------------------------------


def _decode_kernel_paged(
    cl_ref, kvi_ref, vt_ref, lv_ref, q_ref, k_ref, v_ref, *refs,
    scale: float, window: int | None, kv_tile: int, quantized: bool = False,
):
    if quantized:
        ksc_ref, vsc_ref, y_ref, m_ref, l_ref, acc_ref = refs
    else:
        ksc_ref = vsc_ref = None
        y_ref, m_ref, l_ref, acc_ref = refs
    b = pl.program_id(0)
    jj = pl.program_id(2)
    nj = pl.num_programs(2)
    jv = vt_ref[b, jj]  # virtual tile: token positions for the fine mask
    cl = cl_ref[b]  # the row's live cache length (pos + 1)

    @pl.when(jj == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(lv_ref[b, jj] > 0)
    def _step():
        q = q_ref[0, 0].astype(jnp.float32) * scale  # (gp, d)
        k = k_ref[0].astype(jnp.float32)  # (tk, d) — one physical page
        v = v_ref[0].astype(jnp.float32)
        if ksc_ref is not None:  # dequantize the page in-register, post-DMA
            k = k * ksc_ref[0][:, None]
            v = v * vsc_ref[0][:, None]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )  # (gp, tk)
        # fine mask from VIRTUAL positions: the page holds virtual tile jv,
        # so its t-th row is absolute position jv*kv_tile + t
        kpos = jv * kv_tile + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        valid = kpos < cl
        if window is not None:
            valid &= kpos > cl - 1 - window
        s = jnp.where(valid, s, NEG_INF)

        m_prev = m_ref[...]
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev[:, :1] - m_new[:, :1])
        p = jnp.where(valid, jnp.exp(s - m_new[:, :1]), 0.0)
        l_new = alpha * l_ref[:, :1] + jnp.sum(p, axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
            p, v, preferred_element_type=jnp.float32
        )
        m_ref[...] = m_new
        l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(jj == nj - 1)
    def _flush():
        l = l_ref[:, :1]
        y_ref[0, 0] = (acc_ref[...] / jnp.maximum(l, 1e-30)).astype(y_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("scale", "window", "kv_tile", "interpret")
)
def mha_decode_paged(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    cur_len: jax.Array,
    kv_index: jax.Array,
    kv_virt: jax.Array,
    step_live: jax.Array,
    *,
    scale: float,
    window: int | None,
    kv_tile: int,
    interpret: bool = False,
    k_scale: jax.Array | None = None,
    v_scale: jax.Array | None = None,
) -> jax.Array:
    """Flash-decode over a PAGED cache: q (B, KV, Gp, D); k, v are the global
    page pool laid out (KV, n_pages * kv_tile, D) — no batch axis, every row
    reads the pool through its own table.  ``kv_index`` (B, max_live) holds
    physical page ids (the DMA target), ``kv_virt`` the matching virtual kv
    tiles (the fine mask's position base), ``step_live`` the packed liveness
    (:func:`repro.core.sparsity.translate_tables`).  ``cur_len`` (B,) is each
    row's live length in virtual token space; the grid never visits a dead or
    unallocated tile.  ``k_scale`` / ``v_scale`` ((KV, n_pages * kv_tile)
    float32, or None) carry a quantized pool's per-row dequant scales through
    the SAME page indirection — the kernel reconstructs each page tile right
    after its DMA.  Returns (B, KV, Gp, D)."""
    from jax.experimental.pallas import tpu as pltpu

    b, kvh, gp, d = q.shape
    pool_rows = k.shape[1]
    if pool_rows % kv_tile:
        raise ValueError(f"pool rows {pool_rows} vs kv tile {kv_tile}")
    if kv_index.shape[0] != b or kv_virt.shape != kv_index.shape:
        raise ValueError(
            f"tables {kv_index.shape}/{kv_virt.shape} vs batch {b}"
        )
    max_live = kv_index.shape[1]
    quantized = k_scale is not None

    grid = (b, kvh, max_live)
    in_specs = [
        pl.BlockSpec((1, 1, gp, d), lambda b, h, jj, cl, kvi, vt, lv: (b, h, 0, 0)),
        pl.BlockSpec((1, kv_tile, d), lambda b, h, jj, cl, kvi, vt, lv: (h, kvi[b, jj], 0)),
        pl.BlockSpec((1, kv_tile, d), lambda b, h, jj, cl, kvi, vt, lv: (h, kvi[b, jj], 0)),
    ]
    args = [
        cur_len.astype(jnp.int32), kv_index.astype(jnp.int32),
        kv_virt.astype(jnp.int32), step_live.astype(jnp.int32), q, k, v,
    ]
    if quantized:
        sspec = pl.BlockSpec(
            (1, kv_tile), lambda b, h, jj, cl, kvi, vt, lv: (h, kvi[b, jj])
        )
        in_specs += [sspec, sspec]
        args += [k_scale.astype(jnp.float32), v_scale.astype(jnp.float32)]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,  # cur_len, kv_index, kv_virt, step_live
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec(
            (1, 1, gp, d), lambda b, h, jj, cl, kvi, vt, lv: (b, h, 0, 0)
        ),
        scratch_shapes=[
            pltpu.VMEM((gp, _LANES), jnp.float32),
            pltpu.VMEM((gp, _LANES), jnp.float32),
            pltpu.VMEM((gp, d), jnp.float32),
        ],
    )
    return pl.pallas_call(
        functools.partial(
            _decode_kernel_paged, scale=scale, window=window, kv_tile=kv_tile,
            quantized=quantized,
        ),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        interpret=interpret,
    )(*args)


def _chunk_kernel_paged(
    start_ref, kvi_ref, vt_ref, lv_ref, q_ref, k_ref, v_ref, *refs,
    scale: float, window: int | None, s_kv: int,
    q_tile: int, kv_tile: int, n_kv_tiles: int, pattern: str,
    pattern_arg: int | None, quantized: bool = False,
):
    if quantized:
        ksc_ref, vsc_ref, y_ref, m_ref, l_ref, acc_ref = refs
    else:
        ksc_ref = vsc_ref = None
        y_ref, m_ref, l_ref, acc_ref = refs
    b = pl.program_id(0)
    jj = pl.program_id(3)
    nj = pl.num_programs(3)
    jv = vt_ref[b, jj]  # virtual tile (positions); DMA used the physical id
    start = start_ref[b]

    @pl.when(jj == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(lv_ref[b, jj] > 0)
    def _step():
        q = q_ref[0, 0, 0].astype(jnp.float32) * scale  # (cp, d)
        k = k_ref[0].astype(jnp.float32)  # (tk, d) — one physical page
        v = v_ref[0].astype(jnp.float32)
        if ksc_ref is not None:  # dequantize the page in-register, post-DMA
            k = k * ksc_ref[0][:, None]
            v = v * vsc_ref[0][:, None]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )  # (cp, tk)

        qpos = start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        kpos = jv * kv_tile + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = (kpos < s_kv) & (qpos >= kpos)
        if window is not None:
            mask &= kpos > qpos - window
        if pattern != "dense":
            mask &= _decode_live_jnp(
                pattern, qpos // q_tile, jv, n_kv_tiles, q_tile, kv_tile,
                window, pattern_arg,
            )
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev[:, :1] - m_new[:, :1])
        p = jnp.where(mask, jnp.exp(s - m_new[:, :1]), 0.0)
        l_new = alpha * l_ref[:, :1] + jnp.sum(p, axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
            p, v, preferred_element_type=jnp.float32
        )
        m_ref[...] = m_new
        l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(jj == nj - 1)
    def _flush():
        l = l_ref[:, :1]
        y_ref[0, 0, 0] = (acc_ref[...] / jnp.maximum(l, 1e-30)).astype(y_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=(
        "scale", "window", "s_kv", "q_tile", "kv_tile", "pattern",
        "pattern_arg", "interpret",
    ),
)
def mha_chunk_paged(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    start: jax.Array,
    kv_index: jax.Array,
    kv_virt: jax.Array,
    step_live: jax.Array,
    *,
    scale: float,
    window: int | None,
    s_kv: int,
    q_tile: int,
    kv_tile: int,
    pattern: str = "dense",
    pattern_arg: int | None = None,
    interpret: bool = False,
    k_scale: jax.Array | None = None,
    v_scale: jax.Array | None = None,
) -> jax.Array:
    """Mixed chunked-prefill attention over a PAGED shared KV cache.

    q: (B, KV, G, C_pad, D); k, v: the global page pool (KV, n_pages *
    kv_tile, D).  ``kv_index`` (B, max_live) physical page ids, ``kv_virt``
    the matching virtual kv tiles, ``step_live`` packed liveness — the
    translated form of :func:`repro.core.sparsity.chunk_live_tables`.
    ``s_kv`` is the VIRTUAL cache length (fine masks index virtual token
    positions; the per-query pattern gate runs on virtual tiles).  Same grid
    semantics as :func:`mha_chunk` with the batch and kv-head axes split so
    the pool needs no per-row copy.  ``k_scale`` / ``v_scale`` ((KV,
    n_pages * kv_tile) float32, or None): quantized-pool per-row dequant
    scales, page-indirected like K/V and applied right after the tile DMA.
    Returns (B, KV, G, C_pad, D)."""
    from jax.experimental.pallas import tpu as pltpu

    b, kvh, g, cp, d = q.shape
    pool_rows = k.shape[1]
    if pool_rows % kv_tile:
        raise ValueError(f"pool rows {pool_rows} vs kv tile {kv_tile}")
    if kv_index.shape[0] != b or start.shape[0] != b:
        raise ValueError(
            f"table rows {kv_index.shape[0]} / start rows {start.shape[0]} vs B {b}"
        )
    max_live = kv_index.shape[1]
    quantized = k_scale is not None

    grid = (b, kvh, g, max_live)
    in_specs = [
        pl.BlockSpec(
            (1, 1, 1, cp, d),
            lambda b, h, gg, jj, st, kvi, vt, lv: (b, h, gg, 0, 0),
        ),
        pl.BlockSpec(
            (1, kv_tile, d),
            lambda b, h, gg, jj, st, kvi, vt, lv: (h, kvi[b, jj], 0),
        ),
        pl.BlockSpec(
            (1, kv_tile, d),
            lambda b, h, gg, jj, st, kvi, vt, lv: (h, kvi[b, jj], 0),
        ),
    ]
    args = [
        start.astype(jnp.int32), kv_index.astype(jnp.int32),
        kv_virt.astype(jnp.int32), step_live.astype(jnp.int32), q, k, v,
    ]
    if quantized:
        sspec = pl.BlockSpec(
            (1, kv_tile), lambda b, h, gg, jj, st, kvi, vt, lv: (h, kvi[b, jj])
        )
        in_specs += [sspec, sspec]
        args += [k_scale.astype(jnp.float32), v_scale.astype(jnp.float32)]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,  # start, kv_index, kv_virt, step_live
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec(
            (1, 1, 1, cp, d), lambda b, h, gg, jj, st, kvi, vt, lv: (b, h, gg, 0, 0)
        ),
        scratch_shapes=[
            pltpu.VMEM((cp, _LANES), jnp.float32),
            pltpu.VMEM((cp, _LANES), jnp.float32),
            pltpu.VMEM((cp, d), jnp.float32),
        ],
    )
    return pl.pallas_call(
        functools.partial(
            _chunk_kernel_paged, scale=scale, window=window, s_kv=s_kv,
            q_tile=q_tile, kv_tile=kv_tile,
            n_kv_tiles=-(-s_kv // kv_tile), pattern=pattern,
            pattern_arg=pattern_arg, quantized=quantized,
        ),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        interpret=interpret,
    )(*args)
