"""Fused multilayer-dataflow BPMM kernel (Pallas, TPU target).

This kernel IS the paper's §IV orchestration re-expressed for TPU: all
butterfly stages of one slice piece execute back-to-back on a VMEM-resident
token tile.  The radix-2 stages are grouped into two block-diagonal
super-stages (R then L — see :mod:`repro.core.monarch`), each a batch of dense
``b x b`` / ``nb x nb`` MXU matmuls; the stride-wider-than-a-block swap set is
the single in-register axis flip between the two einsums (the multi-line-SPM,
transpose-free analogue).  The intermediate vector never touches HBM —
exactly one HBM read of x and one HBM write of y per token tile, vs one
round-trip *per stage* for the faithful staged form (paper Fig. 2's
cache-pressure pathology).

Grid = (token tiles, gout slices); the token-tile axis is the paper's
coarse-grained streaming dimension (§V-A): iterations pour through the kernel
while the TPU's DMA engine double-buffers the next tile against MXU compute —
the {Load | Cal | Store} decoupling.

Layouts:
    x: (T, gin, nb, b)            token-major, slice grid flattened
    r: (gout, gin, nb, b, b)      super-stage R, block-diagonal over hi
    l: (gout, gin, b, nb, nb)     super-stage L, block-diagonal over lo
    y: (T, gout, nb, b)
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["monarch_bpmm", "pick_token_tile"]


def pick_token_tile(gin: int, nb: int, b: int, dtype_bytes: float = 4) -> int:
    """Token-tile size so x/u/y tiles fit a ~12 MB VMEM budget.

    ``dtype_bytes`` must come from the ACTUAL storage dtype (bf16 tiles are
    half the bytes of f32 and fit twice the tokens); the f32 default is a
    conservative fallback for callers without an array in hand.  Fractional
    widths are legal: quantized KV tiles price at their EFFECTIVE width —
    e.g. ``repro.core.attention.kv_dtype_bytes`` returns ``1 + 4/head_dim``
    for int8/fp8 pages (payload byte + amortized per-row f32 scale) — so a
    quantized stream budgets nearly twice the tokens of bf16 in the same
    VMEM."""
    piece = nb * b
    per_token = (gin + 3) * piece * float(dtype_bytes)  # x(gin) + u + acc + y
    budget = 12 * 1024 * 1024
    tile = int(budget // max(per_token, 1.0))
    for cand in (512, 256, 128, 64, 32, 16, 8):
        if cand <= tile:
            return cand
    return 8


def _kernel(x_ref, r_ref, l_ref, y_ref, *, gin: int):
    acc = None
    for g in range(gin):  # static unroll over input slices (Fig. 10 sum)
        x = x_ref[:, g].astype(jnp.float32)  # (TB, nb, b)
        r = r_ref[0, g].astype(jnp.float32)  # (nb, b, b)
        l = l_ref[0, g].astype(jnp.float32)  # (b, nb, nb)
        # super-stage R: mix lo within each hi block  (batched b x b MXU)
        u = jnp.einsum("thj,hij->thi", x, r, preferred_element_type=jnp.float32)
        # super-stage L: mix hi per lo — the axis flip happens in VMEM
        v = jnp.einsum("tkj,jhk->thj", u, l, preferred_element_type=jnp.float32)
        acc = v if acc is None else acc + v
    y_ref[:, 0] = acc.astype(y_ref.dtype)


@functools.partial(jax.jit, static_argnames=("token_tile", "interpret"))
def monarch_bpmm(
    x: jax.Array,
    r: jax.Array,
    l: jax.Array,
    *,
    token_tile: int | None = None,
    interpret: bool = False,
) -> jax.Array:
    """x: (T, gin, nb, b) -> y: (T, gout, nb, b).  T must divide by the tile
    (the ops wrapper pads)."""
    t, gin, nb, b = x.shape
    gout = r.shape[0]
    tb = token_tile or pick_token_tile(
        gin, nb, b, dtype_bytes=jnp.dtype(x.dtype).itemsize
    )
    if t % tb:
        raise ValueError(f"token count {t} not divisible by tile {tb}")

    grid = (t // tb, gout)
    return pl.pallas_call(
        functools.partial(_kernel, gin=gin),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tb, gin, nb, b), lambda i, o: (i, 0, 0, 0)),
            pl.BlockSpec((1, gin, nb, b, b), lambda i, o: (o, 0, 0, 0, 0)),
            pl.BlockSpec((1, gin, b, nb, nb), lambda i, o: (o, 0, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((tb, 1, nb, b), lambda i, o: (i, o, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((t, gout, nb, b), x.dtype),
        interpret=interpret,
    )(x, r, l)
