"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = [
    "monarch_bpmm_ref",
    "dft_two_stage_ref",
    "mha_reference",
    "mha_pattern_reference",
    "mha_decode_reference",
]


def monarch_bpmm_ref(x: jax.Array, r: jax.Array, l: jax.Array) -> jax.Array:
    """x: (T, gin, nb, b); r: (gout, gin, nb, b, b); l: (gout, gin, b, nb, nb)
    -> y: (T, gout, nb, b).  Sum over gin, fp32 accumulate."""
    xf = x.astype(jnp.float32)
    u = jnp.einsum("oghij,tghj->toghi", r.astype(jnp.float32), xf)
    y = jnp.einsum("ogjhk,togkj->toghj", l.astype(jnp.float32), u)
    return y.sum(axis=2).astype(x.dtype)


def mha_reference(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int | None = None,
) -> jax.Array:
    """Naive full-score softmax attention (f32).  q: (B, S, H, hd);
    k, v: (B, Skv, KV, hd) with GQA broadcast; returns (B, S, H, hd)."""
    b, s, h, hd = q.shape
    kvh = k.shape[2]
    g = h // kvh
    qr = q.reshape(b, s, kvh, g, hd).astype(jnp.float32)
    scores = jnp.einsum("bqkgd,bskd->bkgqs", qr, k.astype(jnp.float32))
    scores = scores / jnp.sqrt(jnp.float32(hd))
    qpos = jnp.arange(s)[:, None]
    kpos = jnp.arange(k.shape[1])[None, :]
    mask = jnp.ones((s, k.shape[1]), bool)
    if causal:
        mask &= qpos >= kpos
    if window is not None:
        mask &= kpos > qpos - window
    scores = jnp.where(mask[None, None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs, v.astype(jnp.float32))
    return out.reshape(b, s, h, hd).astype(q.dtype)


def mha_pattern_reference(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    mask: jax.Array,
) -> jax.Array:
    """Masked dense oracle for block-sparse attention: naive full-score
    softmax under an explicit (S_q, S_kv) boolean mask — the token-level
    expansion of a :class:`repro.core.sparsity.BlockMap` (causal / window
    fine constraints already folded in).  Differentiable; also serves as the
    sparse kernel's VJP fallback."""
    b, s, h, hd = q.shape
    kvh = k.shape[2]
    g = h // kvh
    qr = q.reshape(b, s, kvh, g, hd).astype(jnp.float32)
    scores = jnp.einsum("bqkgd,bskd->bkgqs", qr, k.astype(jnp.float32))
    scores = scores / jnp.sqrt(jnp.float32(hd))
    scores = jnp.where(jnp.asarray(mask)[None, None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs, v.astype(jnp.float32))
    return out.reshape(b, s, h, hd).astype(q.dtype)


def mha_decode_reference(
    q: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    cur_len: jax.Array | None = None,
) -> jax.Array:
    """One-token oracle.  q: (B, H, hd); caches: (B, S, KV, hd);
    ``cur_len`` scalar or per-row (B,) live lengths."""
    b, h, hd = q.shape
    kvh = k_cache.shape[2]
    qr = q.reshape(b, kvh, h // kvh, hd).astype(jnp.float32)
    scores = jnp.einsum("bkgd,bskd->bkgs", qr, k_cache.astype(jnp.float32))
    scores = scores / jnp.sqrt(jnp.float32(hd))
    if cur_len is not None:
        cl = jnp.asarray(cur_len, jnp.int32).reshape(-1, 1)
        mask = jnp.arange(k_cache.shape[1])[None, :] < cl
        scores = jnp.where(mask[:, None, None, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", probs, v_cache.astype(jnp.float32))
    return out.reshape(b, h, hd).astype(q.dtype)


def dft_two_stage_ref(
    xr: jax.Array, xi: jax.Array | None
) -> tuple[jax.Array, jax.Array]:
    """Full DFT along the last axis via jnp.fft (complex64)."""
    x = xr.astype(jnp.complex64)
    if xi is not None:
        x = x + 1j * xi.astype(jnp.complex64)
    y = jnp.fft.fft(x, axis=-1)
    return jnp.real(y).astype(xr.dtype), jnp.imag(y).astype(xr.dtype)
