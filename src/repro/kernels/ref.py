"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["monarch_bpmm_ref", "dft_two_stage_ref"]


def monarch_bpmm_ref(x: jax.Array, r: jax.Array, l: jax.Array) -> jax.Array:
    """x: (T, gin, nb, b); r: (gout, gin, nb, b, b); l: (gout, gin, b, nb, nb)
    -> y: (T, gout, nb, b).  Sum over gin, fp32 accumulate."""
    xf = x.astype(jnp.float32)
    u = jnp.einsum("oghij,tghj->toghi", r.astype(jnp.float32), xf)
    y = jnp.einsum("ogjhk,togkj->toghj", l.astype(jnp.float32), u)
    return y.sum(axis=2).astype(x.dtype)


def dft_two_stage_ref(
    xr: jax.Array, xi: jax.Array | None
) -> tuple[jax.Array, jax.Array]:
    """Full DFT along the last axis via jnp.fft (complex64)."""
    x = xr.astype(jnp.complex64)
    if xi is not None:
        x = x + 1j * xi.astype(jnp.complex64)
    y = jnp.fft.fft(x, axis=-1)
    return jnp.real(y).astype(xr.dtype), jnp.imag(y).astype(xr.dtype)
