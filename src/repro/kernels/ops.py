"""Jit'd dispatch wrappers: model-facing entry points for the Pallas kernels.

On the CPU host (this container) kernels run in ``interpret=True`` mode; on a
real TPU backend they compile through Mosaic.  The wrappers own padding,
layout flattening, and the multi-stage recursion that chains kernel calls for
transforms larger than one fused two-stage tile.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import sparsity, stage_division as sd
from repro.core.attention import AttentionSpec, truncate_kv_live
from repro.kernels import fft2d, flash_attention as fa, monarch_bpmm

__all__ = [
    "monarch_linear",
    "dft_1d",
    "fnet_mixing_kernel",
    "flash_attention",
    "flash_chunk",
    "flash_decode",
    "flash_paged_prefill",
    "flash_paged_chunk",
    "flash_paged_decode",
]


def _interpret() -> bool:
    return jax.default_backend() == "cpu"


def _pad_axis(x: jax.Array, axis: int, to: int) -> jax.Array:
    pad = to - x.shape[axis]
    if pad == 0:
        return x
    cfg = [(0, 0)] * x.ndim
    cfg[axis] = (0, pad)
    return jnp.pad(x, cfg)


def monarch_linear(params, spec, x: jax.Array) -> jax.Array:
    """Fused-kernel execution of a (possibly sliced) monarch linear layer.

    Same contract as ``repro.core.api._apply_monarch`` — used when
    ``spec.impl == "monarch_kernel"``.
    """
    sp = spec.slices
    r, l = params["r"], params["l"]
    gout, gin, nb, b, _ = r.shape
    lead = x.shape[:-1]
    t = int(np.prod(lead)) if lead else 1
    xf = _pad_axis(x.reshape(t, x.shape[-1]), -1, sp.din_pad)
    xf = xf.reshape(t, gin, nb, b)

    # tile budget from the ACTUAL activation dtype: bf16 tiles are half the
    # bytes of f32, so they fit twice the tokens in the same VMEM budget
    tile = monarch_bpmm.pick_token_tile(
        gin, nb, b, dtype_bytes=jnp.dtype(x.dtype).itemsize
    )
    tpad = -(-t // tile) * tile
    xf = _pad_axis(xf, 0, tpad)
    y = monarch_bpmm.monarch_bpmm(
        xf, r.astype(x.dtype), l.astype(x.dtype), token_tile=tile, interpret=_interpret()
    )
    y = y[:t].reshape(t, sp.dout_pad)[:, : sp.dout]
    return y.reshape(*lead, sp.dout)


def dft_1d(
    xr: jax.Array,
    xi: jax.Array | None = None,
    plan: tuple[int, ...] | None = None,
    max_radix: int = sd.MAX_RADIX_COMPLEX,
) -> tuple[jax.Array, jax.Array]:
    """DFT along the last axis, chaining fused two-stage kernel calls per the
    multi-stage division plan (paper §V-B: a 64K transform = two 256-point
    kernel stages swapped through HBM — here the >2-stage tail recurses)."""
    n = xr.shape[-1]
    plan = tuple(plan) if plan else sd.plan_stages(n, max_radix)
    assert int(np.prod(plan)) == n

    lead = xr.shape[:-1]
    t = int(np.prod(lead)) if lead else 1
    xr2 = xr.reshape(t, n)
    xi2 = None if xi is None else xi.reshape(t, n)

    yr, yi = _dft_rec(xr2, xi2, plan)
    return yr.reshape(*lead, n), yi.reshape(*lead, n)


def _dft_rec(xr, xi, plan):
    t, n = xr.shape
    if len(plan) <= 2:
        n1, n2 = (plan[0], 1) if len(plan) == 1 else plan
        if n2 == 1:  # single dense stage
            w = np.asarray(sd.dft_matrix(n))
            wr, wi = jnp.asarray(w.real), jnp.asarray(w.imag)
            if xi is None:
                return xr @ wr, xr @ wi
            return xr @ wr - xi @ wi, xr @ wi + xi @ wr
        tile = fft2d.pick_token_tile(n, xi is not None)
        tpad = -(-t // tile) * tile
        xr_p = _pad_axis(xr, 0, tpad)
        xi_p = None if xi is None else _pad_axis(xi, 0, tpad)
        yr, yi = fft2d.dft_two_stage(
            xr_p, xi_p, n1=n1, n2=n2, token_tile=tile, interpret=_interpret()
        )
        return yr[:t], yi[:t]

    # outer stage n1 in XLA, inner (tail) stages through the fused kernel
    n1, ntail = plan[0], n // plan[0]
    xr_r = xr.reshape(t, n1, ntail)
    xi_r = None if xi is None else xi.reshape(t, n1, ntail)
    w = np.asarray(sd.dft_matrix(n1))
    wr, wi = jnp.asarray(w.real), jnp.asarray(w.imag)
    # contract n1:  a[t, k1, m] = sum_n x[t, n, m] W[n, k1]
    if xi_r is None:
        ar = jnp.einsum("tnm,nk->tkm", xr_r, wr)
        ai = jnp.einsum("tnm,nk->tkm", xr_r, wi)
    else:
        ar = jnp.einsum("tnm,nk->tkm", xr_r, wr) - jnp.einsum("tnm,nk->tkm", xi_r, wi)
        ai = jnp.einsum("tnm,nk->tkm", xr_r, wi) + jnp.einsum("tnm,nk->tkm", xi_r, wr)
    tw = np.asarray(sd.twiddle(n1, ntail))
    twr, twi = jnp.asarray(tw.real), jnp.asarray(tw.imag)
    br = ar * twr - ai * twi
    bi = ar * twi + ai * twr
    cr, ci = _dft_rec(br.reshape(t * n1, ntail), bi.reshape(t * n1, ntail), plan[1:])
    cr = jnp.swapaxes(cr.reshape(t, n1, ntail), 1, 2).reshape(t, n)
    ci = jnp.swapaxes(ci.reshape(t, n1, ntail), 1, 2).reshape(t, n)
    return cr, ci


# --------------------------------------------------------------------------
# Fused flash attention (AttentionSpec.impl == "flash_kernel")
# --------------------------------------------------------------------------

_LANES = 128


def _round_up(n: int, to: int) -> int:
    return -(-n // to) * to


canonical_pattern = sparsity.canonical_pattern


def _flash_prefill_raw(
    q: jax.Array, k: jax.Array, v: jax.Array,
    causal: bool, window: int | None, q_tile: int, kv_tile: int,
    pattern: str, pattern_arg: int | None,
) -> jax.Array:
    """Layout + padding around the Pallas prefill kernel.

    q: (B, S, H, hd); k, v: (B, Skv, KV, hd) -> (B, S, H, hd).  Head dim pads
    to the 128-lane boundary, sequences pad to the tile grid; padded keys are
    masked inside the kernel, padded query rows are sliced off here.  The
    static block map (pattern liveness + causal/window feasibility) becomes
    the kernel's packed kv-tile index map — dead tiles never enter the grid."""
    b, s, h, hd = q.shape
    skv, kvh = k.shape[1], k.shape[2]
    g = h // kvh
    tq, tk = fa.pick_tiles(s, skv, q_tile, kv_tile)
    sq_pad, skv_pad = _round_up(s, tq), _round_up(skv, tk)
    d = _round_up(hd, _LANES)

    bm = sparsity.build_block_map(
        pattern, s, skv, tq, tk, causal=causal, window=window,
        pattern_arg=pattern_arg,
    )

    qt = q.reshape(b, s, kvh, g, hd).transpose(0, 2, 3, 1, 4).reshape(b * kvh, g, s, hd)
    qt = jnp.pad(qt, ((0, 0), (0, 0), (0, sq_pad - s), (0, d - hd)))
    kt = k.transpose(0, 2, 1, 3).reshape(b * kvh, skv, hd)
    vt = v.transpose(0, 2, 1, 3).reshape(b * kvh, skv, hd)
    kt = jnp.pad(kt, ((0, 0), (0, skv_pad - skv), (0, d - hd)))
    vt = jnp.pad(vt, ((0, 0), (0, skv_pad - skv), (0, d - hd)))

    y = fa.mha_prefill(
        qt, kt, vt, jnp.asarray(bm.kv_index), jnp.asarray(bm.step_live),
        scale=1.0 / math.sqrt(hd), causal=causal, window=window,
        s_q=s, s_kv=skv, q_tile=tq, kv_tile=tk, interpret=_interpret(),
    )
    y = y[:, :, :s, :hd].reshape(b, kvh, g, s, hd)
    return y.transpose(0, 3, 1, 2, 4).reshape(b, s, h, hd)


# The kernel has no Pallas backward; training falls back to differentiating
# the chunked XLA form (recompute — cheap next to the fwd save of score
# traffic, and transient score memory stays bounded to (chunk x prefix),
# unlike the naive full-score oracle).  Pattern-sparse forms differentiate
# the masked dense oracle under the same token mask.
@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7, 8))
def _flash_prefill(q, k, v, causal, window, q_tile, kv_tile, pattern, pattern_arg):
    return _flash_prefill_raw(q, k, v, causal, window, q_tile, kv_tile, pattern, pattern_arg)


def _flash_prefill_fwd(q, k, v, causal, window, q_tile, kv_tile, pattern, pattern_arg):
    y = _flash_prefill_raw(q, k, v, causal, window, q_tile, kv_tile, pattern, pattern_arg)
    return y, (q, k, v)


def _flash_prefill_bwd(causal, window, q_tile, kv_tile, pattern, pattern_arg, res, g):
    # local import: avoids a module-load cycle (models.layers imports this
    # module lazily from inside run_attention)
    from repro.models.layers import chunked_attention

    q, k, v = res
    pmask = None
    if pattern != "dense":
        tq, tk = fa.pick_tiles(q.shape[1], k.shape[1], q_tile, kv_tile)
        bm = sparsity.build_block_map(
            pattern, q.shape[1], k.shape[1], tq, tk, causal=causal,
            window=window, pattern_arg=pattern_arg,
        )
        pmask = sparsity.token_mask(bm)
    # chunked (not the naive oracle): transient score memory stays bounded to
    # (chunk x prefix) — the full-score vjp residual is S^2 per head, OOM in
    # exactly the long-context regime sparse patterns target
    _, vjp = jax.vjp(
        lambda q, k, v: chunked_attention(
            q, k, v, causal=causal, window=window, pattern_mask=pmask
        ),
        q, k, v,
    )
    return vjp(g)


_flash_prefill.defvjp(_flash_prefill_fwd, _flash_prefill_bwd)


def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int | None = None,
    spec: AttentionSpec | None = None,
) -> jax.Array:
    """Fused online-softmax attention.  Same contract as
    ``repro.models.layers.chunked_attention`` (q: (B, S, H, hd); k, v:
    (B, Skv, KV, hd)) — used when ``AttentionSpec.impl == "flash_kernel"``.
    ``spec.pattern`` selects the block-sparsity map the kernel grid iterates."""
    spec = spec or AttentionSpec(impl="flash_kernel")
    pattern, arg, causal, window = canonical_pattern(
        spec.pattern, spec.pattern_arg, causal, window
    )
    return _flash_prefill(q, k, v, causal, window, spec.q_tile, spec.kv_tile, pattern, arg)


def flash_chunk(
    q: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    start: jax.Array,
    ntok: jax.Array,
    *,
    spec: AttentionSpec | None = None,
    kv_live: int | None = None,
) -> jax.Array:
    """Mixed chunked-prefill attention over the shared KV cache.

    q: (B, C, H, hd) — row b's chunk queries at absolute positions
    ``start[b] .. start[b]+C-1``; caches: (B, Skv, KV, hd); ``ntok`` (B,) is
    each row's valid-token count (0 = idle, 1 = decode, >1 = prompt chunk).
    Returns (B, C, H, hd); rows ``i >= ntok[b]`` are garbage the caller never
    reads (the engine gathers logits at ``ntok-1``).

    One kernel serves every row mode: the per-row live kv-tile table
    (:func:`repro.core.sparsity.chunk_live_tables`) is traced data built from
    each row's causal frontier ``start + ntok``, so a decode row streams
    exactly its written (pattern-live) tiles while a mid-prompt row streams
    its chunk's — the grid never visits a dead tile for either."""
    spec = spec or AttentionSpec(impl="flash_kernel")
    pattern, arg, _, window = canonical_pattern(
        spec.pattern, spec.pattern_arg, True, None
    )
    b, c, h, hd = q.shape
    kvh = k_cache.shape[2]
    k_cache, v_cache, skv = truncate_kv_live(k_cache, v_cache, kv_live)
    g = h // kvh
    _, tk = fa.pick_tiles(1, skv, spec.q_tile, spec.kv_tile)
    skv_pad = _round_up(skv, tk)
    d = _round_up(hd, _LANES)
    cp = _round_up(c, 8)

    qt = q.reshape(b, c, kvh, g, hd).transpose(0, 2, 3, 1, 4).reshape(b * kvh, g, c, hd)
    qt = jnp.pad(qt, ((0, 0), (0, 0), (0, cp - c), (0, d - hd)))
    kt = k_cache.transpose(0, 2, 1, 3).reshape(b * kvh, skv, hd)
    vt = v_cache.transpose(0, 2, 1, 3).reshape(b * kvh, skv, hd)
    kt = jnp.pad(kt, ((0, 0), (0, skv_pad - skv), (0, d - hd)))
    vt = jnp.pad(vt, ((0, 0), (0, skv_pad - skv), (0, d - hd)))

    start = jnp.asarray(start, jnp.int32).reshape(-1)
    kv_index, step_live = sparsity.chunk_live_tables(
        pattern, start, ntok, c, skv_pad, spec.q_tile, tk,
        window=window, pattern_arg=arg,
    )
    kv_index = jnp.repeat(kv_index, kvh, axis=0)  # (B*KV, max_live)
    step_live = jnp.repeat(step_live, kvh, axis=0)
    start_rows = jnp.repeat(start, kvh)

    y = fa.mha_chunk(
        qt, kt, vt, start_rows, kv_index, step_live,
        scale=1.0 / math.sqrt(hd), window=window, s_kv=skv,
        q_tile=spec.q_tile, kv_tile=tk, pattern=pattern, pattern_arg=arg,
        interpret=_interpret(),
    )
    y = y[:, :, :c, :hd].reshape(b, kvh, g, c, hd)
    return y.transpose(0, 3, 1, 2, 4).reshape(b, c, h, hd)


def flash_decode(
    q: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    cur_len: jax.Array | None = None,
    *,
    spec: AttentionSpec | None = None,
    kv_live: int | None = None,
) -> jax.Array:
    """Flash-decode over a KV cache: partial max/sum-exp combine across kv
    tiles in VMEM.  q: (B, H, hd); caches: (B, S, KV, hd) -> (B, H, hd).
    ``cur_len`` masks cache rows not yet written: a traced scalar applies one
    length to the whole batch, a (B,) vector gives every request its own live
    length (ragged continuous batching).

    True tile skipping, two mechanisms:
    * ``kv_live`` (static, host-known bound on every row's live length — the
      serve engine's bucketed ``max(pos)+1``) truncates the streamed cache to
      its first ``kv_live`` rows before the kernel: a 128-token request on a
      16k cache reads 1 kv tile, not 128.
    * ``spec.pattern`` builds a *per-row* live kv-tile table from ``cur_len``
      (the decoding token's pattern row), so the grid's kv extent is the
      pattern's static worst case (O(log n) tiles for butterfly) and each row
      visits only its own live tiles."""
    spec = spec or AttentionSpec(impl="flash_kernel")
    pattern, arg, _, window = canonical_pattern(
        spec.pattern, spec.pattern_arg, True, None
    )
    b, h, hd = q.shape
    kvh = k_cache.shape[2]
    # static truncation: rows beyond every request's live length are
    # sliced out of the stream entirely (the bias would only mask them)
    k_cache, v_cache, skv = truncate_kv_live(k_cache, v_cache, kv_live)
    g = h // kvh
    _, tk = fa.pick_tiles(1, skv, spec.q_tile, spec.kv_tile)
    skv_pad = _round_up(skv, tk)
    d = _round_up(hd, _LANES)
    gp = _round_up(g, 8)

    qt = jnp.pad(q.reshape(b, kvh, g, hd), ((0, 0), (0, 0), (0, gp - g), (0, d - hd)))
    qt = qt.reshape(b * kvh, gp, d)
    kt = k_cache.transpose(0, 2, 1, 3).reshape(b * kvh, skv, hd)
    vt = v_cache.transpose(0, 2, 1, 3).reshape(b * kvh, skv, hd)
    kt = jnp.pad(kt, ((0, 0), (0, skv_pad - skv), (0, d - hd)))
    vt = jnp.pad(vt, ((0, 0), (0, skv_pad - skv), (0, d - hd)))

    if cur_len is None:
        cl_rows = jnp.full((b,), skv, jnp.int32)
    else:  # scalar broadcasts; (B,) stays per-row
        cl_rows = jnp.broadcast_to(jnp.asarray(cur_len, jnp.int32).reshape(-1), (b,))
    kpos = jnp.arange(skv_pad)
    valid = (kpos[None, :] < skv) & (kpos[None, :] < cl_rows[:, None])  # (B, Skv_pad)
    if window is not None:  # fine window edge (matches the prefill mask)
        valid &= kpos[None, :] > cl_rows[:, None] - 1 - window
    bias = jnp.where(valid, 0.0, fa.NEG_INF).astype(jnp.float32)
    # one validity row per (batch, kv_head) grid row
    bias = jnp.broadcast_to(bias[:, None, :], (b, kvh, skv_pad)).reshape(
        b * kvh, skv_pad
    )

    # per-row live kv-tile tables: each request streams only the cache tiles
    # that are written AND pattern-live for its own position
    kv_index, step_live = sparsity.decode_live_tables(
        pattern, cl_rows, skv_pad, spec.q_tile, tk, window=window, pattern_arg=arg
    )
    kv_index = jnp.repeat(kv_index, kvh, axis=0)  # (B*KV, max_live)
    step_live = jnp.repeat(step_live, kvh, axis=0)

    y = fa.mha_decode(
        qt, kt, vt, bias, kv_index, step_live,
        scale=1.0 / math.sqrt(hd), kv_tile=tk, interpret=_interpret(),
    )
    return y.reshape(b, kvh, gp, d)[:, :, :g, :hd].reshape(b, h, hd)


# --------------------------------------------------------------------------
# Paged cache forms: the kernels stream a batch-shared page pool through the
# translated (physical-page) live tables — same grids, redirected DMA
# --------------------------------------------------------------------------


def _pool_layout(k_pool: jax.Array, v_pool: jax.Array, page: int):
    """(P*page, KV, hd) pool -> kernel layout (KV, P*page, D_pad) + counts."""
    rows, kvh, hd = k_pool.shape
    if rows % page:
        raise ValueError(f"pool rows {rows} not a page multiple ({page})")
    d = _round_up(hd, _LANES)
    kt = jnp.swapaxes(k_pool, 0, 1)
    vt = jnp.swapaxes(v_pool, 0, 1)
    kt = jnp.pad(kt, ((0, 0), (0, 0), (0, d - hd)))
    vt = jnp.pad(vt, ((0, 0), (0, 0), (0, d - hd)))
    return kt, vt, rows // page, d


def _scale_layout(k_scale, v_scale):
    """(P*page, KV) per-row dequant scales -> kernel layout (KV, P*page) f32
    — the scale analogue of :func:`_pool_layout` (no head_dim axis to pad;
    the scale tile rides the page index map, so lanes are the page rows)."""
    if k_scale is None:
        return None, None
    return (
        jnp.swapaxes(k_scale, 0, 1).astype(jnp.float32),
        jnp.swapaxes(v_scale, 0, 1).astype(jnp.float32),
    )


def _virtual_extent(page_table: jax.Array, page: int, kv_live: int | None) -> int:
    """Static virtual cache length the tables cover: the page table's full
    span, truncated to the engine's bucketed ``kv_live`` bound (rounded up to
    a whole page — tables are tile-granular)."""
    vl = page_table.shape[-1] * page
    if kv_live is not None:
        vl = min(vl, _round_up(max(int(kv_live), 1), page))
    return vl


def _local_pool_bound(page_range: tuple[int, int], n_local: int) -> int:
    """Sanity-check a mesh-local call: the pool passed in must be exactly the
    shard ``page_range`` names, and the translation's in-bounds check runs
    against ``hi`` (the sentinel is >= the global page count >= hi, so the
    ownership mask subsumes the allocated mask)."""
    lo, hi = page_range
    if hi - lo != n_local:
        raise ValueError(
            f"page_range {page_range} names {hi - lo} pages but the local "
            f"pool holds {n_local}"
        )
    return hi


def flash_paged_prefill(
    q: jax.Array,
    k_pool: jax.Array,
    v_pool: jax.Array,
    page_table: jax.Array,
    *,
    page: int,
    spec: AttentionSpec | None = None,
    k_scale: jax.Array | None = None,
    v_scale: jax.Array | None = None,
) -> jax.Array:
    """Fused prefill attention reading prompt KV back through the page pool.

    q: (1, S, H, hd) — one admitted request's (bucketed) prompt, positions
    0..S-1; ``k_pool`` / ``v_pool``: (n_pages * page, KV, hd) the global
    pool, already holding this prompt's KV (the model layer scatters before
    attention); ``page_table``: (n_vtiles,) this request's virtual-tile ->
    physical-page map.  The static block map over the prompt translates to
    physical page ids, so the prefill grid streams pool pages directly —
    batch-1 because the table is shared across grid rows, which is exactly
    the admission engine's shape.  ``k_scale`` / ``v_scale`` ((n_pages *
    page, KV) f32 or None) are a quantized pool's per-row dequant scales —
    forwarded through the same page indirection."""
    spec = spec or AttentionSpec(impl="flash_kernel")
    pattern, arg, causal, window = canonical_pattern(
        spec.pattern, spec.pattern_arg, True, None
    )
    b, s, h, hd = q.shape
    if b != 1:
        raise ValueError(
            f"paged prefill is batch-1 (shared block map), got batch {b}"
        )
    kvh = k_pool.shape[1]
    g = h // kvh
    kt, vt, n_pages, d = _pool_layout(k_pool, v_pool, page)
    tq, _ = fa.pick_tiles(s, s, spec.q_tile, spec.kv_tile)
    sq_pad = _round_up(s, tq)

    bm = sparsity.build_block_map(
        pattern, s, s, tq, page, causal=causal, window=window, pattern_arg=arg
    )
    kv_phys, kv_virt, step_live = sparsity.translate_tables(
        jnp.asarray(bm.kv_index), jnp.asarray(bm.step_live),
        jnp.asarray(page_table, jnp.int32).reshape(-1), n_pages,
    )

    qt = q.reshape(1, s, kvh, g, hd).transpose(0, 2, 3, 1, 4).reshape(kvh, g, s, hd)
    qt = jnp.pad(qt, ((0, 0), (0, 0), (0, sq_pad - s), (0, d - hd)))

    ks, vs = _scale_layout(k_scale, v_scale)
    y = fa.mha_prefill(
        qt, kt, vt, kv_phys, step_live,
        scale=1.0 / math.sqrt(hd), causal=causal, window=window,
        s_q=s, s_kv=s, q_tile=tq, kv_tile=page, interpret=_interpret(),
        kv_virt=kv_virt, k_scale=ks, v_scale=vs,
    )
    y = y[:, :, :s, :hd].reshape(1, kvh, g, s, hd)
    return y.transpose(0, 3, 1, 2, 4).reshape(1, s, h, hd)


def flash_paged_chunk(
    q: jax.Array,
    k_pool: jax.Array,
    v_pool: jax.Array,
    start: jax.Array,
    ntok: jax.Array,
    page_table: jax.Array,
    *,
    page: int,
    spec: AttentionSpec | None = None,
    kv_live: int | None = None,
    ring_window: int | None = None,
    ring_tiles: int | None = None,
    page_range: tuple[int, int] | None = None,
    k_scale: jax.Array | None = None,
    v_scale: jax.Array | None = None,
) -> jax.Array:
    """Paged form of :func:`flash_chunk`: q (B, C, H, hd) mixed rows over the
    shared pool (n_pages * page, KV, hd), each row reading through its own
    ``page_table`` row (B, n_vtiles).  The per-row chunk tables are built in
    VIRTUAL tile space (identical liveness to the contiguous engine) and
    translated to physical pages — the kernel grid never visits a dead or
    unallocated tile, and ``kv_live`` buckets the virtual extent exactly as
    the contiguous path buckets its cache truncation.

    ``ring_window`` / ``ring_tiles`` select the mod-window form: the page
    table has ``ring_tiles`` slots reused in phase, the live tables hold
    ABSOLUTE tiles trailing each row's frontier, and the fine mask windows on
    absolute positions — a sliding-window cache in ``ring_tiles`` pages.

    ``page_range=(lo, hi)`` runs the MESH-LOCAL form: the pools are ONE shard
    of a page-sharded cache (pages ``lo..hi-1``), the translated tables mask
    out pages the shard does not own and rebase the rest, so this shard's
    grid prefetches only its own pages.  The result is the shard's partial
    attention over its local pages; cross-shard reassembly needs the online-
    softmax stat merge (a ring/allgather of (m, l, acc)), which is the
    remaining hardware-shakeout item — the serving gate exercises the XLA
    gather path, whose per-shard gathers reassemble by summation."""
    spec = spec or AttentionSpec(impl="flash_kernel")
    pattern, arg, _, window = canonical_pattern(
        spec.pattern, spec.pattern_arg, True, None
    )
    b, c, h, hd = q.shape
    kvh = k_pool.shape[1]
    g = h // kvh
    kt, vt, n_pages, d = _pool_layout(k_pool, v_pool, page)
    cp = _round_up(c, 8)

    start = jnp.asarray(start, jnp.int32).reshape(-1)
    if ring_tiles is not None:
        # ring rows mask purely by causal frontier + absolute window; the
        # virtual extent must cover absolute positions, not the ring span
        pattern, arg = "dense", None
        window = ring_window if window is None else min(window, ring_window)
        skv = _round_up(max(int(kv_live or 1), 1), page)
        kv_index, step_live = sparsity.ring_chunk_tables(
            start, ntok, c, window, page, ring_tiles
        )
    else:
        skv = _virtual_extent(page_table, page, kv_live)
        kv_index, step_live = sparsity.chunk_live_tables(
            pattern, start, ntok, c, skv, spec.q_tile, page,
            window=window, pattern_arg=arg,
        )
    if page_range is not None:
        n_pages = _local_pool_bound(page_range, n_pages)
    kv_phys, kv_virt, step_live = sparsity.translate_tables(
        kv_index, step_live, page_table, n_pages, ring_tiles=ring_tiles,
        page_range=page_range,
    )

    qt = q.reshape(b, c, kvh, g, hd).transpose(0, 2, 3, 1, 4)
    qt = jnp.pad(qt, ((0, 0), (0, 0), (0, 0), (0, cp - c), (0, d - hd)))

    ks, vs = _scale_layout(k_scale, v_scale)
    y = fa.mha_chunk_paged(
        qt, kt, vt, start, kv_phys, kv_virt, step_live,
        scale=1.0 / math.sqrt(hd), window=window, s_kv=skv,
        q_tile=spec.q_tile, kv_tile=page, pattern=pattern, pattern_arg=arg,
        interpret=_interpret(), k_scale=ks, v_scale=vs,
    )
    y = y[:, :, :, :c, :hd]
    return y.transpose(0, 3, 1, 2, 4).reshape(b, c, h, hd)


def flash_paged_decode(
    q: jax.Array,
    k_pool: jax.Array,
    v_pool: jax.Array,
    cur_len: jax.Array,
    page_table: jax.Array,
    *,
    page: int,
    spec: AttentionSpec | None = None,
    kv_live: int | None = None,
    ring_window: int | None = None,
    ring_tiles: int | None = None,
    page_range: tuple[int, int] | None = None,
    k_scale: jax.Array | None = None,
    v_scale: jax.Array | None = None,
) -> jax.Array:
    """Paged form of :func:`flash_decode`: q (B, H, hd) over the shared pool.

    Each row's per-position live-tile table (the same
    :func:`repro.core.sparsity.decode_live_tables` the contiguous kernel
    prefetches) is translated to physical page ids; the fine mask runs on
    the virtual positions, so a freed or never-allocated tile is simply
    absent and the softmax matches the contiguous engine bit-for-bit.

    ``ring_window`` / ``ring_tiles`` select the mod-window form: positions
    are unbounded (``cur_len`` may exceed any cache extent), the live tables
    hold the absolute tiles trailing the frontier, and the same-modulus page
    table hands back the phase-reused physical pages.

    ``page_range`` selects the mesh-local form (see
    :func:`flash_paged_chunk`): the pools are one page shard, tables mask
    and rebase to the shard's own pages."""
    spec = spec or AttentionSpec(impl="flash_kernel")
    pattern, arg, _, window = canonical_pattern(
        spec.pattern, spec.pattern_arg, True, None
    )
    b, h, hd = q.shape
    kvh = k_pool.shape[1]
    g = h // kvh
    kt, vt, n_pages, d = _pool_layout(k_pool, v_pool, page)
    gp = _round_up(g, 8)

    cl_rows = jnp.broadcast_to(jnp.asarray(cur_len, jnp.int32).reshape(-1), (b,))
    if ring_tiles is not None:
        window = ring_window if window is None else min(window, ring_window)
        kv_index, step_live = sparsity.ring_decode_tables(
            cl_rows, window, page, ring_tiles
        )
    else:
        skv = _virtual_extent(page_table, page, kv_live)
        kv_index, step_live = sparsity.decode_live_tables(
            pattern, cl_rows, skv, spec.q_tile, page, window=window, pattern_arg=arg
        )
    if page_range is not None:
        n_pages = _local_pool_bound(page_range, n_pages)
    kv_phys, kv_virt, step_live = sparsity.translate_tables(
        kv_index, step_live, page_table, n_pages, ring_tiles=ring_tiles,
        page_range=page_range,
    )

    qt = jnp.pad(q.reshape(b, kvh, g, hd), ((0, 0), (0, 0), (0, gp - g), (0, d - hd)))

    ks, vs = _scale_layout(k_scale, v_scale)
    y = fa.mha_decode_paged(
        qt, kt, vt, cl_rows, kv_phys, kv_virt, step_live,
        scale=1.0 / math.sqrt(hd), window=window, kv_tile=page,
        interpret=_interpret(), k_scale=ks, v_scale=vs,
    )
    return y[:, :, :g, :hd].reshape(b, h, hd)


def fnet_mixing_kernel(x: jax.Array, max_radix: int = sd.MAX_RADIX_COMPLEX) -> jax.Array:
    """Kernel-backed FNet mixing: Re(DFT_seq(DFT_hidden(x))) over the last two
    axes — the AT-all replacement running through the fused pipeline."""
    seq, hid = x.shape[-2], x.shape[-1]
    yr, yi = dft_1d(x, None, sd.plan_stages(hid, max_radix))
    yr2 = jnp.swapaxes(yr, -1, -2)
    yi2 = jnp.swapaxes(yi, -1, -2)
    zr, _ = dft_1d(yr2, yi2, sd.plan_stages(seq, max_radix))
    return jnp.swapaxes(zr, -1, -2)
