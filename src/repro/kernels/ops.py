"""Jit'd dispatch wrappers: model-facing entry points for the Pallas kernels.

On the CPU host (this container) kernels run in ``interpret=True`` mode; on a
real TPU backend they compile through Mosaic.  The wrappers own padding,
layout flattening, and the multi-stage recursion that chains kernel calls for
transforms larger than one fused two-stage tile.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import stage_division as sd
from repro.core.attention import AttentionSpec
from repro.kernels import fft2d, flash_attention as fa, monarch_bpmm

__all__ = [
    "monarch_linear",
    "dft_1d",
    "fnet_mixing_kernel",
    "flash_attention",
    "flash_decode",
]


def _interpret() -> bool:
    return jax.default_backend() == "cpu"


def _pad_axis(x: jax.Array, axis: int, to: int) -> jax.Array:
    pad = to - x.shape[axis]
    if pad == 0:
        return x
    cfg = [(0, 0)] * x.ndim
    cfg[axis] = (0, pad)
    return jnp.pad(x, cfg)


def monarch_linear(params, spec, x: jax.Array) -> jax.Array:
    """Fused-kernel execution of a (possibly sliced) monarch linear layer.

    Same contract as ``repro.core.api._apply_monarch`` — used when
    ``spec.impl == "monarch_kernel"``.
    """
    sp = spec.slices
    r, l = params["r"], params["l"]
    gout, gin, nb, b, _ = r.shape
    lead = x.shape[:-1]
    t = int(np.prod(lead)) if lead else 1
    xf = _pad_axis(x.reshape(t, x.shape[-1]), -1, sp.din_pad)
    xf = xf.reshape(t, gin, nb, b)

    tile = monarch_bpmm.pick_token_tile(gin, nb, b)
    tpad = -(-t // tile) * tile
    xf = _pad_axis(xf, 0, tpad)
    y = monarch_bpmm.monarch_bpmm(
        xf, r.astype(x.dtype), l.astype(x.dtype), token_tile=tile, interpret=_interpret()
    )
    y = y[:t].reshape(t, sp.dout_pad)[:, : sp.dout]
    return y.reshape(*lead, sp.dout)


def dft_1d(
    xr: jax.Array,
    xi: jax.Array | None = None,
    plan: tuple[int, ...] | None = None,
    max_radix: int = sd.MAX_RADIX_COMPLEX,
) -> tuple[jax.Array, jax.Array]:
    """DFT along the last axis, chaining fused two-stage kernel calls per the
    multi-stage division plan (paper §V-B: a 64K transform = two 256-point
    kernel stages swapped through HBM — here the >2-stage tail recurses)."""
    n = xr.shape[-1]
    plan = tuple(plan) if plan else sd.plan_stages(n, max_radix)
    assert int(np.prod(plan)) == n

    lead = xr.shape[:-1]
    t = int(np.prod(lead)) if lead else 1
    xr2 = xr.reshape(t, n)
    xi2 = None if xi is None else xi.reshape(t, n)

    yr, yi = _dft_rec(xr2, xi2, plan)
    return yr.reshape(*lead, n), yi.reshape(*lead, n)


def _dft_rec(xr, xi, plan):
    t, n = xr.shape
    if len(plan) <= 2:
        n1, n2 = (plan[0], 1) if len(plan) == 1 else plan
        if n2 == 1:  # single dense stage
            w = np.asarray(sd.dft_matrix(n))
            wr, wi = jnp.asarray(w.real), jnp.asarray(w.imag)
            if xi is None:
                return xr @ wr, xr @ wi
            return xr @ wr - xi @ wi, xr @ wi + xi @ wr
        tile = fft2d.pick_token_tile(n, xi is not None)
        tpad = -(-t // tile) * tile
        xr_p = _pad_axis(xr, 0, tpad)
        xi_p = None if xi is None else _pad_axis(xi, 0, tpad)
        yr, yi = fft2d.dft_two_stage(
            xr_p, xi_p, n1=n1, n2=n2, token_tile=tile, interpret=_interpret()
        )
        return yr[:t], yi[:t]

    # outer stage n1 in XLA, inner (tail) stages through the fused kernel
    n1, ntail = plan[0], n // plan[0]
    xr_r = xr.reshape(t, n1, ntail)
    xi_r = None if xi is None else xi.reshape(t, n1, ntail)
    w = np.asarray(sd.dft_matrix(n1))
    wr, wi = jnp.asarray(w.real), jnp.asarray(w.imag)
    # contract n1:  a[t, k1, m] = sum_n x[t, n, m] W[n, k1]
    if xi_r is None:
        ar = jnp.einsum("tnm,nk->tkm", xr_r, wr)
        ai = jnp.einsum("tnm,nk->tkm", xr_r, wi)
    else:
        ar = jnp.einsum("tnm,nk->tkm", xr_r, wr) - jnp.einsum("tnm,nk->tkm", xi_r, wi)
        ai = jnp.einsum("tnm,nk->tkm", xr_r, wi) + jnp.einsum("tnm,nk->tkm", xi_r, wr)
    tw = np.asarray(sd.twiddle(n1, ntail))
    twr, twi = jnp.asarray(tw.real), jnp.asarray(tw.imag)
    br = ar * twr - ai * twi
    bi = ar * twi + ai * twr
    cr, ci = _dft_rec(br.reshape(t * n1, ntail), bi.reshape(t * n1, ntail), plan[1:])
    cr = jnp.swapaxes(cr.reshape(t, n1, ntail), 1, 2).reshape(t, n)
    ci = jnp.swapaxes(ci.reshape(t, n1, ntail), 1, 2).reshape(t, n)
    return cr, ci


# --------------------------------------------------------------------------
# Fused flash attention (AttentionSpec.impl == "flash_kernel")
# --------------------------------------------------------------------------

_LANES = 128


def _round_up(n: int, to: int) -> int:
    return -(-n // to) * to


def _flash_prefill_raw(
    q: jax.Array, k: jax.Array, v: jax.Array,
    causal: bool, window: int | None, q_tile: int, kv_tile: int,
) -> jax.Array:
    """Layout + padding around the Pallas prefill kernel.

    q: (B, S, H, hd); k, v: (B, Skv, KV, hd) -> (B, S, H, hd).  Head dim pads
    to the 128-lane boundary, sequences pad to the tile grid; padded keys are
    masked inside the kernel, padded query rows are sliced off here."""
    b, s, h, hd = q.shape
    skv, kvh = k.shape[1], k.shape[2]
    g = h // kvh
    tq, tk = fa.pick_tiles(s, skv, q_tile, kv_tile)
    sq_pad, skv_pad = _round_up(s, tq), _round_up(skv, tk)
    d = _round_up(hd, _LANES)

    qt = q.reshape(b, s, kvh, g, hd).transpose(0, 2, 3, 1, 4).reshape(b * kvh, g, s, hd)
    qt = jnp.pad(qt, ((0, 0), (0, 0), (0, sq_pad - s), (0, d - hd)))
    kt = k.transpose(0, 2, 1, 3).reshape(b * kvh, skv, hd)
    vt = v.transpose(0, 2, 1, 3).reshape(b * kvh, skv, hd)
    kt = jnp.pad(kt, ((0, 0), (0, skv_pad - skv), (0, d - hd)))
    vt = jnp.pad(vt, ((0, 0), (0, skv_pad - skv), (0, d - hd)))

    y = fa.mha_prefill(
        qt, kt, vt, scale=1.0 / math.sqrt(hd), causal=causal, window=window,
        s_q=s, s_kv=skv, q_tile=tq, kv_tile=tk, interpret=_interpret(),
    )
    y = y[:, :, :s, :hd].reshape(b, kvh, g, s, hd)
    return y.transpose(0, 3, 1, 2, 4).reshape(b, s, h, hd)


# The kernel has no Pallas backward; training falls back to differentiating
# the chunked XLA form (recompute — cheap next to the fwd save of score
# traffic, and transient score memory stays bounded to (chunk x prefix),
# unlike the naive full-score oracle).
@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def _flash_prefill(q, k, v, causal, window, q_tile, kv_tile):
    return _flash_prefill_raw(q, k, v, causal, window, q_tile, kv_tile)


def _flash_prefill_fwd(q, k, v, causal, window, q_tile, kv_tile):
    return _flash_prefill_raw(q, k, v, causal, window, q_tile, kv_tile), (q, k, v)


def _flash_prefill_bwd(causal, window, q_tile, kv_tile, res, g):
    # local import: avoids a module-load cycle (models.layers imports this
    # module lazily from inside run_attention)
    from repro.models.layers import chunked_attention

    q, k, v = res
    _, vjp = jax.vjp(
        lambda q, k, v: chunked_attention(q, k, v, causal=causal, window=window),
        q, k, v,
    )
    return vjp(g)


_flash_prefill.defvjp(_flash_prefill_fwd, _flash_prefill_bwd)


def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int | None = None,
    spec: AttentionSpec | None = None,
) -> jax.Array:
    """Fused online-softmax attention.  Same contract as
    ``repro.models.layers.chunked_attention`` (q: (B, S, H, hd); k, v:
    (B, Skv, KV, hd)) — used when ``AttentionSpec.impl == "flash_kernel"``."""
    spec = spec or AttentionSpec(impl="flash_kernel")
    return _flash_prefill(q, k, v, causal, window, spec.q_tile, spec.kv_tile)


def flash_decode(
    q: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    cur_len: jax.Array | None = None,
    *,
    spec: AttentionSpec | None = None,
) -> jax.Array:
    """Flash-decode over a KV cache: partial max/sum-exp combine across kv
    tiles in VMEM.  q: (B, H, hd); caches: (B, S, KV, hd) -> (B, H, hd).
    ``cur_len`` masks cache rows not yet written: a traced scalar applies one
    length to the whole batch, a (B,) vector gives every request its own live
    length (ragged continuous batching)."""
    spec = spec or AttentionSpec(impl="flash_kernel")
    b, h, hd = q.shape
    skv, kvh = k_cache.shape[1], k_cache.shape[2]
    g = h // kvh
    _, tk = fa.pick_tiles(1, skv, spec.q_tile, spec.kv_tile)
    skv_pad = _round_up(skv, tk)
    d = _round_up(hd, _LANES)
    gp = _round_up(g, 8)

    qt = jnp.pad(q.reshape(b, kvh, g, hd), ((0, 0), (0, 0), (0, gp - g), (0, d - hd)))
    qt = qt.reshape(b * kvh, gp, d)
    kt = k_cache.transpose(0, 2, 1, 3).reshape(b * kvh, skv, hd)
    vt = v_cache.transpose(0, 2, 1, 3).reshape(b * kvh, skv, hd)
    kt = jnp.pad(kt, ((0, 0), (0, skv_pad - skv), (0, d - hd)))
    vt = jnp.pad(vt, ((0, 0), (0, skv_pad - skv), (0, d - hd)))

    kpos = jnp.arange(skv_pad)
    valid = (kpos < skv)[None, :]  # (1, Skv_pad)
    if cur_len is not None:
        cl = jnp.asarray(cur_len, jnp.int32).reshape(-1, 1)  # scalar | (B, 1)
        valid = valid & (kpos[None, :] < cl)
    bias = jnp.where(valid, 0.0, fa.NEG_INF).astype(jnp.float32)
    # one validity row per (batch, kv_head) grid row
    bias = jnp.broadcast_to(bias[:, None, :], (b, kvh, skv_pad)).reshape(
        b * kvh, skv_pad
    )

    y = fa.mha_decode(
        qt, kt, vt, bias, scale=1.0 / math.sqrt(hd), kv_tile=tk,
        interpret=_interpret(),
    )
    return y.reshape(b, kvh, gp, d)[:, :, :g, :hd].reshape(b, h, hd)


def fnet_mixing_kernel(x: jax.Array, max_radix: int = sd.MAX_RADIX_COMPLEX) -> jax.Array:
    """Kernel-backed FNet mixing: Re(DFT_seq(DFT_hidden(x))) over the last two
    axes — the AT-all replacement running through the fused pipeline."""
    seq, hid = x.shape[-2], x.shape[-1]
    yr, yi = dft_1d(x, None, sd.plan_stages(hid, max_radix))
    yr2 = jnp.swapaxes(yr, -1, -2)
    yi2 = jnp.swapaxes(yi, -1, -2)
    zr, _ = dft_1d(yr2, yi2, sd.plan_stages(seq, max_radix))
    return jnp.swapaxes(zr, -1, -2)
