"""Fused two-stage DFT kernel (Pallas, TPU target) — paper Fig. 9 on VMEM.

One grid step executes the whole multi-stage division pipeline for a token
tile with the working set VMEM-resident: reshape ``n = n1 * n2``, stage-1
DFT_n1 (MXU matmul contracting the n1 axis), twiddle (VPU element-wise),
stage-2 DFT_n2 (MXU matmul contracting the n2 axis), digit-reversal transpose
in-register.  The two stages contract *different* axes of the same resident
tile — the transpose-free multi-line-SPM trick (§V-C) expressed through
dot_general dimension numbers instead of SRAM bank lines.

Complex arithmetic is carried as (re, im) planes (TPU is real-valued);
complex x complex matmuls use the 3-multiplication Karatsuba split, so a full
complex stage costs 3 real MXU passes instead of 4 — this is where the
paper's observation that FFT doubles Flow traffic vs real BPMM (§VI-D) turns
into an actual FLOP saving on TPU.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.core import stage_division as sd

__all__ = ["dft_two_stage", "pick_token_tile"]


def pick_token_tile(n: int, complex_in: bool) -> int:
    planes = 6 + (2 if complex_in else 1)
    per_token = planes * n * 4
    budget = 12 * 1024 * 1024
    tile = budget // max(per_token, 1)
    for cand in (256, 128, 64, 32, 16, 8):
        if cand <= tile:
            return cand
    return 8


def _cmatmul(ar, ai, wr, wi):
    """(ar + i·ai) @ (wr + i·wi) with Karatsuba (3 real matmuls)."""
    m1 = jnp.dot(ar, wr, preferred_element_type=jnp.float32)
    m2 = jnp.dot(ai, wi, preferred_element_type=jnp.float32)
    m3 = jnp.dot(ar + ai, wr + wi, preferred_element_type=jnp.float32)
    return m1 - m2, m3 - m1 - m2


def _kernel(
    xr_ref, xi_ref, w1r_ref, w1i_ref, tr_ref, ti_ref, w2r_ref, w2i_ref,
    yr_ref, yi_ref, *, n1: int, n2: int, complex_in: bool,
):
    tb = xr_ref.shape[0]
    xr = xr_ref[...].astype(jnp.float32).reshape(tb, n1, n2)
    w1r = w1r_ref[...].astype(jnp.float32)
    w1i = w1i_ref[...].astype(jnp.float32)
    # ---- stage 1: contract the n1 axis:  a[t, k1, m] = sum_n x[t, n, m] W1[n, k1]
    xrt = jnp.swapaxes(xr, 1, 2).reshape(tb * n2, n1)
    if complex_in:
        xi = xi_ref[...].astype(jnp.float32).reshape(tb, n1, n2)
        xit = jnp.swapaxes(xi, 1, 2).reshape(tb * n2, n1)
        ar, ai = _cmatmul(xrt, xit, w1r, w1i)
    else:
        ar = jnp.dot(xrt, w1r, preferred_element_type=jnp.float32)
        ai = jnp.dot(xrt, w1i, preferred_element_type=jnp.float32)
    ar = jnp.swapaxes(ar.reshape(tb, n2, n1), 1, 2)  # (tb, k1, n2)
    ai = jnp.swapaxes(ai.reshape(tb, n2, n1), 1, 2)
    # ---- twiddle (element-wise, fused on the VMEM-resident tile)
    tr = tr_ref[...].astype(jnp.float32)
    ti = ti_ref[...].astype(jnp.float32)
    br = ar * tr - ai * ti
    bi = ar * ti + ai * tr
    # ---- stage 2: contract the n2 axis:  c[t, k1, k2] = sum_m b[t, k1, m] W2[m, k2]
    w2r = w2r_ref[...].astype(jnp.float32)
    w2i = w2i_ref[...].astype(jnp.float32)
    cr, ci = _cmatmul(br.reshape(tb * n1, n2), bi.reshape(tb * n1, n2), w2r, w2i)
    cr = cr.reshape(tb, n1, n2)
    ci = ci.reshape(tb, n1, n2)
    # ---- digit reversal: k = k1 + n1*k2  ->  layout (k2, k1), in-register
    yr_ref[...] = jnp.swapaxes(cr, 1, 2).reshape(tb, n1 * n2).astype(yr_ref.dtype)
    yi_ref[...] = jnp.swapaxes(ci, 1, 2).reshape(tb, n1 * n2).astype(yi_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("n1", "n2", "token_tile", "interpret")
)
def dft_two_stage(
    xr: jax.Array,
    xi: jax.Array | None,
    *,
    n1: int,
    n2: int,
    token_tile: int | None = None,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """DFT along the last axis of (T, n1*n2) -> (re, im), fused two stages."""
    t, n = xr.shape
    assert n == n1 * n2, (n, n1, n2)
    complex_in = xi is not None
    tb = token_tile or pick_token_tile(n, complex_in)
    if t % tb:
        raise ValueError(f"token count {t} not divisible by tile {tb}")

    w1 = np.asarray(sd.dft_matrix(n1))  # applied as x @ W1 (symmetric)
    w2 = np.asarray(sd.dft_matrix(n2))
    tw = np.asarray(sd.twiddle(n1, n2))
    consts = [
        jnp.asarray(w1.real), jnp.asarray(w1.imag),
        jnp.asarray(tw.real), jnp.asarray(tw.imag),
        jnp.asarray(w2.real), jnp.asarray(w2.imag),
    ]
    if xi is None:
        xi_in = jnp.zeros((1, 1), xr.dtype)  # placeholder, never read
        xi_spec = pl.BlockSpec((1, 1), lambda i: (0, 0))
    else:
        xi_in = xi
        xi_spec = pl.BlockSpec((tb, n), lambda i: (i, 0))

    grid = (t // tb,)
    const_specs = [
        pl.BlockSpec((n1, n1), lambda i: (0, 0)),
        pl.BlockSpec((n1, n1), lambda i: (0, 0)),
        pl.BlockSpec((n1, n2), lambda i: (0, 0)),
        pl.BlockSpec((n1, n2), lambda i: (0, 0)),
        pl.BlockSpec((n2, n2), lambda i: (0, 0)),
        pl.BlockSpec((n2, n2), lambda i: (0, 0)),
    ]
    yr, yi = pl.pallas_call(
        functools.partial(_kernel, n1=n1, n2=n2, complex_in=complex_in),
        grid=grid,
        in_specs=[pl.BlockSpec((tb, n), lambda i: (i, 0)), xi_spec, *const_specs],
        out_specs=[
            pl.BlockSpec((tb, n), lambda i: (i, 0)),
            pl.BlockSpec((tb, n), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((t, n), xr.dtype),
            jax.ShapeDtypeStruct((t, n), xr.dtype),
        ],
        interpret=interpret,
    )(xr, xi_in, *consts)
    return yr, yi
