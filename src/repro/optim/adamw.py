"""AdamW with global-norm clipping and optional bf16 moments (for >300B
models the optimizer state halves; stochastic-rounding-free bf16 moments are
accurate enough at our betas)."""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

__all__ = ["AdamWConfig", "adamw_init", "adamw_update", "global_norm"]


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    moment_dtype: str = "float32"  # float32 | bfloat16


def adamw_init(params, cfg: AdamWConfig):
    dt = jnp.dtype(cfg.moment_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return {
        "mu": jax.tree.map(zeros, params),
        "nu": jax.tree.map(zeros, params),
        "count": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree))
    )


def adamw_update(grads, opt_state, params, lr, cfg: AdamWConfig):
    """Returns (new_params, new_opt_state, stats)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
    count = opt_state["count"] + 1
    c1 = 1.0 - cfg.b1 ** count.astype(jnp.float32)
    c2 = 1.0 - cfg.b2 ** count.astype(jnp.float32)
    mdt = jnp.dtype(cfg.moment_dtype)

    def upd(g, mu, nu, p):
        g = g.astype(jnp.float32) * scale
        mu_f = cfg.b1 * mu.astype(jnp.float32) + (1 - cfg.b1) * g
        nu_f = cfg.b2 * nu.astype(jnp.float32) + (1 - cfg.b2) * g * g
        step = (mu_f / c1) / (jnp.sqrt(nu_f / c2) + cfg.eps)
        step = step + cfg.weight_decay * p.astype(jnp.float32)
        new_p = p.astype(jnp.float32) - lr * step
        return new_p.astype(p.dtype), mu_f.astype(mdt), nu_f.astype(mdt)

    flat_g, treedef = jax.tree.flatten(grads)
    flat_mu = jax.tree.leaves(opt_state["mu"])
    flat_nu = jax.tree.leaves(opt_state["nu"])
    flat_p = jax.tree.leaves(params)
    news = [upd(g, m, n, p) for g, m, n, p in zip(flat_g, flat_mu, flat_nu, flat_p)]
    new_params = jax.tree.unflatten(treedef, [x[0] for x in news])
    new_mu = jax.tree.unflatten(treedef, [x[1] for x in news])
    new_nu = jax.tree.unflatten(treedef, [x[2] for x in news])
    return (
        new_params,
        {"mu": new_mu, "nu": new_nu, "count": count},
        {"grad_norm": gnorm, "clip_scale": scale},
    )
