"""Int8 error-feedback gradient compression for the inter-pod all-reduce.

At multi-pod scale the pod-to-pod links are the slow tier; compressing the
cross-pod gradient sync 4x (fp32 -> int8 + per-tensor scale) cuts the
collective term while error feedback keeps the optimizer unbiased over time:

    q_t   = quant(g_t + e_{t-1})
    e_t   = (g_t + e_{t-1}) - dequant(q_t)
    g_sync = psum(dequant(q_t)) / n_pods

Used inside a shard_map over the `pod` axis (see launch/train.py); the pure
quantization math lives here so it is unit-testable without a mesh.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["quantize_int8", "dequantize_int8", "ef_compress_tree", "psum_compressed"]


def quantize_int8(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    xf = x.astype(jnp.float32)
    scale = jnp.max(jnp.abs(xf)) / 127.0 + 1e-30
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def ef_compress_tree(grads, err):
    """Quantize grads+err leaf-wise; returns (q_tree, scale_tree, new_err)."""
    corrected = jax.tree.map(lambda g, e: g.astype(jnp.float32) + e, grads, err)
    qs = jax.tree.map(quantize_int8, corrected)
    q_tree = jax.tree.map(lambda t: t[0], qs, is_leaf=lambda x: isinstance(x, tuple))
    s_tree = jax.tree.map(lambda t: t[1], qs, is_leaf=lambda x: isinstance(x, tuple))
    deq = jax.tree.map(dequantize_int8, q_tree, s_tree)
    new_err = jax.tree.map(lambda c, d: c - d, corrected, deq)
    return q_tree, s_tree, new_err


def psum_compressed(grads, err, axis_name: str):
    """Error-feedback int8 all-reduce over `axis_name` (inside shard_map).

    int8 payloads sum exactly in int32 across <=128 pods; scales are per-pod
    so we psum the dequantised values of the *quantised* payload — 4x wire
    bytes saved vs fp32 (the int8 tensor is what crosses the link)."""
    q, s, new_err = ef_compress_tree(grads, err)
    deq = jax.tree.map(dequantize_int8, q, s)
    summed = jax.lax.psum(deq, axis_name)
    n = jax.lax.psum(1, axis_name)
    mean = jax.tree.map(lambda x: x / n, summed)
    return mean, new_err
