"""Fault-tolerant checkpointing: atomic commits, async save, auto-resume,
elastic re-shard.

Layout::

    <dir>/step_<n>/shard_<proc>.npz   flattened param/opt leaves (this host)
    <dir>/step_<n>/META.json          step, leaf paths, config fingerprint
    <dir>/step_<n>/COMMITTED          written last -> crash-consistent marker

Restore loads host-side numpy and `device_put`s under the *current* mesh's
shardings — so a checkpoint written on a 2x16x16 mesh restores onto 16x16 (or
any other shape): elastic rescale is just restore-under-new-shardings.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import time

import jax
import numpy as np

__all__ = ["CheckpointManager"]


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        flat[key] = np.asarray(jax.device_get(leaf))
    return flat


def _unflatten_into(tree_like, flat: dict[str, np.ndarray]):
    paths, treedef = jax.tree_util.tree_flatten_with_path(tree_like)
    leaves = []
    for path, leaf in paths:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        if key not in flat:
            raise KeyError(f"checkpoint missing leaf {key}")
        arr = flat[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"leaf {key}: ckpt {arr.shape} vs model {leaf.shape}")
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, leaves)


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, async_save: bool = True):
        self.dir = directory
        self.keep = keep
        self.async_save = async_save
        self._thread: threading.Thread | None = None
        self._seq = 0
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------ save
    def save(self, step: int, state, blocking: bool = False, fingerprint: str = ""):
        """Snapshot to host memory NOW (so training can mutate donated
        buffers), write to disk async unless blocking."""
        self.wait()  # one outstanding save at a time (also: save/save races)
        if step in self.all_steps():
            return  # already committed (e.g. final blocking save after async)
        flat = _flatten(state)
        if self.async_save and not blocking:
            self._thread = threading.Thread(
                target=self._write, args=(step, flat, fingerprint), daemon=True
            )
            self._thread.start()
        else:
            self._write(step, flat, fingerprint)

    def _write(self, step: int, flat: dict, fingerprint: str):
        proc = jax.process_index()
        final = os.path.join(self.dir, f"step_{step:08d}")
        if os.path.exists(os.path.join(final, "COMMITTED")):
            return
        self._seq += 1
        tmp = final + f".tmp_{proc}_{self._seq}"
        os.makedirs(tmp, exist_ok=True)
        np.savez(os.path.join(tmp, f"shard_{proc}.npz"), **flat)
        meta = {
            "step": step,
            "nleaves": len(flat),
            "fingerprint": fingerprint,
            "time": time.time(),
        }
        with open(os.path.join(tmp, "META.json"), "w") as f:
            json.dump(meta, f)
        os.replace(tmp, final) if not os.path.exists(final) else shutil.rmtree(tmp)
        # commit marker last: a crash mid-write leaves no COMMITTED file
        with open(os.path.join(final, "COMMITTED"), "w") as f:
            f.write(str(step))
        self._gc()

    def wait(self):
        if self._thread is not None and self._thread.is_alive():
            self._thread.join()

    def _gc(self):
        steps = self.all_steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"), ignore_errors=True)

    # ------------------------------------------------------------ restore
    def all_steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.dir):
            full = os.path.join(self.dir, name)
            if name.startswith("step_") and os.path.exists(os.path.join(full, "COMMITTED")):
                out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, abstract_state, shardings=None):
        """Load `step` and place under `shardings` (elastic re-shard: the
        shardings may belong to a different mesh than the one that saved)."""
        proc = jax.process_index()
        path = os.path.join(self.dir, f"step_{step:08d}", f"shard_{proc}.npz")
        with np.load(path) as z:
            flat = {k: z[k] for k in z.files}
        host_tree = _unflatten_into(abstract_state, flat)
        if shardings is None:
            return jax.tree.map(jax.numpy.asarray, host_tree)
        return jax.tree.map(jax.device_put, host_tree, shardings)

    def restore_latest(self, abstract_state, shardings=None):
        step = self.latest_step()
        if step is None:
            return None, None
        return step, self.restore(step, abstract_state, shardings)
