"""internvl2-26b [vlm] — InternViT + InternLM2 [arXiv:2404.16821].

48L d_model=6144 48H (GQA kv=8) d_ff=16384 vocab=92553.  The InternViT
frontend is a STUB: `input_specs()` provides precomputed patch embeddings
(n_img_tokens x d_model) prepended to the text (DESIGN.md §4).
"""

from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="internvl2-26b",
    family="vlm",
    n_layers=48,
    d_model=6144,
    vocab=92553,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    n_img_tokens=256,
    grad_accum=4,
)

REDUCED = ModelConfig(
    name="internvl2-26b-reduced",
    family="vlm",
    n_layers=2,
    d_model=64,
    vocab=512,
    n_heads=4,
    n_kv_heads=2,
    head_dim=16,
    d_ff=128,
    n_img_tokens=8,
    attn_chunk=8,
)
