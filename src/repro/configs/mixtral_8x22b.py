"""mixtral-8x22b [moe] — 8 experts top-2, SWA [arXiv:2401.04088].

56L d_model=6144 48H (GQA kv=8) d_ff=16384 vocab=32768, MoE 8e top-2.
Experts (8) don't divide the 16-way model axis -> expert-TP fallback.
"""

from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="mixtral-8x22b",
    family="moe",
    n_layers=56,
    d_model=6144,
    vocab=32768,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    n_experts=8,
    top_k=2,
    sliding_window=4096,
    rope_theta=1e6,
    grad_accum=4,  # micro-batch must stay divisible by the 32-way DP degree
)

REDUCED = ModelConfig(
    name="mixtral-8x22b-reduced",
    family="moe",
    n_layers=2,
    d_model=64,
    vocab=512,
    n_heads=4,
    n_kv_heads=2,
    head_dim=16,
    d_ff=128,
    n_experts=4,
    top_k=2,
    sliding_window=16,
    attn_chunk=8,
)
