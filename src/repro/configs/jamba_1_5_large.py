"""jamba-1.5-large-398b [hybrid] — Mamba+attn 1:7, MoE [arXiv:2403.19887].

72L d_model=8192 64H (GQA kv=8) d_ff=24576 vocab=65536, MoE 16e top-2.
Period of 8: attention at slot 0, mamba at slots 1-7; MoE FFN every other
layer (36 MoE layers).  bf16 params + bf16 moments (398B: the fp32 state
would not fit 256 chips — DESIGN §5).
"""

from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="jamba-1.5-large",
    family="hybrid",
    n_layers=72,
    d_model=8192,
    vocab=65536,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=24576,
    n_experts=16,
    top_k=2,
    moe_period=2,
    attn_period=8,
    ssm_state=128,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_groups=8,
    ssm_chunk=128,
    param_dtype="bfloat16",
    grad_accum=8,  # micro-batch must stay divisible by the 32-way DP degree
)

REDUCED = ModelConfig(
    name="jamba-1.5-large-reduced",
    family="hybrid",
    n_layers=8,
    d_model=64,
    vocab=512,
    n_heads=4,
    n_kv_heads=2,
    head_dim=16,
    d_ff=128,
    n_experts=4,
    top_k=2,
    moe_period=2,
    attn_period=8,
    ssm_state=16,
    ssm_head_dim=16,
    ssm_groups=2,
    ssm_chunk=8,
    attn_chunk=8,
)
