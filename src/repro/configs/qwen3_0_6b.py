"""qwen3-0.6b [dense] — qk_norm, GQA [hf:Qwen/Qwen3-0.6B].

28L d_model=1024 16H (GQA kv=8) d_ff=3072 vocab=151936 (head_dim=128 as in
the released model — decoupled from d_model/n_heads).
"""

from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="qwen3-0.6b",
    family="dense",
    n_layers=28,
    d_model=1024,
    vocab=151936,
    n_heads=16,
    n_kv_heads=8,
    head_dim=128,
    d_ff=3072,
    qk_norm=True,
    rope_theta=1e6,
)

REDUCED = ModelConfig(
    name="qwen3-0.6b-reduced",
    family="dense",
    n_layers=2,
    d_model=64,
    vocab=512,
    n_heads=4,
    n_kv_heads=2,
    head_dim=16,
    d_ff=96,
    qk_norm=True,
    attn_chunk=8,
)
