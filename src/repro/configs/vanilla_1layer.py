"""One-layer vanilla transformer [paper Table IV benchmark].

1K sequence x 1K hidden, 2D-FFT on the attention matrix, BPMM on the
two-layer FFN; LRA-Image vocabulary (256 pixel intensities), batch 256.
"""

from repro.core.api import ButterflyPolicy
from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="vanilla-1layer",
    family="dense",
    n_layers=1,
    d_model=1024,
    vocab=256,
    n_heads=16,
    n_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    causal=False,
    norm="layernorm",
    act="gelu",
    butterfly=ButterflyPolicy(
        impl="monarch", fft_attention=True, on_qkv=False, on_out=False, on_ffn=True
    ),
)

# dense baseline of the same shape (the paper's comparison object)
DENSE = ModelConfig(
    name="vanilla-1layer-dense",
    family="dense",
    n_layers=1,
    d_model=1024,
    vocab=256,
    n_heads=16,
    n_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    causal=False,
    norm="layernorm",
    act="gelu",
)

REDUCED = ModelConfig(
    name="vanilla-1layer-reduced",
    family="dense",
    n_layers=1,
    d_model=64,
    vocab=64,
    n_heads=4,
    n_kv_heads=4,
    head_dim=16,
    d_ff=128,
    causal=False,
    norm="layernorm",
    act="gelu",
    attn_chunk=8,
    butterfly=ButterflyPolicy(
        impl="monarch", fft_attention=True, on_qkv=False, on_out=False, on_ffn=True,
        max_block=32,
    ),
)
