"""mamba2-130m [ssm] — SSD, attention-free [arXiv:2405.21060].

24L d_model=768 (attn-free) vocab=50280, ssm_state=128.
"""

from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="mamba2-130m",
    family="ssm",
    n_layers=24,
    d_model=768,
    vocab=50280,
    ssm_state=128,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_groups=1,
    ssm_chunk=128,
)

REDUCED = ModelConfig(
    name="mamba2-130m-reduced",
    family="ssm",
    n_layers=2,
    d_model=64,
    vocab=512,
    ssm_state=16,
    ssm_expand=2,
    ssm_head_dim=16,
    ssm_groups=1,
    ssm_chunk=8,
)
