"""dbrx-132b [moe] — 16 experts top-4, fine-grained [hf:databricks/dbrx-base].

40L d_model=6144 48H (GQA kv=8) d_ff=10752 vocab=100352, MoE 16e top-4.
16 experts on the 16-way model axis -> 1 expert per device (EP).
"""

from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="dbrx-132b",
    family="moe",
    n_layers=40,
    d_model=6144,
    vocab=100352,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=10752,
    n_experts=16,
    top_k=4,
    rope_theta=5e5,
    grad_accum=4,  # micro-batch must stay divisible by the 32-way DP degree
)

REDUCED = ModelConfig(
    name="dbrx-132b-reduced",
    family="moe",
    n_layers=2,
    d_model=64,
    vocab=512,
    n_heads=4,
    n_kv_heads=2,
    head_dim=16,
    d_ff=96,
    n_experts=4,
    top_k=2,
    attn_chunk=8,
)
