"""Assigned input shapes (the x4 axis of the 40-cell matrix) + input specs.

``decode_*`` / ``long_*`` lower `decode_step` (one new token against a
seq_len-deep KV cache); ``train_*`` lowers `train_step`; ``prefill_*`` lowers
`prefill`.  `applicable()` encodes the skip rules from DESIGN.md §4.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig

__all__ = ["SHAPES", "Shape", "applicable", "batch_specs"]


@dataclasses.dataclass(frozen=True)
class Shape:
    name: str
    kind: str  # train | prefill | decode
    seq: int
    batch: int


SHAPES: dict[str, Shape] = {
    "train_4k": Shape("train_4k", "train", 4_096, 256),
    "prefill_32k": Shape("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": Shape("decode_32k", "decode", 32_768, 128),
    "long_500k": Shape("long_500k", "decode", 524_288, 1),
}


def applicable(cfg: ModelConfig, shape: Shape) -> tuple[bool, str]:
    """DESIGN.md §4: long_500k needs sub-quadratic attention."""
    if shape.name != "long_500k":
        return True, ""
    if cfg.family == "encdec":
        return False, "enc-dec (448-token decoder in the real model); full attention"
    if cfg.family in ("ssm", "hybrid"):
        return True, "O(1)-state decode (SSM/hybrid)"
    if cfg.sliding_window:
        return True, f"SWA window={cfg.sliding_window} bounds the KV cache"
    return False, "pure full attention — quadratic; skipped per assignment"


def batch_specs(cfg: ModelConfig, shape: Shape) -> dict:
    """ShapeDtypeStruct stand-ins for the non-cache model inputs."""
    tok = jax.ShapeDtypeStruct((shape.batch, shape.seq), jnp.int32)
    one = jax.ShapeDtypeStruct((shape.batch, 1), jnp.int32)
    adt = jnp.dtype(cfg.dtype)
    out: dict = {}
    if shape.kind == "train":
        out = {"tokens": tok, "labels": tok}
    elif shape.kind == "prefill":
        out = {"tokens": tok}
    else:  # decode
        out = {"tokens": one}
    if cfg.family == "encdec" and shape.kind != "decode":
        out["frames"] = jax.ShapeDtypeStruct((shape.batch, cfg.enc_seq, cfg.d_model), adt)
    if cfg.n_img_tokens and shape.kind != "decode":
        out["img_embeds"] = jax.ShapeDtypeStruct(
            (shape.batch, cfg.n_img_tokens, cfg.d_model), adt
        )
    return out
