"""Hybrid butterfly-sparsity network [paper §III, Fig. 1] — the paper's
attention-map orchestration target: early encoder layers run *butterfly-block-
sparse attention* (the score matrix keeps only radix-2 stride-pair kv tiles —
O(N log N) live blocks), the tail swaps attention for the FNet-style 2D-FFT
mixer (FABNet's trade-off, paper ref [8]), and every FFN is a BPMM butterfly
linear.  One config exercises all three sparsity substrates end to end.

The per-slot ``attn_pattern`` override carries the depth split; the butterfly
attention layers execute through whichever ``AttentionSpec.impl`` is selected
(``+flash`` makes the kernel grid skip the dead tiles for real).
"""

from repro.core.api import ButterflyPolicy
from repro.core.attention import AttentionSpec
from repro.models.config import ModelConfig, Slot

_ATTN = Slot("attn", "dense", attn_pattern="butterfly")
_FFT = Slot("fft", "dense")

FULL = ModelConfig(
    name="hybrid-butterfly",
    family="dense",
    n_layers=12,
    d_model=768,
    vocab=30522,
    n_heads=12,
    n_kv_heads=12,
    head_dim=64,
    d_ff=3072,
    causal=False,
    norm="layernorm",
    act="gelu",
    slots_override=(_ATTN,) * 8 + (_FFT,) * 4,
    attention=AttentionSpec(),
    butterfly=ButterflyPolicy(
        impl="monarch", on_qkv=False, on_out=False, on_ffn=True
    ),
)

REDUCED = ModelConfig(
    name="hybrid-butterfly-reduced",
    family="dense",
    n_layers=4,
    d_model=64,
    vocab=512,
    n_heads=4,
    n_kv_heads=4,
    head_dim=16,
    d_ff=128,
    causal=False,
    norm="layernorm",
    act="gelu",
    attn_chunk=8,
    slots_override=(_ATTN,) * 2 + (_FFT,) * 2,
    attention=AttentionSpec(q_tile=8),
    butterfly=ButterflyPolicy(
        impl="monarch", on_qkv=False, on_out=False, on_ffn=True, max_block=32
    ),
)
