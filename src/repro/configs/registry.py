"""Architecture registry: ``get("<arch>[+variant...]", reduced=...)``.

Variants apply the paper's technique to any architecture as config suffixes
(stackable, e.g. ``yi-6b+bpmm+flash+butterfly_attn``):
    +bpmm      Monarch-grouped BPMM on qkv/out/ffn (the multilayer-dataflow form)
    +bpmm-r2   faithful radix-2 staged BPMM (the §Perf baseline form)
    +bpmm-k    fused Pallas-kernel BPMM
    +fft       2D-FFT attention replacement (non-causal stacks only)
    +flash     fused Pallas flash-attention kernel on the softmax path
    +butterfly_attn  butterfly-block-sparse attention map (§III; radix-2
                     stride pairs over kv tiles — under +flash the kernel
                     grid skips dead tiles)
    +strided_attn    strided/dilated block-sparse attention map
    +global_attn     global+window block-sparse attention map
"""

from __future__ import annotations

import dataclasses

from repro.core.api import ButterflyPolicy
from repro.models.config import ModelConfig

from repro.configs import (
    dbrx_132b,
    fabnet,
    hybrid_butterfly,
    internvl2_26b,
    jamba_1_5_large,
    mamba2_130m,
    mixtral_8x22b,
    qwen2_72b,
    qwen3_0_6b,
    vanilla_1layer,
    whisper_base,
    yi_34b,
    yi_6b,
)

_MODULES = {
    "mamba2-130m": mamba2_130m,
    "mixtral-8x22b": mixtral_8x22b,
    "dbrx-132b": dbrx_132b,
    "internvl2-26b": internvl2_26b,
    "yi-34b": yi_34b,
    "qwen2-72b": qwen2_72b,
    "yi-6b": yi_6b,
    "qwen3-0.6b": qwen3_0_6b,
    "whisper-base": whisper_base,
    "jamba-1.5-large": jamba_1_5_large,
    "fabnet-base": fabnet,
    "hybrid-butterfly": hybrid_butterfly,
    "vanilla-1layer": vanilla_1layer,
}

ASSIGNED = [
    "mamba2-130m",
    "mixtral-8x22b",
    "dbrx-132b",
    "internvl2-26b",
    "yi-34b",
    "qwen2-72b",
    "yi-6b",
    "qwen3-0.6b",
    "whisper-base",
    "jamba-1.5-large",
]

PAPER = ["fabnet-base", "hybrid-butterfly", "vanilla-1layer"]

_VARIANTS = {
    "bpmm": dict(impl="monarch"),
    "bpmm-r2": dict(impl="radix2"),
    "bpmm-k": dict(impl="monarch_kernel"),
    "fft": dict(impl="monarch", fft_attention=True, on_qkv=False, on_out=False, on_ffn=False),
}

# attention-spec transforms: stackable, order-independent (each touches its
# own field), e.g. "+flash+butterfly_attn" == "+butterfly_attn+flash"
_ATTN_VARIANTS = {
    "flash": dict(impl="flash_kernel"),
    "butterfly_attn": dict(pattern="butterfly"),
    "strided_attn": dict(pattern="strided"),
    "global_attn": dict(pattern="global_window"),
}


def names() -> list[str]:
    return list(_MODULES)


def get(name: str, reduced: bool = False) -> ModelConfig:
    base, *variants = name.split("+")
    if base not in _MODULES:
        raise KeyError(f"unknown arch {base!r}; known: {sorted(_MODULES)}")
    mod = _MODULES[base]
    cfg: ModelConfig = mod.REDUCED if reduced else mod.FULL
    for variant in variants:
        if variant in _ATTN_VARIANTS:
            spec = dataclasses.replace(cfg.attention, **_ATTN_VARIANTS[variant])
            cfg = dataclasses.replace(
                cfg, name=f"{cfg.name}+{variant}", attention=spec
            )
            continue
        if variant not in _VARIANTS:
            known = sorted(_VARIANTS) + sorted(_ATTN_VARIANTS)
            raise KeyError(f"unknown variant {variant!r}; known: {known}")
        kw = dict(_VARIANTS[variant])
        if variant == "fft" and cfg.causal:
            raise ValueError(f"{base} is causal; the FFT (FNet) mixer is encoder-only")
        if reduced:
            kw["max_block"] = 32
        pol = dataclasses.replace(cfg.butterfly, **kw)
        cfg = dataclasses.replace(cfg, name=f"{cfg.name}+{variant}", butterfly=pol)
    return cfg
