"""qwen2-72b [dense] — GQA, QKV bias [arXiv:2407.10671].

80L d_model=8192 64H (GQA kv=8) d_ff=29568 vocab=152064.
"""

from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="qwen2-72b",
    family="dense",
    n_layers=80,
    d_model=8192,
    vocab=152064,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=29568,
    qkv_bias=True,
    rope_theta=1e6,
    grad_accum=8,
)

REDUCED = ModelConfig(
    name="qwen2-72b-reduced",
    family="dense",
    n_layers=2,
    d_model=64,
    vocab=512,
    n_heads=4,
    n_kv_heads=2,
    head_dim=16,
    d_ff=160,
    qkv_bias=True,
    attn_chunk=8,
)
