"""yi-34b [dense] — llama-arch GQA [arXiv:2403.04652].

60L d_model=7168 56H (GQA kv=8) d_ff=20480 vocab=64000.
56 q-heads don't divide the 16-way model axis -> context-parallel attention.
"""

from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="yi-34b",
    family="dense",
    n_layers=60,
    d_model=7168,
    vocab=64000,
    n_heads=56,
    n_kv_heads=8,
    head_dim=128,
    d_ff=20480,
    rope_theta=5e6,
    grad_accum=4,
)

REDUCED = ModelConfig(
    name="yi-34b-reduced",
    family="dense",
    n_layers=2,
    d_model=64,
    vocab=512,
    n_heads=7,  # keeps the non-divisible-heads (CP fallback) wiring honest
    n_kv_heads=1,
    head_dim=16,
    d_ff=128,
    attn_chunk=8,
)
