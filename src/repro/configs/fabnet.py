"""FABNet-Base [paper benchmark] — the SOTA butterfly accelerator's workload
(Fan et al., MICRO'22 — paper ref [8]): 2D-FFT attention + BPMM FFN encoder
blocks, evaluated at sequence scales 128..1K (paper Fig. 17).
"""

from repro.core.api import ButterflyPolicy
from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="fabnet-base",
    family="dense",
    n_layers=12,
    d_model=768,
    vocab=30522,
    n_heads=12,
    n_kv_heads=12,
    head_dim=64,
    d_ff=3072,
    causal=False,
    norm="layernorm",
    act="gelu",
    butterfly=ButterflyPolicy(
        impl="monarch", fft_attention=True, on_qkv=False, on_out=False, on_ffn=True
    ),
)

REDUCED = ModelConfig(
    name="fabnet-base-reduced",
    family="dense",
    n_layers=2,
    d_model=64,
    vocab=512,
    n_heads=4,
    n_kv_heads=4,
    head_dim=16,
    d_ff=128,
    causal=False,
    norm="layernorm",
    act="gelu",
    attn_chunk=8,
    butterfly=ButterflyPolicy(
        impl="monarch", fft_attention=True, on_qkv=False, on_out=False, on_ffn=True,
        max_block=32,
    ),
)
