"""whisper-base [audio] — enc-dec, conv frontend (stub) [arXiv:2212.04356].

6L d_model=512 8H d_ff=2048 vocab=51865.  6 encoder + 6 decoder layers;
the mel-conv frontend is a STUB: `input_specs()` provides (B, 1500, 512)
precomputed frame embeddings.  RoPE replaces learned positions (DESIGN §7).
"""

from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="whisper-base",
    family="encdec",
    n_layers=6,
    n_enc_layers=6,
    enc_seq=1500,
    d_model=512,
    vocab=51865,
    n_heads=8,
    n_kv_heads=8,
    head_dim=64,
    d_ff=2048,
    norm="layernorm",
    act="gelu",
)

REDUCED = ModelConfig(
    name="whisper-base-reduced",
    family="encdec",
    n_layers=2,
    n_enc_layers=2,
    enc_seq=12,
    d_model=64,
    vocab=512,
    n_heads=4,
    n_kv_heads=4,
    head_dim=16,
    d_ff=128,
    norm="layernorm",
    act="gelu",
    attn_chunk=8,
)
