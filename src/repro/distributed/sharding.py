"""Logical-axis sharding rules with divisibility fallbacks.

Every parameter and activation carries *logical* axis names; this module maps
them onto whatever mesh is in scope.  The mapping degrades gracefully: a
logical axis whose dimension does not divide the assigned mesh axes is left
replicated (e.g. yi-34b's 56 q-heads on a 16-way `model` axis), and the model
layer then falls back to its alternative parallelism (context parallelism for
attention, expert-TP for MoE) — decided once per config in
:func:`repro.models.model.resolve_parallelism`.

Logical axes:
    batch   -> (pod, data)   data parallel (pod axis only on multi-pod meshes)
    seq     -> model          sequence / context parallelism at layer bounds
    tp      -> model          tensor parallel (heads, d_ff, vocab, experts,
                              butterfly block-diagonals)
    fsdp    -> data           ZeRO-3 parameter sharding
    expert  -> model          expert parallelism
    pages   -> pages          the paged KV pool's page axis (serve meshes
                              only; absent axis -> pools replicate, which is
                              the single-chip behaviour)
    None    -> replicated
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = [
    "RULES",
    "ParamSpec",
    "spec_for",
    "sharding_for",
    "constrain",
    "init_tree",
    "abstract_tree",
    "sharding_tree",
    "shard_map",
]


def shard_map(f, mesh, in_specs, out_specs, axis_names=None):
    """``jax.shard_map`` across jax versions.

    >= 0.5 exposes it as ``jax.shard_map`` (with ``axis_names`` for partially
    manual meshes); 0.4.x has ``jax.experimental.shard_map`` where the same
    intent spells ``auto=`` (complement set) and requires ``check_rep=False``.
    """
    sm = getattr(jax, "shard_map", None)
    if sm is not None:
        kw = {} if axis_names is None else {"axis_names": set(axis_names)}
        return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kw)
    from jax.experimental.shard_map import shard_map as sm_legacy

    kw: dict = {"check_rep": False}
    if axis_names is not None:
        kw["auto"] = frozenset(mesh.axis_names) - frozenset(axis_names)
    out = sm_legacy(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kw)
    if kw.get("auto"):
        # 0.4.x: eager partially-auto shard_map is NotImplemented; jit is the
        # supported path (a nested jit inlines when already traced)
        out = jax.jit(out)
    return out

# logical axis -> candidate mesh axes (in priority order; all present ones used)
RULES: dict[str | None, tuple[str, ...]] = {
    "batch": ("pod", "data"),
    "seq": ("model",),
    "tp": ("model",),
    "fsdp": ("data",),
    "expert": ("model",),
    "vocab": ("model",),
    "pages": ("pages",),
    None: (),
}

# pure data parallelism: no TP — batch spreads over the model axis too and
# parameters FSDP over both axes.  The right regime for small models where
# TP collectives dwarf compute (mamba2-130m hillclimb, §Perf).
RULES_PURE_DP: dict[str | None, tuple[str, ...]] = {
    "batch": ("pod", "data", "model"),
    "seq": (),
    "tp": (),
    "fsdp": ("data", "model"),
    "expert": (),
    "vocab": ("model",),
    "pages": ("pages",),
    None: (),
}


def _mesh_axis_sizes(mesh: Mesh) -> dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def spec_for(
    shape: Sequence[int],
    axes: Sequence[str | None],
    mesh: Mesh,
    rules: dict | None = None,
) -> P:
    """PartitionSpec for `shape` under logical `axes`, with divisibility
    fallback (non-dividing dims replicate) and no mesh axis used twice."""
    rules = rules or RULES
    sizes = _mesh_axis_sizes(mesh)
    used: set[str] = set()
    out: list[Any] = []
    assert len(shape) == len(axes), (shape, axes)
    for dim, logical in zip(shape, axes):
        cands = [
            a
            for a in rules.get(logical, ())
            if a in sizes and a not in used
        ]
        take: list[str] = []
        prod = 1
        for a in cands:
            if dim % (prod * sizes[a]) == 0:
                take.append(a)
                prod *= sizes[a]
        if take:
            used.update(take)
            out.append(tuple(take) if len(take) > 1 else take[0])
        else:
            out.append(None)
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def sharding_for(
    shape: Sequence[int], axes: Sequence[str | None], mesh: Mesh, rules: dict | None = None
) -> NamedSharding:
    return NamedSharding(mesh, spec_for(shape, axes, mesh, rules))


def constrain(
    x: jax.Array,
    axes: Sequence[str | None],
    mesh: Mesh | None,
    rules: dict | None = None,
) -> jax.Array:
    """with_sharding_constraint under logical axes; no-op without a mesh or on
    a single-device mesh (keeps smoke tests free of sharding machinery)."""
    if mesh is None or math.prod(mesh.devices.shape) == 1:
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, spec_for(x.shape, axes, mesh, rules))
    )


# --------------------------------------------------------------------------
# Parameter specs — single source of truth for shapes, init and sharding.
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: tuple[int, ...]
    axes: tuple[str | None, ...]
    init: str = "normal"  # normal | zeros | ones
    scale: float | None = None  # None -> 1/sqrt(fan_in = shape[-2] or [-1])

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)

    def initializer(self) -> Callable[[jax.Array, Any], jax.Array]:
        if self.init == "zeros":
            return lambda k, dt: jnp.zeros(self.shape, dt)
        if self.init == "ones":
            return lambda k, dt: jnp.ones(self.shape, dt)
        scale = self.scale
        if scale is None:
            fan_in = self.shape[-2] if len(self.shape) >= 2 else self.shape[-1]
            scale = 1.0 / math.sqrt(max(fan_in, 1))
        return lambda k, dt: (jax.random.normal(k, self.shape, jnp.float32) * scale).astype(dt)


def _is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def init_tree(specs, key: jax.Array, dtype=jnp.float32):
    """Materialise a parameter pytree from a ParamSpec tree (deterministic:
    keys are folded from the flattened path order)."""
    leaves, treedef = jax.tree.flatten(specs, is_leaf=_is_spec)
    keys = jax.random.split(key, len(leaves))
    vals = [s.initializer()(k, dtype) for s, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, vals)


def abstract_tree(specs, dtype=jnp.float32):
    """ShapeDtypeStruct tree (for dry-run lowering — no allocation)."""
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, dtype), specs, is_leaf=_is_spec
    )


def sharding_tree(specs, mesh: Mesh, rules: dict | None = None):
    return jax.tree.map(
        lambda s: sharding_for(s.shape, s.axes, mesh, rules), specs, is_leaf=_is_spec
    )


def data_shardings(tree, mesh: Mesh):
    """Batch-dim-0 shardings for an input batch tree (ShapeDtypeStructs or
    arrays); falls back to replicated when the batch doesn't divide (e.g. the
    long_500k single-sequence decode)."""
    return jax.tree.map(
        lambda s: sharding_for(s.shape, ("batch",) + (None,) * (len(s.shape) - 1), mesh),
        tree,
    )
