"""Fault-tolerance runtime: restart supervision + straggler detection.

On a real multi-pod deployment the supervisor wraps the per-host training
process (launched under `jax.distributed`); preemption / device failure
surfaces as an exception, the supervisor restores from the latest committed
checkpoint and continues.  The logic is host-side and hardware-agnostic, so
it is fully exercised by the CPU test-suite (kill-and-resume test).
"""

from __future__ import annotations

import dataclasses
import logging
import time
from collections import deque
from typing import Callable

log = logging.getLogger("repro.ft")

__all__ = ["RestartPolicy", "run_with_restarts", "StragglerDetector"]


@dataclasses.dataclass
class RestartPolicy:
    max_restarts: int = 10
    backoff_s: float = 1.0
    backoff_mult: float = 2.0
    max_backoff_s: float = 300.0


def run_with_restarts(body: Callable[[int], None], policy: RestartPolicy = RestartPolicy()):
    """Run `body(attempt)` until it returns; restart on exception.

    `body` is expected to resume from the latest checkpoint internally (see
    launch/train.py) — the supervisor only bounds retries and backs off.
    """
    backoff = policy.backoff_s
    for attempt in range(policy.max_restarts + 1):
        try:
            return body(attempt)
        except KeyboardInterrupt:
            raise
        except Exception as e:  # noqa: BLE001 — anything can kill a worker
            if attempt == policy.max_restarts:
                log.error("run failed after %d restarts: %s", attempt, e)
                raise
            log.warning("attempt %d failed (%s); restarting in %.1fs", attempt, e, backoff)
            time.sleep(backoff)
            backoff = min(backoff * policy.backoff_mult, policy.max_backoff_s)
    return None


class StragglerDetector:
    """Flags steps slower than `threshold` x rolling median.

    At fleet scale the mitigation is re-scheduling the slow host / dropping
    it from the mesh (elastic rescale via CheckpointManager.restore under a
    smaller mesh); here the detector exposes the decision signal + counters.
    """

    def __init__(self, window: int = 50, threshold: float = 3.0, patience: int = 3):
        self.times: deque[float] = deque(maxlen=window)
        self.threshold = threshold
        self.patience = patience
        self.consecutive_slow = 0
        self.flagged = 0

    def median(self) -> float:
        if not self.times:
            return 0.0
        s = sorted(self.times)
        return s[len(s) // 2]

    def record(self, step_time: float) -> bool:
        """Record a step; returns True when mitigation should trigger."""
        med = self.median()
        is_slow = bool(self.times) and len(self.times) >= 5 and step_time > self.threshold * med
        self.times.append(step_time)
        if is_slow:
            self.consecutive_slow += 1
            self.flagged += 1
        else:
            self.consecutive_slow = 0
        return self.consecutive_slow >= self.patience
