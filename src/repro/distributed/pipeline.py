"""GPipe-style pipeline parallelism via shard_map + ppermute.

Each device along the `pipe` mesh axis owns one stage's parameters; the
microbatch stream flows through `M + S - 1` ticks with activations handed to
the next stage by collective_permute.  Bubble fraction = (S-1)/(M+S-1), so
callers pick M >= 4*S.  This is the optional third parallelism tier for
meshes configured as (pipe, data, model); the 40-cell dry-run meshes are
(pod, data, model), and PP is exercised by its own test/benchmark.
"""

from __future__ import annotations

from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.distributed.sharding import shard_map

__all__ = ["gpipe"]


def gpipe(
    stage_fn: Callable,
    stage_params,
    microbatches: jax.Array,  # (M, mb, ...) — the microbatch stream
    mesh: Mesh,
    axis: str = "pipe",
):
    """Run `stage_fn(params_i, x)` as an S-deep pipeline over `axis`.

    stage_params: pytree with leading dim S (one slice per stage).
    Returns (M, mb, ...) outputs (replicated along `axis`).
    """
    s = dict(zip(mesh.axis_names, mesh.devices.shape))[axis]
    m = microbatches.shape[0]

    def shard_fn(params_local, stream_local):
        params_local = jax.tree.map(lambda a: a[0], params_local)  # (1,...) -> (...)
        idx = jax.lax.axis_index(axis)
        zero = jnp.zeros_like(stream_local[0])
        carry = zero
        collected = []
        perm = [(i, (i + 1) % s) for i in range(s)]
        for t in range(m + s - 1):
            # stage 0 ingests microbatch t (beyond M: dead ticks)
            feed = stream_local[t] if t < m else zero
            inp = jnp.where(idx == 0, feed, carry)
            out = stage_fn(params_local, inp)
            carry = jax.lax.ppermute(out, axis, perm)
            if t >= s - 1:  # emitted by the last stage at these ticks
                collected.append(jnp.where(idx == s - 1, out, jnp.zeros_like(out)))
        stacked = jnp.stack(collected)  # (M, mb, ...)
        # replicate the result: only the last stage holds nonzero values
        return jax.lax.psum(stacked, axis)

    fn = shard_map(
        shard_fn,
        mesh=mesh,
        in_specs=(P(axis), P()),
        out_specs=P(),
    )
    return fn(stage_params, microbatches)
