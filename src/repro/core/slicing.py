"""Weight-matrix slicing for unequal input/output hidden sizes (paper Fig. 10).

A butterfly transform is square (power of two).  Real linear layers are not:
``W`` is ``din x dout`` with arbitrary dims.  The paper slices ``W`` into
square pieces, decomposes each piece as butterfly matrices, multiplies each
piece by its input slice, and sums (din > dout) or concatenates (dout > din)
the piece products.  We generalise to the full grid case: pad both dims up to
multiples of a power-of-two piece size ``s``, giving a ``gin x gout`` grid of
square pieces; outputs concatenate over ``gout`` and sum over ``gin``.
"""

from __future__ import annotations

import math
from typing import NamedTuple

__all__ = ["SlicePlan", "plan_slicing"]


class SlicePlan(NamedTuple):
    din: int
    dout: int
    piece: int  # square piece size (power of two)
    gin: int  # input slices  (padded_din  / piece)
    gout: int  # output slices (padded_dout / piece)

    @property
    def din_pad(self) -> int:
        return self.gin * self.piece

    @property
    def dout_pad(self) -> int:
        return self.gout * self.piece


def plan_slicing(din: int, dout: int, max_piece: int = 8192) -> SlicePlan:
    """Choose the square piece size: the largest power of two <= min(din, dout)
    (capped), so the smaller dim needs at most one slice of padding."""
    s = 1 << int(math.floor(math.log2(min(din, dout))))
    s = min(s, max_piece)
    gin = -(-din // s)
    gout = -(-dout // s)
    return SlicePlan(din, dout, s, gin, gout)
