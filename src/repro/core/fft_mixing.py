"""FNet-style 2-D FFT attention replacement (paper Fig. 1c, benchmark AT-all).

``mix(x) = Re( DFT_seq( DFT_hidden(x) ) )`` — token and feature mixing with no
learned attention weights, O(N log N).  Executed through the multi-stage
division planner so every stage is a batched small dense matmul (MXU) with
twiddle layers in between; the fused two-stage Pallas kernel is used for the
sequence transform when enabled.
"""

from __future__ import annotations

from functools import partial
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import stage_division as sd

__all__ = ["fnet_mixing", "dft_real_stages", "fnet_mixing_reference"]


def fnet_mixing_reference(x: jax.Array) -> jax.Array:
    """Oracle: complex 2-D FFT over (seq, hidden), real part (FNet eq. 1)."""
    return jnp.real(jnp.fft.fft(jnp.fft.fft(x.astype(jnp.complex64), axis=-1), axis=-2))


def _dft_mats(n: int):
    m = np.asarray(sd.dft_matrix(n))
    return jnp.asarray(m.real.astype(np.float32)), jnp.asarray(m.imag.astype(np.float32))


def _twiddle_mats(n1: int, n2: int):
    t = np.asarray(sd.twiddle(n1, n2))
    return jnp.asarray(t.real.astype(np.float32)), jnp.asarray(t.imag.astype(np.float32))


def dft_real_stages(
    xr: jax.Array, xi: jax.Array | None, axis: int, plan: Sequence[int]
) -> tuple[jax.Array, jax.Array]:
    """DFT along ``axis`` in real arithmetic via the stage plan.

    Complex tensors are carried as (re, im) pairs because the TPU MXU (and
    Pallas) are real-valued — this mirrors the paper's observation (§VI-D)
    that complex FFT doubles the Flow traffic vs real BPMM: each stage here is
    4 real matmuls (3 with Karatsuba, see kernels/fft2d.py).
    """
    xr = jnp.moveaxis(xr, axis, -1)
    xi = None if xi is None else jnp.moveaxis(xi, axis, -1)
    n = xr.shape[-1]
    plan = tuple(plan)
    assert int(np.prod(plan)) == n, (plan, n)

    def one(xr, xi, n):
        wr, wi = _dft_mats(n)
        dtype = xr.dtype
        wr, wi = wr.astype(dtype), wi.astype(dtype)
        if xi is None:
            return xr @ wr.T, xr @ wi.T
        return xr @ wr.T - xi @ wi.T, xr @ wi.T + xi @ wr.T

    def rec(xr, xi, plan):
        n = xr.shape[-1]
        if len(plan) == 1:
            return one(xr, xi, n)
        n1, n2 = plan[0], int(np.prod(plan[1:]))
        s = xr.shape[:-1]
        xr = xr.reshape(*s, n1, n2)
        xi = None if xi is None else xi.reshape(*s, n1, n2)
        # stage 1 along n1
        ar, ai = rec(
            jnp.swapaxes(xr, -1, -2), None if xi is None else jnp.swapaxes(xi, -1, -2), (n1,)
        )
        ar, ai = jnp.swapaxes(ar, -1, -2), jnp.swapaxes(ai, -1, -2)
        # twiddle
        tr, ti = _twiddle_mats(n1, n2)
        tr, ti = tr.astype(ar.dtype), ti.astype(ar.dtype)
        br = ar * tr - ai * ti
        bi = ar * ti + ai * tr
        # stage 2 along n2 (tail of the plan)
        cr, ci = rec(br, bi, plan[1:])
        # digit reversal
        cr = jnp.swapaxes(cr, -1, -2).reshape(*s, n)
        ci = jnp.swapaxes(ci, -1, -2).reshape(*s, n)
        return cr, ci

    yr, yi = rec(xr, xi, plan)
    return jnp.moveaxis(yr, -1, axis), jnp.moveaxis(yi, -1, axis)


def fnet_mixing(
    x: jax.Array,
    seq_plan: Sequence[int] | None = None,
    hid_plan: Sequence[int] | None = None,
    max_radix: int = sd.MAX_RADIX_COMPLEX,
) -> jax.Array:
    """2-D FFT mixing over the last two axes (..., seq, hidden), real output.

    Pure-jnp staged implementation (the XLA baseline); the hillclimbed path
    replaces the inner transforms with the fused Pallas kernel via
    :mod:`repro.kernels.ops`.
    """
    seq, hid = x.shape[-2], x.shape[-1]
    hid_plan = tuple(hid_plan) if hid_plan else sd.plan_stages(hid, max_radix)
    seq_plan = tuple(seq_plan) if seq_plan else sd.plan_stages(seq, max_radix)
    yr, yi = dft_real_stages(x, None, -1, hid_plan)
    yr, _ = dft_real_stages(yr, yi, -2, seq_plan)
    return yr
