"""Pluggable attention execution: ``AttentionSpec`` + analytic accounting.

Mirror of :class:`repro.core.api.LinearSpec` for the softmax path.  The paper's
diagnosis (Fig. 2) is that the attention AT-all is memory-bound on
block-oriented backends because the score matrix makes a full HBM round trip;
the multilayer-dataflow fix (§IV, §V-A) keeps the score tile VMEM-resident and
streams token tiles — {Load | Cal | Store} — with exactly one HBM read/write
per tile.  ``AttentionSpec.impl`` selects which execution form runs the
attention stage of every model in the zoo:

* ``xla_chunked``  — prefix-chunked XLA einsum attention (reference form;
  materialises per-chunk score matrices in HBM — the Fig. 2 pathology)
* ``flash_kernel`` — fused Pallas online-softmax kernel
  (:mod:`repro.kernels.flash_attention`): scores never leave VMEM

The spec also carries the kernel tile geometry and powers the analytic
FLOP/HBM-byte accounting used by the dry-run roofline and the Fig. 2/15
benchmarks (Pallas custom-calls report ~zero cost through XLA's
``cost_analysis``, so the fused form is accounted here).
"""

from __future__ import annotations

import dataclasses

from repro.core import sparsity

__all__ = [
    "AttentionSpec",
    "override_attention",
    "truncate_kv_live",
    "attention_flops",
    "attention_hbm_bytes",
    "kv_dtype_bytes",
    "ragged_attention_flops",
    "ragged_attention_hbm_bytes",
]


def truncate_kv_live(k_cache, v_cache, kv_live: int | None):
    """Statically truncate a KV cache to its first ``kv_live`` rows (the
    serve engine's bucketed bound on every row's live length) — the single
    definition of the clamp every execution form applies, so the fused and
    XLA paths can never diverge on it.  Returns (k, v, skv)."""
    skv = k_cache.shape[1]
    if kv_live is not None and kv_live < skv:
        skv = max(int(kv_live), 1)
        k_cache = k_cache[:, :skv]
        v_cache = v_cache[:, :skv]
    return k_cache, v_cache, skv

IMPLS = ("xla_chunked", "flash_kernel")


@dataclasses.dataclass(frozen=True)
class AttentionSpec:
    """Where the attention softmax path executes and with what tiling.

    ``chunk`` / ``f32_softmax`` apply to the ``xla_chunked`` form;
    ``q_tile`` / ``kv_tile`` are the Pallas grid tile sizes of the
    ``flash_kernel`` form (rows of Q and KV resident in VMEM per grid step).

    ``pattern`` selects the static block-sparsity of the score matrix
    (:mod:`repro.core.sparsity`: dense | causal | window | butterfly |
    strided | global_window); ``pattern_arg`` is its knob (window tokens,
    stride in tiles, global tile count).  The fused kernel iterates only live
    blocks via the map's kv-tile index table; the XLA forms mask with the same
    map — bit-identical liveness either way.  The pattern tile granularity is
    ``q_tile`` x ``kv_tile`` for *both* impls.
    """

    impl: str = "xla_chunked"  # xla_chunked | flash_kernel
    chunk: int = 2048
    q_tile: int = 128
    kv_tile: int = 128
    f32_softmax: bool = True
    pattern: str = "dense"  # see repro.core.sparsity.PATTERNS
    pattern_arg: int | None = None

    def __post_init__(self) -> None:
        if self.impl not in IMPLS:
            raise ValueError(f"unknown attention impl {self.impl!r}; known: {IMPLS}")
        if self.pattern not in sparsity.PATTERNS:
            raise ValueError(
                f"unknown attention pattern {self.pattern!r}; known: {sparsity.PATTERNS}"
            )

    @property
    def fused(self) -> bool:
        return self.impl == "flash_kernel"

    @property
    def sparse(self) -> bool:
        """True when the pattern prunes blocks beyond causal/window."""
        return self.pattern not in ("dense", "causal", "window")


def override_attention(cfg, impl: str | None = None, pattern: str | None = None):
    """Return ``cfg`` (any dataclass with an ``attention`` AttentionSpec
    field) with the spec's impl/pattern replaced — the single override knob
    behind the serve-engine and dry-run CLI surfaces.  No-op when both are
    None."""
    if impl is None and pattern is None:
        return cfg
    spec = cfg.attention
    if impl is not None:
        spec = dataclasses.replace(spec, impl=impl)
    if pattern is not None:
        spec = dataclasses.replace(spec, pattern=pattern)
    return dataclasses.replace(cfg, attention=spec)


def _pattern_kv_avg(
    s_q: int,
    s_kv: int,
    *,
    causal: bool,
    window: int | None,
    pattern: str,
    pattern_arg: int | None,
    q_tile: int,
    kv_tile: int,
) -> float:
    """Average live kv per query row.  Structural patterns price the block
    map exactly (block-granular, as the sparse kernel executes); the
    dense/causal/window family keeps the closed forms.  A decode step
    (``s_q == 1``) prices the *steady-state mean row* of the full CAUSAL map
    — decode only ever reads the written prefix regardless of the caller's
    ``causal`` flag, and the decoding token's own row density varies with
    position, so the causal mean is what a stream of steps pays."""
    if pattern not in ("dense", "causal", "window"):
        s_q_eff = s_kv if s_q == 1 else s_q
        causal_eff = True if s_q == 1 else causal
        return sparsity.pattern_kv_density(
            pattern, s_q_eff, s_kv, q_tile, kv_tile, causal=causal_eff,
            window=window, pattern_arg=pattern_arg,
        ) * s_kv
    if pattern == "causal":
        causal = True
    if pattern == "window" and window is None:
        window = pattern_arg
    kv_avg = s_kv / 2 if (causal and s_q == s_kv) else s_kv
    if window is not None:
        kv_avg = min(kv_avg, window)
    return kv_avg


def attention_flops(
    batch: int,
    s_q: int,
    s_kv: int,
    heads: int,
    head_dim: int,
    *,
    causal: bool = True,
    window: int | None = None,
    pattern: str = "dense",
    pattern_arg: int | None = None,
    q_tile: int = 128,
    kv_tile: int = 128,
) -> float:
    """Model FLOPs of the softmax stage (QK^T + PV) over the *live* score
    area — impl-independent (the fused kernel skips dead blocks; the XLA form
    wastes the difference computing masked blocks, which its HBM accounting
    exposes)."""
    kv_avg = _pattern_kv_avg(
        s_q, s_kv, causal=causal, window=window, pattern=pattern,
        pattern_arg=pattern_arg, q_tile=q_tile, kv_tile=kv_tile,
    )
    return 2.0 * 2.0 * batch * s_q * kv_avg * heads * head_dim


def attention_hbm_bytes(
    spec: AttentionSpec,
    batch: int,
    s_q: int,
    s_kv: int,
    heads: int,
    kv_heads: int,
    head_dim: int,
    *,
    causal: bool = True,
    window: int | None = None,
    dtype_bytes: int = 2,
) -> float:
    """HBM traffic of the softmax stage under the given execution form.

    ``flash_kernel``: one read of Q and one write of O; the score tile never
    leaves VMEM.  K/V are *re-streamed* from HBM once per (gqa group x q-tile)
    grid row — the block map's kv-tile index table prunes the grid, so each
    pass reads only the pattern-live tiles (density factor from
    :mod:`repro.core.sparsity`), not the full prefix.

    ``xla_chunked``: K/V read once, but the full score matrix round-trips HBM
    (write + softmax read, probs write + einsum read: 4 passes over the
    visible (S_q x S_kv) block, in f32 when ``f32_softmax``).  Structural
    patterns are *mask-only* on this backend — dead blocks are still computed
    and round-tripped, so the pattern does not shrink this term (the paper's
    Fig. 2 point: sparsity without dataflow orchestration saves nothing).
    """
    qo_io = dtype_bytes * batch * s_q * heads * head_dim * 2  # Q read + O write
    kv_vis = _pattern_kv_avg(
        s_q, s_kv, causal=causal, window=window,
        pattern=spec.pattern if spec.fused else "dense",
        pattern_arg=spec.pattern_arg, q_tile=spec.q_tile, kv_tile=spec.kv_tile,
    )
    if spec.fused:
        g = max(heads // max(kv_heads, 1), 1)
        kv_passes = g * max(-(-s_q // spec.q_tile), 1)
        kv_io = dtype_bytes * batch * kv_heads * head_dim * 2 * kv_passes * kv_vis
        return float(qo_io + kv_io)
    kv_io = dtype_bytes * batch * s_kv * kv_heads * head_dim * 2  # K + V once
    score_bytes = 4 if spec.f32_softmax else dtype_bytes
    return float(qo_io + kv_io + 4 * score_bytes * batch * heads * s_q * kv_vis)


def kv_dtype_bytes(
    kv_dtype: str, head_dim: int, base_bytes: float = 2.0
) -> float:
    """Effective HBM bytes per stored KV *value* in a paged pool at
    ``kv_dtype``, including the amortized per-(row, kv_head) float32 scale
    the quantized layouts carry (4 bytes spread over ``head_dim`` values —
    :mod:`repro.core.quant`).  ``bf16`` pools store at the model's cache
    dtype (``base_bytes``) and carry no scales.  Pass the result anywhere a
    byte pricer takes ``dtype_bytes`` — both decode streaming traffic and
    resident pool capacity scale by exactly this factor."""
    if kv_dtype == "bf16":
        return float(base_bytes)
    if kv_dtype in ("int8", "fp8_e4m3"):
        return 1.0 + 4.0 / max(head_dim, 1)
    raise ValueError(
        f"kv_dtype must be one of ('bf16', 'int8', 'fp8_e4m3'), got {kv_dtype!r}"
    )


# --------------------------------------------------------------------------
# Ragged (continuous-batching) accounting: per-row live KV
# --------------------------------------------------------------------------


def ragged_attention_flops(
    s_q: int,
    cur_lens,
    heads: int,
    head_dim: int,
    *,
    pattern: str = "dense",
    pattern_arg: int | None = None,
    q_tile: int = 128,
    kv_tile: int = 128,
) -> float:
    """Softmax-stage FLOPs of a ragged batch: each row attends exactly its
    own live KV prefix (``cur_lens``, one length per request) — the batch
    total is the sum, i.e. batch x *average* live KV per row.  ``s_q`` is 1
    for a decode step, the bucketed prompt length for a ragged prefill.
    Structural ``pattern``s scale each row by its block map's density."""
    total = 0.0
    for cl in cur_lens:
        total += attention_flops(
            1, s_q, int(cl), heads, head_dim, causal=False, pattern=pattern,
            pattern_arg=pattern_arg, q_tile=q_tile, kv_tile=kv_tile,
        )
    return total


def ragged_attention_hbm_bytes(
    spec: AttentionSpec,
    s_q: int,
    cur_lens,
    heads: int,
    kv_heads: int,
    head_dim: int,
    *,
    dtype_bytes: int = 2,
) -> float:
    """HBM traffic of the softmax stage over a ragged batch: the per-row sum
    of :func:`attention_hbm_bytes` at that row's live KV length.  This is the
    *useful* traffic — the continuous-batching engine's decode still streams
    the padded cache, so (sum cur_lens) / (batch x cache_len) is exactly the
    cache-utilization ratio the serve_throughput benchmark reports."""
    total = 0.0
    for cl in cur_lens:
        total += attention_hbm_bytes(
            spec, 1, s_q, int(cl), heads, kv_heads, head_dim,
            causal=False, dtype_bytes=dtype_bytes,
        )
    return total
