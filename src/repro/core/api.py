"""User-facing butterfly layers: the paper's technique as a composable module.

``ButterflyPolicy`` selects where butterfly sparsity enters a model (the
paper's ablation axes: q/k/v projections, output projection, FFN, experts) and
which execution form runs it:

* ``radix2``        — faithful staged BPMM (log N passes; §Perf baseline)
* ``monarch``       — grouped two-super-stage XLA einsums (multilayer dataflow)
* ``monarch_kernel``— fused Pallas kernel (one HBM round-trip; TPU target)
* ``dense``         — no sparsity (the paper's dense baseline)

All linear layers in the model zoo route through :func:`init_linear` /
:func:`apply_linear`, so the technique is a config flag, not a model fork.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.core import butterfly as bfly
from repro.core import monarch as mo
from repro.core.slicing import SlicePlan, plan_slicing

__all__ = [
    "ButterflyPolicy",
    "LinearSpec",
    "init_linear",
    "apply_linear",
    "linear_param_count",
    "linear_flops",
]

Params = dict[str, Any]


@dataclasses.dataclass(frozen=True)
class ButterflyPolicy:
    """Where + how butterfly sparsity is applied (paper Fig. 11 ablation axes)."""

    impl: str = "dense"  # dense | radix2 | monarch | monarch_kernel
    on_qkv: bool = True
    on_out: bool = True
    on_ffn: bool = True
    on_experts: bool = False
    fft_attention: bool = False  # FNet-style AT-all replacement (encoder only)
    max_piece: int = 8192
    max_block: int = 512  # super-stage radix budget (paper's DFG limit)

    @property
    def enabled(self) -> bool:
        return self.impl != "dense"

    def for_site(self, site: str) -> str:
        """Effective impl for a layer site in {qkv, out, ffn, experts, other}."""
        if not self.enabled:
            return "dense"
        ok = {
            "qkv": self.on_qkv,
            "out": self.on_out,
            "ffn": self.on_ffn,
            "experts": self.on_experts,
        }.get(site, False)
        return self.impl if ok else "dense"


DENSE = ButterflyPolicy()


@dataclasses.dataclass(frozen=True)
class LinearSpec:
    din: int
    dout: int
    impl: str = "dense"
    use_bias: bool = False
    max_piece: int = 8192
    max_block: int = 512

    @property
    def slices(self) -> SlicePlan:
        return plan_slicing(self.din, self.dout, self.max_piece)

    @property
    def block(self) -> int:
        return 1 << mo.split_point(self.slices.piece, self.max_block)


def init_linear(key: jax.Array, spec: LinearSpec, dtype=jnp.float32) -> Params:
    kw, kb = jax.random.split(key)
    params: Params = {}
    if spec.impl == "dense":
        scale = 1.0 / math.sqrt(spec.din)
        params["w"] = jax.random.normal(kw, (spec.din, spec.dout), dtype) * scale
    elif spec.impl == "radix2":
        sp = spec.slices
        stages = []
        for shape in bfly.stage_shapes(sp.piece):
            kw, k = jax.random.split(kw)
            w = jax.random.normal(k, (sp.gout, sp.gin, *shape), dtype)
            stages.append(w * math.sqrt(0.5) / math.sqrt(sp.gin) ** (1.0 / len(bfly.stage_shapes(sp.piece))))
        params["stages"] = stages
    elif spec.impl in ("monarch", "monarch_kernel"):
        sp = spec.slices
        b = spec.block
        nb = sp.piece // b
        kr, kl = jax.random.split(kw)
        gscale = 1.0 / math.sqrt(sp.gin)
        params["r"] = (
            jax.random.normal(kr, (sp.gout, sp.gin, nb, b, b), dtype) / math.sqrt(b)
        )
        params["l"] = (
            jax.random.normal(kl, (sp.gout, sp.gin, b, nb, nb), dtype)
            / math.sqrt(nb)
            * gscale
        )
    else:
        raise ValueError(f"unknown linear impl {spec.impl!r}")
    if spec.use_bias:
        params["b"] = jnp.zeros((spec.dout,), dtype)
    return params


def _pad_last(x: jax.Array, to: int) -> jax.Array:
    pad = to - x.shape[-1]
    if pad == 0:
        return x
    return jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)])


def apply_linear(params: Params, spec: LinearSpec, x: jax.Array) -> jax.Array:
    """y = x @ W (+ b) under the configured butterfly execution form."""
    if spec.impl == "dense":
        y = x @ params["w"].astype(x.dtype)
    elif spec.impl == "radix2":
        y = _apply_radix2(params, spec, x)
    elif spec.impl == "monarch":
        y = _apply_monarch(params, spec, x)
    elif spec.impl == "monarch_kernel":
        from repro.kernels import ops  # local import: kernels are optional

        y = ops.monarch_linear(params, spec, x)
    else:
        raise ValueError(spec.impl)
    if spec.use_bias:
        y = y + params["b"].astype(y.dtype)
    return y


def _apply_radix2(params: Params, spec: LinearSpec, x: jax.Array) -> jax.Array:
    """Faithful staged BPMM over the slice grid — one strided pass per stage."""
    sp = spec.slices
    x = _pad_last(x, sp.din_pad)
    lead = x.shape[:-1]
    # (..., gin, piece) -> broadcast a gout axis; stream stages
    xg = x.reshape(*lead, 1, sp.gin, sp.piece)
    for w in params["stages"]:
        w = w.astype(x.dtype)
        gout, gin, blocks, _, _, s = w.shape
        xr = xg.reshape(*lead, xg.shape[-3], gin, blocks, 2, s)
        x0, x1 = xr[..., 0, :], xr[..., 1, :]
        y0 = w[..., 0, 0, :] * x0 + w[..., 0, 1, :] * x1
        y1 = w[..., 1, 0, :] * x0 + w[..., 1, 1, :] * x1
        xg = jnp.stack([y0, y1], axis=-2).reshape(*lead, gout, gin, sp.piece)
    y = xg.sum(axis=-2).reshape(*lead, sp.dout_pad)
    return y[..., : sp.dout]


def _apply_monarch(params: Params, spec: LinearSpec, x: jax.Array) -> jax.Array:
    """Grouped two-super-stage apply over the slice grid (XLA einsums)."""
    sp = spec.slices
    r, l = params["r"].astype(x.dtype), params["l"].astype(x.dtype)
    gout, gin, nb, b, _ = r.shape
    x = _pad_last(x, sp.din_pad)
    lead = x.shape[:-1]
    xr = x.reshape(*lead, gin, nb, b)
    u = jnp.einsum("oghij,...ghj->...oghi", r, xr)
    y = jnp.einsum("ogjhk,...ogkj->...oghj", l, u)
    y = y.sum(axis=-3).reshape(*lead, sp.dout_pad)
    return y[..., : sp.dout]


def linear_param_count(spec: LinearSpec) -> int:
    if spec.impl == "dense":
        n = spec.din * spec.dout
    else:
        sp = spec.slices
        g = sp.gin * sp.gout
        if spec.impl == "radix2":
            n = g * bfly.butterfly_param_count(sp.piece)
        else:
            n = g * mo.monarch_param_count(sp.piece, spec.block)
    return n + (spec.dout if spec.use_bias else 0)


def linear_flops(spec: LinearSpec, tokens: int) -> int:
    """Model (useful) FLOPs for `tokens` row-vectors through this layer."""
    if spec.impl == "dense":
        return 2 * tokens * spec.din * spec.dout
    sp = spec.slices
    g = sp.gin * sp.gout
    if spec.impl == "radix2":
        # 4 mul + 2 add per element pair per stage = 6 flops per 2 elements
        return tokens * g * 3 * sp.piece * bfly.num_stages(sp.piece)
    return tokens * g * mo.monarch_flops(sp.piece, spec.block)
