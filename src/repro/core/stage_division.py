"""Multi-stage division — the paper's Cooley–Tukey scalability method (§V-B).

A transform too large for one DFG (paper: > 512 real / > 256 complex points;
here: larger than one VMEM-resident super-stage) is factored ``N = r1 * r2 *
...`` and executed as a chain of batched small transforms with twiddle layers
in between (paper Fig. 9).  The paper's Fig. 14 finding — *balanced* divisions
maximise utilisation — is encoded in :func:`plan_stages`, which factors N into
the most balanced radix tuple subject to ``max_radix``.

General mixed-radix identity used (decimation in time), for ``N = N1 * N2``,
input index ``n = N2*n1 + n2``, output index ``k = k1 + N1*k2``::

    A[k1, n2] = sum_n1 x[n1, n2] * w_N1^(n1 k1)        # stage 1, along axis 0
    B[k1, n2] = A[k1, n2] * w_N^(n2 k1)                # twiddle (FFT only)
    X[k1, k2] = sum_n2 B[k1, n2] * w_N2^(n2 k2)        # stage 2, along axis 1

and the output lives at ``(k2, k1)`` after the final digit-reversal transpose.
Stage 1 recurses when ``len(plan) > 2``.
"""

from __future__ import annotations

from functools import lru_cache
from typing import Sequence

import jax.numpy as jnp
import numpy as np

__all__ = [
    "factorize",
    "plan_stages",
    "dft_matrix",
    "twiddle",
    "mixed_radix_dft",
    "stage_flops",
]

# Paper §V-B: the largest single-DFG scale on the 16-PE array.  We keep the
# same budgets — they happen to match comfortable VMEM tile sizes too.
MAX_RADIX_REAL = 512
MAX_RADIX_COMPLEX = 256


def factorize(n: int) -> list[int]:
    """Prime factorisation (ascending)."""
    out, d = [], 2
    while d * d <= n:
        while n % d == 0:
            out.append(d)
            n //= d
        d += 1
    if n > 1:
        out.append(n)
    return out


@lru_cache(maxsize=None)
def plan_stages(n: int, max_radix: int = MAX_RADIX_COMPLEX) -> tuple[int, ...]:
    """Balanced radix plan: factors of ``n``, each <= max_radix, as equal as
    possible (paper Fig. 14: 64*64 beats 16*256 for 4K points).

    Greedy: repeatedly peel the radix closest to ``n ** (1/k)`` for the
    smallest feasible stage count ``k``.
    """
    if n <= max_radix:
        return (n,)
    primes = factorize(n)
    if max(primes) > max_radix:
        raise ValueError(f"{n} has prime factor {max(primes)} > max_radix {max_radix}")

    # smallest feasible stage count, with backtracking: divisor structure can
    # make k stages infeasible even when max_radix**k >= n (e.g. 3640 @ 64)
    k = 2
    while max_radix**k < n:
        k += 1
    for kk in range(k, len(primes) + 1):
        plan = _search(n, kk, max_radix)
        if plan is not None:
            return tuple(sorted(plan, reverse=True))
    raise ValueError(f"no stage division found for {n} under max_radix {max_radix}")


def _search(remaining: int, stages: int, max_radix: int) -> tuple[int, ...] | None:
    """Balanced-first divisor search (backtracking)."""
    if stages == 1:
        return (remaining,) if remaining <= max_radix else None
    target = remaining ** (1.0 / stages)
    cands = [
        d
        for d in _divisors(remaining)
        if 1 < d <= max_radix and remaining // d <= max_radix ** (stages - 1)
    ]
    for d in sorted(cands, key=lambda d: abs(d - target)):
        tail = _search(remaining // d, stages - 1, max_radix)
        if tail is not None:
            return (d,) + tail
    return None


def _divisors(n: int) -> list[int]:
    out = []
    d = 1
    while d * d <= n:
        if n % d == 0:
            out.append(d)
            if d != n // d:
                out.append(n // d)
        d += 1
    return sorted(out)


def dft_matrix(n: int, dtype=np.complex64) -> np.ndarray:
    """Dense DFT matrix ``Omega_N`` of Eq. (1).  Pure numpy so it stays a
    compile-time constant under jit."""
    idx = np.arange(n)
    return np.exp(-2j * np.pi * np.outer(idx, idx) / n).astype(dtype)


def twiddle(n1: int, n2: int, dtype=np.complex64) -> np.ndarray:
    """Twiddle ``w_N^(k1 n2)`` of shape (n1, n2) — the element-wise layer of
    paper Fig. 9 step 3.  Pure numpy (compile-time constant)."""
    k1 = np.arange(n1)[:, None]
    n2i = np.arange(n2)[None, :]
    return np.exp(-2j * np.pi * k1 * n2i / (n1 * n2)).astype(dtype)


def mixed_radix_dft(x: jnp.ndarray, plan: Sequence[int] | None = None) -> jnp.ndarray:
    """DFT along the last axis via the multi-stage division plan.

    Pure-jnp oracle (complex); the Pallas kernel in
    :mod:`repro.kernels.fft2d` implements the fused two-stage version in real
    arithmetic.  Matches ``jnp.fft.fft`` for any composite smooth N.
    """
    n = x.shape[-1]
    if plan is None:
        plan = plan_stages(n)
    plan = tuple(plan)
    assert int(np.prod(plan)) == n, (plan, n)
    x = x.astype(jnp.complex64)
    if len(plan) == 1:
        return x @ dft_matrix(n).T

    n1, n2 = plan[0], int(np.prod(plan[1:]))
    xr = x.reshape(*x.shape[:-1], n1, n2)
    # stage 1: DFT_n1 along the n1 axis (recursion bottoms out in a matmul)
    a = jnp.swapaxes(mixed_radix_dft(jnp.swapaxes(xr, -1, -2), (n1,)), -1, -2)
    # twiddle
    a = a * twiddle(n1, n2)
    # stage 2: DFT_n2 along the n2 axis (recurse with the tail plan)
    b = mixed_radix_dft(a, plan[1:])
    # digit reversal: output index k = k1 + n1 * k2  ->  lay out as (k2, k1)
    return jnp.swapaxes(b, -1, -2).reshape(*x.shape[:-1], n)


def stage_flops(n: int, plan: Sequence[int], complex_valued: bool = True) -> int:
    """Model FLOPs of the staged transform: sum over stages of batched dense
    small matmuls + twiddle layers.  Complex mul = 6 flops, add = 2."""
    mul, add = (6, 2) if complex_valued else (2, 1)  # fused mul-add counted apart
    total = 0
    for r in plan:
        per = (n // r) * (r * r * (mul + add))  # (n/r) transforms of r x r
        total += per
    total += (len(plan) - 1) * n * mul  # twiddle layers
    return total
