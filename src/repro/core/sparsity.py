"""Static block-sparsity maps for the attention score matrix (paper §III).

The paper's hybrid butterfly-sparsity network prunes the attention map itself:
whole (q_tile x kv_tile) score blocks are statically dead and never computed.
This module is the single source of truth for *which* blocks live — the Pallas
kernels iterate only the live set (real compute/HBM skipping via the grid's
kv-tile index map), the XLA forms mask with the same map, the analytic
FLOP/HBM accounting scales by its density, and the test oracle expands it to a
token-level mask.  One map, four consumers — parity is by construction.

Patterns (``AttentionSpec.pattern``; block (i, j) indexes q-tile x kv-tile):

* ``dense``          every block live (causal/window feasibility still prunes)
* ``causal``         alias of dense with causal forced on
* ``window``         alias of dense with a sliding window (``pattern_arg`` =
                     window in tokens when the call site gives none)
* ``butterfly``      radix-2 butterfly over kv tiles: j live for q-tile i iff
                     ``i ^ j`` has at most one bit set — i and j differ in at
                     most one bit, the union of all log2(n) butterfly stages'
                     stride pairs (Pixelated-Butterfly-style, O(N log N) blocks)
* ``strided``        local diagonal + every ``pattern_arg``-th earlier tile
                     (Sparse-Transformer dilated form; default stride
                     ~sqrt(n_kv_tiles))
* ``global_window``  first ``pattern_arg`` kv tiles are global (every query
                     attends them, their queries attend everything) + a local
                     diagonal band (Longformer-style)

Block liveness composes with causal/window *feasibility* (blocks entirely
above the diagonal or outside the window are dead regardless of pattern) and
with the fine in-tile mask (causal diagonal, window edge, padded keys) that
keeps partially-live tiles exact.  Patterns are *block-granular* by
definition: the token-level reference mask is the block map expanded to
tokens, AND the fine constraints — the kernels and the oracle agree exactly.
"""

from __future__ import annotations

import dataclasses
import math

import numpy as np

__all__ = [
    "PATTERNS",
    "BlockMap",
    "canonical_pattern",
    "build_block_map",
    "token_mask",
    "pick_pattern_tiles",
    "pattern_kv_density",
    "decode_max_live",
    "decode_live_tables",
    "decode_token_mask",
    "chunk_live_tables",
    "chunk_token_mask",
    "translate_tables",
    "page_last_reader",
    "page_last_reader_union",
    "page_residency",
    "page_peak_resident",
    "page_resume_peak",
]

PATTERNS = ("dense", "causal", "window", "butterfly", "strided", "global_window")

_LANES = 128  # kv tiles align to the TPU lane width (mirrors flash_attention)


def canonical_pattern(
    pattern: str, pattern_arg: int | None, causal: bool, window: int | None
) -> tuple[str, int | None, bool, int | None]:
    """Fold the ``causal`` / ``window`` pattern *aliases* into the explicit
    flags every execution path already carries, so kernels and masks only ever
    see the structural patterns (dense / butterfly / strided / global_window)."""
    if pattern == "causal":
        return "dense", None, True, window
    if pattern == "window":
        win = window if window is not None else pattern_arg
        if win is None:
            raise ValueError("pattern 'window' needs pattern_arg (window tokens)")
        return "dense", None, causal, win
    return pattern, pattern_arg, causal, window


def pick_pattern_tiles(s_q: int, s_kv: int, q_tile: int, kv_tile: int) -> tuple[int, int]:
    """The *effective* tile grid a problem runs on (kernel clamp mirrored).

    Every consumer of a block map — fused kernel, XLA mask, accounting, test
    oracle — must build it on the same grid, so the clamp lives here and
    :func:`repro.kernels.flash_attention.pick_tiles` delegates to it."""
    tq = min(q_tile, -(-s_q // 8) * 8)
    tk = min(kv_tile, -(-s_kv // _LANES) * _LANES)
    return max(tq, 8), max(tk, _LANES)


@dataclasses.dataclass(frozen=True)
class BlockMap:
    """Static liveness of the (q_tile x kv_tile) score blocks + the packed
    per-q-row kv-tile index map the sparse kernel grid iterates.

    ``kv_index[i, jj]`` is the jj-th live kv-tile of q-tile row i; rows with
    fewer than ``max_live`` live tiles pad with tile 0 and ``step_live`` 0 —
    padded steps are skipped inside the kernel (no MXU work) and revisit an
    already-resident block (no fresh HBM traffic)."""

    pattern: str
    s_q: int
    s_kv: int
    q_tile: int
    kv_tile: int
    causal: bool
    window: int | None
    live: np.ndarray  # (n_q_tiles, n_kv_tiles) bool
    kv_index: np.ndarray  # (n_q_tiles, max_live) int32
    step_live: np.ndarray  # (n_q_tiles, max_live) int32 (0 | 1)

    @property
    def n_q_tiles(self) -> int:
        return self.live.shape[0]

    @property
    def n_kv_tiles(self) -> int:
        return self.live.shape[1]

    @property
    def max_live(self) -> int:
        return self.kv_index.shape[1]

    @property
    def grid_steps(self) -> int:
        """kv-axis grid iterations per (batch x head x q-tile-row) sweep."""
        return self.n_q_tiles * self.max_live

    @property
    def dense_grid_steps(self) -> int:
        return self.n_q_tiles * self.n_kv_tiles

    @property
    def kv_density(self) -> float:
        """Mean live-block fraction per q row — the analytic density factor."""
        return float(self.live.sum()) / max(self.live.size, 1)


def _span(i: int, q_tile: int, kv_tile: int, n_kv: int) -> tuple[int, int]:
    """kv-tile indices overlapped by q-tile i (inclusive lo, hi)."""
    lo = (i * q_tile) // kv_tile
    hi = ((i + 1) * q_tile - 1) // kv_tile
    return min(lo, n_kv - 1), min(hi, n_kv - 1)


def _pattern_live(
    pattern: str, nq: int, nk: int, q_tile: int, kv_tile: int,
    causal: bool, pattern_arg: int | None,
) -> np.ndarray:
    live = np.zeros((nq, nk), bool)
    if pattern in ("dense", "causal", "window"):
        live[:] = True
        return live
    if pattern == "butterfly":
        # i and j differ in at most one bit over the kv-tile index space
        j = np.arange(nk)
        for i in range(nq):
            lo, hi = _span(i, q_tile, kv_tile, nk)
            for ii in range(lo, hi + 1):
                x = ii ^ j
                live[i] |= (x & (x - 1)) == 0  # x == 0 or power of two
        return live
    if pattern == "strided":
        stride = pattern_arg or max(2, int(math.isqrt(max(nk, 1))))
        j = np.arange(nk)
        for i in range(nq):
            lo, hi = _span(i, q_tile, kv_tile, nk)
            for ii in range(lo, hi + 1):
                live[i] |= j == ii
                if causal:
                    live[i] |= (j < ii) & ((ii - j) % stride == 0)
                else:
                    live[i] |= np.abs(ii - j) % stride == 0
        return live
    if pattern == "global_window":
        g = pattern_arg or 1
        band = 1  # local diagonal band in tiles (window arg adds more)
        j = np.arange(nk)
        for i in range(nq):
            lo, hi = _span(i, q_tile, kv_tile, nk)
            live[i] |= j < g  # global kv tiles: everyone attends them
            if lo < g:  # global q rows attend everything
                live[i] = True
            live[i] |= (j >= lo - band) & (j <= hi + band)
        return live
    raise ValueError(f"unknown sparsity pattern {pattern!r}; known: {PATTERNS}")


def build_block_map(
    pattern: str,
    s_q: int,
    s_kv: int,
    q_tile: int,
    kv_tile: int,
    *,
    causal: bool = True,
    window: int | None = None,
    pattern_arg: int | None = None,
) -> BlockMap:
    """Build the static per-q-tile live kv-tile map on the given tile grid.

    ``q_tile`` / ``kv_tile`` must be the *effective* tiles of the executing
    path (:func:`pick_pattern_tiles`).  The named ``causal`` / ``window``
    aliases fold into the feasibility pruning; explicit ``causal`` / ``window``
    args compose with every pattern."""
    if pattern not in PATTERNS:
        raise ValueError(f"unknown sparsity pattern {pattern!r}; known: {PATTERNS}")
    if pattern == "causal":
        causal = True
    if pattern == "window":
        window = window or pattern_arg
        if window is None:
            raise ValueError("pattern 'window' needs pattern_arg (window tokens)")
    nq = -(-s_q // q_tile)
    nk = -(-s_kv // kv_tile)
    live = _pattern_live(pattern, nq, nk, q_tile, kv_tile, causal, pattern_arg)

    i = np.arange(nq)[:, None]
    j = np.arange(nk)[None, :]
    live &= j * kv_tile < s_kv  # blocks entirely in key padding
    if causal:
        live &= j * kv_tile <= i * q_tile + q_tile - 1
    if window is not None:
        live &= j * kv_tile + kv_tile - 1 > i * q_tile - window
    # every q row keeps >= 1 live block (an all-dead softmax row is NaN); the
    # clamped diagonal block is always feasible under causal + window
    for r in range(nq):
        if not live[r].any():
            lo, _ = _span(r, q_tile, kv_tile, nk)
            live[r, lo] = True

    max_live = max(int(live.sum(axis=1).max()), 1)
    kv_index = np.zeros((nq, max_live), np.int32)
    step_live = np.zeros((nq, max_live), np.int32)
    for r in range(nq):
        idx = np.nonzero(live[r])[0]
        kv_index[r, : len(idx)] = idx
        step_live[r, : len(idx)] = 1
    return BlockMap(
        pattern=pattern, s_q=s_q, s_kv=s_kv, q_tile=q_tile, kv_tile=kv_tile,
        causal=causal, window=window, live=live, kv_index=kv_index,
        step_live=step_live,
    )


def token_mask(bm: BlockMap) -> np.ndarray:
    """Expand the block map to the exact token-level mask (s_q, s_kv): live
    block AND fine causal/window constraints.  This is the oracle's mask and
    the definition of pattern correctness for every execution form."""
    m = np.repeat(np.repeat(bm.live, bm.q_tile, axis=0), bm.kv_tile, axis=1)
    m = m[: bm.s_q, : bm.s_kv]
    qpos = np.arange(bm.s_q)[:, None]
    kpos = np.arange(bm.s_kv)[None, :]
    if bm.causal:
        m = m & (qpos >= kpos)
    if bm.window is not None:
        m = m & (kpos > qpos - bm.window)
    return m


def pattern_kv_density(
    pattern: str,
    s_q: int,
    s_kv: int,
    q_tile: int,
    kv_tile: int,
    *,
    causal: bool = True,
    window: int | None = None,
    pattern_arg: int | None = None,
) -> float:
    """Fraction of the (s_q x s_kv) score area that is live under the pattern
    — block-granular, i.e. exactly the compute/HBM the sparse kernel performs
    (partially-live boundary tiles count whole, as executed)."""
    tq, tk = pick_pattern_tiles(s_q, s_kv, q_tile, kv_tile)
    bm = build_block_map(
        pattern, s_q, s_kv, tq, tk, causal=causal, window=window,
        pattern_arg=pattern_arg,
    )
    return bm.kv_density


# --------------------------------------------------------------------------
# Decode: per-row live kv-tile tables over the cache (traced positions)
# --------------------------------------------------------------------------


def decode_max_live(
    pattern: str,
    cache_len: int,
    q_tile: int,
    kv_tile: int,
    *,
    window: int | None = None,
    pattern_arg: int | None = None,
) -> int:
    """Static worst-case live kv-tile count for a single decode row — the
    sparse decode grid's kv extent.  Exact: the max row population of the full
    prefill-shaped map at the cache length (the decoding token's q-tile row is
    one of those rows)."""
    bm = build_block_map(
        pattern, cache_len, cache_len, q_tile, kv_tile, causal=True,
        window=window, pattern_arg=pattern_arg,
    )
    return int(bm.live.sum(axis=1).max())


def _decode_live_jnp(pattern, qi, j, nk, q_tile, kv_tile, window, pattern_arg):
    """Per-row block liveness (jnp): qi (B, 1) q-tile index, j (1, nk)."""
    import jax.numpy as jnp

    # q-tile span in kv-tile space (q_tile may differ from kv_tile)
    lo = (qi * q_tile) // kv_tile
    hi = ((qi + 1) * q_tile - 1) // kv_tile
    hi = jnp.minimum(hi, nk - 1)
    lo = jnp.minimum(lo, nk - 1)
    if pattern in ("dense", "causal", "window"):
        live = jnp.ones_like(j | qi, bool)
    elif pattern == "butterfly":
        live = jnp.zeros_like(j | qi, bool)
        # static bound on the q-tile's kv-tile span; per-row gate keeps it
        # identical to the static builder's inclusive [lo, hi] range
        for off in range((q_tile - 1) // kv_tile + 2):
            ii = jnp.minimum(lo + off, nk - 1)
            x = ii ^ j
            live |= ((x & (x - 1)) == 0) & (lo + off <= hi)
    elif pattern == "strided":
        stride = pattern_arg or max(2, int(math.isqrt(max(nk, 1))))
        live = jnp.zeros_like(j | qi, bool)
        for off in range((q_tile - 1) // kv_tile + 2):
            ii = jnp.minimum(lo + off, nk - 1)
            live |= ((j == ii) | ((j < ii) & ((ii - j) % stride == 0))) & (
                lo + off <= hi
            )
    elif pattern == "global_window":
        g = pattern_arg or 1
        live = (j < g) | (lo < g) | ((j >= lo - 1) & (j <= hi + 1))
    else:
        raise ValueError(f"unknown sparsity pattern {pattern!r}; known: {PATTERNS}")
    return live


def _pack_live(live, j, max_live: int):
    """Pack per-row live kv-tile indices first (stable in j), padded with
    tile 0 / live 0 — the table layout both sparse kernels dereference.
    live: (B, nk) bool; j: (1, nk) int32.  Returns (kv_index, step_live)."""
    import jax.numpy as jnp

    nk = live.shape[1]
    order = jnp.argsort(jnp.where(live, j, nk + j), axis=1)[:, :max_live]
    packed_live = jnp.take_along_axis(live, order, axis=1)
    kv_index = jnp.where(packed_live, order, 0).astype(jnp.int32)
    return kv_index, packed_live.astype(jnp.int32)


def decode_live_tables(
    pattern: str,
    cur_len,  # (B,) traced live lengths (pos + 1)
    cache_len: int,
    q_tile: int,
    kv_tile: int,
    *,
    window: int | None = None,
    pattern_arg: int | None = None,
    max_live: int | None = None,
):
    """Per-row packed live kv-tile tables for sparse flash-decode.

    Returns (kv_index (B, max_live) int32, step_live (B, max_live) int32).
    Row b's decoding token sits in q-tile ``(cur_len[b]-1) // q_tile``; its
    live kv tiles are the pattern row restricted to written cache tiles
    (``j * kv_tile < cur_len[b]``) — dead tiles are *absent from the table*,
    so the kernel grid never visits them."""
    import jax.numpy as jnp

    nk = -(-cache_len // kv_tile)
    if max_live is None:
        max_live = decode_max_live(
            pattern, cache_len, q_tile, kv_tile, window=window,
            pattern_arg=pattern_arg,
        )
    max_live = min(max_live, nk)
    cl = jnp.asarray(cur_len, jnp.int32).reshape(-1, 1)  # (B, 1)
    qi = jnp.maximum(cl - 1, 0) // q_tile
    j = jnp.arange(nk, dtype=jnp.int32)[None, :]  # (1, nk)
    live = _decode_live_jnp(pattern, qi, j, nk, q_tile, kv_tile, window, pattern_arg)
    live &= j * kv_tile < cl  # only written cache tiles
    if window is not None:
        live &= (j + 1) * kv_tile - 1 > cl - 1 - window
    live |= j == jnp.minimum(qi * q_tile // kv_tile, nk - 1)  # diag always live
    return _pack_live(live, j, max_live)


def decode_token_mask(
    pattern: str,
    cur_len,
    cache_len: int,
    q_tile: int,
    kv_tile: int,
    *,
    window: int | None = None,
    pattern_arg: int | None = None,
):
    """Token-level decode mask (B, cache_len) bool (jnp) — the XLA decode
    form's view of the same per-row live tile set (parity with the sparse
    kernel by construction; the caller still ANDs its ``cur_len`` mask)."""
    import jax.numpy as jnp

    nk = -(-cache_len // kv_tile)
    kv_index, step_live = decode_live_tables(
        pattern, cur_len, cache_len, q_tile, kv_tile, window=window,
        pattern_arg=pattern_arg, max_live=nk,
    )
    tile_live = jnp.zeros((kv_index.shape[0], nk), bool)
    tile_live = tile_live.at[
        jnp.arange(kv_index.shape[0])[:, None], kv_index
    ].max(step_live > 0)
    mask = jnp.repeat(tile_live, kv_tile, axis=1)[:, :cache_len]
    return mask


# --------------------------------------------------------------------------
# Mixed chunked-prefill steps: per-row chunk tables over the shared cache
# --------------------------------------------------------------------------


def chunk_max_live(
    pattern: str,
    chunk: int,
    cache_len: int,
    q_tile: int,
    kv_tile: int,
    *,
    window: int | None = None,
    pattern_arg: int | None = None,
) -> int:
    """Static worst-case live kv-tile count for one chunk row of the mixed
    step — the chunk kernel grid's kv extent.

    A chunk of ``chunk`` queries starting anywhere inside q-tile ``i`` spans
    q-tile rows ``i .. i + span - 1`` (``span = (chunk-1)//q_tile + 2``; the
    start is not tile-aligned); its table is the union of those rows' pattern
    sets, capped right by the written frontier (< ``(i+span)*q_tile``) and
    left by the first query's window edge (> ``i*q_tile - window``).  The max
    over ``i`` of that union's population is an exact worst case for
    :func:`chunk_live_tables` — computed on the same static map, so the
    argsort pack can never truncate a live tile."""
    nq = -(-cache_len // q_tile)
    nk = -(-cache_len // kv_tile)
    span = (max(chunk, 1) - 1) // q_tile + 2
    live = _pattern_live(pattern, nq, nk, q_tile, kv_tile, True, pattern_arg)
    j = np.arange(nk)
    best = 1
    for i in range(nq):
        u = np.zeros(nk, bool)
        for r in range(i, min(i + span, nq)):
            u |= live[r]
        u &= j * kv_tile <= min((i + span) * q_tile, cache_len) - 1
        if window is not None:
            u &= (j + 1) * kv_tile - 1 > i * q_tile - window
        u[min((i * q_tile) // kv_tile, nk - 1)] = True  # forced diagonal
        best = max(best, int(u.sum()))
    return min(best, nk)


def chunk_live_tables(
    pattern: str,
    start,  # (B,) traced absolute position of each row's first chunk query
    ntok,  # (B,) traced valid-token count per row (0 = idle slot)
    chunk: int,
    cache_len: int,
    q_tile: int,
    kv_tile: int,
    *,
    window: int | None = None,
    pattern_arg: int | None = None,
):
    """Per-row packed live kv-tile tables for the mixed chunk kernel.

    Returns (kv_index (B, max_live) int32, step_live (B, max_live) int32).
    Row b's queries sit at absolute positions ``start[b] .. start[b]+ntok[b]-1``
    over the shared cache; the table is the union of those rows' pattern-live
    kv tiles (the same per-q-tile machinery as :func:`decode_live_tables`),
    restricted to written cache tiles (``j * kv_tile < start + ntok``) — the
    causal frontier guarantees every readable key is already written.  The
    fine in-kernel mask then trims each query back to its own q-tile's row, so
    per-query liveness matches the static prefill map exactly."""
    import jax.numpy as jnp

    nk = -(-cache_len // kv_tile)
    start = jnp.asarray(start, jnp.int32).reshape(-1)
    ntok = jnp.asarray(ntok, jnp.int32).reshape(-1)
    b = start.shape[0]
    qpos = start[:, None] + jnp.arange(chunk, dtype=jnp.int32)[None, :]  # (B, C)
    qi = (qpos // q_tile).reshape(-1, 1)  # (B*C, 1)
    j = jnp.arange(nk, dtype=jnp.int32)[None, :]  # (1, nk)
    live = _decode_live_jnp(pattern, qi, j, nk, q_tile, kv_tile, window, pattern_arg)
    live = live.reshape(b, chunk, nk)
    # idle / budget-starved rows keep their first query row so the table is
    # never empty (the kernel's flush still emits zeros for fully-dead rows)
    valid_q = jnp.arange(chunk)[None, :] < jnp.maximum(ntok, 1)[:, None]
    live &= valid_q[:, :, None]
    live = live.any(axis=1)  # (B, nk): union over the chunk's q rows
    live &= j * kv_tile < (start + jnp.maximum(ntok, 1))[:, None]  # written
    if window is not None:
        # earliest key any chunk query can reach is start - window + 1 (the
        # first query's window edge); later queries only reach further right
        live &= (j + 1) * kv_tile - 1 > (start - window)[:, None]
    # the tile holding the row's own start is always feasible (NaN guard,
    # mirrors decode_live_tables' forced diagonal)
    live |= j == jnp.minimum((start[:, None] // q_tile) * q_tile // kv_tile, nk - 1)
    max_live = chunk_max_live(
        pattern, chunk, cache_len, q_tile, kv_tile, window=window,
        pattern_arg=pattern_arg,
    )
    return _pack_live(live, j, max_live)


# --------------------------------------------------------------------------
# Paged KV cache: virtual-tile -> physical-page translation + page lifetimes
# --------------------------------------------------------------------------


def translate_tables(
    kv_index, step_live, page_table, n_pages: int, *,
    ring_tiles: int | None = None,
    page_range: tuple[int, int] | None = None,
):
    """Compose packed live *virtual* kv-tile tables with a page table.

    ``kv_index`` / ``step_live``: (R, max_live) the packed tables
    :func:`decode_live_tables` / :func:`chunk_live_tables` /
    :class:`BlockMap` emit — entries index VIRTUAL kv tiles of a request's
    logical cache.  ``page_table``: (R, n_vtiles) or (n_vtiles,) int32 mapping
    virtual tile -> physical page id in a global pool of ``n_pages`` pages;
    unallocated tiles hold the sentinel ``n_pages``.

    ``ring_tiles`` is the mod-window modulus: when set, the page table has
    only ``ring_tiles`` slots and virtual tile ``j`` lives in slot
    ``j % ring_tiles`` — a sliding-window request reuses a window-sized page
    set in phase instead of allocating one page per absolute tile.  The
    returned ``kv_virt`` stays ABSOLUTE either way: the kernels' fine masks
    index token positions, which never wrap.

    ``page_range`` makes the translation MESH-LOCAL: ``(lo, hi)`` is the
    half-open physical page range one shard of a page-sharded pool owns
    (GSPMD partitions the pool's page axis contiguously, see
    :func:`repro.models.transformer.paged_pool_specs`).  Entries outside the
    range are masked dead — that shard's kernel never prefetches a page it
    does not hold — and in-range ids are REBASED to the shard's local pool
    (``phys - lo``), so the shard indexes its own ``hi - lo`` pages.  Each
    allocated tile is owned by exactly one shard, so summing the shards'
    attention partials (or gathers) reassembles the replicated result — the
    invariant the mesh-local sweep test pins.

    Returns ``(kv_phys, kv_virt, step_live')``: the same packed layout with
    physical page ids (clamped in-bounds so dead steps still DMA a real page),
    the untouched virtual ids, and liveness ANDed with "the tile is
    allocated" — a live-but-freed tile can only arise from a
    retention-schedule bug, and masking it keeps the failure a parity miss
    instead of reading another request's keys.  The kernel grid shape is
    unchanged: dead tiles were already absent, translation only redirects the
    DMA."""
    import jax.numpy as jnp

    kv_index = jnp.asarray(kv_index, jnp.int32)
    step_live = jnp.asarray(step_live, jnp.int32)
    pt = jnp.asarray(page_table, jnp.int32)
    slot = kv_index % ring_tiles if ring_tiles else kv_index
    if pt.ndim == 1:
        phys = pt[slot]
    else:
        phys = jnp.take_along_axis(pt, slot, axis=1)
    live = step_live * (phys < n_pages).astype(jnp.int32)
    if page_range is not None:
        lo, hi = page_range
        if not 0 <= lo < hi <= n_pages:
            raise ValueError(
                f"page_range {page_range} outside pool of {n_pages}"
            )
        live = live * ((phys >= lo) & (phys < hi)).astype(jnp.int32)
        return jnp.clip(phys - lo, 0, hi - lo - 1), kv_index, live
    return jnp.minimum(phys, n_pages - 1), kv_index, live


# --------------------------------------------------------------------------
# Mod-window rings: sliding-window caches as phase-reused page tables
# --------------------------------------------------------------------------


def ring_tiles_for(window: int, step_span: int, kv_tile: int) -> int:
    """Ring modulus (page-table slot count) for a sliding-window cache.

    During one engine step of up to ``step_span`` query positions, the live
    key span is ``window + step_span - 1`` tokens (the step's first query
    still reads back ``window``, its last query writes ``step_span - 1``
    ahead), plus one tile of alignment slack — so ``R`` distinct slots
    guarantee no two simultaneously-live absolute tiles collide mod ``R``,
    and a partially-overwritten frontier slot only ever shadows positions the
    window mask already rejects (``R * kv_tile >= window + kv_tile``)."""
    return -(-(window + max(step_span, 1) - 1) // kv_tile) + 1


def ring_decode_tables(cur_len, window: int, kv_tile: int, ring_tiles: int):
    """Per-row live ABSOLUTE kv-tile tables for mod-window flash-decode.

    Returns (kv_index (B, max_live) int32, step_live (B, max_live) int32)
    in the same packed layout as :func:`decode_live_tables`, but the indices
    are absolute virtual tiles that may exceed any cache bound — decode under
    a sliding window is unbounded in position; only the most recent
    ``window`` keys are live, and those sit in the ``ring_tiles`` tiles
    trailing the frontier.  Feed through :func:`translate_tables` with the
    same ``ring_tiles`` to reach physical pages."""
    import jax.numpy as jnp

    max_live = min(ring_tiles, (window - 1) // kv_tile + 2)
    cl = jnp.asarray(cur_len, jnp.int32).reshape(-1, 1)  # (B, 1)
    ft = jnp.maximum(cl - 1, 0) // kv_tile  # frontier tile
    vt = ft - jnp.arange(max_live, dtype=jnp.int32)[None, :]
    live = (vt >= 0) & (vt * kv_tile < cl)
    live &= (vt + 1) * kv_tile - 1 > cl - 1 - window
    return vt, live.astype(jnp.int32)


def ring_chunk_tables(
    start, ntok, chunk: int, window: int, kv_tile: int, ring_tiles: int
):
    """Per-row live ABSOLUTE kv-tile tables for a mod-window mixed chunk.

    Row b's queries sit at ``start[b] .. start[b] + ntok[b] - 1``; its live
    tiles run from the first query's window edge to the last query's write
    frontier — at most ``window + chunk - 1`` tokens, which is exactly the
    span :func:`ring_tiles_for` sizes the ring to hold without collision.
    Same packed layout and :func:`translate_tables` contract as
    :func:`ring_decode_tables`."""
    import jax.numpy as jnp

    max_live = min(ring_tiles, (window + max(chunk, 1) - 2) // kv_tile + 2)
    start = jnp.asarray(start, jnp.int32).reshape(-1, 1)  # (B, 1)
    ntok = jnp.asarray(ntok, jnp.int32).reshape(-1, 1)
    fr = start + jnp.maximum(ntok, 1) - 1  # last query position per row
    ft = fr // kv_tile
    vt = ft - jnp.arange(max_live, dtype=jnp.int32)[None, :]
    live = (vt >= 0) & (vt * kv_tile <= fr)
    live &= (vt + 1) * kv_tile - 1 > start - window
    return vt, live.astype(jnp.int32)


def ring_slot_tiles(frontier, kv_tile: int, ring_tiles: int):
    """Which ABSOLUTE virtual tile each ring slot currently holds.

    ``frontier``: (B,) highest written position per row.  Slot ``s`` holds
    the largest tile ``j <= frontier_tile`` with ``j % ring_tiles == s``, or
    -1 when no such tile has been written yet.  This is the XLA gather
    forms' position base: slot s's r-th row is absolute position
    ``slot_tile * kv_tile + r`` (stale rows beyond the frontier inside the
    frontier slot carry the PREVIOUS lap's positions, but claiming the
    current lap is safe — those positions are ``> frontier`` and every
    caller masks ``kpos <= frontier``).  Returns (B, ring_tiles) int32."""
    import jax.numpy as jnp

    fr = jnp.asarray(frontier, jnp.int32).reshape(-1, 1)  # (B, 1)
    ft = jnp.maximum(fr, 0) // kv_tile
    s = jnp.arange(ring_tiles, dtype=jnp.int32)[None, :]
    vt = ft - (ft - s) % ring_tiles
    return jnp.where((vt >= 0) & (fr >= 0), vt, -1)


def page_last_reader(
    pattern: str,
    length: int,
    q_tile: int,
    kv_tile: int,
    *,
    window: int | None = None,
    pattern_arg: int | None = None,
) -> np.ndarray:
    """Last query position that can ever read each virtual kv tile of a
    request whose positions span ``0 .. length-1``.

    Returns (n_tiles,) int64: ``last_reader[j]`` is the sup over the static
    block map's live rows of the row's last query position — conservative
    over the traced decode/chunk tables by construction (they are built from
    the same per-q-tile liveness, only further restricted by written/window
    frontiers).  Once a request's next query position exceeds
    ``last_reader[j]``, page j is dead forever and its physical page can be
    freed: this is what makes a butterfly row's resident set shrink to the
    O(log n) tiles its future rows can touch, where dense-causal retains all
    of them."""
    bm = build_block_map(
        pattern, length, length, q_tile, kv_tile, causal=True, window=window,
        pattern_arg=pattern_arg,
    )
    nq, nk = bm.live.shape
    row_end = np.minimum((np.arange(nq) + 1) * q_tile - 1, length - 1)
    last = np.full(nk, -1, np.int64)
    for j in range(nk):
        readers = np.nonzero(bm.live[:, j])[0]
        if len(readers):
            last[j] = row_end[readers[-1]]
    # a written tile is always read at least by its own positions' rows (the
    # forced diagonal); a -1 here would free a page while it is still the
    # write frontier, so clamp to the tile's own last position
    own_end = np.minimum((np.arange(nk) + 1) * kv_tile - 1, length - 1)
    return np.maximum(last, own_end)


def page_last_reader_union(
    patterns,
    length: int,
    q_tile: int,
    kv_tile: int,
    *,
    pattern_arg: int | None = None,
) -> np.ndarray:
    """Elementwise-max :func:`page_last_reader` over a set of pattern names
    (``causal``/``window`` aliases canonicalised).  One page table serves
    every layer of a stack, so a request's retention is the union of its
    slots' patterns — the serve engine's admission reservation and the
    dry-run's capacity pricing both build on THIS schedule, from one
    definition.  A bare pattern name means a single-pattern stack."""
    if isinstance(patterns, str):
        patterns = (patterns,)
    nt = -(-length // kv_tile)
    last = np.zeros(nt, np.int64)
    for p in patterns:
        pat, arg, _, win = canonical_pattern(p, pattern_arg, True, None)
        last = np.maximum(
            last,
            page_last_reader(
                pat, length, q_tile, kv_tile, window=win, pattern_arg=arg
            ),
        )
    return last


def page_residency(
    last_reader: np.ndarray,
    length: int,
    kv_tile: int,
    step_span: int = 1,
    start_tile: int = 0,
    ring_tiles: int | None = None,
    n_shards: int = 1,
) -> np.ndarray:
    """Resident page count at every frontier position, given the per-tile
    last-reader schedule.  A tile is resident from its first write (position
    ``j * kv_tile``) until the next query position passes ``last_reader[j]``.
    The engine advances in steps of up to ``step_span`` query positions and
    only frees *after* a step, so each tile's interval widens by
    ``step_span - 1`` on the left.  This one curve is shared by the serve
    engine's admission reservation (its suffix max is the remaining-peak
    commitment that makes ``PagePool.alloc`` infallible) and by the
    dry-run/benchmark accounting — the invariant math has exactly one home.

    ``start_tile`` restricts the curve to tiles ``j >= start_tile``: the
    UNIQUE-SUFFIX residency of a request whose first ``start_tile * kv_tile``
    positions alias radix-cached prefix pages.  Aliased tiles cost the
    request no allocations (the cache's refcount carries them), and the
    divergence-frontier tile — start_tile itself when the match ends
    mid-page — IS counted, because a copy-on-write fork allocates a private
    page there.

    ``ring_tiles`` caps the curve at the mod-window reservation: a
    sliding-window request recycles a fixed ``ring_tiles``-slot page set in
    phase (see :func:`translate_tables`), so its residency can never exceed
    the ring, whatever the last-reader schedule says.

    ``n_shards > 1`` prices a MESH-SHARDED pool instead: the per-shard
    residency curve under a balanced allocator (the engine's
    :class:`repro.launch.serve.PagePool` places every allocation on the
    fullest-free shard, so no shard ever holds more than
    ``ceil(resident / n_shards)`` of the request's pages).  This is the
    analytic bound the dry-run's per-shard ``capacity_ratio`` and the
    ``--check-shard`` gate's per-shard peak assertion both price from."""
    diff = np.zeros(length + 1, np.int64)
    for j in range(start_tile, len(last_reader)):
        lo = max(j * kv_tile - (max(step_span, 1) - 1), 0)
        diff[lo] += 1
        diff[min(int(last_reader[j]), length - 1) + 1] -= 1
    res = np.cumsum(diff)[:length]
    if ring_tiles is not None:
        res = np.minimum(res, ring_tiles)
    if n_shards > 1:
        res = -(-res // n_shards)
    return res


def page_peak_resident(
    pattern: str,
    length: int,
    q_tile: int,
    kv_tile: int,
    *,
    window: int | None = None,
    pattern_arg: int | None = None,
    step_span: int = 1,
    start_tile: int = 0,
) -> int:
    """Worst-case simultaneously-resident page count over a request's whole
    lifetime (the max of :func:`page_residency` over the
    :func:`page_last_reader` schedule) — the sound admission reservation for
    the paged serve engine, and the per-request page price the dry-run's
    ``kv_cache`` record reports.  With ``start_tile > 0`` this is the
    unique-suffix reservation of a prefix-cache hit: only the pages the
    request itself allocates (beyond the shared, refcounted prefix)."""
    last = page_last_reader(
        pattern, length, q_tile, kv_tile, window=window, pattern_arg=pattern_arg
    )
    res = page_residency(last, length, kv_tile, step_span, start_tile)
    return int(res.max()) if length else 0


def page_resume_peak(
    patterns,
    length: int,
    q_tile: int,
    kv_tile: int,
    *,
    frontier: int,
    step_span: int = 1,
    pattern_arg: int | None = None,
) -> int:
    """Residency-from-frontier: the worst-case resident page count of a
    request RESUMED at query position ``frontier`` — the admission
    reservation the serve engine makes when a preempted request re-enters
    through the restartable chunked-prefill path (or when a prefix-cache
    hit starts prefill at its divergence frontier; the two are the same
    computation, which is why resume rides the prefix-hit machinery).

    The request's written positions still span ``0..length-1``; tiles below
    ``frontier``'s tile are carried by the radix cache's references (or
    recomputed cold), so the resumed request itself only ever allocates
    from tile ``frontier // kv_tile`` up — the max of the
    :func:`page_residency` curve over positions ``>= frontier`` with
    ``start_tile`` at the frontier's tile.  ``patterns`` is the stack's
    attention-pattern set, as for :func:`page_last_reader_union`."""
    if length <= 0:
        return 0
    if not 0 <= frontier < length:
        raise ValueError(
            f"resume frontier {frontier} outside written span 0..{length - 1}"
        )
    last = page_last_reader_union(
        patterns, length, q_tile, kv_tile, pattern_arg=pattern_arg
    )
    res = page_residency(
        last, length, kv_tile, step_span, start_tile=frontier // kv_tile
    )
    return int(res[frontier:].max())


def chunk_token_mask(
    pattern: str,
    qpos,  # (B, C) traced absolute query positions
    cache_len: int,
    q_tile: int,
    kv_tile: int,
    *,
    window: int | None = None,
    pattern_arg: int | None = None,
):
    """Token-level pattern mask (B, C, cache_len) bool (jnp) for a mixed
    chunk: each query's own q-tile row of the pattern map, expanded to tokens
    (the XLA mixed form's view; the caller ANDs the causal frontier and fine
    window).  Per-query semantics are identical to the static prefill map and
    to the fine in-kernel mask of the chunk kernel — NOT the chunk-table
    union, which is block-superset only."""
    import jax.numpy as jnp

    nk = -(-cache_len // kv_tile)
    b, c = qpos.shape
    qi = jnp.asarray(qpos, jnp.int32).reshape(-1, 1)  # (B*C, 1)
    qi = qi // q_tile
    j = jnp.arange(nk, dtype=jnp.int32)[None, :]
    live = _decode_live_jnp(pattern, qi, j, nk, q_tile, kv_tile, window, pattern_arg)
    mask = jnp.repeat(live, kv_tile, axis=1)[:, :cache_len]
    return mask.reshape(b, c, cache_len)
