"""Static block-sparsity maps for the attention score matrix (paper §III).

The paper's hybrid butterfly-sparsity network prunes the attention map itself:
whole (q_tile x kv_tile) score blocks are statically dead and never computed.
This module is the single source of truth for *which* blocks live — the Pallas
kernels iterate only the live set (real compute/HBM skipping via the grid's
kv-tile index map), the XLA forms mask with the same map, the analytic
FLOP/HBM accounting scales by its density, and the test oracle expands it to a
token-level mask.  One map, four consumers — parity is by construction.

Patterns (``AttentionSpec.pattern``; block (i, j) indexes q-tile x kv-tile):

* ``dense``          every block live (causal/window feasibility still prunes)
* ``causal``         alias of dense with causal forced on
* ``window``         alias of dense with a sliding window (``pattern_arg`` =
                     window in tokens when the call site gives none)
* ``butterfly``      radix-2 butterfly over kv tiles: j live for q-tile i iff
                     ``i ^ j`` has at most one bit set — i and j differ in at
                     most one bit, the union of all log2(n) butterfly stages'
                     stride pairs (Pixelated-Butterfly-style, O(N log N) blocks)
* ``strided``        local diagonal + every ``pattern_arg``-th earlier tile
                     (Sparse-Transformer dilated form; default stride
                     ~sqrt(n_kv_tiles))
* ``global_window``  first ``pattern_arg`` kv tiles are global (every query
                     attends them, their queries attend everything) + a local
                     diagonal band (Longformer-style)

Block liveness composes with causal/window *feasibility* (blocks entirely
above the diagonal or outside the window are dead regardless of pattern) and
with the fine in-tile mask (causal diagonal, window edge, padded keys) that
keeps partially-live tiles exact.  Patterns are *block-granular* by
definition: the token-level reference mask is the block map expanded to
tokens, AND the fine constraints — the kernels and the oracle agree exactly.
"""

from __future__ import annotations

import dataclasses
import math

import numpy as np

__all__ = [
    "PATTERNS",
    "BlockMap",
    "canonical_pattern",
    "build_block_map",
    "token_mask",
    "pick_pattern_tiles",
    "pattern_kv_density",
    "decode_max_live",
    "decode_live_tables",
    "decode_token_mask",
]

PATTERNS = ("dense", "causal", "window", "butterfly", "strided", "global_window")

_LANES = 128  # kv tiles align to the TPU lane width (mirrors flash_attention)


def canonical_pattern(
    pattern: str, pattern_arg: int | None, causal: bool, window: int | None
) -> tuple[str, int | None, bool, int | None]:
    """Fold the ``causal`` / ``window`` pattern *aliases* into the explicit
    flags every execution path already carries, so kernels and masks only ever
    see the structural patterns (dense / butterfly / strided / global_window)."""
    if pattern == "causal":
        return "dense", None, True, window
    if pattern == "window":
        win = window if window is not None else pattern_arg
        if win is None:
            raise ValueError("pattern 'window' needs pattern_arg (window tokens)")
        return "dense", None, causal, win
    return pattern, pattern_arg, causal, window


def pick_pattern_tiles(s_q: int, s_kv: int, q_tile: int, kv_tile: int) -> tuple[int, int]:
    """The *effective* tile grid a problem runs on (kernel clamp mirrored).

    Every consumer of a block map — fused kernel, XLA mask, accounting, test
    oracle — must build it on the same grid, so the clamp lives here and
    :func:`repro.kernels.flash_attention.pick_tiles` delegates to it."""
    tq = min(q_tile, -(-s_q // 8) * 8)
    tk = min(kv_tile, -(-s_kv // _LANES) * _LANES)
    return max(tq, 8), max(tk, _LANES)


@dataclasses.dataclass(frozen=True)
class BlockMap:
    """Static liveness of the (q_tile x kv_tile) score blocks + the packed
    per-q-row kv-tile index map the sparse kernel grid iterates.

    ``kv_index[i, jj]`` is the jj-th live kv-tile of q-tile row i; rows with
    fewer than ``max_live`` live tiles pad with tile 0 and ``step_live`` 0 —
    padded steps are skipped inside the kernel (no MXU work) and revisit an
    already-resident block (no fresh HBM traffic)."""

    pattern: str
    s_q: int
    s_kv: int
    q_tile: int
    kv_tile: int
    causal: bool
    window: int | None
    live: np.ndarray  # (n_q_tiles, n_kv_tiles) bool
    kv_index: np.ndarray  # (n_q_tiles, max_live) int32
    step_live: np.ndarray  # (n_q_tiles, max_live) int32 (0 | 1)

    @property
    def n_q_tiles(self) -> int:
        return self.live.shape[0]

    @property
    def n_kv_tiles(self) -> int:
        return self.live.shape[1]

    @property
    def max_live(self) -> int:
        return self.kv_index.shape[1]

    @property
    def grid_steps(self) -> int:
        """kv-axis grid iterations per (batch x head x q-tile-row) sweep."""
        return self.n_q_tiles * self.max_live

    @property
    def dense_grid_steps(self) -> int:
        return self.n_q_tiles * self.n_kv_tiles

    @property
    def kv_density(self) -> float:
        """Mean live-block fraction per q row — the analytic density factor."""
        return float(self.live.sum()) / max(self.live.size, 1)


def _span(i: int, q_tile: int, kv_tile: int, n_kv: int) -> tuple[int, int]:
    """kv-tile indices overlapped by q-tile i (inclusive lo, hi)."""
    lo = (i * q_tile) // kv_tile
    hi = ((i + 1) * q_tile - 1) // kv_tile
    return min(lo, n_kv - 1), min(hi, n_kv - 1)


def _pattern_live(
    pattern: str, nq: int, nk: int, q_tile: int, kv_tile: int,
    causal: bool, pattern_arg: int | None,
) -> np.ndarray:
    live = np.zeros((nq, nk), bool)
    if pattern in ("dense", "causal", "window"):
        live[:] = True
        return live
    if pattern == "butterfly":
        # i and j differ in at most one bit over the kv-tile index space
        j = np.arange(nk)
        for i in range(nq):
            lo, hi = _span(i, q_tile, kv_tile, nk)
            for ii in range(lo, hi + 1):
                x = ii ^ j
                live[i] |= (x & (x - 1)) == 0  # x == 0 or power of two
        return live
    if pattern == "strided":
        stride = pattern_arg or max(2, int(math.isqrt(max(nk, 1))))
        j = np.arange(nk)
        for i in range(nq):
            lo, hi = _span(i, q_tile, kv_tile, nk)
            for ii in range(lo, hi + 1):
                live[i] |= j == ii
                if causal:
                    live[i] |= (j < ii) & ((ii - j) % stride == 0)
                else:
                    live[i] |= np.abs(ii - j) % stride == 0
        return live
    if pattern == "global_window":
        g = pattern_arg or 1
        band = 1  # local diagonal band in tiles (window arg adds more)
        j = np.arange(nk)
        for i in range(nq):
            lo, hi = _span(i, q_tile, kv_tile, nk)
            live[i] |= j < g  # global kv tiles: everyone attends them
            if lo < g:  # global q rows attend everything
                live[i] = True
            live[i] |= (j >= lo - band) & (j <= hi + band)
        return live
    raise ValueError(f"unknown sparsity pattern {pattern!r}; known: {PATTERNS}")


def build_block_map(
    pattern: str,
    s_q: int,
    s_kv: int,
    q_tile: int,
    kv_tile: int,
    *,
    causal: bool = True,
    window: int | None = None,
    pattern_arg: int | None = None,
) -> BlockMap:
    """Build the static per-q-tile live kv-tile map on the given tile grid.

    ``q_tile`` / ``kv_tile`` must be the *effective* tiles of the executing
    path (:func:`pick_pattern_tiles`).  The named ``causal`` / ``window``
    aliases fold into the feasibility pruning; explicit ``causal`` / ``window``
    args compose with every pattern."""
    if pattern not in PATTERNS:
        raise ValueError(f"unknown sparsity pattern {pattern!r}; known: {PATTERNS}")
    if pattern == "causal":
        causal = True
    if pattern == "window":
        window = window or pattern_arg
        if window is None:
            raise ValueError("pattern 'window' needs pattern_arg (window tokens)")
    nq = -(-s_q // q_tile)
    nk = -(-s_kv // kv_tile)
    live = _pattern_live(pattern, nq, nk, q_tile, kv_tile, causal, pattern_arg)

    i = np.arange(nq)[:, None]
    j = np.arange(nk)[None, :]
    live &= j * kv_tile < s_kv  # blocks entirely in key padding
    if causal:
        live &= j * kv_tile <= i * q_tile + q_tile - 1
    if window is not None:
        live &= j * kv_tile + kv_tile - 1 > i * q_tile - window
    # every q row keeps >= 1 live block (an all-dead softmax row is NaN); the
    # clamped diagonal block is always feasible under causal + window
    for r in range(nq):
        if not live[r].any():
            lo, _ = _span(r, q_tile, kv_tile, nk)
            live[r, lo] = True

    max_live = max(int(live.sum(axis=1).max()), 1)
    kv_index = np.zeros((nq, max_live), np.int32)
    step_live = np.zeros((nq, max_live), np.int32)
    for r in range(nq):
        idx = np.nonzero(live[r])[0]
        kv_index[r, : len(idx)] = idx
        step_live[r, : len(idx)] = 1
    return BlockMap(
        pattern=pattern, s_q=s_q, s_kv=s_kv, q_tile=q_tile, kv_tile=kv_tile,
        causal=causal, window=window, live=live, kv_index=kv_index,
        step_live=step_live,
    )


def token_mask(bm: BlockMap) -> np.ndarray:
    """Expand the block map to the exact token-level mask (s_q, s_kv): live
    block AND fine causal/window constraints.  This is the oracle's mask and
    the definition of pattern correctness for every execution form."""
    m = np.repeat(np.repeat(bm.live, bm.q_tile, axis=0), bm.kv_tile, axis=1)
    m = m[: bm.s_q, : bm.s_kv]
    qpos = np.arange(bm.s_q)[:, None]
    kpos = np.arange(bm.s_kv)[None, :]
    if bm.causal:
        m = m & (qpos >= kpos)
    if bm.window is not None:
        m = m & (kpos > qpos - bm.window)
    return m


def pattern_kv_density(
    pattern: str,
    s_q: int,
    s_kv: int,
    q_tile: int,
    kv_tile: int,
    *,
    causal: bool = True,
    window: int | None = None,
    pattern_arg: int | None = None,
) -> float:
    """Fraction of the (s_q x s_kv) score area that is live under the pattern
    — block-granular, i.e. exactly the compute/HBM the sparse kernel performs
    (partially-live boundary tiles count whole, as executed)."""
    tq, tk = pick_pattern_tiles(s_q, s_kv, q_tile, kv_tile)
    bm = build_block_map(
        pattern, s_q, s_kv, tq, tk, causal=causal, window=window,
        pattern_arg=pattern_arg,
    )
    return bm.kv_density


# --------------------------------------------------------------------------
# Decode: per-row live kv-tile tables over the cache (traced positions)
# --------------------------------------------------------------------------


def decode_max_live(
    pattern: str,
    cache_len: int,
    q_tile: int,
    kv_tile: int,
    *,
    window: int | None = None,
    pattern_arg: int | None = None,
) -> int:
    """Static worst-case live kv-tile count for a single decode row — the
    sparse decode grid's kv extent.  Exact: the max row population of the full
    prefill-shaped map at the cache length (the decoding token's q-tile row is
    one of those rows)."""
    bm = build_block_map(
        pattern, cache_len, cache_len, q_tile, kv_tile, causal=True,
        window=window, pattern_arg=pattern_arg,
    )
    return int(bm.live.sum(axis=1).max())


def _decode_live_jnp(pattern, qi, j, nk, q_tile, kv_tile, window, pattern_arg):
    """Per-row block liveness (jnp): qi (B, 1) q-tile index, j (1, nk)."""
    import jax.numpy as jnp

    # q-tile span in kv-tile space (q_tile may differ from kv_tile)
    lo = (qi * q_tile) // kv_tile
    hi = ((qi + 1) * q_tile - 1) // kv_tile
    hi = jnp.minimum(hi, nk - 1)
    lo = jnp.minimum(lo, nk - 1)
    if pattern in ("dense", "causal", "window"):
        live = jnp.ones_like(j | qi, bool)
    elif pattern == "butterfly":
        live = jnp.zeros_like(j | qi, bool)
        # static bound on the q-tile's kv-tile span; per-row gate keeps it
        # identical to the static builder's inclusive [lo, hi] range
        for off in range((q_tile - 1) // kv_tile + 2):
            ii = jnp.minimum(lo + off, nk - 1)
            x = ii ^ j
            live |= ((x & (x - 1)) == 0) & (lo + off <= hi)
    elif pattern == "strided":
        stride = pattern_arg or max(2, int(math.isqrt(max(nk, 1))))
        live = jnp.zeros_like(j | qi, bool)
        for off in range((q_tile - 1) // kv_tile + 2):
            ii = jnp.minimum(lo + off, nk - 1)
            live |= ((j == ii) | ((j < ii) & ((ii - j) % stride == 0))) & (
                lo + off <= hi
            )
    elif pattern == "global_window":
        g = pattern_arg or 1
        live = (j < g) | (lo < g) | ((j >= lo - 1) & (j <= hi + 1))
    else:
        raise ValueError(f"unknown sparsity pattern {pattern!r}; known: {PATTERNS}")
    return live


def decode_live_tables(
    pattern: str,
    cur_len,  # (B,) traced live lengths (pos + 1)
    cache_len: int,
    q_tile: int,
    kv_tile: int,
    *,
    window: int | None = None,
    pattern_arg: int | None = None,
    max_live: int | None = None,
):
    """Per-row packed live kv-tile tables for sparse flash-decode.

    Returns (kv_index (B, max_live) int32, step_live (B, max_live) int32).
    Row b's decoding token sits in q-tile ``(cur_len[b]-1) // q_tile``; its
    live kv tiles are the pattern row restricted to written cache tiles
    (``j * kv_tile < cur_len[b]``) — dead tiles are *absent from the table*,
    so the kernel grid never visits them."""
    import jax.numpy as jnp

    nk = -(-cache_len // kv_tile)
    if max_live is None:
        max_live = decode_max_live(
            pattern, cache_len, q_tile, kv_tile, window=window,
            pattern_arg=pattern_arg,
        )
    max_live = min(max_live, nk)
    cl = jnp.asarray(cur_len, jnp.int32).reshape(-1, 1)  # (B, 1)
    qi = jnp.maximum(cl - 1, 0) // q_tile
    j = jnp.arange(nk, dtype=jnp.int32)[None, :]  # (1, nk)
    live = _decode_live_jnp(pattern, qi, j, nk, q_tile, kv_tile, window, pattern_arg)
    live &= j * kv_tile < cl  # only written cache tiles
    if window is not None:
        live &= (j + 1) * kv_tile - 1 > cl - 1 - window
    live |= j == jnp.minimum(qi * q_tile // kv_tile, nk - 1)  # diag always live
    # pack live indices first (stable in j), pad with tile 0 / live 0
    order = jnp.argsort(jnp.where(live, j, nk + j), axis=1)[:, :max_live]
    packed_live = jnp.take_along_axis(live, order, axis=1)
    kv_index = jnp.where(packed_live, order, 0).astype(jnp.int32)
    return kv_index, packed_live.astype(jnp.int32)


def decode_token_mask(
    pattern: str,
    cur_len,
    cache_len: int,
    q_tile: int,
    kv_tile: int,
    *,
    window: int | None = None,
    pattern_arg: int | None = None,
):
    """Token-level decode mask (B, cache_len) bool (jnp) — the XLA decode
    form's view of the same per-row live tile set (parity with the sparse
    kernel by construction; the caller still ANDs its ``cur_len`` mask)."""
    import jax.numpy as jnp

    nk = -(-cache_len // kv_tile)
    kv_index, step_live = decode_live_tables(
        pattern, cur_len, cache_len, q_tile, kv_tile, window=window,
        pattern_arg=pattern_arg, max_live=nk,
    )
    tile_live = jnp.zeros((kv_index.shape[0], nk), bool)
    tile_live = tile_live.at[
        jnp.arange(kv_index.shape[0])[:, None], kv_index
    ].max(step_live > 0)
    mask = jnp.repeat(tile_live, kv_tile, axis=1)[:, :cache_len]
    return mask
