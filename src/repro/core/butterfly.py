"""Radix-2 butterfly factor matrices — the paper-faithful BPMM substrate.

A length-``N = 2**m`` butterfly product is ``W = B_m @ ... @ B_1`` where stage
``k`` pairs elements at stride ``s = 2**(k-1)`` inside contiguous blocks of
size ``2**k`` (paper Fig. 4).  Each stage holds exactly ``2N`` nonzeros, so the
full product has ``2 N log2 N`` parameters vs ``N**2`` dense — the 2/N-sparse
factors of paper §II-B.

This module is the *faithful* form: ``apply_butterfly`` executes the stages one
by one, exactly the way a block-oriented backend (GPU / plain XLA) runs them —
one strided reshape + elementwise multiply-add per stage, i.e. one HBM
round-trip per stage.  That is the memory-bound behaviour the paper profiles in
Fig. 2 and is the §Perf baseline.  The orchestrated (multilayer-dataflow) form
lives in :mod:`repro.core.monarch` and :mod:`repro.kernels.monarch_bpmm`.

Weight layout per stage ``k`` (1-based):  ``w_k`` has shape
``(N / 2**k, 2, 2, 2**(k-1))`` = (blocks, out-arm, in-arm, twiddle-index).
For block ``j`` and offset ``t < s``::

    y[j*2s + t]     = w[j,0,0,t] * x[j*2s + t] + w[j,0,1,t] * x[j*2s + s + t]
    y[j*2s + s + t] = w[j,1,0,t] * x[j*2s + t] + w[j,1,1,t] * x[j*2s + s + t]
"""

from __future__ import annotations

import math
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "num_stages",
    "stage_shapes",
    "init_butterfly",
    "fft_butterfly_factors",
    "bit_reversal_permutation",
    "apply_stage",
    "apply_butterfly",
    "butterfly_to_dense",
    "butterfly_param_count",
]


def num_stages(n: int) -> int:
    if n < 2 or n & (n - 1):
        raise ValueError(f"butterfly size must be a power of two >= 2, got {n}")
    return n.bit_length() - 1


def stage_shapes(n: int) -> list[tuple[int, int, int, int]]:
    """Weight shapes [(blocks, 2, 2, stride)] for stages k = 1..log2(n)."""
    return [(n >> k, 2, 2, 1 << (k - 1)) for k in range(1, num_stages(n) + 1)]


def butterfly_param_count(n: int) -> int:
    return 2 * n * num_stages(n)


def init_butterfly(key: jax.Array, n: int, dtype=jnp.float32) -> list[jax.Array]:
    """Random init of a radix-2 butterfly stack.

    Each 2x2 arm block is initialised so the stage is approximately
    norm-preserving: entries ~ N(0, 1/2) per arm (fan-in of 2 per output).
    """
    keys = jax.random.split(key, num_stages(n))
    factors = []
    for k, shape in zip(keys, stage_shapes(n)):
        factors.append(jax.random.normal(k, shape, dtype) * math.sqrt(0.5))
    return factors


def bit_reversal_permutation(n: int) -> np.ndarray:
    """Index permutation ``P_N`` of Eq. (4): decimation-in-time input order."""
    m = num_stages(n)
    idx = np.arange(n)
    rev = np.zeros(n, dtype=np.int64)
    for b in range(m):
        rev |= ((idx >> b) & 1) << (m - 1 - b)
    return rev


def fft_butterfly_factors(n: int) -> list[jax.Array]:
    """Complex radix-2 DIT factors: ``DFT_N = B_m ... B_1 P_bitrev`` (Eq. 4).

    Stage k combines arms with twiddle ``w = exp(-2πi t / 2**k)``::

        top = x_top + w * x_bot ;  bot = x_top - w * x_bot
    """
    factors = []
    for blocks, _, _, s in stage_shapes(n):
        t = np.arange(s)
        w = np.exp(-2j * np.pi * t / (2 * s)).astype(np.complex64)
        ones = np.ones_like(w)
        stage = np.stack(
            [np.stack([ones, w], 0), np.stack([ones, -w], 0)], 0
        )  # (2, 2, s)
        factors.append(jnp.asarray(np.broadcast_to(stage, (blocks, 2, 2, s)).copy()))
    return factors


def apply_stage(w: jax.Array, x: jax.Array) -> jax.Array:
    """Apply one butterfly stage along the last axis of ``x``."""
    blocks, _, _, s = w.shape
    n = x.shape[-1]
    if n != blocks * 2 * s:
        raise ValueError(f"stage of size {blocks * 2 * s} applied to dim {n}")
    xr = x.reshape(*x.shape[:-1], blocks, 2, s)
    x0, x1 = xr[..., 0, :], xr[..., 1, :]
    y0 = w[:, 0, 0] * x0 + w[:, 0, 1] * x1
    y1 = w[:, 1, 0] * x0 + w[:, 1, 1] * x1
    return jnp.stack([y0, y1], axis=-2).reshape(x.shape)


def apply_butterfly(factors: Sequence[jax.Array], x: jax.Array) -> jax.Array:
    """Faithful staged execution: ``B_m(...B_1(x))`` — log N passes over x."""
    for w in factors:
        x = apply_stage(w, x)
    return x


def _stage_dense(w: np.ndarray) -> np.ndarray:
    """Materialise one stage as a dense (n, n) matrix (tests / conversion)."""
    blocks, _, _, s = w.shape
    n = blocks * 2 * s
    out = np.zeros((n, n), dtype=np.asarray(w).dtype)
    for j in range(blocks):
        base = j * 2 * s
        for t in range(s):
            out[base + t, base + t] = w[j, 0, 0, t]
            out[base + t, base + s + t] = w[j, 0, 1, t]
            out[base + s + t, base + t] = w[j, 1, 0, t]
            out[base + s + t, base + s + t] = w[j, 1, 1, t]
    return out


def butterfly_to_dense(factors: Sequence[jax.Array]) -> np.ndarray:
    """Dense ``B_m @ ... @ B_1`` (row-vector convention: y = W @ x)."""
    mats = [_stage_dense(np.asarray(w)) for w in factors]
    out = mats[0]
    for m in mats[1:]:
        out = m @ out
    return out
