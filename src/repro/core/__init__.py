# The paper's primary contribution: butterfly sparsity (BPMM + FFT attention)
# orchestrated as a multilayer dataflow — faithful radix-2 form, grouped
# (Monarch) TPU-native form, Cooley-Tukey multi-stage division, Fig.10 slicing.
from repro.core.api import (  # noqa: F401
    ButterflyPolicy,
    LinearSpec,
    apply_linear,
    init_linear,
    linear_flops,
    linear_param_count,
)
from repro.core.attention import (  # noqa: F401
    AttentionSpec,
    attention_flops,
    attention_hbm_bytes,
)
from repro.core.fft_mixing import fnet_mixing, fnet_mixing_reference  # noqa: F401
