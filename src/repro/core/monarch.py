"""Radix-grouped butterfly — the multilayer-dataflow form, TPU-native.

The paper keeps all ``log N`` butterfly stages resident in the PE array so the
intermediate vector never returns to DDR (§IV).  On TPU the equivalent
orchestration is to *group* the stages into two block-diagonal super-stages
that execute back-to-back on one VMEM-resident tile:

    index i = hi * b + lo,   b = 2**p,  nb = N / b

    stages 1..p      (strides < b)  mix `lo` within each `hi` block
                      -> R: (nb, b, b)   block-diagonal over hi
    stages p+1..m    (strides >= b) mix `hi` for each fixed `lo`
                      -> L: (b, nb, nb)  block-diagonal over lo

    y[hi, lo] = sum_hi' L[lo, hi, hi'] * ( sum_lo' R[hi', lo, lo'] * x[hi', lo'] )

This is exactly the Monarch factorisation (Dao et al. 2022 — the paper's ref
[7]); Monarch ⊇ butterfly products, so grouping is lossless
(:func:`group_butterfly_factors` converts any radix-2 stack exactly).  Each
super-stage is a batch of dense ``b x b`` / ``nb x nb`` matmuls — MXU work —
and the paper's intra-array element swaps become free intra-block systolic
movement.  Strides wider than the group (paper: wider than the PE array, which
wrap back into the same PE) become the single axis flip between the two
einsums, with no materialised transpose (the multi-line-SPM analogue).

Learnable BPMM layers parameterise (R, L) directly: ``N*(b + N/b)`` params,
minimised at ``b = sqrt(N)`` — same O(N^1.5) family as two-stage division;
the faithful ``2 N log N`` radix-2 stack remains available for parity runs.
"""

from __future__ import annotations

import math
from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import butterfly as bfly

__all__ = [
    "MonarchParams",
    "split_point",
    "init_monarch",
    "monarch_apply",
    "group_butterfly_factors",
    "monarch_to_dense",
    "monarch_param_count",
    "monarch_flops",
]


class MonarchParams(NamedTuple):
    """R: (nb, b, b) block-diag over hi; L: (b, nb, nb) block-diag over lo."""

    r: jax.Array
    l: jax.Array

    @property
    def n(self) -> int:
        return self.r.shape[0] * self.r.shape[1]

    @property
    def b(self) -> int:
        return self.r.shape[1]


def split_point(n: int, max_block: int = 512) -> int:
    """Balanced split p for N = 2**m: b = 2**p ~= sqrt(N), capped by the VMEM
    super-stage budget (paper's single-DFG limit)."""
    m = bfly.num_stages(n)
    p = (m + 1) // 2
    while (1 << p) > max_block:
        p -= 1
    while n // (1 << p) > max_block:
        p += 1
    if (1 << p) > max_block:
        raise ValueError(f"n={n} cannot be grouped into blocks <= {max_block}")
    return p


def monarch_param_count(n: int, b: int) -> int:
    nb = n // b
    return nb * b * b + b * nb * nb


def monarch_flops(n: int, b: int, batch: int = 1) -> int:
    """Multiply-add FLOPs (x2) of the two grouped stages per vector."""
    nb = n // b
    return batch * 2 * (nb * b * b + b * nb * nb)


def init_monarch(key: jax.Array, n: int, b: int | None = None, dtype=jnp.float32) -> MonarchParams:
    if b is None:
        b = 1 << split_point(n)
    nb = n // b
    if nb * b != n:
        raise ValueError(f"block size {b} must divide n={n}")
    kr, kl = jax.random.split(key)
    # variance-preserving: each stage contracts over b (resp. nb) inputs
    r = jax.random.normal(kr, (nb, b, b), dtype) / math.sqrt(b)
    l = jax.random.normal(kl, (b, nb, nb), dtype) / math.sqrt(nb)
    return MonarchParams(r, l)


def monarch_apply(params: MonarchParams, x: jax.Array) -> jax.Array:
    """Grouped two-super-stage apply (pure-jnp; kernel version in
    repro.kernels.monarch_bpmm).  x: (..., N)."""
    nb, b, _ = params.r.shape
    xr = x.reshape(*x.shape[:-1], nb, b)
    # super-stage R: mix lo within each hi block
    u = jnp.einsum("hij,...hj->...hi", params.r, xr)
    # super-stage L: mix hi for each lo (axis flip fused into the einsum —
    # the transpose-free multi-line-SPM analogue)
    y = jnp.einsum("jhk,...kj->...hj", params.l, u)
    return y.reshape(x.shape)


# --------------------------------------------------------------------------
# Exact conversion: radix-2 stack -> grouped (R, L).  Used to port faithful
# BPMM weights onto the fused kernel and in equivalence tests.
# --------------------------------------------------------------------------


def _butterfly_block(w: np.ndarray, j: int, size: int) -> np.ndarray:
    """Dense (size, size) butterfly block for global block index j of a stage
    with stride s = size // 2."""
    s = size // 2
    out = np.zeros((size, size), dtype=np.asarray(w).dtype)
    for t in range(s):
        out[t, t] = w[j, 0, 0, t]
        out[t, s + t] = w[j, 0, 1, t]
        out[s + t, t] = w[j, 1, 0, t]
        out[s + t, s + t] = w[j, 1, 1, t]
    return out


def group_butterfly_factors(
    factors: Sequence[jax.Array], p: int | None = None
) -> MonarchParams:
    """Exactly regroup radix-2 stages 1..p into R and p+1..m into L."""
    factors = [np.asarray(f) for f in factors]
    n = factors[0].shape[0] * 2 * factors[0].shape[3]
    m = bfly.num_stages(n)
    if p is None:
        p = split_point(n)
    b, nb = 1 << p, n >> p
    dtype = factors[0].dtype

    # R[hi] = S_p[hi] @ ... @ S_1[hi] : stages k<=p restricted to hi block
    r = np.broadcast_to(np.eye(b, dtype=dtype), (nb, b, b)).copy()
    for k in range(1, p + 1):
        w = factors[k - 1]
        size = 1 << k
        per = b // size  # sub-blocks of this stage inside one hi block
        for hi in range(nb):
            s_mat = np.zeros((b, b), dtype=dtype)
            for jj in range(per):
                blk = _butterfly_block(w, hi * per + jj, size)
                s_mat[jj * size : (jj + 1) * size, jj * size : (jj + 1) * size] = blk
            r[hi] = s_mat @ r[hi]

    # L[lo] = S'_m[lo] @ ... @ S'_{p+1}[lo] : stages k>p act in hi-space with
    # weights indexed by t = t_hi * b + lo
    l = np.broadcast_to(np.eye(nb, dtype=dtype), (b, nb, nb)).copy()
    for k in range(p + 1, m + 1):
        w = factors[k - 1]
        size_hi = 1 << (k - p)  # block size in hi-space
        s_hi = size_hi // 2
        blocks_hi = nb // size_hi
        for lo in range(b):
            s_mat = np.zeros((nb, nb), dtype=dtype)
            for j in range(blocks_hi):
                base = j * size_hi
                for t_hi in range(s_hi):
                    t = t_hi * b + lo
                    s_mat[base + t_hi, base + t_hi] = w[j, 0, 0, t]
                    s_mat[base + t_hi, base + s_hi + t_hi] = w[j, 0, 1, t]
                    s_mat[base + s_hi + t_hi, base + t_hi] = w[j, 1, 0, t]
                    s_mat[base + s_hi + t_hi, base + s_hi + t_hi] = w[j, 1, 1, t]
            l[lo] = s_mat @ l[lo]

    return MonarchParams(jnp.asarray(r), jnp.asarray(l))


def monarch_to_dense(params: MonarchParams) -> np.ndarray:
    """Dense (N, N) materialisation, y = W @ x convention (tests only)."""
    nb, b, _ = params.r.shape
    n = nb * b
    w = np.zeros((n, n), dtype=np.asarray(params.r).dtype)
    r, l = np.asarray(params.r), np.asarray(params.l)
    # y[hi, lo] = sum_{hi'} L[lo, hi, hi'] sum_{lo'} R[hi', lo, lo'] x[hi', lo']
    for hi in range(nb):
        for lo in range(b):
            row = np.zeros((nb, b), dtype=w.dtype)
            for hip in range(nb):
                row[hip, :] += l[lo, hi, hip] * r[hip, lo, :]
            w[hi * b + lo, :] = row.reshape(-1)
    return w
