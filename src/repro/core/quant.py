"""Quantized paged-KV helpers: per-row symmetric quantization for page pools.

The paged cache stores K/V pages at a reduced ``kv_dtype`` (int8 or fp8_e4m3)
next to a small float32 scale tensor with one entry per (row, kv_head) —
``scale[r, h]`` reconstructs row ``r`` of head ``h`` as ``q * scale``.  The
granularity is deliberate: decode appends ONE row per step into a partially
filled page, so a true per-page scale would have to requantize every
previously written row on each append (either an extra gather/rescale/scatter
per decode step or compounding rounding error across up to ``page``
requantizations).  Per-row scales make every write independent, and because
the scale rows live in the same ``n_pages * page`` flat layout as the KV rows
they ride the page tables for free — copy-on-write page copies, radix prefix
aliasing, mod-window rings, and the sharded pool's ownership ``transfer()``
all carry scales without knowing they exist.

Schemes (both symmetric, zero-point-free — attention rows are centred):

* ``int8``:     ``scale = absmax / 127``, values rounded and clipped.
* ``fp8_e4m3``: ``scale = absmax / 448`` (the e4m3 finite max), scaled cast —
  the mantissa keeps ~3 bits, the shared exponent headroom comes from the
  scale.  Gated on the running jax exposing ``jnp.float8_e4m3fn``.
* ``bf16``:     the unquantized passthrough — no scale leaves exist and every
  code path compiles the exact PR-9 graph (bit-identity is a test contract).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = [
    "KV_DTYPES",
    "INT8_MAX",
    "FP8_MAX",
    "fp8_supported",
    "validate_kv_dtype",
    "kv_store_dtype",
    "quantize_rows",
    "dequantize_rows",
]

KV_DTYPES = ("bf16", "int8", "fp8_e4m3")
INT8_MAX = 127.0
FP8_MAX = 448.0  # largest finite float8_e4m3fn


def fp8_supported() -> bool:
    return hasattr(jnp, "float8_e4m3fn")


def validate_kv_dtype(kv_dtype: str) -> str:
    if kv_dtype not in KV_DTYPES:
        raise ValueError(
            f"kv_dtype must be one of {KV_DTYPES}, got {kv_dtype!r}"
        )
    if kv_dtype == "fp8_e4m3" and not fp8_supported():
        raise ValueError(
            "kv_dtype='fp8_e4m3' needs jnp.float8_e4m3fn, which this jax "
            "build does not expose — use 'int8' (same byte width) or 'bf16'"
        )
    return kv_dtype


def kv_store_dtype(kv_dtype: str, base_dtype) -> jnp.dtype:
    """The dtype pool pages are STORED at for ``kv_dtype`` (``base_dtype`` is
    the model's compute/cache dtype, returned unchanged for 'bf16')."""
    validate_kv_dtype(kv_dtype)
    if kv_dtype == "int8":
        return jnp.dtype(jnp.int8)
    if kv_dtype == "fp8_e4m3":
        return jnp.dtype(jnp.float8_e4m3fn)
    return jnp.dtype(base_dtype)


def _qmax(store_dtype) -> float:
    store_dtype = jnp.dtype(store_dtype)
    if store_dtype == jnp.dtype(jnp.int8):
        return INT8_MAX
    if fp8_supported() and store_dtype == jnp.dtype(jnp.float8_e4m3fn):
        return FP8_MAX
    raise ValueError(f"no quantization scheme for store dtype {store_dtype}")


def quantize_rows(x: jax.Array, store_dtype) -> tuple[jax.Array, jax.Array]:
    """Symmetric per-row quantization over the last (head_dim) axis.

    x: (..., hd) float -> (q: (..., hd) ``store_dtype``, scale: (...,) f32)
    with ``q * scale ~= x``.  All-zero rows keep scale 1 (q is 0 anyway), so
    dequantizing never divides by or multiplies with a zero scale."""
    qmax = _qmax(store_dtype)
    xf = x.astype(jnp.float32)
    absmax = jnp.max(jnp.abs(xf), axis=-1)
    scale = jnp.where(absmax > 0, absmax / qmax, 1.0).astype(jnp.float32)
    y = xf / scale[..., None]
    if jnp.dtype(store_dtype) == jnp.dtype(jnp.int8):
        q = jnp.clip(jnp.round(y), -qmax, qmax).astype(store_dtype)
    else:
        q = y.astype(store_dtype)
    return q, scale


def dequantize_rows(q: jax.Array, scale: jax.Array, dtype=jnp.float32) -> jax.Array:
    """Inverse of :func:`quantize_rows`: q (..., hd) x scale (...,) -> float."""
    return (q.astype(jnp.float32) * scale[..., None].astype(jnp.float32)).astype(dtype)
