"""Mixture-of-experts FFN: top-k routing with capacity, scatter dispatch.

Dispatch is scatter/gather based (not the (T, E, C) one-hot einsum, which is
quadratic in memory): tokens are assigned a position-in-expert via a cumsum
over the routing one-hot, dropped beyond capacity, scattered into per-expert
buffers, run through batched expert FFNs, and gathered back weighted by the
router gates.  Under EP (experts sharded over `model`) XLA turns the
scatter/gather into the all-to-all dispatch; under expert-TP (mixtral: 8
experts on a 16-way axis) the expert weights shard their hidden dim instead.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import api
from repro.distributed.sharding import ParamSpec, constrain
from repro.models import params as pp
from repro.models.config import ModelConfig
from repro.models.layers import Runtime, silu

__all__ = ["moe_specs", "apply_moe"]


def _expert_axes(cfg: ModelConfig, rt_mode: str, transpose: bool):
    """(E, din, dout) axes.  One chain covers both EP and expert-TP: when E
    divides the model axis it takes it (EP: dbrx/jamba, 16 experts) and the
    ffn dim's `tp` request is skipped (axis already used); when E does not
    divide (mixtral, 8 experts on 16) E replicates and the ffn dim picks the
    model axis up instead (expert-TP)."""
    del cfg, rt_mode
    return ("expert", "tp", "fsdp") if transpose else ("expert", "fsdp", "tp")


def moe_specs(cfg: ModelConfig, n_periods: int, moe_mode: str) -> dict:
    e, d, f = cfg.n_experts, cfg.d_model, cfg.expert_d_ff
    lead = (n_periods, e)

    def mat(din, dout, transpose):
        ax = _expert_axes(cfg, moe_mode, transpose)
        return ParamSpec((n_periods, *lead[1:], din, dout), (None, *ax), scale=1.0 / din**0.5)

    specs = {
        "router": ParamSpec((n_periods, d, e), (None, "fsdp", None), scale=1.0 / d**0.5),
        "w1": mat(d, f, False),
        "w3": mat(d, f, False),
        "w2": mat(f, d, True),
    }
    if cfg.butterfly.for_site("experts") != "dense":
        lspec = api.LinearSpec(d, f, cfg.butterfly.impl, max_block=cfg.butterfly.max_block)
        lspec_t = api.LinearSpec(f, d, cfg.butterfly.impl, max_block=cfg.butterfly.max_block)
        specs = {
            "router": specs["router"],
            "w1": _stack_specs(pp.linear_specs(lspec), (n_periods, e)),
            "w3": _stack_specs(pp.linear_specs(lspec), (n_periods, e)),
            "w2": _stack_specs(pp.linear_specs(lspec_t), (n_periods, e)),
        }
    return specs


def _stack_specs(tree: dict, lead: tuple[int, ...]) -> dict:
    return {
        k: ParamSpec((*lead, *s.shape), (None,) * len(lead) + s.axes, s.init, s.scale)
        for k, s in tree.items()
    }


def apply_moe(
    mparams: dict,
    cfg: ModelConfig,
    x: jax.Array,
    rt: Runtime,
    dropless: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """x: (B, S, D) -> (out, aux_loss).  mparams are per-layer (no period dim).

    Group-local one-hot einsum dispatch (GShard / t5x style): tokens are
    grouped (group axis sharded over data), routed with per-group capacity,
    and dispatched/combined via (G, Sg, E, C) einsums.  This is the form the
    SPMD partitioner handles natively — the dispatch einsum becomes the EP
    all-to-all — unlike a global scatter, which degenerates into
    full-replication copies (found via the dbrx dry-run: 90s of collectives
    per step before this rewrite).
    """
    b, s, d = x.shape
    t = b * s
    e, k = cfg.n_experts, cfg.top_k
    sg = min(cfg.moe_group, t)
    while t % sg:
        sg //= 2
    g = t // sg
    cap = max(int(cfg.capacity_factor * k * sg / e), 1)
    cap = min(cap, sg * k)
    if dropless and sg <= 256:
        # decode-scale batches route exactly (capacity = group size covers the
        # worst-case all-tokens-to-one-expert); prefill keeps capacity
        # semantics like training (documented eval drop risk, standard)
        cap = sg

    xg = x.reshape(g, sg, d)
    xg = constrain(xg, ("batch", None, None), rt.mesh, rt.rules)
    logits = jnp.einsum("gsd,de->gse", xg, mparams["router"].astype(x.dtype))
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)  # (G, Sg, E)
    gate, idx = jax.lax.top_k(probs, k)  # (G, Sg, k)
    gate = gate / jnp.clip(gate.sum(-1, keepdims=True), 1e-9)

    # position-in-expert per group: exclusive cumsum over the (Sg, k) stream
    emask = jax.nn.one_hot(idx, e, dtype=jnp.float32)  # (G, Sg, k, E)
    em_flat = emask.reshape(g, sg * k, e)
    prior = jnp.cumsum(em_flat, axis=1) - em_flat  # assignments before this one
    pos = (prior * em_flat).sum(-1).reshape(g, sg, k)  # (G, Sg, k)
    keep = (pos < cap).astype(jnp.float32)

    # dispatch/combine one-hots, accumulated over k (k is tiny) to avoid the
    # 5-D (G, Sg, k, E, C) intermediate
    dtype = x.dtype
    disp = None  # (G, Sg, E, C) 0/1
    comb = None  # (G, Sg, E, C) gate-weighted
    for j in range(k):
        pos_oh = jax.nn.one_hot(pos[..., j].astype(jnp.int32), cap, dtype=jnp.float32)
        term = emask[:, :, j, :, None] * pos_oh[:, :, None, :] * keep[..., j, None, None]
        disp = term if disp is None else disp + term
        wterm = term * gate[..., j, None, None]
        comb = wterm if comb is None else comb + wterm

    ep_axes = ("batch", None, "expert", None) if rt.moe_mode == "ep" else ("batch", None, None, None)
    disp = constrain(disp.astype(dtype), ep_axes, rt.mesh, rt.rules)
    comb = comb.astype(dtype)

    # dispatch: this einsum IS the all-to-all under EP sharding
    xe = jnp.einsum("gsec,gsd->gecd", disp, xg)
    xe = constrain(xe, ("batch", "expert", None, None) if rt.moe_mode == "ep" else ("batch", None, None, None), rt.mesh, rt.rules)

    # expert FFN (SwiGLU), batched over E
    if cfg.butterfly.for_site("experts") != "dense":
        lspec = api.LinearSpec(d, cfg.expert_d_ff, cfg.butterfly.impl, max_block=cfg.butterfly.max_block)
        lspec_t = api.LinearSpec(cfg.expert_d_ff, d, cfg.butterfly.impl, max_block=cfg.butterfly.max_block)
        fe = lambda p, xb, ls: pp.apply_linear_p(p, ls, xb)
        h = jax.vmap(fe, in_axes=(0, 1, None), out_axes=1)(mparams["w1"], xe, lspec)
        h3 = jax.vmap(fe, in_axes=(0, 1, None), out_axes=1)(mparams["w3"], xe, lspec)
        h = silu(h) * h3
        out_e = jax.vmap(fe, in_axes=(0, 1, None), out_axes=1)(mparams["w2"], h, lspec_t)
    else:
        w1 = mparams["w1"].astype(dtype)
        w3 = mparams["w3"].astype(dtype)
        w2 = mparams["w2"].astype(dtype)
        h = silu(jnp.einsum("gecd,edf->gecf", xe, w1)) * jnp.einsum("gecd,edf->gecf", xe, w3)
        out_e = jnp.einsum("gecf,efd->gecd", h, w2)

    # combine: the reverse all-to-all, gate-weighted
    y = jnp.einsum("gsec,gecd->gsd", comb, out_e)

    # load-balancing aux loss (Switch-style, per group then averaged)
    me = probs.mean(axis=1)  # (G, E) mean router prob
    ce = emask.sum(axis=2).mean(axis=1)  # (G, E) fraction of tokens per expert
    aux = e * jnp.mean(jnp.sum(me * ce, axis=-1))
    return y.reshape(b, s, d), aux
