"""ParamSpec builders for (butterfly-able) linear layers.

Single source of truth for shape + init + sharding of every linear site, so
the paper's technique is a pure config swap: the spec tree changes shape but
the call site (`apply_linear_p`) stays identical.
"""

from __future__ import annotations

import math

import jax

from repro.core import api, butterfly as bfly
from repro.distributed.sharding import ParamSpec

__all__ = ["linear_specs", "apply_linear_p"]


def linear_specs(
    lspec: api.LinearSpec,
    axes: tuple[str | None, str | None] = ("fsdp", "tp"),
    scale: float | None = None,
) -> dict:
    """ParamSpec tree for one linear site under the configured impl."""
    out: dict = {}
    if lspec.impl == "dense":
        out["w"] = ParamSpec((lspec.din, lspec.dout), axes, scale=scale)
    elif lspec.impl in ("monarch", "monarch_kernel"):
        sp = lspec.slices
        b = lspec.block
        nb = sp.piece // b
        gin_scale = 1.0 / math.sqrt(sp.gin)
        out["r"] = ParamSpec(
            (sp.gout, sp.gin, nb, b, b),
            (None, None, "tp", None, "fsdp"),
            scale=1.0 / math.sqrt(b),
        )
        out["l"] = ParamSpec(
            (sp.gout, sp.gin, b, nb, nb),
            (None, None, "tp", "fsdp", None),
            scale=gin_scale / math.sqrt(nb),
        )
    elif lspec.impl == "radix2":
        sp = lspec.slices
        shapes = bfly.stage_shapes(sp.piece)
        st_scale = math.sqrt(0.5) * sp.gin ** (-0.5 / len(shapes))
        for i, shape in enumerate(shapes):
            out[f"s{i:02d}"] = ParamSpec(
                (sp.gout, sp.gin, *shape),
                (None, None, "tp", None, None, "fsdp"),
                scale=st_scale,
            )
    else:
        raise ValueError(lspec.impl)
    if lspec.use_bias:
        out["b"] = ParamSpec((lspec.dout,), (None,), init="zeros")
    return out


def apply_linear_p(params: dict, lspec: api.LinearSpec, x: jax.Array) -> jax.Array:
    """Adapter from the spec-tree param layout to core.api.apply_linear."""
    if lspec.impl == "radix2":
        n = bfly.num_stages(lspec.slices.piece)
        p = {"stages": [params[f"s{i:02d}"] for i in range(n)]}
        if "b" in params:
            p["b"] = params["b"]
        return api.apply_linear(p, lspec, x)
    return api.apply_linear(params, lspec, x)
