"""Model configuration — one dataclass covers all ten assigned families.

Every architecture is expressed as a periodic layer pattern (``period_slots``)
so dense, MoE, SSM, hybrid and enc-dec stacks share one scan-based runtime.
"""

from __future__ import annotations

import dataclasses
from typing import Literal

from repro.core.api import ButterflyPolicy
from repro.core.attention import AttentionSpec

__all__ = ["ModelConfig", "Slot"]


@dataclasses.dataclass(frozen=True)
class Slot:
    """One layer slot inside the repeating period.

    ``attn_pattern`` overrides ``AttentionSpec.pattern`` for this slot only —
    the paper's §III hybrid butterfly-sparsity stacks mix butterfly-sparse
    attention layers with dense/FNet layers at different depths."""

    mixer: Literal["attn", "mamba", "fft"]  # token mixing sublayer
    ffn: Literal["dense", "moe", "none"] = "dense"
    attn_pattern: str | None = None  # per-slot sparsity pattern override


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    vocab: int
    # attention
    n_heads: int = 0
    n_kv_heads: int = 0
    head_dim: int = 0
    d_ff: int = 0
    rope_theta: float = 1e4
    qkv_bias: bool = False
    qk_norm: bool = False
    sliding_window: int | None = None
    # MoE
    n_experts: int = 0
    top_k: int = 0
    moe_period: int = 1  # MoE FFN every `moe_period` layers (jamba: 2)
    moe_d_ff: int = 0
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01
    moe_group: int = 512  # group-local dispatch size (GShard-style)
    # SSM (mamba2 / SSD)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_groups: int = 1
    ssm_conv: int = 4
    ssm_chunk: int = 128
    attn_period: int = 0  # hybrid: one attn layer per `attn_period` (jamba: 8)
    # encoder-decoder (whisper)
    n_enc_layers: int = 0
    enc_seq: int = 0  # stub-frontend sequence length (whisper: 1500 frames)
    # vlm (internvl2)
    n_img_tokens: int = 0  # stub patch embeddings prepended to the text
    # non-causal encoder-style stack (fabnet / vanilla benchmarks)
    causal: bool = True
    norm: str = "rmsnorm"  # rmsnorm | layernorm
    act: str = "swiglu"  # swiglu | gelu
    # explicit layer pattern (the paper's §III hybrid butterfly-sparsity
    # stacks): when set, this IS the repeating period — n_layers must divide
    # by its length as usual (one period == the whole depth when equal)
    slots_override: tuple[Slot, ...] | None = None
    # the paper's technique
    butterfly: ButterflyPolicy = ButterflyPolicy()
    # attention execution form (impl + kernel tile geometry); the legacy
    # `attn_chunk` / `attn_f32_softmax` perf levers below override the spec's
    # chunk/f32 fields — see `attention_spec`
    attention: AttentionSpec = AttentionSpec()
    # execution
    dtype: str = "bfloat16"
    param_dtype: str = "float32"
    remat: bool = True
    attn_chunk: int = 2048
    norm_eps: float = 1e-5
    grad_accum: int = 1  # microbatches per train step
    # cost-probe mode: python-unrolled periods instead of lax.scan.  XLA's
    # cost_analysis counts while-loop bodies ONCE (verified), so the dry-run
    # extrapolates per-period costs from small unrolled probes while the real
    # (scanned) module provides the compile/memory proof.
    unroll_layers: bool = False
    # ---- performance levers (EXPERIMENTS.md §Perf) ----
    # pure_dp: no tensor parallelism — batch shards over the model axis too
    # (right answer for small models where TP collectives dwarf compute)
    pure_dp: bool = False
    # boundary_mode: "sp" shards layer-boundary activations over the model
    # axis (Megatron sequence parallelism); "replicated" keeps them local so
    # weight-grad contractions never cross the model axis (classic Megatron —
    # kills the giant f32 dW all-reduces XLA schedules under SP)
    boundary_mode: str = "sp"
    # f32 softmax in attention scores (baseline) vs bf16 (halves the
    # attention-score HBM traffic, the dominant memory term at 32k)
    attn_f32_softmax: bool = True
    # cast f32 master params to the compute dtype *before* the FSDP
    # all-gathers (sharded-local cast), so parameter collectives move bf16:
    # halves the dominant collective term of every FSDP train cell
    cast_params_once: bool = False

    # ---------------- derived ----------------
    @property
    def attention_spec(self) -> AttentionSpec:
        """The effective AttentionSpec: impl + tiles from `attention`,
        chunk/f32 from the per-config perf levers (single source of truth for
        the hillclimb sweeps that toggle them)."""
        return dataclasses.replace(
            self.attention, chunk=self.attn_chunk, f32_softmax=self.attn_f32_softmax
        )

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def period_slots(self) -> tuple[Slot, ...]:
        """The repeating layer pattern; n_layers must divide evenly."""
        if self.slots_override is not None:
            return self.slots_override
        if self.family == "ssm":
            return (Slot("mamba", "dense"),)
        if self.family == "hybrid":
            period = self.attn_period or 8
            slots = []
            for i in range(period):
                mixer = "attn" if i == 0 else "mamba"
                ffn = (
                    "moe"
                    if self.n_experts and (i % self.moe_period == self.moe_period - 1)
                    else "dense"
                )
                slots.append(Slot(mixer, ffn))
            return tuple(slots)
        mixer = "fft" if self.butterfly.fft_attention and not self.causal else "attn"
        if self.n_experts and self.moe_period == 1:
            return (Slot(mixer, "moe"),)
        if self.n_experts:
            slots = [
                Slot(mixer, "moe" if i % self.moe_period == self.moe_period - 1 else "dense")
                for i in range(self.moe_period)
            ]
            return tuple(slots)
        return (Slot(mixer, "dense"),)

    @property
    def n_periods(self) -> int:
        n = len(self.period_slots)
        assert self.n_layers % n == 0, (self.n_layers, n)
        return self.n_layers // n

    @property
    def expert_d_ff(self) -> int:
        return self.moe_d_ff or self.d_ff

    def validate(self) -> None:
        assert self.d_model > 0 and self.n_layers > 0 and self.vocab > 0
        if any(s.mixer == "attn" for s in self.period_slots):
            assert self.n_heads > 0 and self.head_dim > 0
            assert self.n_heads % max(self.n_kv_heads, 1) == 0
        if any(s.mixer == "mamba" for s in self.period_slots):
            assert self.ssm_state > 0 and self.d_inner % self.ssm_head_dim == 0
        _ = self.n_periods
