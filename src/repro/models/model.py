"""Model registry + runtime resolution + analytic FLOP/param accounting."""

from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.core import api
from repro.distributed import sharding as shd
from repro.models import transformer as tf
from repro.models.config import ModelConfig
from repro.models.layers import Runtime

__all__ = [
    "resolve_runtime",
    "build_specs",
    "init_params",
    "abstract_params",
    "param_shardings",
    "count_params",
    "model_flops_per_token",
]


def rules_for(cfg: ModelConfig) -> dict | None:
    return shd.RULES_PURE_DP if cfg.pure_dp else None


def resolve_runtime(cfg: ModelConfig, mesh: Mesh | None) -> Runtime:
    """Pick attention/MoE parallelism from divisibility (DESIGN.md §5)."""
    tp = 1
    if mesh is not None and "model" in mesh.axis_names:
        tp = dict(zip(mesh.axis_names, mesh.devices.shape))["model"]
    attn_mode = "tp" if (cfg.n_heads == 0 or cfg.n_heads % max(tp, 1) == 0) else "cp"
    moe_mode = "ep" if (cfg.n_experts == 0 or cfg.n_experts % max(tp, 1) == 0) else "tp"
    return Runtime(mesh=mesh, attn_mode=attn_mode, moe_mode=moe_mode,
                   rules=rules_for(cfg))


def build_specs(cfg: ModelConfig) -> dict:
    cfg.validate()
    return tf.model_specs(cfg)


def init_params(cfg: ModelConfig, key: jax.Array):
    return shd.init_tree(build_specs(cfg), key, jnp.dtype(cfg.param_dtype))


def abstract_params(cfg: ModelConfig):
    return shd.abstract_tree(build_specs(cfg), jnp.dtype(cfg.param_dtype))


def param_shardings(cfg: ModelConfig, mesh: Mesh):
    return shd.sharding_tree(build_specs(cfg), mesh, rules_for(cfg))


def count_params(cfg: ModelConfig) -> int:
    specs = build_specs(cfg)
    return sum(
        math.prod(s.shape)
        for s in jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, shd.ParamSpec))
    )


def _active_params(cfg: ModelConfig) -> int:
    """Parameters touched per token (MoE counts top_k of n_experts)."""
    total = count_params(cfg)
    if not cfg.n_experts:
        return total
    specs = build_specs(cfg)
    expert_leaves = 0
    for j, slot in enumerate(cfg.period_slots):
        sl = specs["layers"][f"slot{j:02d}"]
        if "moe" in sl:
            for name in ("w1", "w2", "w3"):
                if name in sl["moe"]:
                    sub = sl["moe"][name]
                    leaves = jax.tree.leaves(
                        sub, is_leaf=lambda x: isinstance(x, shd.ParamSpec)
                    )
                    expert_leaves += sum(math.prod(s.shape) for s in leaves)
    active_frac = cfg.top_k / max(cfg.n_experts, 1)
    return int(total - expert_leaves * (1 - active_frac))


def model_flops_per_token(cfg: ModelConfig, seq_len: int, mode: str = "train") -> float:
    """MODEL_FLOPS: 6·N_active per token (train) or 2·N_active (fwd) plus the
    exact attention term (4·S·d per layer halved for causal).  This is the
    'useful FLOPs' numerator of the roofline table."""
    n_active = _active_params(cfg)
    # embedding + head are matmul-active; embeddings gather is not a matmul
    n_active -= cfg.vocab * cfg.d_model  # the gather table
    mult = 6 if mode == "train" else 2
    per_tok = mult * n_active
    # attention score+value flops: 2 * 2 * S_kv_avg * (n_heads*head_dim)
    n_attn_layers = sum(1 for s in cfg.period_slots for _ in [0] if s.mixer == "attn")
    n_attn_layers = n_attn_layers * cfg.n_periods
    if n_attn_layers and cfg.n_heads:
        s_kv = seq_len / 2 if cfg.causal else seq_len
        if cfg.sliding_window:
            s_kv = min(s_kv, cfg.sliding_window)
        attn = 2 * 2 * s_kv * cfg.n_heads * cfg.head_dim * n_attn_layers
        per_tok += (3 if mode == "train" else 1) * attn
    return per_tok


def decode_flops_per_token(cfg: ModelConfig, cache_len: int) -> float:
    """MODEL_FLOPS for one decode step per sequence (fwd only, full KV read)."""
    n_active = _active_params(cfg) - cfg.vocab * cfg.d_model
    per_tok = 2 * n_active
    n_attn_layers = sum(1 for s in cfg.period_slots if s.mixer == "attn") * cfg.n_periods
    if n_attn_layers and cfg.n_heads:
        s_kv = min(cache_len, cfg.sliding_window) if cfg.sliding_window else cache_len
        per_tok += 2 * 2 * s_kv * cfg.n_heads * cfg.head_dim * n_attn_layers
    return per_tok
