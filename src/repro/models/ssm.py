"""Mamba-2 (SSD — state-space duality, arXiv:2405.21060) mixer.

Chunked matmul formulation: within each length-Q chunk the output is a masked
(CBᵀ ⊙ decay) matmul (MXU-friendly quadratic-in-Q work); across chunks a
short `lax.scan` carries the (H, N, P) state recurrence.  Decode is the O(1)
single-step recurrence on the cached state.  The short causal conv1d is
expressed as k shifted adds (train) / a (k-1)-deep cached window (decode).
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.core import api
from repro.distributed.sharding import ParamSpec
from repro.models import params as pp
from repro.models.config import ModelConfig
from repro.models.layers import Runtime, rms_norm, silu

__all__ = [
    "mamba_specs",
    "apply_mamba",
    "mamba_decode",
    "ssd_reference",
]


def _proj_dims(cfg: ModelConfig) -> tuple[int, int]:
    """in_proj: D -> [z (d_inner), xBC (d_inner + 2*G*N), dt (H)]."""
    d_xbc = cfg.d_inner + 2 * cfg.ssm_groups * cfg.ssm_state
    return cfg.d_inner + d_xbc + cfg.ssm_heads, d_xbc


def mamba_specs(cfg: ModelConfig, n_periods: int) -> dict:
    d_all, d_xbc = _proj_dims(cfg)
    in_spec = api.LinearSpec(cfg.d_model, d_all, cfg.butterfly.for_site("qkv"))
    out_spec = api.LinearSpec(cfg.d_inner, cfg.d_model, cfg.butterfly.for_site("out"))

    def stack(tree):
        return {
            k: ParamSpec((n_periods, *s.shape), (None,) + s.axes, s.init, s.scale)
            for k, s in tree.items()
        }

    return {
        "in_proj": stack(pp.linear_specs(in_spec)),
        "out_proj": stack(pp.linear_specs(out_spec, axes=("tp", "fsdp"))),
        "conv_w": ParamSpec((n_periods, cfg.ssm_conv, d_xbc), (None, None, "tp"), scale=0.5),
        "conv_b": ParamSpec((n_periods, d_xbc), (None, "tp"), init="zeros"),
        "a_log": ParamSpec((n_periods, cfg.ssm_heads), (None, None), init="zeros"),
        "dt_bias": ParamSpec((n_periods, cfg.ssm_heads), (None, None), init="zeros"),
        "d_skip": ParamSpec((n_periods, cfg.ssm_heads), (None, None), init="ones"),
        "norm_w": ParamSpec((n_periods, cfg.d_inner), (None, None), init="zeros"),
    }


def _split_proj(cfg: ModelConfig, zxbcdt: jax.Array):
    d_i, g, n, h = cfg.d_inner, cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads
    z = zxbcdt[..., :d_i]
    xbc = zxbcdt[..., d_i : d_i + d_i + 2 * g * n]
    dt = zxbcdt[..., -h:]
    return z, xbc, dt


def _causal_conv(xbc: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv1d over (B, L, C) via k shifted adds."""
    k = w.shape[0]
    out = xbc * w[-1]
    for i in range(1, k):
        shifted = jnp.pad(xbc, ((0, 0), (i, 0), (0, 0)))[:, : xbc.shape[1]]
        out = out + shifted * w[-1 - i]
    return silu(out + b)


def _ssd_chunked(cfg: ModelConfig, x, dt, a, bmat, cmat, init_state=None):
    """SSD scan.  x: (B,L,H,P); dt: (B,L,H) f32; a: (H,) f32 negative;
    bmat/cmat: (B,L,G,N).  Returns (y (B,L,H,P), final_state (B,H,N,P) f32)."""
    b, l, h, p = x.shape
    g, n = bmat.shape[2], bmat.shape[3]
    rep = h // g
    q = min(cfg.ssm_chunk, l)
    if l % q:
        q = math.gcd(l, q)
    nc = l // q

    da = dt * a  # (B, L, H) log-decay per step (negative)
    cs = jnp.cumsum(da.reshape(b, nc, q, h), axis=2)  # inclusive cum log-decay
    csr = cs.reshape(b, nc, q, g, rep)

    xc = x.reshape(b, nc, q, g, rep, p)
    bc = bmat.reshape(b, nc, q, g, n)
    cc = cmat.reshape(b, nc, q, g, n)
    dt_c = dt.reshape(b, nc, q, g, rep)

    # ---- intra-chunk: y_i += sum_{j<=i} (C_i.B_j) exp(cs_i - cs_j) dt_j x_j
    cb = jnp.einsum("bcigN,bcjgN->bcgij", cc, bc, preferred_element_type=jnp.float32)
    ldecay = csr[:, :, :, :, :, None] - jnp.moveaxis(csr, 2, -1)[:, :, None]
    mask = jnp.tril(jnp.ones((q, q), bool))  # i >= j
    lmat = jnp.where(mask[None, None, :, None, None, :], jnp.exp(ldecay), 0.0)
    m = jnp.moveaxis(cb, 2, 3)[:, :, :, :, None, :] * lmat  # (b,nc,i,g,rep,j)
    m = m * jnp.moveaxis(dt_c, 2, -1)[:, :, None]  # * dt_j
    y_intra = jnp.einsum("bcigrj,bcjgrp->bcigrp", m.astype(x.dtype), xc)

    # ---- chunk states: S_c = sum_j exp(cs_last - cs_j) dt_j B_j x_j^T
    sdecay = jnp.exp(cs[:, :, -1:, :] - cs).reshape(b, nc, q, g, rep)
    wx = xc * (sdecay * dt_c)[..., None].astype(x.dtype)
    s_chunk = jnp.einsum("bcjgN,bcjgrp->bcgrNp", bc, wx)

    # ---- inter-chunk recurrence (short scan over chunks)
    tot = jnp.exp(cs[:, :, -1, :]).reshape(b, nc, g, rep)

    def step(s_prev, inp):
        s_c, t_c = inp
        new = s_prev * t_c[..., None, None] + s_c.astype(jnp.float32)
        return new, s_prev

    init = (
        jnp.zeros((b, g, rep, n, p), jnp.float32)
        if init_state is None
        else init_state.reshape(b, g, rep, n, p).astype(jnp.float32)
    )
    s_fin, s_prevs = jax.lax.scan(
        step, init, (jnp.moveaxis(s_chunk, 1, 0), jnp.moveaxis(tot, 1, 0))
    )
    s_prevs = jnp.moveaxis(s_prevs, 0, 1)  # (b,nc,g,rep,N,p)

    # ---- inter-chunk contribution: y_i += exp(cs_i) C_i . S_prev
    y_inter = jnp.einsum(
        "bcigN,bcgrNp->bcigrp", cc, s_prevs.astype(x.dtype)
    ) * jnp.exp(csr)[..., None].astype(x.dtype)

    y = (y_intra + y_inter).reshape(b, l, h, p)
    return y, s_fin.reshape(b, h, n, p)


def _pre_ssd(mparams, cfg, x):
    in_spec = api.LinearSpec(cfg.d_model, _proj_dims(cfg)[0], cfg.butterfly.for_site("qkv"))
    g, n = cfg.ssm_groups, cfg.ssm_state
    zxbcdt = pp.apply_linear_p(mparams["in_proj"], in_spec, x)
    z, xbc_raw, dt = _split_proj(cfg, zxbcdt)
    xbc = _causal_conv(
        xbc_raw, mparams["conv_w"].astype(x.dtype), mparams["conv_b"].astype(x.dtype)
    )
    xs = xbc[..., : cfg.d_inner]
    bmat = xbc[..., cfg.d_inner : cfg.d_inner + g * n].reshape(*x.shape[:2], g, n)
    cmat = xbc[..., cfg.d_inner + g * n :].reshape(*x.shape[:2], g, n)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + mparams["dt_bias"])
    a = -jnp.exp(mparams["a_log"].astype(jnp.float32))
    return z, xbc_raw, xs, bmat, cmat, dt, a


def _post_ssd(mparams, cfg, x, y, xh, z):
    out_spec = api.LinearSpec(cfg.d_inner, cfg.d_model, cfg.butterfly.for_site("out"))
    y = y + xh * mparams["d_skip"].astype(x.dtype)[None, None, :, None]
    y = y.reshape(*x.shape[:2], cfg.d_inner)
    y = rms_norm(y * silu(z), mparams["norm_w"], cfg.norm_eps)
    return pp.apply_linear_p(mparams["out_proj"], out_spec, y)


def apply_mamba(
    mparams: dict, cfg: ModelConfig, x: jax.Array, rt: Runtime, *, return_cache=False
):
    """Full-sequence mamba2 block.  x: (B, L, D).
    With return_cache: (out, {conv (B,k-1,C), state (B,H,N,P)})."""
    h, p = cfg.ssm_heads, cfg.ssm_head_dim
    z, xbc_raw, xs, bmat, cmat, dt, a = _pre_ssd(mparams, cfg, x)
    xh = xs.reshape(*x.shape[:2], h, p)
    y, state = _ssd_chunked(cfg, xh, dt, a, bmat, cmat)
    out = _post_ssd(mparams, cfg, x, y, xh, z)
    if return_cache:
        conv_cache = xbc_raw[:, -(cfg.ssm_conv - 1) :, :]
        return out, {"conv": conv_cache, "state": state}
    return out


def mamba_decode(mparams: dict, cfg: ModelConfig, x: jax.Array, cache: dict, rt: Runtime):
    """Single-token step.  x: (B, 1, D); cache: {conv (B,k-1,C), state (B,H,N,P)}."""
    in_spec = api.LinearSpec(cfg.d_model, _proj_dims(cfg)[0], cfg.butterfly.for_site("qkv"))
    out_spec = api.LinearSpec(cfg.d_inner, cfg.d_model, cfg.butterfly.for_site("out"))
    g, n, h, p = cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim

    zxbcdt = pp.apply_linear_p(mparams["in_proj"], in_spec, x)
    z, xbc_raw, dt = _split_proj(cfg, zxbcdt)  # (B,1,*)
    window = jnp.concatenate([cache["conv"].astype(x.dtype), xbc_raw], axis=1)  # (B,k,C)
    w = mparams["conv_w"].astype(x.dtype)
    conv_out = silu(
        jnp.einsum("bkc,kc->bc", window, w)[:, None] + mparams["conv_b"].astype(x.dtype)
    )  # (B,1,C)
    new_conv = window[:, 1:]

    xs = conv_out[..., : cfg.d_inner]
    bmat = conv_out[:, 0, cfg.d_inner : cfg.d_inner + g * n].reshape(-1, g, n)
    cmat = conv_out[:, 0, cfg.d_inner + g * n :].reshape(-1, g, n)
    dtv = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + mparams["dt_bias"])  # (B,H)
    a = -jnp.exp(mparams["a_log"].astype(jnp.float32))

    xh = xs[:, 0].reshape(-1, h, p).astype(jnp.float32)  # (B,H,P)
    state = cache["state"].astype(jnp.float32)  # (B,H,N,P)
    rep = h // g
    bm = jnp.repeat(bmat, rep, axis=1).astype(jnp.float32)  # (B,H,N)
    cm = jnp.repeat(cmat, rep, axis=1).astype(jnp.float32)
    decay = jnp.exp(dtv * a)  # (B,H)
    new_state = state * decay[..., None, None] + jnp.einsum(
        "bhN,bhp->bhNp", bm * dtv[..., None], xh
    )
    y = jnp.einsum("bhN,bhNp->bhp", cm, new_state) + xh * mparams["d_skip"][:, None]
    y = y.reshape(-1, 1, cfg.d_inner).astype(x.dtype)
    y = rms_norm(y * silu(z), mparams["norm_w"], cfg.norm_eps)
    out = pp.apply_linear_p(mparams["out_proj"], out_spec, y)
    return out, {"conv": new_conv, "state": new_state}


def ssd_reference(x, dt, a, bmat, cmat, init_state=None):
    """Naive sequential SSD recurrence (oracle for tests).

    x: (B,L,H,P); dt: (B,L,H); a: (H,); bmat/cmat: (B,L,G,N).
    S_t = exp(dt_t a) S_{t-1} + dt_t B_t x_t^T ;  y_t = C_t . S_t
    """
    b, l, h, p = x.shape
    g, n = bmat.shape[2], bmat.shape[3]
    rep = h // g
    bm = jnp.repeat(bmat, rep, axis=2).astype(jnp.float32)  # (B,L,H,N)
    cm = jnp.repeat(cmat, rep, axis=2).astype(jnp.float32)
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)

    def step(s, inp):
        xt, dt_t, b_t, c_t = inp
        decay = jnp.exp(dt_t * a)  # (B,H)
        s = s * decay[..., None, None] + jnp.einsum("bhN,bhp->bhNp", b_t * dt_t[..., None], xt)
        y = jnp.einsum("bhN,bhNp->bhp", c_t, s)
        return s, y

    init = (
        jnp.zeros((b, h, n, p), jnp.float32)
        if init_state is None
        else init_state.astype(jnp.float32)
    )
    s_fin, ys = jax.lax.scan(
        step,
        init,
        (
            jnp.moveaxis(xf, 1, 0),
            jnp.moveaxis(dtf, 1, 0),
            jnp.moveaxis(bm, 1, 0),
            jnp.moveaxis(cm, 1, 0),
        ),
    )
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype), s_fin
