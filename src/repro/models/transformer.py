"""Periodic-pattern transformer runtime: dense / MoE / SSM / hybrid / enc-dec.

One scan-over-periods executes every architecture: a period is a static tuple
of layer slots (attn|mamba|fft mixer x dense|moe|none FFN), parameters are
stacked over periods, and caches mirror the slot structure.  The paper's
technique enters exclusively through the linear-layer specs (BPMM sites) and
the `fft` mixer slot (AT-all replacement), so dense baselines and butterfly
variants share every line of runtime code.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.core import api
from repro.core import quant
from repro.core.fft_mixing import fnet_mixing
from repro.distributed.sharding import ParamSpec, constrain
from repro.models import params as pp
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.config import ModelConfig, Slot
from repro.core import sparsity
from repro.models.layers import (
    Runtime,
    apply_rope,
    gather_pages,
    gelu,
    layer_norm,
    rms_norm,
    run_attention,
    run_chunk_attention,
    run_decode_attention,
    run_paged_chunk_attention,
    run_paged_decode_attention,
    run_paged_prefill_attention,
    silu,
)

Params = dict[str, Any]

# --------------------------------------------------------------------------
# Param specs
# --------------------------------------------------------------------------


def _norm_specs(cfg: ModelConfig, n_periods: int) -> dict:
    out = {"w": ParamSpec((n_periods, cfg.d_model), (None, None), init="zeros")}
    if cfg.norm == "layernorm":
        out["b"] = ParamSpec((n_periods, cfg.d_model), (None, None), init="zeros")
    return out


def _stack(tree: dict, n: int) -> dict:
    return {
        k: ParamSpec((n, *s.shape), (None,) + s.axes, s.init, s.scale)
        for k, s in tree.items()
    }


def attn_specs(cfg: ModelConfig, n_periods: int) -> dict:
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    bias = cfg.qkv_bias
    sq = api.LinearSpec(d, h * hd, cfg.butterfly.for_site("qkv"), use_bias=bias)
    sk = api.LinearSpec(d, kv * hd, cfg.butterfly.for_site("qkv"), use_bias=bias)
    so = api.LinearSpec(h * hd, d, cfg.butterfly.for_site("out"))
    out = {
        "wq": _stack(pp.linear_specs(sq), n_periods),
        "wk": _stack(pp.linear_specs(sk), n_periods),
        "wv": _stack(pp.linear_specs(sk), n_periods),
        "wo": _stack(pp.linear_specs(so, axes=("tp", "fsdp")), n_periods),
    }
    if cfg.qk_norm:
        out["q_norm"] = ParamSpec((n_periods, hd), (None, None), init="zeros")
        out["k_norm"] = ParamSpec((n_periods, hd), (None, None), init="zeros")
    return out


def ffn_specs(cfg: ModelConfig, n_periods: int) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    s1 = api.LinearSpec(d, f, cfg.butterfly.for_site("ffn"))
    s2 = api.LinearSpec(f, d, cfg.butterfly.for_site("ffn"))
    out = {
        "w1": _stack(pp.linear_specs(s1), n_periods),
        "w2": _stack(pp.linear_specs(s2, axes=("tp", "fsdp")), n_periods),
    }
    if cfg.act == "swiglu":
        out["w3"] = _stack(pp.linear_specs(s1), n_periods)
    return out


def slot_specs(cfg: ModelConfig, slot: Slot, n_periods: int, cross: bool = False) -> dict:
    out: dict = {"mixer_norm": _norm_specs(cfg, n_periods)}
    if slot.mixer == "attn":
        out["attn"] = attn_specs(cfg, n_periods)
    elif slot.mixer == "mamba":
        out["mamba"] = ssm_mod.mamba_specs(cfg, n_periods)
    if cross:
        out["cross_norm"] = _norm_specs(cfg, n_periods)
        out["cross"] = attn_specs(cfg, n_periods)
    if slot.ffn != "none":
        out["ffn_norm"] = _norm_specs(cfg, n_periods)
        if slot.ffn == "moe":
            rt_mode = "ep"  # spec sharding falls back automatically if E % tp != 0
            out["moe"] = moe_mod.moe_specs(cfg, n_periods, rt_mode)
        else:
            out["ffn"] = ffn_specs(cfg, n_periods)
    return out


def model_specs(cfg: ModelConfig) -> dict:
    specs: dict = {
        "embed": ParamSpec((cfg.vocab, cfg.d_model), ("fsdp", "tp"), scale=1.0),
        "head": ParamSpec((cfg.d_model, cfg.vocab), ("fsdp", "tp")),
        "final_norm": _norm_specs(cfg, 1),
        "layers": {
            f"slot{j:02d}": slot_specs(cfg, s, cfg.n_periods)
            for j, s in enumerate(cfg.period_slots)
        },
    }
    if cfg.family == "encdec":
        enc_slot = Slot("fft" if cfg.butterfly.fft_attention else "attn", "dense")
        specs["encoder"] = {
            "layers": {
                "slot00": slot_specs(cfg, enc_slot, cfg.n_enc_layers)
            },
            "final_norm": _norm_specs(cfg, 1),
        }
        # decoder slots get cross-attention
        specs["layers"] = {
            f"slot{j:02d}": slot_specs(cfg, s, cfg.n_periods, cross=True)
            for j, s in enumerate(cfg.period_slots)
        }
    return specs


# --------------------------------------------------------------------------
# Apply
# --------------------------------------------------------------------------


def _norm(nparams: dict, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    if cfg.norm == "layernorm":
        return layer_norm(x, nparams["w"], nparams["b"], cfg.norm_eps)
    return rms_norm(x, nparams["w"], cfg.norm_eps)


def _proj(aparams, cfg, x, name, heads):
    site = {"wq": "qkv", "wk": "qkv", "wv": "qkv", "wo": "out"}[name]
    bias = cfg.qkv_bias and name != "wo"
    if name == "wo":
        spec = api.LinearSpec(cfg.n_heads * cfg.head_dim, cfg.d_model, cfg.butterfly.for_site(site))
    else:
        spec = api.LinearSpec(cfg.d_model, heads * cfg.head_dim, cfg.butterfly.for_site(site), use_bias=bias)
    return pp.apply_linear_p(aparams[name], spec, x)


def _ring_place(c: jax.Array, lengths: jax.Array, klen: int) -> jax.Array:
    """Reorder a full-length KV tensor into ring order: slot ``t`` holds the
    newest key whose absolute position ≡ t (mod klen) below the row's length.

    c: (B, S, KV, hd) -> (B, klen, KV, hd).  A later decode write at
    ``pos % klen`` then lands exactly on the oldest in-window key — for any
    prompt length, not just multiples of the window.  Rows with
    ``lengths[b] < klen`` leave slots >= lengths[b] as clamped duplicates;
    the decode-side ``cur_len`` mask never reads them.
    """
    t = jnp.arange(klen)
    last = lengths.astype(jnp.int32)[:, None] - 1  # (B, 1)
    p = last - ((last - t[None, :]) % klen)
    p = jnp.clip(p, 0, c.shape[1] - 1)
    return jnp.take_along_axis(c, p[:, :, None, None], axis=1)


def _paged_kv_write(
    pool: jax.Array,
    new: jax.Array,
    rows: jax.Array,
    valid: jax.Array,
    page_table: jax.Array,
    page: int,
    ring_tiles: int | None = None,
    scale: jax.Array | None = None,
):
    """Page-table-indirected masked scatter: token KV at absolute positions
    ``rows`` (B, C) lands at ``page_table[b, rows // page] * page + rows %
    page`` of the flat pool (n_pages * page, KV, hd).  Rows that are invalid
    (beyond ``ntok`` / ``lengths``) or whose virtual tile is unallocated
    (sentinel id) scatter out of bounds and are dropped — a row can never
    clobber a page it does not own.

    ``ring_tiles`` is the mod-window modulus: the table has ``ring_tiles``
    slots and absolute tile ``rows // page`` writes slot
    ``(rows // page) % ring_tiles`` — the paged replacement for the
    contiguous ``_ring_place`` write path, phase-aligned for any position.

    Copy-on-write contract: with prefix sharing, a page table entry may
    alias a physical page other requests (or the host radix cache) also
    read.  The scatter itself cannot know refcounts, so the HOST must
    guarantee every tile overlapping a write range is exclusively held
    before the step — ``ServeLoop._ensure_writable`` forks shared pages
    (``PagePool.fork`` + :func:`paged_copy_page`) and repoints the table
    entry, making the first divergent write land in a private copy.

    ``scale`` selects the QUANTIZED pool form: the pool stores int8 /
    fp8_e4m3 pages and ``scale`` is the matching (n_pages * page, KV) f32
    per-row-per-head scale pool.  Each written row is quantized
    independently (:func:`repro.core.quant.quantize_rows` — symmetric absmax
    over head_dim, the scheme resolved from ``pool.dtype``) and its scale
    scatters through the SAME flat page-row index, so a page and its scales
    can never diverge — CoW copies, radix aliasing, rings, and shard
    transfers carry them as one unit.  Returns ``(pool, scale)`` in that
    form, the pool alone otherwise (the PR-9 graph, bit-identical)."""
    n_pages = pool.shape[0] // page
    vt = rows // page
    if ring_tiles is not None:
        vt = vt % ring_tiles
    vt = jnp.clip(vt, 0, page_table.shape[1] - 1)
    phys = jnp.take_along_axis(page_table, vt, axis=1)
    flat = phys * page + rows % page
    flat = jnp.where(valid & (phys < n_pages), flat, pool.shape[0])
    if scale is None:
        return pool.at[flat.reshape(-1)].set(
            new.astype(pool.dtype).reshape(-1, *new.shape[2:]), mode="drop"
        )
    qv, sc = quant.quantize_rows(new, pool.dtype)  # (B, C, KV, hd), (B, C, KV)
    pool = pool.at[flat.reshape(-1)].set(
        qv.reshape(-1, *qv.shape[2:]), mode="drop"
    )
    scale = scale.at[flat.reshape(-1)].set(
        sc.reshape(-1, *sc.shape[2:]).astype(scale.dtype), mode="drop"
    )
    return pool, scale


def paged_copy_page(caches: dict, src: jax.Array, dst: jax.Array, page: int) -> dict:
    """Copy physical page ``src``'s rows onto page ``dst`` in every pool leaf
    — the device half of a copy-on-write fork.  ``src``/``dst`` are traced
    scalars so the host engine compiles this once; positions the copied page
    holds that the forking request has not written yet are either identical
    prefix KV (shared tokens) or masked by the causal frontier until the
    request overwrites them."""

    def cp(c):  # (n_periods, n_pages * page, KV, hd)
        rows = jax.lax.dynamic_slice_in_dim(c, src * page, page, axis=1)
        return jax.lax.dynamic_update_slice_in_dim(c, rows, dst * page, axis=1)

    return jax.tree.map(cp, caches)


def apply_attention(
    aparams: dict,
    cfg: ModelConfig,
    x: jax.Array,
    rt: Runtime,
    *,
    causal: bool,
    positions: jax.Array,
    mode: str,  # train | encode | prefill | decode | mixed
    cache: dict | None = None,
    pos: jax.Array | None = None,
    kv_source: jax.Array | None = None,
    is_cross: bool = False,
    use_rope: bool = True,
    lengths: jax.Array | None = None,  # (B,) true prompt lengths (ragged prefill)
    attn_pattern: str | None = None,  # per-slot sparsity override (hybrid stacks)
    kv_live: int | None = None,  # static live-cache bound (sparse serve decode)
    ntok: jax.Array | None = None,  # (B,) valid chunk tokens (mixed step)
    page_table: jax.Array | None = None,  # (B, n_vtiles) paged-cache indirection
    page: int | None = None,  # tokens per page (static; = the kv tile)
):
    b, s, d = x.shape
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    spec = cfg.attention_spec
    if attn_pattern is not None:
        spec = dataclasses.replace(spec, pattern=attn_pattern)
    if is_cross or (cfg.sliding_window and spec.sparse):
        # patterns index absolute token positions: cross-attention KV has no
        # such positions, and ring caches store keys in mod-window order —
        # both fall back to the dense map (window sparsity still applies)
        spec = dataclasses.replace(spec, pattern="dense")

    q = _proj(aparams, cfg, x, "wq", h).reshape(b, s, h, hd)
    if is_cross and (mode == "decode" or kv_source is None):
        k_new = v_new = None  # cross-attention KV lives in the cache / pages
    else:
        src = kv_source if is_cross else x
        k_new = _proj(aparams, cfg, src, "wk", kv).reshape(b, src.shape[1], kv, hd)
        v_new = _proj(aparams, cfg, src, "wv", kv).reshape(b, src.shape[1], kv, hd)

    if cfg.qk_norm:
        q = rms_norm(q, aparams["q_norm"], cfg.norm_eps)
        if k_new is not None:
            k_new = rms_norm(k_new, aparams["k_norm"], cfg.norm_eps)
    if use_rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        if k_new is not None and not is_cross:
            k_new = apply_rope(k_new, positions, cfg.rope_theta)

    new_cache = None
    if page_table is not None and is_cross:
        # READ-ONLY shared page range: the encoder's cross KV was prefilled
        # once into refcounted pages (:func:`paged_encode`) and this request's
        # ``page_table`` merely aliases them — decode/chunk steps never write
        # a cross page, so copy-on-write can never trigger and every decoder
        # sharing the encoder output shares the physical pages outright.
        assert cache is not None and page is not None
        kg = gather_pages(cache["k"], page_table, cfg.enc_seq, page)
        vg = gather_pages(cache["v"], page_table, cfg.enc_seq, page)
        if mode == "decode":
            out = run_decode_attention(
                q[:, 0], kg, vg, None, spec=spec, rt=rt
            )[:, None]
        else:  # mixed chunk rows: every query reads the whole encoder output
            out = run_attention(q, kg, vg, spec=spec, causal=False, rt=rt)
        new_cache = cache  # pools untouched by construction
    elif page_table is not None:
        # paged KV cache: ``cache`` is the GLOBAL page pool (n_pages * page,
        # KV, hd) shared by every batch row; ``page_table`` (B, n_vtiles)
        # maps each row's virtual kv tiles to physical pages.  Writes are
        # page-table-indirected masked scatters (invalid / unallocated rows
        # drop), reads go through the translated live-tile tables — the same
        # liveness maps as the contiguous engine, one extra indirection.
        # A sliding-window config turns the table into a MOD-WINDOW RING:
        # absolute tile j lives in slot j % ring_tiles, positions are
        # unbounded, and the fine masks window on absolute positions — the
        # paged replacement for the contiguous ``_ring_place`` path.
        assert cache is not None and pos is not None and page is not None
        ring_tiles = ring_window = None
        if cfg.sliding_window:
            _, _, _, sw = sparsity.canonical_pattern(
                spec.pattern, spec.pattern_arg, True, None
            )
            ring_window = min(cfg.sliding_window, sw) if sw else cfg.sliding_window
            ring_tiles = page_table.shape[1]
            spec = dataclasses.replace(spec, pattern="dense")
        kc, vc = cache["k"], cache["v"]
        # quantized pools carry per-(row, kv_head) scale leaves in the same
        # flat page layout as K/V — absent for bf16 (see repro.core.quant)
        ksc, vsc = cache.get("k_scale"), cache.get("v_scale")

        def write(kc, vc, ksc, vsc, rows, valid, ring=None):
            if ksc is None:
                kc = _paged_kv_write(kc, k_new, rows, valid, page_table, page, ring)
                vc = _paged_kv_write(vc, v_new, rows, valid, page_table, page, ring)
                return kc, vc, None, None
            kc, ksc = _paged_kv_write(
                kc, k_new, rows, valid, page_table, page, ring, scale=ksc
            )
            vc, vsc = _paged_kv_write(
                vc, v_new, rows, valid, page_table, page, ring, scale=vsc
            )
            return kc, vc, ksc, vsc

        def pack(kc, vc, ksc, vsc):
            out = {"k": kc, "v": vc}
            if ksc is not None:
                out["k_scale"], out["v_scale"] = ksc, vsc
            return out

        if mode == "mixed":
            assert ntok is not None
            rows = pos[:, None] + jnp.arange(s, dtype=jnp.int32)  # (B, C)
            valid = jnp.arange(s)[None, :] < ntok[:, None]
            kc, vc, ksc, vsc = write(kc, vc, ksc, vsc, rows, valid, ring_tiles)
            new_cache = pack(kc, vc, ksc, vsc)
            out = run_paged_chunk_attention(
                q, kc, vc, pos, ntok, page_table, page=page, spec=spec,
                rt=rt, kv_live=kv_live, ring_window=ring_window,
                ring_tiles=ring_tiles, k_scale=ksc, v_scale=vsc,
            )
        elif mode == "decode":
            # every row writes at its own position; a retired slot's page
            # table is all-sentinel so its (garbage) write drops, and a
            # mid-prompt row's write is overwritten by its next chunk before
            # any consequential read — same discipline as the contiguous
            # wave, with the page table enforcing ownership
            rows = pos[:, None]  # (B, 1)
            valid = jnp.ones_like(rows, bool)
            kc, vc, ksc, vsc = write(kc, vc, ksc, vsc, rows, valid, ring_tiles)
            new_cache = pack(kc, vc, ksc, vsc)
            out = run_paged_decode_attention(
                q[:, 0], kc, vc, pos + 1, page_table, page=page, spec=spec,
                rt=rt, kv_live=kv_live, ring_window=ring_window,
                ring_tiles=ring_tiles, k_scale=ksc, v_scale=vsc,
            )[:, None]
        elif mode == "prefill":
            if ring_tiles is not None:
                raise ValueError(
                    "mod-window paged caches stream prefill through the "
                    "chunk path; monolithic prefill would wrap the ring"
                )
            rows = jnp.broadcast_to(
                jnp.arange(s, dtype=jnp.int32)[None, :], (b, s)
            )
            ln = (
                lengths if lengths is not None else jnp.full((b,), s, jnp.int32)
            )
            valid = jnp.arange(s)[None, :] < ln[:, None]
            kc, vc, ksc, vsc = write(kc, vc, ksc, vsc, rows, valid)
            new_cache = pack(kc, vc, ksc, vsc)
            out = run_paged_prefill_attention(
                q, k_new, v_new, kc, vc, page_table, page=page, spec=spec,
                rt=rt, k_scale=ksc, v_scale=vsc,
            )
        else:
            raise ValueError(f"paged caches have no {mode!r} mode")
    elif mode == "mixed":
        # mixed chunked-prefill step: row b consumes ntok[b] tokens at
        # absolute positions pos[b] .. pos[b]+ntok[b]-1 (0 = idle slot,
        # 1 = decode, >1 = prompt chunk) — the chunk KV is scattered straight
        # into the shared cache BEFORE attention (in-chunk causal self-
        # attention reads its own keys), and the per-row causal frontier
        # inside run_chunk_attention doubles as the written-cache mask.
        assert cache is not None and pos is not None and ntok is not None
        assert not is_cross, "mixed steps are self-attention only"
        assert not cfg.sliding_window, (
            "mixed chunked prefill needs absolute cache positions; ring "
            "caches go through the admission-prefill path"
        )
        cache_len = cache["k"].shape[1]
        rows = pos[:, None] + jnp.arange(s, dtype=jnp.int32)  # (B, C)
        valid = jnp.arange(s)[None, :] < ntok[:, None]
        # invalid rows scatter out of bounds and are dropped — idle / budget-
        # starved / decode rows never clobber cache rows they don't own
        rows = jnp.where(valid, rows, cache_len)
        upd = jax.vmap(lambda c, n, r: c.at[r].set(n, mode="drop"))
        kc = upd(cache["k"], k_new.astype(cache["k"].dtype), rows)
        vc = upd(cache["v"], v_new.astype(cache["v"].dtype), rows)
        new_cache = {"k": kc, "v": vc}
        out = run_chunk_attention(
            q, kc, vc, pos, ntok, spec=spec, rt=rt, kv_live=kv_live
        )
    elif mode == "decode":
        assert cache is not None and pos is not None
        if not is_cross:  # self-attention: append the token's kv at pos
            cache_len = cache["k"].shape[1]
            wpos = pos % cache_len if cfg.sliding_window else pos
            kn = k_new.astype(cache["k"].dtype)
            vn = v_new.astype(cache["v"].dtype)
            if jnp.ndim(pos) == 0:  # batch-wide position (static batch)
                kc = jax.lax.dynamic_update_slice_in_dim(cache["k"], kn, wpos, axis=1)
                vc = jax.lax.dynamic_update_slice_in_dim(cache["v"], vn, wpos, axis=1)
            else:  # ragged: every request writes at its own position
                upd = jax.vmap(
                    lambda c, n, p: jax.lax.dynamic_update_slice_in_dim(c, n, p, axis=0)
                )
                kc, vc = upd(cache["k"], kn, wpos), upd(cache["v"], vn, wpos)
            new_cache = {"k": kc, "v": vc}
            # live-KV mask (scalar or (B,)): rows beyond min(pos+1, klen) are
            # unwritten — for a sliding-window ring cache their zero-init keys
            # would otherwise score e^0 in the softmax
            cur = jnp.minimum(pos + 1, cache_len)
            out = run_decode_attention(
                q[:, 0], kc, vc, cur, spec=spec, rt=rt,
                kv_live=None if cfg.sliding_window else kv_live,
            )
        else:  # cross-attention: static KV from the encoder pass
            new_cache = cache
            out = run_decode_attention(
                q[:, 0], cache["k"], cache["v"], None, spec=spec, rt=rt
            )
        out = out[:, None]
    else:
        win = cfg.sliding_window if causal else None
        out = run_attention(
            q, k_new, v_new, spec=spec,
            causal=causal and not is_cross, window=win, rt=rt,
        )
        if mode == "prefill":
            kc, vc = k_new, v_new
            win = cfg.sliding_window
            if not is_cross and win and kc.shape[1] > win:
                # keep only the ring window — otherwise the layer scan stacks
                # the full-seq KV for every layer before the final slice
                # (found via the 2-pod mixtral prefill: 120 GiB of temps).
                # Ring (mod-window) order, per-row length: the decode write at
                # pos % klen stays phase-aligned for any prompt length
                ln = (
                    lengths
                    if lengths is not None
                    else jnp.full((b,), kc.shape[1], jnp.int32)
                )
                kc, vc = _ring_place(kc, ln, win), _ring_place(vc, ln, win)
            new_cache = {"k": kc, "v": vc}

    out = _proj(aparams, cfg, out.reshape(b, s, h * hd), "wo", h)
    return out, new_cache


def apply_ffn(fparams: dict, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    s1 = api.LinearSpec(cfg.d_model, cfg.d_ff, cfg.butterfly.for_site("ffn"))
    s2 = api.LinearSpec(cfg.d_ff, cfg.d_model, cfg.butterfly.for_site("ffn"))
    h = pp.apply_linear_p(fparams["w1"], s1, x)
    if cfg.act == "swiglu":
        h = silu(h) * pp.apply_linear_p(fparams["w3"], s1, x)
    else:
        h = gelu(h)
    return pp.apply_linear_p(fparams["w2"], s2, h)


def apply_slot(
    slot: Slot,
    sparams: dict,
    cfg: ModelConfig,
    x: jax.Array,
    rt: Runtime,
    *,
    mode: str,
    positions: jax.Array,
    cache: dict | None = None,
    pos: jax.Array | None = None,
    enc_out: jax.Array | None = None,
    causal: bool = True,
    lengths: jax.Array | None = None,
    kv_live: int | None = None,
    ntok: jax.Array | None = None,
    page_table: jax.Array | None = None,
    page: int | None = None,
    cross_table: jax.Array | None = None,
):
    """One layer: pre-norm mixer + (optional cross-attn) + pre-norm FFN."""
    aux = jnp.zeros((), jnp.float32)
    new_cache: dict = {}
    hmix = _norm(sparams["mixer_norm"], cfg, x)
    if slot.mixer == "attn":
        mix, c = apply_attention(
            sparams["attn"], cfg, hmix, rt, causal=causal, positions=positions,
            mode=mode, cache=None if cache is None else cache.get("attn"), pos=pos,
            lengths=lengths, attn_pattern=slot.attn_pattern, kv_live=kv_live,
            ntok=ntok, page_table=page_table, page=page,
        )
        if c is not None:
            new_cache["attn"] = c
    elif slot.mixer == "mamba":
        if mode == "decode":
            mix, c = ssm_mod.mamba_decode(sparams["mamba"], cfg, hmix, cache["mamba"], rt)
            new_cache["mamba"] = c
        elif mode == "prefill":
            mix, c = ssm_mod.apply_mamba(sparams["mamba"], cfg, hmix, rt, return_cache=True)
            new_cache["mamba"] = c
        else:
            mix = ssm_mod.apply_mamba(sparams["mamba"], cfg, hmix, rt)
    elif slot.mixer == "fft":
        mix = fnet_mixing(hmix)  # AT-all replacement: parameter-free token mixing
    else:
        raise ValueError(slot.mixer)
    x = x + mix

    if "cross" in sparams and (
        enc_out is not None or mode == "decode" or cross_table is not None
    ):
        hx = _norm(sparams["cross_norm"], cfg, x)
        cmix, cc = apply_attention(
            sparams["cross"], cfg, hx, rt, causal=False, positions=positions,
            mode=mode, cache=None if cache is None else cache.get("cross"), pos=pos,
            kv_source=enc_out, is_cross=True, use_rope=False,
            page_table=cross_table, page=page,
        )
        if cc is not None:
            new_cache["cross"] = cc
        x = x + cmix

    if slot.ffn != "none":
        hffn = _norm(sparams["ffn_norm"], cfg, x)
        if slot.ffn == "moe":
            y, aux = moe_mod.apply_moe(
                sparams["moe"], cfg, hffn, rt, dropless=(mode != "train")
            )
        else:
            y = apply_ffn(sparams["ffn"], cfg, hffn)
        x = x + y
    return x, new_cache, aux


def _boundary(x, rt, cfg=None):
    s = x.shape[1]
    tp = 1
    if rt.mesh is not None and "model" in rt.mesh.axis_names:
        tp = dict(zip(rt.mesh.axis_names, rt.mesh.devices.shape))["model"]
    sp = cfg is None or cfg.boundary_mode == "sp"
    axes = ("batch", "seq" if sp and s % max(tp, 1) == 0 and s > 1 else None, None)
    return constrain(x, axes, rt.mesh, rt.rules)


def run_stack(
    layer_params: dict,
    cfg: ModelConfig,
    x: jax.Array,
    rt: Runtime,
    *,
    slots: tuple[Slot, ...],
    mode: str,
    positions: jax.Array,
    caches: dict | None = None,  # stacked (n_periods, ...) per slot
    pos: jax.Array | None = None,
    enc_out: jax.Array | None = None,
    causal: bool = True,
    lengths: jax.Array | None = None,  # (B,) ragged prompt lengths (prefill)
    kv_live: int | None = None,  # static live-cache bound (sparse serve decode)
    ntok: jax.Array | None = None,  # (B,) valid chunk tokens (mixed step)
    page_table: jax.Array | None = None,  # (B, n_vtiles) paged-cache tables
    page: int | None = None,  # tokens per page (static)
    cross_table: jax.Array | None = None,  # (B, n_ctiles) shared cross pages
):
    """Scan the periodic layer pattern.  Returns (x, new_caches, aux_sum)."""

    def body(carry, per):
        x, aux = carry
        p_params, p_cache = per
        new_cache = {}
        for j, slot in enumerate(slots):
            key = f"slot{j:02d}"
            x = _boundary(x, rt, cfg)
            x, c, a = apply_slot(
                slot, p_params[key], cfg, x, rt, mode=mode, positions=positions,
                cache=None if p_cache is None else p_cache[key], pos=pos,
                enc_out=enc_out, causal=causal, lengths=lengths, kv_live=kv_live,
                ntok=ntok, page_table=page_table, page=page,
                cross_table=cross_table,
            )
            new_cache[key] = c
            aux = aux + a
        return (x, aux), new_cache

    if cfg.remat and mode == "train":
        body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)

    if cfg.unroll_layers:  # cost-probe mode: see ModelConfig.unroll_layers
        n = jax.tree.leaves(layer_params)[0].shape[0]
        carry = (x, jnp.zeros((), jnp.float32))
        outs = []
        for i in range(n):
            p_i = jax.tree.map(lambda a: a[i], layer_params)
            c_i = None if caches is None else jax.tree.map(lambda a: a[i], caches)
            carry, nc = body(carry, (p_i, c_i))
            outs.append(nc)
        (x, aux) = carry
        new_caches = jax.tree.map(lambda *xs: jnp.stack(xs), *outs) if outs else {}
        return x, new_caches, aux

    if caches is None:
        (x, aux), new_caches = jax.lax.scan(
            lambda c, p: body(c, (p, None)), (x, jnp.zeros((), jnp.float32)), layer_params
        )
    else:
        (x, aux), new_caches = jax.lax.scan(
            body, (x, jnp.zeros((), jnp.float32)), (layer_params, caches)
        )
    return x, new_caches, aux


# --------------------------------------------------------------------------
# Top level: embed -> stack -> head
# --------------------------------------------------------------------------


def embed_tokens(params: Params, cfg: ModelConfig, tokens: jax.Array, rt: Runtime):
    # cast-then-gather: the distributed gather (and its psum) moves bf16, not
    # the f32 master copy
    table = params["embed"].astype(cfg.dtype)
    return jnp.take(table, tokens, axis=0)


def run_encoder(params: Params, cfg: ModelConfig, frames: jax.Array, rt: Runtime):
    """Stub-frontend encoder (whisper): frames are precomputed embeddings."""
    x = frames.astype(cfg.dtype)
    enc_slot = Slot("fft" if cfg.butterfly.fft_attention else "attn", "dense")
    positions = jnp.arange(x.shape[1])
    x, _, _ = run_stack(
        params["encoder"]["layers"], cfg, x, rt, slots=(enc_slot,),
        mode="encode", positions=positions, causal=False,
    )
    nf = jax.tree.map(lambda a: a[0], params["encoder"]["final_norm"])
    return _norm(nf, cfg, x)


def forward(
    params: Params,
    cfg: ModelConfig,
    batch: dict,
    rt: Runtime,
    *,
    mode: str = "train",
):
    """Returns (logits, aux).  batch: tokens (B,S) [+ img_embeds | frames]."""
    tokens = batch["tokens"]
    x = embed_tokens(params, cfg, tokens, rt)
    if cfg.n_img_tokens and "img_embeds" in batch:
        x = jnp.concatenate([batch["img_embeds"].astype(x.dtype), x], axis=1)
    enc_out = None
    if cfg.family == "encdec":
        enc_out = run_encoder(params, cfg, batch["frames"], rt)
    positions = jnp.arange(x.shape[1])
    x = _boundary(x, rt, cfg)
    x, _, aux = run_stack(
        params["layers"], cfg, x, rt, slots=cfg.period_slots, mode=mode,
        positions=positions, enc_out=enc_out, causal=cfg.causal,
    )
    nf = jax.tree.map(lambda a: a[0], params["final_norm"])
    x = _norm(nf, cfg, x)
    if cfg.n_img_tokens and "img_embeds" in batch:
        x = x[:, batch["img_embeds"].shape[1] :]
    logits = x @ params["head"].astype(x.dtype)
    return logits, aux


def loss_fn(params: Params, cfg: ModelConfig, batch: dict, rt: Runtime):
    """Cross entropy without materialising f32 full-vocab tensors.

    Logits stay in the activation dtype; the exp-sum accumulates in f32
    *inside* the reduction (fused convert), and the label logit is gathered
    per-token before upcasting — the backward pass then scatters a bf16 (not
    f32) cotangent.  This halves+ the dominant memory-roofline term of every
    train cell (found via the qwen3 dry-run probe: three 2.3 GiB f32 copies).
    """
    logits, aux = forward(params, cfg, batch, rt, mode="train")
    labels = batch["labels"]
    mask = (labels >= 0).astype(jnp.float32)
    lmax = jax.lax.stop_gradient(jnp.max(logits, axis=-1, keepdims=True))
    shifted = logits - lmax  # activation dtype
    sumexp = jnp.sum(jnp.exp(shifted), axis=-1, dtype=jnp.float32)
    lse = jnp.log(sumexp) + lmax[..., 0].astype(jnp.float32)
    ll = jnp.take_along_axis(shifted, jnp.maximum(labels, 0)[..., None], axis=-1)
    ll = ll[..., 0].astype(jnp.float32) + lmax[..., 0].astype(jnp.float32)
    nll = (lse - ll) * mask
    ntok = jnp.maximum(mask.sum(), 1.0)
    loss = nll.sum() / ntok
    zloss = 1e-4 * ((lse * mask) ** 2).sum() / ntok
    total = loss + zloss + cfg.router_aux_coef * aux
    return total, {"loss": loss, "zloss": zloss, "aux": aux, "ntok": ntok}


# --------------------------------------------------------------------------
# Serving: prefill + decode
# --------------------------------------------------------------------------


def prefill(
    params: Params,
    cfg: ModelConfig,
    batch: dict,
    rt: Runtime,
    cache_len: int,
    *,
    lengths: jax.Array | None = None,
):
    """Run the prompt, return (last-token logits, caches padded to cache_len).

    ``lengths`` (B,) enables the ragged form: tokens are *right*-padded (real
    tokens at 0..L-1, so RoPE positions and the causal mask are exact — pad
    tokens sit strictly in the future of every real token and are never
    attended), the returned logits are gathered at each row's own last real
    token, and sliding-window caches are ring-placed per row.  Pad-token KV
    written beyond a row's length is left in the cache; the decode-side
    per-row ``cur_len`` mask (min(pos+1, klen)) never reads it and the first
    decode steps overwrite it in place.  Stateful (mamba) mixers integrate the
    whole padded sequence, so ragged lengths require attention-only stacks.
    """
    tokens = batch["tokens"]
    x = embed_tokens(params, cfg, tokens, rt)
    if cfg.n_img_tokens and "img_embeds" in batch:
        assert lengths is None, "ragged prefill does not support image prefixes"
        x = jnp.concatenate([batch["img_embeds"].astype(x.dtype), x], axis=1)
    enc_out = None
    if cfg.family == "encdec":
        enc_out = run_encoder(params, cfg, batch["frames"], rt)
    positions = jnp.arange(x.shape[1])
    x = _boundary(x, rt, cfg)
    x, caches, _ = run_stack(
        params["layers"], cfg, x, rt, slots=cfg.period_slots, mode="prefill",
        positions=positions, enc_out=enc_out, causal=cfg.causal, lengths=lengths,
    )
    nf = jax.tree.map(lambda a: a[0], params["final_norm"])
    x = _norm(nf, cfg, x)
    if lengths is None:
        last = x[:, -1]
    else:  # per-request last real token
        idx = jnp.clip(lengths.astype(jnp.int32) - 1, 0, x.shape[1] - 1)
        last = jnp.take_along_axis(x, idx[:, None, None], axis=1)[:, 0]
    logits = last @ params["head"].astype(x.dtype)
    caches = _pad_kv_caches(caches, cfg, cache_len)
    return logits, caches


def _pad_kv_caches(caches, cfg: ModelConfig, cache_len: int):
    def fix(slot_cache):
        out = {}
        for name, c in slot_cache.items():
            if name in ("attn",) and c:
                k, v = c["k"], c["v"]
                tgt = min(cache_len, cfg.sliding_window) if cfg.sliding_window else cache_len
                if k.shape[2] < tgt:
                    padw = [(0, 0), (0, 0), (0, tgt - k.shape[2]), (0, 0), (0, 0)]
                    k, v = jnp.pad(k, padw), jnp.pad(v, padw)
                elif k.shape[2] > tgt:
                    k, v = k[:, :, -tgt:], v[:, :, -tgt:]
                out[name] = {"k": k, "v": v}
            else:
                out[name] = c
        return out

    return {key: fix(slot) for key, slot in caches.items()}


def cache_specs(cfg: ModelConfig, batch: int, cache_len: int) -> dict:
    """ParamSpec tree for the decode caches (dry-run stand-ins + shardings).

    Mirrors exactly the structure `run_stack(mode="prefill")` emits, stacked
    over periods.  Attention KV caches shard (batch -> data, seq -> model);
    mamba states shard heads over model when divisible.
    """
    n = cfg.n_periods
    kv, hd = cfg.n_kv_heads, cfg.head_dim
    klen = min(cache_len, cfg.sliding_window) if cfg.sliding_window else cache_len
    out: dict = {}
    for j, slot in enumerate(cfg.period_slots):
        sc: dict = {}
        if slot.mixer == "attn":
            kvspec = ParamSpec(
                (n, batch, klen, kv, hd), (None, "batch", "seq", "tp", None)
            )
            sc["attn"] = {"k": kvspec, "v": kvspec}
        elif slot.mixer == "mamba":
            d_xbc = cfg.d_inner + 2 * cfg.ssm_groups * cfg.ssm_state
            sc["mamba"] = {
                "conv": ParamSpec(
                    (n, batch, cfg.ssm_conv - 1, d_xbc), (None, "batch", None, "tp")
                ),
                "state": ParamSpec(
                    (n, batch, cfg.ssm_heads, cfg.ssm_state, cfg.ssm_head_dim),
                    (None, "batch", "tp", None, None),
                ),
            }
        if cfg.family == "encdec":
            ckv = ParamSpec(
                (n, batch, cfg.enc_seq, kv, hd), (None, "batch", "seq", "tp", None)
            )
            sc["cross"] = {"k": ckv, "v": ckv}
        out[f"slot{j:02d}"] = sc
    return out


def paged_pool_specs(
    cfg: ModelConfig,
    n_pages: int,
    page: int,
    cross_pages: int | None = None,
    kv_dtype: str = "bf16",
) -> dict:
    """ParamSpec tree for the paged KV cache: one GLOBAL page pool per
    attention slot, (n_periods, n_pages * page, KV, hd) — no batch axis, no
    per-slot ``cache_len`` reservation.  Resident HBM is the pool; per-request
    footprint is the pages its page table holds, so capacity prices at live
    tiles instead of ``batch x cache_len``.  Sliding-window configs need no
    special layout here — the ring modulus lives in the page TABLE
    (mod-window slots), the pool is just pages.  Encoder-decoder stacks add a
    per-slot ``cross`` pool of ``cross_pages`` pages holding the encoder
    output's KV as read-only shared page ranges.  Pools shard KV heads over
    the model axis AND pages over the ``pages`` mesh axis: GSPMD partitions
    the row axis contiguously, so shard ``s`` of ``k`` owns physical pages
    ``[s * n_pages/k, (s+1) * n_pages/k)`` — the same ranges the host-side
    :class:`repro.launch.serve.PagePool` shards its free lists over, which
    is what lets :func:`repro.core.sparsity.translate_tables` rebase a
    shard's tables into its local page range.  A mesh without a ``pages``
    axis (every single-chip test mesh) replicates the pools, the old
    behaviour.  The cross pool stays replicated — it is read-only and
    shared, its capacity is not the scaling axis.

    ``kv_dtype`` != 'bf16' adds float32 ``k_scale`` / ``v_scale`` leaves
    shaped (n_periods, n_pages * page, KV) to each self-attention pool —
    one symmetric scale per (row, kv_head), sharded and paged exactly like
    the rows they reconstruct (:mod:`repro.core.quant`).  Cross pools stay
    unquantized: they are written once at encode and read-only shared, so
    capacity pressure (the quantization motive) never lands on them."""
    n = cfg.n_periods
    kv, hd = cfg.n_kv_heads, cfg.head_dim
    out: dict = {}
    for j, slot in enumerate(cfg.period_slots):
        sc: dict = {}
        if slot.mixer == "attn":
            kvspec = ParamSpec(
                (n, n_pages * page, kv, hd), (None, "pages", "tp", None)
            )
            sc["attn"] = {"k": kvspec, "v": kvspec}
            if kv_dtype != "bf16":
                quant.validate_kv_dtype(kv_dtype)
                sspec = ParamSpec((n, n_pages * page, kv), (None, "pages", "tp"))
                sc["attn"]["k_scale"] = sspec
                sc["attn"]["v_scale"] = sspec
        elif slot.mixer == "mamba":
            raise ValueError("paged serving requires attention-only stacks")
        if cfg.family == "encdec":
            cspec = ParamSpec(
                (n, (cross_pages or n_pages) * page, kv, hd),
                (None, None, "tp", None),
            )
            sc["cross"] = {"k": cspec, "v": cspec}
        out[f"slot{j:02d}"] = sc
    return out


def paged_prefill(
    params: Params,
    cfg: ModelConfig,
    batch: dict,
    rt: Runtime,
    *,
    caches: dict,
    page_table: jax.Array,
    page: int,
    lengths: jax.Array | None = None,
):
    """Admission prefill into a PAGED cache: the prompt's KV is scattered
    through the page table into the global pool and attention reads it back
    through the translated block map (batch-1; the page table is one row).
    Returns (last-real-token logits, updated pools) — no contiguous wave, no
    cache insert: the pool already holds the request's pages."""
    if cfg.sliding_window:
        raise ValueError(
            "mod-window paged caches stream prefill through the chunk path"
        )
    if cfg.family == "encdec":
        raise ValueError(
            "encdec paged admission streams decoder chunks after paged_encode"
        )
    tokens = batch["tokens"]
    x = embed_tokens(params, cfg, tokens, rt)
    positions = jnp.arange(x.shape[1])
    x = _boundary(x, rt, cfg)
    x, caches, _ = run_stack(
        params["layers"], cfg, x, rt, slots=cfg.period_slots, mode="prefill",
        positions=positions, caches=caches, causal=cfg.causal, lengths=lengths,
        page_table=page_table, page=page,
        pos=jnp.zeros((tokens.shape[0],), jnp.int32),
    )
    nf = jax.tree.map(lambda a: a[0], params["final_norm"])
    x = _norm(nf, cfg, x)
    if lengths is None:
        last = x[:, -1]
    else:
        idx = jnp.clip(lengths.astype(jnp.int32) - 1, 0, x.shape[1] - 1)
        last = jnp.take_along_axis(x, idx[:, None, None], axis=1)[:, 0]
    logits = last @ params["head"].astype(x.dtype)
    return logits, caches


def paged_encode(
    params: Params,
    cfg: ModelConfig,
    frames: jax.Array,
    rt: Runtime,
    *,
    caches: dict,
    cross_table: jax.Array,
    page: int,
):
    """Run the encoder ONCE and scatter every decoder slot's cross-attention
    KV into the shared cross page pool through ``cross_table`` (one row of
    physical page ids covering ``cfg.enc_seq`` positions).

    The written pages are READ-ONLY for the rest of their life: every decoder
    request sharing this encoder input aliases them via ``PagePool.retain``,
    decode never writes a cross page, so copy-on-write can never trigger and
    cross-attention prefix sharing falls out of the refcounts for free.
    Returns the updated pools (non-cross leaves untouched)."""
    enc_out = run_encoder(params, cfg, frames, rt)
    b, s_enc = enc_out.shape[0], enc_out.shape[1]
    kv, hd = cfg.n_kv_heads, cfg.head_dim
    rows = jnp.broadcast_to(
        jnp.arange(s_enc, dtype=jnp.int32)[None, :], (b, s_enc)
    )
    valid = jnp.ones((b, s_enc), bool)
    ct = jnp.asarray(cross_table, jnp.int32).reshape(b, -1)
    new_caches = dict(caches)
    for j, _slot in enumerate(cfg.period_slots):
        key = f"slot{j:02d}"
        slot_params = params["layers"][key]
        if "cross" not in slot_params or "cross" not in caches[key]:
            continue
        kp, vp = caches[key]["cross"]["k"], caches[key]["cross"]["v"]
        for i in range(cfg.n_periods):
            ap = jax.tree.map(lambda a: a[i], slot_params["cross"])
            k_new = _proj(ap, cfg, enc_out, "wk", kv).reshape(b, s_enc, kv, hd)
            v_new = _proj(ap, cfg, enc_out, "wv", kv).reshape(b, s_enc, kv, hd)
            if cfg.qk_norm:
                k_new = rms_norm(k_new, ap["k_norm"], cfg.norm_eps)
            kp = kp.at[i].set(_paged_kv_write(kp[i], k_new, rows, valid, ct, page))
            vp = vp.at[i].set(_paged_kv_write(vp[i], v_new, rows, valid, ct, page))
        new_caches[key] = {**caches[key], "cross": {"k": kp, "v": vp}}
    return new_caches


def decode_step(
    params: Params,
    cfg: ModelConfig,
    caches: dict,
    tokens: jax.Array,
    pos: jax.Array,
    rt: Runtime,
    *,
    kv_live: int | None = None,
    page_table: jax.Array | None = None,
    page: int | None = None,
    cross_table: jax.Array | None = None,
):
    """One token for the whole batch.  tokens: (B, 1); pos: scalar int32
    (static batch) or (B,) int32 per-request positions (ragged batch —
    RoPE angles, cache write slots, and live-KV masks all go per row).

    ``kv_live`` (static) bounds every row's live cache length — attention
    streams only the first ``kv_live`` cache rows instead of the whole padded
    cache (the serve engine passes its bucketed ``max(pos)+1``)."""
    x = embed_tokens(params, cfg, tokens, rt)
    pos = jnp.asarray(pos, jnp.int32)
    if pos.ndim == 0:
        positions = jnp.full((x.shape[0], 1), pos, jnp.int32)
    else:
        positions = pos[:, None]
    x, new_caches, _ = run_stack(
        params["layers"], cfg, x, rt, slots=cfg.period_slots, mode="decode",
        positions=positions, caches=caches, pos=pos, causal=cfg.causal,
        kv_live=kv_live, page_table=page_table, page=page,
        cross_table=cross_table,
    )
    nf = jax.tree.map(lambda a: a[0], params["final_norm"])
    x = _norm(nf, cfg, x)
    logits = x[:, 0] @ params["head"].astype(x.dtype)
    return logits, new_caches


def mixed_step(
    params: Params,
    cfg: ModelConfig,
    caches: dict,
    tokens: jax.Array,
    pos: jax.Array,
    ntok: jax.Array,
    rt: Runtime,
    *,
    kv_live: int | None = None,
    page_table: jax.Array | None = None,
    page: int | None = None,
    cross_table: jax.Array | None = None,
):
    """One mixed chunked-prefill/decode step for the whole batch.

    tokens: (B, C); pos: (B,) absolute position of each row's first token;
    ntok: (B,) valid tokens per row — 0 (idle slot), 1 (decode), 2..C (prompt
    chunk).  Row b's tokens land at cache positions ``pos[b]..pos[b]+ntok-1``
    and every query attends its own causal prefix, so prompt chunks stream
    into the shared cache while decode rows take their next token in the SAME
    compiled step — decode throughput is never gated on a prefill finishing
    (the request-level {Load | Cal | Store} overlap of §V-A).

    Returns (logits (B, vocab) at each row's LAST valid token — the sampling
    row for decode rows and for the chunk that completes a prompt — and the
    new caches).  Rows with ntok == 0 return garbage logits the engine never
    reads.  ``kv_live`` bounds the hottest row's frontier (bucketed, static).
    """
    b, c = tokens.shape
    x = embed_tokens(params, cfg, tokens, rt)
    pos = jnp.asarray(pos, jnp.int32)
    ntok = jnp.asarray(ntok, jnp.int32)
    positions = pos[:, None] + jnp.arange(c, dtype=jnp.int32)  # (B, C)
    x = _boundary(x, rt, cfg)
    x, new_caches, _ = run_stack(
        params["layers"], cfg, x, rt, slots=cfg.period_slots, mode="mixed",
        positions=positions, caches=caches, pos=pos, causal=cfg.causal,
        kv_live=kv_live, ntok=ntok, page_table=page_table, page=page,
        cross_table=cross_table,
    )
    nf = jax.tree.map(lambda a: a[0], params["final_norm"])
    x = _norm(nf, cfg, x)
    idx = jnp.clip(ntok - 1, 0, c - 1)
    last = jnp.take_along_axis(x, idx[:, None, None], axis=1)[:, 0]
    logits = last @ params["head"].astype(x.dtype)
    return logits, new_caches
