"""Shared layers: norms, RoPE, attention execution forms + spec dispatch.

Two attention execution forms live behind :class:`repro.core.attention.
AttentionSpec` (selected per model via ``ModelConfig.attention``):

* ``xla_chunked`` — :func:`chunked_attention` here: queries are processed in
  static *prefix chunks*, each attending exactly its causal KV prefix (plus a
  masked diagonal block).  This keeps compiled FLOPs within ~(1 + 1/n_chunks)
  of the causal optimum — important because the roofline terms are read off
  the compiled HLO — and bounds transient score memory to (chunk x prefix).
  Sliding windows (mixtral) drop whole out-of-window chunks statically.
  Score matrices still round-trip HBM: this is the paper's Fig. 2 baseline.
* ``flash_kernel`` — the fused Pallas online-softmax kernel
  (:mod:`repro.kernels.flash_attention`): score tiles stay VMEM-resident.

:func:`run_attention` / :func:`run_decode_attention` are the dispatchers the
model runtime calls.
"""

from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.core import quant, sparsity
from repro.core.attention import AttentionSpec, truncate_kv_live
from repro.distributed.sharding import constrain

__all__ = [
    "Runtime",
    "rms_norm",
    "layer_norm",
    "apply_rope",
    "chunked_attention",
    "decode_attention",
    "chunk_attention_cache",
    "run_attention",
    "run_decode_attention",
    "run_chunk_attention",
    "gather_pages",
    "run_paged_prefill_attention",
    "run_paged_decode_attention",
    "run_paged_chunk_attention",
    "silu",
    "gelu",
]


@dataclasses.dataclass(frozen=True)
class Runtime:
    """Per-run execution context: mesh + resolved parallelism modes."""

    mesh: Mesh | None = None
    attn_mode: str = "tp"  # tp (head-sharded) | cp (sequence-sharded)
    moe_mode: str = "ep"  # ep | tp
    interpret: bool = True  # Pallas kernels in interpret mode (CPU host)
    rules: dict | None = None  # sharding-rule override (pure_dp lever)


def rms_norm(x: jax.Array, w: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)).astype(x.dtype) * (1.0 + w.astype(x.dtype))


def layer_norm(x: jax.Array, w: jax.Array, b: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return y.astype(x.dtype) * (1.0 + w.astype(x.dtype)) + b.astype(x.dtype)


def silu(x):
    return x * jax.nn.sigmoid(x)


def gelu(x):
    return jax.nn.gelu(x, approximate=True)


def _rope_angles(positions: jax.Array, head_dim: int, theta: float) -> tuple:
    half = head_dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * freqs  # (..., S, half)
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(
    x: jax.Array, positions: jax.Array, theta: float = 1e4
) -> jax.Array:
    """x: (B, S, H, hd); positions: (S,) or (B, S)."""
    hd = x.shape[-1]
    cos, sin = _rope_angles(positions, hd, theta)
    if cos.ndim == 2:  # (S, half) -> broadcast batch/heads
        cos, sin = cos[None, :, None, :], sin[None, :, None, :]
    else:  # (B, S, half)
        cos, sin = cos[:, :, None, :], sin[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    y = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return y.astype(x.dtype)


def _q_axes(rt: Runtime, chunk_len: int, heads: int):
    tp = 1
    if rt.mesh is not None and "model" in rt.mesh.axis_names:
        tp = dict(zip(rt.mesh.axis_names, rt.mesh.devices.shape))["model"]
    if rt.attn_mode == "tp" and heads % max(tp, 1) == 0:
        return ("batch", None, "tp", None)
    if chunk_len % max(tp, 1) == 0:
        return ("batch", "seq", None, None)
    return ("batch", None, None, None)


def chunked_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int | None = None,
    chunk: int = 2048,
    rt: Runtime = Runtime(),
    f32_softmax: bool = True,
    pattern_mask: np.ndarray | None = None,
) -> jax.Array:
    """Prefix-chunked attention (the ``xla_chunked`` reference form).
    q: (B, S, H, hd); k, v: (B, S, KV, hd).

    ``pattern_mask`` is the static (S_q, S_kv) token-level expansion of a
    block-sparsity map — *mask-only* on this backend: dead blocks are still
    computed and round-tripped through HBM, which is exactly the paper's
    point about sparsity without dataflow orchestration."""
    b, s, h, hd = q.shape
    kvh = k.shape[2]
    g = h // kvh
    scale = 1.0 / math.sqrt(hd)
    chunk = min(chunk, s)
    # non-divisible S (prime lengths included): pad up to a chunk multiple and
    # mask the tail — NOT gcd(s, chunk), which degenerates to chunk=1 and
    # statically unrolls s chunks
    s_pad = -(-s // chunk) * chunk
    n_chunks = s_pad // chunk
    padded = s_pad != s

    q = constrain(q, _q_axes(rt, s, h), rt.mesh, rt.rules)
    # KV must stay seq-local: a seq-sharded KV would force the SPMD partitioner
    # into full-replication copies at every chunk slice.  KV heads shard over
    # `model` when divisible, otherwise replicate (GQA KV replication).
    k = constrain(k, ("batch", None, "tp", None), rt.mesh, rt.rules)
    v = constrain(v, ("batch", None, "tp", None), rt.mesh, rt.rules)
    if padded:
        pad = [(0, 0), (0, s_pad - s), (0, 0), (0, 0)]
        q = jnp.pad(q, pad)
        if causal:  # self-attention: prefix slicing needs the padded length
            k, v = jnp.pad(k, pad), jnp.pad(v, pad)
    qr = q.reshape(b, s_pad, kvh, g, hd)
    pm_full = None
    if pattern_mask is not None:
        # pad q rows True (sliced off at the end), kv cols False (dead tail)
        pm_full = np.ones((s_pad, k.shape[1]), bool)
        pm_full[:s, : pattern_mask.shape[1]] = pattern_mask
        pm_full[:s, pattern_mask.shape[1] :] = False
    outs = []
    for i in range(n_chunks):  # static unroll: exact per-chunk causal prefixes
        q_i = jax.lax.slice_in_dim(qr, i * chunk, (i + 1) * chunk, axis=1)
        end = (i + 1) * chunk if causal else k.shape[1]
        start = 0
        if window is not None and causal:
            # earliest key needed by the FIRST query row of this chunk
            start = max(0, i * chunk - window + 1)
            start = (start // chunk) * chunk  # align to chunk (conservative)
        k_i = jax.lax.slice_in_dim(k, start, end, axis=1)
        v_i = jax.lax.slice_in_dim(v, start, end, axis=1)
        scores = jnp.einsum(
            "bqkgd,bskd->bkgqs", q_i, k_i, preferred_element_type=jnp.float32
        ) * scale
        if not f32_softmax:  # §Perf lever: halve the score HBM traffic
            scores = scores.astype(q.dtype)
        neg = jnp.asarray(-1e30 if f32_softmax else -3e38, scores.dtype)
        if causal or window is not None or pattern_mask is not None:
            qpos = i * chunk + jnp.arange(chunk)
            kpos = start + jnp.arange(end - start)
            mask = jnp.ones((chunk, end - start), bool)
            if causal:
                mask &= qpos[:, None] >= kpos[None, :]
                if padded:
                    mask &= kpos[None, :] < s  # padded tail keys
            if window is not None:
                mask &= kpos[None, :] > qpos[:, None] - window
            if pm_full is not None:  # static numpy slice of the pattern mask
                mask &= jnp.asarray(pm_full[i * chunk : (i + 1) * chunk, start:end])
            scores = jnp.where(mask[None, None, None], scores, neg)
        probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
        out_i = jnp.einsum("bkgqs,bskd->bqkgd", probs, v_i)
        outs.append(out_i.reshape(b, chunk, h, hd))
    out = jnp.concatenate(outs, axis=1) if len(outs) > 1 else outs[0]
    return out[:, :s] if padded else out


def decode_attention(
    q: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    cur_len: jax.Array | None = None,
    pattern_mask: jax.Array | None = None,
) -> jax.Array:
    """One-token attention over a (possibly sequence-sharded) KV cache.

    q: (B, H, hd); caches: (B, S, KV, hd).  ``cur_len`` masks unwritten cache
    rows: a scalar applies one live length batch-wide, a (B,) vector masks
    per request (ragged continuous batching).  ``pattern_mask`` (B, S) is the
    per-row token expansion of the block-sparsity map (mask-only on this
    backend).  Scores stay tiny, so plain einsum + softmax — XLA inserts the
    cross-shard max/sum reductions when the cache's S axis is sharded
    (flash-decode style combine).
    """
    b, h, hd = q.shape
    kvh = k_cache.shape[2]
    g = h // kvh
    scale = 1.0 / math.sqrt(hd)
    qr = q.reshape(b, kvh, g, hd)
    scores = jnp.einsum(
        "bkgd,bskd->bkgs", qr, k_cache, preferred_element_type=jnp.float32
    ) * scale
    if cur_len is not None:
        cl = jnp.asarray(cur_len, jnp.int32).reshape(-1, 1)  # scalar | (B, 1)
        mask = jnp.arange(k_cache.shape[1])[None, :] < cl  # (1|B, S)
        scores = jnp.where(mask[:, None, None, :], scores, -1e30)
    if pattern_mask is not None:
        scores = jnp.where(pattern_mask[:, None, None, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgs,bskd->bkgd", probs, v_cache)
    return out.reshape(b, h, hd)


def chunk_attention_cache(
    q: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    start: jax.Array,
    *,
    window: int | None = None,
    pattern_mask: jax.Array | None = None,
    kpos: jax.Array | None = None,
) -> jax.Array:
    """Chunk-of-queries attention over a shared KV cache with a per-row
    causal frontier (the XLA form of the mixed chunked-prefill step).

    q: (B, C, H, hd); caches: (B, S, KV, hd); ``start`` (B,) is the absolute
    position of each row's first query — query i attends cache keys at
    positions ``<= start[b] + i`` (its own position is the newest written
    row, so the frontier doubles as the written-cache mask).
    ``pattern_mask`` (B, C, S) is the per-query token expansion of the
    block-sparsity map (mask-only on this backend).  ``kpos`` (B, S)
    overrides the identity position map when the cache rows are NOT laid out
    at their absolute positions (the mod-window ring gathers slot-ordered
    pages; stale slots carry an out-of-frontier sentinel).  Rows beyond a
    row's valid count produce garbage the caller never reads."""
    b, c, h, hd = q.shape
    skv, kvh = k_cache.shape[1], k_cache.shape[2]
    g = h // kvh
    scale = 1.0 / math.sqrt(hd)
    qr = q.reshape(b, c, kvh, g, hd)
    scores = jnp.einsum(
        "bqkgd,bskd->bkgqs", qr, k_cache, preferred_element_type=jnp.float32
    ) * scale
    qpos = jnp.asarray(start, jnp.int32)[:, None] + jnp.arange(c, dtype=jnp.int32)
    if kpos is None:
        kpos = jnp.arange(skv, dtype=jnp.int32)[None, :]  # (1, S) identity
    kpos = jnp.asarray(kpos, jnp.int32)
    mask = kpos[:, None, :] <= qpos[:, :, None]  # (B, C, S) frontier
    if window is not None:
        mask &= kpos[:, None, :] > qpos[:, :, None] - window
    if pattern_mask is not None:
        mask &= pattern_mask
    scores = jnp.where(mask[:, None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs, v_cache)
    return out.reshape(b, c, h, hd)


def _fused_ok(rt: Runtime) -> bool:
    # pallas_call is a per-device kernel: under a >1-chip mesh the SPMD
    # partitioner cannot split it, so the spec falls back to the XLA form
    # (which the partitioner shards freely) instead of erroring.
    return rt.mesh is None or rt.mesh.devices.size <= 1


def run_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    spec: AttentionSpec = AttentionSpec(),
    causal: bool = True,
    window: int | None = None,
    rt: Runtime = Runtime(),
) -> jax.Array:
    """Execute train/prefill attention under the configured spec.

    ``spec.pattern`` applies to both forms: the fused kernel iterates only
    live blocks (grid-level skipping); the chunked form masks with the same
    map's token expansion (mask-only — parity target and multi-chip
    fallback)."""
    if spec.fused and _fused_ok(rt):
        from repro.kernels import ops  # local import: kernels are optional

        return ops.flash_attention(q, k, v, causal=causal, window=window, spec=spec)
    pattern, arg, causal, window = sparsity.canonical_pattern(
        spec.pattern, spec.pattern_arg, causal, window
    )
    pmask = None
    if pattern != "dense":
        tq, tk = sparsity.pick_pattern_tiles(
            q.shape[1], k.shape[1], spec.q_tile, spec.kv_tile
        )
        bm = sparsity.build_block_map(
            pattern, q.shape[1], k.shape[1], tq, tk, causal=causal,
            window=window, pattern_arg=arg,
        )
        pmask = sparsity.token_mask(bm)
    return chunked_attention(
        q, k, v, causal=causal, window=window, chunk=spec.chunk, rt=rt,
        f32_softmax=spec.f32_softmax, pattern_mask=pmask,
    )


def run_decode_attention(
    q: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    cur_len: jax.Array | None = None,
    *,
    spec: AttentionSpec = AttentionSpec(),
    rt: Runtime = Runtime(),
    kv_live: int | None = None,
) -> jax.Array:
    """Execute one-token cache attention under the configured spec.

    ``cur_len``: None (whole cache live), scalar (batch-wide live length), or
    (B,) per-request live lengths (ragged continuous batching).  ``kv_live``
    is a static host-known upper bound on every row's live length (the serve
    engine's bucketed ``max(pos)+1``): both forms read only the first
    ``kv_live`` cache rows instead of streaming the padded cache.
    ``spec.pattern`` restricts each row to its own live kv tiles."""
    if spec.fused and _fused_ok(rt):
        from repro.kernels import ops

        return ops.flash_decode(
            q, k_cache, v_cache, cur_len, spec=spec, kv_live=kv_live
        )
    k_cache, v_cache, skv = truncate_kv_live(k_cache, v_cache, kv_live)
    pattern, arg, _, window = sparsity.canonical_pattern(
        spec.pattern, spec.pattern_arg, True, None
    )
    pmask = None
    if pattern != "dense" or window is not None:
        _, tk = sparsity.pick_pattern_tiles(1, skv, spec.q_tile, spec.kv_tile)
        if cur_len is None:
            cl = jnp.full((q.shape[0],), skv, jnp.int32)
        else:
            cl = jnp.broadcast_to(
                jnp.asarray(cur_len, jnp.int32).reshape(-1), (q.shape[0],)
            )
        pmask = sparsity.decode_token_mask(
            pattern, cl, skv, spec.q_tile, tk, window=window, pattern_arg=arg
        )
        if window is not None:  # fine window edge (matches the prefill mask)
            pmask &= jnp.arange(skv)[None, :] > cl[:, None] - 1 - window
    return decode_attention(q, k_cache, v_cache, cur_len, pattern_mask=pmask)


# --------------------------------------------------------------------------
# Paged cache dispatch: the fused kernels stream the pool through translated
# physical-page tables; the XLA forms gather the virtual cache back from the
# pool and run the SAME masked forms — parity with the contiguous engine by
# construction (one liveness map, two address spaces).
# --------------------------------------------------------------------------


def gather_pages(
    pool: jax.Array, page_table: jax.Array, n_rows: int, page: int,
    page_range: tuple[int, int] | None = None,
) -> jax.Array:
    """Materialise rows ``0..n_rows-1`` of each request's VIRTUAL cache from
    the shared page pool.  pool: (n_pages * page, KV, hd); page_table:
    (B, n_vtiles) physical page ids (sentinel ``n_pages`` = unallocated) ->
    (B, n_rows, KV, hd).  Unallocated tiles gather clamped garbage — every
    consumer masks them (causal frontier / cur_len / pattern), exactly as the
    contiguous engine masks its unwritten rows.

    Aliasing is transparent here: with the radix prefix cache, SEVERAL rows'
    tables (and several virtual tiles, in principle) may name the same
    physical page — a pure read-side gather returns each row its own view of
    the shared rows, bit-identical to a private copy, so the XLA forms need
    no CoW awareness (the host engine forks pages before any write).

    ``page_range=(lo, hi)`` makes the gather MESH-LOCAL: ``pool`` is then ONE
    shard of a page-sharded pool holding physical pages ``lo..hi-1``
    (``(hi - lo) * page`` rows), ids rebase to the shard, and rows whose page
    the shard does not own come back ZERO — each allocated tile is owned by
    exactly one shard, so a sum over the shards' gathers reassembles the
    replicated gather on every allocated row (a ``psum`` inside
    ``shard_map``, a plain sum in the host-side sweep test)."""
    if page_range is not None:
        lo, hi = page_range
        rows = jnp.arange(n_rows, dtype=jnp.int32)
        vt = rows // page
        phys = page_table[:, vt]  # (B, n_rows) global ids
        owned = (phys >= lo) & (phys < hi)
        loc = jnp.clip(phys - lo, 0, hi - lo - 1)
        flat = loc * page + (rows % page)[None, :]
        out = pool[flat]
        # broadcast `owned` over the pool's trailing dims — (KV, hd) for a
        # KV pool, (KV,) for a quantized pool's per-row scale leaf
        owned = owned.reshape(owned.shape + (1,) * (out.ndim - 2))
        return jnp.where(owned, out, jnp.zeros((), out.dtype))
    n_pages = pool.shape[0] // page
    rows = jnp.arange(n_rows, dtype=jnp.int32)
    vt = rows // page  # (n_rows,)
    phys = jnp.clip(page_table[:, vt], 0, n_pages - 1)  # (B, n_rows)
    flat = phys * page + (rows % page)[None, :]
    return pool[flat]


def ring_kpos(frontier: jax.Array, page: int, ring_tiles: int) -> jax.Array:
    """Absolute token position of every SLOT-ORDERED ring cache row.

    A mod-window gather (``gather_pages`` over a ``ring_tiles``-slot table)
    returns rows in slot order, not position order; this is the matching
    (B, ring_tiles * page) position map: slot s's r-th row is
    ``slot_tile(s) * page + r`` (the lap :func:`repro.core.sparsity.
    ring_slot_tiles` resolves from the frontier), and never-written slots
    carry a large sentinel every causal/frontier mask rejects."""
    st = sparsity.ring_slot_tiles(frontier, page, ring_tiles)  # (B, R)
    base = jnp.where(st >= 0, st * page, jnp.int32(1 << 30))
    off = jnp.arange(page, dtype=jnp.int32)
    return (base[:, :, None] + off[None, None, :]).reshape(st.shape[0], -1)


def _gather_dequant(q, k_pool, v_pool, k_scale, v_scale, page_table, n_rows, page):
    """Gather both pools' virtual rows and, for a quantized pool, the
    matching scale rows — reconstructing the bf16 cache the contiguous
    (oracle) forms consume.  The scale leaves ride the SAME page table, so a
    CoW-forked, radix-aliased, or ring-phased page always lands next to its
    own scales."""
    kg = gather_pages(k_pool, page_table, n_rows, page)
    vg = gather_pages(v_pool, page_table, n_rows, page)
    if k_scale is not None:
        ks = gather_pages(k_scale, page_table, n_rows, page)
        vs = gather_pages(v_scale, page_table, n_rows, page)
        kg = quant.dequantize_rows(kg, ks, dtype=q.dtype)
        vg = quant.dequantize_rows(vg, vs, dtype=q.dtype)
    return kg, vg


def run_paged_prefill_attention(
    q: jax.Array,
    k_new: jax.Array,
    v_new: jax.Array,
    k_pool: jax.Array,
    v_pool: jax.Array,
    page_table: jax.Array,
    *,
    page: int,
    spec: AttentionSpec = AttentionSpec(),
    rt: Runtime = Runtime(),
    k_scale: jax.Array | None = None,
    v_scale: jax.Array | None = None,
) -> jax.Array:
    """Admission prefill over a paged cache: q/k_new/v_new are the (1, S)
    prompt's projections (already scattered into the pool by the caller).
    The fused kernel reads the KV back *through the page table* — the
    physical-page indexing proof for the prefill grid; the XLA form attends
    the in-flight projections directly (the gather would reproduce them, and
    for a QUANTIZED pool the in-flight values are the exact pre-quantization
    KV — no dequant needed)."""
    if spec.fused and _fused_ok(rt):
        from repro.kernels import ops

        return ops.flash_paged_prefill(
            q, k_pool, v_pool, page_table, page=page, spec=spec,
            k_scale=k_scale, v_scale=v_scale,
        )
    return run_attention(q, k_new, v_new, spec=spec, causal=True, rt=rt)


def run_paged_decode_attention(
    q: jax.Array,
    k_pool: jax.Array,
    v_pool: jax.Array,
    cur_len: jax.Array,
    page_table: jax.Array,
    *,
    page: int,
    spec: AttentionSpec = AttentionSpec(),
    rt: Runtime = Runtime(),
    kv_live: int | None = None,
    ring_window: int | None = None,
    ring_tiles: int | None = None,
    k_scale: jax.Array | None = None,
    v_scale: jax.Array | None = None,
) -> jax.Array:
    """One-token attention over the paged pool: q (B, H, hd), per-row
    ``cur_len`` live lengths in virtual token space.  ``kv_live`` buckets the
    virtual extent (compile-per-bucket, like the contiguous engine).
    ``ring_window`` / ``ring_tiles`` select the mod-window ring form:
    positions are unbounded, the table's ``ring_tiles`` slots are reused in
    phase, and only the trailing ``ring_window`` keys are live.
    ``k_scale`` / ``v_scale`` carry a quantized pool's per-row dequant
    scales: the fused kernel dequantizes post-DMA, the XLA forms right after
    the gather — one scheme, two address spaces."""
    if spec.fused and _fused_ok(rt):
        from repro.kernels import ops

        return ops.flash_paged_decode(
            q, k_pool, v_pool, cur_len, page_table, page=page, spec=spec,
            kv_live=kv_live, ring_window=ring_window, ring_tiles=ring_tiles,
            k_scale=k_scale, v_scale=v_scale,
        )
    if ring_tiles is not None:
        cl = jnp.broadcast_to(
            jnp.asarray(cur_len, jnp.int32).reshape(-1), (q.shape[0],)
        )
        kg, vg = _gather_dequant(
            q, k_pool, v_pool, k_scale, v_scale, page_table,
            ring_tiles * page, page,
        )
        kpos = ring_kpos(cl - 1, page, ring_tiles)  # (B, R*page) slot order
        mask = (kpos < cl[:, None]) & (kpos > (cl[:, None] - 1 - ring_window))
        return decode_attention(q, kg, vg, None, pattern_mask=mask)
    n_rows = page_table.shape[1] * page
    if kv_live is not None:
        n_rows = min(n_rows, max(int(kv_live), 1))
    kg, vg = _gather_dequant(
        q, k_pool, v_pool, k_scale, v_scale, page_table, n_rows, page
    )
    return run_decode_attention(q, kg, vg, cur_len, spec=spec, rt=rt)


def run_paged_chunk_attention(
    q: jax.Array,
    k_pool: jax.Array,
    v_pool: jax.Array,
    start: jax.Array,
    ntok: jax.Array,
    page_table: jax.Array,
    *,
    page: int,
    spec: AttentionSpec = AttentionSpec(),
    rt: Runtime = Runtime(),
    kv_live: int | None = None,
    ring_window: int | None = None,
    ring_tiles: int | None = None,
    k_scale: jax.Array | None = None,
    v_scale: jax.Array | None = None,
) -> jax.Array:
    """Mixed chunked-prefill attention over the paged pool (the paged form of
    :func:`run_chunk_attention`): q (B, C, H, hd) rows at absolute positions
    ``start[b]..``, per-row page tables, per-row live-tile tables translated
    to physical pages.  ``ring_window`` / ``ring_tiles`` select the
    mod-window ring form (slot-phase tables, absolute-position masks).
    ``k_scale`` / ``v_scale``: quantized-pool dequant scales (fused:
    post-DMA in-kernel; XLA: post-gather)."""
    if spec.fused and _fused_ok(rt):
        from repro.kernels import ops

        return ops.flash_paged_chunk(
            q, k_pool, v_pool, start, ntok, page_table, page=page, spec=spec,
            kv_live=kv_live, ring_window=ring_window, ring_tiles=ring_tiles,
            k_scale=k_scale, v_scale=v_scale,
        )
    if ring_tiles is not None:
        sv = jnp.asarray(start, jnp.int32).reshape(-1)
        nv = jnp.asarray(ntok, jnp.int32).reshape(-1)
        fr = sv + jnp.maximum(nv, 1) - 1  # per-row write frontier
        kg, vg = _gather_dequant(
            q, k_pool, v_pool, k_scale, v_scale, page_table,
            ring_tiles * page, page,
        )
        kpos = ring_kpos(fr, page, ring_tiles)
        return chunk_attention_cache(
            q, kg, vg, sv, window=ring_window, kpos=kpos
        )
    n_rows = page_table.shape[1] * page
    if kv_live is not None:
        n_rows = min(n_rows, max(int(kv_live), 1))
    kg, vg = _gather_dequant(
        q, k_pool, v_pool, k_scale, v_scale, page_table, n_rows, page
    )
    return run_chunk_attention(q, kg, vg, start, ntok, spec=spec, rt=rt)


def run_chunk_attention(
    q: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    start: jax.Array,
    ntok: jax.Array,
    *,
    spec: AttentionSpec = AttentionSpec(),
    rt: Runtime = Runtime(),
    kv_live: int | None = None,
) -> jax.Array:
    """Execute one mixed chunked-prefill attention step under the configured
    spec: q (B, C, H, hd) chunk queries at absolute positions
    ``start[b]..start[b]+C-1`` over the shared cache, per-row causal frontier.

    The fused kernel streams each row's own live kv-tile table
    (:func:`repro.core.sparsity.chunk_live_tables` — traced from
    ``start + ntok``); the XLA form masks with the same map's per-query token
    expansion.  ``kv_live`` is the engine's bucketed static bound on the
    hottest row's frontier — both forms read only that cache prefix."""
    if spec.fused and _fused_ok(rt):
        from repro.kernels import ops

        return ops.flash_chunk(
            q, k_cache, v_cache, start, ntok, spec=spec, kv_live=kv_live
        )
    k_cache, v_cache, skv = truncate_kv_live(k_cache, v_cache, kv_live)
    pattern, arg, _, window = sparsity.canonical_pattern(
        spec.pattern, spec.pattern_arg, True, None
    )
    pmask = None
    if pattern != "dense":
        _, tk = sparsity.pick_pattern_tiles(1, skv, spec.q_tile, spec.kv_tile)
        qpos = jnp.asarray(start, jnp.int32)[:, None] + jnp.arange(
            q.shape[1], dtype=jnp.int32
        )
        pmask = sparsity.chunk_token_mask(
            pattern, qpos, skv, spec.q_tile, tk, window=window, pattern_arg=arg
        )
    return chunk_attention_cache(
        q, k_cache, v_cache, start, window=window, pattern_mask=pmask
    )
