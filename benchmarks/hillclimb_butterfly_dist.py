import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""§Perf iteration D: distributed execution of one butterfly (monarch) FFN
layer — GSPMD partitioner vs explicit shard_map orchestration.

The paper's §IV insight restated one level up: generic block-oriented
machinery (here: the SPMD partitioner) mis-schedules butterfly structure;
explicit orchestration (tokens sharded, 30x-smaller factors replicated,
factor-grad psum only) recovers it.

    PYTHONPATH=src python -m benchmarks.hillclimb_butterfly_dist
"""

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import api
from repro.launch import analysis
from repro.launch.mesh import make_production_mesh


def main():
    mesh = make_production_mesh()  # 16x16
    spec = api.LinearSpec(4096, 4096, "monarch")  # yi-6b-scale butterfly FFN
    pshape = jax.eval_shape(lambda: api.init_linear(jax.random.PRNGKey(0), spec))
    x = jax.ShapeDtypeStruct((16 * 4096, 4096), jnp.bfloat16)  # 65k tokens

    def fwd_loss(p, xl):
        y = api.apply_linear(p, spec, xl)
        return jnp.sum(y.astype(jnp.float32) ** 2)

    grad_fn = jax.grad(fwd_loss, argnums=(0, 1))

    psh_rep = jax.tree.map(lambda s: NamedSharding(mesh, P()), pshape)
    psh_tp = {
        "r": NamedSharding(mesh, P(None, None, "model", None, "data")),
        "l": NamedSharding(mesh, P(None, None, "model", "data", None)),
    }
    xsh = NamedSharding(mesh, P(("data",)))

    rows = []
    for name, ps in (("partitioner-TP", psh_tp), ("partitioner-replicated", psh_rep)):
        co = (
            jax.jit(grad_fn, in_shardings=(ps, xsh), out_shardings=(ps, xsh))
            .lower(pshape, x)
            .compile()
        )
        rows.append((name, analysis.roofline(co, mesh.devices.size, 0.0)))

    from repro.distributed.sharding import shard_map

    shard_grad = shard_map(
        grad_fn,
        mesh=mesh,
        in_specs=(P(), P(("data", "model"))),
        out_specs=(P(), P(("data", "model"))),
    )
    co = jax.jit(shard_grad).lower(pshape, x).compile()
    rows.append(("shard_map-replicated", analysis.roofline(co, mesh.devices.size, 0.0)))

    print("name,us_per_call,derived")
    base = rows[0][1]
    for name, rl in rows:
        print(
            f"hillclimbD/{name},{rl.t_step*1e6:.3f},"
            f"t_mem_ms={rl.t_memory*1e3:.3f} t_coll_ms={rl.t_collective*1e3:.3f} "
            f"speedup_vs_TP={base.t_step/rl.t_step:.1f}x"
        )


if __name__ == "__main__":
    main()
