"""Roofline table generator: reads dry-run jsonl records and renders the
EXPERIMENTS.md §Roofline table (terms in seconds, dominant bottleneck,
MODEL_FLOPS ratio, roofline fraction)."""

from __future__ import annotations

import argparse
import json


def load(paths: list[str]) -> list[dict]:
    recs = []
    for p in paths:
        with open(p) as f:
            for line in f:
                if line.strip():
                    recs.append(json.loads(line))
    # keep the LAST record per (arch, shape, mesh) — reruns override
    dedup: dict[tuple, dict] = {}
    for r in recs:
        dedup[(r["arch"], r["shape"], r["mesh"])] = r
    return list(dedup.values())


def fmt_row(r: dict) -> str:
    if r["status"] == "skipped":
        return (f"| {r['arch']} | {r['shape']} | — | — | — | — | skipped | — | — | "
                f"{r['reason']} |")
    if r["status"] != "ok" or not r.get("roofline"):
        return f"| {r['arch']} | {r['shape']} | ERROR: {r.get('error','?')[:60]} |"
    rl = r["roofline"]
    mem = r["memory"]["peak_est_bytes"] / 2**30
    return (
        f"| {r['arch']} | {r['shape']} | {rl['t_compute']*1e3:.1f} | "
        f"{rl['t_memory']*1e3:.1f} | {rl['t_collective']*1e3:.1f} | {mem:.1f} | "
        f"**{rl['dominant']}** | {rl['useful_ratio']:.2f} | "
        f"{rl['roofline_fraction']:.1%} | |"
    )


HEADER = (
    "| arch | shape | t_compute (ms) | t_memory (ms) | t_collective (ms) | "
    "mem/dev (GiB) | dominant | MODEL/HLO flops | roofline frac | note |\n"
    "|---|---|---|---|---|---|---|---|---|---|"
)


def table(recs: list[dict], mesh: str = "16x16") -> str:
    rows = [HEADER]
    order = {s: i for i, s in enumerate(["train_4k", "prefill_32k", "decode_32k", "long_500k"])}
    recs = [r for r in recs if r["mesh"] == mesh]
    recs.sort(key=lambda r: (r["arch"], order.get(r["shape"], 9)))
    for r in recs:
        rows.append(fmt_row(r))
    return "\n".join(rows)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("files", nargs="+")
    ap.add_argument("--mesh", default="16x16")
    args = ap.parse_args()
    print(table(load(args.files), args.mesh))


if __name__ == "__main__":
    main()
