"""Paper Fig. 2 — profiling dense vs FFT/butterfly attention kernels.

The paper profiles ViT/BERT kernels on Jetson Xavier NX and finds the
butterfly (fft) kernels lose cache hit-rate and gain no wall-clock despite
the FLOP reduction.  TPU analogue: the staged butterfly's arithmetic
intensity collapses vs the dense kernels, flipping them from compute-bound to
memory-bound at the HBM roofline — same diagnosis, different memory system.

derived column: arithmetic intensity (flops/byte) and bound.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import butterfly as bf
from repro.core.fft_mixing import fnet_mixing
from benchmarks.common import emit, modeled, sds

# ViT-Base: 197 tokens x 768; BERT-Large-ish: 512..4096 x 1024 (paper scales)
CASES = [
    ("vit", 128, 197, 768),
    ("bert-512", 32, 512, 1024),
    ("bert-2k", 8, 2048, 1024),
    ("bert-4k", 4, 4096, 1024),
]


def dense_to_qkv(x, w):
    return x @ w


def dense_attention(q, k, v):
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k) / jnp.sqrt(q.shape[-1] * 1.0)
    p = jax.nn.softmax(s.astype(jnp.float32), -1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", p, v)


def staged_bpmm(factors, x):
    return bf.apply_butterfly(factors, x)


def rows():
    out = []
    for name, b, s, d in CASES:
        h, hd = d // 64, 64
        x = sds((b, s, d))
        w = sds((d, 3 * d))
        q = sds((b, s, h, hd))
        m_qkv = modeled(f"fig2/{name}/dense-to_qkv", dense_to_qkv, x, w)
        m_att = modeled(f"fig2/{name}/dense-attention", dense_attention, q, q, q)
        # butterfly: staged radix-2 BPMM on the qkv projection (3 x d->d)
        n2 = 1 << (d - 1).bit_length()
        factors = [sds(sh) for sh in [(n2 >> k, 2, 2, 1 << (k - 1)) for k in range(1, n2.bit_length())]]
        xp = sds((b * s, n2))
        m_bp = modeled(f"fig2/{name}/bpmm-staged", lambda *a: staged_bpmm(list(a[1:]), a[0]), xp, *factors)
        # fft attention replacement (AT-all)
        m_fft = modeled(f"fig2/{name}/fft-at-all", lambda xx: fnet_mixing(xx), x)
        for m in (m_qkv, m_att, m_bp, m_fft):
            out.append((m.name, m.us, f"intensity={m.intensity:.1f} bound={m.bound}"))
    return out


def main():
    emit(rows())


if __name__ == "__main__":
    main()
