"""Paper Fig. 2 — profiling dense vs FFT/butterfly attention kernels.

The paper profiles ViT/BERT kernels on Jetson Xavier NX and finds the
butterfly (fft) kernels lose cache hit-rate and gain no wall-clock despite
the FLOP reduction.  TPU analogue: the staged butterfly's arithmetic
intensity collapses vs the dense kernels, flipping them from compute-bound to
memory-bound at the HBM roofline — same diagnosis, different memory system.

The attention softmax stage itself is profiled under both execution forms of
``AttentionSpec`` (select with ``--attn``):

* ``xla_chunked``  — prefix-chunked XLA attention, HLO-modeled (the score
  matrix round-trips HBM: the Fig. 2 pathology)
* ``flash_kernel`` — fused Pallas online-softmax kernel, analytic accounting
  (XLA reports the custom call at ~zero cost): one HBM read of Q/K/V, one
  write of O, scores VMEM-resident

derived column: arithmetic intensity (flops/byte) and bound.
"""

from __future__ import annotations

import argparse
import functools

import jax
import jax.numpy as jnp

from repro.core import butterfly as bf
from repro.core.attention import AttentionSpec, attention_flops, attention_hbm_bytes
from repro.core.fft_mixing import fnet_mixing
from repro.models.layers import chunked_attention
from benchmarks.common import analytic, emit, modeled, sds

# ViT-Base: 197 tokens x 768; BERT-Large-ish: 512..4096 x 1024 (paper scales)
CASES = [
    ("vit", 128, 197, 768),
    ("bert-512", 32, 512, 1024),
    ("bert-2k", 8, 2048, 1024),
    ("bert-4k", 4, 4096, 1024),
]


def dense_to_qkv(x, w):
    return x @ w


def dense_attention(q, k, v):
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k) / jnp.sqrt(q.shape[-1] * 1.0)
    p = jax.nn.softmax(s.astype(jnp.float32), -1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", p, v)


def staged_bpmm(factors, x):
    return bf.apply_butterfly(factors, x)


def _attention_rows(name: str, b: int, s: int, h: int, hd: int, impls: list[str]):
    """The softmax stage under each configured execution form."""
    out = []
    q = sds((b, s, h, hd))
    if "xla_chunked" in impls:
        fn = functools.partial(chunked_attention, causal=False, chunk=min(2048, s))
        out.append(modeled(f"fig2/{name}/attn-xla_chunked", fn, q, q, q))
    if "flash_kernel" in impls:
        spec = AttentionSpec(impl="flash_kernel")
        out.append(analytic(
            f"fig2/{name}/attn-flash_kernel",
            attention_flops(b, s, s, h, hd, causal=False),
            attention_hbm_bytes(spec, b, s, s, h, h, hd, causal=False),
        ))
    return out


def rows(impls: list[str]):
    out = []
    for name, b, s, d in CASES:
        h, hd = d // 64, 64
        x = sds((b, s, d))
        w = sds((d, 3 * d))
        q = sds((b, s, h, hd))
        ms = [
            modeled(f"fig2/{name}/dense-to_qkv", dense_to_qkv, x, w),
            modeled(f"fig2/{name}/dense-attention", dense_attention, q, q, q),
        ]
        ms += _attention_rows(name, b, s, h, hd, impls)
        # butterfly: staged radix-2 BPMM on the qkv projection (3 x d->d)
        n2 = 1 << (d - 1).bit_length()
        factors = [sds(sh) for sh in [(n2 >> k, 2, 2, 1 << (k - 1)) for k in range(1, n2.bit_length())]]
        xp = sds((b * s, n2))
        ms.append(modeled(f"fig2/{name}/bpmm-staged", lambda *a: staged_bpmm(list(a[1:]), a[0]), xp, *factors))
        # fft attention replacement (AT-all)
        ms.append(modeled(f"fig2/{name}/fft-at-all", lambda xx: fnet_mixing(xx), x))
        for m in ms:
            out.append((m.name, m.us, f"intensity={m.intensity:.1f} bound={m.bound}"))
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--attn", default="both",
                    choices=["xla_chunked", "flash_kernel", "both"],
                    help="which attention execution form(s) to profile")
    args = ap.parse_args()
    impls = ["xla_chunked", "flash_kernel"] if args.attn == "both" else [args.attn]
    emit(rows(impls))


if __name__ == "__main__":
    main()
