"""Shared benchmark machinery: modeled v5e roofline times from compiled cost.

This container has no TPU, so "time" for every benchmark is the roofline
model evaluated on the compiled artifact (single device, no collectives):

    t = max(HLO_flops / 197e12, HLO_bytes / 819e9)          [seconds]

For Pallas-kernel paths XLA reports a near-zero-cost custom-call, so kernels
are accounted analytically (reads + writes + model flops) — flagged in the
`source` column.
"""

from __future__ import annotations

import dataclasses
import json
import os
from typing import Callable

import jax
import jax.numpy as jnp

PEAK_FLOPS = 197e12
HBM_BW = 819e9


@dataclasses.dataclass
class Modeled:
    name: str
    flops: float
    hbm_bytes: float
    source: str = "hlo"  # hlo | analytic

    @property
    def t_compute(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes / HBM_BW

    @property
    def t(self) -> float:
        return max(self.t_compute, self.t_memory)

    @property
    def us(self) -> float:
        return self.t * 1e6

    @property
    def intensity(self) -> float:
        return self.flops / max(self.hbm_bytes, 1.0)

    @property
    def bound(self) -> str:
        return "compute" if self.t_compute >= self.t_memory else "memory"


def modeled(name: str, fn: Callable, *args) -> Modeled:
    """Lower+compile fn(*args as ShapeDtypeStructs ok) and read its cost."""
    compiled = jax.jit(fn).lower(*args).compile()
    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    return Modeled(name, float(cost.get("flops", 0.0)), float(cost.get("bytes accessed", 0.0)))


def analytic(name: str, flops: float, hbm_bytes: float) -> Modeled:
    return Modeled(name, flops, hbm_bytes, source="analytic")


def sds(shape, dtype=jnp.bfloat16):
    return jax.ShapeDtypeStruct(shape, dtype)


def emit(rows: list[tuple[str, float, str]]):
    """Print the `name,us_per_call,derived` CSV contract."""
    for name, us, derived in rows:
        print(f"{name},{us:.3f},{derived}")


def _bench_stamp() -> dict:
    """Provenance stamp for a BENCH section: the repo HEAD sha and an ISO
    UTC timestamp, so every row in the perf trajectory is attributable to
    the commit that produced it.  Outside a git checkout sha is None."""
    import datetime
    import subprocess

    try:
        sha = subprocess.run(
            ["git", "rev-parse", "HEAD"],
            cwd=os.path.dirname(os.path.abspath(__file__)),
            capture_output=True, text=True, timeout=10, check=True,
        ).stdout.strip()
    except (OSError, subprocess.SubprocessError):
        sha = None
    now = datetime.datetime.now(datetime.timezone.utc)
    return {
        "git_sha": sha,
        "written_at": now.isoformat(timespec="seconds"),
    }


def write_bench_json(path: str, section: str, rows: list[dict]) -> None:
    """Merge ``rows`` under ``section`` into the machine-readable perf file
    (``BENCH_attention.json``): each benchmark owns one section, re-runs
    replace it, other sections survive — the cross-PR perf trajectory.
    Each section is stamped with the producing commit's sha and an ISO
    timestamp (``meta``); the measurements live under ``rows``."""
    data: dict = {}
    if os.path.exists(path):
        try:
            with open(path) as f:
                data = json.load(f)
        except (OSError, json.JSONDecodeError):
            data = {}
    data[section] = {"meta": _bench_stamp(), "rows": rows}
    with open(path, "w") as f:
        json.dump(data, f, indent=1, sort_keys=True)
        f.write("\n")
