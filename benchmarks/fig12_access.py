"""Paper Fig. 12 — data-accessing requirement: staged butterfly vs the
multilayer-dataflow (fused) execution.

The paper compresses the SPM access requirement below 12.48% by keeping all
butterfly stages resident in the PE array.  TPU analogue: HBM bytes of the
log N staged XLA execution (one round-trip per stage) vs the fused Pallas
kernel (one read of x + weights, one write of y; intermediate stays in VMEM).

derived: access ratio fused/staged (lower = better orchestration).
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.core import butterfly as bf, monarch as mo
from benchmarks.common import analytic, emit, modeled, sds


def rows():
    out = []
    tokens = 4096
    for n in (1024, 4096, 8192):
        stages = bf.num_stages(n)
        factors = [sds(sh, jnp.bfloat16) for sh in bf.stage_shapes(n)]
        x = sds((tokens, n), jnp.bfloat16)
        m_staged = modeled(
            f"fig12/n{n}/staged-radix2",
            lambda xx, *fs: bf.apply_butterfly(list(fs), xx),
            x, *factors,
        )
        # fused kernel: x once in, y once out, grouped weights once
        b = 1 << mo.split_point(n)
        nb = n // b
        w_bytes = (nb * b * b + b * nb * nb) * 2
        io_bytes = 2 * tokens * n * 2 + w_bytes
        flops = mo.monarch_flops(n, b, tokens)
        m_fused = analytic(f"fig12/n{n}/fused-multilayer", flops, io_bytes)
        ratio = m_fused.hbm_bytes / m_staged.hbm_bytes
        out.append((m_staged.name, m_staged.us, f"bytes={m_staged.hbm_bytes/1e6:.1f}MB"))
        out.append(
            (m_fused.name, m_fused.us,
             f"bytes={m_fused.hbm_bytes/1e6:.1f}MB access_ratio={ratio:.2%}")
        )
    return out


def main():
    emit(rows())


if __name__ == "__main__":
    main()
