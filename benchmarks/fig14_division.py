"""Paper Fig. 14 — calUnit utilization across stage divisions.

The paper finds balanced Cooley-Tukey divisions (64x64 over 16x256) maximise
calculation-unit utilization.  TPU analogue: MXU utilization proxy for each
division = useful flops / flops of the 128-aligned MXU tiles each stage's
matmuls occupy (small radices waste systolic-array occupancy exactly like
shallow stages waste PE flow in the paper).

derived: utilization per division; best division flagged.
"""

from __future__ import annotations

import numpy as np

from repro.core import stage_division as sd
from benchmarks.common import emit


def _divisions(n: int):
    out = []
    for r1 in (16, 32, 64, 128, 256, 512):
        if n % r1 == 0 and n // r1 <= 512 and n // r1 >= 2:
            out.append((r1, n // r1))
    return out


def mxu_utilization(plan, tokens=4096):
    """useful / occupied flops with 128x128 MXU tiles, batched over tokens."""
    useful = 0.0
    occupied = 0.0
    n = int(np.prod(plan))
    for r in plan:
        batch = tokens * (n // r)  # rows through the r x r stage matmul
        useful += 2 * batch * r * r
        tile = 128
        pad = -(-r // tile) * tile
        rows_pad = -(-batch // 8) * 8
        occupied += 2 * rows_pad * pad * pad
    return useful / occupied


def rows():
    out = []
    for n in (2048, 4096, 8192):
        best, best_u = None, -1.0
        cands = []
        for plan in _divisions(n):
            u = mxu_utilization(plan)
            cands.append((plan, u))
            if u > best_u:
                best, best_u = plan, u
        for plan, u in cands:
            flag = " <-- best" if plan == best else ""
            out.append(
                (f"fig14/bpmm-{n}/{plan[0]}x{plan[1]}", 0.0, f"mxu_util={u:.2%}{flag}")
            )
        bal = sd.plan_stages(n, 512)
        out.append((f"fig14/bpmm-{n}/planner", 0.0, f"planner_chose={bal}"))
    return out


def main():
    emit(rows())


if __name__ == "__main__":
    main()
