"""Serve-engine throughput: static batching vs continuous batching vs the
chunked-prefill mixed-step engine under scenario workloads (wall-clock
tokens/sec on this host).

The serving-level analogue of the paper's §V-A streaming parallelism, at two
levels: static (wave) batching stalls every slot on the longest request of
the wave; continuous batching frees slots early but still blocks ALL live
decode slots for each admission's batch-1 prefill; the chunked engine runs
one ``mixed_step`` per iteration where prompt chunks stream into the shared
cache WHILE decode rows sample — the admission stall disappears entirely
(``decode_stall_steps`` is 0 by construction).

Scenarios (``--scenario``):

* ``mixed``        heterogeneous prompt/generation lengths (the ragged case)
* ``long_prompt``  short decoders in flight when one near-cache-length
                   prompt arrives mid-decode — the admission-stall showcase
* ``burst``        arrivals in bursts of batch-size groups
* ``poisson``      Poisson arrivals (seeded exponential inter-arrival gaps)
                   with a mixed interactive/batch priority split — the
                   irregular-traffic shape the priority scheduler and the
                   SLO stats (p50/p99 TTFT + ITL per class) exist for
* ``sliding_window``  ragged traffic under a sliding-window config (the
                   contiguous modes serve the seed per-slot ring; chunked/
                   paged serve mod-window ring page tables; ``--window``
                   overrides the default cache_len // 4)

    PYTHONPATH=src python -m benchmarks.serve_throughput [--attn both]
        [--pattern butterfly] [--scenario long_prompt] [--modes all]
        [--chunk-size 32] [--batch 4] [--requests 12] [--cache-len 64]
        [--check-chunked] [--seed 0] [--json BENCH_attention.json]

``--check-chunked`` is the CI regression gate for the scheduler: it exits
nonzero unless the chunked engine (a) never stalls a decode-eligible row,
(b) generates token-identically to the continuous engine, (c) produces
strictly more tokens per engine iteration than static batching does per
dispatch, and (d) stays within a loose 0.5x wall-clock sanity bound of
static (wall-clock on smoke shapes is dispatch-noise; see check_chunked).
Every row also lands in the machine-readable ``BENCH_attention.json``
(tokens/sec, FLOPs, HBM bytes per decode step) so the perf trajectory is
tracked across PRs.
"""

from __future__ import annotations

import argparse
import dataclasses
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import registry
from repro.core.attention import (
    AttentionSpec,
    ragged_attention_flops,
    ragged_attention_hbm_bytes,
)
from repro.launch.mesh import make_local_mesh, make_mesh, make_pages_mesh
from repro.launch.serve import DisaggRouter, Request, ServeLoop
from repro.models import model as M

from benchmarks.common import write_bench_json


def mixed_workload(cfg, n: int, cache_len: int, seed: int) -> list[Request]:
    """Heterogeneous prompt/generation lengths (the ragged case)."""
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n):
        plen = int(rng.integers(3, max(4, cache_len // 3)))
        max_new = int(rng.integers(2, max(3, cache_len // 3)))
        prompt = rng.integers(0, cfg.vocab, size=plen).astype(np.int32)
        reqs.append(Request(uid=i, prompt=prompt, max_new=max_new))
    return reqs


def long_prompt_workload(cfg, n: int, cache_len: int, seed: int) -> list[Request]:
    """Short decoders in flight when a near-cache-length prompt arrives
    mid-decode: the admission-prefill engine stalls every live decode slot
    for the whole long prefill; the chunked engine streams it in chunks
    while decode keeps advancing."""
    rng = np.random.default_rng(seed)
    long_len = max(cache_len // 2, cache_len - 4 * max(cache_len // 16, 2))
    n_short = max(n - 1, 1)
    reqs = []
    for i in range(n_short):
        plen = int(rng.integers(3, max(4, cache_len // 16)))
        max_new = int(rng.integers(cache_len // 8, max(cache_len // 4, 3)))
        prompt = rng.integers(0, cfg.vocab, size=plen).astype(np.int32)
        reqs.append(Request(uid=i, prompt=prompt, max_new=max_new))
    # the long prompt arrives a few steps in, mid-decode of the short ones
    reqs.append(Request(
        uid=n_short,
        prompt=rng.integers(0, cfg.vocab, size=long_len).astype(np.int32),
        max_new=3,
        arrival=3,
    ))
    return reqs


def burst_workload(cfg, n: int, cache_len: int, seed: int, batch: int) -> list[Request]:
    """Arrivals in bursts of ``batch`` requests every few steps."""
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n):
        plen = int(rng.integers(3, max(4, cache_len // 4)))
        max_new = int(rng.integers(2, max(3, cache_len // 4)))
        prompt = rng.integers(0, cfg.vocab, size=plen).astype(np.int32)
        reqs.append(Request(
            uid=i, prompt=prompt, max_new=max_new,
            arrival=(i // max(batch, 1)) * 4,
        ))
    return reqs


def poisson_workload(cfg, n: int, cache_len: int, seed: int) -> list[Request]:
    """Poisson arrival process (exponential inter-arrival gaps in engine
    clock units) over a mixed-priority population: ~1/3 ``batch`` requests
    with longer prompts/generations, the rest ``interactive`` and short.
    Seeded, so the scenario is a deterministic replay — the same arrival
    tape every run — which is what lets CI compare schedulers on it."""
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(scale=2.0, size=n)
    arrivals = np.floor(np.cumsum(gaps)).astype(int)
    reqs = []
    for i in range(n):
        interactive = rng.random() >= 1 / 3
        if interactive:
            plen = int(rng.integers(3, max(4, cache_len // 8)))
            max_new = int(rng.integers(2, max(3, cache_len // 8)))
        else:
            plen = int(rng.integers(cache_len // 4, max(cache_len // 2, 5)))
            max_new = int(rng.integers(3, max(4, cache_len // 4)))
        prompt = rng.integers(0, cfg.vocab, size=plen).astype(np.int32)
        reqs.append(Request(
            uid=i, prompt=prompt, max_new=max_new, arrival=int(arrivals[i]),
            priority="interactive" if interactive else "batch",
        ))
    return reqs


def shared_prefix_workload(cfg, n: int, cache_len: int, seed: int) -> list[Request]:
    """Every request = one long shared prefix (half the cache) + a short
    unique suffix — the system-prompt/few-shot-template traffic shape the
    radix prefix cache exists for.  Under the paged engine the first request
    prefills and caches the prefix; every later admission aliases it and
    prefills only its suffix (the ``prefix_cache`` BENCH section records the
    hit tokens and FLOPs saved).  The contiguous modes run the same workload
    cold, so the row doubles as the no-sharing reference."""
    rng = np.random.default_rng(seed)
    page = 128  # effective kv tile of the default spec
    prefix_len = max((cache_len // 2 // page) * page, page)
    prefix_len = min(prefix_len, max(cache_len - 2 * page, page))
    if cache_len < 2 * page:  # smoke shapes below one page: plain ragged
        return mixed_workload(cfg, n, cache_len, seed)
    shared = rng.integers(0, cfg.vocab, size=prefix_len).astype(np.int32)
    reqs = []
    for i in range(n):
        slen = int(rng.integers(1, page // 2))
        prompt = np.concatenate(
            [shared, rng.integers(0, cfg.vocab, size=slen).astype(np.int32)]
        )
        reqs.append(Request(uid=i, prompt=prompt, max_new=int(rng.integers(2, 5))))
    return reqs


def sliding_window_workload(cfg, n: int, cache_len: int, seed: int) -> list[Request]:
    """Ragged traffic for a sliding-window config (``main`` applies the
    window to the model): prompts deep enough that decode laps the
    mod-window ring, so static/continuous exercise the seed contiguous ring
    while chunked/paged stream the same requests through ring page tables."""
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n):
        plen = int(rng.integers(max(cache_len // 4, 3), max(3 * cache_len // 4, 4)))
        max_new = int(rng.integers(3, max(4, cache_len // 4)))
        prompt = rng.integers(0, cfg.vocab, size=plen).astype(np.int32)
        reqs.append(Request(uid=i, prompt=prompt, max_new=max_new))
    return reqs


def make_workload(cfg, scenario: str, n: int, cache_len: int, seed: int, batch: int):
    if scenario == "mixed":
        return mixed_workload(cfg, n, cache_len, seed)
    if scenario == "long_prompt":
        return long_prompt_workload(cfg, n, cache_len, seed)
    if scenario == "burst":
        return burst_workload(cfg, n, cache_len, seed, batch)
    if scenario == "poisson":
        return poisson_workload(cfg, n, cache_len, seed)
    if scenario == "shared_prefix":
        return shared_prefix_workload(cfg, n, cache_len, seed)
    if scenario == "sliding_window":
        return sliding_window_workload(cfg, n, cache_len, seed)
    raise ValueError(f"unknown scenario {scenario!r}")


MODES = ("static", "continuous", "chunked", "paged")


def run_mode(cfg, mesh, params, reqs, *, mode, batch, cache_len, chunk_size,
             reps: int = 3):
    def fresh():
        return [
            Request(uid=r.uid, prompt=r.prompt, max_new=r.max_new,
                    arrival=r.arrival, priority=r.priority)
            for r in reqs
        ]

    with ServeLoop(
        cfg, mesh, params, batch=batch, cache_len=cache_len,
        static_batching=(mode == "static"),
        chunked=(mode in ("chunked", "paged")), paged=(mode == "paged"),
        chunk_size=chunk_size,
    ) as loop:
        loop.run(fresh())  # warmup: compiles prefill buckets + decode steps
        best = None
        for _ in range(reps):  # best-of-N: host scheduling noise dwarfs the
            work = fresh()     # deltas on small smoke workloads
            t0 = time.perf_counter()
            done = loop.run(work)
            dt = time.perf_counter() - t0
            if best is None or dt < best[1]:
                toks = sum(len(r.generated) for r in done)
                best = (toks, dt, dict(loop.stats), done)
    return best


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--attn", default="both",
                    choices=["xla_chunked", "flash_kernel", "both"])
    ap.add_argument("--pattern", default="dense",
                    choices=["dense", "butterfly", "strided", "global_window"])
    ap.add_argument("--scenario", default="mixed",
                    choices=["mixed", "long_prompt", "burst", "poisson",
                             "shared_prefix", "sliding_window"])
    ap.add_argument("--window", type=int, default=None,
                    help="sliding window for the sliding_window scenario "
                         "(default cache_len // 4)")
    ap.add_argument("--modes", default="all",
                    help="comma list of static,continuous,chunked (or 'all'; "
                         "'none' skips the mode sweep and runs only the "
                         "requested --check-* gates — required when XLA "
                         "forces >1 host device, where the data-parallel "
                         "mode sweep cannot shard its batch-1 prefill)")
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--cache-len", type=int, default=64)
    ap.add_argument("--chunk-size", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--check-chunked", action="store_true",
                    help="CI gate: zero decode stalls, token-identical to "
                         "continuous, more tokens/iteration than static's "
                         "tokens/dispatch, 0.5x wall-clock sanity bound")
    ap.add_argument("--check-paged", action="store_true",
                    help="CI gate: paged engine token-identical to the "
                         "contiguous engine, peak resident pages < the dense "
                         "reservation, and >= 2x concurrent long-context "
                         "requests at a fixed page-pool budget (deterministic "
                         "capacity sub-benchmark; emits the paged_capacity "
                         "BENCH section)")
    ap.add_argument("--check-ring", action="store_true",
                    help="CI gate: paged mod-window ring token-identical to "
                         "the seed contiguous ring engine on prompts that "
                         "lap the ring, with peak resident pages <= the "
                         "window reservation (batch x ring_tiles) and below "
                         "the dense reservation (deterministic "
                         "sub-benchmark; emits the ring_capacity BENCH "
                         "section)")
    ap.add_argument("--check-prefix", action="store_true",
                    help="CI gate: 4 requests sharing a 4k-token prefix must "
                         "cost >= 3x less admission prefill FLOPs and peak "
                         "resident pages with the radix prefix cache than "
                         "without, token-identically, pool fully drained "
                         "(deterministic sub-benchmark; emits the "
                         "prefix_cache BENCH section)")
    ap.add_argument("--check-preempt", action="store_true",
                    help="CI gate: under a page-pool overload with mixed "
                         "priorities, the priority scheduler preempts a "
                         "batch request for an interactive one; the "
                         "preempted-then-resumed request must be "
                         "token-identical to its unpreempted run, the "
                         "interactive p99 TTFT must beat FIFO's on the same "
                         "tape, no request starves, and both pools drain at "
                         "close() (deterministic sub-benchmark; emits the "
                         "preemption BENCH section)")
    ap.add_argument("--check-shard", action="store_true",
                    help="CI gate: the disaggregated prefill/decode engine "
                         "over a 4-way page-sharded pool must be "
                         "token-identical to the single-loop replicated "
                         "engine on the mixed workload, every shard's peak "
                         "resident pages must stay within "
                         "ceil(replicated peak / 4) + slack (the balanced "
                         "allocator bound), and both pools must drain at "
                         "close().  Shards the DEVICE pool too when the "
                         "host exposes >= 4 devices (set XLA_FLAGS="
                         "--xla_force_host_platform_device_count=4); falls "
                         "back to host-side-only shard accounting otherwise "
                         "(deterministic sub-benchmark; emits the "
                         "shard_capacity BENCH section)")
    ap.add_argument("--check-quant", action="store_true",
                    help="CI gate: at a FIXED HBM byte budget (16 bf16 "
                         "pages), the int8 paged pool — whose pages are "
                         "(1 + 4/head_dim)/2 the bytes, so the same budget "
                         "buys more of them — must admit >= 2x the "
                         "concurrent long-context requests of the bf16 "
                         "pool, with greedy tokens IDENTICAL between the "
                         "two runs on the gate workload, admission-prefill "
                         "logits within tolerance of the bf16 pool's, and "
                         "both pools drained at close() (deterministic "
                         "sub-benchmark; emits the quant_capacity BENCH "
                         "section)")
    ap.add_argument("--json", default="BENCH_attention.json",
                    help="machine-readable output path ('' disables)")
    args = ap.parse_args()

    base = dataclasses.replace(registry.get(args.arch, reduced=True), dtype="float32")
    if args.scenario == "sliding_window":
        base = dataclasses.replace(
            base, sliding_window=args.window or max(args.cache_len // 4, 2)
        )
    mesh = make_local_mesh()
    params = M.init_params(base, jax.random.PRNGKey(0))
    reqs = make_workload(
        base, args.scenario, args.requests, args.cache_len, args.seed, args.batch
    )
    plens = [len(r.prompt) for r in reqs]
    gens = [r.max_new for r in reqs]
    print(
        f"workload: {args.scenario}, {args.requests} requests, "
        f"prompts {min(plens)}..{max(plens)}, max_new {min(gens)}..{max(gens)}, "
        f"batch={args.batch}, cache_len={args.cache_len}, "
        f"chunk_size={args.chunk_size}"
    )

    impls = (
        ["xla_chunked", "flash_kernel"] if args.attn == "both" else [args.attn]
    )
    if args.modes in ("none", ""):
        modes = ()
    elif args.modes == "all":
        modes = MODES
    else:
        modes = tuple(args.modes.split(","))
    for m in modes:
        if m not in MODES:
            raise SystemExit(f"unknown mode {m!r}; known: {MODES}")
    hdr = (
        f"{'attn':<14} {'mode':<12} {'tok':>5} {'steps':>6} {'stalls':>6} "
        f"{'wall s':>8} {'tok/s':>8} {'live-KV flop/step':>17} "
        f"{'live-KV B/step':>14} {'cache util':>10}"
    )
    print(hdr)
    print("-" * len(hdr))
    json_rows = []
    cap_json = []
    prefix_json = []
    ring_json = []
    preempt_json = []
    shard_json = []
    quant_json = []
    failures = []
    for impl in impls:
        cfg = dataclasses.replace(
            base, attention=AttentionSpec(impl=impl, pattern=args.pattern)
        )
        per_mode: dict[str, tuple] = {}
        for mode in modes:
            toks, dt, stats, done = run_mode(
                cfg, mesh, params, reqs, mode=mode,
                batch=args.batch, cache_len=args.cache_len,
                chunk_size=args.chunk_size,
            )
            per_mode[mode] = (toks, dt, stats, done)
            # analytic ragged decode-step accounting at the workload's
            # steady state: every request halfway through its generation
            cur = [len(r.prompt) + r.max_new // 2 for r in done]
            spec = cfg.attention_spec
            fl = ragged_attention_flops(
                1, cur, cfg.n_heads, cfg.head_dim, pattern=spec.pattern,
                pattern_arg=spec.pattern_arg, q_tile=spec.q_tile,
                kv_tile=spec.kv_tile,
            )
            hbm = ragged_attention_hbm_bytes(
                cfg.attention_spec, 1, cur, cfg.n_heads, cfg.n_kv_heads,
                cfg.head_dim,
            )
            util = sum(cur) / (len(cur) * args.cache_len)
            steps = stats.get("mixed_steps") or stats["decode_steps"]
            stalls = (
                stats.get("decode_stall_steps", 0)
                if mode == "chunked"
                else stats.get("admission_stall_steps", 0)
            )
            print(
                f"{impl:<14} {mode:<12} {toks:>5} {steps:>6} {stalls:>6} "
                f"{dt:>8.2f} {toks / dt:>8.1f} {fl:>17.3g} {hbm:>14.3g} "
                f"{util:>10.2f}"
            )
            json_rows.append({
                "attn": impl,
                "pattern": args.pattern,
                "scenario": args.scenario,
                "mode": mode,
                "tokens": toks,
                "steps": steps,
                "stall_steps": stalls,
                "prefill_tokens": stats.get("prefill_tokens"),
                "decode_kv_live_max": stats.get("decode_kv_live_max"),
                "pool_pages": stats.get("pool_pages"),
                "pool_peak_pages": stats.get("pool_peak_pages"),
                "wall_s": round(dt, 3),
                "tokens_per_s": round(toks / dt, 2),
                "live_kv_flops_per_step": fl,
                "live_kv_hbm_bytes_per_step": hbm,
                "cache_util": round(util, 3),
                "slo": stats.get("slo"),
                "slo_attainment": stats.get("slo_attainment"),
                "preemptions": stats.get("preemptions"),
                "aging_promotions": stats.get("aging_promotions"),
                "starved_requests": stats.get("starved_requests"),
            })
        if args.check_chunked:
            failures += check_chunked(impl, per_mode)
        if args.check_paged:
            cap_rows, cap_fail = check_paged_capacity(
                cfg, mesh, params, impl=impl, pattern=args.pattern,
            )
            cap_json += cap_rows
            failures += cap_fail
        if args.check_prefix:
            pre_rows, pre_fail = check_prefix(
                cfg, mesh, params, impl=impl, pattern=args.pattern,
            )
            prefix_json += pre_rows
            failures += pre_fail
        if args.check_ring:
            ring_rows, ring_fail = check_ring(
                cfg, mesh, params, impl=impl, pattern=args.pattern,
            )
            ring_json += ring_rows
            failures += ring_fail
        if args.check_preempt:
            pr_rows, pr_fail = check_preempt(
                cfg, mesh, params, impl=impl, pattern=args.pattern,
            )
            preempt_json += pr_rows
            failures += pr_fail
        if args.check_shard:
            sh_rows, sh_fail = check_shard(
                cfg, mesh, params, impl=impl, pattern=args.pattern,
            )
            shard_json += sh_rows
            failures += sh_fail
        if args.check_quant:
            q_rows, q_fail = check_quant(
                cfg, mesh, params, impl=impl, pattern=args.pattern,
            )
            quant_json += q_rows
            failures += q_fail
        if args.scenario == "shared_prefix" and "paged" in per_mode:
            # the scenario's paged run doubles as the prefix-cache BENCH row:
            # how much admission work the radix tree absorbed on this shape
            _, _, pstats, _ = per_mode["paged"]
            prefix_json.append({
                "attn": impl,
                "pattern": args.pattern,
                "scenario": args.scenario,
                "requests": args.requests,
                "prefix_hits": pstats.get("prefix_hits"),
                "prefix_hit_tokens": pstats.get("prefix_hit_tokens"),
                "prefill_tokens": pstats.get("prefill_tokens"),
                "prefill_flops": pstats.get("prefill_flops"),
                "cow_forks": pstats.get("cow_forks"),
                "pool_peak_pages": pstats.get("pool_peak_pages"),
                "prefix_inserted_pages": pstats.get("prefix_inserted_pages"),
                "prefix_evicted_pages": pstats.get("prefix_evicted_pages"),
            })
    if args.json:
        # one section per (scenario, pattern): CI's butterfly smoke row and
        # the chunked-scheduler gate both survive in the artifact; a
        # gates-only run (--modes none) must not blank a populated section
        if json_rows:
            write_bench_json(
                args.json, f"serve_throughput/{args.scenario}/{args.pattern}",
                json_rows,
            )
        if cap_json:
            write_bench_json(args.json, "paged_capacity", cap_json)
        if prefix_json:
            write_bench_json(args.json, "prefix_cache", prefix_json)
        if ring_json:
            write_bench_json(args.json, "ring_capacity", ring_json)
        if preempt_json:
            write_bench_json(args.json, "preemption", preempt_json)
        if shard_json:
            write_bench_json(args.json, "shard_capacity", shard_json)
        if quant_json:
            write_bench_json(args.json, "quant_capacity", quant_json)
    if failures:
        for f in failures:
            print(f"CHECK FAILED: {f}", file=sys.stderr)
        raise SystemExit(1)
    if args.check_chunked:
        print("check-chunked: all assertions passed")
    if args.check_paged:
        print("check-paged: all assertions passed")
    if args.check_prefix:
        print("check-prefix: all assertions passed")
    if args.check_ring:
        print("check-ring: all assertions passed")
    if args.check_preempt:
        print("check-preempt: all assertions passed")
    if args.check_shard:
        print("check-shard: all assertions passed")
    if args.check_quant:
        print("check-quant: all assertions passed")


def check_paged_capacity(cfg, mesh, params, *, impl: str, pattern: str):
    """The paged-capacity CI gate: long-context mixed requests at a FIXED
    HBM budget.  The contiguous engine reserves ``cache_len`` rows per slot,
    so a budget of two slots serves two requests at a time no matter how
    short their live sets; the paged engine spends the same bytes as a page
    pool and packs however many requests' live pages fit.  Deterministic
    assertions: (a) paged generations are token-identical to the contiguous
    engine, (b) peak resident pages stay strictly below the dense
    reservation, (c) max concurrent requests reach >= 2x the contiguous
    slots.  Returns (bench rows, failures)."""
    page = 128  # the effective kv tile of the default spec
    cache_len = 8 * page  # 8 virtual tiles per request's worst case
    contig_batch = 2
    budget_pages = contig_batch * (cache_len // page)  # the dense reservation
    chunk = 64
    rng = np.random.default_rng(7)
    lens = [(int(rng.integers(3 * page // 2, 2 * page + page // 2)), int(rng.integers(2, 4)))
            for _ in range(6)]
    prompts = [rng.integers(0, cfg.vocab, size=ln).astype(np.int32) for ln, _ in lens]

    def mk():
        return [
            Request(uid=i, prompt=p, max_new=mn)
            for i, (p, (_, mn)) in enumerate(zip(prompts, lens))
        ]

    with ServeLoop(
        cfg, mesh, params, batch=contig_batch, cache_len=cache_len,
        chunked=True, chunk_size=chunk,
    ) as contig:
        t0 = time.perf_counter()
        done_c = contig.run(mk())
        dt_c = time.perf_counter() - t0
    with ServeLoop(
        cfg, mesh, params, batch=len(prompts), cache_len=cache_len,
        chunked=True, chunk_size=chunk, paged=True, pool_pages=budget_pages,
    ) as paged:
        assert paged.page == page, (
            f"capacity gate sized its budget in {page}-token pages but the "
            f"engine derived {paged.page}-token pages — the dense-reservation "
            "comparison would be in mismatched units"
        )
        t0 = time.perf_counter()
        done_p = paged.run(mk())
        dt_p = time.perf_counter() - t0

    failures = []
    for rc, rp in zip(done_c, done_p):
        if rc.generated != rp.generated:
            failures.append(
                f"{impl}/{pattern}: uid {rc.uid} paged generations diverge "
                f"from contiguous at the capacity shape"
            )
            break
    peak = paged.stats["pool_peak_pages"]
    if peak >= budget_pages:
        failures.append(
            f"{impl}/{pattern}: peak resident pages {peak} >= dense "
            f"reservation {budget_pages} — paging saved nothing"
        )
    conc = paged.stats["max_concurrent"]
    if conc < 2 * contig_batch:
        failures.append(
            f"{impl}/{pattern}: {conc} concurrent long-context requests < "
            f"2x the contiguous engine's {contig_batch} at the same "
            f"{budget_pages}-page HBM budget"
        )
    row = {
        "attn": impl,
        "pattern": pattern,
        "cache_len": cache_len,
        "page_tokens": page,
        "budget_pages": budget_pages,
        "contiguous_concurrent": contig_batch,
        "paged_concurrent": conc,
        "capacity_x": round(conc / contig_batch, 2),
        "pool_peak_pages": peak,
        "page_allocs": paged.stats["page_allocs"],
        "admission_backpressure": paged.stats["admission_backpressure"],
        "tokens": sum(len(r.generated) for r in done_p),
        "wall_s_contiguous": round(dt_c, 3),
        "wall_s_paged": round(dt_p, 3),
    }
    print(
        f"paged_capacity[{impl}/{pattern}]: {conc}x concurrent vs "
        f"{contig_batch} contiguous at {budget_pages} pages "
        f"(peak resident {peak}, {row['capacity_x']}x)"
    )
    return [row], failures


def check_quant(cfg, mesh, params, *, impl: str, pattern: str):
    """The quantized-pool CI gate: int8 pages at the SAME HBM byte budget.

    A bf16 page stores ``2 * head_dim`` bytes per (row, kv_head); an int8
    page stores ``head_dim`` payload bytes plus one f32 scale, so the same
    byte budget that buys 16 bf16 pages buys
    ``floor(16 * 2*head_dim / (head_dim + 4))`` int8 pages.  Long-context
    requests sized at ~6 pages of peak residency then make admission
    capacity the observable: the bf16 pool packs 2 concurrent requests, the
    int8 pool must pack >= 2x that (the tentpole's capacity claim, measured
    end-to-end through the scheduler's backpressure, not computed from
    widths).  Deterministic assertions: (a) int8 ``max_concurrent`` >= 2x
    bf16's, (b) greedy generations are IDENTICAL between the two runs —
    quantization noise on this workload stays below every argmax margin, so
    any token flip is a scale-handling bug, not rounding, (c) a direct
    admission-prefill through the quantized pool keeps final-token logits
    within tolerance of the bf16 pool's (the fused path reads dequantized
    pages in-kernel; tolerance 0.05 on logits of O(3) magnitude is ~10x
    the measured divergence), (d) both pools drain at close().  Returns
    (bench rows, failures)."""
    page = 128  # the effective kv tile of the default spec
    cache_len = 8 * page
    bf16_pages = 16  # the fixed budget, priced in bf16-page bytes
    hd = cfg.head_dim
    int8_pages = int(bf16_pages * 2.0 * hd / (hd + 4))
    chunk = 64
    rng = np.random.default_rng(7)
    # ~6 pages of peak residency each: ceil((len + max_new) / page) == 6
    lens = [int(rng.integers(645, 760)) for _ in range(5)]
    prompts = [rng.integers(0, cfg.vocab, size=ln).astype(np.int32) for ln in lens]

    def mk():
        return [Request(uid=i, prompt=p, max_new=3) for i, p in enumerate(prompts)]

    failures = []
    runs = {}
    for kd, pages in (("bf16", bf16_pages), ("int8", int8_pages)):
        t0 = time.perf_counter()
        with ServeLoop(
            cfg, mesh, params, batch=len(prompts), cache_len=cache_len,
            chunked=True, chunk_size=chunk, paged=True, pool_pages=pages,
            kv_dtype=kd,
        ) as loop:
            done = loop.run(mk())
            dt = time.perf_counter() - t0
            conc = loop.stats["max_concurrent"]
            bp = loop.stats["admission_backpressure"]
        if loop.pool.in_use:
            failures.append(
                f"{impl}/{pattern}: {kd} pool leaked "
                f"{loop.pool.in_use} pages after the quant gate run"
            )
        runs[kd] = (done, conc, bp, dt, pages)

    done_bf, conc_bf, _, dt_bf, _ = runs["bf16"]
    done_i8, conc_i8, bp_i8, dt_i8, _ = runs["int8"]
    for rb, ri in zip(done_bf, done_i8):
        if rb.generated != ri.generated:
            failures.append(
                f"{impl}/{pattern}: uid {rb.uid} int8 generations diverge "
                f"from bf16 on the gate workload — a scale-handling bug, "
                f"not quantization noise"
            )
            break
    if conc_i8 < 2 * conc_bf:
        failures.append(
            f"{impl}/{pattern}: int8 packed {conc_i8} concurrent requests "
            f"vs bf16's {conc_bf} at the same byte budget — expected >= 2x"
        )

    # direct admission prefill through both pools: logits divergence
    from repro.launch.serving.entries import make_paged_fns, zero_pools

    nv = cache_len // page
    plen = 200
    toks = np.zeros((1, 256), np.int32)
    toks[0, :plen] = prompts[0][:plen]
    pt = jnp.arange(nv, dtype=jnp.int32)[None, :]
    lg = {}
    for kd in ("bf16", "int8"):
        pre = make_paged_fns(
            cfg, mesh, n_pages=nv, page=page, chunk=chunk, kv_dtype=kd
        )[0]
        pools = zero_pools(cfg, mesh, nv, page, kv_dtype=kd)
        logits, _ = pre(
            params, pools, {"tokens": jnp.asarray(toks)},
            jnp.asarray([plen], jnp.int32), pt,
        )
        lg[kd] = np.asarray(logits[0], np.float32)
    div = float(np.max(np.abs(lg["bf16"] - lg["int8"])))
    tol = 0.05
    if div > tol:
        failures.append(
            f"{impl}/{pattern}: admission-prefill logits diverge by {div:.4f} "
            f"between bf16 and int8 pools (tolerance {tol})"
        )
    if int(lg["bf16"].argmax()) != int(lg["int8"].argmax()):
        failures.append(
            f"{impl}/{pattern}: admission-prefill argmax flipped between "
            f"bf16 and int8 pools"
        )

    row = {
        "attn": impl,
        "pattern": pattern,
        "cache_len": cache_len,
        "page_tokens": page,
        "head_dim": hd,
        "budget_bf16_pages": bf16_pages,
        "budget_int8_pages": int8_pages,
        "bf16_concurrent": conc_bf,
        "int8_concurrent": conc_i8,
        "capacity_x": round(conc_i8 / max(conc_bf, 1), 2),
        "int8_admission_backpressure": bp_i8,
        "tokens": sum(len(r.generated) for r in done_i8),
        "prefill_logits_max_div": round(div, 5),
        "wall_s_bf16": round(dt_bf, 3),
        "wall_s_int8": round(dt_i8, 3),
    }
    print(
        f"quant_capacity[{impl}/{pattern}]: int8 {conc_i8}x concurrent vs "
        f"bf16 {conc_bf}x at the same byte budget "
        f"({bf16_pages} bf16 pages == {int8_pages} int8 pages, "
        f"{row['capacity_x']}x, logits div {div:.4f})"
    )
    return [row], failures


def check_ring(cfg, mesh, params, *, impl: str, pattern: str):
    """The mod-window ring CI gate: prompts deep enough that decode laps the
    ring, served by the seed contiguous ring engine (admission-prefill over
    per-slot rows) and by the paged engine's mod-window page tables (chunked
    auto-upgrades).  Deterministic assertions: (a) paged-ring generations
    are token-identical to the contiguous ring, (b) peak resident pages stay
    within the window reservation (batch x ring_tiles — ring requests hold a
    FIXED page set), (c) that reservation undercuts the dense one
    (cache_len's tiles per slot), i.e. paging a window actually caps
    residency.  Returns (bench rows, failures) and emits the
    ``ring_capacity`` BENCH section."""
    page = 128  # the effective kv tile of the default spec
    window = 2 * page
    cache_len = 8 * page  # dense reservation: 8 tiles per slot
    chunk = 64
    batch = 3
    wcfg = dataclasses.replace(cfg, sliding_window=window)
    rng = np.random.default_rng(13)
    # prompts past ring_tiles * page positions: the ring wraps mid-prefill,
    # and every request decodes past its prompt (more laps)
    lens = [(int(rng.integers(4 * page, 7 * page)), int(rng.integers(3, 7)))
            for _ in range(5)]
    prompts = [rng.integers(0, cfg.vocab, size=ln).astype(np.int32)
               for ln, _ in lens]

    def mk():
        return [Request(uid=i, prompt=p, max_new=mn)
                for i, (p, (_, mn)) in enumerate(zip(prompts, lens))]

    with ServeLoop(
        wcfg, mesh, params, batch=batch, cache_len=cache_len,
    ) as contig:
        t0 = time.perf_counter()
        done_c = contig.run(mk())
        dt_c = time.perf_counter() - t0
    with ServeLoop(
        wcfg, mesh, params, batch=batch, cache_len=cache_len,
        chunked=True, chunk_size=chunk,
    ) as paged:
        assert paged.paged and paged.ring_tiles is not None, (
            "a chunked sliding-window loop must auto-upgrade to the paged ring"
        )
        assert paged.page == page, (
            f"ring gate sized its reservation in {page}-token pages but the "
            f"engine derived {paged.page}-token pages"
        )
        t0 = time.perf_counter()
        done_p = paged.run(mk())
        dt_p = time.perf_counter() - t0

    failures = []
    for rc, rp in zip(done_c, done_p):
        if rc.generated != rp.generated:
            failures.append(
                f"{impl}/{pattern}: uid {rc.uid} paged-ring generations "
                f"diverge from the contiguous ring engine"
            )
            break
    reservation = batch * paged.ring_tiles
    dense = batch * (cache_len // page)
    peak = paged.stats["pool_peak_pages"]
    if peak > reservation:
        failures.append(
            f"{impl}/{pattern}: peak resident pages {peak} > window "
            f"reservation {reservation} ({batch} slots x {paged.ring_tiles} "
            f"ring tiles) — a ring request leaked past its fixed page set"
        )
    if reservation >= dense:
        failures.append(
            f"{impl}/{pattern}: window reservation {reservation} >= dense "
            f"reservation {dense} — the mod-window table saves nothing at "
            f"window {window} / cache_len {cache_len}"
        )
    row = {
        "attn": impl,
        "pattern": pattern,
        "window": window,
        "cache_len": cache_len,
        "page_tokens": page,
        "ring_tiles": paged.ring_tiles,
        "window_reservation_pages": reservation,
        "dense_reservation_pages": dense,
        "pool_peak_pages": peak,
        "page_allocs": paged.stats["page_allocs"],
        "tokens": sum(len(r.generated) for r in done_p),
        "wall_s_contiguous": round(dt_c, 3),
        "wall_s_paged": round(dt_p, 3),
    }
    print(
        f"ring_capacity[{impl}/{pattern}]: peak {peak} pages within the "
        f"{reservation}-page window reservation (dense would hold {dense}) "
        f"at window {window}, ring_tiles {paged.ring_tiles}"
    )
    return [row], failures


def check_prefix(cfg, mesh, params, *, impl: str, pattern: str):
    """The prefix-cache CI gate: 4 requests sharing a 4k-token prefix, run
    through the paged admission engine twice — radix cache ON vs OFF (the
    no-sharing baseline).  Deterministic assertions: (a) generations are
    token-identical between the two runs, (b) admission prefill FLOPs drop
    >= 3x (the first request pays the full prefix once; the other three
    prefill only their short unique suffixes), (c) peak resident pages drop
    >= 3x (one shared copy of the prefix tiles instead of four private
    ones), (d) both pools fully drain — every refcount back to zero.
    Returns (bench rows, failures)."""
    page = 128  # the effective kv tile of the default spec
    prefix_len = 4096  # 32 shared pages
    cache_len = prefix_len + 2 * page  # room for suffix + generation
    n_req = 4
    rng = np.random.default_rng(11)
    shared = rng.integers(0, cfg.vocab, size=prefix_len).astype(np.int32)
    prompts = [
        np.concatenate(
            [shared, rng.integers(0, cfg.vocab, size=int(sl)).astype(np.int32)]
        )
        for sl in rng.integers(8, page // 2, size=n_req)
    ]

    def mk():
        return [Request(uid=i, prompt=p, max_new=2)
                for i, p in enumerate(prompts)]

    # pool sized so the cold run can hold all four requests' dense prefixes
    # concurrently — the baseline the sharing win is measured against
    pool = n_req * (cache_len // page)
    runs = {}
    for warm in (False, True):
        done = None
        try:
            with ServeLoop(
                cfg, mesh, params, batch=n_req, cache_len=cache_len,
                chunk_size=512, paged=True, pool_pages=pool,
                prefix_cache=warm,
            ) as loop:
                assert loop.page == page, (
                    f"prefix gate sized its prefix in {page}-token pages "
                    f"but the engine derived {loop.page}-token pages"
                )
                t0 = time.perf_counter()
                done = loop.run(mk())
                dt = time.perf_counter() - t0
                stats = dict(loop.stats)
        except RuntimeError:
            if done is None:  # run() itself failed, not the close() drain
                raise
            # leak at close(): leave it visible in in_use below
        runs[warm] = (done, stats, loop.pool.in_use, dt)

    failures = []
    done_c, stats_c, inuse_c, dt_c = runs[False]
    done_w, stats_w, inuse_w, dt_w = runs[True]
    for rc, rw in zip(done_c, done_w):
        if rc.generated != rw.generated:
            failures.append(
                f"{impl}/{pattern}: uid {rc.uid} generations diverge with "
                f"the prefix cache on — sharing corrupted tokens"
            )
            break
    flops_x = stats_c["prefill_flops"] / max(stats_w["prefill_flops"], 1.0)
    if flops_x < 3.0:
        failures.append(
            f"{impl}/{pattern}: admission prefill FLOPs only dropped "
            f"{flops_x:.2f}x (< 3x) with 4 requests sharing a "
            f"{prefix_len}-token prefix"
        )
    pages_x = stats_c["pool_peak_pages"] / max(stats_w["pool_peak_pages"], 1)
    if pages_x < 3.0:
        failures.append(
            f"{impl}/{pattern}: peak resident pages only dropped "
            f"{pages_x:.2f}x (< 3x): {stats_c['pool_peak_pages']} cold vs "
            f"{stats_w['pool_peak_pages']} shared"
        )
    if stats_w["prefix_hits"] != n_req - 1:
        failures.append(
            f"{impl}/{pattern}: {stats_w['prefix_hits']} prefix hits, "
            f"expected {n_req - 1} (every request after the first)"
        )
    for tag, inuse in (("cold", inuse_c), ("warm", inuse_w)):
        if inuse != 0:
            failures.append(
                f"{impl}/{pattern}: {tag} run left {inuse} pages referenced "
                f"after completion — refcount leak"
            )
    row = {
        "attn": impl,
        "pattern": pattern,
        "prefix_tokens": prefix_len,
        "requests": n_req,
        "prefill_flops_cold": stats_c["prefill_flops"],
        "prefill_flops_shared": stats_w["prefill_flops"],
        "prefill_flops_x": round(flops_x, 2),
        "prefill_tokens_cold": stats_c["prefill_tokens"],
        "prefill_tokens_shared": stats_w["prefill_tokens"],
        "peak_pages_cold": stats_c["pool_peak_pages"],
        "peak_pages_shared": stats_w["pool_peak_pages"],
        "peak_pages_x": round(pages_x, 2),
        "prefix_hits": stats_w["prefix_hits"],
        "prefix_hit_tokens": stats_w["prefix_hit_tokens"],
        "cow_forks": stats_w["cow_forks"],
        "wall_s_cold": round(dt_c, 3),
        "wall_s_shared": round(dt_w, 3),
    }
    print(
        f"prefix_cache[{impl}/{pattern}]: prefill FLOPs {flops_x:.1f}x "
        f"lower, peak pages {stats_c['pool_peak_pages']} -> "
        f"{stats_w['pool_peak_pages']} ({pages_x:.1f}x) across {n_req} "
        f"requests sharing {prefix_len} tokens"
    )
    return [row], failures


def check_preempt(cfg, mesh, params, *, impl: str, pattern: str):
    """The preemption CI gate: a deterministic overload tape on the paged
    chunked engine.  Two long ``batch`` requests arrive at t=0 and together
    reserve 8 of the 10 pool pages; an ``interactive`` request at t=6 still
    fits (committed 10/10), but a second one at t=8 cannot — the priority
    scheduler must evict the youngest batch request (its written prefix
    lands in the radix tree, so resume is a warm hit) while FIFO, run on
    the same tape, can only wait for a completion.  Deterministic
    assertions: (a) the priority run preempts >= 1 time and resumes the
    victim, (b) EVERY request — including the preempted-then-resumed one —
    generates token-identically to an uncontended run with an ample pool,
    (c) the interactive class's p99 TTFT under priority scheduling beats
    FIFO's on the same workload, (d) no request starves in either run, and
    (e) both runs' pools fully drain at ``close()``.  Returns (bench rows,
    failures) and emits the ``preemption`` BENCH section."""
    page = 128  # the effective kv tile of the default spec
    cache_len = 8 * page
    chunk = 64
    batch = 4
    pool = 10  # 2 batch x 4 pages + 1 interactive x 2 fills it exactly
    rng = np.random.default_rng(17)
    spec = [  # (priority, plen, max_new, arrival)
        ("batch", 448, 24, 0),
        ("batch", 448, 24, 0),
        ("interactive", 160, 8, 6),
        ("interactive", 160, 8, 8),
    ]
    prompts = [rng.integers(0, cfg.vocab, size=pl).astype(np.int32)
               for _, pl, _, _ in spec]

    def mk():
        return [
            Request(uid=i, prompt=p, max_new=mn, arrival=ar, priority=prio)
            for i, (p, (prio, _, mn, ar)) in enumerate(zip(prompts, spec))
        ]

    def run(scheduler: str, pool_pages: int):
        with ServeLoop(
            cfg, mesh, params, batch=batch, cache_len=cache_len,
            chunked=True, chunk_size=chunk, paged=True,
            pool_pages=pool_pages, scheduler=scheduler,
            slo_ttft=24, slo_itl=6.0,
        ) as loop:
            assert loop.page == page, (
                f"preempt gate sized its pool in {page}-token pages but the "
                f"engine derived {loop.page}-token pages"
            )
            t0 = time.perf_counter()
            done = loop.run(mk())
            dt = time.perf_counter() - t0
            stats = dict(loop.stats)
        return done, stats, loop.pool.in_use, dt

    done_ref, _, _, _ = run("priority", 64)  # ample pool: no preemption
    done_p, stats_p, inuse_p, dt_p = run("priority", pool)
    done_f, stats_f, inuse_f, dt_f = run("fifo", pool)

    failures = []
    if stats_p["preemptions"] < 1 or stats_p["resumes"] < 1:
        failures.append(
            f"{impl}/{pattern}: overload tape produced "
            f"{stats_p['preemptions']} preemptions / "
            f"{stats_p['resumes']} resumes — the gate exercised nothing"
        )
    for tag, done in (("preempting", done_p), ("fifo", done_f)):
        for rr, rd in zip(done_ref, done):
            if rr.generated != rd.generated:
                failures.append(
                    f"{impl}/{pattern}: uid {rr.uid} {tag} generations "
                    f"diverge from the uncontended run — "
                    f"preemption/requeue corrupted tokens"
                )
                break
    ttft_p = stats_p["slo"]["interactive"]["ttft_p99"]
    ttft_f = stats_f["slo"]["interactive"]["ttft_p99"]
    if not ttft_p < ttft_f:
        failures.append(
            f"{impl}/{pattern}: interactive p99 TTFT {ttft_p:.1f} clocks "
            f"under priority scheduling is not below FIFO's {ttft_f:.1f} "
            f"on the same overload tape"
        )
    for tag, stats in (("priority", stats_p), ("fifo", stats_f)):
        if stats["starved_requests"]:
            failures.append(
                f"{impl}/{pattern}: {stats['starved_requests']} requests "
                f"starved (no tokens emitted) under {tag} scheduling"
            )
    for tag, inuse in (("priority", inuse_p), ("fifo", inuse_f)):
        if inuse != 0:
            failures.append(
                f"{impl}/{pattern}: {tag} run left {inuse} pages "
                f"referenced after close() — refcount leak"
            )
    row = {
        "attn": impl,
        "pattern": pattern,
        "cache_len": cache_len,
        "pool_pages": pool,
        "preemptions": stats_p["preemptions"],
        "resumes": stats_p["resumes"],
        "resume_warm_hits": stats_p["resume_warm_hits"],
        "aging_promotions": stats_p["aging_promotions"],
        "slo_priority": stats_p["slo"],
        "slo_fifo": stats_f["slo"],
        "slo_attainment_priority": stats_p["slo_attainment"],
        "slo_attainment_fifo": stats_f["slo_attainment"],
        "interactive_ttft_p99_priority": ttft_p,
        "interactive_ttft_p99_fifo": ttft_f,
        "tokens": sum(len(r.generated) for r in done_p),
        "wall_s_priority": round(dt_p, 3),
        "wall_s_fifo": round(dt_f, 3),
    }
    print(
        f"preemption[{impl}/{pattern}]: {stats_p['preemptions']} "
        f"preemptions, {stats_p['resume_warm_hits']}/{stats_p['resumes']} "
        f"warm resumes; interactive p99 TTFT {ttft_p:.0f} clocks vs FIFO "
        f"{ttft_f:.0f} at a {pool}-page pool"
    )
    return [row], failures


def check_shard(cfg, mesh, params, *, impl: str, pattern: str):
    """The mesh-sharded disaggregation CI gate.

    Reference: the single-loop paged engine over a REPLICATED pool on the
    plain data mesh.  Candidate: the :class:`DisaggRouter` (prefill worker +
    decode worker, page-table handoff) over a 4-way page-sharded pool — on a
    mesh with a ``pages`` axis when the host exposes a multiple of 4 devices
    (CI sets ``XLA_FLAGS=--xla_force_host_platform_device_count=4``), else
    host-side shard accounting over the replicated device pool (the
    allocator's ranges and the capacity assertions are identical either
    way; only the physical placement differs).

    Deterministic assertions: (a) disagg generations token-identical to the
    single loop, (b) every shard's peak resident pages within
    ``ceil(replicated peak / 4) + 2`` (the balanced allocator bound, slack
    for handoff-timing skew), (c) both engines' pools fully drained at
    ``close()``.  Returns (bench rows, failures)."""
    n_shards = 4
    cache_len, chunk = 512, 32
    rng = np.random.default_rng(13)
    lens = [(int(rng.integers(20, 360)), int(rng.integers(2, 6)))
            for _ in range(6)]
    prompts = [rng.integers(0, cfg.vocab, size=ln).astype(np.int32)
               for ln, _ in lens]

    def mk():
        return [
            Request(uid=i, prompt=p, max_new=mn)
            for i, (p, (_, mn)) in enumerate(zip(prompts, lens))
        ]

    # The reference engine runs data-parallel-free: on a multi-device host
    # (XLA_FLAGS forcing 4 CPU devices) make_local_mesh() puts data=4 and the
    # batch-1 admission prefill cannot shard 4-way, so pin a 1-device mesh.
    ref_mesh = (
        mesh if jax.device_count() == 1
        else make_mesh((1, 1), ("data", "model"))
    )
    with ServeLoop(
        cfg, ref_mesh, params, batch=3, cache_len=cache_len, chunked=True,
        chunk_size=chunk, paged=True,
    ) as rep:
        t0 = time.perf_counter()
        done_r = rep.run(mk())
        dt_r = time.perf_counter() - t0
        rep_peak = rep.stats["pool_peak_pages"]
        rep_pool = rep.stats["pool_pages"]

    device_sharded = jax.device_count() % n_shards == 0 and (
        jax.device_count() >= n_shards
    )
    smesh = make_pages_mesh(n_shards) if device_sharded else mesh
    with DisaggRouter(
        cfg, smesh, params, batch=3, prefill_batch=2, cache_len=cache_len,
        chunk_size=chunk, pool_pages=rep_pool,
        **({} if device_sharded else {"page_shards": n_shards}),
    ) as dis:
        t0 = time.perf_counter()
        done_d = dis.run(mk())
        dt_d = time.perf_counter() - t0

    failures = []
    for rr, rd in zip(done_r, done_d):
        if rd.generated != rr.generated:
            failures.append(
                f"{impl}/{pattern}: uid {rr.uid} disagg-sharded generations "
                "diverge from the single-loop replicated engine"
            )
            break
    shard_peaks = dis.stats.get("shard_peak_pages", [])
    bound = -(-rep_peak // n_shards) + 2
    if not shard_peaks or len(shard_peaks) != n_shards:
        failures.append(
            f"{impl}/{pattern}: expected {n_shards} shard peaks in stats, "
            f"got {shard_peaks!r}"
        )
    elif max(shard_peaks) > bound:
        failures.append(
            f"{impl}/{pattern}: shard peak pages {max(shard_peaks)} > "
            f"ceil(replicated peak {rep_peak} / {n_shards}) + 2 = {bound} — "
            "the balanced allocator is not balancing"
        )
    if rep.pool.in_use or dis.pool.in_use:
        failures.append(
            f"{impl}/{pattern}: pools not drained after close() "
            f"(replicated {rep.pool.in_use}, sharded {dis.pool.in_use})"
        )
    row = {
        "attn": impl,
        "pattern": pattern,
        "cache_len": cache_len,
        "n_shards": n_shards,
        "device_sharded": device_sharded,
        "devices": jax.device_count(),
        "pool_pages": dis.stats["pool_pages"],
        "replicated_peak_pages": rep_peak,
        "shard_peak_pages": shard_peaks,
        "shard_peak_bound": bound,
        "handoffs": dis.stats["handoffs"],
        "handoff_wait_steps": dis.stats["handoff_wait_steps"],
        "prefill_batch": dis.stats["prefill_batch"],
        "decode_batch": dis.stats["decode_batch"],
        "tokens": sum(len(r.generated) for r in done_d),
        "wall_s_single_loop": round(dt_r, 3),
        "wall_s_disagg": round(dt_d, 3),
    }
    print(
        f"shard_capacity[{impl}/{pattern}]: {n_shards}-way "
        f"{'device' if device_sharded else 'host'}-sharded pool, shard "
        f"peaks {shard_peaks} vs replicated {rep_peak} (bound {bound}), "
        f"{row['handoffs']} handoffs"
    )
    return [row], failures


def check_chunked(impl: str, per_mode: dict) -> list[str]:
    """The CI gate.  The load-bearing assertions are deterministic: zero
    decode stalls, token-identical generations vs continuous, and strictly
    more tokens per engine iteration than static batching produces per
    dispatch — the scheduler property the chunked engine exists for (a
    regression that stalls, fragments chunks, or wave-barriers admission
    shows up as a step-count blowup).  Wall-clock only gets a loose 0.5x
    sanity bound: on CI-sized smoke workloads both engines are
    dispatch-bound (~60 jit calls each) so runner noise swamps real deltas —
    the wall-clock win is demonstrated at scale by the long_prompt scenario
    (2x tokens/sec at a 4k prompt arriving mid-decode on this host)."""
    missing = [m for m in ("chunked", "static", "continuous") if m not in per_mode]
    if missing:  # a gate with its baselines absent must fail, not pass
        return [f"{impl}: --check-chunked needs modes {missing} in --modes"]
    out = []
    ctoks, cdt, cstats, cdone = per_mode["chunked"]
    if cstats.get("decode_stall_steps", 0) != 0:
        out.append(f"{impl}: chunked decode stalled "
                   f"{cstats['decode_stall_steps']} steps")
    stoks, sdt, sstats, _ = per_mode["static"]
    s_dispatches = sstats["decode_steps"] + sstats["prefill_calls"]
    if ctoks / cstats["mixed_steps"] <= stoks / s_dispatches:
        out.append(
            f"{impl}: chunked {ctoks / cstats['mixed_steps']:.2f} "
            f"tokens/iteration <= static {stoks / s_dispatches:.2f} "
            f"tokens/dispatch — scheduler regression"
        )
    if ctoks / cdt < 0.5 * stoks / sdt:
        out.append(
            f"{impl}: chunked {ctoks / cdt:.1f} tok/s < 0.5 x static "
            f"{stoks / sdt:.1f} tok/s"
        )
    _, _, _, vdone = per_mode["continuous"]
    for rc, rv in zip(cdone, vdone):
        if rc.generated != rv.generated:
            out.append(
                f"{impl}: uid {rc.uid} chunked generations diverge from "
                f"continuous"
            )
            break
    return out


if __name__ == "__main__":
    main()
