"""Serve-engine throughput: static batching vs continuous batching under a
mixed prompt/generation-length workload (wall-clock tokens/sec on this host).

The serving-level analogue of the paper's §V-A streaming parallelism: static
(wave) batching stalls every slot on the longest request of the wave — the
request-level "complicated data accessing pattern brings utilization
degradation" — while continuous batching streams admissions into freed slots
so the decode array never idles.  Rows cover both attention execution forms
(``--attn xla_chunked|flash_kernel|both``); the analytic columns report the
*useful* decode-attention traffic (per-row live KV via
``ragged_attention_*``) and the cache-utilization ratio it implies.

    PYTHONPATH=src python -m benchmarks.serve_throughput [--attn both]
        [--pattern butterfly] [--batch 4] [--requests 12] [--cache-len 64]
        [--seed 0] [--json BENCH_attention.json]

``--pattern`` runs the engine with a block-sparse attention map (sparse
prefill + sparse decode through the pattern's live-tile tables).  Every row
also lands in the machine-readable ``BENCH_attention.json`` (tokens/sec,
FLOPs, HBM bytes per decode step) so the perf trajectory is tracked across
PRs.
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import numpy as np

from repro.configs import registry
from repro.core.attention import (
    AttentionSpec,
    ragged_attention_flops,
    ragged_attention_hbm_bytes,
)
from repro.launch.mesh import make_local_mesh
from repro.launch.serve import Request, ServeLoop
from repro.models import model as M

from benchmarks.common import write_bench_json


def mixed_workload(cfg, n: int, cache_len: int, seed: int) -> list[Request]:
    """Heterogeneous prompt/generation lengths (the ragged case)."""
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n):
        plen = int(rng.integers(3, max(4, cache_len // 3)))
        max_new = int(rng.integers(2, max(3, cache_len // 3)))
        prompt = rng.integers(0, cfg.vocab, size=plen).astype(np.int32)
        reqs.append(Request(uid=i, prompt=prompt, max_new=max_new))
    return reqs


def run_mode(cfg, mesh, params, reqs, *, batch, cache_len, static):
    loop = ServeLoop(
        cfg, mesh, params, batch=batch, cache_len=cache_len,
        static_batching=static,
    )
    work = [Request(uid=r.uid, prompt=r.prompt, max_new=r.max_new) for r in reqs]
    loop.run(work)  # warmup: compiles prefill buckets + decode
    work = [Request(uid=r.uid, prompt=r.prompt, max_new=r.max_new) for r in reqs]
    t0 = time.perf_counter()
    done = loop.run(work)
    dt = time.perf_counter() - t0
    toks = sum(len(r.generated) for r in done)
    return toks, dt, loop.stats, done


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--attn", default="both",
                    choices=["xla_chunked", "flash_kernel", "both"])
    ap.add_argument("--pattern", default="dense",
                    choices=["dense", "butterfly", "strided", "global_window"])
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--cache-len", type=int, default=64)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--json", default="BENCH_attention.json",
                    help="machine-readable output path ('' disables)")
    args = ap.parse_args()

    base = dataclasses.replace(registry.get(args.arch, reduced=True), dtype="float32")
    mesh = make_local_mesh()
    params = M.init_params(base, jax.random.PRNGKey(0))
    reqs = mixed_workload(base, args.requests, args.cache_len, args.seed)
    plens = [len(r.prompt) for r in reqs]
    gens = [r.max_new for r in reqs]
    print(
        f"workload: {args.requests} requests, prompts {min(plens)}..{max(plens)}, "
        f"max_new {min(gens)}..{max(gens)}, batch={args.batch}, "
        f"cache_len={args.cache_len}"
    )

    impls = (
        ["xla_chunked", "flash_kernel"] if args.attn == "both" else [args.attn]
    )
    hdr = (
        f"{'attn':<14} {'mode':<12} {'tok':>5} {'steps':>6} {'wall s':>8} "
        f"{'tok/s':>8} {'live-KV flop/step':>17} {'live-KV B/step':>14} "
        f"{'cache util':>10}"
    )
    print(hdr)
    print("-" * len(hdr))
    json_rows = []
    for impl in impls:
        cfg = dataclasses.replace(
            base, attention=AttentionSpec(impl=impl, pattern=args.pattern)
        )
        for static in (True, False):
            toks, dt, stats, done = run_mode(
                cfg, mesh, params, reqs,
                batch=args.batch, cache_len=args.cache_len, static=static,
            )
            # analytic ragged decode-step accounting at the workload's
            # steady state: every request halfway through its generation
            cur = [len(r.prompt) + r.max_new // 2 for r in done]
            spec = cfg.attention_spec
            fl = ragged_attention_flops(
                1, cur, cfg.n_heads, cfg.head_dim, pattern=spec.pattern,
                pattern_arg=spec.pattern_arg, q_tile=spec.q_tile,
                kv_tile=spec.kv_tile,
            )
            hbm = ragged_attention_hbm_bytes(
                cfg.attention_spec, 1, cur, cfg.n_heads, cfg.n_kv_heads,
                cfg.head_dim,
            )
            util = sum(cur) / (len(cur) * args.cache_len)
            mode = "static" if static else "continuous"
            print(
                f"{impl:<14} {mode:<12} {toks:>5} {stats['decode_steps']:>6} "
                f"{dt:>8.2f} {toks / dt:>8.1f} {fl:>17.3g} {hbm:>14.3g} "
                f"{util:>10.2f}"
            )
            json_rows.append({
                "attn": impl,
                "pattern": args.pattern,
                "mode": mode,
                "tokens": toks,
                "decode_steps": stats["decode_steps"],
                "decode_kv_live_max": stats.get("decode_kv_live_max"),
                "wall_s": round(dt, 3),
                "tokens_per_s": round(toks / dt, 2),
                "live_kv_flops_per_step": fl,
                "live_kv_hbm_bytes_per_step": hbm,
                "cache_util": round(util, 3),
            })
    if args.json:
        write_bench_json(args.json, "serve_throughput", json_rows)


if __name__ == "__main__":
    main()
