"""Paper Table IV — end-to-end one-layer vanilla transformer (1K seq, 1K
hidden, LRA-Image, batch 256): latency and throughput.

The paper reports 2.06 ms / 485 pred/s for its design (vs 2.4 ms for the
FPGA butterfly accelerator).  We report the modeled v5e latency of the same
workload, butterfly vs dense, and the derived throughput.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.configs import vanilla_1layer
from repro.models import model as M
from repro.models import transformer as tf
from repro.models.layers import Runtime
from benchmarks.common import Modeled, emit, sds

BATCH, SEQ = 256, 1024


def model_time(cfg) -> Modeled:
    rt = Runtime(mesh=None)
    params = M.abstract_params(cfg)
    batch = {"tokens": sds((BATCH, SEQ), jnp.int32)}
    fn = lambda p, t: tf.forward(p, cfg, t, rt, mode="eval")[0]
    compiled = jax.jit(fn).lower(params, batch).compile()
    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    return Modeled(cfg.name, float(cost["flops"]), float(cost["bytes accessed"]))


def rows():
    out = []
    bfly = dataclasses.replace(vanilla_1layer.FULL, remat=False)
    dense = dataclasses.replace(vanilla_1layer.DENSE, remat=False)
    m_b = model_time(bfly)
    m_d = model_time(dense)
    for m, tag in ((m_b, "butterfly"), (m_d, "dense")):
        lat_ms = m.t * 1e3
        pred_s = BATCH / m.t
        out.append(
            (f"table4/{tag}", m.us,
             f"latency_ms={lat_ms:.3f} pred_per_s={pred_s:.0f} bound={m.bound}")
        )
    out.append(("table4/speedup", 0.0, f"butterfly_vs_dense={m_d.t/m_b.t:.2f}x"))
    return out


def main():
    emit(rows())


if __name__ == "__main__":
    main()
