# One function per paper table. Print ``name,us_per_call,derived`` CSV.
"""Benchmark harness: `PYTHONPATH=src python -m benchmarks.run [--only fig2]`.

Every module maps to one paper artifact (see DESIGN.md §6).  Times are
modeled v5e roofline times from compiled HLO cost (this host is CPU-only);
`derived` carries the paper-relevant ratio for each artifact.
"""

from __future__ import annotations

import argparse
import sys
import traceback

from benchmarks import (
    fig2_profiling,
    fig12_access,
    fig14_division,
    fig15_speedup,
    fig17_fabnet,
    table4_e2e,
)

MODULES = {
    "fig2": fig2_profiling,
    "fig12": fig12_access,
    "fig14": fig14_division,
    "fig15": fig15_speedup,
    "fig17": fig17_fabnet,
    "table4": table4_e2e,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, choices=list(MODULES))
    args = ap.parse_args()
    names = [args.only] if args.only else list(MODULES)
    print("name,us_per_call,derived")
    failed = []
    for name in names:
        try:
            MODULES[name].main()
        except Exception as e:  # noqa: BLE001
            failed.append(name)
            print(f"{name},nan,ERROR:{type(e).__name__}:{e}", file=sys.stderr)
            traceback.print_exc()
    if failed:
        raise SystemExit(f"benchmarks failed: {failed}")


if __name__ == "__main__":
    main()
