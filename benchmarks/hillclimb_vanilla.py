"""§Perf cell C: the paper's Table-IV workload (vanilla-1layer, 1K seq x 1K
hidden, batch 256) — faithful butterfly -> multilayer-dataflow orchestration.

Iterations (single chip, modeled v5e roofline):
  0. dense                      — the paper's dense baseline
  1. radix2 + staged DFT        — PAPER-FAITHFUL butterfly: one strided pass
                                  per stage (the GPU-style execution of Fig.2)
  2. monarch + staged DFT       — stages grouped into block-diagonal MXU
                                  matmuls (multilayer dataflow, XLA form)
  3. fused kernels (analytic)   — Pallas kernels keep the working set in
                                  VMEM: butterfly components pay one HBM
                                  round-trip (kernels/monarch_bpmm, fft2d)
  4. + bf16 scores              — beyond-paper: attention gone (FFT), but the
                                  e2e still carries f32 copies; bf16 halves.
"""

import dataclasses
import json

import jax
import jax.numpy as jnp

from repro.configs import vanilla_1layer
from repro.core import monarch as mo, stage_division as sd
from repro.core.api import ButterflyPolicy, LinearSpec, apply_linear, init_linear
from repro.core.fft_mixing import fnet_mixing
from repro.models import model as M
from repro.models import transformer as tf
from repro.models.layers import Runtime
from benchmarks.common import Modeled, analytic, modeled, sds

B, S, D, F = 256, 1024, 1024, 4096
RT = Runtime(mesh=None)


def model_cost(cfg) -> Modeled:
    params = M.abstract_params(cfg)
    batch = {"tokens": sds((B, S), jnp.int32)}
    fn = lambda p, t: tf.forward(p, cfg, t, RT, mode="eval")[0]
    compiled = jax.jit(fn).lower(params, batch).compile()
    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    return Modeled(cfg.name, float(cost["flops"]), float(cost["bytes accessed"]))


def component_cost(name, fn, *args) -> Modeled:
    return modeled(name, fn, *args)


def kernel_component_analytics():
    """Analytic (VMEM-fused) costs for the butterfly components."""
    t = B * S
    # FFT mixing (seq 1024 + hidden 1024, two-stage 32x32 kernels chained)
    sp = sd.plan_stages(S)
    hp = sd.plan_stages(D)
    fft_flops = B * (D * sd.stage_flops(S, sp) + S * sd.stage_flops(D, hp))
    fft_io = t * D * 2 * (1 + 2 + 2 + 1)  # x in; re/im inter-stage; re out
    # FFN BPMM: 1024 -> 4096 (gout=4) and 4096 -> 1024 (gin=4), b=32
    b1 = 1 << mo.split_point(1024)
    per_piece = mo.monarch_flops(1024, b1, t)
    ffn_flops = (4 + 4) * per_piece
    wbytes = 8 * (mo.monarch_param_count(1024, b1)) * 2
    ffn_io = t * (D + F) * 2 * 2 + wbytes
    return analytic("fft-kernel", fft_flops, fft_io), analytic("ffn-kernel", ffn_flops, ffn_io)


def main():
    rows = []
    dense = dataclasses.replace(vanilla_1layer.DENSE, remat=False)
    r2 = dataclasses.replace(
        vanilla_1layer.FULL, name="vanilla+radix2", remat=False,
        butterfly=dataclasses.replace(vanilla_1layer.FULL.butterfly, impl="radix2"),
    )
    mon = dataclasses.replace(vanilla_1layer.FULL, name="vanilla+monarch", remat=False)

    m_dense = model_cost(dense)
    m_r2 = model_cost(r2)
    m_mon = model_cost(mon)

    # component attribution for the kernel projection
    t = B * S
    x2 = sds((t, D))
    spec_m1 = LinearSpec(D, F, "monarch")
    spec_m2 = LinearSpec(F, D, "monarch")
    p1 = jax.eval_shape(lambda: init_linear(jax.random.PRNGKey(0), spec_m1))
    p2 = jax.eval_shape(lambda: init_linear(jax.random.PRNGKey(0), spec_m2))
    m_ffn_mon = modeled(
        "ffn-monarch-xla",
        lambda a, b_, c: apply_linear(b_, spec_m2, apply_linear(a, spec_m1, c)),
        p1, p2, x2,
    )
    m_fft_staged = modeled("fft-staged-xla", lambda x: fnet_mixing(x), sds((B, S, D)))
    k_fft, k_ffn = kernel_component_analytics()

    m_kernel = Modeled(
        "vanilla+fused-kernels",
        m_mon.flops - m_ffn_mon.flops - m_fft_staged.flops + k_ffn.flops + k_fft.flops,
        m_mon.hbm_bytes - m_ffn_mon.hbm_bytes - m_fft_staged.hbm_bytes
        + k_ffn.hbm_bytes + k_fft.hbm_bytes,
        source="hlo+analytic",
    )

    out = []
    for m, note in [
        (m_dense, "paper dense baseline"),
        (m_r2, "PAPER-FAITHFUL staged butterfly"),
        (m_mon, "multilayer-dataflow grouping (XLA)"),
        (m_kernel, "fused Pallas kernels (VMEM-resident)"),
    ]:
        lat = m.t * 1e3
        out.append(dict(variant=m.name, flops=m.flops, bytes=m.hbm_bytes,
                        latency_ms=lat, pred_per_s=B / m.t, bound=m.bound,
                        speedup_vs_dense=m_dense.t / m.t, source=m.source, note=note))
        print(f"{m.name:28s} {lat:9.3f} ms  {B/m.t:8.0f} pred/s  "
              f"{m_dense.t/m.t:5.2f}x vs dense  bound={m.bound}  [{note}]")
    comps = dict(
        fft_staged_bytes=m_fft_staged.hbm_bytes, fft_kernel_bytes=k_fft.hbm_bytes,
        ffn_monarch_bytes=m_ffn_mon.hbm_bytes, ffn_kernel_bytes=k_ffn.hbm_bytes,
    )
    print("component access compression:",
          f"fft {k_fft.hbm_bytes/m_fft_staged.hbm_bytes:.1%},",
          f"ffn {k_ffn.hbm_bytes/m_ffn_mon.hbm_bytes:.1%}")
    with open("results/hillclimb.jsonl", "a") as f:
        f.write(json.dumps({"cell": "vanilla", "rows": out, "components": comps}) + "\n")


if __name__ == "__main__":
    main()
