"""Paper Fig. 17 — FABNet across sequence scales 128..1K.

Modeled per-block forward time of FABNet (2D-FFT attention + BPMM FFN)
against the dense vanilla block of the same width, at the paper's scales.
derived: speedup over the dense baseline (the paper normalises to Jetson
Nano; we normalise to the dense-XLA baseline on the same chip).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.configs import registry
from repro.models import model as M
from repro.models import transformer as tf
from repro.models.layers import Runtime
from benchmarks.common import Modeled, emit, sds


def block_time(cfg, b, s) -> Modeled:
    rt = Runtime(mesh=None)
    params = M.abstract_params(cfg)
    batch = {"tokens": sds((b, s), jnp.int32)}
    fn = lambda p, t: tf.forward(p, cfg, t, rt, mode="eval")[0]
    compiled = jax.jit(fn).lower(params, batch).compile()
    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    return Modeled(cfg.name, float(cost["flops"]), float(cost["bytes accessed"]))


def rows():
    out = []
    fab = registry.get("fabnet-base")
    dense = dataclasses.replace(
        fab, name="fabnet-dense-baseline",
        butterfly=type(fab.butterfly)(),  # all-dense policy
    )
    for s in (128, 256, 512, 1024):
        b = 32
        m_fab = block_time(dataclasses.replace(fab, remat=False), b, s)
        m_dense = block_time(dataclasses.replace(dense, remat=False), b, s)
        sp = m_dense.t / m_fab.t
        out.append((f"fig17/fabnet-{s}", m_fab.us, f"speedup_vs_dense={sp:.2f}x"))
        out.append((f"fig17/dense-{s}", m_dense.us, f"bound={m_dense.bound}"))
    return out


def main():
    emit(rows())


if __name__ == "__main__":
    main()
