"""Paper Fig. 15 — execution time: dense attention kernels vs butterfly
kernels under the multilayer-dataflow orchestration.

TPU analogue, per ViT/BERT kernel: modeled time of the dense kernel (XLA) vs
the butterfly replacement executed (a) staged — the block-oriented baseline,
and (b) fused/orchestrated — analytic kernel accounting.  The speedup
dense/fused mirrors the paper's tensor-core-vs-dataflow rows; staged/fused
mirrors its cuda-core (butterfly on GPU) rows.

derived: speedups.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import butterfly as bf, monarch as mo, stage_division as sd
from repro.core.attention import AttentionSpec, attention_flops, attention_hbm_bytes
from benchmarks.common import analytic, emit, modeled, sds

CASES = [
    ("vit-at-all", 128, 256, 768),
    ("vit-to_qkv", 128, 256, 768),
    ("bert-at-all-4k", 4, 4096, 1024),
    ("bert-to_qkv-4k", 4, 4096, 1024),
    ("bert-at-all-64k", 1, 65536, 1024),
]


def dense_attention(q, k, v):
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k) / jnp.sqrt(q.shape[-1] * 1.0)
    p = jax.nn.softmax(s.astype(jnp.float32), -1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", p, v)


def _fft_analytic(name, b, s, d):
    """Fused 2-stage FFT mixing kernel: one HBM round trip per stage chain."""
    sp, hp = sd.plan_stages(s), sd.plan_stages(d)
    flops = b * (d * sd.stage_flops(s, sp) + s * sd.stage_flops(d, hp))
    # chain: hidden DFT (1 round trip, re+im out), seq DFT (re+im in, re out)
    io = b * s * d * 2 * (1 + 2 + 2 + 1)
    return analytic(name, flops, io)


def rows():
    out = []
    for name, b, s, d in CASES:
        h, hd = d // 64, 64
        if "at-all" in name:
            q = sds((b, s, h, hd))
            m_dense = modeled(f"fig15/{name}/dense", dense_attention, q, q, q)
            m_fused = _fft_analytic(f"fig15/{name}/butterfly-fused", b, s, d)
            # the softmax path itself under the streaming-dataflow form:
            # fused Pallas flash attention (scores VMEM-resident)
            m_flash = analytic(
                f"fig15/{name}/attn-flash-fused",
                attention_flops(b, s, s, h, hd, causal=False),
                attention_hbm_bytes(
                    AttentionSpec(impl="flash_kernel"), b, s, s, h, h, hd, causal=False
                ),
            )
        else:
            m_flash = None
            x = sds((b * s, d))
            w = sds((d, 3 * d))
            m_dense = modeled(f"fig15/{name}/dense", lambda x, w: x @ w, x, w)
            n2 = 1 << (d - 1).bit_length()
            bsz = 1 << mo.split_point(n2)
            nb = n2 // bsz
            flops = 3 * mo.monarch_flops(n2, bsz, b * s)
            io = 3 * (2 * b * s * n2 * 2 + (nb * bsz**2 + bsz * nb**2) * 2)
            m_fused = analytic(f"fig15/{name}/butterfly-fused", flops, io)
        speed = m_dense.t / m_fused.t
        out.append((m_dense.name, m_dense.us, f"bound={m_dense.bound}"))
        out.append((m_fused.name, m_fused.us, f"speedup_vs_dense={speed:.2f}x"))
        if m_flash is not None:
            out.append((
                m_flash.name, m_flash.us,
                f"speedup_vs_dense={m_dense.t / m_flash.t:.2f}x",
            ))
    return out


def main():
    emit(rows())


if __name__ == "__main__":
    main()
