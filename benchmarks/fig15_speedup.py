"""Paper Fig. 15 — execution time: dense attention kernels vs butterfly
kernels under the multilayer-dataflow orchestration.

TPU analogue, per ViT/BERT kernel: modeled time of the dense kernel (XLA) vs
the butterfly replacement executed (a) staged — the block-oriented baseline,
and (b) fused/orchestrated — analytic kernel accounting.  The speedup
dense/fused mirrors the paper's tensor-core-vs-dataflow rows; staged/fused
mirrors its cuda-core (butterfly on GPU) rows.

``--attn flash`` adds the fused flash-attention softmax path rows;
``--pattern butterfly|strided|global_window`` additionally prices the
*block-sparse* flash kernel (the §III attention-map sparsity: the grid
iterates only live kv tiles, so both FLOPs and kv re-streaming scale by the
block map's density).  Every row also lands in the machine-readable
``BENCH_attention.json`` (``--json`` to relocate) so the perf trajectory is
tracked across PRs.

    PYTHONPATH=src python -m benchmarks.fig15_speedup --attn flash --pattern butterfly

derived: speedups.
"""

from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from repro.core import monarch as mo, sparsity, stage_division as sd
from repro.core.attention import AttentionSpec, attention_flops, attention_hbm_bytes
from benchmarks.common import analytic, emit, modeled, sds, write_bench_json

CASES = [
    ("vit-at-all", 128, 256, 768),
    ("vit-to_qkv", 128, 256, 768),
    ("bert-at-all-4k", 4, 4096, 1024),
    ("bert-to_qkv-4k", 4, 4096, 1024),
    ("bert-at-all-64k", 1, 65536, 1024),
]


def dense_attention(q, k, v):
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k) / jnp.sqrt(q.shape[-1] * 1.0)
    p = jax.nn.softmax(s.astype(jnp.float32), -1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", p, v)


def _fft_analytic(name, b, s, d):
    """Fused 2-stage FFT mixing kernel: one HBM round trip per stage chain."""
    sp, hp = sd.plan_stages(s), sd.plan_stages(d)
    flops = b * (d * sd.stage_flops(s, sp) + s * sd.stage_flops(d, hp))
    # chain: hidden DFT (1 round trip, re+im out), seq DFT (re+im in, re out)
    io = b * s * d * 2 * (1 + 2 + 2 + 1)
    return analytic(name, flops, io)


def _flash_analytic(name, b, s, h, hd, pattern="dense", pattern_arg=None):
    spec = AttentionSpec(
        impl="flash_kernel", pattern=pattern, pattern_arg=pattern_arg
    )
    # block-map density at THIS shape: at small S the tile grid can collapse
    # to one or two 128-wide kv tiles, where e.g. butterfly keeps every block
    # live (popcount(i^j) <= 1 always holds on a 2x2 map) and the row prices
    # identically to dense flash — emitted so degenerate rows self-explain
    density = sparsity.pattern_kv_density(
        pattern, s, s, spec.q_tile, spec.kv_tile, causal=False,
        pattern_arg=pattern_arg,
    )
    m = analytic(
        name,
        attention_flops(
            b, s, s, h, hd, causal=False, pattern=pattern,
            pattern_arg=pattern_arg, q_tile=spec.q_tile, kv_tile=spec.kv_tile,
        ),
        attention_hbm_bytes(spec, b, s, s, h, h, hd, causal=False),
    )
    return m, density


def rows(attn: str | None, pattern: str | None):
    out = []
    for name, b, s, d in CASES:
        h, hd = d // 64, 64
        flash_rows = []
        if "at-all" in name:
            q = sds((b, s, h, hd))
            m_dense = modeled(f"fig15/{name}/dense", dense_attention, q, q, q)
            m_fused = _fft_analytic(f"fig15/{name}/butterfly-fused", b, s, d)
            if attn:
                # the softmax path itself under the streaming-dataflow form:
                # fused Pallas flash attention (scores VMEM-resident)
                flash_rows.append(
                    _flash_analytic(f"fig15/{name}/attn-flash-fused", b, s, h, hd)
                    + (False,)
                )
                if pattern:
                    # block-sparse flash: the grid iterates only live tiles
                    flash_rows.append(_flash_analytic(
                        f"fig15/{name}/attn-flash-{pattern}", b, s, h, hd,
                        pattern=pattern,
                    ) + (True,))
        else:
            x = sds((b * s, d))
            w = sds((d, 3 * d))
            m_dense = modeled(f"fig15/{name}/dense", lambda x, w: x @ w, x, w)
            n2 = 1 << (d - 1).bit_length()
            bsz = 1 << mo.split_point(n2)
            nb = n2 // bsz
            flops = 3 * mo.monarch_flops(n2, bsz, b * s)
            io = 3 * (2 * b * s * n2 * 2 + (nb * bsz**2 + bsz * nb**2) * 2)
            m_fused = analytic(f"fig15/{name}/butterfly-fused", flops, io)
        speed = m_dense.t / m_fused.t
        out.append((m_dense, f"bound={m_dense.bound}"))
        out.append((m_fused, f"speedup_vs_dense={speed:.2f}x"))
        for m, density, is_sparse in flash_rows:
            if is_sparse and density >= 1.0:
                # the tile map degenerated to dense at this shape (e.g.
                # butterfly on a 2x2 grid at s=256 keeps every block live):
                # a "speedup_vs_dense" here would compare dense to itself
                # and mislead the trajectory diffs — mark it instead
                out.append((m, f"degenerate=dense density={density:.4f}"))
            else:
                out.append((
                    m,
                    f"speedup_vs_dense={m_dense.t / m.t:.2f}x "
                    f"density={density:.4f}",
                ))
    return out


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--attn", default=None, choices=["flash"],
                    help="add fused flash-attention softmax-path rows")
    ap.add_argument("--pattern", default=None,
                    choices=["butterfly", "strided", "global_window"],
                    help="add block-sparse flash rows under this pattern")
    ap.add_argument("--json", default="BENCH_attention.json",
                    help="machine-readable output path ('' disables)")
    # parse_known: benchmarks.run invokes main() under its own argv
    args, _ = ap.parse_known_args()
    if args.pattern and not args.attn:
        args.attn = "flash"  # sparse rows ARE flash rows — imply, don't drop

    rws = rows(args.attn, args.pattern)
    emit([(m.name, m.us, derived) for m, derived in rws])
    if args.json:
        write_bench_json(args.json, "fig15", [
            {
                "name": m.name,
                "us": round(m.us, 3),
                "flops": m.flops,
                "hbm_bytes": m.hbm_bytes,
                "bound": m.bound,
                "source": m.source,
                "derived": derived,
            }
            for m, derived in rws
        ])


if __name__ == "__main__":
    main()
