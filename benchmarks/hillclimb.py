import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""§Perf hillclimb driver: lower+probe config variants of the three chosen
cells and log hypothesis -> change -> before/after to results/hillclimb.jsonl.

    PYTHONPATH=src python -m benchmarks.hillclimb --cell mamba2
    PYTHONPATH=src python -m benchmarks.hillclimb --cell mixtral
    PYTHONPATH=src python -m benchmarks.hillclimb --cell vanilla
"""

import argparse
import dataclasses
import json

from repro.configs import registry


def _variants_mamba2():
    base = registry.get("mamba2-130m")
    return "mamba2-130m", "train_4k", [
        ("baseline(sp+tp)", base),
        ("pure_dp", dataclasses.replace(base, pure_dp=True)),
        ("pure_dp+chunk64", dataclasses.replace(base, pure_dp=True, ssm_chunk=64)),
        ("pure_dp+chunk256", dataclasses.replace(base, pure_dp=True, ssm_chunk=256)),
    ]


def _variants_mixtral():
    base = registry.get("mixtral-8x22b")
    return "mixtral-8x22b", "train_4k", [
        ("baseline(sp)", base),
        ("boundary_replicated", dataclasses.replace(base, boundary_mode="replicated")),
        (
            "boundary_replicated+bf16sm",
            dataclasses.replace(base, boundary_mode="replicated", attn_f32_softmax=False),
        ),
        (
            "bf16sm_only",
            dataclasses.replace(base, attn_f32_softmax=False),
        ),
    ]


def _variants_qwen3():
    base = registry.get("qwen3-0.6b")
    return "qwen3-0.6b", "train_4k", [
        ("baseline(sp)", base),
        ("pure_dp", dataclasses.replace(base, pure_dp=True)),
        ("boundary_replicated", dataclasses.replace(base, boundary_mode="replicated")),
        ("pure_dp+bf16sm", dataclasses.replace(base, pure_dp=True, attn_f32_softmax=False)),
    ]


CELLS = {
    "mamba2": _variants_mamba2,
    "mixtral": _variants_mixtral,
    "qwen3": _variants_qwen3,
}


def main():
    from repro.launch.dryrun import run_cell  # imports after XLA_FLAGS

    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", required=True, choices=list(CELLS))
    ap.add_argument("--only", default=None, help="run a single variant by name")
    ap.add_argument("--out", default="results/hillclimb.jsonl")
    args = ap.parse_args()

    arch, shape, variants = CELLS[args.cell]()
    with open(args.out, "a") as f:
        for name, cfg in variants:
            if args.only and name != args.only:
                continue
            rec = run_cell(arch, shape, multi_pod=False, cfg_override=cfg)
            rec["variant"] = name
            rec["cell"] = args.cell
            f.write(json.dumps(rec) + "\n")
            f.flush()
            if rec["status"] == "ok":
                r = rec["roofline"]
                print(
                    f"[{args.cell}/{name}] t_comp={r['t_compute']*1e3:.1f}ms "
                    f"t_mem={r['t_memory']*1e3:.1f}ms t_coll={r['t_collective']*1e3:.1f}ms "
                    f"dom={r['dominant']} useful={r['useful_ratio']:.2f} "
                    f"roofline={r['roofline_fraction']:.2%} "
                    f"mem/dev={rec['memory']['peak_est_bytes']/2**30:.1f}GiB",
                    flush=True,
                )
            else:
                print(f"[{args.cell}/{name}] {rec['status']}: {rec.get('error','')[:200]}", flush=True)


if __name__ == "__main__":
    main()
