"""AttentionSpec backend parity: flash_kernel vs xla_chunked vs naive oracle.

All kernel paths run in Pallas interpret mode (CPU host, set by ops wrappers).
Covers causal/non-causal, sliding window, GQA (h != kv), odd/prime S needing
padding, decode-step equivalence against the prefill last token, and the full
model integration (forward + prefill/decode through ModelConfig.attention).
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.attention import AttentionSpec, attention_hbm_bytes
from repro.kernels import ops, ref
from repro.models.layers import Runtime, chunked_attention, run_attention, run_decode_attention

RT = Runtime(mesh=None)
ATOL = 1e-4

# (b, s, h, kvh, hd, causal, window)
SWEEP = [
    (2, 16, 4, 4, 16, True, None),  # MHA causal
    (2, 16, 4, 2, 16, False, None),  # GQA non-causal
    (1, 37, 6, 3, 8, True, None),  # prime S: padding fallback
    (1, 37, 6, 3, 8, False, None),
    (2, 64, 4, 2, 16, True, 24),  # sliding window
    (1, 130, 8, 1, 32, True, None),  # MQA, S just over one kv tile
]


def _qkv(b, s, h, kvh, hd, key=0):
    ks = jax.random.split(jax.random.PRNGKey(key), 3)
    q = jax.random.normal(ks[0], (b, s, h, hd), jnp.float32)
    k = jax.random.normal(ks[1], (b, s, kvh, hd), jnp.float32)
    v = jax.random.normal(ks[2], (b, s, kvh, hd), jnp.float32)
    return q, k, v


@pytest.mark.parametrize("b,s,h,kvh,hd,causal,window", SWEEP)
def test_xla_chunked_matches_oracle(b, s, h, kvh, hd, causal, window):
    q, k, v = _qkv(b, s, h, kvh, hd)
    y = chunked_attention(q, k, v, causal=causal, window=window, chunk=16, rt=RT)
    y_ref = ref.mha_reference(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=ATOL, rtol=1e-4)


@pytest.mark.parametrize("b,s,h,kvh,hd,causal,window", SWEEP)
def test_flash_kernel_matches_oracle(b, s, h, kvh, hd, causal, window):
    q, k, v = _qkv(b, s, h, kvh, hd)
    spec = AttentionSpec(impl="flash_kernel", q_tile=16, kv_tile=128)
    y = ops.flash_attention(q, k, v, causal=causal, window=window, spec=spec)
    y_ref = ref.mha_reference(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=ATOL, rtol=1e-4)


def test_flash_kernel_cross_attention_lengths():
    """s_q != s_kv (encoder-decoder cross-attention) under both impls."""
    b, sq, skv, h, kvh, hd = 2, 15, 70, 4, 2, 16
    ks = jax.random.split(jax.random.PRNGKey(11), 3)
    q = jax.random.normal(ks[0], (b, sq, h, hd), jnp.float32)
    k = jax.random.normal(ks[1], (b, skv, kvh, hd), jnp.float32)
    v = jax.random.normal(ks[2], (b, skv, kvh, hd), jnp.float32)
    y_ref = ref.mha_reference(q, k, v, causal=False)
    for impl in ("xla_chunked", "flash_kernel"):
        y = run_attention(
            q, k, v, spec=AttentionSpec(impl=impl, chunk=8, q_tile=8),
            causal=False, rt=RT,
        )
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=ATOL, rtol=1e-4)


def test_run_attention_impl_parity():
    q, k, v = _qkv(2, 24, 4, 2, 16)
    ys = {
        impl: run_attention(
            q, k, v, spec=AttentionSpec(impl=impl, chunk=8, q_tile=8), causal=True, rt=RT
        )
        for impl in ("xla_chunked", "flash_kernel")
    }
    np.testing.assert_allclose(
        np.asarray(ys["xla_chunked"]), np.asarray(ys["flash_kernel"]), atol=ATOL, rtol=1e-4
    )


def test_chunked_prime_length_pads_instead_of_unrolling():
    """gcd fallback would build 37 chunks; padding builds ceil(37/16)=3."""
    q, k, v = _qkv(1, 37, 2, 2, 8, key=3)
    jaxpr = jax.make_jaxpr(
        lambda q, k, v: chunked_attention(q, k, v, causal=True, chunk=16, rt=RT)
    )(q, k, v)
    n_dots = sum(1 for eqn in jaxpr.eqns if eqn.primitive.name == "dot_general")
    # 3 chunks x 2 einsums; the gcd fallback would emit 37 x 2
    assert n_dots <= 8, f"tail fallback statically unrolled: {n_dots} dot_generals"
    # and correctness of the masked tail
    y = chunked_attention(q, k, v, causal=True, chunk=16, rt=RT)
    y_ref = ref.mha_reference(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=ATOL, rtol=1e-4)


@pytest.mark.parametrize("impl", ["xla_chunked", "flash_kernel"])
def test_decode_matches_prefill_last_token(impl):
    b, s, h, kvh, hd = 2, 24, 4, 2, 16
    q, k, v = _qkv(b, s, h, kvh, hd, key=5)
    spec = AttentionSpec(impl=impl, chunk=8, q_tile=8)
    full = run_attention(q, k, v, spec=spec, causal=True, rt=RT)
    last = run_decode_attention(q[:, -1], k, v, jnp.int32(s), spec=spec, rt=RT)
    np.testing.assert_allclose(
        np.asarray(last), np.asarray(full[:, -1]), atol=ATOL, rtol=1e-4
    )


@pytest.mark.parametrize("impl", ["xla_chunked", "flash_kernel"])
def test_decode_cur_len_masks_cache_tail(impl):
    """Cache rows beyond cur_len (unwritten slots) must not leak in."""
    b, h, kvh, hd, cache, cur = 2, 4, 2, 16, 160, 97
    ks = jax.random.split(jax.random.PRNGKey(7), 3)
    q = jax.random.normal(ks[0], (b, h, hd), jnp.float32)
    kc = jax.random.normal(ks[1], (b, cache, kvh, hd), jnp.float32)
    vc = jax.random.normal(ks[2], (b, cache, kvh, hd), jnp.float32)
    spec = AttentionSpec(impl=impl)
    y = run_decode_attention(q, kc, vc, jnp.int32(cur), spec=spec, rt=RT)
    y_ref = ref.mha_decode_reference(q, kc[:, :cur], vc[:, :cur], None)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=ATOL, rtol=1e-4)


@pytest.mark.parametrize("impl", ["xla_chunked", "flash_kernel"])
def test_decode_per_row_cur_len(impl):
    """Ragged batches: every request masks the cache at its OWN live length.

    Regression for the (1, Skv) broadcast bias: one cur_len row shared by the
    whole batch silently mis-masked every other request."""
    b, h, kvh, hd, cache = 3, 4, 2, 16, 160
    ks = jax.random.split(jax.random.PRNGKey(13), 3)
    q = jax.random.normal(ks[0], (b, h, hd), jnp.float32)
    kc = jax.random.normal(ks[1], (b, cache, kvh, hd), jnp.float32)
    vc = jax.random.normal(ks[2], (b, cache, kvh, hd), jnp.float32)
    cur = jnp.array([5, 97, 160], jnp.int32)  # heterogeneous live lengths
    spec = AttentionSpec(impl=impl)
    y = run_decode_attention(q, kc, vc, cur, spec=spec, rt=RT)
    for i in range(b):
        c = int(cur[i])
        y_i = ref.mha_decode_reference(q[i : i + 1], kc[i : i + 1, :c], vc[i : i + 1, :c])
        np.testing.assert_allclose(
            np.asarray(y[i : i + 1]), np.asarray(y_i), atol=ATOL, rtol=1e-4,
            err_msg=f"row {i} (cur_len {c})",
        )
    # per-row ref with the vector mask agrees too
    y_ref = ref.mha_decode_reference(q, kc, vc, cur)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=ATOL, rtol=1e-4)


def test_ragged_accounting_reduces_to_uniform():
    """Ragged FLOP/byte accounting == per-row sum; uniform rows == batched."""
    from repro.core.attention import (
        attention_flops,
        ragged_attention_flops,
        ragged_attention_hbm_bytes,
    )

    spec = AttentionSpec(impl="xla_chunked")
    h, kvh, hd = 16, 8, 64
    lens = [128, 512, 1024, 32]
    fl = ragged_attention_flops(1, lens, h, hd)
    assert fl == sum(attention_flops(1, 1, l, h, hd, causal=False) for l in lens)
    uniform = [256] * 4
    assert ragged_attention_hbm_bytes(spec, 1, uniform, h, kvh, hd) == (
        attention_hbm_bytes(spec, 4, 1, 256, h, kvh, hd, causal=False)
    )


def test_flash_kernel_is_differentiable():
    """Training through the fused form falls back to the XLA VJP."""
    q, k, v = _qkv(1, 16, 2, 2, 8, key=9)
    spec = AttentionSpec(impl="flash_kernel", q_tile=8)

    def loss(q, k, v):
        return jnp.sum(run_attention(q, k, v, spec=spec, causal=True, rt=RT) ** 2)

    g_flash = jax.grad(loss, argnums=(0, 1, 2))(q, k, v)
    g_ref = jax.grad(
        lambda q, k, v: jnp.sum(ref.mha_reference(q, k, v, causal=True) ** 2),
        argnums=(0, 1, 2),
    )(q, k, v)
    for gf, gr in zip(g_flash, g_ref):
        np.testing.assert_allclose(np.asarray(gf), np.asarray(gr), atol=1e-3, rtol=1e-3)


def test_model_forward_parity_across_impls():
    """Full transformer forward: flash_kernel == xla_chunked logits."""
    from repro.configs import registry
    from repro.models import model as M
    from repro.models import transformer as tf

    base = dataclasses.replace(registry.get("yi-6b", reduced=True), dtype="float32")
    params = M.init_params(base, jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, base.vocab)
    outs = {}
    for impl in ("xla_chunked", "flash_kernel"):
        cfg = dataclasses.replace(base, attention=AttentionSpec(impl=impl))
        outs[impl], _ = tf.forward(params, cfg, {"tokens": tokens}, RT, mode="train")
    scale = float(jnp.max(jnp.abs(outs["xla_chunked"])))
    err = float(jnp.max(jnp.abs(outs["xla_chunked"] - outs["flash_kernel"])))
    assert err < 1e-4 * max(scale, 1.0), err


def test_model_decode_parity_flash():
    """prefill + decode_step under flash_kernel matches the full forward."""
    from repro.configs import registry
    from repro.models import model as M
    from repro.models import transformer as tf

    cfg = dataclasses.replace(
        registry.get("qwen3-0.6b+flash", reduced=True), dtype="float32"
    )
    assert cfg.attention.impl == "flash_kernel"
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0, cfg.vocab)
    full, _ = tf.forward(params, cfg, {"tokens": tokens}, RT, mode="train")
    lp, caches = tf.prefill(params, cfg, {"tokens": tokens[:, :-1]}, RT, cache_len=12)
    ld, _ = tf.decode_step(params, cfg, caches, tokens[:, -1:], jnp.int32(11), RT)
    tol = 2e-4 * float(jnp.max(jnp.abs(full)))
    assert float(jnp.max(jnp.abs(lp - full[:, -2]))) < tol, "prefill logits diverge"
    assert float(jnp.max(jnp.abs(ld - full[:, -1]))) < tol, "decode logits diverge"


def test_fused_form_saves_score_traffic():
    """The accounting that motivates the refactor: fused << chunked bytes."""
    spec_x = AttentionSpec(impl="xla_chunked")
    spec_f = AttentionSpec(impl="flash_kernel")
    args = (4, 4096, 4096, 16, 16, 64)
    assert attention_hbm_bytes(spec_f, *args) < 0.25 * attention_hbm_bytes(spec_x, *args)
