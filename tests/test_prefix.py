"""Radix-tree prefix cache: shared-prefix runs must be token-identical to
cold-start runs across patterns x backends x scheduling modes, CoW must
isolate sibling divergence, and eviction-then-readmit must stay correct.

The sharing contract: butterfly (and every other static) live-tile map is a
pure function of position, so prefix KV tiles are bit-identical across
requests — aliasing them through the page table changes WHICH physical rows
a request reads, never their values.  Every test therefore reduces to: same
tokens out, fewer prefill tokens / resident pages in, pool drained after.
"""

import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import registry
from repro.core.attention import AttentionSpec
from repro.launch.mesh import make_local_mesh
from repro.launch.serve import PagePool, RadixCache, Request, ServeLoop
from repro.models import model as M


def _f32(cfg):
    return dataclasses.replace(cfg, dtype="float32", capacity_factor=8.0)


def _cfg(pattern="dense", arg=None, impl="xla_chunked"):
    return dataclasses.replace(
        _f32(registry.get("qwen3-0.6b", reduced=True)),
        attention=AttentionSpec(impl=impl, pattern=pattern, pattern_arg=arg),
    )


def _shared_reqs(cfg, *, prefix_len=200, suffixes=(60, 30, 45), max_new=3,
                 seed=3):
    """A donor plus siblings sharing `prefix_len` tokens, all with distinct
    suffixes — the donor's insert makes every later request a radix hit."""
    rng = np.random.default_rng(seed)
    shared = rng.integers(0, cfg.vocab, size=prefix_len).astype(np.int32)
    prompts = [
        np.concatenate([shared, rng.integers(0, cfg.vocab, size=s).astype(np.int32)])
        for s in suffixes
    ]
    return [Request(uid=i, prompt=p, max_new=max_new)
            for i, p in enumerate(prompts)]


# --------------------------------------------------------------------------
# RadixCache unit behaviour
# --------------------------------------------------------------------------


def test_radix_match_insert_split():
    """Insert/match at page granularity: full-page prefixes are cacheable,
    mid-edge divergence splits at the page boundary, and the tree holds one
    reference per owned page (so eviction is the only way pages die)."""
    page = 4
    pool = PagePool(16)
    radix = RadixCache(pool, page)
    toks = np.arange(12, dtype=np.int32)
    pages = [pool.alloc() for _ in range(3)]
    radix.insert(toks, pages)
    assert radix.held_pages == 3
    assert all(pool.page_refs(p) == 2 for p in pages)  # caller + tree
    for p in pages:
        pool.release(p)

    # exact and partial matches, at page granularity
    m, mp = radix.match(toks, len(toks))
    assert m == 12 and [int(x) for x in mp] == pages
    div = np.concatenate([toks[:6], np.array([99, 98], np.int32)])
    m2, mp2 = radix.match(div, len(div))
    assert m2 == 6  # mid-page divergence inside page 1: alias pages 0 and 1
    assert [int(x) for x in mp2] == pages[:2]
    # sub-page matches come back raw; the engine's admission path discards
    # them (m >= page required) since CoW would copy the tile anyway
    m3, mp3 = radix.match(np.array([0, 1, 99], np.int32), 3)
    assert m3 == 2 and len(mp3) == 1

    # inserting the divergent branch splits the shared edge page-aligned
    dp = pool.alloc()
    radix.insert(div, [pages[0], dp])
    assert radix.held_pages == 4  # pages 0,1,2 + the divergent page
    m4, mp4 = radix.match(div, len(div))
    assert m4 == 8 and [int(x) for x in mp4] == [pages[0], dp]
    m5, mp5 = radix.match(toks, len(toks))
    assert m5 == 12 and [int(x) for x in mp5] == pages
    pool.release(dp)
    radix.clear()
    assert pool.in_use == 0


def test_radix_evict_lru_only_unreferenced():
    """Eviction walks childless leaves in LRU order and only frees pages no
    request still reads (refs == 1, i.e. tree-only)."""
    page = 2
    pool = PagePool(8)
    radix = RadixCache(pool, page)
    a = np.array([0, 1, 2, 3], np.int32)
    b = np.array([9, 8, 7, 6], np.int32)
    pa = [pool.alloc(), pool.alloc()]
    pb = [pool.alloc(), pool.alloc()]
    radix.insert(a, pa)  # older (lower LRU clock)
    radix.insert(b, pb)
    # drop caller refs on a (tree-only); KEEP them on b — a live request
    # still aliases b's pages, so b must survive eviction
    for p in pa:
        pool.release(p)
    freed = radix.evict(2)
    assert freed == 2  # branch a (LRU, unreferenced) went; b survived
    assert radix.match(b, 4)[0] == 4 and radix.match(a, 4)[0] == 0
    for p in pb:
        pool.release(p)
    radix.clear()
    assert pool.in_use == 0


# --------------------------------------------------------------------------
# gather_pages with aliased tables
# --------------------------------------------------------------------------


def test_gather_pages_aliased_tables_parity():
    """Two rows whose page tables alias the same physical prefix page must
    gather bit-identical prefix rows, equal to a private-copy layout — the
    read side needs no CoW awareness."""
    import jax.numpy as jnp

    from repro.models.layers import gather_pages

    page, n_pages, KV, hd = 4, 6, 2, 3
    rng = np.random.default_rng(0)
    pool = jnp.asarray(rng.normal(size=(n_pages * page, KV, hd)).astype(np.float32))
    # rows 0 and 1 share physical page 2 for vtile 0; diverge on vtile 1
    aliased = jnp.asarray(np.array([[2, 0], [2, 1]], np.int32))
    private = jnp.asarray(np.array([[2, 0], [3, 1]], np.int32))
    # make the "private copy" page 3 hold the same values as shared page 2
    pool_priv = pool.at[3 * page:4 * page].set(pool[2 * page:3 * page])
    out_a = np.asarray(gather_pages(pool, aliased, 2 * page, page))
    out_p = np.asarray(gather_pages(pool_priv, private, 2 * page, page))
    # shared vtile 0 rows identical across the two rows of the aliased table
    np.testing.assert_array_equal(out_a[0][:page], out_a[1][:page])
    # and aliasing == private copy, bit for bit
    np.testing.assert_array_equal(out_a, out_p)


# --------------------------------------------------------------------------
# Engine: shared-prefix vs cold-start token identity (the parity matrix)
# --------------------------------------------------------------------------

# pattern, pattern_arg, impl, scheduling mode
PREFIX_CASES = [
    ("dense", None, "xla_chunked", "admission"),
    ("dense", None, "flash_kernel", "admission"),
    ("dense", None, "xla_chunked", "chunked"),
    ("dense", None, "flash_kernel", "chunked"),
    ("window", 16, "xla_chunked", "admission"),
    ("window", 16, "flash_kernel", "chunked"),
    ("butterfly", None, "xla_chunked", "chunked"),
    ("butterfly", None, "flash_kernel", "admission"),
]


@pytest.mark.parametrize("pattern,arg,impl,mode", PREFIX_CASES)
def test_shared_prefix_matches_cold_start(pattern, arg, impl, mode):
    """With the radix cache on, requests sharing a long prefix must emit
    EXACTLY the tokens the cold-start (prefix_cache=False) engine emits —
    for every pattern and backend, both scheduler modes, GQA included
    (reduced qwen3 is 4 query heads over 2 kv heads).  Sharing must actually
    engage (prefix_hit_tokens > 0) and fewer prompt tokens must be prefilled
    than the cold run; the pool drains either way."""
    cfg = _cfg(pattern, arg, impl)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    mesh = make_local_mesh()
    chunked = mode == "chunked"
    kw = dict(batch=2, cache_len=512, chunked=chunked, chunk_size=32,
              paged=True)

    cold = ServeLoop(cfg, mesh, params, prefix_cache=False, **kw)
    ref = cold.run(_shared_reqs(cfg))
    warm = ServeLoop(cfg, mesh, params, **kw)
    out = warm.run(_shared_reqs(cfg))

    for r1, r2 in zip(ref, out):
        assert r2.generated == r1.generated, f"uid {r1.uid}"
    assert cold.stats["prefix_hit_tokens"] == 0
    assert warm.stats["prefix_hit_tokens"] > 0
    assert warm.stats["prefill_tokens"] < cold.stats["prefill_tokens"]
    warm.close()  # drops the persistent radix refs; raises on leaks
    cold.close()
    assert warm.pool.in_use == 0 and cold.pool.in_use == 0


def test_cow_sibling_divergence_isolation():
    """Mid-page divergence: the donor caches 2 full pages (260 tokens), the
    sibling shares only 200 — its first suffix write lands inside the shared
    frontier page and MUST fork a private copy (cow_forks >= 1) while both
    requests' tokens stay identical to the cold engine (the donor's view of
    the shared page is never corrupted)."""
    cfg = _cfg()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    mesh = make_local_mesh()
    rng = np.random.default_rng(3)
    shared = rng.integers(0, cfg.vocab, size=200).astype(np.int32)
    donor = np.concatenate([shared, rng.integers(0, cfg.vocab, size=60).astype(np.int32)])
    sib = np.concatenate([shared, rng.integers(0, cfg.vocab, size=30).astype(np.int32)])

    def mk():
        return [Request(uid=0, prompt=donor, max_new=4),
                Request(uid=1, prompt=sib, max_new=4)]

    for chunked in (False, True):
        kw = dict(batch=1, cache_len=512, chunked=chunked, chunk_size=32)
        ref = ServeLoop(cfg, mesh, params, **kw).run(mk())
        loop = ServeLoop(cfg, mesh, params, paged=True, **kw)
        out = loop.run(mk())
        for r1, r2 in zip(ref, out):
            assert r2.generated == r1.generated, (chunked, r1.uid)
        assert loop.stats["cow_forks"] >= 1, chunked
        assert loop.stats["prefix_hit_tokens"] == 200, chunked
        loop.close()
        assert loop.pool.in_use == 0


def test_eviction_then_readmit_correct():
    """Pool pressure must evict cached prefixes (LRU) instead of
    backpressuring forever, and a LATER request re-using an evicted prefix
    simply re-prefills cold — same tokens, pool drained."""
    cfg = _cfg()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    mesh = make_local_mesh()
    rng = np.random.default_rng(7)
    pa = rng.integers(0, cfg.vocab, size=300).astype(np.int32)
    pb = rng.integers(0, cfg.vocab, size=300).astype(np.int32)
    # a, b, a again: caching a (3 pages) then b forces a's eviction in a
    # 4-page pool; the third request re-admits the evicted prefix
    def mk():
        return [Request(uid=0, prompt=pa, max_new=3),
                Request(uid=1, prompt=pb, max_new=3),
                Request(uid=2, prompt=pa.copy(), max_new=3)]

    kw = dict(batch=1, cache_len=512, chunked=True, chunk_size=32)
    ref = ServeLoop(cfg, mesh, params, **kw).run(mk())
    loop = ServeLoop(cfg, mesh, params, paged=True, pool_pages=4, **kw)
    out = loop.run(mk())
    for r1, r2 in zip(ref, out):
        assert r2.generated == r1.generated, f"uid {r1.uid}"
    assert loop.stats["prefix_evicted_pages"] > 0
    loop.close()
    assert loop.pool.in_use == 0


def test_prefix_cache_off_is_pr5_behaviour():
    """prefix_cache=False must reproduce the PR 5 engine exactly: no radix
    stats movement, prefill_tokens == sum of prompt lengths."""
    cfg = _cfg()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    reqs = _shared_reqs(cfg)
    total = sum(len(r.prompt) for r in reqs)
    loop = ServeLoop(cfg, make_local_mesh(), params, batch=2, cache_len=512,
                     chunked=True, chunk_size=32, paged=True,
                     prefix_cache=False)
    loop.run(reqs)
    assert loop.stats["prefix_hits"] == 0
    assert loop.stats["prefill_tokens"] == total
    loop.close()
    assert loop.pool.in_use == 0
