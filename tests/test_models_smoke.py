"""Per-arch smoke tests: reduced config, one forward + one grad step on CPU,
output shapes + no NaNs.  Full configs are exercised only via the dry-run."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.models import model as M
from repro.models import transformer as tf
from repro.models.layers import Runtime

RT = Runtime(mesh=None)
B, S = 2, 16


def _batch(cfg, key=1):
    tokens = jax.random.randint(jax.random.PRNGKey(key), (B, S), 0, cfg.vocab)
    batch = {"tokens": tokens, "labels": tokens}
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(
            jax.random.PRNGKey(key + 1), (B, cfg.enc_seq, cfg.d_model)
        )
    if cfg.n_img_tokens:
        batch["img_embeds"] = jax.random.normal(
            jax.random.PRNGKey(key + 2), (B, cfg.n_img_tokens, cfg.d_model)
        )
    return batch


@pytest.mark.parametrize("arch", registry.ASSIGNED + registry.PAPER)
def test_arch_smoke_forward_and_grad(arch):
    cfg = registry.get(arch, reduced=True)
    cfg.validate()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg)

    logits, aux = tf.forward(params, cfg, batch, RT, mode="train")
    assert logits.shape == (B, S, cfg.vocab)
    assert not bool(jnp.any(jnp.isnan(logits))), "NaN logits"

    loss, metrics = tf.loss_fn(params, cfg, batch, RT)
    assert np.isfinite(float(loss))

    grads = jax.grad(lambda p: tf.loss_fn(p, cfg, batch, RT)[0])(params)
    gn = float(
        jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2) for g in jax.tree.leaves(grads)))
    )
    assert np.isfinite(gn) and gn > 0


@pytest.mark.parametrize("variant", ["+bpmm", "+bpmm-r2", "+bpmm-k"])
def test_butterfly_variants_smoke(variant):
    cfg = registry.get("yi-6b" + variant, reduced=True)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    loss, _ = tf.loss_fn(params, cfg, _batch(cfg), RT)
    assert np.isfinite(float(loss))


def test_fft_variant_on_encoder_arch():
    cfg = registry.get("fabnet-base+fft", reduced=True)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    loss, _ = tf.loss_fn(params, cfg, _batch(cfg), RT)
    assert np.isfinite(float(loss))


def test_fft_variant_rejected_on_causal_arch():
    with pytest.raises(ValueError, match="causal"):
        registry.get("yi-6b+fft", reduced=True)


def test_butterfly_param_compression():
    """The paper's premise: butterfly shrinks linear-layer parameters."""
    dense = registry.get("yi-6b")
    bfly = registry.get("yi-6b+bpmm")
    assert M.count_params(bfly) < 0.35 * M.count_params(dense)


def test_param_counts_match_public_sizes():
    """Full configs should land near the published parameter counts."""
    expect = {
        "mamba2-130m": (0.10e9, 0.22e9),
        "yi-6b": (5.5e9, 6.5e9),
        "yi-34b": (32e9, 36e9),
        "qwen2-72b": (70e9, 76e9),
        "mixtral-8x22b": (135e9, 145e9),
        "dbrx-132b": (125e9, 137e9),
        "jamba-1.5-large": (370e9, 420e9),
        "whisper-base": (0.06e9, 0.12e9),
        "qwen3-0.6b": (0.55e9, 0.80e9),
        "internvl2-26b": (18e9, 27e9),  # LM backbone only (ViT is stubbed)
    }
    for arch, (lo, hi) in expect.items():
        n = M.count_params(registry.get(arch))
        assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B not in [{lo/1e9}, {hi/1e9}]"
