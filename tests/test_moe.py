"""MoE routing invariants: capacity, gate normalisation, EP/TP paths."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.api import ButterflyPolicy
from repro.distributed.sharding import init_tree
from repro.models import moe
from repro.models.config import ModelConfig
from repro.models.layers import Runtime

RT = Runtime(mesh=None)


def _setup(e=4, k=2, cap=8.0, butterfly=False):
    pol = ButterflyPolicy(impl="monarch", on_experts=True, max_block=16) if butterfly else ButterflyPolicy()
    cfg = ModelConfig(
        name="t", family="moe", n_layers=1, d_model=32, vocab=64, n_heads=2,
        n_kv_heads=2, head_dim=16, d_ff=64, n_experts=e, top_k=k,
        capacity_factor=cap, butterfly=pol,
    )
    specs = moe.moe_specs(cfg, 1, "ep")
    params = init_tree(specs, jax.random.PRNGKey(0))
    params = jax.tree.map(lambda a: a[0], params)  # drop the period dim
    return cfg, params


def test_moe_runs_and_shapes():
    cfg, params = _setup()
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 32))
    y, aux = moe.apply_moe(params, cfg, x, RT)
    assert y.shape == x.shape
    assert np.isfinite(float(aux)) and float(aux) > 0


def test_generous_capacity_equals_topk_dense_mixture():
    """With capacity >= T no tokens drop: output == explicit top-k mixture."""
    cfg, params = _setup(e=4, k=2, cap=100.0)
    x = jax.random.normal(jax.random.PRNGKey(2), (1, 8, 32))
    y, _ = moe.apply_moe(params, cfg, x, RT)

    x2 = x.reshape(-1, 32)
    logits = (x2 @ params["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, -1)
    gate, idx = jax.lax.top_k(probs, 2)
    gate = gate / gate.sum(-1, keepdims=True)

    def expert(e_i, xi):
        h = jax.nn.silu(xi @ params["w1"][e_i]) * (xi @ params["w3"][e_i])
        return h @ params["w2"][e_i]

    y_ref = jnp.stack(
        [
            sum(gate[t, j] * expert(int(idx[t, j]), x2[t]) for j in range(2))
            for t in range(x2.shape[0])
        ]
    )
    np.testing.assert_allclose(
        np.asarray(y.reshape(-1, 32), np.float32),
        np.asarray(y_ref, np.float32),
        rtol=2e-2, atol=2e-2,
    )


def test_capacity_drops_tokens():
    """With capacity 1 per expert most token copies must drop (output norm
    shrinks but stays finite)."""
    cfg, params = _setup(e=4, k=2, cap=0.125)
    x = jax.random.normal(jax.random.PRNGKey(3), (1, 16, 32))
    y, _ = moe.apply_moe(params, cfg, x, RT)
    assert np.isfinite(float(jnp.abs(y).max()))
    y_full, _ = moe.apply_moe(params, dataclasses.replace(cfg, capacity_factor=100.0), x, RT)
    assert float(jnp.abs(y).sum()) < float(jnp.abs(y_full).sum())


def test_moe_with_butterfly_experts():
    cfg, params = _setup(butterfly=True)
    x = jax.random.normal(jax.random.PRNGKey(4), (2, 8, 32))
    y, aux = moe.apply_moe(params, cfg, x, RT)
    assert y.shape == x.shape and not bool(jnp.any(jnp.isnan(y)))
