"""One cache substrate: mod-window ring page tables and read-only encoder
cross page ranges serve token-identically to the seed contiguous engines.

The contiguous admission engine (``chunked=False, paged=False``) is the
parity baseline here — it is the seed ring/encdec implementation the paged
substrate retires.  Every case decodes past the window (ring wrap), and the
qwen3 reduced config is GQA (4 query heads over 2 kv heads)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.core.attention import AttentionSpec
from repro.launch.mesh import make_local_mesh
from repro.launch.serve import Request, ServeLoop
from repro.models import model as M


def _f32(cfg):
    return dataclasses.replace(cfg, dtype="float32", capacity_factor=8.0)


def _cfg(arch, impl, **tweaks):
    return dataclasses.replace(
        _f32(registry.get(arch, reduced=True)),
        attention=AttentionSpec(impl=impl), **tweaks,
    )


# distinct prompt lengths / budgets; window cases decode past pos=window
LENS = [(7, 8), (3, 5), (12, 3)]


def _mkreqs(cfg, extras=None, seed=3):
    rng = np.random.default_rng(seed)
    return [
        Request(uid=i, prompt=rng.integers(0, cfg.vocab, size=ln).astype(np.int32),
                max_new=mn, extras=dict(extras or {}))
        for i, (ln, mn) in enumerate(LENS)
    ]


def _tokens(done):
    return {r.uid: list(r.generated) for r in done}


def _contiguous_ref(cfg, params, extras=None):
    loop = ServeLoop(cfg, make_local_mesh(), params, batch=3, cache_len=24)
    return _tokens(loop.run(_mkreqs(cfg, extras)))


# --------------------------------------------------------------------------
# Sliding window through the mod-window ring page table
# --------------------------------------------------------------------------


@pytest.mark.parametrize("mode", ["admission", "chunked"])
@pytest.mark.parametrize("impl", ["xla_chunked", "flash_kernel"])
def test_ring_paged_matches_contiguous(mode, impl):
    """window=10 qwen3 (GQA) through the paged ring — both scheduler modes,
    both backends — emits exactly the contiguous admission engine's tokens.
    chunked=True auto-upgrades to paged (no contiguous chunked ring path)."""
    cfg = _cfg("qwen3-0.6b", impl, sliding_window=10)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    ref = _contiguous_ref(cfg, params)

    kw = dict(batch=3, cache_len=24)
    if mode == "chunked":
        loop = ServeLoop(cfg, make_local_mesh(), params, chunked=True,
                         chunk_size=4, **kw)
        assert loop.paged, "chunked ring must auto-upgrade to the paged engine"
    else:
        loop = ServeLoop(cfg, make_local_mesh(), params, paged=True, **kw)
    got = _tokens(loop.run(_mkreqs(cfg)))
    assert got == ref, f"{mode}/{impl}: {got} != {ref}"
    # ring requests hold a FIXED page set: peak residency is bounded by the
    # ring reservation, never the full prompt+decode span
    assert loop.stats["pool_peak_pages"] <= 3 * loop.ring_tiles
    loop.close()
    assert loop.pool.in_use == 0


def test_ring_radix_disabled():
    """Ring slots are reused in phase — token-keyed aliasing would serve a
    later lap's KV for an earlier position.  The radix must be OFF."""
    cfg = _cfg("qwen3-0.6b", "xla_chunked", sliding_window=10)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    loop = ServeLoop(cfg, make_local_mesh(), params, batch=2, cache_len=24,
                     paged=True)
    assert loop.radix is None and not loop.prefix_cache
    loop.close()


# --------------------------------------------------------------------------
# Encoder-decoder through read-only shared cross page ranges
# --------------------------------------------------------------------------


@pytest.mark.parametrize("mode", ["admission", "chunked"])
def test_encdec_paged_matches_contiguous(mode):
    """whisper through the paged engine: the encoder output prefills once
    into refcounted cross pages, every decoder aliases the range read-only
    (CoW never triggers), tokens identical to the contiguous engine."""
    cfg = _cfg("whisper-base", "xla_chunked")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    extras = {"frames": jax.random.normal(
        jax.random.PRNGKey(2), (cfg.enc_seq, cfg.d_model), jnp.float32)}
    ref = _contiguous_ref(cfg, params, extras)

    kw = dict(batch=3, cache_len=24)
    if mode == "chunked":
        loop = ServeLoop(cfg, make_local_mesh(), params, chunked=True,
                         chunk_size=4, **kw)
        assert loop.paged, "chunked encdec must auto-upgrade to paged"
    else:
        loop = ServeLoop(cfg, make_local_mesh(), params, paged=True, **kw)
    got = _tokens(loop.run(_mkreqs(cfg, extras)))
    assert got == ref, f"{mode}: {got} != {ref}"
    # all three requests share one frames input: one encode, two aliases
    assert loop.stats["encode_calls"] == 1
    assert loop.stats["prefix_hits"] >= 2
    assert loop.stats["cow_forks"] == 0, "cross ranges are read-only"
    loop.close()
    assert loop.pool.in_use == 0 and loop.cross_pool.in_use == 0


def test_encdec_shared_encoder_warm_run():
    """The frames-keyed encoder cache persists across run(): a warm second
    run with the same frames encodes NOTHING (encode_calls == 0, every
    admission a prefix hit) and still matches the contiguous tokens."""
    cfg = _cfg("whisper-base", "xla_chunked")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    extras = {"frames": jax.random.normal(
        jax.random.PRNGKey(2), (cfg.enc_seq, cfg.d_model), jnp.float32)}
    ref = _contiguous_ref(cfg, params, extras)

    loop = ServeLoop(cfg, make_local_mesh(), params, batch=3, cache_len=24,
                     paged=True)
    assert _tokens(loop.run(_mkreqs(cfg, extras))) == ref
    assert _tokens(loop.run(_mkreqs(cfg, extras))) == ref
    assert loop.stats["encode_calls"] == 0
    assert loop.stats["prefix_hits"] == len(LENS)
    assert loop.stats["prefix_hit_tokens"] == len(LENS) * cfg.enc_seq
    loop.close()
    assert loop.cross_pool.in_use == 0


# --------------------------------------------------------------------------
# Persistence across run() + explicit close()  (satellite 1)
# --------------------------------------------------------------------------


def test_radix_persists_across_runs():
    """The radix tree survives run() boundaries: a warm second run of the
    same prompt admits with prefix_hits > 0 and skips the matched prefill."""
    cfg = _cfg("qwen3-0.6b", "xla_chunked")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    loop = ServeLoop(cfg, make_local_mesh(), params, batch=2, cache_len=128,
                     paged=True, page=16)
    rng = np.random.default_rng(7)
    prompt = rng.integers(0, cfg.vocab, size=80).astype(np.int32)

    def mk():
        return [Request(uid=0, prompt=prompt.copy(), max_new=3)]

    r1 = _tokens(loop.run(mk()))
    assert loop.stats["prefix_hits"] == 0  # cold
    r2 = _tokens(loop.run(mk()))
    assert r2 == r1
    assert loop.stats["prefix_hits"] > 0, "warm run must hit the radix"
    assert loop.stats["prefill_tokens"] < len(prompt)
    assert loop.pool.in_use > 0, "the tree holds pages between runs"
    loop.close()
    assert loop.pool.in_use == 0


def test_close_detects_leaks():
    """close() raises on undrained pages — the drain assertion moved out of
    run() (persistent caches legitimately hold pages between runs)."""
    cfg = _cfg("qwen3-0.6b", "xla_chunked")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    loop = ServeLoop(cfg, make_local_mesh(), params, batch=2, cache_len=128,
                     paged=True)
    leaked = loop.pool.alloc()
    with pytest.raises(RuntimeError, match="leak"):
        loop.close()
    loop.pool.release(leaked)
    loop.close()  # clean close after the leak is fixed
    loop.close()  # and idempotent


# --------------------------------------------------------------------------
# The one surviving rejection
# --------------------------------------------------------------------------


def test_img_token_extras_still_rejected():
    """Image-token extras have no chunked/paged write path — the engine must
    refuse loudly instead of silently dropping the patch tokens."""
    cfg = dataclasses.replace(_cfg("qwen3-0.6b", "xla_chunked"), n_img_tokens=4)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    for kw in (dict(paged=True), dict(chunked=True)):
        with pytest.raises(ValueError, match="image-token"):
            ServeLoop(cfg, make_local_mesh(), params, batch=2, cache_len=24,
                      **kw)
