"""Data pipeline: determinism, resumability, non-degenerate statistics."""

import numpy as np

from repro.data.pipeline import DataConfig, host_batch


def test_batches_deterministic_per_step():
    cfg = DataConfig(vocab=100, seq_len=32, global_batch=4, seed=7)
    a = host_batch(cfg, 5)
    b = host_batch(cfg, 5)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    np.testing.assert_array_equal(a["labels"], b["labels"])


def test_batches_differ_across_steps_and_seeds():
    cfg = DataConfig(vocab=100, seq_len=32, global_batch=4, seed=7)
    assert not np.array_equal(host_batch(cfg, 1)["tokens"], host_batch(cfg, 2)["tokens"])
    cfg2 = DataConfig(vocab=100, seq_len=32, global_batch=4, seed=8)
    assert not np.array_equal(host_batch(cfg, 1)["tokens"], host_batch(cfg2, 1)["tokens"])


def test_labels_are_next_tokens():
    cfg = DataConfig(vocab=50, seq_len=16, global_batch=2)
    b = host_batch(cfg, 0)
    # labels[t] is the continuation of tokens[t]: they overlap shifted by one
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_distribution_nonuniform_and_local_structure():
    cfg = DataConfig(vocab=1000, seq_len=256, global_batch=8)
    b = host_batch(cfg, 0)
    toks = b["tokens"].ravel()
    # Zipf-ish: token 0 much more frequent than the tail
    assert (toks == 0).mean() > 10 * (toks == 900).mean()
    # repeat-previous structure: adjacent-equal rate >> uniform chance
    rep = (b["tokens"][:, 1:] == b["tokens"][:, :-1]).mean()
    assert rep > 0.15
