"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret=True)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import api, fft_mixing as fm
from repro.kernels import fft2d, monarch_bpmm as mk, ops, ref


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "t,gin,gout,nb,b",
    [(16, 1, 1, 4, 8), (32, 2, 3, 8, 16), (8, 1, 2, 16, 32), (24, 3, 1, 2, 64)],
)
def test_monarch_kernel_sweep(t, gin, gout, nb, b, dtype):
    key = jax.random.PRNGKey(t + nb)
    ks = jax.random.split(key, 3)
    x = jax.random.normal(ks[0], (t, gin, nb, b), dtype)
    r = (jax.random.normal(ks[1], (gout, gin, nb, b, b), jnp.float32) / np.sqrt(b)).astype(dtype)
    l = (jax.random.normal(ks[2], (gout, gin, b, nb, nb), jnp.float32) / np.sqrt(nb)).astype(dtype)
    y = mk.monarch_bpmm(x, r, l, token_tile=8, interpret=True)
    y_ref = ref.monarch_bpmm_ref(x, r, l)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        np.asarray(y, np.float32), np.asarray(y_ref, np.float32), rtol=tol, atol=tol
    )


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("complex_in", [False, True])
@pytest.mark.parametrize("t,n1,n2", [(8, 4, 8), (16, 16, 16), (8, 8, 32), (8, 32, 8)])
def test_fft_kernel_sweep(t, n1, n2, complex_in, dtype):
    x = jax.random.normal(jax.random.PRNGKey(0), (t, n1 * n2), dtype)
    xi = jax.random.normal(jax.random.PRNGKey(1), (t, n1 * n2), dtype) if complex_in else None
    yr, yi = fft2d.dft_two_stage(x, xi, n1=n1, n2=n2, token_tile=8, interpret=True)
    rr, ri = ref.dft_two_stage_ref(x.astype(jnp.float32), None if xi is None else xi.astype(jnp.float32))
    scale = float(jnp.max(jnp.abs(rr))) + 1e-6
    tol = 1e-4 if dtype == jnp.float32 else 4e-2
    np.testing.assert_allclose(np.asarray(yr, np.float32), np.asarray(rr), rtol=tol, atol=tol * scale)
    np.testing.assert_allclose(np.asarray(yi, np.float32), np.asarray(ri), rtol=tol, atol=tol * scale)


def test_ops_monarch_linear_matches_einsum_path():
    spec_e = api.LinearSpec(100, 300, "monarch", max_block=32)
    spec_k = api.LinearSpec(100, 300, "monarch_kernel", max_block=32)
    p = api.init_linear(jax.random.PRNGKey(3), spec_e)
    x = jax.random.normal(jax.random.PRNGKey(4), (3, 7, 100), jnp.float32)
    y1 = api.apply_linear(p, spec_e, x)
    y2 = api.apply_linear(p, spec_k, x)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("plan", [(8, 8, 8), (16, 16), (4, 8, 4, 4)])
def test_ops_dft_multistage(plan):
    n = int(np.prod(plan))
    x = jax.random.normal(jax.random.PRNGKey(5), (4, n), jnp.float32)
    yr, yi = ops.dft_1d(x, None, plan=plan)
    f = np.fft.fft(np.asarray(x), axis=-1)
    np.testing.assert_allclose(np.asarray(yr), f.real, rtol=1e-3, atol=1e-3 * np.abs(f).max())
    np.testing.assert_allclose(np.asarray(yi), f.imag, rtol=1e-3, atol=1e-3 * np.abs(f).max())


def test_fnet_kernel_matches_reference():
    x = jax.random.normal(jax.random.PRNGKey(6), (2, 64, 96), jnp.float32)
    y = ops.fnet_mixing_kernel(x, max_radix=16)
    y_ref = fm.fnet_mixing_reference(x)
    np.testing.assert_allclose(
        np.asarray(y), np.asarray(y_ref), rtol=1e-3, atol=1e-3 * float(jnp.abs(y_ref).max())
    )


def test_fnet_staged_xla_matches_reference():
    x = jax.random.normal(jax.random.PRNGKey(7), (2, 128, 96), jnp.float32)
    y = fm.fnet_mixing(x, max_radix=32)
    y_ref = fm.fnet_mixing_reference(x)
    np.testing.assert_allclose(
        np.asarray(y), np.asarray(y_ref), rtol=1e-3, atol=1e-3 * float(jnp.abs(y_ref).max())
    )
