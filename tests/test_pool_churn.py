"""PagePool refcount churn: the free list never hands out a referenced page.

Property test over random alloc/retain/fork/release storms.  The invariant
under attack is the one prefix sharing leans on: a physical page backing N
readers (request page tables + the radix cache) must stay off the free list
until the LAST reference is released — otherwise two requests silently share
KV rows that one of them is about to overwrite.
"""

import numpy as np
import pytest

pytest.importorskip("hypothesis")  # property tests degrade to skips without it
from hypothesis import given, settings, strategies as st

from repro.launch.serve import PagePool


@settings(max_examples=60, deadline=None)
@given(
    n_pages=st.integers(min_value=1, max_value=17),
    ops=st.lists(
        st.tuples(st.sampled_from(["alloc", "retain", "fork", "release"]),
                  st.integers(min_value=0, max_value=10**6)),
        min_size=1, max_size=300,
    ),
)
def test_pool_churn_never_leaks_referenced_pages(n_pages, ops):
    """Model-based check: mirror the pool with a plain dict of refcounts and
    a multiset of outstanding references; after every op the pool's view must
    match the model, and alloc must only ever return pages the model says are
    free.  fork() conserves total references (caller's ref moves)."""
    pool = PagePool(n_pages)
    refs: dict[int, int] = {}  # model: pid -> live refcount
    held: list[int] = []  # outstanding references, one entry each

    for op, pick in ops:
        if op == "alloc":
            if pool.free_pages:
                pid = pool.alloc()
                assert refs.get(pid, 0) == 0, "free list handed out a referenced page"
                refs[pid] = 1
                held.append(pid)
        elif op == "retain" and held:
            pid = held[pick % len(held)]
            pool.retain(pid)
            refs[pid] += 1
            held.append(pid)
        elif op == "fork" and held:
            i = pick % len(held)
            pid = held[i]
            if refs[pid] >= 2 and pool.free_pages:
                new = pool.fork(pid)
                assert refs.get(new, 0) == 0, "fork returned a referenced page"
                assert new != pid
                refs[pid] -= 1
                refs[new] = 1
                held[i] = new
            else:
                with pytest.raises((ValueError, RuntimeError)):
                    pool.fork(pid)
        elif op == "release" and held:
            pid = held.pop(pick % len(held))
            pool.release(pid)
            refs[pid] -= 1
            if refs[pid] == 0:
                del refs[pid]
        # conservation + agreement with the model after every step
        assert pool.in_use == len(refs)
        assert pool.in_use + pool.free_pages == n_pages
        for pid, r in refs.items():
            assert pool.page_refs(pid) == r
        assert sum(refs.values()) == len(held)

    # drain: after all outstanding refs go, every page id is allocatable again
    for pid in held:
        pool.release(pid)
    assert pool.in_use == 0
    got = sorted(pool.alloc() for _ in range(n_pages))
    assert got == list(range(n_pages))


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2**31 - 1))
def test_pool_random_walk_free_list_integrity(seed):
    """Unstructured storm driven by a PRNG: interleave all four ops with
    whatever arguments are currently legal and check the free list and the
    refcount vector never disagree (the alloc-time assert stays silent)."""
    rng = np.random.default_rng(seed)
    pool = PagePool(int(rng.integers(2, 12)))
    held: list[int] = []
    for _ in range(400):
        r = rng.random()
        if r < 0.35 and pool.free_pages:
            held.append(pool.alloc())
        elif r < 0.55 and held:
            pid = held[int(rng.integers(len(held)))]
            pool.retain(pid)
            held.append(pid)
        elif r < 0.7 and held and pool.free_pages:
            i = int(rng.integers(len(held)))
            if pool.page_refs(held[i]) >= 2:
                held[i] = pool.fork(held[i])
        elif held:
            pool.release(held.pop(int(rng.integers(len(held)))))
        assert pool.in_use + pool.free_pages == pool.n_pages
    for pid in held:
        pool.release(pid)
    assert pool.in_use == 0
