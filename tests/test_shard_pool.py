"""Mesh-sharded page pool: the host allocator's shard accounting, the
mesh-local table translation, and the per-shard gather reassembly.

The invariant everything here pins: GSPMD partitions the device pool's page
axis into contiguous ranges, the host :class:`PagePool` shards its free
lists over the SAME ranges, and every allocated page is owned by exactly
one shard — so masked-and-rebased per-shard translations partition the
replicated liveness, and summing per-shard gathers reassembles the
replicated gather bit-exactly."""

import numpy as np
import pytest

jax = pytest.importorskip("jax")
import jax.numpy as jnp  # noqa: E402

from repro.core import sparsity  # noqa: E402
from repro.launch.serve import PagePool  # noqa: E402
from repro.models.layers import gather_pages  # noqa: E402

# (pattern, pattern_arg/window, cache_len, page) — dense-causal, sliding
# window, and the paper's butterfly, at an uneven tail tile
TABLE_SWEEP = [
    ("causal", None, 256, 32),
    ("window", 64, 256, 32),
    ("butterfly", None, 512, 64),
    ("causal", None, 240, 32),  # ragged: cache_len not a tile multiple
]


def _random_tables(rng, B, n_vtiles, n_pages):
    """Page tables with sentinels (unallocated tails) and cross-row aliasing
    (prefix sharing), each allocated id drawn without replacement so pages
    are owned once — matching the allocator's contract."""
    pt = np.full((B, n_vtiles), n_pages, np.int32)
    perm = rng.permutation(n_pages)
    k = 0
    shared = perm[k]; k += 1  # one page aliased by every row (radix hit)
    for b in range(B):
        n_alloc = rng.integers(1, n_vtiles + 1)
        pt[b, 0] = shared
        for t in range(1, n_alloc):
            pt[b, t] = perm[k]
            k += 1
    return pt


@pytest.mark.parametrize("pattern,arg,cache_len,page", TABLE_SWEEP)
@pytest.mark.parametrize("n_shards", [2, 4])
def test_sharded_translate_partitions_replicated(
    pattern, arg, cache_len, page, n_shards
):
    """Per-shard translate_tables masks + rebases such that the live entries
    across shards PARTITION the replicated live entries, with physical ids
    rebased by exactly the shard base."""
    rng = np.random.default_rng(42)
    B = 3
    n_vtiles = -(-cache_len // page)
    n_pages = ((B * n_vtiles + 4) // n_shards + 1) * n_shards
    pt = _random_tables(rng, B, n_vtiles, n_pages)
    cur = jnp.asarray(
        rng.integers(1, cache_len + 1, size=B), jnp.int32
    )
    window = arg if pattern == "window" else None
    kvi, lv = sparsity.decode_live_tables(
        pattern, cur, cache_len, page, page,
        window=window, pattern_arg=None if pattern == "window" else arg,
    )
    phys_r, virt_r, live_r = sparsity.translate_tables(
        kvi, lv, jnp.asarray(pt), n_pages
    )
    phys_r, live_r = np.asarray(phys_r), np.asarray(live_r)
    pps = n_pages // n_shards
    live_sum = np.zeros_like(live_r)
    for s in range(n_shards):
        lo, hi = s * pps, (s + 1) * pps
        phys_s, virt_s, live_s = sparsity.translate_tables(
            kvi, lv, jnp.asarray(pt), n_pages, page_range=(lo, hi)
        )
        phys_s, live_s = np.asarray(phys_s), np.asarray(live_s)
        np.testing.assert_array_equal(np.asarray(virt_s), np.asarray(virt_r))
        # a shard's live entries are replicated-live AND in its range
        assert ((live_s == 1) <= (live_r == 1)).all()
        sel = live_s == 1
        assert (phys_s[sel] + lo == phys_r[sel]).all()
        assert (phys_s[sel] >= 0).all() and (phys_s[sel] < pps).all()
        live_sum += live_s
    # each replicated-live entry owned by exactly ONE shard, none by two
    np.testing.assert_array_equal(live_sum, live_r)


@pytest.mark.parametrize("n_shards", [2, 4])
@pytest.mark.parametrize("kv_heads", [1, 2])  # MHA and GQA-shaped pools
def test_sharded_gather_reassembles_replicated(n_shards, kv_heads):
    """Sum of mesh-local gathers over per-shard sub-pools == the replicated
    gather on every ALLOCATED row (unallocated rows gather clamped garbage
    replicated-side, zeros shard-side — every consumer masks them)."""
    rng = np.random.default_rng(7)
    B, page, n_vtiles, hd = 3, 16, 6, 8
    n_pages = ((B * n_vtiles + 2) // n_shards + 1) * n_shards
    pool = jnp.asarray(
        rng.normal(size=(n_pages * page, kv_heads, hd)).astype(np.float32)
    )
    pt = _random_tables(rng, B, n_vtiles, n_pages)
    n_rows = n_vtiles * page
    rep = np.asarray(gather_pages(pool, jnp.asarray(pt), n_rows, page))
    pps = n_pages // n_shards
    acc = np.zeros_like(rep)
    for s in range(n_shards):
        lo, hi = s * pps, (s + 1) * pps
        local = pool[lo * page : hi * page]
        acc += np.asarray(
            gather_pages(
                local, jnp.asarray(pt), n_rows, page, page_range=(lo, hi)
            )
        )
    alloc_rows = pt[:, np.arange(n_rows) // page] != n_pages  # (B, n_rows)
    np.testing.assert_allclose(acc[alloc_rows], rep[alloc_rows], rtol=0, atol=0)
    # unowned rows contribute exactly zero from every shard
    assert (acc[~alloc_rows] == 0).all()


def test_page_residency_per_shard_ceil():
    last = np.asarray([10, 20, 30, 40, 50, 60, 70, 80])
    res = sparsity.page_residency(last, 81, 10)
    res4 = sparsity.page_residency(last, 81, 10, n_shards=4)
    np.testing.assert_array_equal(res4, -(-res // 4))


# -- the sharded host allocator ------------------------------------------


def test_pool_shard_ranges_and_balance():
    pool = PagePool(16, n_shards=4)
    assert pool.pages_per_shard == 4
    pids = [pool.alloc(f"r{i}") for i in range(8)]
    # balanced placement: 8 pages over 4 shards -> exactly 2 per shard
    assert pool.shard_in_use == [2, 2, 2, 2]
    for pid in pids:
        assert pool.shard_of(pid) == pid // 4
    for pid in pids:
        pool.release(pid)
    assert pool.in_use == 0
    assert pool.shard_in_use == [0, 0, 0, 0]
    assert pool.shard_peak_in_use == [2, 2, 2, 2]
    assert pool.peak_in_use == 8


def test_pool_shard_peak_bound_under_churn():
    """Random alloc/release churn: balanced placement keeps every shard's
    peak within ceil(global peak / n_shards) + 1."""
    rng = np.random.default_rng(3)
    pool = PagePool(32, n_shards=4)
    held = []
    for _ in range(500):
        if held and (len(held) >= 32 or rng.random() < 0.45):
            pool.release(held.pop(rng.integers(len(held))))
        else:
            held.append(pool.alloc())
    bound = -(-pool.peak_in_use // 4) + 1
    assert max(pool.shard_peak_in_use) <= bound
    for p in held:
        pool.release(p)
    pool.close()


def test_pool_rejects_uneven_shards():
    with pytest.raises(ValueError, match="do not split"):
        PagePool(10, n_shards=4)


def test_pool_one_shard_is_flat_lifo():
    """1-shard pools must stay bit-identical to the historical flat free
    list (page 0 first) — token-level engine tests depend on the ids."""
    pool = PagePool(4)
    assert [pool.alloc() for _ in range(4)] == [0, 1, 2, 3]


def test_transfer_moves_label_not_refcount():
    pool = PagePool(4, n_shards=2)
    pid = pool.alloc("prefill:req1")
    pool.transfer(pid, "prefill:req1", "decode:req1")
    assert pool.page_refs(pid) == 1
    assert pool.holders() == {"decode:req1": 1}
    with pytest.raises(ValueError, match="holds no reference"):
        pool.transfer(pid, "prefill:req1", "decode:req1")
    pool.release(pid, "decode:req1")
    pool.close()


def test_close_leak_report_names_holders():
    pool = PagePool(8, n_shards=2)
    a = pool.alloc("req1")
    b = pool.alloc("req2")
    pool.retain(b, "radix")
    with pytest.raises(RuntimeError) as e:
        pool.close(context="end of test")
    msg = str(e.value)
    assert "end of test" in msg
    assert "'req1': 1" in msg and "'req2': 1" in msg and "'radix': 1" in msg
    pool.release(a, "req1")
    pool.release(b, "req2")
    pool.release(b, "radix")
    pool.close()  # drained: returns quietly
