"""Mamba2/SSD: chunked form vs sequential recurrence oracle (hypothesis)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # property tests degrade to skips without it
from hypothesis import given, settings, strategies as st

from repro.models import ssm
from repro.models.config import ModelConfig


def _cfg(heads, head_dim, groups, state, chunk):
    return ModelConfig(
        name="t", family="ssm", n_layers=2, d_model=heads * head_dim // 2, vocab=64,
        ssm_state=state, ssm_head_dim=head_dim, ssm_groups=groups, ssm_chunk=chunk,
    )


@settings(max_examples=10, deadline=None)
@given(
    groups=st.sampled_from([1, 2]),
    rep=st.sampled_from([1, 2, 3]),
    length=st.sampled_from([8, 24, 32]),
    chunk=st.sampled_from([4, 8, 16]),
)
def test_ssd_chunked_equals_sequential(groups, rep, length, chunk):
    heads = groups * rep
    cfg = _cfg(heads, 8, groups, 8, chunk)
    key = jax.random.PRNGKey(groups * 100 + rep * 10 + length)
    ks = jax.random.split(key, 5)
    b = 2
    x = jax.random.normal(ks[0], (b, length, heads, 8), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, length, heads)))
    a = -jnp.exp(jax.random.normal(ks[2], (heads,)) * 0.5)
    bm = jax.random.normal(ks[3], (b, length, groups, 8)) / np.sqrt(8)
    cm = jax.random.normal(ks[4], (b, length, groups, 8)) / np.sqrt(8)
    y_ref, s_ref = ssm.ssd_reference(x, dt, a, bm, cm)
    y, s = ssm._ssd_chunked(cfg, x, dt, a, bm, cm)
    scale = float(jnp.max(jnp.abs(y_ref))) + 1e-6
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), rtol=1e-4, atol=1e-4 * scale)
    np.testing.assert_allclose(np.asarray(s), np.asarray(s_ref), rtol=1e-4, atol=1e-4)


def test_ssd_initial_state_threading():
    """Chunked scan with an initial state == one long sequential pass."""
    cfg = _cfg(4, 8, 2, 8, 8)
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    b, l = 2, 32
    x = jax.random.normal(ks[0], (b, l, 4, 8), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, l, 4)))
    a = -jnp.exp(jax.random.normal(ks[2], (4,)) * 0.5)
    bm = jax.random.normal(ks[3], (b, l, 2, 8)) / np.sqrt(8)
    cm = jax.random.normal(ks[4], (b, l, 2, 8)) / np.sqrt(8)
    half = l // 2
    _, s_half = ssm._ssd_chunked(cfg, x[:, :half], dt[:, :half], a, bm[:, :half], cm[:, :half])
    y2, s2 = ssm._ssd_chunked(
        cfg, x[:, half:], dt[:, half:], a, bm[:, half:], cm[:, half:], init_state=s_half
    )
    y_ref, s_ref = ssm.ssd_reference(x, dt, a, bm, cm)
    np.testing.assert_allclose(np.asarray(s2), np.asarray(s_ref), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(
        np.asarray(y2), np.asarray(y_ref[:, half:]), rtol=1e-4,
        atol=1e-4 * float(jnp.max(jnp.abs(y_ref))),
    )


def test_decay_stability_long_sequence():
    """No overflow/NaN in the decay math over long sequences."""
    cfg = _cfg(2, 8, 1, 8, 64)
    b, l = 1, 512
    ks = jax.random.split(jax.random.PRNGKey(1), 5)
    x = jax.random.normal(ks[0], (b, l, 2, 8), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, l, 2)) + 2.0)  # large dt
    a = -jnp.exp(jnp.array([1.0, 2.0]))  # strong decay
    bm = jax.random.normal(ks[3], (b, l, 1, 8))
    cm = jax.random.normal(ks[4], (b, l, 1, 8))
    y, s = ssm._ssd_chunked(cfg, x, dt, a, bm, cm)
    assert not bool(jnp.any(jnp.isnan(y)))
    assert not bool(jnp.any(jnp.isnan(s)))
