"""SLO-aware preemption: priority scheduling, restartable prefill, and
graceful degradation under page-pool pressure.

The scheduler contract under test: a higher-priority admission that cannot
reserve its page-residency peak evicts the youngest lowest-priority victim
(written prefix donated to the radix tree, pages released, request
requeued), and the victim's resume — a re-prefill of its effective prompt
through the ordinary chunk entry point — is token-identical to a run that
was never preempted, because greedy sampling makes the rebuilt cache
deterministic.  Aging bounds batch-class delay (delayed, never starved),
the per-request preemption cap plus minimum-progress floor bound wasted
work (no livelock), and rings/encdec are declared non-preemptible (fixed
page sets, radix-disabled — there is nothing warm to resume from).
"""

import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import registry
from repro.core import sparsity
from repro.core.attention import AttentionSpec
from repro.launch.mesh import make_local_mesh
from repro.launch.serve import DisaggRouter, Request, ServeLoop, _AdmitQueue
from repro.models import model as M


def _cfg(pattern="dense", arg=None, impl="xla_chunked", **kw):
    return dataclasses.replace(
        registry.get("qwen3-0.6b", reduced=True),
        dtype="float32", capacity_factor=8.0,
        attention=AttentionSpec(impl=impl, pattern=pattern, pattern_arg=arg),
        **kw,
    )


@pytest.fixture(scope="module")
def setup():
    cfg = _cfg()
    mesh = make_local_mesh()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, mesh, params


def _overload_reqs(cfg, seed=5):
    """Two long batch requests fill the 4-page pool (2 pages peak each at
    cache_len 512 / page 128); the interactive request at t=4 cannot
    reserve its page and must preempt the youngest batch victim."""
    rng = np.random.default_rng(seed)
    spec = [("batch", 200, 10, 0), ("batch", 200, 10, 0),
            ("interactive", 100, 4, 4)]
    return [
        Request(uid=i, priority=prio, max_new=mn, arrival=ar,
                prompt=rng.integers(0, cfg.vocab, size=pl).astype(np.int32))
        for i, (prio, pl, mn, ar) in enumerate(spec)
    ]


# --------------------------------------------------------------------------
# _AdmitQueue unit behaviour (pure host code)
# --------------------------------------------------------------------------


def _req(uid, priority="interactive", arrival=0):
    return Request(uid=uid, prompt=np.array([1], np.int32), max_new=1,
                   priority=priority, arrival=arrival)


def test_admit_queue_priority_and_arrival():
    """Interactive outranks batch regardless of push order; a request is
    invisible until its arrival clock; FIFO order breaks ties in a class."""
    b = _req(0, "batch")
    i1 = _req(1, "interactive", arrival=2)
    i2 = _req(2, "interactive", arrival=2)
    q = _AdmitQueue([b, i1, i2], aging_steps=100)
    assert q.peek(0) is b  # the interactives have not arrived yet
    assert q.peek(2) is i1  # arrived: class rank wins, then FIFO in class
    q.pop(i1, 2)
    assert q.peek(2) is i2
    q.pop(i2, 2)
    assert q.peek(2) is b
    with pytest.raises(ValueError, match="not in queue"):
        q.pop(i1, 2)


def test_admit_queue_aging_promotes_batch():
    """After ``aging_steps`` clocks of waiting, a batch request ranks with
    the interactive class — by its (older) arrival it then wins the tie."""
    b = _req(0, "batch", arrival=0)
    i = _req(1, "interactive", arrival=3)
    q = _AdmitQueue([b, i], aging_steps=4)
    assert q.peek(3) is i  # not aged yet: interactive first
    assert q.peek(4) is b  # aged at clock 4: batch promoted, older arrival
    q.pop(b, 4)
    assert q.promotions == 1


def test_admit_queue_fifo_ignores_priority():
    b = _req(0, "batch", arrival=0)
    i = _req(1, "interactive", arrival=0)
    q = _AdmitQueue([b, i], aging_steps=4, fifo=True)
    assert q.peek(0) is b
    q.pop(b, 100)
    assert q.promotions == 0  # fifo never counts promotions


def test_admit_queue_starvation_freedom_property():
    """Property: under ANY arrival/priority tape, every request is peeked
    within aging_steps + (number of requests) clocks of its arrival if the
    queue pops whatever it peeks — aging makes the schedule starvation-free."""
    hyp = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")

    @hyp.settings(max_examples=60, deadline=None)
    @hyp.given(
        tape=st.lists(
            st.tuples(st.sampled_from(["interactive", "batch"]),
                      st.integers(0, 12)),
            min_size=1, max_size=8,
        ),
        aging=st.integers(1, 6),
    )
    def prop(tape, aging):
        reqs = [_req(u, prio, arrival=ar)
                for u, (prio, ar) in enumerate(tape)]
        q = _AdmitQueue(list(reqs), aging_steps=aging)
        served_at = {}
        clock = 0
        while len(q):
            r = q.peek(clock)
            if r is None:
                clock += 1
                continue
            q.pop(r, clock)
            served_at[r.uid] = clock
            clock += 1
        bound = aging + len(reqs)
        for r in reqs:
            wait = served_at[r.uid] - r.arrival
            assert wait <= bound, (
                f"uid {r.uid} ({r.priority}) waited {wait} > {bound}"
            )

    prop()


# --------------------------------------------------------------------------
# Request validation at admission
# --------------------------------------------------------------------------


def test_request_validation_errors():
    cfg = _cfg()
    loop = ServeLoop(cfg, make_local_mesh(), None, batch=1, cache_len=512,
                     paged=True, pool_pages=2)
    ok = dict(prompt=np.arange(8, dtype=np.int32), max_new=2)
    with pytest.raises(ValueError, match="arrival"):
        loop.run([Request(uid=0, arrival=-1, **ok)])
    with pytest.raises(ValueError, match="priority"):
        loop.run([Request(uid=0, priority="urgent", **ok)])
    with pytest.raises(ValueError, match="non-empty"):
        loop.run([Request(uid=0, prompt=np.empty(0, np.int32), max_new=2)])
    with pytest.raises(ValueError, match="unservable"):
        loop.run([Request(uid=0, prompt=np.arange(500, dtype=np.int32)
                          % cfg.vocab, max_new=8)])
    loop.close()


def test_scheduler_kwarg_validation(setup):
    cfg, mesh, _ = setup
    with pytest.raises(ValueError, match="scheduler"):
        ServeLoop(cfg, mesh, None, batch=1, cache_len=64, scheduler="lifo")
    with pytest.raises(ValueError, match="aging_steps"):
        ServeLoop(cfg, mesh, None, batch=1, cache_len=64, aging_steps=0)
    with pytest.raises(ValueError, match="max_preemptions"):
        ServeLoop(cfg, mesh, None, batch=1, cache_len=64, max_preemptions=-1)


# --------------------------------------------------------------------------
# Preemption end to end: token identity, resume, drain
# --------------------------------------------------------------------------


def test_preempt_token_identity_and_drain(setup):
    """The overload tape preempts the youngest batch request; every request
    — the victim included — must emit exactly the tokens of an uncontended
    run with an ample pool, no request starves, and the pool drains."""
    cfg, mesh, params = setup
    kw = dict(batch=3, cache_len=512, chunked=True, chunk_size=32,
              paged=True)
    with ServeLoop(cfg, mesh, params, pool_pages=12, **kw) as ample:
        ref = ample.run(_overload_reqs(cfg))
        assert ample.stats["preemptions"] == 0
    with ServeLoop(cfg, mesh, params, pool_pages=4, **kw) as loop:
        done = loop.run(_overload_reqs(cfg))
        assert loop.stats["preemptions"] >= 1
        assert loop.stats["resumes"] >= 1
        assert loop.stats["starved_requests"] == 0
        for r1, r2 in zip(ref, done):
            assert r2.generated == r1.generated, f"uid {r1.uid}"
            assert len(r2.generated) == r1.max_new
        # the victim is the YOUNGEST batch request (uid 1 admitted second)
        assert done[1].preemptions >= 1 and done[0].preemptions == 0
        assert done[2].preemptions == 0  # interactive is never a victim
    assert loop.pool.in_use == 0


def test_preempt_cap_zero_disables(setup):
    """max_preemptions=0 turns pressure back into plain backpressure —
    same tokens, zero evictions."""
    cfg, mesh, params = setup
    kw = dict(batch=3, cache_len=512, chunked=True, chunk_size=32,
              paged=True)
    with ServeLoop(cfg, mesh, params, pool_pages=12, **kw) as ample:
        ref = ample.run(_overload_reqs(cfg))
    with ServeLoop(cfg, mesh, params, pool_pages=4, max_preemptions=0,
                   **kw) as loop:
        assert not loop.preemptible
        done = loop.run(_overload_reqs(cfg))
        assert loop.stats["preemptions"] == 0
        assert loop.stats["admission_backpressure"] > 0
        for r1, r2 in zip(ref, done):
            assert r2.generated == r1.generated, f"uid {r1.uid}"


def test_preempt_seeded_interleaving_sweep(setup):
    """Seeded random arrival/priority tapes through BOTH paged scheduler
    modes under pool pressure: whatever interleaving of preemptions,
    resumes, aging promotions and backpressure falls out, tokens must match
    the ample-pool run, nothing starves, and the pool drains."""
    cfg, mesh, params = setup
    for mode_kw in (dict(chunked=True, chunk_size=32), dict()):
        kw = dict(batch=3, cache_len=256, paged=True, aging_steps=8, **mode_kw)
        with ServeLoop(cfg, mesh, params, pool_pages=12, **kw) as ample, \
                ServeLoop(cfg, mesh, params, pool_pages=3, **kw) as tight:
            for seed in (0, 1, 2):
                rng = np.random.default_rng(seed)
                reqs = [
                    Request(
                        uid=i,
                        prompt=rng.integers(
                            0, cfg.vocab,
                            size=int(rng.integers(20, 180)),
                        ).astype(np.int32),
                        max_new=int(rng.integers(2, 6)),
                        arrival=int(rng.integers(0, 10)),
                        priority=("interactive", "batch")[rng.random() < .5],
                    )
                    for i in range(5)
                ]

                def clone(rs):
                    return [Request(uid=r.uid, prompt=r.prompt,
                                    max_new=r.max_new, arrival=r.arrival,
                                    priority=r.priority) for r in rs]

                ref = ample.run(clone(reqs))
                done = tight.run(clone(reqs))
                assert tight.stats["starved_requests"] == 0, seed
                for r1, r2 in zip(ref, done):
                    assert r2.generated == r1.generated, (seed, r1.uid)
                    assert r2.preemptions <= tight.max_preemptions
        assert tight.pool.in_use == 0


def test_aging_prevents_batch_starvation(setup):
    """One serve slot, a stream of interactive arrivals, one batch request:
    without aging the batch request would wait out every interactive; with
    a small aging_steps it is promoted and completes."""
    cfg, mesh, params = setup
    rng = np.random.default_rng(9)

    def mk():
        reqs = [Request(
            uid=0, priority="batch", max_new=3, arrival=0,
            prompt=rng.integers(0, cfg.vocab, size=12).astype(np.int32))]
        reqs += [
            Request(uid=1 + i, priority="interactive", max_new=3, arrival=i,
                    prompt=rng.integers(0, cfg.vocab, size=8).astype(np.int32))
            for i in range(4)
        ]
        return reqs

    with ServeLoop(cfg, mesh, params, batch=1, cache_len=64, chunked=True,
                   chunk_size=16, aging_steps=4) as loop:
        done = loop.run(mk())
    assert loop.stats["aging_promotions"] >= 1
    assert loop.stats["starved_requests"] == 0
    assert all(len(r.generated) == r.max_new for r in done)


# --------------------------------------------------------------------------
# Non-preemptible families
# --------------------------------------------------------------------------


def test_nonpreemptible_families(setup):
    """Rings hold fixed page sets with the radix disabled (nothing warm to
    resume from) and encdec requests pin shared cross ranges — both are
    declared non-preemptible; fifo scheduling also never preempts."""
    cfg, mesh, _ = setup
    wcfg = dataclasses.replace(cfg, sliding_window=10)
    ring = ServeLoop(wcfg, mesh, None, batch=2, cache_len=24, chunked=True,
                     chunk_size=4)
    assert ring.paged and not ring.preemptible
    fifo = ServeLoop(cfg, mesh, None, batch=2, cache_len=512, paged=True,
                     scheduler="fifo")
    assert not fifo.preemptible
    prio = ServeLoop(cfg, mesh, None, batch=2, cache_len=512, paged=True)
    assert prio.preemptible
    wcfg2 = registry.get("whisper-base", reduced=True)
    wcfg2 = dataclasses.replace(
        wcfg2, dtype="float32",
        attention=AttentionSpec(impl="xla_chunked", pattern="dense"),
    )
    enc = ServeLoop(wcfg2, mesh, None, batch=2, cache_len=24, paged=True)
    assert not enc.preemptible
    for lp in (ring, fifo, prio, enc):
        lp.close()


# --------------------------------------------------------------------------
# SLO instrumentation
# --------------------------------------------------------------------------


def test_slo_stats_shape(setup):
    """Every run aggregates per-class p50/p99 TTFT and ITL in clock units,
    plus an attainment fraction; TTFT of a t=0 admission on the contiguous
    chunked engine is its prefill-chunk count."""
    cfg, mesh, params = setup
    rng = np.random.default_rng(3)
    reqs = [
        Request(uid=i, priority=("interactive", "batch")[i % 2],
                prompt=rng.integers(0, cfg.vocab, size=16).astype(np.int32),
                max_new=4)
        for i in range(4)
    ]
    with ServeLoop(cfg, mesh, params, batch=2, cache_len=64, chunked=True,
                   chunk_size=16, slo_ttft=50, slo_itl=10.0) as loop:
        done = loop.run(reqs)
    slo = loop.stats["slo"]
    assert set(slo) == {"interactive", "batch"}
    for cls in slo.values():
        assert cls["n"] == 2
        assert 0 < cls["ttft_p50"] <= cls["ttft_p99"]
        assert cls["itl_p50"] <= cls["itl_p99"]
    assert loop.stats["slo_attainment"] == 1.0  # loose SLOs: all attained
    for r in done:
        assert r.ttft is not None and len(r.emit_clocks) == r.max_new


def test_slo_attainment_fraction(setup):
    """An impossible TTFT SLO (0 clocks) is missed by every request."""
    cfg, mesh, params = setup
    reqs = [Request(uid=0, prompt=np.arange(8, dtype=np.int32), max_new=2)]
    with ServeLoop(cfg, mesh, params, batch=1, cache_len=64, chunked=True,
                   chunk_size=16, slo_ttft=0) as loop:
        loop.run(reqs)
    assert loop.stats["slo_attainment"] == 0.0


# --------------------------------------------------------------------------
# close() idempotence, context manager, leak attribution
# --------------------------------------------------------------------------


def test_close_idempotent_and_context_manager(setup):
    cfg, mesh, params = setup
    with ServeLoop(cfg, mesh, params, batch=1, cache_len=512,
                   paged=True, pool_pages=4) as loop:
        loop.run([Request(uid=0, prompt=np.arange(10, dtype=np.int32),
                          max_new=2)])
    loop.close()  # second close after the context exit: a clean no-op
    assert loop.pool.in_use == 0


def test_context_manager_does_not_mask_body_exception(setup):
    """An exception inside the with-body propagates even when close() would
    itself raise on the leak the abandoned run left behind."""
    cfg, mesh, _ = setup
    with pytest.raises(KeyError, match="boom"):
        with ServeLoop(cfg, mesh, None, batch=1, cache_len=512,
                       paged=True, pool_pages=4) as loop:
            loop.pool.alloc(owner="test-body")  # simulate mid-flight state
            raise KeyError("boom")
    loop.pool.release(0, owner="test-body")
    loop.close()


def test_leak_attribution_names_owner(setup):
    """A leaked page surfaces its owner label in the close() error, and a
    failed close stays re-runnable after the straggler releases."""
    cfg, mesh, _ = setup
    loop = ServeLoop(cfg, mesh, None, batch=1, cache_len=512, paged=True,
                     pool_pages=4)
    pid = loop.pool.alloc(owner="test-straggler")
    with pytest.raises(RuntimeError, match="test-straggler"):
        loop.close()
    loop.pool.release(pid, owner="test-straggler")
    loop.close()
    loop.close()  # idempotent after the clean one


# --------------------------------------------------------------------------
# Resume reservations: sparsity.page_resume_peak
# --------------------------------------------------------------------------


def test_page_resume_peak_matches_full_run():
    """Resuming at frontier 0 must price exactly the from-scratch residency
    peak, and any mid-stream frontier can only need fewer-or-equal pages."""
    L, q_tile, kv_tile = 96, 8, 8
    for pattern in ("causal", "butterfly", "window"):
        arg = 16 if pattern == "window" else None
        full = sparsity.page_peak_resident(
            pattern, L, q_tile, kv_tile, step_span=4, pattern_arg=arg)
        at0 = sparsity.page_resume_peak(
            pattern, L, q_tile, kv_tile, frontier=0, step_span=4,
            pattern_arg=arg)
        assert at0 == full, pattern
        prev = full
        for f in (10, 40, 70, L - 1):
            p = sparsity.page_resume_peak(
                pattern, L, q_tile, kv_tile, frontier=f, step_span=4,
                pattern_arg=arg)
            assert 0 < p <= prev, (pattern, f)
            prev = p


def test_page_resume_peak_frontier_bounds():
    with pytest.raises(ValueError, match="frontier"):
        sparsity.page_resume_peak("causal", 32, 8, 8, frontier=32)
    with pytest.raises(ValueError, match="frontier"):
        sparsity.page_resume_peak("causal", 32, 8, 8, frontier=-1)
    assert sparsity.page_resume_peak("causal", 0, 8, 8, frontier=0) == 0


# --------------------------------------------------------------------------
# Preemption-aware chunk budget (resume_chunk_frac)
# --------------------------------------------------------------------------


def test_resume_budget_cap_counts_and_stays_identical(setup):
    """A resumed victim re-prefills at a reduced ``resume_chunk_frac`` share
    of the step budget — the ``resume_budget_capped`` stat counts the
    shrunk chunks, fresh admissions keep the full budget, and the capped
    run stays token-identical to the uncontended reference (chunking never
    changes greedy tokens, only how the prefill is sliced)."""
    cfg, mesh, params = setup
    kw = dict(batch=3, cache_len=512, chunked=True, chunk_size=32,
              paged=True)
    with ServeLoop(cfg, mesh, params, pool_pages=12, **kw) as ample:
        ref = ample.run(_overload_reqs(cfg))
        assert "resume_budget_capped" not in ample.stats  # nothing resumed
    with ServeLoop(cfg, mesh, params, pool_pages=4,
                   resume_chunk_frac=0.25, **kw) as loop:
        done = loop.run(_overload_reqs(cfg))
        assert loop.stats["resumes"] >= 1
        # the ~200-token victim re-prefills in ceil(consumed / 8) chunks of
        # cap = int(32 * 0.25) = 8 instead of 32, so the cap must fire
        assert loop.stats["resume_budget_capped"] >= 1
        for r1, r2 in zip(ref, done):
            assert r2.generated == r1.generated, f"uid {r1.uid}"
    assert loop.pool.in_use == 0


def test_resume_budget_frac_one_never_caps(setup):
    """``resume_chunk_frac=1.0`` is the no-op cap: the victim's draws are
    already bounded by the step budget, so the stat never appears."""
    cfg, mesh, params = setup
    with ServeLoop(cfg, mesh, params, batch=3, cache_len=512, chunked=True,
                   chunk_size=32, paged=True, pool_pages=4,
                   resume_chunk_frac=1.0) as loop:
        loop.run(_overload_reqs(cfg))
        assert loop.stats["preemptions"] >= 1
        assert "resume_budget_capped" not in loop.stats


def test_resume_chunk_frac_validation(setup):
    cfg, mesh, params = setup
    for bad in (0.0, -0.5, 1.5):
        with pytest.raises(ValueError, match="resume_chunk_frac"):
            ServeLoop(cfg, mesh, params, batch=2, cache_len=64,
                      chunked=True, paged=True, resume_chunk_frac=bad)


# --------------------------------------------------------------------------
# DisaggRouter construction contract (cheap: rejected before any compile)
# --------------------------------------------------------------------------


def test_disagg_rejects_unsupported_configs(setup):
    cfg, mesh, params = setup
    with pytest.raises(ValueError, match="prefill_batch"):
        DisaggRouter(cfg, mesh, params, batch=2, prefill_batch=0,
                     cache_len=64)
    ring_cfg = dataclasses.replace(cfg, sliding_window=32)
    with pytest.raises(ValueError, match="sliding"):
        DisaggRouter(ring_cfg, mesh, params, batch=2, cache_len=64)
    with pytest.raises(ValueError, match="paged"):
        DisaggRouter(cfg, mesh, params, batch=2, cache_len=64, paged=False)
    with pytest.raises(ValueError, match="chunked"):
        DisaggRouter(cfg, mesh, params, batch=2, cache_len=64, chunked=False)
