"""Prefill + decode == full forward, for every cache-bearing family (f32)."""

import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import registry
from repro.models import model as M
from repro.models import transformer as tf
from repro.models.layers import Runtime

RT = Runtime(mesh=None)
B, S = 2, 16


def _f32(cfg):
    # capacity_factor high so the train-mode reference forward is dropless
    # too (decode uses exact dropless dispatch)
    return dataclasses.replace(cfg, dtype="float32", capacity_factor=8.0)


@pytest.mark.parametrize(
    "arch", ["yi-6b", "mamba2-130m", "jamba-1.5-large", "whisper-base", "mixtral-8x22b"]
)
def test_decode_matches_forward(arch):
    cfg = _f32(registry.get(arch, reduced=True))
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
    batch = {"tokens": tokens, "labels": tokens}
    pb = {"tokens": tokens[:, :-1]}
    if cfg.family == "encdec":
        frames = jax.random.normal(jax.random.PRNGKey(2), (B, cfg.enc_seq, cfg.d_model))
        batch["frames"] = frames
        pb["frames"] = frames

    full, _ = tf.forward(params, cfg, batch, RT, mode="train")
    lp, caches = tf.prefill(params, cfg, pb, RT, cache_len=S)
    ld, _ = tf.decode_step(params, cfg, caches, tokens[:, -1:], jnp.int32(S - 1), RT)

    tol = 2e-4 * float(jnp.max(jnp.abs(full)))
    assert float(jnp.max(jnp.abs(lp - full[:, -2]))) < tol, "prefill logits diverge"
    assert float(jnp.max(jnp.abs(ld - full[:, -1]))) < tol, "decode logits diverge"


def test_multi_step_decode_matches_forward():
    cfg = _f32(registry.get("yi-6b", reduced=True))
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
    full, _ = tf.forward(params, cfg, {"tokens": tokens}, RT)

    plen = S - 4
    _, caches = tf.prefill(params, cfg, {"tokens": tokens[:, :plen]}, RT, cache_len=S)
    for j in range(4):
        ld, caches = tf.decode_step(
            params, cfg, caches, tokens[:, plen + j : plen + j + 1], jnp.int32(plen + j), RT
        )
        err = float(jnp.max(jnp.abs(ld - full[:, plen + j])))
        assert err < 2e-4 * float(jnp.max(jnp.abs(full))), f"step {j}: {err}"


def test_serve_loop_generates():
    import numpy as np

    from repro.launch.mesh import make_local_mesh
    from repro.launch.serve import Request, ServeLoop

    cfg = _f32(registry.get("qwen3-0.6b", reduced=True))
    mesh = make_local_mesh()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    loop = ServeLoop(cfg, mesh, params, batch=2, cache_len=32)
    reqs = [
        Request(uid=0, prompt=np.array([5, 6, 7], np.int32), max_new=4),
        Request(uid=1, prompt=np.array([9, 3], np.int32), max_new=3),
    ]
    done = loop.run(reqs)
    assert len(done[0].generated) == 4
    assert len(done[1].generated) == 3
    assert all(0 <= t < cfg.vocab for r in done for t in r.generated)
