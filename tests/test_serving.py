"""Prefill + decode == full forward, for every cache-bearing family (f32)."""

import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import registry
from repro.models import model as M
from repro.models import transformer as tf
from repro.models.layers import Runtime

RT = Runtime(mesh=None)
B, S = 2, 16


def _f32(cfg):
    # capacity_factor high so the train-mode reference forward is dropless
    # too (decode uses exact dropless dispatch)
    return dataclasses.replace(cfg, dtype="float32", capacity_factor=8.0)


@pytest.mark.parametrize(
    "arch", ["yi-6b", "mamba2-130m", "jamba-1.5-large", "whisper-base", "mixtral-8x22b"]
)
def test_decode_matches_forward(arch):
    cfg = _f32(registry.get(arch, reduced=True))
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
    batch = {"tokens": tokens, "labels": tokens}
    pb = {"tokens": tokens[:, :-1]}
    if cfg.family == "encdec":
        frames = jax.random.normal(jax.random.PRNGKey(2), (B, cfg.enc_seq, cfg.d_model))
        batch["frames"] = frames
        pb["frames"] = frames

    full, _ = tf.forward(params, cfg, batch, RT, mode="train")
    lp, caches = tf.prefill(params, cfg, pb, RT, cache_len=S)
    ld, _ = tf.decode_step(params, cfg, caches, tokens[:, -1:], jnp.int32(S - 1), RT)

    tol = 2e-4 * float(jnp.max(jnp.abs(full)))
    assert float(jnp.max(jnp.abs(lp - full[:, -2]))) < tol, "prefill logits diverge"
    assert float(jnp.max(jnp.abs(ld - full[:, -1]))) < tol, "decode logits diverge"


def test_multi_step_decode_matches_forward():
    cfg = _f32(registry.get("yi-6b", reduced=True))
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
    full, _ = tf.forward(params, cfg, {"tokens": tokens}, RT)

    plen = S - 4
    _, caches = tf.prefill(params, cfg, {"tokens": tokens[:, :plen]}, RT, cache_len=S)
    for j in range(4):
        ld, caches = tf.decode_step(
            params, cfg, caches, tokens[:, plen + j : plen + j + 1], jnp.int32(plen + j), RT
        )
        err = float(jnp.max(jnp.abs(ld - full[:, plen + j])))
        assert err < 2e-4 * float(jnp.max(jnp.abs(full))), f"step {j}: {err}"


def test_serve_loop_generates():
    import numpy as np

    from repro.launch.mesh import make_local_mesh
    from repro.launch.serve import Request, ServeLoop

    cfg = _f32(registry.get("qwen3-0.6b", reduced=True))
    mesh = make_local_mesh()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    loop = ServeLoop(cfg, mesh, params, batch=2, cache_len=32)
    reqs = [
        Request(uid=0, prompt=np.array([5, 6, 7], np.int32), max_new=4),
        Request(uid=1, prompt=np.array([9, 3], np.int32), max_new=3),
    ]
    done = loop.run(reqs)
    assert len(done[0].generated) == 4
    assert len(done[1].generated) == 3
    assert all(0 <= t < cfg.vocab for r in done for t in r.generated)


# --------------------------------------------------------------------------
# Ragged continuous batching: sliding-window ring masking + mixed-length
# parity against isolated decoding
# --------------------------------------------------------------------------


@pytest.mark.parametrize("impl", ["xla_chunked", "flash_kernel"])
def test_sliding_window_decode_matches_forward(impl):
    """Ring-cache decode at pos < window: unwritten ring rows must be masked.

    cache_len > prompt leaves zero-initialised ring rows; before the live-KV
    mask those scored e^0 in the softmax and decode diverged from forward.
    The loop then crosses pos >= window, covering the ring-wrap phase too.
    """
    from repro.core.attention import AttentionSpec

    cfg = dataclasses.replace(
        _f32(registry.get("qwen3-0.6b", reduced=True)),
        sliding_window=10,
        attention=AttentionSpec(impl=impl),
    )
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
    full, _ = tf.forward(params, cfg, {"tokens": tokens}, RT)
    plen = 6  # < window, and cache_len=24 > plen: ring rows 6..9 start unwritten
    _, caches = tf.prefill(params, cfg, {"tokens": tokens[:, :plen]}, RT, cache_len=24)
    tol = 2e-4 * float(jnp.max(jnp.abs(full)))
    for j in range(S - plen):
        ld, caches = tf.decode_step(
            params, cfg, caches, tokens[:, plen + j : plen + j + 1],
            jnp.int32(plen + j), RT,
        )
        err = float(jnp.max(jnp.abs(ld - full[:, plen + j])))
        assert err < tol, f"step {j} (pos {plen + j}): {err}"


def _reference_greedy(cfg, params, prompt, max_new, cache_len, extras=None):
    """Greedy-decode one request in isolation (eager batch-1 prefill+decode)."""
    import numpy as np

    batch = {"tokens": jnp.asarray(np.asarray(prompt)[None, :])}
    for key, val in (extras or {}).items():
        batch[key] = jnp.asarray(val)[None]
    logits, caches = tf.prefill(params, cfg, batch, RT, cache_len=cache_len)
    nxt = int(jnp.argmax(logits[0]))
    out = [nxt]
    for j in range(max_new - 1):
        logits, caches = tf.decode_step(
            params, cfg, caches, jnp.asarray([[nxt]], jnp.int32),
            jnp.int32(len(prompt) + j), RT,
        )
        nxt = int(jnp.argmax(logits[0]))
        out.append(nxt)
    return out


# arch, cfg tweaks, attn impl — GQA, sliding window (pos < window included),
# and encoder-decoder cross-attention decode
RAGGED_CASES = [
    ("qwen3-0.6b", {}, "xla_chunked"),
    ("qwen3-0.6b", {}, "flash_kernel"),
    ("qwen3-0.6b", {"sliding_window": 10}, "xla_chunked"),
    ("qwen3-0.6b", {"sliding_window": 10}, "flash_kernel"),
    ("whisper-base", {}, "xla_chunked"),
]


@pytest.mark.parametrize("arch,tweaks,impl", RAGGED_CASES)
def test_ragged_batch_matches_isolated(arch, tweaks, impl):
    """A mixed-length batch through the continuous engine generates exactly
    what each request generates when decoded alone (same params, greedy)."""
    import numpy as np

    from repro.core.attention import AttentionSpec
    from repro.launch.mesh import make_local_mesh
    from repro.launch.serve import Request, ServeLoop

    cfg = dataclasses.replace(
        _f32(registry.get(arch, reduced=True)),
        attention=AttentionSpec(impl=impl),
        **tweaks,
    )
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(3)
    extras = {}
    if cfg.family == "encdec":
        extras = {
            "frames": jax.random.normal(
                jax.random.PRNGKey(2), (cfg.enc_seq, cfg.d_model), jnp.float32
            )
        }
    # distinct prompt lengths and max_new; window cases decode past pos=window
    reqs = [
        Request(
            uid=i,
            prompt=rng.integers(0, cfg.vocab, size=ln).astype(np.int32),
            max_new=mn,
            extras=dict(extras),
        )
        for i, (ln, mn) in enumerate([(7, 8), (3, 5), (12, 3)])
    ]
    loop = ServeLoop(cfg, make_local_mesh(), params, batch=3, cache_len=24)
    done = loop.run(reqs)
    for r in done:
        ref = _reference_greedy(
            cfg, params, r.prompt, r.max_new, 24, extras=extras
        )
        assert r.generated == ref, f"uid {r.uid}: {r.generated} != {ref}"


def test_serve_loop_rejects_stateful_mixers():
    """Bucketed right-pad prefill would fold pad tokens into SSM state —
    the engine must refuse loudly, not generate silently-wrong streams."""
    from repro.launch.mesh import make_local_mesh
    from repro.launch.serve import ServeLoop

    cfg = _f32(registry.get("mamba2-130m", reduced=True))
    with pytest.raises(ValueError, match="attention-only"):
        ServeLoop(cfg, make_local_mesh(), None, batch=2, cache_len=32)


# --------------------------------------------------------------------------
# Chunked-prefill mixed-step engine
# --------------------------------------------------------------------------


def test_next_bucket_boundaries():
    """Buckets must stay a bounded set (powers of two or exactly the cap) so
    the jit shape cache is bounded; n > cap is a caller bug, not a shape."""
    from repro.launch.serve import _next_bucket

    assert _next_bucket(1, 64) == 8
    assert _next_bucket(8, 64) == 8
    assert _next_bucket(9, 64) == 16
    assert _next_bucket(33, 64) == 64
    assert _next_bucket(64, 64) == 64
    # non-power-of-two cap: n landing between the cap and the next power of
    # two must clamp to the cap, never leak arbitrary n into the jit cache
    assert _next_bucket(20, 24) == 24
    assert _next_bucket(24, 24) == 24
    assert _next_bucket(5, 24) == 8
    with pytest.raises(ValueError, match="exceeds cap"):
        _next_bucket(25, 24)
    vals = {_next_bucket(n, 100) for n in range(1, 101)}
    assert vals <= {8, 16, 32, 64, 100}


# pattern, pattern_arg, impl, cache_len, (prompt_len, max_new) list, chunk.
# dense/window run at small shapes; butterfly needs cache_len >= 512 so the
# kv-tile grid (128-wide tiles) actually has dead tiles to skip.  qwen3 is
# GQA (4 heads over 2 kv heads) throughout.
CHUNKED_CASES = [
    ("dense", None, "xla_chunked", 64, [(17, 6), (3, 5), (41, 3)], 8),
    ("dense", None, "flash_kernel", 64, [(17, 6), (3, 5), (41, 3)], 8),
    ("window", 16, "xla_chunked", 64, [(17, 6), (3, 5), (41, 3)], 8),
    ("window", 16, "flash_kernel", 64, [(17, 6), (3, 5), (41, 3)], 8),
    ("butterfly", None, "xla_chunked", 512, [(300, 5), (7, 6), (150, 3)], 32),
    ("butterfly", None, "flash_kernel", 512, [(300, 4), (7, 4)], 32),
]


@pytest.mark.parametrize("pattern,arg,impl,cache_len,lens,chunk", CHUNKED_CASES)
def test_chunked_engine_matches_admission_engine(
    pattern, arg, impl, cache_len, lens, chunk
):
    """The mixed-step engine must be token-identical to the admission-prefill
    engine (and to isolated greedy decoding) on interleaved long/short
    prompts — chunked prefill changes the schedule, never the math."""
    import numpy as np

    from repro.core.attention import AttentionSpec
    from repro.launch.mesh import make_local_mesh
    from repro.launch.serve import Request, ServeLoop

    cfg = dataclasses.replace(
        _f32(registry.get("qwen3-0.6b", reduced=True)),
        attention=AttentionSpec(impl=impl, pattern=pattern, pattern_arg=arg),
    )
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(11)
    prompts = [rng.integers(0, cfg.vocab, size=ln).astype(np.int32) for ln, _ in lens]

    def mk():
        return [
            Request(uid=i, prompt=p, max_new=mn)
            for i, (p, (_, mn)) in enumerate(zip(prompts, lens))
        ]

    mesh = make_local_mesh()
    ref = ServeLoop(cfg, mesh, params, batch=2, cache_len=cache_len).run(mk())
    ch = ServeLoop(
        cfg, mesh, params, batch=2, cache_len=cache_len, chunked=True,
        chunk_size=chunk,
    ).run(mk())
    for r1, r2 in zip(ref, ch):
        assert r2.generated == r1.generated, f"uid {r1.uid}"
    if pattern == "dense":  # the engines also match isolated decoding
        for r in ch:
            assert r.generated == _reference_greedy(
                cfg, params, r.prompt, r.max_new, cache_len
            ), f"uid {r.uid} vs isolated"


def test_chunked_decode_never_stalls_on_admission():
    """A long prompt arriving mid-decode must stream in chunks WHILE the live
    decode rows keep sampling: zero decode stalls, overlap steps observed,
    and generations still token-identical to the admission engine."""
    import numpy as np

    from repro.launch.mesh import make_local_mesh
    from repro.launch.serve import Request, ServeLoop

    cfg = _f32(registry.get("qwen3-0.6b", reduced=True))
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(4)
    short = [rng.integers(0, cfg.vocab, size=4).astype(np.int32) for _ in range(2)]
    long_p = rng.integers(0, cfg.vocab, size=90).astype(np.int32)

    def mk():
        rs = [Request(uid=i, prompt=p, max_new=12) for i, p in enumerate(short)]
        rs.append(Request(uid=2, prompt=long_p, max_new=3, arrival=2))
        return rs

    mesh = make_local_mesh()
    loop = ServeLoop(
        cfg, mesh, params, batch=3, cache_len=128, chunked=True, chunk_size=8
    )
    done = loop.run(mk())
    assert loop.stats["decode_stall_steps"] == 0
    # the long prompt needs ceil(90/8) > 11 chunk steps; the short requests'
    # 12 decode steps must overlap them rather than wait
    assert loop.stats["overlap_steps"] >= 3
    assert loop.stats["prefill_calls"] == 0
    ref = ServeLoop(cfg, mesh, params, batch=3, cache_len=128).run(mk())
    for r1, r2 in zip(ref, done):
        assert r2.generated == r1.generated, f"uid {r1.uid}"


def test_kv_live_bucket_boundary_butterfly_decode():
    """Regression: butterfly decode with the live cache bucketed at
    ``hot`` one above a power of two (cur_len 129 -> kv_live 256 on a 512
    cache) must match the untruncated decode — the per-row live-tile tables
    rebuilt at the truncated length may not change liveness."""
    from repro.core.attention import AttentionSpec
    from repro.models.layers import run_decode_attention

    key = jax.random.PRNGKey(2)
    b, h, kv, hd, cache = 2, 4, 2, 16, 512
    kq, kk, kv_ = jax.random.split(key, 3)
    q = jax.random.normal(kq, (b, h, hd), jnp.float32)
    kc = jax.random.normal(kk, (b, cache, kv, hd), jnp.float32)
    vc = jax.random.normal(kv_, (b, cache, kv, hd), jnp.float32)
    cur = jnp.asarray([129, 65], jnp.int32)  # one above a power of two
    for impl in ("xla_chunked", "flash_kernel"):
        spec = AttentionSpec(impl=impl, pattern="butterfly")
        full = run_decode_attention(q, kc, vc, cur, spec=spec)
        bucketed = run_decode_attention(q, kc, vc, cur, spec=spec, kv_live=256)
        err = float(jnp.max(jnp.abs(full - bucketed)))
        assert err < 1e-5, f"{impl}: kv_live truncation diverged by {err}"


@pytest.mark.parametrize("pattern", ["dense", "butterfly"])
@pytest.mark.parametrize("impl", ["xla_chunked", "flash_kernel"])
def test_chunk_attention_matches_prefill_rows(pattern, impl):
    """A mid-sequence chunk of queries over the shared cache must equal the
    same rows of a full prefill — per-query pattern liveness (each query's
    own q-tile row), causal frontier, GQA grouping all exact."""
    import numpy as np

    from repro.core.attention import AttentionSpec
    from repro.models.layers import run_attention, run_chunk_attention

    key = jax.random.PRNGKey(3)
    b, s, h, kvh, hd, c = 2, 512, 4, 2, 16, 96
    kq, kk, kv_ = jax.random.split(key, 3)
    q = jax.random.normal(kq, (b, s, h, hd), jnp.float32)
    k = jax.random.normal(kk, (b, s, kvh, hd), jnp.float32)
    v = jax.random.normal(kv_, (b, s, kvh, hd), jnp.float32)
    spec = AttentionSpec(impl=impl, pattern=pattern)
    full = run_attention(q, k, v, spec=spec, causal=True)
    start = np.asarray([200, 64], np.int32)  # not tile-aligned on row 0
    qc = jnp.stack([q[i, p : p + c] for i, p in enumerate(start)])
    out = run_chunk_attention(
        qc, k, v, jnp.asarray(start), jnp.full((b,), c, jnp.int32), spec=spec
    )
    ref = jnp.stack([full[i, p : p + c] for i, p in enumerate(start)])
    tol = 2e-5 * float(jnp.max(jnp.abs(ref)))
    err = float(jnp.max(jnp.abs(out - ref)))
    assert err < tol, f"{impl}/{pattern}: chunk rows diverge by {err}"


def test_serve_admit_evict_mid_stream():
    """More requests than slots: short requests exit, queued ones are admitted
    into the freed slot mid-stream, and every stream still matches isolation."""
    import numpy as np

    from repro.launch.mesh import make_local_mesh
    from repro.launch.serve import Request, ServeLoop

    cfg = _f32(registry.get("qwen3-0.6b", reduced=True))
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(5)
    reqs = [
        Request(uid=i, prompt=rng.integers(0, cfg.vocab, size=ln).astype(np.int32),
                max_new=mn)
        for i, (ln, mn) in enumerate([(4, 2), (6, 7), (3, 1), (9, 4), (2, 5)])
    ]
    loop = ServeLoop(cfg, make_local_mesh(), params, batch=2, cache_len=32)
    done = loop.run(reqs)
    # with 2 slots and a 7-step stream in flight, uid 3/4 can only complete
    # via mid-stream admission into evicted slots
    assert loop.stats["prefill_calls"] == 5
    assert loop.stats["decode_steps"] < sum(r.max_new for r in reqs)
    for r in done:
        assert len(r.generated) == r.max_new
        ref = _reference_greedy(cfg, params, r.prompt, r.max_new, 32)
        assert r.generated == ref, f"uid {r.uid}"
