"""Quantized paged KV cache: round-trip bounds, scale carriage through the
page machinery (CoW fork, ownership transfer, shard split), paged-vs-
contiguous attention error under per-dtype tolerances across patterns x
backends x modes (GQA included), and the bf16 bit-identity contract.

The contract under test: a pool stored at int8/fp8 with per-(row, kv_head)
scales must behave exactly like a bf16 pool up to the quantizer's rounding —
same liveness, same masks, same page sharing — and ``kv_dtype='bf16'`` must
compile the exact pre-quantization graph (no scale leaves, identical tokens).
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.core import quant, sparsity
from repro.core.attention import AttentionSpec, kv_dtype_bytes
from repro.kernels.monarch_bpmm import pick_token_tile
from repro.launch.mesh import make_local_mesh
from repro.launch.serve import PagePool, Request, ServeLoop
from repro.launch.serving.entries import zero_pools
from repro.models import model as M
from repro.models import transformer as tf
from repro.models.layers import (
    Runtime,
    run_attention,
    run_chunk_attention,
    run_decode_attention,
    run_paged_chunk_attention,
    run_paged_decode_attention,
    run_paged_prefill_attention,
)


def _f32(cfg):
    return dataclasses.replace(cfg, dtype="float32", capacity_factor=8.0)


STORE_DTYPES = [("int8", jnp.int8)] + (
    [("fp8_e4m3", jnp.float8_e4m3fn)] if quant.fp8_supported() else []
)


# --------------------------------------------------------------------------
# Quantize/dequantize round trip: per-row error bounds
# --------------------------------------------------------------------------


@pytest.mark.parametrize("name,store", STORE_DTYPES)
def test_round_trip_error_bounds(name, store):
    """Symmetric per-row quantization must bound the reconstruction error by
    the scheme's step size: absmax/(2*127) per row for int8, absmax/16 for
    fp8_e4m3 (3 mantissa bits -> half-ulp relative error 2^-4)."""
    x = jax.random.normal(jax.random.PRNGKey(0), (3, 5, 2, 64), jnp.float32) * 7.3
    q, s = quant.quantize_rows(x, store)
    assert q.dtype == jnp.dtype(store) and s.dtype == jnp.float32
    assert s.shape == x.shape[:-1]
    xr = quant.dequantize_rows(q, s)
    err = jnp.max(jnp.abs(xr - x), axis=-1)
    absmax = jnp.max(jnp.abs(x), axis=-1)
    bound = absmax / 254.0 if name == "int8" else absmax / 16.0
    assert bool(jnp.all(err <= bound + 1e-6)), f"{name} exceeded its bound"


def test_round_trip_zero_rows_exact():
    """All-zero rows keep scale 1 and reconstruct exactly (never a 0 * 0/0)."""
    x = jnp.zeros((4, 2, 8), jnp.float32)
    q, s = quant.quantize_rows(x, jnp.int8)
    assert bool(jnp.all(s == 1.0))
    assert bool(jnp.all(quant.dequantize_rows(q, s) == 0.0))


def test_kv_dtype_validation_and_store():
    with pytest.raises(ValueError, match="kv_dtype"):
        quant.validate_kv_dtype("int4")
    assert quant.kv_store_dtype("bf16", jnp.float32) == jnp.dtype(jnp.float32)
    assert quant.kv_store_dtype("int8", jnp.float32) == jnp.dtype(jnp.int8)
    if quant.fp8_supported():
        assert (
            quant.kv_store_dtype("fp8_e4m3", jnp.float32)
            == jnp.dtype(jnp.float8_e4m3fn)
        )
    # quantized widths price payload + amortized f32 scale per head_dim values
    assert kv_dtype_bytes("bf16", 64) == 2.0
    assert kv_dtype_bytes("int8", 64) == pytest.approx(1.0 + 4.0 / 64)
    assert kv_dtype_bytes("fp8_e4m3", 128) == pytest.approx(1.0 + 4.0 / 128)
    with pytest.raises(ValueError):
        kv_dtype_bytes("int4", 64)


# --------------------------------------------------------------------------
# Satellite: pick_token_tile budgets quantized tiles at their true width
# --------------------------------------------------------------------------


def test_pick_token_tile_quantized_width():
    """At a geometry pinched between tile candidates, the quantized effective
    width (1 + 4/hd bytes) must admit a strictly larger token tile than bf16
    — the VMEM budget prices true bytes, not container dtypes."""
    gin, nb, b = 125, 8, 16  # (gin+3) * nb * b = 16384 bytes/token at 1B
    t_bf16 = pick_token_tile(gin, nb, b, dtype_bytes=2.0)
    t_int8 = pick_token_tile(gin, nb, b, dtype_bytes=kv_dtype_bytes("int8", 64))
    assert t_int8 > t_bf16
    assert t_bf16 == 256 and t_int8 == 512
    # monotone: fp8 prices the same byte width as int8
    assert pick_token_tile(gin, nb, b, kv_dtype_bytes("fp8_e4m3", 64)) == t_int8
    # int dtype_bytes callers (the existing activation path) are unchanged
    assert pick_token_tile(gin, nb, b, 4) <= t_bf16


# --------------------------------------------------------------------------
# Scale carriage: CoW page copy, pool specs, zero_pools dtypes, transfer
# --------------------------------------------------------------------------


def test_paged_copy_page_carries_scales():
    """The device half of a CoW fork tree-maps every pool leaf — K/V rows
    and their scale rows move together, so a forked page can never read
    another page's scales."""
    page, n_pages, kv, hd = 4, 3, 2, 8
    rows = n_pages * page
    key = jax.random.PRNGKey(1)
    caches = {
        "slot00": {
            "attn": {
                "k": jax.random.normal(key, (1, rows, kv, hd)),
                "v": jax.random.normal(key, (1, rows, kv, hd)),
                "k_scale": jax.random.uniform(key, (1, rows, kv)) + 0.5,
                "v_scale": jax.random.uniform(key, (1, rows, kv)) + 0.5,
            }
        }
    }
    out = tf.paged_copy_page(caches, jnp.int32(0), jnp.int32(2), page)
    for name in ("k", "v", "k_scale", "v_scale"):
        src = caches["slot00"]["attn"][name][:, 0 * page:1 * page]
        dst = out["slot00"]["attn"][name][:, 2 * page:3 * page]
        np.testing.assert_array_equal(np.asarray(src), np.asarray(dst), name)
        # untouched pages stay untouched
        np.testing.assert_array_equal(
            np.asarray(caches["slot00"]["attn"][name][:, page:2 * page]),
            np.asarray(out["slot00"]["attn"][name][:, page:2 * page]),
        )


def test_pool_specs_and_zero_pools_dtypes():
    """Quantized pool trees add f32 ``*_scale`` leaves next to the K/V pools
    they reconstruct; bf16 trees have none (the PR-9 layout, bit-for-bit).
    Cross pools stay unquantized by policy."""
    cfg = _f32(registry.get("qwen3-0.6b", reduced=True))
    mesh = make_local_mesh()
    base = tf.paged_pool_specs(cfg, 4, 8)
    q8 = tf.paged_pool_specs(cfg, 4, 8, kv_dtype="int8")
    for slot, sc in q8.items():
        assert set(sc["attn"]) == {"k", "v", "k_scale", "v_scale"}
        assert set(base[slot]["attn"]) == {"k", "v"}
        assert sc["attn"]["k_scale"].shape == sc["attn"]["k"].shape[:-1]
    with pytest.raises(ValueError, match="kv_dtype"):
        tf.paged_pool_specs(cfg, 4, 8, kv_dtype="int4")

    pools = zero_pools(cfg, mesh, 4, 8, kv_dtype="int8")
    for sc in pools.values():
        assert sc["attn"]["k"].dtype == jnp.int8
        assert sc["attn"]["v"].dtype == jnp.int8
        assert sc["attn"]["k_scale"].dtype == jnp.float32
    bfp = zero_pools(cfg, mesh, 4, 8, kv_dtype="bf16")
    ref = zero_pools(cfg, mesh, 4, 8)
    assert jax.tree_util.tree_structure(bfp) == jax.tree_util.tree_structure(ref)
    for a, b in zip(jax.tree_util.tree_leaves(bfp), jax.tree_util.tree_leaves(ref)):
        assert a.dtype == b.dtype and a.shape == b.shape


def test_transfer_relabels_without_touching_payload_keys():
    """Ownership transfer moves one host-side reference label; the physical
    page id — the key every device payload and scale row is addressed by —
    never changes, so quantized pages ride a handoff untouched."""
    pool = PagePool(8, n_shards=2)
    pid = pool.alloc("prefill:0")
    pool.transfer(pid, "prefill:0", "decode:0")
    assert pool.holders() == {"decode:0": 1}
    assert pool.page_refs(pid) == 1  # the count is untouched
    with pytest.raises(ValueError, match="holds no reference"):
        pool.transfer(pid, "prefill:0", "x")
    pool.release(pid, "decode:0")
    assert pool.in_use == 0


# --------------------------------------------------------------------------
# Paged-vs-contiguous attention error across patterns x impls x modes (GQA)
# --------------------------------------------------------------------------

# per-dtype max-abs-error tolerance for attention outputs over O(1) values:
# bf16 = the unquantized pool (float32 in tests) — only kernel-vs-XLA float
# association noise; int8 ~ absmax/254 per row pre-softmax; fp8 ~ absmax/16
_TOL = {"bf16": 3e-5, "int8": 0.08, "fp8_e4m3": 0.4}

QUANT_CASES = [
    (pattern, arg, s, impl, kd)
    for pattern, arg, s in (
        ("dense", None, 128), ("window", 16, 128), ("butterfly", None, 512),
    )
    for impl in ("xla_chunked", "flash_kernel")
    for kd in ("bf16", "int8") + (("fp8_e4m3",) if quant.fp8_supported() else ())
]


def _build_pool(k_full, v_full, page, kv_dtype):
    """Scatter exact (B, S, KV, hd) KV into a per-request-paged pool at
    ``kv_dtype`` through the real write path, returning the pool leaves and
    the identity page tables."""
    b, s, kv, hd = k_full.shape
    n_tiles = -(-s // page)
    n_pages = b * n_tiles
    store = quant.kv_store_dtype(kv_dtype, jnp.float32)
    pt = (
        jnp.arange(b, dtype=jnp.int32)[:, None] * n_tiles
        + jnp.arange(n_tiles, dtype=jnp.int32)[None, :]
    )
    rows = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None, :], (b, s))
    valid = jnp.ones((b, s), bool)
    kp = jnp.zeros((n_pages * page, kv, hd), store)
    vp = jnp.zeros((n_pages * page, kv, hd), store)
    if kv_dtype == "bf16":
        kp = tf._paged_kv_write(kp, k_full, rows, valid, pt, page)
        vp = tf._paged_kv_write(vp, v_full, rows, valid, pt, page)
        return kp, vp, None, None, pt
    ks = jnp.zeros((n_pages * page, kv), jnp.float32)
    vs = jnp.zeros((n_pages * page, kv), jnp.float32)
    kp, ks = tf._paged_kv_write(kp, k_full, rows, valid, pt, page, scale=ks)
    vp, vs = tf._paged_kv_write(vp, v_full, rows, valid, pt, page, scale=vs)
    return kp, vp, ks, vs, pt


@pytest.mark.parametrize("pattern,arg,s,impl,kv_dtype", QUANT_CASES)
def test_paged_quant_matches_contiguous(pattern, arg, s, impl, kv_dtype):
    """Attention outputs through a quantized paged pool must sit within the
    dtype's tolerance of the contiguous (exact-KV) oracle on every execution
    form and mode — decode, chunk, and admission prefill; 4 query heads over
    2 kv heads (GQA)."""
    b, h, kv, hd = 2, 4, 2, 64
    spec = AttentionSpec(impl=impl, pattern=pattern, pattern_arg=arg)
    page = sparsity.pick_pattern_tiles(1, s, spec.q_tile, spec.kv_tile)[1]
    rt = Runtime()
    key = jax.random.PRNGKey(3)
    kk, kv_, kq, kc = jax.random.split(key, 4)
    k_full = jax.random.normal(kk, (b, s, kv, hd), jnp.float32)
    v_full = jax.random.normal(kv_, (b, s, kv, hd), jnp.float32)
    kp, vp, ks, vs, pt = _build_pool(k_full, v_full, page, kv_dtype)
    tol = _TOL[kv_dtype]

    # -- decode: per-row live lengths ------------------------------------
    q1 = jax.random.normal(kq, (b, h, hd), jnp.float32)
    cur = jnp.asarray([s, s - 37], jnp.int32)  # row 1 mid-tile frontier
    got = run_paged_decode_attention(
        q1, kp, vp, cur, pt, page=page, spec=spec, rt=rt,
        k_scale=ks, v_scale=vs,
    )
    ref = run_decode_attention(q1, k_full, v_full, cur, spec=spec, rt=rt)
    assert float(jnp.max(jnp.abs(got - ref))) <= tol, "decode"

    # -- chunk: mixed rows at their own frontiers ------------------------
    c = 8
    qc = jax.random.normal(kc, (b, c, h, hd), jnp.float32)
    start = jnp.asarray([s - c, s // 2], jnp.int32)
    ntok = jnp.asarray([c, c - 3], jnp.int32)
    got = run_paged_chunk_attention(
        qc, kp, vp, start, ntok, pt, page=page, spec=spec, rt=rt,
        k_scale=ks, v_scale=vs,
    )
    ref = run_chunk_attention(qc, k_full, v_full, start, ntok, spec=spec, rt=rt)
    assert float(jnp.max(jnp.abs(got - ref))) <= tol, "chunk"

    # -- admission prefill: batch-1 prompt over its own pages ------------
    qp = jax.random.normal(kq, (1, s, h, hd), jnp.float32)
    got = run_paged_prefill_attention(
        qp, k_full[:1], v_full[:1], kp, vp, pt[:1], page=page, spec=spec,
        rt=rt, k_scale=ks, v_scale=vs,
    )
    ref = run_attention(qp, k_full[:1], v_full[:1], spec=spec, causal=True, rt=rt)
    assert float(jnp.max(jnp.abs(got - ref))) <= tol, "prefill"


# --------------------------------------------------------------------------
# End-to-end engine: bf16 bit-identity, fused-vs-XLA agreement, shard parity
# --------------------------------------------------------------------------


def _serve(cfg, mesh, params, prompts, **kw):
    loop = ServeLoop(cfg, mesh, params, batch=2, cache_len=64, paged=True, **kw)
    out = loop.run([
        Request(uid=i, prompt=p, max_new=5) for i, p in enumerate(prompts)
    ])
    loop.close()
    assert loop.pool.in_use == 0
    return [r.generated for r in out]


def test_serve_kv_dtype_end_to_end():
    """Three engine-level contracts on one workload (GQA config):
    ``kv_dtype='bf16'`` is token-identical to the default paged engine (the
    PR-9 graph — no scale leaves exist to change it); the fused int8 path is
    token-identical to the XLA int8 path (both read the SAME quantized pool,
    so greedy argmax must agree); and host page sharding cannot change int8
    results (physical page ids are not part of the math)."""
    cfg = _f32(registry.get("qwen3-0.6b", reduced=True))
    mesh = make_local_mesh()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(11)
    prompts = [
        rng.integers(0, cfg.vocab, size=ln).astype(np.int32)
        for ln in (17, 3, 41)
    ]
    base = _serve(cfg, mesh, params, prompts)
    bf16 = _serve(cfg, mesh, params, prompts, kv_dtype="bf16")
    assert bf16 == base, "kv_dtype='bf16' must reproduce the default engine"

    i8_xla = _serve(cfg, mesh, params, prompts, kv_dtype="int8")
    i8_fused = _serve(
        cfg, mesh, params, prompts, kv_dtype="int8", attn_impl="flash_kernel"
    )
    assert i8_fused == i8_xla, "fused and XLA read the same quantized pool"

    i8_sharded = _serve(
        cfg, mesh, params, prompts, kv_dtype="int8", page_shards=2,
        pool_pages=16,
    )
    assert i8_sharded == i8_xla, "page sharding is invisible to the math"


def test_serve_quantized_rejects_contiguous():
    cfg = _f32(registry.get("qwen3-0.6b", reduced=True))
    with pytest.raises(ValueError, match="paged"):
        ServeLoop(
            cfg, make_local_mesh(), None, batch=1, cache_len=64,
            kv_dtype="int8",
        )
