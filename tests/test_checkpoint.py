"""Checkpoint manager: atomic commit, restore bitwise, gc, elastic reshard."""

import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.launch.mesh import make_mesh


def _state(seed=0):
    k = jax.random.PRNGKey(seed)
    return {
        "params": {"w": jax.random.normal(k, (8, 4)), "b": jnp.zeros(4)},
        "opt": {"mu": {"w": jnp.ones((8, 4)), "b": jnp.ones(4)}},
        "step": jnp.int32(7),
    }


def test_save_restore_bitwise(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    st = _state()
    mgr.save(7, st, blocking=True)
    step, got = mgr.restore_latest(jax.tree.map(np.asarray, st))
    assert step == 7
    for a, b in zip(jax.tree.leaves(st), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_uncommitted_checkpoints_ignored(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    st = _state()
    mgr.save(5, st, blocking=True)
    mgr.save(9, st, blocking=True)
    os.remove(tmp_path / "step_00000009" / "COMMITTED")  # simulate crash mid-write
    assert mgr.latest_step() == 5


def test_gc_keeps_last_k(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, async_save=False)
    st = _state()
    for s in (1, 2, 3, 4):
        mgr.save(s, st, blocking=True)
    assert mgr.all_steps() == [3, 4]


def test_async_save_then_restore(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_save=True)
    st = _state()
    mgr.save(3, st)
    mgr.wait()
    step, got = mgr.restore_latest(jax.tree.map(np.asarray, st))
    assert step == 3


def test_shape_mismatch_rejected(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    mgr.save(1, _state(), blocking=True)
    bad = _state()
    bad["params"]["w"] = jnp.zeros((4, 4))
    with pytest.raises(ValueError, match="ckpt"):
        mgr.restore(1, bad)


def test_elastic_reshard_across_mesh_shapes(tmp_path):
    """Save under one mesh shape, restore under another (elastic rescale)."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    mgr = CheckpointManager(str(tmp_path), async_save=False)
    st = _state()
    mgr.save(2, st, blocking=True)
    mesh_b = make_mesh((1, 1), ("data", "model"))
    shardings = jax.tree.map(lambda a: NamedSharding(mesh_b, P()), st)
    step, got = mgr.restore_latest(jax.tree.map(np.asarray, st), shardings)
    assert step == 2
    np.testing.assert_array_equal(np.asarray(got["params"]["w"]), np.asarray(st["params"]["w"]))
