"""Core butterfly math: FFT equivalence, grouping exactness, param counts."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # property tests degrade to skips without it
from hypothesis import given, settings, strategies as st

from repro.core import butterfly as bf, monarch as mo, stage_division as sd


@pytest.mark.parametrize("n", [4, 8, 16, 64, 128])
def test_fft_factors_equal_dft(n):
    """B_m ... B_1 P == DFT_N (paper Eq. 4)."""
    x = np.random.randn(3, n).astype(np.float32) + 1j * np.random.randn(3, n).astype(np.float32)
    perm = bf.bit_reversal_permutation(n)
    fac = bf.fft_butterfly_factors(n)
    y = np.asarray(bf.apply_butterfly(fac, jnp.asarray(x[:, perm].astype(np.complex64))))
    ref = np.fft.fft(x, axis=-1)
    np.testing.assert_allclose(y, ref, rtol=2e-4, atol=2e-4 * np.abs(ref).max())


@pytest.mark.parametrize("n", [8, 32, 64])
def test_staged_apply_matches_dense(n):
    fac = bf.init_butterfly(jax.random.PRNGKey(n), n)
    w = bf.butterfly_to_dense(fac)
    x = np.random.randn(5, n).astype(np.float32)
    y1 = np.asarray(bf.apply_butterfly(fac, jnp.asarray(x)))
    np.testing.assert_allclose(y1, x @ w.T, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("n", [16, 32, 64, 256])
def test_monarch_grouping_exact(n):
    """Grouping radix-2 stages into (R, L) is lossless (Monarch ⊇ butterfly)."""
    fac = bf.init_butterfly(jax.random.PRNGKey(n), n)
    mp = mo.group_butterfly_factors(fac)
    x = np.random.randn(4, n).astype(np.float32)
    y1 = np.asarray(bf.apply_butterfly(fac, jnp.asarray(x)))
    y2 = np.asarray(mo.monarch_apply(mp, jnp.asarray(x)))
    np.testing.assert_allclose(y1, y2, rtol=1e-4, atol=1e-4 * np.abs(y1).max())


def test_monarch_grouped_fft():
    n = 64
    fac = bf.fft_butterfly_factors(n)
    mp = mo.group_butterfly_factors(fac)
    x = np.random.randn(2, n).astype(np.complex64)
    perm = bf.bit_reversal_permutation(n)
    y = np.asarray(mo.monarch_apply(mp, jnp.asarray(x[:, perm])))
    ref = np.fft.fft(x, axis=-1)
    np.testing.assert_allclose(y, ref, rtol=1e-3, atol=1e-3 * np.abs(ref).max())


def test_param_counts():
    assert bf.butterfly_param_count(1024) == 2 * 1024 * 10
    assert mo.monarch_param_count(1024, 32) == 1024 * (32 + 32)
    # sparsity: butterfly 2N logN << N^2
    assert bf.butterfly_param_count(4096) < 4096**2 // 80


@settings(max_examples=20, deadline=None)
@given(
    logn=st.integers(min_value=2, max_value=7),
    batch=st.integers(min_value=1, max_value=4),
)
def test_property_grouping_any_split(logn, batch):
    """For every legal split point p, grouping is exact (hypothesis)."""
    n = 1 << logn
    fac = bf.init_butterfly(jax.random.PRNGKey(logn * 13 + batch), n)
    x = np.random.RandomState(0).randn(batch, n).astype(np.float32)
    y_ref = np.asarray(bf.apply_butterfly(fac, jnp.asarray(x)))
    for p in range(1, logn):
        mp = mo.group_butterfly_factors(fac, p=p)
        y = np.asarray(mo.monarch_apply(mp, jnp.asarray(x)))
        np.testing.assert_allclose(y, y_ref, rtol=2e-4, atol=2e-4 * np.abs(y_ref).max() + 1e-6)


@settings(max_examples=25, deadline=None)
@given(st.integers(min_value=2, max_value=4096))
def test_property_stage_plans(n):
    """Plans multiply back to n, respect max_radix, and are balanced."""
    primes = sd.factorize(n)
    if max(primes) > 64:
        return  # un-plannable under this radix budget
    plan = sd.plan_stages(n, 64)
    assert int(np.prod(plan)) == n
    assert all(r <= 64 for r in plan)
    if len(plan) > 1:  # balance: max/min ratio bounded (paper Fig. 14)
        assert max(plan) <= 64 and min(plan) >= 2


@pytest.mark.parametrize("n", [6, 12, 64, 96, 768, 4096])
def test_mixed_radix_dft(n):
    x = np.random.randn(2, n).astype(np.float32)
    plan = sd.plan_stages(n, 64)
    y = np.asarray(sd.mixed_radix_dft(jnp.asarray(x), plan))
    ref = np.fft.fft(x, axis=-1)
    np.testing.assert_allclose(y, ref, rtol=1e-3, atol=1e-3 * np.abs(ref).max())
