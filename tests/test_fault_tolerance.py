"""Restart supervisor + straggler detector + resumable training."""

import dataclasses

import numpy as np
import pytest

from repro.distributed.fault_tolerance import RestartPolicy, StragglerDetector, run_with_restarts


def test_run_with_restarts_retries_then_succeeds():
    calls = []

    def body(attempt):
        calls.append(attempt)
        if attempt < 2:
            raise RuntimeError("simulated preemption")
        return "done"

    out = run_with_restarts(body, RestartPolicy(max_restarts=5, backoff_s=0.0))
    assert out == "done"
    assert calls == [0, 1, 2]


def test_run_with_restarts_gives_up():
    def body(attempt):
        raise RuntimeError("persistent failure")

    with pytest.raises(RuntimeError, match="persistent"):
        run_with_restarts(body, RestartPolicy(max_restarts=2, backoff_s=0.0))


def test_straggler_detector_flags_persistent_slowness():
    det = StragglerDetector(window=20, threshold=3.0, patience=3)
    for _ in range(10):
        assert not det.record(1.0)
    assert not det.record(5.0)  # first slow step: no action yet
    assert not det.record(5.0)
    assert det.record(5.0)  # 3 consecutive -> mitigate
    assert det.flagged == 3


def test_straggler_detector_tolerates_blips():
    det = StragglerDetector(window=20, threshold=3.0, patience=3)
    for _ in range(10):
        det.record(1.0)
    det.record(9.0)
    for _ in range(5):
        assert not det.record(1.0)


def test_training_resumes_from_checkpoint(tmp_path):
    """Kill-and-resume: a second train_loop continues from the saved step and
    reproduces the exact state of an uninterrupted run (same data stream)."""
    import jax

    from repro.configs import registry
    from repro.data.pipeline import DataConfig
    from repro.launch.mesh import make_local_mesh
    from repro.launch.train import TrainHParams, train_loop

    cfg = dataclasses.replace(registry.get("qwen3-0.6b", reduced=True), remat=False)
    mesh = make_local_mesh()
    hp = TrainHParams(peak_lr=1e-3, warmup=2, total_steps=8)
    dc = DataConfig(vocab=cfg.vocab, seq_len=16, global_batch=4)

    # uninterrupted reference run
    ref_state, ref_hist = train_loop(
        cfg, mesh, hp, dc, steps=6, ckpt_dir=str(tmp_path / "ref"), ckpt_every=100,
        log_every=0,
    )
    # interrupted run: 3 steps, checkpoint, then resume to 6
    train_loop(cfg, mesh, hp, dc, steps=3, ckpt_dir=str(tmp_path / "ab"), ckpt_every=3,
               log_every=0)
    res_state, res_hist = train_loop(
        cfg, mesh, hp, dc, steps=6, ckpt_dir=str(tmp_path / "ab"), ckpt_every=100,
        log_every=0,
    )
    assert int(res_state["step"]) == int(ref_state["step"]) == 6
    for a, b in zip(jax.tree.leaves(ref_state["params"]), jax.tree.leaves(res_state["params"])):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32), rtol=1e-5, atol=1e-6
        )
