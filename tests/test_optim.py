"""Optimizer + schedule + gradient compression math."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import AdamWConfig, adamw_init, adamw_update, cosine_schedule
from repro.optim.adamw import global_norm
from repro.optim.compression import (
    dequantize_int8,
    ef_compress_tree,
    quantize_int8,
)


def test_adamw_converges_quadratic():
    target = jnp.array([1.0, -2.0, 3.0])
    params = {"w": jnp.zeros(3)}
    cfg = AdamWConfig(weight_decay=0.0)
    opt = adamw_init(params, cfg)
    for _ in range(300):
        g = {"w": 2 * (params["w"] - target)}
        params, opt, _ = adamw_update(g, opt, params, 0.05, cfg)
    np.testing.assert_allclose(np.asarray(params["w"]), np.asarray(target), atol=1e-2)


def test_clip_norm_applied():
    params = {"w": jnp.zeros(4)}
    cfg = AdamWConfig(clip_norm=1.0)
    opt = adamw_init(params, cfg)
    g = {"w": jnp.full(4, 100.0)}
    _, _, stats = adamw_update(g, opt, params, 1e-3, cfg)
    assert float(stats["grad_norm"]) > 100
    assert float(stats["clip_scale"]) < 0.01


def test_bf16_moments():
    params = {"w": jnp.ones(8)}
    cfg = AdamWConfig(moment_dtype="bfloat16")
    opt = adamw_init(params, cfg)
    assert opt["mu"]["w"].dtype == jnp.bfloat16
    p2, opt2, _ = adamw_update({"w": jnp.ones(8)}, opt, params, 1e-2, cfg)
    assert opt2["nu"]["w"].dtype == jnp.bfloat16
    assert np.isfinite(np.asarray(p2["w"], np.float32)).all()


def test_cosine_schedule_shape():
    lrs = [float(cosine_schedule(jnp.int32(s), peak_lr=1.0, warmup=10, total=100)) for s in range(100)]
    assert lrs[0] < lrs[9] <= 1.0  # warmup rises
    assert abs(lrs[10] - 1.0) < 0.05  # peak at end of warmup
    assert lrs[-1] < 0.2  # decays toward the floor
    assert lrs[-1] >= 0.1 * 0.99  # but not below floor*peak


# ------------------------- compression -------------------------


def test_quantize_roundtrip_error_bound():
    x = jax.random.normal(jax.random.PRNGKey(0), (1000,)) * 5
    q, s = quantize_int8(x)
    err = jnp.abs(dequantize_int8(q, s) - x)
    assert float(err.max()) <= float(s) * 0.5 + 1e-6  # half-ULP rounding


def test_error_feedback_is_unbiased_over_time():
    """Sum of EF-compressed grads converges to the sum of true grads."""
    rng = np.random.RandomState(0)
    grads = [
        {"a": jnp.asarray(rng.randn(64).astype(np.float32)),
         "b": jnp.asarray(rng.randn(8, 8).astype(np.float32) * 0.01)}
        for _ in range(50)
    ]
    err = jax.tree.map(jnp.zeros_like, grads[0])
    sent_sum = jax.tree.map(jnp.zeros_like, grads[0])
    true_sum = jax.tree.map(jnp.zeros_like, grads[0])
    for g in grads:
        q, s, err = ef_compress_tree(g, err)
        deq = jax.tree.map(dequantize_int8, q, s)
        sent_sum = jax.tree.map(jnp.add, sent_sum, deq)
        true_sum = jax.tree.map(jnp.add, true_sum, g)
    # residual error is bounded by one quantisation step, not 50 of them
    for k in ("a", "b"):
        resid = np.abs(np.asarray(sent_sum[k] - true_sum[k]))
        onestep = np.abs(np.asarray(err[k]))
        assert resid.max() <= onestep.max() + 1e-5


def test_global_norm():
    t = {"a": jnp.ones(4), "b": jnp.ones((2, 2)) * 2}
    assert abs(float(global_norm(t)) - np.sqrt(4 + 16)) < 1e-5
