"""Paged KV cache: allocator invariants, page-table translation, retention
schedules, and paged-vs-contiguous token parity across patterns x backends x
scheduling modes.

The contract under test: one more level of indirection (live virtual tile ->
physical page) must never change a single token — the packed live tables the
kernels prefetch are the SAME liveness maps, translated — while resident
memory becomes proportional to live pages instead of batch x cache_len.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.core import sparsity
from repro.core.attention import AttentionSpec
from repro.launch.serve import PagePool, Request, ServeLoop
from repro.models import model as M


def _f32(cfg):
    return dataclasses.replace(cfg, dtype="float32", capacity_factor=8.0)


# --------------------------------------------------------------------------
# PagePool: alloc/free/reuse under churn, fragmentation bound, exhaustion
# --------------------------------------------------------------------------


def test_page_pool_churn_invariants():
    """Admit/evict storm: pages stay unique, free+in_use is conserved, and —
    the fragmentation bound — alloc succeeds whenever in_use < n_pages
    (pages are unit-granular, so there is no external fragmentation)."""
    rng = np.random.default_rng(0)
    pool = PagePool(13)
    held: list[int] = []
    for _ in range(500):
        if held and rng.random() < 0.45:
            pool.release(held.pop(rng.integers(len(held))))
        elif pool.in_use < pool.n_pages:
            held.append(pool.alloc())
        assert pool.in_use == len(held)
        assert pool.free_pages + pool.in_use == pool.n_pages
        assert len(set(held)) == len(held), "double-allocated page"
        assert all(0 <= p < pool.n_pages for p in held)
    assert pool.peak_in_use <= pool.n_pages
    # reuse: drain and refill — every page id comes back
    for p in held:
        pool.release(p)
    got = sorted(pool.alloc() for _ in range(pool.n_pages))
    assert got == list(range(pool.n_pages))


def test_page_pool_exhaustion_raises():
    pool = PagePool(2)
    a, _ = pool.alloc(), pool.alloc()
    with pytest.raises(RuntimeError, match="exhausted"):
        pool.alloc()
    with pytest.raises(ValueError):
        PagePool(0)
    # double free must fail loudly: a page on the free list twice would be
    # handed to two requests — silent cross-request KV corruption
    pool.release(a)
    with pytest.raises(ValueError, match="double free"):
        pool.release(a)


def test_page_pool_refcount_sharing():
    """Refcounted sharing semantics behind the radix cache: retain adds a
    reader, release drops one (page frees only at zero), fork moves the
    caller's ref onto a fresh private page, and misuse — retain/fork of a
    free page, fork of an exclusively-held page — fails loudly instead of
    corrupting a sibling's KV."""
    pool = PagePool(4)
    a = pool.alloc()
    pool.retain(a)  # second reader (e.g. radix cache holds the page)
    pool.retain(a)
    assert pool.page_refs(a) == 3
    pool.release(a)
    pool.release(a)
    assert pool.page_refs(a) == 1 and pool.in_use == 1
    # exclusively held -> fork is an engine bug (write could go in place)
    with pytest.raises(ValueError, match="exclusively"):
        pool.fork(a)
    pool.retain(a)
    b = pool.fork(a)  # CoW: caller's ref moves to the private copy
    assert b != a
    assert pool.page_refs(a) == 1 and pool.page_refs(b) == 1
    assert pool.fork_count == 1
    pool.release(a)
    pool.release(b)
    assert pool.in_use == 0
    with pytest.raises(ValueError, match="retain of free"):
        pool.retain(a)
    with pytest.raises(ValueError, match="fork of free"):
        pool.fork(a)
    with pytest.raises(ValueError):
        pool.page_refs(99)


def test_page_pool_shared_page_survives_one_readers_exit():
    """The retention contract prefix sharing needs: with two readers on one
    page, the first reader's full release path must NOT return the page to
    the free list — a fresh alloc gets a different page id."""
    pool = PagePool(2)
    shared = pool.alloc()
    pool.retain(shared)  # second request aliases the prefix page
    pool.release(shared)  # first request finishes
    assert pool.page_refs(shared) == 1
    other = pool.alloc()  # must not be `shared` — it still has a reader
    assert other != shared
    pool.release(other)
    pool.release(shared)
    assert pool.in_use == 0 and pool.free_pages == 2


# --------------------------------------------------------------------------
# Translation + retention schedules
# --------------------------------------------------------------------------


def test_translate_tables_sentinel_and_clamp():
    kvi = np.array([[0, 1, 2], [1, 2, 0]], np.int32)
    lv = np.array([[1, 1, 1], [1, 1, 0]], np.int32)
    pt = np.array([[5, 9, 3], [7, 16, 2]], np.int32)  # 16 == sentinel
    phys, virt, live = sparsity.translate_tables(kvi, lv, pt, 16)
    assert np.asarray(phys).tolist() == [[5, 9, 3], [15, 2, 7]]
    assert np.asarray(virt).tolist() == kvi.tolist()
    # row 1 entry 0 hits the sentinel: masked dead, clamped in bounds
    assert np.asarray(live).tolist() == [[1, 1, 1], [0, 1, 0]]
    # 1-D page table (batch-1 prefill form) broadcasts over table rows
    phys1, _, live1 = sparsity.translate_tables(kvi, lv, pt[0], 16)
    assert np.asarray(phys1).tolist() == [[5, 9, 3], [9, 3, 5]]
    assert np.asarray(live1).tolist() == lv.tolist()


def test_page_last_reader_dense_retains_everything():
    last = sparsity.page_last_reader("dense", 512, 128, 128)
    assert last.tolist() == [511] * 4  # causal: every tile read to the end


def test_page_last_reader_window_frees_tail():
    last = sparsity.page_last_reader("dense", 1024, 128, 128, window=128)
    # tile 0 (positions 0..127) is out of every window past position ~255
    assert last[0] < 300
    assert last[-1] == 1023


def test_page_peak_resident_orders():
    """dense retains all tiles; window caps at ~window/page; butterfly sits
    strictly between at scale — the capacity ordering the paper's routed
    sparsity predicts."""
    s, t = 2048, 128
    dense = sparsity.page_peak_resident("dense", s, t, t)
    bfly = sparsity.page_peak_resident("butterfly", s, t, t)
    win = sparsity.page_peak_resident("dense", s, t, t, window=256)
    assert dense == s // t
    assert win <= 3
    assert win < bfly < dense
    # the decode-phase tail is O(log n): with the frontier in the last tile,
    # the live row itself is the resident set
    assert sparsity.decode_max_live("butterfly", s, t, t) <= 12


def test_page_last_reader_covers_traced_tables():
    """Soundness of freeing: any tile a traced decode table marks live at
    cur_len must have last_reader >= cur_len - 1 (the query's position)."""
    s, t = 1024, 128
    for pattern in ("butterfly", "strided", "global_window"):
        last = sparsity.page_last_reader(pattern, s, t, t)
        for cl in (1, 129, 256, 513, 777, 1024):
            kvi, lv = sparsity.decode_live_tables(
                pattern, jnp.asarray([cl]), s, t, t
            )
            for j, alive in zip(np.asarray(kvi)[0], np.asarray(lv)[0]):
                if alive:
                    assert last[j] >= cl - 1, (pattern, cl, j)


# --------------------------------------------------------------------------
# Engine parity: paged vs contiguous across patterns x impls x modes
# --------------------------------------------------------------------------

# pattern, pattern_arg, impl, scheduling mode, cache_len, (plen, max_new)*, chunk
PARITY_CASES = [
    ("dense", None, "xla_chunked", "admission", 64, [(17, 6), (3, 5), (41, 3)], 8),
    ("dense", None, "flash_kernel", "admission", 64, [(17, 6), (3, 5), (41, 3)], 8),
    ("dense", None, "xla_chunked", "chunked", 64, [(17, 6), (3, 5), (41, 3)], 8),
    ("dense", None, "flash_kernel", "chunked", 64, [(17, 6), (3, 5), (41, 3)], 8),
    ("window", 16, "xla_chunked", "admission", 64, [(17, 6), (3, 5), (41, 3)], 8),
    ("window", 16, "flash_kernel", "admission", 64, [(17, 6), (3, 5), (41, 3)], 8),
    ("window", 16, "xla_chunked", "chunked", 64, [(17, 6), (3, 5), (41, 3)], 8),
    ("window", 16, "flash_kernel", "chunked", 64, [(17, 6), (3, 5), (41, 3)], 8),
    ("butterfly", None, "xla_chunked", "admission", 512, [(200, 3), (7, 3)], 32),
    ("butterfly", None, "flash_kernel", "admission", 512, [(200, 3), (7, 3)], 32),
    ("butterfly", None, "xla_chunked", "chunked", 512, [(200, 3), (7, 3)], 32),
    ("butterfly", None, "flash_kernel", "chunked", 512, [(200, 3), (7, 3)], 32),
]


@pytest.mark.parametrize("pattern,arg,impl,mode,cache_len,lens,chunk", PARITY_CASES)
def test_paged_matches_contiguous(pattern, arg, impl, mode, cache_len, lens, chunk):
    """The paged engine must be token-identical to the contiguous engine in
    BOTH scheduling modes (decode-grid admission and chunk-grid streaming),
    for every pattern and both backends — GQA included (qwen3 is 4 heads
    over 2 kv heads reduced).  After the run the pool must be fully drained
    (every page freed exactly once)."""
    from repro.launch.mesh import make_local_mesh

    cfg = dataclasses.replace(
        _f32(registry.get("qwen3-0.6b", reduced=True)),
        attention=AttentionSpec(impl=impl, pattern=pattern, pattern_arg=arg),
    )
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(11)
    prompts = [rng.integers(0, cfg.vocab, size=ln).astype(np.int32) for ln, _ in lens]

    def mk():
        return [
            Request(uid=i, prompt=p, max_new=mn)
            for i, (p, (_, mn)) in enumerate(zip(prompts, lens))
        ]

    mesh = make_local_mesh()
    chunked = mode == "chunked"
    ref = ServeLoop(
        cfg, mesh, params, batch=2, cache_len=cache_len, chunked=chunked,
        chunk_size=chunk,
    ).run(mk())
    loop = ServeLoop(
        cfg, mesh, params, batch=2, cache_len=cache_len, chunked=chunked,
        chunk_size=chunk, paged=True,
    )
    pag = loop.run(mk())
    for r1, r2 in zip(ref, pag):
        assert r2.generated == r1.generated, f"uid {r1.uid}"
    loop.close()  # releases the persistent radix refs; raises on leaks
    assert loop.pool.in_use == 0, "pages leaked after the run"
    assert loop.stats["pool_peak_pages"] <= loop.stats["pool_pages"]


def test_paged_out_of_pages_backpressure():
    """A pool sized for ONE request's peak must serialize admissions (FIFO
    backpressure, counted in stats), still complete every request, and stay
    token-identical — out-of-pages is scheduling pressure, never corruption."""
    from repro.launch.mesh import make_local_mesh

    cfg = _f32(registry.get("qwen3-0.6b", reduced=True))
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(5)
    prompts = [rng.integers(0, cfg.vocab, size=ln).astype(np.int32)
               for ln in (150, 140, 130)]

    def mk():
        return [Request(uid=i, prompt=p, max_new=3) for i, p in enumerate(prompts)]

    mesh = make_local_mesh()
    ref = ServeLoop(cfg, mesh, params, batch=3, cache_len=512).run(mk())
    # each request needs ceil((150+2)/128)+ = 2 pages; pool of 2 forces
    # one-at-a-time service through 3 slots
    loop = ServeLoop(
        cfg, mesh, params, batch=3, cache_len=512, paged=True, pool_pages=2,
    )
    done = loop.run(mk())
    assert loop.stats["admission_backpressure"] > 0
    assert loop.stats["max_concurrent"] == 1
    for r1, r2 in zip(ref, done):
        assert r2.generated == r1.generated, f"uid {r1.uid}"
    loop.close()
    assert loop.pool.in_use == 0


def test_paged_unservable_request_rejected():
    """A request whose peak residency exceeds the whole pool must be refused
    up front, not deadlock the engine."""
    from repro.launch.mesh import make_local_mesh

    cfg = _f32(registry.get("qwen3-0.6b", reduced=True))
    loop = ServeLoop(
        cfg, make_local_mesh(), None, batch=1, cache_len=512, paged=True,
        pool_pages=1,
    )
    big = Request(uid=0, prompt=np.arange(300, dtype=np.int32) % cfg.vocab,
                  max_new=2)
    with pytest.raises(ValueError, match="unservable"):
        loop.run([big])


def test_paged_butterfly_peak_below_dense_reservation():
    """The capacity claim at test scale: a butterfly request's peak resident
    pages stay strictly below the contiguous engine's dense reservation
    (batch x cache tiles), because mid-prompt tiles free as the pattern's
    remaining stride pairs move past them."""
    from repro.launch.mesh import make_local_mesh

    cfg = dataclasses.replace(
        _f32(registry.get("qwen3-0.6b", reduced=True)),
        attention=AttentionSpec(impl="flash_kernel", pattern="butterfly"),
    )
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(9)
    reqs = [
        Request(uid=i,
                prompt=rng.integers(0, cfg.vocab, size=300).astype(np.int32),
                max_new=3)
        for i in range(2)
    ]
    loop = ServeLoop(
        cfg, make_local_mesh(), params, batch=2, cache_len=512, chunked=True,
        chunk_size=32, paged=True,
    )
    loop.run(reqs)
    dense_reservation = 2 * loop.n_vtiles
    assert loop.stats["pool_peak_pages"] < dense_reservation
    assert loop.stats["page_allocs"] >= loop.stats["pool_peak_pages"]
