"""Sharding rules + multi-device integration (subprocess with fake devices)."""

import json
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

from repro.distributed.sharding import ParamSpec, spec_for
from repro.launch.mesh import make_mesh

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _mesh(shape, names):
    return make_mesh(shape, names)


def test_spec_for_divisibility_fallback():
    mesh = _mesh((1, 1), ("data", "model"))
    # single-device mesh: everything replicates but specs still build
    assert spec_for((64, 64), ("fsdp", "tp"), mesh) is not None


def test_spec_for_rules():
    import jax.sharding as js

    mesh = _mesh((1, 1), ("data", "model"))
    p = spec_for((56, 128), ("tp", None), mesh)  # 56 % 1 == 0 -> sharded ('model' size 1)
    assert isinstance(p, js.PartitionSpec)


def _run_subprocess(body: str, ndev: int = 8) -> str:
    """Run a snippet under a forced multi-device CPU backend."""
    code = textwrap.dedent(body)
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={ndev}"
    env["PYTHONPATH"] = SRC
    # force CPU: the fake-device flag only applies to the host platform, and
    # letting jax probe a TPU backend here hangs for minutes in CI containers
    env["JAX_PLATFORMS"] = "cpu"
    out = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True, env=env, timeout=600
    )
    assert out.returncode == 0, f"stdout:\n{out.stdout}\nstderr:\n{out.stderr[-3000:]}"
    return out.stdout


def test_spec_for_fallbacks_multidevice():
    out = _run_subprocess("""
        import jax
        from repro.distributed.sharding import spec_for
        from repro.launch.mesh import make_mesh
        mesh = make_mesh((2, 4), ("data", "model"))
        # 56 % 4 == 0 -> sharded; 54 % 4 != 0 -> replicated fallback
        print(spec_for((56, 10), ("tp", None), mesh))
        print(spec_for((54, 10), ("tp", None), mesh))
        # batch spreads over (pod, data) only when both divide
        mesh3 = make_mesh((2, 2, 2), ("pod", "data", "model"))
        print(spec_for((8, 16), ("batch", None), mesh3))
        print(spec_for((2, 16), ("batch", None), mesh3))
        print(spec_for((1, 16), ("batch", None), mesh3))
    """)
    lines = out.strip().splitlines()
    assert "model" in lines[0]
    assert "model" not in lines[1]
    assert "pod" in lines[2] and "data" in lines[2]
    assert "pod" in lines[3] and "data" not in lines[3]
    assert "pod" not in lines[4]


def test_train_step_runs_sharded():
    """Real sharded train step on a 2x4 fake mesh: loss finite, params update."""
    out = _run_subprocess("""
        import dataclasses, jax, jax.numpy as jnp, numpy as np
        from repro.configs import registry
        from repro.data.pipeline import DataConfig, global_batch
        from repro.launch.mesh import make_mesh
        from repro.launch.train import TrainHParams, make_train_step, init_train_state, train_state_shardings
        cfg = dataclasses.replace(registry.get("qwen3-0.6b", reduced=True),
                                  n_heads=4, n_kv_heads=4, attn_chunk=16)
        mesh = make_mesh((2, 4), ("data", "model"))
        hp = TrainHParams(peak_lr=1e-3, warmup=1, total_steps=4)
        step, st_sh, _ = make_train_step(cfg, mesh, hp)
        with mesh:
            state = init_train_state(cfg, hp, jax.random.PRNGKey(0))
            state = jax.tree.map(jax.device_put, state, st_sh)
        dc = DataConfig(vocab=cfg.vocab, seq_len=32, global_batch=8)
        losses = []
        for s in range(3):
            batch = global_batch(dc, s, mesh)
            state, m = step(state, batch)
            losses.append(float(m["loss"]))
        assert all(np.isfinite(losses)), losses
        assert int(state["step"]) == 3
        print("LOSSES", losses)
    """)
    assert "LOSSES" in out


def test_gpipe_pipeline_parallelism():
    """GPipe over an 8-deep pipe axis == sequential stage application."""
    out = _run_subprocess("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.distributed.pipeline import gpipe
        from repro.launch.mesh import make_mesh
        S, M, mb, d = 8, 16, 4, 16
        mesh = make_mesh((S,), ("pipe",))
        keys = jax.random.split(jax.random.PRNGKey(0), S)
        params = {"w": jnp.stack([jax.random.normal(k, (d, d)) / np.sqrt(d) for k in keys]),
                  "b": jnp.zeros((S, d))}
        xs = jax.random.normal(jax.random.PRNGKey(1), (M, mb, d))
        stage = lambda p, x: jnp.tanh(x @ p["w"] + p["b"])
        with mesh:
            y = gpipe(stage, params, xs, mesh, axis="pipe")
        # sequential reference
        ref = xs
        for i in range(S):
            ref = stage({"w": params["w"][i], "b": params["b"][i]}, ref)
        err = float(jnp.max(jnp.abs(y - ref)))
        assert err < 1e-5, err
        print("GPIPE-OK", err)
    """)
    assert "GPIPE-OK" in out


def test_wire_compression_shard_map():
    """int8 EF all-reduce over a pod axis inside shard_map: grads match the
    uncompressed mean within one quantisation step."""
    out = _run_subprocess("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.distributed.sharding import shard_map
        from repro.launch.mesh import make_mesh
        from repro.optim.compression import psum_compressed
        mesh = make_mesh((4, 2), ("pod", "data"))
        g = jax.random.normal(jax.random.PRNGKey(0), (4, 64))  # per-pod grads
        err = jnp.zeros((4, 64))
        def f(g, e):
            mean, new_e = psum_compressed({"g": g[0]}, {"g": e[0]}, "pod")
            return mean["g"], new_e["g"][None]
        fn = shard_map(f, mesh=mesh, in_specs=(P("pod"), P("pod")),
                       out_specs=(P(), P("pod")), axis_names={"pod"})
        with mesh:
            mean, new_err = fn(g, err)
        ref = g.mean(0)
        err_bound = float(jnp.abs(g).max()) / 127 + 1e-6
        assert float(jnp.abs(mean - ref).max()) <= err_bound
        print("COMPRESS-OK")
    """)
    assert "COMPRESS-OK" in out


def test_param_shardings_cover_all_leaves():
    from repro.configs import registry
    from repro.models import model as M
    from repro.distributed import sharding as shd

    mesh = _mesh((1, 1), ("data", "model"))
    for arch in ["yi-6b", "jamba-1.5-large", "whisper-base"]:
        cfg = registry.get(arch, reduced=True)
        specs = M.build_specs(cfg)
        sh = shd.sharding_tree(specs, mesh)
        n_specs = len(jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, ParamSpec)))
        n_sh = len(jax.tree.leaves(sh))
        assert n_specs == n_sh > 0
