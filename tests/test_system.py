"""End-to-end behaviour: training reduces loss; attention/mixing substrates
agree with naive references; the paper's complexity claims hold at system
level."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.data.pipeline import DataConfig
from repro.launch.mesh import make_local_mesh
from repro.launch.train import TrainHParams, train_loop
from repro.models.layers import Runtime, chunked_attention

RT = Runtime(mesh=None)


def _train(arch, steps=30, **cfg_over):
    cfg = dataclasses.replace(registry.get(arch, reduced=True), remat=False, **cfg_over)
    mesh = make_local_mesh()
    hp = TrainHParams(peak_lr=3e-3, warmup=5, total_steps=steps)
    dc = DataConfig(vocab=cfg.vocab, seq_len=32, global_batch=8)
    _, hist = train_loop(cfg, mesh, hp, dc, steps=steps, log_every=0)
    return hist


def test_training_reduces_loss_dense():
    hist = _train("qwen3-0.6b")
    assert hist[-1] < hist[0] - 0.5, (hist[0], hist[-1])


def test_training_reduces_loss_butterfly():
    """The paper's technique trains: BPMM layers learn the same synthetic
    stream (accuracy-proxy for paper Fig. 11 / Table II)."""
    hist = _train("yi-6b+bpmm")
    assert hist[-1] < hist[0] - 0.5, (hist[0], hist[-1])


def test_training_reduces_loss_fabnet():
    """FABNet (FFT attention + BPMM FFN) — the paper's own benchmark model."""
    hist = _train("fabnet-base")
    assert hist[-1] < hist[0] - 0.3, (hist[0], hist[-1])


def test_chunked_attention_matches_naive():
    """Chunked-prefix attention == naive masked softmax attention."""
    b, s, h, kv, hd = 2, 32, 4, 2, 16
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (b, s, h, hd))
    k = jax.random.normal(ks[1], (b, s, kv, hd))
    v = jax.random.normal(ks[2], (b, s, kv, hd))

    def naive(q, k, v, causal=True, window=None):
        g = h // kv
        qr = q.reshape(b, s, kv, g, hd)
        scores = jnp.einsum("bqkgd,bskd->bkgqs", qr, k) / np.sqrt(hd)
        qpos, kpos = jnp.arange(s)[:, None], jnp.arange(s)[None, :]
        mask = jnp.ones((s, s), bool)
        if causal:
            mask &= qpos >= kpos
        if window:
            mask &= kpos > qpos - window
        scores = jnp.where(mask[None, None, None], scores, -1e30)
        probs = jax.nn.softmax(scores, -1)
        return jnp.einsum("bkgqs,bskd->bqkgd", probs, v).reshape(b, s, h, hd)

    for causal, window, chunk in [(True, None, 8), (False, None, 16), (True, 8, 8), (True, 12, 4)]:
        out = chunked_attention(q, k, v, causal=causal, window=window, chunk=chunk, rt=RT)
        ref = naive(q, k, v, causal, window)
        err = float(jnp.max(jnp.abs(out - ref)))
        assert err < 1e-4, (causal, window, chunk, err)


def test_swa_window_rounding_is_conservative():
    """Chunk-aligned window start must include (never exclude) valid keys."""
    b, s, h, hd = 1, 32, 2, 8
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (b, s, h, hd))
    k = jax.random.normal(ks[1], (b, s, h, hd))
    v = jax.random.normal(ks[2], (b, s, h, hd))
    # window == s: must equal plain causal regardless of chunking
    a = chunked_attention(q, k, v, causal=True, window=s, chunk=8, rt=RT)
    c = chunked_attention(q, k, v, causal=True, window=None, chunk=8, rt=RT)
    np.testing.assert_allclose(
        np.asarray(a, np.float32), np.asarray(c, np.float32), rtol=1e-5, atol=1e-5
    )


def test_paper_flop_reduction_claim():
    """O(N^2) -> O(N log N): butterfly linear flops shrink by the expected
    asymptotic factor (paper §I: complexity and weight size)."""
    from repro.core.api import LinearSpec, linear_flops, linear_param_count

    n = 4096
    dense = LinearSpec(n, n, "dense")
    r2 = LinearSpec(n, n, "radix2")
    mon = LinearSpec(n, n, "monarch")
    t = 1
    assert linear_flops(r2, t) / linear_flops(dense, t) < 0.01  # 3·logN/2N ~ .004
    assert linear_flops(mon, t) / linear_flops(dense, t) < 0.05  # 2(b+n/b)/2n ~ .03
    assert linear_param_count(r2) / linear_param_count(dense) < 0.01


def test_unroll_layers_matches_scan():
    """The dry-run cost-probe mode computes the same function as the scan."""
    from repro.models import model as M, transformer as tf

    cfg = dataclasses.replace(registry.get("yi-6b", reduced=True), dtype="float32")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab)
    batch = {"tokens": tokens, "labels": tokens}
    l1, _ = tf.forward(params, cfg, batch, RT)
    cfg2 = dataclasses.replace(cfg, unroll_layers=True)
    l2, _ = tf.forward(params, cfg2, batch, RT)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), rtol=1e-5, atol=1e-5)
