"""Mod-window ring page-table translation properties.

A sliding-window request's absolute virtual tile ``j`` lives in page-table
slot ``j % ring_tiles``.  These tests drive arbitrary serve interleavings —
a prompt streamed in chunks, then single-token decode steps, positions
running laps around the ring — through the REAL table builders
(:func:`ring_chunk_tables` / :func:`ring_decode_tables` /
:func:`translate_tables`) against a masked-oracle simulator, checking at
every step:

* phase alignment — every live tile translates to physical slot
  ``tile % ring_tiles``, whatever the interleaving;
* token identity — the ring exposes EXACTLY the window's positions and none
  of them has been overwritten by a later lap (``ring_tiles_for``'s
  ``R * page >= window + page`` slack is what makes this hold at the
  partially-overwritten frontier slot);
* the slot-ordered XLA gather (``ring_kpos``) reproduces masked full-cache
  attention bit-for-bit, GQA included.

The property-based layer runs only where hypothesis is installed; a
deterministic seeded sweep of the same invariant always runs.
"""

import numpy as np
import pytest

from repro.core import sparsity


# --------------------------------------------------------------------------
# The invariant, checked against a masked-oracle ring simulator
# --------------------------------------------------------------------------


def _check_interleaving(window, page, plen, chunk, steps):
    """Simulate one request's life: prompt of ``plen`` tokens admitted in
    ``chunk``-sized pieces, then ``steps`` decode steps.  The simulator
    writes each position's id into its mod-window ring row; the tables must
    expose exactly the oracle window at every step."""
    R = sparsity.ring_tiles_for(window, max(chunk, 1), page)
    # the collision-freedom slack: one step's live span always fits
    assert R * page >= window + page

    ring = np.full((R, page), -1, np.int64)  # absolute position per row
    pt = np.arange(R, dtype=np.int32)[None, :]  # identity table, B=1

    def write(p):
        ring[(p // page) % R, p % page] = p

    def check(kv, live, queries):
        phys, virt, live2 = (
            np.asarray(x)
            for x in sparsity.translate_tables(
                np.asarray(kv), np.asarray(live), pt, R, ring_tiles=R
            )
        )
        tiles = {}
        for t, lv, ph in zip(virt[0], live2[0], phys[0]):
            if lv:
                assert ph == t % R, f"tile {t} in slot {ph} != {t % R}"
                tiles[int(t)] = int(ph)
        for q in queries:
            for p in range(max(0, q - window + 1), q + 1):
                assert p // page in tiles, (
                    f"q={q}: window position {p} not covered "
                    f"(tiles {sorted(tiles)})"
                )
                got = ring[(p // page) % R, p % page]
                assert got == p, (
                    f"q={q}: position {p} lapped — ring row holds {got}"
                )

    pos = 0
    while pos < plen:  # chunked prefill: write the chunk, then attend
        n = min(chunk, plen - pos)
        for p in range(pos, pos + n):
            write(p)
        kv, live = sparsity.ring_chunk_tables([pos], [n], chunk, window, page, R)
        check(kv, live, range(pos, pos + n))
        pos += n
    for _ in range(steps):  # decode: one write + one query per step
        write(pos)
        pos += 1
        kv, live = sparsity.ring_decode_tables([pos], window, page, R)
        check(kv, live, [pos - 1])


# hand-picked corners: window == page, window < page, multi-lap decode,
# chunk larger than window, single-token everything
SWEEP = [
    (4, 4, 9, 4, 14),
    (3, 8, 5, 2, 20),
    (10, 4, 7, 8, 25),
    (10, 4, 1, 1, 30),
    (17, 8, 40, 16, 12),
    (5, 2, 23, 3, 19),
    (1, 1, 3, 1, 9),
    (7, 4, 12, 5, 0),
]


@pytest.mark.parametrize("window,page,plen,chunk,steps", SWEEP)
def test_ring_translation_sweep(window, page, plen, chunk, steps):
    _check_interleaving(window, page, plen, chunk, steps)


def test_ring_translation_random_interleavings():
    """Seeded random sweep — the always-on stand-in for the property test on
    boxes without hypothesis."""
    rng = np.random.default_rng(11)
    for _ in range(20):
        window = int(rng.integers(1, 24))
        page = int(rng.integers(1, 9))
        plen = int(rng.integers(1, 50))
        chunk = int(rng.integers(1, 12))
        steps = int(rng.integers(0, 3 * window + 3))  # several laps
        _check_interleaving(window, page, plen, chunk, steps)


def test_ring_property_hypothesis():
    """Property form: ANY (window, page, plen, chunk, steps) interleaving
    keeps phase alignment and masked-oracle token identity."""
    hyp = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")

    @hyp.settings(max_examples=40, deadline=None)
    @hyp.given(
        window=st.integers(1, 30),
        page=st.integers(1, 8),
        plen=st.integers(1, 60),
        chunk=st.integers(1, 10),
        steps=st.integers(0, 40),
    )
    def prop(window, page, plen, chunk, steps):
        _check_interleaving(window, page, plen, chunk, steps)

    prop()


# --------------------------------------------------------------------------
# Slot-ordered gather == masked full-cache attention (GQA)
# --------------------------------------------------------------------------


def test_ring_gather_decode_matches_masked_oracle_gqa():
    """The XLA ring branch's building blocks — ``gather_pages`` over a
    mod-window table + ``ring_kpos`` absolute positions — reproduce masked
    full-cache decode attention exactly, with 4 query heads over 2 kv heads
    and the frontier deep into the third lap."""
    import jax
    import jax.numpy as jnp

    from repro.models import layers

    window, page = 10, 4
    R = sparsity.ring_tiles_for(window, 1, page)
    L = 37  # cur_len: several laps past R * page rows
    H, KV, hd = 4, 2, 8

    kf, vf, kq = jax.random.split(jax.random.PRNGKey(0), 3)
    k_full = jax.random.normal(kf, (1, L, KV, hd), jnp.float32)
    v_full = jax.random.normal(vf, (1, L, KV, hd), jnp.float32)
    q = jax.random.normal(kq, (1, H, hd), jnp.float32)

    # write the last window's rows ringwise, as the engine's scatter does
    pool_k = np.zeros((R * page, KV, hd), np.float32)
    pool_v = np.zeros((R * page, KV, hd), np.float32)
    for p in range(L):
        r = ((p // page) % R) * page + p % page
        pool_k[r] = np.asarray(k_full[0, p])
        pool_v[r] = np.asarray(v_full[0, p])

    pt = jnp.arange(R, dtype=jnp.int32)[None, :]
    kg = layers.gather_pages(jnp.asarray(pool_k), pt, R * page, page)
    vg = layers.gather_pages(jnp.asarray(pool_v), pt, R * page, page)
    kpos = layers.ring_kpos(jnp.asarray([L - 1]), page, R)
    mask = (kpos < L) & (kpos > L - 1 - window)
    out = layers.decode_attention(q, kg, vg, None, pattern_mask=mask)

    opos = jnp.arange(L, dtype=jnp.int32)[None, :]
    omask = (opos < L) & (opos > L - 1 - window)
    ref = layers.decode_attention(q, k_full, v_full, None, pattern_mask=omask)

    assert float(jnp.max(jnp.abs(out - ref))) < 1e-6
