"""Block-sparse attention: liveness maps, true tile skipping, and parity of
every pattern against the masked dense oracle (token-level expansion of the
same block map) — prefill and decode, fused kernel and XLA form.

The regression that matters: statically-dead tiles must be ABSENT from the
kernel grid (inspected via the block map's packed kv-tile index table that IS
the grid's index map), not merely masked inside it.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import sparsity
from repro.core.attention import AttentionSpec, attention_flops, attention_hbm_bytes
from repro.kernels import ops, ref
from repro.models.layers import Runtime, run_attention, run_decode_attention

RT = Runtime(mesh=None)
ATOL = 2e-5

# (pattern, pattern_arg, b, s, h, kvh, hd, causal, q_tile)
PATTERN_SWEEP = [
    ("butterfly", None, 2, 512, 4, 2, 16, True, 128),  # GQA causal
    ("butterfly", None, 1, 509, 4, 4, 16, False, 128),  # prime S, non-causal
    ("butterfly", None, 1, 256, 4, 2, 16, True, 64),  # q_tile != kv_tile span
    ("strided", 2, 1, 512, 4, 2, 16, True, 128),
    ("strided", None, 1, 384, 6, 3, 8, True, 128),
    ("global_window", 1, 2, 512, 4, 2, 16, True, 128),
    ("global_window", 1, 1, 1021, 4, 4, 16, False, 128),  # prime, non-causal
]


def _qkv(b, s, h, kvh, hd, key=0):
    ks = jax.random.split(jax.random.PRNGKey(key), 3)
    q = jax.random.normal(ks[0], (b, s, h, hd), jnp.float32)
    k = jax.random.normal(ks[1], (b, s, kvh, hd), jnp.float32)
    v = jax.random.normal(ks[2], (b, s, kvh, hd), jnp.float32)
    return q, k, v


def _oracle(q, k, v, pattern, arg, causal, q_tile, kv_tile=128):
    """Masked dense oracle: same block map, token-expanded."""
    tq, tk = sparsity.pick_pattern_tiles(q.shape[1], k.shape[1], q_tile, kv_tile)
    bm = sparsity.build_block_map(
        pattern, q.shape[1], k.shape[1], tq, tk, causal=causal, pattern_arg=arg
    )
    return ref.mha_pattern_reference(q, k, v, jnp.asarray(sparsity.token_mask(bm))), bm


@pytest.mark.parametrize("pattern,arg,b,s,h,kvh,hd,causal,q_tile", PATTERN_SWEEP)
def test_flash_pattern_matches_masked_oracle(pattern, arg, b, s, h, kvh, hd, causal, q_tile):
    q, k, v = _qkv(b, s, h, kvh, hd)
    spec = AttentionSpec(
        impl="flash_kernel", pattern=pattern, pattern_arg=arg, q_tile=q_tile
    )
    y = ops.flash_attention(q, k, v, causal=causal, spec=spec)
    y_ref, bm = _oracle(q, k, v, pattern, arg, causal, q_tile)
    assert bm.live.sum() < bm.live.size, "sweep case is not actually sparse"
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=ATOL, rtol=1e-5)


@pytest.mark.parametrize("pattern,arg,b,s,h,kvh,hd,causal,q_tile", PATTERN_SWEEP)
def test_xla_chunked_pattern_matches_masked_oracle(pattern, arg, b, s, h, kvh, hd, causal, q_tile):
    """The chunked form masks with the SAME map — cross-impl parity."""
    q, k, v = _qkv(b, s, h, kvh, hd, key=1)
    spec = AttentionSpec(
        impl="xla_chunked", pattern=pattern, pattern_arg=arg, q_tile=q_tile, chunk=128
    )
    y = run_attention(q, k, v, spec=spec, causal=causal, rt=RT)
    y_ref, _ = _oracle(q, k, v, pattern, arg, causal, q_tile)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("impl", ["xla_chunked", "flash_kernel"])
def test_window_pattern_alias(impl):
    """pattern='window' == explicit sliding-window flags on both impls."""
    q, k, v = _qkv(2, 160, 4, 2, 16, key=2)
    spec = AttentionSpec(impl=impl, pattern="window", pattern_arg=24, q_tile=16, chunk=32)
    y = run_attention(q, k, v, spec=spec, causal=True, rt=RT)
    y_ref = ref.mha_reference(q, k, v, causal=True, window=24)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=1e-4, rtol=1e-4)


# --------------------------------------------------------------------------
# Liveness regressions: dead tiles are absent from the grid, not masked
# --------------------------------------------------------------------------


def test_butterfly_4k_strictly_fewer_grid_steps_than_dense_causal():
    """Acceptance: butterfly prefill at S=4096 runs strictly fewer kv-tile
    grid steps than dense causal — via the index map that IS the grid."""
    s, t = 4096, 128
    bf = sparsity.build_block_map("butterfly", s, s, t, t, causal=True)
    dense = sparsity.build_block_map("dense", s, s, t, t, causal=True)
    assert bf.grid_steps < dense.grid_steps, (bf.grid_steps, dense.grid_steps)
    # O(N log N): the widest row carries ~log2(n)+1 live tiles, not n
    assert bf.max_live <= bf.n_kv_tiles.bit_length() + 1
    # and the live fraction shrinks accordingly
    assert bf.kv_density < 0.25


def test_dead_tiles_absent_from_index_map():
    """Every packed table entry is a live block; every dead block is absent."""
    for pattern, arg in [("butterfly", None), ("strided", 4), ("global_window", 2)]:
        bm = sparsity.build_block_map(pattern, 2048, 2048, 128, 128, causal=True,
                                      pattern_arg=arg)
        for r in range(bm.n_q_tiles):
            tabled = set(bm.kv_index[r][bm.step_live[r] > 0].tolist())
            live = set(np.nonzero(bm.live[r])[0].tolist())
            assert tabled == live, f"{pattern} row {r}: table {tabled} != live {live}"
        assert not bm.live.all(), f"{pattern}: map is dense — nothing skipped"


def test_decode_tables_read_only_live_tiles():
    """A 130-token request on a 2048 cache streams 2 kv tiles, not 16; a
    butterfly row at full depth streams O(log n) tiles."""
    cur = jnp.array([130, 2048], jnp.int32)
    ki, sl = sparsity.decode_live_tables("dense", cur, 2048, 128, 128)
    live0 = np.asarray(ki[0][np.asarray(sl[0]) > 0])
    assert set(live0.tolist()) == {0, 1}, live0  # ceil(130/128) written tiles
    ki_b, sl_b = sparsity.decode_live_tables("butterfly", cur, 2048, 128, 128)
    n_tiles = 2048 // 128
    assert ki_b.shape[1] <= n_tiles.bit_length() + 1  # static grid extent
    full_row = set(np.asarray(ki_b[1][np.asarray(sl_b[1]) > 0]).tolist())
    expect = {j for j in range(16) if bin(15 ^ j).count("1") <= 1}
    assert full_row == expect, (full_row, expect)


def test_flash_decode_kv_live_static_truncation():
    """kv_live slices the streamed cache (grid shrinks); output is exact."""
    ks = jax.random.split(jax.random.PRNGKey(7), 3)
    b, h, kvh, hd, cache = 2, 4, 2, 16, 1024
    q = jax.random.normal(ks[0], (b, h, hd), jnp.float32)
    kc = jax.random.normal(ks[1], (b, cache, kvh, hd), jnp.float32)
    vc = jax.random.normal(ks[2], (b, cache, kvh, hd), jnp.float32)
    cur = jnp.array([97, 130], jnp.int32)
    for spec in (AttentionSpec(impl="flash_kernel"), AttentionSpec()):
        y = run_decode_attention(q, kc, vc, cur, spec=spec, rt=RT, kv_live=256)
        y_ref = ref.mha_decode_reference(q, kc, vc, cur)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=ATOL, rtol=1e-5)


# --------------------------------------------------------------------------
# Decode == prefill under every pattern (incl. window edge at pos < window)
# --------------------------------------------------------------------------


@pytest.mark.parametrize("pattern,arg", [
    ("butterfly", None), ("strided", 2), ("global_window", 1),
])
@pytest.mark.parametrize("impl", ["xla_chunked", "flash_kernel"])
def test_pattern_decode_matches_prefill_last_token(pattern, arg, impl):
    b, s, h, kvh, hd = 2, 512, 4, 2, 16
    q, k, v = _qkv(b, s, h, kvh, hd, key=5)
    spec = AttentionSpec(impl=impl, pattern=pattern, pattern_arg=arg, chunk=128)
    full = run_attention(q, k, v, spec=spec, causal=True, rt=RT)
    last = run_decode_attention(q[:, -1], k, v, jnp.int32(s), spec=spec, rt=RT)
    np.testing.assert_allclose(
        np.asarray(last), np.asarray(full[:, -1]), atol=1e-4, rtol=1e-4
    )


@pytest.mark.parametrize("impl", ["xla_chunked", "flash_kernel"])
def test_window_pattern_decode_edge_below_window(impl):
    """Window-pattern decode at pos < window: the whole (short) prefix lives."""
    b, s, h, kvh, hd, win = 1, 272, 4, 2, 16, 160
    q, k, v = _qkv(b, s, h, kvh, hd, key=6)
    spec = AttentionSpec(impl=impl, pattern="window", pattern_arg=win, chunk=64)
    full = run_attention(q, k, v, spec=spec, causal=True, rt=RT)
    for pos in (12, win - 1, win + 40, s - 1):  # below, at, and past the edge
        last = run_decode_attention(
            q[:, pos], k[:, : pos + 1], v[:, : pos + 1], jnp.int32(pos + 1),
            spec=spec, rt=RT,
        )
        np.testing.assert_allclose(
            np.asarray(last), np.asarray(full[:, pos]), atol=1e-4, rtol=1e-4,
            err_msg=f"pos {pos}",
        )


def test_pattern_decode_per_row_ragged():
    """Ragged butterfly decode: each row masks by its OWN position's live
    tile set (flash tables vs per-row XLA mask vs per-row oracle)."""
    b, h, kvh, hd, cache = 3, 4, 2, 16, 512
    ks = jax.random.split(jax.random.PRNGKey(8), 3)
    q = jax.random.normal(ks[0], (b, h, hd), jnp.float32)
    kc = jax.random.normal(ks[1], (b, cache, kvh, hd), jnp.float32)
    vc = jax.random.normal(ks[2], (b, cache, kvh, hd), jnp.float32)
    cur = jnp.array([70, 300, 512], jnp.int32)
    outs = {}
    for impl in ("xla_chunked", "flash_kernel"):
        spec = AttentionSpec(impl=impl, pattern="butterfly")
        outs[impl] = run_decode_attention(q, kc, vc, cur, spec=spec, rt=RT)
    np.testing.assert_allclose(
        np.asarray(outs["xla_chunked"]), np.asarray(outs["flash_kernel"]),
        atol=ATOL, rtol=1e-5,
    )
    tmask = sparsity.decode_token_mask("butterfly", cur, cache, 128, 128)
    m = np.asarray(tmask & (jnp.arange(cache)[None, :] < cur[:, None]))
    for i in range(b):
        sc = jnp.einsum(
            "kgd,skd->kgs", np.asarray(q[i]).reshape(kvh, h // kvh, hd),
            np.asarray(kc[i], np.float32),
        ) / np.sqrt(hd)
        sc = jnp.where(jnp.asarray(m[i])[None, None, :], sc, -1e30)
        pr = jax.nn.softmax(sc, -1)
        o = jnp.einsum("kgs,skd->kgd", pr, np.asarray(vc[i], np.float32))
        np.testing.assert_allclose(
            np.asarray(outs["flash_kernel"][i]).reshape(kvh, h // kvh, hd),
            np.asarray(o), atol=ATOL, rtol=1e-5, err_msg=f"row {i}",
        )


# --------------------------------------------------------------------------
# Gradients, accounting, config plumbing
# --------------------------------------------------------------------------


def test_pattern_flash_is_differentiable():
    """Sparse training falls back to the masked-oracle VJP."""
    q, k, v = _qkv(1, 256, 2, 2, 8, key=9)
    spec = AttentionSpec(impl="flash_kernel", pattern="butterfly")

    def loss(q, k, v):
        return jnp.sum(run_attention(q, k, v, spec=spec, causal=True, rt=RT) ** 2)

    g_flash = jax.grad(loss, argnums=(0, 1, 2))(q, k, v)
    y_ref, bm = _oracle(q, k, v, "butterfly", None, True, 128)
    mask = jnp.asarray(sparsity.token_mask(bm))
    g_ref = jax.grad(
        lambda q, k, v: jnp.sum(ref.mha_pattern_reference(q, k, v, mask) ** 2),
        argnums=(0, 1, 2),
    )(q, k, v)
    for gf, gr in zip(g_flash, g_ref):
        np.testing.assert_allclose(np.asarray(gf), np.asarray(gr), atol=1e-3, rtol=1e-3)


def test_pattern_accounting_density():
    """Analytic FLOPs/HBM scale by the block map's density on the fused form;
    the XLA form keeps full traffic (mask-only — the paper's Fig. 2 point)."""
    s, h, hd = 4096, 16, 64
    fl_dense = attention_flops(1, s, s, h, hd, causal=True)
    fl_bf = attention_flops(1, s, s, h, hd, causal=True, pattern="butterfly")
    assert fl_bf < 0.5 * fl_dense
    spec_f = AttentionSpec(impl="flash_kernel", pattern="butterfly")
    spec_fd = AttentionSpec(impl="flash_kernel")
    args = (1, s, s, h, h, hd)
    assert attention_hbm_bytes(spec_f, *args) < attention_hbm_bytes(spec_fd, *args)
    spec_x = AttentionSpec(impl="xla_chunked", pattern="butterfly")
    spec_xd = AttentionSpec(impl="xla_chunked")
    assert attention_hbm_bytes(spec_x, *args) == attention_hbm_bytes(spec_xd, *args)


def test_registry_pattern_variants_and_hybrid():
    from repro.configs import registry

    cfg = registry.get("yi-6b+flash+butterfly_attn", reduced=True)
    assert cfg.attention.impl == "flash_kernel"
    assert cfg.attention.pattern == "butterfly"
    cfg2 = registry.get("qwen3-0.6b+strided_attn", reduced=True)
    assert cfg2.attention.pattern == "strided"
    hy = registry.get("hybrid-butterfly", reduced=True)
    pats = [s.attn_pattern for s in hy.period_slots]
    mixers = [s.mixer for s in hy.period_slots]
    assert "butterfly" in pats and "fft" in mixers  # §III: sparse attn + FFT tail
    with pytest.raises(ValueError, match="unknown attention pattern"):
        AttentionSpec(pattern="nope")


def test_hybrid_model_forward_impl_parity():
    """The §III hybrid stack produces the same logits under both impls."""
    from repro.configs import registry
    from repro.models import model as M
    from repro.models import transformer as tf

    base = dataclasses.replace(
        registry.get("hybrid-butterfly", reduced=True), dtype="float32"
    )
    params = M.init_params(base, jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, base.vocab)
    outs = {}
    for impl in ("xla_chunked", "flash_kernel"):
        cfg = dataclasses.replace(
            base, attention=dataclasses.replace(base.attention, impl=impl)
        )
        outs[impl], _ = tf.forward(params, cfg, {"tokens": tokens}, RT, mode="train")
    scale = float(jnp.max(jnp.abs(outs["xla_chunked"])))
    err = float(jnp.max(jnp.abs(outs["xla_chunked"] - outs["flash_kernel"])))
    assert err < 1e-4 * max(scale, 1.0), err


def test_serve_loop_sparse_decode_buckets():
    """The engine's decode streams the bucketed live prefix, not the padded
    cache, and still matches isolated greedy decoding."""
    from repro.configs import registry
    from repro.launch.mesh import make_local_mesh
    from repro.launch.serve import Request, ServeLoop
    from repro.models import model as M
    from repro.models import transformer as tf

    cfg = dataclasses.replace(
        registry.get("qwen3-0.6b", reduced=True), dtype="float32",
        attention=AttentionSpec(impl="flash_kernel", q_tile=8, pattern="butterfly"),
    )
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(4)
    reqs = [
        Request(uid=i, prompt=rng.integers(0, cfg.vocab, size=ln).astype(np.int32),
                max_new=mn)
        for i, (ln, mn) in enumerate([(5, 4), (3, 6)])
    ]
    loop = ServeLoop(cfg, make_local_mesh(), params, batch=2, cache_len=64)
    done = loop.run(reqs)
    assert loop.stats["decode_kv_live_max"] < 64  # streamed < padded cache
    for r in done:
        logits, caches = tf.prefill(
            params, cfg, {"tokens": jnp.asarray(np.asarray(r.prompt)[None])},
            RT, cache_len=64,
        )
        nxt = int(jnp.argmax(logits[0]))
        expect = [nxt]
        for j in range(r.max_new - 1):
            logits, caches = tf.decode_step(
                params, cfg, caches, jnp.asarray([[nxt]], jnp.int32),
                jnp.int32(len(r.prompt) + j), RT,
            )
            nxt = int(jnp.argmax(logits[0]))
            expect.append(nxt)
        assert r.generated == expect, f"uid {r.uid}"
